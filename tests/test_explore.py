"""ChannelTable IR, batched Max-Plus analysis, and the sweep/admission
design-space-exploration subsystem."""

import dataclasses

import numpy as np
import pytest

from repro.core import (
    DYNAP_SE,
    AdmissionError,
    HardwareState,
    bind_ours,
    bind_pycarl,
    bind_spinemap,
    build_app,
    build_static_orders,
    mcr_howard,
    partition_greedy,
    runtime_admit,
    score_free_tile_subsets,
    sdfg_from_clusters,
    single_tile_order,
    small_app,
    sweep,
)
from repro.core.maxplus import mcr_batch, stack_graphs, throughput_batch
from repro.core.sdfg import (
    KIND_BUFFER,
    KIND_ORDER,
    KIND_SELF,
    Channel,
    ChannelTable,
    SDFG,
    hardware_aware_sdfg,
)


@pytest.fixture(scope="module")
def compiled():
    snn = small_app(260, 3200, seed=31)
    cl = partition_greedy(snn, DYNAP_SE)
    app = sdfg_from_clusters(cl, hw=DYNAP_SE)
    return snn, cl, app


# ======================================================================
# ChannelTable IR
# ======================================================================
def test_channel_table_roundtrip():
    chans = [
        Channel(0, 1, 0, 2.0, delay=0.5, kind="data"),
        Channel(1, 0, 3, 2.0, kind="buffer"),
        Channel(2, 2, 1, 1.0, kind="self"),
    ]
    t = ChannelTable.from_channels(chans)
    assert len(t) == 3
    assert list(t) == chans                       # iterator view round-trips
    assert t[1] == chans[1]
    assert t.kind_names() == ["data", "buffer", "self"]


def test_sdfg_accepts_list_and_stores_table():
    g = SDFG(
        n_actors=2,
        exec_time=np.array([1.0, 2.0]),
        channels=[Channel(0, 1, 0, 1.0), Channel(1, 0, 1, 1.0)],
    )
    assert isinstance(g.channels, ChannelTable)
    src, dst, w, m = g.edges_arrays()
    np.testing.assert_array_equal(src, [0, 1])
    np.testing.assert_array_equal(m, [0, 1])
    np.testing.assert_allclose(w, [2.0, 1.0])     # tau[dst] + delay


def test_clustered_channel_arrays_match_dict_view(compiled):
    _, cl, _ = compiled
    d = cl.channel_spikes                          # compat dict view
    assert len(d) == cl.n_channels
    for i, j, r in zip(cl.channel_src, cl.channel_dst, cl.channel_rate):
        assert d[(int(i), int(j))] == pytest.approx(float(r))
    # arrays are (src, dst)-sorted: deterministic IR for stacking
    key = cl.channel_src * cl.n_clusters + cl.channel_dst
    assert np.all(np.diff(key) > 0)


def test_hardware_aware_sdfg_structure(compiled):
    _, cl, app = compiled
    b = bind_ours(cl, DYNAP_SE)
    orders, _ = build_static_orders(app, b.binding, DYNAP_SE)
    g = hardware_aware_sdfg(app, b.binding, DYNAP_SE, orders)
    t = g.table
    n_self = int((t.kind == KIND_SELF).sum())
    assert n_self == app.n_actors
    # every non-self app channel got a buffer back-edge
    n_data = cl.n_channels
    assert int((t.kind == KIND_BUFFER).sum()) == n_data
    # order cycles close per tile (one wrap-around token each)
    order_mask = t.kind == KIND_ORDER
    if order_mask.any():
        assert t.tokens[order_mask].sum() == sum(
            1 for o in orders if len([a for a in o]) > 1
        )
    assert g.is_live()


# ======================================================================
# batched analysis vs per-graph Howard
# ======================================================================
def test_mcr_batch_matches_howard_across_bindings(compiled):
    _, cl, app = compiled
    rng = np.random.default_rng(7)
    graphs = []
    for binder in (bind_ours, bind_spinemap, bind_pycarl):
        b = binder(cl, DYNAP_SE)
        orders, _ = build_static_orders(app, b.binding, DYNAP_SE)
        graphs.append(hardware_aware_sdfg(app, b.binding, DYNAP_SE, orders))
    for _ in range(5):
        binding = rng.integers(0, DYNAP_SE.n_tiles, size=app.n_actors)
        graphs.append(hardware_aware_sdfg(app, binding, DYNAP_SE))
    expected = np.array([mcr_howard(g) for g in graphs])
    got = mcr_batch(stack_graphs(graphs), backend="edges")
    np.testing.assert_allclose(got, expected, rtol=1e-6)


def test_mcr_batch_matches_howard_on_real_apps():
    """Acceptance shape at test scale: stacked real-app graphs, mixed
    topologies and actor counts, 1e-6 relative vs per-graph Howard."""
    graphs = []
    for name in ("ImgSmooth", "MLP-MNIST"):
        cl = partition_greedy(build_app(name), DYNAP_SE)
        app = sdfg_from_clusters(cl, hw=DYNAP_SE)
        for binder in (bind_ours, bind_spinemap):
            b = binder(cl, DYNAP_SE)
            orders, _ = build_static_orders(app, b.binding, DYNAP_SE)
            graphs.append(hardware_aware_sdfg(app, b.binding, DYNAP_SE, orders))
    expected = np.array([mcr_howard(g) for g in graphs])
    got = mcr_batch(stack_graphs(graphs), backend="edges")
    np.testing.assert_allclose(got, expected, rtol=1e-6)


def test_mcr_batch_dense_kernel_backend(compiled):
    """The Pallas/jnp dense path (float32 matrix squaring) agrees loosely."""
    _, cl, app = compiled
    rng = np.random.default_rng(3)
    graphs = [
        hardware_aware_sdfg(
            app, rng.integers(0, 4, size=app.n_actors), DYNAP_SE
        )
        for _ in range(4)
    ]
    expected = np.array([mcr_howard(g) for g in graphs])
    got = mcr_batch(stack_graphs(graphs), backend="dense")
    np.testing.assert_allclose(got, expected, rtol=1e-3)


@pytest.mark.parametrize("backend", ["edges", "dense"])
def test_throughput_batch_zero_for_acyclic(backend):
    g_line = SDFG(
        n_actors=2,
        exec_time=np.array([1.0, 1.0]),
        channels=[Channel(0, 1, 0, 1.0)],
    )
    thr = throughput_batch([g_line], backend=backend)
    assert thr.shape == (1,)
    assert thr[0] == 0.0


# ======================================================================
# sweep API
# ======================================================================
def test_sweep_report_matches_per_graph_loop(compiled):
    snn, _, _ = compiled
    batched = sweep(
        [snn], crossbar_sizes=(64, 128), tile_counts=(1, 4),
        binders=("ours", "spinemap"),
    )
    looped = sweep(
        [snn], crossbar_sizes=(64, 128), tile_counts=(1, 4),
        binders=("ours", "spinemap"), method="howard-loop",
    )
    assert batched.n_candidates == looped.n_candidates == 8
    for pb, pl_ in zip(batched.points, looped.points):
        assert (pb.app, pb.crossbar, pb.n_tiles, pb.binder) == (
            pl_.app, pl_.crossbar, pl_.n_tiles, pl_.binder
        )
        assert pb.throughput == pytest.approx(pl_.throughput, rel=1e-6)
    best = batched.best(snn.name)
    assert best.throughput == max(p.throughput for p in batched.points)


# ======================================================================
# run-time admission: error + batched tile-subset scoring
# ======================================================================
def test_admission_rejects_oversized_request(compiled):
    _, cl, _ = compiled
    order, _ = single_tile_order(cl, DYNAP_SE)
    state = HardwareState(DYNAP_SE)
    state.allocated["other"] = [0, 1, 2]
    with pytest.raises(AdmissionError, match="requested 2 tiles but only 1"):
        runtime_admit(cl, state, order, n_tiles_request=2)
    with pytest.raises(AdmissionError, match="no free tiles"):
        state.allocated["more"] = [3]
        runtime_admit(cl, state, order)


def test_admission_subset_scoring_beats_first_k(compiled):
    _, cl, _ = compiled
    order, _ = single_tile_order(cl, DYNAP_SE)
    best = runtime_admit(
        cl, HardwareState(DYNAP_SE), order, n_tiles_request=2
    )
    first = runtime_admit(
        cl, HardwareState(DYNAP_SE), order, n_tiles_request=2,
        tile_selection="first",
    )
    assert best.throughput >= first.throughput * (1 - 1e-9)
    assert len(set(best.binding.tolist())) <= 2


def test_score_free_tile_subsets_consistent(compiled):
    _, cl, _ = compiled
    order, _ = single_tile_order(cl, DYNAP_SE)
    hw16 = dataclasses.replace(DYNAP_SE, n_tiles=16)
    scores = score_free_tile_subsets(
        cl, hw16, list(range(8)), 2, order, max_candidates=16
    )
    assert len(scores.throughputs) == len(scores.subsets) <= 16
    assert scores.best == scores.subsets[int(np.argmax(scores.throughputs))]
    assert np.all(scores.throughputs > 0)
    # the virtual binding is reusable by runtime_admit: k-tile ids only
    assert set(scores.binding.tolist()) <= {0, 1}
    assert sorted(a for o in scores.virt_orders for a in o) == list(
        range(cl.n_clusters)
    )

"""Chip-level objective layer: batched energy model, rectangular-mesh
regression, Pareto binding optimization, multi-app joint placement, and
per-controller compile-cache counters."""

import dataclasses
import itertools

import numpy as np
import pytest

import repro.core.engine as engine_mod
from repro.core import (
    APP_NAMES,
    DYNAP_SE,
    AdmissionController,
    HardwareConfig,
    batch_execute,
    build_app,
    cut_spikes,
    cut_spikes_batch,
    disjoint_union,
    mcr_howard,
    optimize_binding,
    partition_greedy,
    project_order_batch,
    score_free_tile_subsets,
    sdfg_from_clusters,
    single_tile_order,
    small_app,
    sweep,
)

HW9 = dataclasses.replace(DYNAP_SE, n_tiles=9)


@pytest.fixture(scope="module")
def tiny():
    snn = small_app(260, 3200, seed=31)
    return partition_greedy(snn, DYNAP_SE)


@pytest.fixture(scope="module")
def tiny_app(tiny):
    return sdfg_from_clusters(tiny, hw=DYNAP_SE)


# ======================================================================
# hardware: rectangular mesh regression (8- and 12-tile chips)
# ======================================================================
@pytest.mark.parametrize(
    "n_tiles,shape", [(4, (2, 2)), (8, (2, 4)), (9, (3, 3)),
                      (12, (3, 4)), (16, (4, 4)), (2, (1, 2)),
                      (1024, (32, 32))]
)
def test_mesh_shape_exact_factorization(n_tiles, shape):
    hw = dataclasses.replace(DYNAP_SE, n_tiles=n_tiles)
    c, r = hw.mesh_shape
    assert (c, r) == shape
    assert c * r == n_tiles                      # no out-of-mesh tiles


@pytest.mark.parametrize("n_tiles", [8, 12])
def test_rectangular_mesh_coordinates_and_hops(n_tiles):
    """Regression for the old square-only isqrt mesh: on 8- and 12-tile
    chips every tile must sit inside the declared mesh, and hop counts
    must be a genuine Manhattan metric on that rectangle."""
    hw = dataclasses.replace(DYNAP_SE, n_tiles=n_tiles)
    c, r = hw.mesh_shape
    for t in range(n_tiles):
        assert 0 <= t % c < c and 0 <= t // c < r
    pairs = list(itertools.product(range(n_tiles), repeat=2))
    src = np.array([p[0] for p in pairs])
    dst = np.array([p[1] for p in pairs])
    hops = hw.hops_array(src, dst)
    # vectorized == scalar, symmetric, zero-diagonal, bounded by the mesh
    assert all(int(h) == hw.hops(int(s), int(d))
               for s, d, h in zip(src, dst, hops))
    h_mat = hops.reshape(n_tiles, n_tiles)
    np.testing.assert_array_equal(h_mat, h_mat.T)
    assert np.all(np.diag(h_mat) == 0)
    assert h_mat.max() == (c - 1) + (r - 1)      # opposite mesh corners
    # triangle inequality on a metric mesh
    for a, b, m in itertools.product(range(n_tiles), repeat=3):
        assert h_mat[a, b] <= h_mat[a, m] + h_mat[m, b]


def test_comm_delay_array_on_rectangular_mesh():
    hw = dataclasses.replace(DYNAP_SE, n_tiles=8)
    src = np.array([0, 3, 7, 2])
    dst = np.array([0, 7, 1, 2])
    got = hw.comm_delay_array(np.full(4, 10.0), src, dst)
    want = [hw.comm_delay(10.0, int(s), int(d)) for s, d in zip(src, dst)]
    np.testing.assert_allclose(got, want)
    assert got[0] == got[3] == 0.0               # same-tile pairs are free


# ======================================================================
# hardware: batched energy model
# ======================================================================
def test_energy_array_mirrors_comm_delay_array():
    hw = DYNAP_SE
    src = np.array([0, 0, 0, 1])
    dst = np.array([0, 1, 3, 2])
    rates = np.array([5.0, 5.0, 5.0, 0.0])
    e = hw.energy_array(rates, src, dst)
    assert e[0] == 0.0                           # co-located: free
    assert e[3] == 0.0                           # no spikes: free
    hops = hw.hops_array(src, dst)
    np.testing.assert_allclose(
        e, np.where(hops == 0, 0.0,
                    rates * (hw.e_packet_encode + hw.e_link_hop * hops))
    )
    assert e[2] > e[1] > 0                       # more hops, more energy


def test_chip_energy_terms_and_dead_rows():
    hw = DYNAP_SE
    e = hw.chip_energy(
        periods=np.array([10.0, np.inf, -1.0]),
        cut_traffic=np.array([100.0, 0.0, 0.0]),
        spike_hops=np.array([150.0, 0.0, 0.0]),
        tiles_used=np.array([4, 1, 1]),
        read_charge=1000.0,
    )
    want = (hw.e_spike_read * 1000.0 + hw.e_packet_encode * 100.0
            + hw.e_link_hop * 150.0 + hw.p_tile_idle * 4 * 10.0)
    assert e[0] == pytest.approx(want)
    assert np.isinf(e[1]) and np.isinf(e[2])     # dead rows


# ======================================================================
# binding: vectorized cut_spikes
# ======================================================================
def test_cut_spikes_batch_matches_scalar(tiny):
    rng = np.random.default_rng(5)
    bindings = rng.integers(0, 4, size=(12, tiny.n_clusters))
    got = cut_spikes_batch(tiny, bindings)
    want = np.array([cut_spikes(tiny, b) for b in bindings])
    np.testing.assert_allclose(got, want)
    # single (n,) binding promotes to B=1
    one = cut_spikes_batch(tiny, bindings[0])
    assert one.shape == (1,)
    assert one[0] == pytest.approx(want[0])


# ======================================================================
# engine: energy out of the same stacked arrays as the period
# ======================================================================
def test_batch_execute_with_energy_matches_manual(tiny, tiny_app):
    rng = np.random.default_rng(11)
    pop = rng.integers(0, 4, size=(6, tiny.n_clusters))
    order, _ = single_tile_order(tiny, DYNAP_SE)
    ob = project_order_batch(order, pop)
    rep = batch_execute(tiny_app, pop, DYNAP_SE, ob, with_energy=True)
    assert rep.energies.shape == rep.periods.shape
    assert np.all(np.isfinite(rep.energies))
    np.testing.assert_allclose(
        rep.metrics.cut_traffic, cut_spikes_batch(tiny, pop)
    )
    hops = DYNAP_SE.hops_array(
        pop[:, tiny.channel_src], pop[:, tiny.channel_dst]
    )
    s_hops = (tiny.channel_rate[None, :] * hops).sum(axis=1)
    np.testing.assert_allclose(rep.metrics.spike_hops, s_hops)
    tiles_used = np.array([len(set(b.tolist())) for b in pop])
    np.testing.assert_array_equal(rep.metrics.tiles_used, tiles_used)
    # crossbar reads scale with the target cluster's mean row length
    row_len = tiny.synapses_used / np.maximum(tiny.inputs_used, 1)
    read_charge = float(
        (np.maximum(tiny.channel_rate, 1e-6)
         * row_len[tiny.channel_dst]).sum()
    )
    assert rep.metrics.read_charge == pytest.approx(read_charge)
    assert rep.metrics.read_charge > rep.metrics.total_spikes  # rows > 1
    want = (
        DYNAP_SE.e_spike_read * rep.metrics.read_charge
        + DYNAP_SE.e_packet_encode * rep.metrics.cut_traffic
        + DYNAP_SE.e_link_hop * s_hops
        + DYNAP_SE.p_tile_idle * tiles_used * rep.periods
    )
    np.testing.assert_allclose(rep.energies, want)


def test_energy_objective_adds_no_stack_build(tiny, monkeypatch):
    """The accumulators ride the stack build's own hop pass: scoring with
    energy still builds ONE EdgeStack per generation (+ final)."""
    calls = []
    real = engine_mod.stack_hardware_aware

    def counting(app, bindings, hw, orders_list=None, **kw):
        calls.append(kw.get("with_metrics", False))
        return real(app, bindings, hw, orders_list, **kw)

    monkeypatch.setattr(engine_mod, "stack_hardware_aware", counting)
    gens, pop = 3, 16
    rep = optimize_binding(
        tiny, DYNAP_SE, population=pop, generations=gens, rng_seed=1,
        objective="pareto",
    )
    assert len(calls) == gens + 1
    assert all(calls)                            # every build carried metrics
    assert rep.n_stack_builds == gens + 1


# ======================================================================
# optimizer objectives: pareto never worse on period, energy never worse
# than the seeds on energy
# ======================================================================
def test_pareto_never_worse_than_period_on_standard_apps():
    """Acceptance invariant: at equal budget, objective="pareto" yields a
    period <= objective="period" on every Table-1 app.  Structural: the
    pareto trajectory is the period trajectory (same rng stream, same
    elites), and its final exact re-score pool is a superset."""
    for name in APP_NAMES:
        cl = partition_greedy(build_app(name), DYNAP_SE)
        kw = dict(population=16, generations=2, elite=4, rng_seed=9)
        rep_p = optimize_binding(cl, DYNAP_SE, objective="period", **kw)
        rep_x = optimize_binding(cl, DYNAP_SE, objective="pareto", **kw)
        assert rep_x.period <= rep_p.period * (1 + 1e-9), name
        # the front is real: non-empty, exact, non-dominated, period-sorted
        assert rep_x.front, name
        periods = [pt.period for pt in rep_x.front]
        energies = [pt.energy for pt in rep_x.front]
        assert periods == sorted(periods), name
        assert energies == sorted(energies, reverse=True), name
        assert rep_x.front[0].period == pytest.approx(rep_x.period), name


def test_energy_objective_never_worse_than_seeds(tiny):
    rep = optimize_binding(
        tiny, DYNAP_SE, population=24, generations=3, rng_seed=3,
        objective="energy",
    )
    assert np.isfinite(rep.energy)
    assert rep.energy <= rep.best_seed_energy * (1 + 1e-9)
    assert rep.energy <= min(rep.seed_energies.values()) * (1 + 1e-9)
    # histories record both metrics
    assert all(np.isfinite(h.best_energy) for h in rep.history)
    assert all(np.isfinite(h.best_period) for h in rep.history)


def test_objective_validation(tiny):
    with pytest.raises(ValueError, match="unknown objective"):
        optimize_binding(tiny, DYNAP_SE, population=8, generations=1,
                         objective="watts")
    with pytest.raises(ValueError, match="unknown objective"):
        AdmissionController(DYNAP_SE, objective="watts")
    with pytest.raises(ValueError, match="unknown placement"):
        AdmissionController(DYNAP_SE, placement="global")


def test_epsilon_front_period_tie_keeps_min_energy():
    from repro.core.optimize import _epsilon_front

    periods = np.array([1.0, 1.0, 2.0, 3.0])
    energies = np.array([10.0, 5.0, 20.0, 4.0])
    idx = _epsilon_front(periods, energies, eps=0.0)
    # row 0 is dominated by row 1 at equal period; row 2 by both
    assert idx.tolist() == [1, 3]


def test_record_cache_stats_removes_by_identity():
    """Two fresh (value-equal) sinks nesting must each unregister their
    OWN object — value-based removal would drop the outer sink on the
    inner exit and leave the dead inner one registered."""
    from repro.core import CompileCacheStats
    from repro.core.engine import _CACHE_SINKS, record_cache_stats

    a, b = CompileCacheStats(), CompileCacheStats()
    assert a == b                                # value-equal, distinct
    with record_cache_stats(a):
        with record_cache_stats(b):
            assert _CACHE_SINKS[-1] is b
        assert len(_CACHE_SINKS) == 1 and _CACHE_SINKS[-1] is a
    assert a not in [s for s in _CACHE_SINKS if s is a]


# ======================================================================
# sdfg: disjoint union
# ======================================================================
def test_disjoint_union_mcr_is_max_of_parts():
    a = sdfg_from_clusters(partition_greedy(small_app(150, 1800, seed=1),
                                            DYNAP_SE), hw=DYNAP_SE)
    b = sdfg_from_clusters(partition_greedy(small_app(200, 2400, seed=2),
                                            DYNAP_SE), hw=DYNAP_SE)
    u = disjoint_union([a, b])
    assert u.n_actors == a.n_actors + b.n_actors
    assert u.is_live()
    assert mcr_howard(u) == pytest.approx(
        max(mcr_howard(a), mcr_howard(b)), rel=1e-9
    )


# ======================================================================
# runtime: joint placement vs isolated on a deterministic churn
# ======================================================================
def _churn(placement, objective="period"):
    ctl = AdmissionController(
        HW9, placement=placement, joint_budget=(2, 12),
        track_chip_metrics=True, objective=objective,
    )
    for i in range(3):
        snn = small_app(180, 2200, seed=50 + i)
        snn.name = f"app{i}"
        ctl.register(snn)
    for i in range(3):
        ctl.admit(f"app{i}", n_tiles_request=3)
    return ctl


def test_joint_placement_never_worse_than_its_isolated_seed():
    iso = _churn("isolated")
    joint = _churn("joint")
    m_iso = iso.chip_metrics()
    m_joint = joint.chip_metrics()
    # identical workload; the isolated placement seeds every rebalance,
    # so the chip period can only improve
    assert m_joint["chip_period"] <= m_iso["chip_period"] * (1 + 1e-9)
    assert m_joint["chip_throughput"] >= m_iso["chip_throughput"] * (1 - 1e-9)
    rebalances = [e for e in joint.events if e.kind == "rebalance"]
    assert len(rebalances) == 2                  # admits 2 and 3
    assert all(e.chip_throughput > 0 for e in rebalances)
    assert all(e.chip_energy > 0 and np.isfinite(e.chip_energy)
               for e in rebalances)
    assert not any(e.kind == "rebalance" for e in iso.events)


def test_joint_placement_keeps_state_consistent():
    ctl = _churn("joint")
    for name, tiles in ctl.running().items():
        rep = ctl.reports[name]
        assert sorted({int(t) for t in rep.binding}) == tiles
        assert rep.throughput > 0
        # every cluster appears exactly once in the app's order slices
        assert sorted(a for o in rep.orders for a in o) == list(
            range(rep.binding.size)
        )
    # joint placement redistributes within the combined footprint only
    foot = {t for ts in ctl.running().values() for t in ts}
    assert foot <= set(range(HW9.n_tiles))
    # eviction triggers one more rebalance over the survivors
    ctl.evict("app0")
    assert ctl.events[-1].kind == "rebalance"
    assert "app0" not in ctl.running()


def test_isolated_default_records_no_chip_metrics():
    ctl = AdmissionController(DYNAP_SE)      # placement="isolated", no track
    snn = small_app(150, 1800, seed=3)
    ctl.register(snn)
    ctl.admit(snn.name, n_tiles_request=2)
    assert all(e.chip_throughput == 0.0 for e in ctl.events)
    # but chip_metrics() works on demand
    m = ctl.chip_metrics()
    assert m["n_resident"] == 1
    assert m["chip_throughput"] > 0 and np.isfinite(m["chip_energy"])


# ======================================================================
# compile-cache counters across AdmissionController lifecycles
# ======================================================================
def test_compile_cache_stats_across_admission_lifecycle():
    ctl = AdmissionController(DYNAP_SE)
    snn = small_app(200, 2400, seed=8)
    ctl.register(snn)
    ctl.admit(snn.name, n_tiles_request=2)
    first = ctl.cache_stats.as_dict()
    assert first["misses"] > 0                   # fresh shapes traced

    art = ctl.artifacts[(snn.name, ctl.hw)]
    hits_before = art.hits
    ctl.finish(snn.name)
    ctl.admit(snn.name, n_tiles_request=2)       # re-admission
    # DesignArtifact cache hit: no re-clustering, no re-ordering
    assert art.hits > hits_before
    second = ctl.cache_stats.as_dict()
    # shape-bucket cache hit: the same stacked shapes are re-analyzed
    assert second["hits"] > first["hits"]
    assert second["misses"] == first["misses"]
    assert second["n_distinct_shapes"] == first["n_distinct_shapes"]


def test_compile_cache_counters_do_not_leak_between_controllers():
    snn = small_app(200, 2400, seed=8)
    a = AdmissionController(DYNAP_SE)
    a.register(snn)
    a.admit(snn.name, n_tiles_request=2)
    snapshot = a.cache_stats.as_dict()

    b = AdmissionController(DYNAP_SE)
    assert b.cache_stats.as_dict()["hits"] == 0
    assert b.cache_stats.as_dict()["misses"] == 0
    b.register(snn)
    b.admit(snn.name, n_tiles_request=2)
    # b counted its own work; a's counters did not move
    assert b.cache_stats.as_dict()["misses"] > 0
    assert a.cache_stats.as_dict() == snapshot


# ======================================================================
# explore: energy metrics in sweeps and subset scoring
# ======================================================================
def test_sweep_reports_energy_and_pareto_front(tiny):
    report = sweep(
        [tiny.snn], tile_counts=(1, 4), binders=("ours", "spinemap"),
    )
    assert all(np.isfinite(p.energy) and p.energy > 0 for p in report.points)
    for p in report.points:
        if p.n_tiles == 1:                       # everything co-located
            assert p.cut_spikes == 0.0 and p.spike_hops == 0.0
        assert p.spike_hops >= p.cut_spikes      # every cut spike hops >= 1
    front = report.pareto_front(tiny.snn.name)
    assert front
    thrs = [p.throughput for p in front]
    es = [p.energy for p in front]
    assert thrs == sorted(thrs, reverse=True)
    assert es == sorted(es, reverse=True)
    # no survivor is dominated (incl. equal-throughput ties)
    for p in front:
        assert not any(
            q.throughput >= p.throughput and q.energy < p.energy
            for q in report.points if q.app == p.app
        )
    # header row gained the new columns
    assert report.rows()[0][-2:] == ("spike_hops", "energy_pj")


def test_score_free_tile_subsets_reports_energies(tiny):
    hw16 = dataclasses.replace(DYNAP_SE, n_tiles=16)
    order, _ = single_tile_order(tiny, hw16)
    scores = score_free_tile_subsets(
        tiny, hw16, list(range(8)), 2, order, max_candidates=16
    )
    assert scores.energies is not None
    assert scores.energies.shape == scores.throughputs.shape
    assert np.all(np.isfinite(scores.energies))
    assert scores.best_energy in scores.subsets

"""Array-native compile front-end: cross-validation of the wave-based
partitioner, the dense batched FCFS order constructor, the OrderBatch
projection path, the shape-bucket compile cache, and the comm-guided
mutation (PR 4)."""

import dataclasses

import numpy as np
import pytest

from repro.core import (
    DYNAP_SE,
    ExecutionTrace,
    OrderBatch,
    SelfTimedExecutor,
    batch_execute,
    bind_ours,
    build_app,
    build_static_orders,
    build_static_orders_batch,
    compile_cache_stats,
    mcr_batch,
    mcr_howard,
    optimize_binding,
    order_cycle_lower_bounds,
    partition_greedy,
    partition_greedy_reference,
    project_order,
    project_order_batch,
    reset_compile_cache_stats,
    sdfg_from_clusters,
    single_tile_order,
    small_app,
    stack_hardware_aware,
)
from repro.core.hardware import HardwareConfig
from repro.core.optimize import _comm_guided_mutate
from repro.core.partition import ClusteredSNN
from repro.core.sdfg import hardware_aware_sdfg
from tests._hypothesis_compat import given, settings, st


# ======================================================================
# wave-based partitioner vs the scalar reference
# ======================================================================
@settings(max_examples=10, deadline=None)
@given(
    st.integers(min_value=40, max_value=320),
    st.integers(min_value=200, max_value=4500),
    st.integers(min_value=0, max_value=1000),
)
def test_wave_partitioner_bit_identical_randomized(n_neurons, n_synapses, seed):
    snn = small_app(n_neurons, n_synapses, seed=seed)
    wave = partition_greedy(snn, DYNAP_SE)
    ref = partition_greedy_reference(snn, DYNAP_SE)
    assert wave.n_clusters == ref.n_clusters
    np.testing.assert_array_equal(wave.cluster_of, ref.cluster_of)
    np.testing.assert_array_equal(wave.inputs_used, ref.inputs_used)
    np.testing.assert_array_equal(wave.synapses_used, ref.synapses_used)
    np.testing.assert_allclose(wave.out_spikes, ref.out_spikes)


@pytest.mark.parametrize("name", ["MLP-MNIST", "CNN-MNIST"])
def test_wave_partitioner_bit_identical_table1(name):
    snn = build_app(name)
    wave = partition_greedy(snn, DYNAP_SE)
    ref = partition_greedy_reference(snn, DYNAP_SE)
    np.testing.assert_array_equal(wave.cluster_of, ref.cluster_of)
    # feasibility is re-checked by check_clustering inside both calls;
    # utilization must therefore agree exactly too
    assert wave.utilization(DYNAP_SE.tile.crossbar) == ref.utilization(
        DYNAP_SE.tile.crossbar
    )


def test_wave_partitioner_small_crossbar():
    """Non-default crossbar geometry exercises different probe dynamics."""
    from repro.core.hardware import CrossbarConfig, TileConfig

    hw = dataclasses.replace(
        DYNAP_SE, tile=TileConfig(crossbar=CrossbarConfig(64, 64, 64 * 64))
    )
    snn = small_app(300, 3600, seed=9)
    np.testing.assert_array_equal(
        partition_greedy(snn, hw).cluster_of,
        partition_greedy_reference(snn, hw).cluster_of,
    )


# ======================================================================
# dense batched FCFS constructor vs the heapq oracle
# ======================================================================
@settings(max_examples=8, deadline=None)
@given(st.integers(min_value=0, max_value=500))
def test_orders_batch_equals_heapq_oracle(seed):
    rng = np.random.default_rng(seed)
    snn = small_app(
        80 + 30 * (seed % 7), 600 + 300 * (seed % 5), seed=seed,
        recurrent=bool(seed % 2),
    )
    cl = partition_greedy(snn, DYNAP_SE)
    app = sdfg_from_clusters(cl, hw=DYNAP_SE)
    bindings = np.stack([
        rng.integers(0, DYNAP_SE.n_tiles, size=app.n_actors)
        for _ in range(4)
    ])
    batch = build_static_orders_batch(app, bindings, DYNAP_SE)
    for b in range(bindings.shape[0]):
        oracle = SelfTimedExecutor(app, bindings[b], DYNAP_SE).run(
            iterations=1
        ).tile_orders
        assert batch[b] == oracle, b


def test_orders_batch_periods_match_operational_oracle():
    """The period of the batch-constructed schedule must equal the
    operational steady state of replaying those very orders (<= 1e-6)."""
    snn = small_app(200, 2400, seed=3)
    cl = partition_greedy(snn, DYNAP_SE)
    hw = dataclasses.replace(
        DYNAP_SE,
        tile=dataclasses.replace(DYNAP_SE.tile, input_buffer=64,
                                 output_buffer=64),
    )
    app = sdfg_from_clusters(cl, hw=hw)
    rng = np.random.default_rng(1)
    bindings = np.stack([
        bind_ours(cl, hw).binding
        if i == 0 else rng.integers(0, hw.n_tiles, size=app.n_actors)
        for i in range(3)
    ])
    orders = build_static_orders_batch(app, bindings, hw)
    rep = batch_execute(app, bindings, hw, orders, backend="edges")
    for b in range(bindings.shape[0]):
        trace: ExecutionTrace = SelfTimedExecutor(
            app, bindings[b], hw, orders=orders[b]
        ).run(iterations=400)
        assert rep.periods[b] == pytest.approx(
            trace.steady_period(), rel=1e-6
        ), b


def test_orders_batch_single_binding_promotes():
    snn = small_app(120, 1200, seed=4)
    cl = partition_greedy(snn, DYNAP_SE)
    app = sdfg_from_clusters(cl, hw=DYNAP_SE)
    b = bind_ours(cl, DYNAP_SE).binding
    batch = build_static_orders_batch(app, b, DYNAP_SE)
    assert len(batch) == 1
    old, _ = build_static_orders(app, b, DYNAP_SE, iterations=1)
    assert batch[0] == old


def test_single_tile_order_methods_agree():
    """The dense single-tile constructor equals the heapq path at the
    §4.4 step-2 horizon (one firing per actor defines the order)."""
    snn = small_app(180, 2200, seed=8)
    cl = partition_greedy(snn, DYNAP_SE)
    fast, _ = single_tile_order(cl, DYNAP_SE)
    slow, _ = single_tile_order(cl, DYNAP_SE, method="heapq",
                                sim_iterations=1)
    assert fast == slow
    assert sorted(fast) == list(range(cl.n_clusters))


# ======================================================================
# OrderBatch: batched Lemma-1 projection == per-candidate list path
# ======================================================================
@pytest.fixture(scope="module")
def projected():
    snn = small_app(260, 3200, seed=31)
    cl = partition_greedy(snn, DYNAP_SE)
    app = sdfg_from_clusters(cl, hw=DYNAP_SE)
    order, _ = single_tile_order(cl, DYNAP_SE)
    rng = np.random.default_rng(7)
    bindings = np.stack([
        rng.integers(0, DYNAP_SE.n_tiles, size=app.n_actors)
        for _ in range(8)
    ])
    return app, order, bindings


def test_project_order_batch_rows_match_project_order(projected):
    app, order, bindings = projected
    ob = project_order_batch(order, bindings)
    assert isinstance(ob, OrderBatch)
    assert ob.n_graphs == bindings.shape[0] and ob.n_actors == app.n_actors
    for b in range(bindings.shape[0]):
        assert ob.row(b, bindings[b], DYNAP_SE.n_tiles) == project_order(
            list(order), bindings[b], DYNAP_SE.n_tiles
        )


def test_order_batch_periods_match_list_path(projected):
    app, order, bindings = projected
    ob = project_order_batch(order, bindings)
    ol = [project_order(list(order), b, DYNAP_SE.n_tiles) for b in bindings]
    rep_ob = batch_execute(app, bindings, DYNAP_SE, ob, backend="edges")
    rep_ol = batch_execute(app, bindings, DYNAP_SE, ol, backend="edges")
    np.testing.assert_allclose(rep_ob.periods, rep_ol.periods, rtol=1e-9)
    # and both match per-graph Howard on the same order-augmented graphs
    expected = [
        mcr_howard(hardware_aware_sdfg(app, b, DYNAP_SE, o))
        for b, o in zip(bindings, ol)
    ]
    np.testing.assert_allclose(rep_ob.periods, expected, rtol=1e-6)


def test_order_batch_shortcut_stack_preserves_mcr(projected):
    app, order, bindings = projected
    ob = project_order_batch(order, bindings)
    plain = stack_hardware_aware(app, bindings, DYNAP_SE, ob)
    fast = stack_hardware_aware(
        app, bindings, DYNAP_SE, ob, relax_shortcuts=True
    )
    assert fast.n_edges >= plain.n_edges
    np.testing.assert_allclose(
        mcr_batch(plain, backend="edges"),
        mcr_batch(fast, backend="edges"),
        rtol=1e-7,
    )


def test_order_batch_lower_bounds_sound_and_match_legacy(projected):
    app, order, bindings = projected
    ob = project_order_batch(order, bindings)
    ol = [project_order(list(order), b, DYNAP_SE.n_tiles) for b in bindings]
    lo_ob = order_cycle_lower_bounds(app.exec_time, bindings, ob)
    lo_ol = order_cycle_lower_bounds(app.exec_time, bindings, ol)
    np.testing.assert_allclose(lo_ob, lo_ol)
    periods = batch_execute(app, bindings, DYNAP_SE, ob,
                            backend="edges").periods
    assert np.all(lo_ob <= periods + 1e-9)


def test_project_order_batch_appends_missing_actors():
    """Defensive parity with project_order: actors absent from the order
    are appended per tile in id order."""
    binding = np.array([1, 0, 1, 0])
    partial = [2, 0]                    # actors 1 and 3 missing
    ob = project_order_batch(partial, binding[None, :])
    assert ob.row(0, binding, 2) == project_order(partial, binding, 2)


# ======================================================================
# shape-bucket compile cache
# ======================================================================
def test_bucket_sizes_pow2ish():
    from repro.core.engine import _bucket_size

    assert [_bucket_size(x) for x in (1, 2, 3, 4, 5, 6, 7, 9, 13, 17)] == [
        1, 2, 3, 4, 6, 6, 8, 12, 16, 24
    ]
    for x in range(1, 500):
        bx = _bucket_size(x)
        assert bx >= x and bx <= 2 * x


def test_pad_stack_to_buckets_preserves_periods(projected):
    from repro.core import pad_stack_to_buckets

    app, order, bindings = projected
    ob = project_order_batch(order, bindings[:5])
    stack = stack_hardware_aware(app, bindings[:5], DYNAP_SE, ob)
    padded, _ = pad_stack_to_buckets(stack)
    assert padded.n_graphs >= stack.n_graphs
    assert padded.n_edges >= stack.n_edges
    np.testing.assert_allclose(
        mcr_batch(stack, backend="edges"),
        mcr_batch(padded, backend="edges")[: stack.n_graphs],
        rtol=1e-9,
    )


def test_cache_counters_hit_on_repeated_shapes(projected):
    app, order, bindings = projected
    ob = project_order_batch(order, bindings)
    reset_compile_cache_stats()
    try:
        batch_execute(app, bindings, DYNAP_SE, ob, backend="edges")
        batch_execute(app, bindings, DYNAP_SE, ob, backend="edges")
        batch_execute(app, bindings[:2], DYNAP_SE,
                      project_order_batch(order, bindings[:2]),
                      backend="edges")
        stats = compile_cache_stats()
        assert stats.hits == 1 and stats.misses == 2
        assert 0.0 < stats.hit_rate < 1.0
        assert stats.as_dict()["n_distinct_shapes"] == 2
    finally:
        reset_compile_cache_stats()


def test_optimizer_generations_share_one_shape(projected):
    """OrderBatch makes the stacked shape generation-invariant: a whole
    optimizer run records exactly ONE distinct scoring shape."""
    snn = small_app(260, 3200, seed=31)
    cl = partition_greedy(snn, DYNAP_SE)
    reset_compile_cache_stats()
    try:
        optimize_binding(cl, DYNAP_SE, population=12, generations=3,
                         rng_seed=0)
        stats = compile_cache_stats()
        # generations at rel_tol 1e-4 + final exact re-score may differ in
        # candidate count (deduped pool) -> at most two distinct shapes
        assert len(stats.shapes) <= 2
        assert stats.hits >= 2
    finally:
        reset_compile_cache_stats()


# ======================================================================
# comm-critical-path guided mutation
# ======================================================================
def _chatty_clusters(n=8) -> ClusteredSNN:
    """A clustered app whose channel 0->4 dominates all traffic."""
    src = np.array([0, 1, 2], dtype=np.int64)
    dst = np.array([4, 2, 3], dtype=np.int64)
    rate = np.array([5000.0, 1.0, 1.0])
    order = np.lexsort((dst, src))
    return ClusteredSNN(
        snn=None,
        cluster_of=np.zeros(n, dtype=np.int32),
        n_clusters=n,
        channel_src=src[order],
        channel_dst=dst[order],
        channel_rate=rate[order],
        inputs_used=np.full(n, 8.0),
        neurons_used=np.full(n, 8.0),
        synapses_used=np.full(n, 30.0),
        out_spikes=np.full(n, 4.0),
        in_spikes=np.full(n, 4.0),
    )


def test_comm_guided_mutate_colocates_heaviest_cut():
    cl = _chatty_clusters()
    hw = dataclasses.replace(DYNAP_SE, n_tiles=16)
    rng = np.random.default_rng(0)
    pop = rng.integers(0, 16, size=(32, cl.n_clusters))
    pop[:, 0] = 0
    pop[:, 4] = 15           # heaviest channel endpoints far apart
    _comm_guided_mutate(
        pop, cl.channel_src, cl.channel_dst, cl.channel_rate, hw, rng
    )
    # every row co-located the dominant channel (moved 0->15 or 4->0)
    assert np.all(pop[:, 0] == pop[:, 4])


def test_comm_guided_mutate_noop_when_no_cut():
    cl = _chatty_clusters()
    hw = dataclasses.replace(DYNAP_SE, n_tiles=16)
    rng = np.random.default_rng(1)
    pop = np.zeros((4, cl.n_clusters), dtype=np.int64)   # all co-located
    before = pop.copy()
    _comm_guided_mutate(
        pop, cl.channel_src, cl.channel_dst, cl.channel_rate, hw, rng
    )
    np.testing.assert_array_equal(pop, before)


def test_optimizer_improves_comm_dominated_app():
    """NoC-bound operating point: link/route costs dominate compute, so
    co-locating chatty clusters is the winning move the comm mutation
    makes reachable.  The optimizer must strictly beat every Eq.-7 seed
    (deterministic under the fixed rng_seed)."""
    comm_hw = dataclasses.replace(
        DYNAP_SE, n_tiles=16,
        t_spike_link=0.4, t_route=5.0, t_spike_encode=0.05,
    )
    snn = small_app(200, 2600, seed=13)
    cl = partition_greedy(snn, comm_hw)
    rep = optimize_binding(
        cl, comm_hw, population=24, generations=5, rng_seed=2
    )
    assert rep.period <= rep.best_seed_period * (1 + 1e-9)
    assert rep.improvement > 0.0

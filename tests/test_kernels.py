"""Per-kernel validation: sweep shapes/dtypes, assert allclose vs ref oracle.

All Pallas kernels run in interpret mode on CPU (the kernel body executes in
Python); on a real TPU the same code paths compile to Mosaic.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

RNG = np.random.default_rng(42)


# ======================================================================
# maxplus_matmul
# ======================================================================
@pytest.mark.parametrize(
    "m,k,n",
    [(128, 128, 128), (256, 128, 384), (200, 150, 90), (64, 300, 64), (1, 128, 128)],
)
def test_maxplus_matmul_shapes(m, k, n):
    a = RNG.normal(size=(m, k)).astype(np.float32)
    b = RNG.normal(size=(k, n)).astype(np.float32)
    out = ops.maxplus_matmul(a, b)
    exp = ref.maxplus_matmul_ref(jnp.asarray(a), jnp.asarray(b))
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=1e-5)


def test_maxplus_matmul_neginf_identity():
    """-inf is absorbing: the (max,+) identity matrix round-trips."""
    n = 128
    eye = np.full((n, n), -np.inf, dtype=np.float32)
    np.fill_diagonal(eye, 0.0)
    a = RNG.normal(size=(n, n)).astype(np.float32)
    out = ops.maxplus_matmul(a, eye)
    np.testing.assert_allclose(np.asarray(out), a, atol=1e-6)


@pytest.mark.parametrize("g,m,k,n", [(3, 128, 128, 128), (2, 200, 96, 64), (5, 32, 32, 32)])
def test_maxplus_bmm_shapes(g, m, k, n):
    a = RNG.normal(size=(g, m, k)).astype(np.float32)
    b = RNG.normal(size=(g, k, n)).astype(np.float32)
    out = ops.maxplus_bmm(a, b)
    exp = ref.maxplus_bmm_ref(jnp.asarray(a), jnp.asarray(b))
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=1e-5)


def test_maxplus_bmm_kernel_interpret_matches_ref():
    """The batched Pallas kernel itself (interpret mode) against the oracle."""
    from repro.kernels.maxplus_matmul import maxplus_bmm as kern

    a = RNG.normal(size=(2, 128, 128)).astype(np.float32)
    b = RNG.normal(size=(2, 128, 128)).astype(np.float32)
    out = kern(jnp.asarray(a), jnp.asarray(b), interpret=True)
    exp = ref.maxplus_bmm_ref(jnp.asarray(a), jnp.asarray(b))
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=1e-5)


def test_maxplus_bmm_neginf_padding_rows():
    """-inf rows/cols (the EdgeStack padding convention) stay neutral."""
    g, n = 2, 64
    a = RNG.normal(size=(g, n, n)).astype(np.float32)
    b = RNG.normal(size=(g, n, n)).astype(np.float32)
    a[:, n // 2:, :] = -np.inf
    out = np.asarray(ops.maxplus_bmm(a, b))
    assert np.all(np.isneginf(out[:, n // 2:, :]))
    exp = np.asarray(ref.maxplus_bmm_ref(jnp.asarray(a), jnp.asarray(b)))
    np.testing.assert_allclose(out[:, : n // 2], exp[:, : n // 2], atol=1e-5)


def test_maxplus_matmul_associativity():
    a = RNG.normal(size=(64, 64)).astype(np.float32)
    b = RNG.normal(size=(64, 64)).astype(np.float32)
    c = RNG.normal(size=(64, 64)).astype(np.float32)
    left = ops.maxplus_matmul(np.asarray(ops.maxplus_matmul(a, b)), c)
    right = ops.maxplus_matmul(a, np.asarray(ops.maxplus_matmul(b, c)))
    np.testing.assert_allclose(np.asarray(left), np.asarray(right), atol=1e-4)


# ======================================================================
# lif_crossbar
# ======================================================================
@pytest.mark.parametrize("b,n_in,n_out", [(8, 128, 128), (3, 300, 200), (16, 96, 64)])
@pytest.mark.parametrize("dtype", [np.float32])
def test_lif_crossbar_shapes(b, n_in, n_out, dtype):
    s = (RNG.random((b, n_in)) < 0.2).astype(dtype)
    w = RNG.normal(size=(n_in, n_out)).astype(dtype)
    v = RNG.normal(size=(b, n_out)).astype(dtype)
    out_s, out_v = ops.lif_crossbar_step(s, w, v)
    exp_s, exp_v = ref.lif_crossbar_step_ref(
        jnp.asarray(s), jnp.asarray(w), jnp.asarray(v)
    )
    np.testing.assert_allclose(np.asarray(out_s), np.asarray(exp_s))
    np.testing.assert_allclose(np.asarray(out_v), np.asarray(exp_v), atol=1e-4)


def test_lif_crossbar_threshold_semantics():
    """A neuron exactly at threshold fires and resets."""
    s = np.ones((8, 128), np.float32)
    w = np.zeros((128, 128), np.float32)
    w[:, 0] = 1.0 / 128.0  # column 0 accumulates exactly 1.0 == v_th
    v = np.zeros((8, 128), np.float32)
    out_s, out_v = ops.lif_crossbar_step(s, w, v, leak=0.9, v_th=1.0, v_reset=0.0)
    assert np.all(np.asarray(out_s)[:, 0] >= 0.99)
    assert np.allclose(np.asarray(out_v)[:, 0], 0.0)
    assert np.all(np.asarray(out_s)[:, 1:] == 0)


def test_lif_multi_step_trajectory_matches_ref():
    """Iterated kernel == iterated oracle over 10 steps (state carried)."""
    s = (RNG.random((4, 256)) < 0.3).astype(np.float32)
    w = (RNG.normal(size=(256, 256)) * 0.1).astype(np.float32)
    v_k = np.zeros((4, 256), np.float32)
    v_r = jnp.zeros((4, 256), jnp.float32)
    s_k, s_r = s, jnp.asarray(s)
    for _ in range(10):
        s_k, v_k = ops.lif_crossbar_step(np.asarray(s_k), w, np.asarray(v_k))
        s_r, v_r = ref.lif_crossbar_step_ref(s_r, jnp.asarray(w), v_r)
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_r))
    np.testing.assert_allclose(np.asarray(v_k), np.asarray(v_r), atol=1e-3)


# ======================================================================
# flash_attention
# ======================================================================
@pytest.mark.parametrize(
    "b,hq,hkv,s,d",
    [(1, 2, 2, 128, 64), (2, 4, 2, 256, 64), (1, 8, 1, 384, 128), (1, 2, 2, 200, 64)],
)
def test_flash_attention_causal(b, hq, hkv, s, d):
    q = RNG.normal(size=(b, hq, s, d)).astype(np.float32)
    k = RNG.normal(size=(b, hkv, s, d)).astype(np.float32)
    v = RNG.normal(size=(b, hkv, s, d)).astype(np.float32)
    out = ops.flash_attention(q, k, v, causal=True)
    exp = ref.attention_ref(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=2e-3)


@pytest.mark.parametrize("window", [64, 128, 256])
def test_flash_attention_sliding_window(window):
    b, h, s, d = 1, 2, 384, 64
    q = RNG.normal(size=(b, h, s, d)).astype(np.float32)
    k = RNG.normal(size=(b, h, s, d)).astype(np.float32)
    v = RNG.normal(size=(b, h, s, d)).astype(np.float32)
    out = ops.flash_attention(q, k, v, causal=True, window=window)
    exp = ref.attention_ref(
        jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), causal=True, window=window
    )
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=2e-3)


def test_flash_attention_bf16():
    b, h, s, d = 1, 2, 256, 64
    q = jnp.asarray(RNG.normal(size=(b, h, s, d)), dtype=jnp.bfloat16)
    k = jnp.asarray(RNG.normal(size=(b, h, s, d)), dtype=jnp.bfloat16)
    v = jnp.asarray(RNG.normal(size=(b, h, s, d)), dtype=jnp.bfloat16)
    out = ops.flash_attention(q, k, v, causal=True)
    exp = ref.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(
        np.asarray(out, dtype=np.float32),
        np.asarray(exp, dtype=np.float32),
        atol=3e-2,
    )


# ======================================================================
# mamba_scan
# ======================================================================
@pytest.mark.parametrize("B,L,D,N,chunk", [(1, 128, 128, 8, 64), (2, 256, 256, 16, 128),
                                           (1, 200, 128, 16, 64)])
def test_mamba_scan_shapes(B, L, D, N, chunk):
    x = RNG.normal(size=(B, L, D)).astype(np.float32)
    dt = (0.01 + 0.1 * RNG.random((B, L, D))).astype(np.float32)
    a = (-np.exp(RNG.normal(size=(D, N)))).astype(np.float32)
    bm = RNG.normal(size=(B, L, N)).astype(np.float32)
    cm = RNG.normal(size=(B, L, N)).astype(np.float32)
    y, h = ops.mamba_scan(x, dt, a, bm, cm, chunk=chunk)
    ye, he = ref.mamba_scan_ref(
        jnp.asarray(x), jnp.asarray(dt), jnp.asarray(a), jnp.asarray(bm), jnp.asarray(cm)
    )
    np.testing.assert_allclose(np.asarray(y), np.asarray(ye), atol=3e-3)
    np.testing.assert_allclose(np.asarray(h), np.asarray(he), atol=3e-3)


def test_mamba_scan_is_causal():
    """Perturbing the future never changes the past."""
    B, L, D, N = 1, 128, 128, 8
    x = RNG.normal(size=(B, L, D)).astype(np.float32)
    dt = (0.05 * np.ones((B, L, D))).astype(np.float32)
    a = (-np.ones((D, N))).astype(np.float32)
    bm = RNG.normal(size=(B, L, N)).astype(np.float32)
    cm = RNG.normal(size=(B, L, N)).astype(np.float32)
    y1, _ = ops.mamba_scan(x, dt, a, bm, cm, chunk=64)
    x2 = x.copy()
    x2[:, 100:] += 10.0
    y2, _ = ops.mamba_scan(x2, dt, a, bm, cm, chunk=64)
    np.testing.assert_allclose(
        np.asarray(y1)[:, :100], np.asarray(y2)[:, :100], atol=1e-5
    )
    assert not np.allclose(np.asarray(y1)[:, 100:], np.asarray(y2)[:, 100:])


# ======================================================================
# kernel <-> core integration: power iteration uses maxplus kernel
# ======================================================================
def test_power_iteration_with_kernel_matches_howard():
    from repro.core.maxplus import maxplus_matrix, mcm_power_iteration, mcr_howard
    from repro.core.sdfg import SDFG, Channel

    rng = np.random.default_rng(7)
    n = 40
    tau = rng.uniform(1, 5, size=n)
    channels = [Channel(i, i, 1, 1.0, kind="self") for i in range(n)]
    for i in range(n):
        channels.append(Channel(i, (i + 1) % n, 1 if i == n - 1 else 0, 1.0))
    for _ in range(2 * n):
        i, j = int(rng.integers(n)), int(rng.integers(n))
        if i != j:
            channels.append(Channel(i, j, 1, 1.0))
    g = SDFG(n_actors=n, exec_time=tau, channels=channels)
    T = maxplus_matrix(g)
    lam = mcm_power_iteration(T, iters=300, use_kernel=True)
    assert np.isclose(lam, mcr_howard(g), rtol=1e-3)

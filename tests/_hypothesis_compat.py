"""Optional-``hypothesis`` shim for the property tests.

``hypothesis`` is a dev dependency (see requirements-dev.txt) but must not
be a hard import: the tier-1 suite has to collect and run in environments
without it.  When present, re-export the real ``given/settings/strategies``.
When absent, fall back to a deterministic stand-in that runs each property
test over a fixed sample of the strategy's range — weaker than real
property testing, but the invariants still get exercised.

Only the tiny strategy surface these tests use is implemented
(``st.integers(min_value=..., max_value=...)``).
"""

from __future__ import annotations

try:  # pragma: no cover - exercised implicitly by which env runs the suite
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # deterministic fallback
    HAVE_HYPOTHESIS = False

    class _IntRange:
        def __init__(self, min_value: int, max_value: int):
            self.min_value = min_value
            self.max_value = max_value

        def sample(self, n: int) -> list[int]:
            lo, hi = self.min_value, self.max_value
            span = hi - lo
            # endpoints + a deterministic spread across the range
            pts = [lo + (span * k) // max(n - 1, 1) for k in range(n)]
            return sorted(set(pts))

    class st:  # type: ignore[no-redef]
        @staticmethod
        def integers(min_value: int, max_value: int) -> _IntRange:
            return _IntRange(min_value, max_value)

    def settings(*, max_examples: int = 10, **_ignored):
        def deco(fn):
            fn._max_examples = max_examples
            return fn

        return deco

    def given(*strategies: _IntRange):
        def deco(fn):
            # NOT functools.wraps: the wrapper must expose a zero-argument
            # signature or pytest treats the strategy params as fixtures
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_max_examples", 10)
                cols = []
                for idx, s in enumerate(strategies):
                    samp = s.sample(n)
                    # rotate each axis at a different stride so the zipped
                    # combos vary on every argument, not just the last
                    cols.append(
                        [samp[(k * (idx + 1) + idx) % len(samp)] for k in range(n)]
                    )
                for combo in zip(*cols):
                    fn(*args, *combo, **kwargs)

            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper

        return deco

"""SNN2SDF export round-trip + compressed-gradient training."""

import numpy as np

from repro.core import DYNAP_SE, partition_greedy, sdfg_from_clusters, small_app
from repro.core.export import from_json, to_dot, to_json
from repro.core.maxplus import mcr_howard


def test_sdfg_json_roundtrip_preserves_mcm():
    snn = small_app(150, 2000, seed=9)
    cl = partition_greedy(snn, DYNAP_SE)
    g = sdfg_from_clusters(cl, hw=DYNAP_SE)
    g2 = from_json(to_json(g))
    assert g2.n_actors == g.n_actors
    assert np.isclose(mcr_howard(g2), mcr_howard(g))


def test_sdfg_dot_is_valid_graphviz_ish():
    snn = small_app(100, 1200, seed=10)
    cl = partition_greedy(snn, DYNAP_SE)
    g = sdfg_from_clusters(cl, hw=DYNAP_SE)
    dot = to_dot(g)
    assert dot.startswith("digraph")
    assert dot.count("->") >= cl.n_channels


def test_train_with_compressed_grads_learns():
    from repro.launch import train

    losses = train.main([
        "--arch", "qwen2-1.5b", "--smoke", "--steps", "20",
        "--seq-len", "32", "--batch", "4", "--compress-grads",
        "--log-every", "100",
    ])
    assert all(np.isfinite(losses))
    assert np.mean(losses[-5:]) < np.mean(losses[:5])

"""Data pipeline, optimizer, checkpoint, compression, elastic controller."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, load_checkpoint, save_checkpoint
from repro.checkpoint.manager import latest_step
from repro.data import DataConfig, TokenStream
from repro.launch.elastic import (
    ElasticController,
    HeartbeatTracker,
    StragglerPolicy,
    plan_elastic_mesh,
)
from repro.optim import (
    AdamWConfig,
    adamw_init,
    adamw_update,
    compress_int8,
    cosine_schedule,
    decompress_int8,
    ef_compress_gradients,
)


# ======================================================================
# data
# ======================================================================
def test_data_deterministic_and_sharded():
    cfg = DataConfig(vocab=100, seq_len=32, global_batch=8, seed=7)
    full = TokenStream(cfg).batch(3)
    shards = [
        TokenStream(cfg, shard_id=i, num_shards=4).batch(3) for i in range(4)
    ]
    recombined = np.concatenate([s["tokens"] for s in shards])
    np.testing.assert_array_equal(full["tokens"], recombined)
    # same (seed, step) -> same batch
    again = TokenStream(cfg).batch(3)
    np.testing.assert_array_equal(full["tokens"], again["tokens"])
    # labels are next-token
    np.testing.assert_array_equal(full["labels"][:, :-1], full["tokens"][:, 1:])


def test_data_markov_is_learnable():
    """A bigram table on the synthetic stream beats uniform entropy."""
    cfg = DataConfig(vocab=50, seq_len=256, global_batch=4, seed=1)
    b = TokenStream(cfg).batch(0)
    toks = b["tokens"].reshape(-1)
    counts = np.ones((50, 50))
    for a, c in zip(toks[:-1], toks[1:]):
        counts[a, c] += 1
    probs = counts / counts.sum(1, keepdims=True)
    nll = -np.mean(
        np.log(probs[toks[:-1], toks[1:]])
    )
    assert nll < np.log(50) * 0.9  # clearly below uniform


# ======================================================================
# optimizer
# ======================================================================
@pytest.mark.parametrize("state_dtype", ["float32", "bfloat16", "int8"])
def test_adamw_converges_quadratic(state_dtype):
    opt = AdamWConfig(lr=0.1, weight_decay=0.0, state_dtype=state_dtype)
    params = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(4, 128)),
                               jnp.float32)}
    state = adamw_init(params, opt)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    l0 = float(loss(params))
    for _ in range(60):
        g = jax.grad(loss)(params)
        params, state = adamw_update(params, g, state, opt)
    assert float(loss(params)) < 0.05 * l0


def test_int8_moments_roundtrip_small_error():
    from repro.optim.adamw import _dequantize, _quantize

    x = jnp.asarray(np.random.default_rng(0).normal(size=(8, 300)), jnp.float32)
    q, s = _quantize(x, 128)
    back = _dequantize(q, s, x.shape, 128)
    err = np.abs(np.asarray(back) - np.asarray(x)).max()
    assert err < np.abs(np.asarray(x)).max() / 100


def test_cosine_schedule_shape():
    first = float(cosine_schedule(jnp.int32(0)))
    assert 0.0 < first <= 1.0 / 200 + 1e-6  # warmup starts at (0+1)/warmup
    peak = float(cosine_schedule(jnp.int32(200)))
    assert 0.99 <= peak <= 1.0
    end = float(cosine_schedule(jnp.int32(10_000)))
    assert end == pytest.approx(0.1, abs=1e-3)


# ======================================================================
# gradient compression
# ======================================================================
def test_compression_roundtrip_and_error_feedback():
    g = {"a": jnp.asarray(np.random.default_rng(0).normal(size=(1000,)),
                          jnp.float32)}
    q, s = compress_int8(g["a"])
    back = decompress_int8(q, s, (1000,))
    assert float(jnp.abs(back - g["a"]).max()) < 0.05
    # error feedback: two steps of identical grads — residual shrinks bias
    comp1, err1 = ef_compress_gradients(g, None)
    comp2, err2 = ef_compress_gradients(g, err1)
    deq1 = decompress_int8(*comp1["a"], (1000,))
    deq2 = decompress_int8(*comp2["a"], (1000,))
    total = np.asarray(deq1 + deq2)
    ideal = 2 * np.asarray(g["a"])
    # with EF the SUM of transmitted grads tracks the true sum better than 2x
    # a single lossy transmission
    assert np.abs(total - ideal).mean() <= np.abs(2 * np.asarray(deq1) - ideal).mean() + 1e-9


# ======================================================================
# checkpoint
# ======================================================================
def test_checkpoint_roundtrip_and_gc(tmp_path):
    tree = {"w": jnp.arange(12.0).reshape(3, 4), "b": jnp.zeros(5)}
    mgr = CheckpointManager(str(tmp_path), keep=2, every=1)
    for step in (1, 2, 3, 4):
        mgr.maybe_save(step, tree, extra={"data_step": step})
    assert latest_step(tmp_path) == 4
    step, restored, extra = mgr.restore_latest(tree)
    assert step == 4 and extra["data_step"] == 4
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(tree["w"]))
    # gc kept only last 2
    kept = sorted(d.name for d in tmp_path.iterdir() if d.name.startswith("step"))
    assert len(kept) == 2


def test_checkpoint_torn_write_ignored(tmp_path):
    tree = {"w": jnp.ones(3)}
    save_checkpoint(tmp_path, 1, tree)
    # simulate torn write: incomplete manifest
    bad = tmp_path / "step_00000002"
    bad.mkdir()
    (bad / "manifest.json").write_text("{not json")
    assert latest_step(tmp_path) == 1


def test_train_restart_bit_exact(tmp_path):
    """Kill-and-restart: resumed run reproduces the uninterrupted run."""
    from repro.launch import train

    a = train.main([
        "--arch", "qwen2-1.5b", "--smoke", "--steps", "8",
        "--seq-len", "32", "--batch", "4", "--log-every", "100",
    ])
    ck = str(tmp_path / "ck")
    train.main([
        "--arch", "qwen2-1.5b", "--smoke", "--steps", "4",
        "--seq-len", "32", "--batch", "4", "--ckpt-dir", ck,
        "--ckpt-every", "2", "--log-every", "100",
    ])
    b = train.main([
        "--arch", "qwen2-1.5b", "--smoke", "--steps", "8",
        "--seq-len", "32", "--batch", "4", "--ckpt-dir", ck,
        "--ckpt-every", "2", "--log-every", "100",
    ])
    # steps 4..7 of the resumed run match the uninterrupted run
    np.testing.assert_allclose(a[4:], b[-4:], rtol=1e-4)


# ======================================================================
# elastic / fault tolerance
# ======================================================================
def test_heartbeat_failure_detection():
    clock = [0.0]
    tr = HeartbeatTracker(4, timeout=10.0, clock=lambda: clock[0])
    clock[0] = 5.0
    for h in (0, 1, 2):
        tr.beat(h)
    clock[0] = 14.0  # host 3 silent for 14s > timeout; 0-2 beat 9s ago
    dead = tr.sweep()
    assert dead == [3]
    assert tr.alive_hosts() == [0, 1, 2]


def test_elastic_mesh_planning():
    assert plan_elastic_mesh(256, model_parallel=16) == (16, 16)
    assert plan_elastic_mesh(255, model_parallel=16) == (15, 16)
    assert plan_elastic_mesh(15, model_parallel=16) is None


def test_straggler_becomes_failure():
    ctrl = ElasticController(4, chips_per_host=64, model_parallel=16,
                             straggler=StragglerPolicy(deadline_s=1.0, patience=2))
    assert ctrl.step({0: 0.5, 1: 0.5, 2: 0.5, 3: 5.0}) is None  # 1 miss
    new = ctrl.step({0: 0.5, 1: 0.5, 2: 0.5, 3: 5.0})           # 2nd miss
    assert new == (12, 16)  # 3 hosts x 64 chips = 192 = 12 x 16


def test_remesh_checkpoint_restore_roundtrip(tmp_path):
    """Params saved on one mesh restore onto a smaller mesh."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    mesh_a = jax.make_mesh((1, 1), ("data", "model"))
    tree = {"w": jnp.arange(64.0).reshape(8, 8)}
    save_checkpoint(tmp_path, 10, tree)
    mesh_b = jax.make_mesh((1,), ("data",))
    sh = {"w": NamedSharding(mesh_b, P(None, None))}
    restored, _ = load_checkpoint(tmp_path, 10, tree, shardings=sh)
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(tree["w"]))

"""Public-API documentation contract.

Every public callable of the engine / exploration / runtime / optimizer
layers must carry a docstring (the architecture pass documents array shapes
and units there — see docs/ARCHITECTURE.md).  Public = not underscore-
prefixed and defined in the module itself (re-exports are checked where
they are defined).
"""

import inspect

import repro.core.engine
import repro.core.explore
import repro.core.optimize
import repro.core.runtime

MODULES = (
    repro.core.engine,
    repro.core.explore,
    repro.core.optimize,
    repro.core.runtime,
)


def _public_callables(mod):
    for name in dir(mod):
        if name.startswith("_"):
            continue
        obj = getattr(mod, name)
        if not (inspect.isfunction(obj) or inspect.isclass(obj)):
            continue
        if getattr(obj, "__module__", None) != mod.__name__:
            continue  # re-export; documented at its definition site
        yield name, obj


def _public_methods(cls):
    for name, obj in vars(cls).items():
        if name.startswith("_"):
            continue
        if isinstance(obj, property):
            yield name, obj.fget
        elif inspect.isfunction(obj):
            yield name, obj


def test_module_docstrings():
    for mod in MODULES:
        assert mod.__doc__ and mod.__doc__.strip(), mod.__name__


def test_public_callables_have_docstrings():
    missing = []
    for mod in MODULES:
        for name, obj in _public_callables(mod):
            if not (obj.__doc__ and obj.__doc__.strip()):
                missing.append(f"{mod.__name__}.{name}")
            if inspect.isclass(obj):
                for mname, meth in _public_methods(obj):
                    if not (meth.__doc__ and meth.__doc__.strip()):
                        missing.append(f"{mod.__name__}.{name}.{mname}")
    assert not missing, f"public callables without docstrings: {missing}"

"""Fault- and drift-adaptive runtime re-mapping (PR 8).

ChipState degradation semantics, their threading through the batched
engine, the controller's inject/detect/remap loop (never-regress, explicit
displacement, cached-vs-exact agreement after every mutation), and the
failure-storm generator.
"""

import dataclasses

import numpy as np
import pytest

from repro.core import (
    DYNAP_SE,
    DYNAP_SE_16,
    AdmissionController,
    ChipState,
    batch_execute,
    failure_storm,
    sdfg_from_clusters,
    small_app,
)
from repro.core.engine import project_order_batch
from tests._hypothesis_compat import given, settings, st

HW64 = dataclasses.replace(DYNAP_SE, n_tiles=64)


def _apps(n, seed0=300, prefix="f"):
    apps = []
    for i in range(n):
        snn = small_app(150, 1800, seed=seed0 + i)
        snn.name = f"{prefix}{i}"
        apps.append(snn)
    return apps


def _controller(n_apps=4, seed0=300, prefix="f", hw=HW64, request=3):
    ctl = AdmissionController(hw, placement="joint", region_scope=True)
    for snn in _apps(n_apps, seed0=seed0, prefix=prefix):
        ctl.admit(snn, n_tiles_request=request)
    return ctl


def _bound_tiles(ctl):
    return sorted({int(t) for ts in ctl.running().values() for t in ts})


def _no_dead_bindings(ctl):
    return all(
        not ctl.chip.dead[int(t)]
        for ts in ctl.running().values()
        for t in ts
    )


def _cached_matches_exact(ctl, rtol=1e-6):
    mc = ctl.chip_metrics()
    me = ctl.chip_metrics(exact=True)
    if mc is None or me is None:
        return mc is me
    return bool(
        np.isclose(mc["chip_throughput"], me["chip_throughput"], rtol=rtol)
    )


# -- ChipState -----------------------------------------------------------
def test_chipstate_lifecycle():
    cs = ChipState(DYNAP_SE_16)
    assert cs.pristine and cs.n_alive == 16 and cs.epoch == 0
    cs.fail_tiles([3, 7])
    assert not cs.pristine and cs.n_alive == 14
    assert cs.dead[[3, 7]].all() and cs.epoch == 1
    assert cs.dead_rows(np.array([[0, 1], [2, 3], [7, 7]])).tolist() == [
        False, True, True,
    ]
    cs.heal_tiles([3])
    assert cs.dead[7] and not cs.dead[3] and cs.epoch == 2
    cs.heal_tiles([7])
    assert cs.pristine
    cs.set_drift("a", 2.0)
    assert not cs.pristine and cs.drift == {"a": 2.0}
    cs.set_drift("a", 1.0)   # factor 1.0 removes the entry
    assert cs.pristine
    cs.throttle_link(0, 1, 4.0)
    assert not cs.pristine
    cs.heal_link(0, 1)
    assert cs.pristine


def test_chipstate_validation():
    cs = ChipState(DYNAP_SE_16)
    with pytest.raises(ValueError):
        cs.fail_tiles([16])
    with pytest.raises(ValueError):
        cs.throttle_link(0, 5, 2.0)   # not mesh-adjacent (hops 2)
    with pytest.raises(ValueError):
        cs.throttle_link(0, 1, 0.5)   # a throttle can only slow down
    with pytest.raises(ValueError):
        cs.set_drift("a", 0.0)
    assert cs.pristine


def test_route_scale_xy_crossings():
    # 4x4 mesh, throttle the horizontal link (1,1)-(2,1): tiles 5-6
    cs = ChipState(DYNAP_SE_16)
    assert cs.route_scale() is None
    cs.throttle_link(5, 6, 3.0)
    rs = cs.route_scale()
    # XY routes horizontally along the SOURCE row first: 4->7 sweeps row 1
    assert rs[4, 7] == 3.0 and rs[4, 3] == 3.0
    # row-0 horizontal then column vertical never touches row 1's links
    assert rs[1, 6] == 1.0 and rs[0, 3] == 1.0
    # reverse direction crosses the same undirected link
    assert rs[7, 4] == 3.0
    assert rs[5, 5] == 1.0
    src = np.array([4, 1])
    dst = np.array([7, 6])
    assert cs.route_scale_array(src, dst).tolist() == [3.0, 1.0]
    cs.heal_link(5, 6)
    assert cs.route_scale() is None


def test_comm_delay_link_scale():
    hw = DYNAP_SE_16
    spikes = np.array([10.0, 10.0, 0.0])
    hops = np.array([2, 2, 0])
    base = hw.comm_delay_from_hops(spikes, hops)
    slow = hw.comm_delay_from_hops(spikes, hops, np.array([1.0, 4.0, 4.0]))
    assert slow[0] == base[0] and slow[1] > base[1]
    assert base[2] == 0.0 and slow[2] == 0.0   # co-located stays free


# -- engine threading ----------------------------------------------------
def test_engine_degradation_scoring():
    ctl = _controller(1, prefix="e")
    name = "e0"
    art = ctl.artifacts[(name, ctl.hw)]
    graph = art.graph if art.graph is not None else sdfg_from_clusters(
        art.clustered, hw=ctl.hw
    )
    binding = ctl.reports[name].binding
    ob = project_order_batch(
        [int(a) for a in art.single_order], binding[None, :]
    )
    base = batch_execute(graph, binding, ctl.hw, ob, with_energy=True)
    # a pristine chip state changes nothing, bit for bit
    rep = batch_execute(
        graph, binding, ctl.hw, ob, chip_state=ChipState(ctl.hw)
    )
    assert rep.periods[0] == base.periods[0]
    # a dead bound tile makes the row infeasible
    cs = ChipState(ctl.hw)
    cs.fail_tiles([int(binding[0])])
    rep = batch_execute(graph, binding, ctl.hw, ob, chip_state=cs)
    assert np.isinf(rep.periods[0])
    # throttling every link can only slow the row down
    cs = ChipState(ctl.hw)
    side = ctl.hw.mesh_shape[1]
    for t in range(ctl.hw.n_tiles):
        if t % side + 1 < side:
            cs.throttle_link(t, t + 1, 8.0)
        if t + side < ctl.hw.n_tiles:
            cs.throttle_link(t, t + side, 8.0)
    rep = batch_execute(graph, binding, ctl.hw, ob, chip_state=cs)
    assert rep.periods[0] >= base.periods[0]
    # rate drift scales the observed spike traffic
    rep = batch_execute(
        graph, binding, ctl.hw, ob, with_energy=True, rate_scale=2.0
    )
    assert rep.periods[0] >= base.periods[0]
    assert (
        float(rep.metrics.cut_traffic[0])
        == pytest.approx(2 * float(base.metrics.cut_traffic[0]))
    )


# -- controller: detection + remap ---------------------------------------
def test_stale_detection_scopes_to_affected_apps():
    ctl = _controller(3, prefix="s")
    assert ctl.stale_apps() == []
    # mutate the chip directly (no event): detection must flag exactly
    # the drifted app — a no-op factor flags nobody
    ctl.chip.set_drift("s1", 3.0)
    stale = ctl.stale_apps()
    assert "s1" in stale
    affected = {
        n for c in ctl._tile_components() if "s1" in c for n in c
    }
    assert set(stale) <= affected
    ctl.remap(stale=stale)
    assert ctl.stale_apps() == []
    assert _cached_matches_exact(ctl)


@settings(max_examples=6, deadline=None)
@given(st.integers(min_value=0, max_value=10_000))
def test_fault_remap_never_regresses(seed):
    """Post-remap chip throughput >= the repaired seed placement's, no
    dead tile ever bound, cached combine == exact re-score."""
    rng = np.random.default_rng(seed)
    ctl = _controller(4, seed0=400 + seed % 7, prefix=f"p{seed % 7}_")
    for _ in range(2):
        bound = [t for t in _bound_tiles(ctl) if not ctl.chip.dead[t]]
        if not bound:
            break
        victim = int(bound[int(rng.integers(len(bound)))])
        ctl.inject_fault([victim])
        remaps = [e for e in ctl.events if e.kind == "remap"]
        assert remaps, "a fault on a bound tile must trigger a remap"
        e = remaps[-1]
        assert e.chip_throughput >= e.seed_throughput * (1 - 1e-6)
        assert _no_dead_bindings(ctl)
        assert _cached_matches_exact(ctl)


def test_random_event_sequence_cached_vs_exact():
    """Randomized fault/heal/drift/throttle/churn sequence: after EVERY
    event the cached component combine must match the exact full-union
    re-score and no resident may hold a dead tile."""
    rng = np.random.default_rng(11)
    ctl = _controller(5, prefix="q")
    names = [f"q{i}" for i in range(5)]
    side = ctl.hw.mesh_shape[1]
    failed: list[int] = []
    for step in range(14):
        k = int(rng.integers(6))
        if k == 0 and len(failed) < 6:
            bound = [t for t in _bound_tiles(ctl) if not ctl.chip.dead[t]]
            if bound:
                t = int(bound[int(rng.integers(len(bound)))])
                ctl.inject_fault([t])
                failed.append(t)
        elif k == 1 and failed:
            ctl.heal([failed.pop(int(rng.integers(len(failed))))])
        elif k == 2:
            app = names[int(rng.integers(len(names)))]
            ctl.inject_drift(app, float(rng.uniform(0.5, 3.0)))
        elif k == 3:
            a = int(rng.integers(ctl.hw.n_tiles))
            b = a + 1 if a % side + 1 < side else a - 1
            ctl.inject_fault(links=[(min(a, b), max(a, b))], throttle=4.0)
        elif k == 4:
            app = names[int(rng.integers(len(names)))]
            if app in ctl.state.allocated:
                ctl.evict(app)
        else:
            app = names[int(rng.integers(len(names)))]
            if app not in ctl.state.allocated:
                ctl.admit(app, n_tiles_request=3)
        assert _no_dead_bindings(ctl), f"dead binding after step {step}"
        assert _cached_matches_exact(ctl), f"cache drift after step {step}"


def _trajectory_signature(ctl):
    """Everything deterministic about a trajectory (wall clocks excluded)."""
    return [
        (
            e.kind, e.app, tuple(e.tiles), round(e.throughput, 12),
            round(e.chip_throughput, 12), round(e.seed_throughput, 12),
            e.scope, e.region_apps, round(e.factor, 12),
        )
        for e in ctl.events
    ]


def test_fault_trajectory_deterministic():
    def scenario():
        ctl = _controller(4, prefix="d")
        victims = _bound_tiles(ctl)[:2]
        ctl.inject_fault([victims[0]])
        ctl.inject_drift("d2", 1.7)
        ctl.inject_fault([victims[1]])
        ctl.heal(victims, drift_apps=["d2"])
        return ctl

    a, b = scenario(), scenario()
    assert _trajectory_signature(a) == _trajectory_signature(b)
    assert np.allclose(
        a.chip_metrics()["chip_throughput"],
        b.chip_metrics()["chip_throughput"],
        rtol=0,
    )


def test_displacement_is_explicit():
    """Killing every tile displaces residents with explicit events —
    never a silent drop — and the books stay consistent."""
    ctl = _controller(2, prefix="x", hw=DYNAP_SE, request=2)
    before = set(ctl.running())
    assert before == {"x0", "x1"}
    displaced = ctl.inject_fault(list(range(DYNAP_SE.n_tiles)))
    assert set(displaced) == before
    assert ctl.running() == {}
    kinds = [e.kind for e in ctl.events]
    assert kinds.count("displaced") == 2
    # accounting: every pre-fault resident is displaced or still running
    assert before == set(displaced) | set(ctl.running())
    # the chip heals back to a usable state
    ctl.heal(list(range(DYNAP_SE.n_tiles)))
    assert ctl.chip.pristine
    ctl.admit("x0", n_tiles_request=2)
    assert "x0" in ctl.running()


def test_remap_matches_full_reoptimization_feasibility():
    """Oracle cross-check: after a fault+remap (a) survivors ∪ displaced
    == pre-fault residents, (b) a from-scratch controller on an
    identically-degraded chip admits exactly the survivor set, (c) a
    forced FULL joint re-optimization — seeded, hence never-worse — does
    not beat the incremental remap by more than the optimizer's own
    search slack."""
    ctl = _controller(5, prefix="o")
    before = set(ctl.running())
    victims = _bound_tiles(ctl)[:2]
    displaced = ctl.inject_fault(victims)
    assert before == set(displaced) | set(ctl.running())
    remap_thr = ctl.chip_metrics()["chip_throughput"]

    fresh = AdmissionController(HW64, placement="joint", region_scope=True)
    fresh.chip.fail_tiles(victims)
    for snn in _apps(5, seed0=300, prefix="o"):
        if snn.name in ctl.running():
            fresh.admit(snn, n_tiles_request=3)
    assert set(fresh.running()) == set(ctl.running())
    assert _no_dead_bindings(fresh)

    ctl._rebalance_full()   # exact full-union re-opt, same degraded chip
    full_thr = ctl.chip_metrics()["chip_throughput"]
    assert full_thr >= remap_thr * (1 - 1e-6)
    assert _no_dead_bindings(ctl)


def test_heal_recovers_throughput():
    ctl = _controller(4, prefix="h")
    victim = _bound_tiles(ctl)[0]
    ctl.inject_fault([victim])
    degraded = ctl.chip_metrics()["chip_throughput"]
    ctl.heal([victim])
    assert ctl.chip.pristine
    healed = ctl.chip_metrics()["chip_throughput"]
    # healing only widens the feasible set; the remap seeds from the
    # degraded placement, so throughput can only recover or hold
    assert healed >= degraded * (1 - 1e-6)
    assert _cached_matches_exact(ctl)


def test_remap_skips_untouched_tenants():
    """A fault on a far-away FREE tile must not disturb any resident."""
    ctl = _controller(3, prefix="u")
    bound = set(_bound_tiles(ctl))
    free = [t for t in range(HW64.n_tiles) if t not in bound]
    # the farthest free tile from every binding (corners are farthest)
    far = max(
        free,
        key=lambda t: min(
            ctl.hw.hops_array(np.array([t]), np.array([b]))[0] for b in bound
        ),
    )
    before = {n: tuple(ts) for n, ts in ctl.running().items()}
    thr0 = ctl.chip_metrics()["chip_throughput"]
    ctl.inject_fault([int(far)])
    after = {n: tuple(ts) for n, ts in ctl.running().items()}
    assert before == after
    assert ctl.chip_metrics()["chip_throughput"] == pytest.approx(
        thr0, rel=1e-9
    )


# -- failure storms ------------------------------------------------------
def test_failure_storm_deterministic_and_bounded():
    kw = dict(
        seed=5, heal_after=2.0, p_throttle=0.2, p_drift=0.2,
        drift_apps=["a", "b"], max_dead_frac=0.25,
    )
    s1 = failure_storm(25, 64, **kw)
    s2 = failure_storm(25, 64, **kw)
    assert s1 == s2
    assert all(s1[i].t <= s1[i + 1].t for i in range(len(s1) - 1))
    kinds = {e.kind for e in s1}
    assert kinds <= {"fail", "heal", "throttle", "drift"}
    dead: set[int] = set()
    slow: set[tuple] = set()
    link_heals = 0
    for e in s1:
        if e.kind == "fail":
            dead.update(e.tiles)
            assert len(dead) / 64 <= 0.25
        elif e.kind == "heal" and e.link is not None:
            assert e.link in slow   # link heals pair with earlier throttles
            slow.discard(e.link)
            link_heals += 1
        elif e.kind == "heal":
            assert e.tiles and set(e.tiles) <= dead   # pair with earlier fails
            dead.difference_update(e.tiles)
        elif e.kind == "throttle":
            a, b = e.link
            assert b - a in (1, 8) and e.factor >= 2.0
            slow.add(e.link)
        else:
            assert e.app in ("a", "b") and e.factor > 0
    assert link_heals == sum(e.kind == "throttle" for e in s1)
    assert failure_storm(25, 64, seed=6) != s1

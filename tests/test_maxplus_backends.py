"""Cross-backend max-plus validation (ISSUE 9): the device-resident
``"csr-jit"`` lambda-search vs the numpy ``"edges"`` oracle and per-graph
:func:`mcr_howard`, the deadlock / acyclic conventions, determinism,
accelerator-aware backend auto-selection, and the dense backend's
shortcut-derived squaring-round count."""

import dataclasses
import math

import numpy as np
import pytest

from repro.core import (
    DYNAP_SE,
    ChipState,
    batch_execute,
    mcr_batch,
    mcr_howard,
    partition_greedy,
    sdfg_from_clusters,
    small_app,
    stack_graphs,
)
from repro.core import engine as engine_mod
from repro.core import maxplus as mp
from repro.core.maxplus import EdgeStack
from repro.core.sdfg import SDFG, Channel
from tests._hypothesis_compat import given, settings, st

NEG_INF = float("-inf")


def random_live_sdfg(rng: np.random.Generator, n: int) -> SDFG:
    """Random strongly-cyclic live event graph (as in test_maxplus)."""
    tau = rng.uniform(0.5, 5.0, size=n)
    channels = [Channel(i, i, 1, 1.0, kind="self") for i in range(n)]
    for i in range(n):
        channels.append(Channel(i, (i + 1) % n, 1 if i == n - 1 else 0, 1.0))
    for _ in range(int(rng.integers(0, 2 * n))):
        i, j = int(rng.integers(n)), int(rng.integers(n))
        if i == j:
            continue
        channels.append(
            Channel(i, j, 1 if j <= i else int(rng.integers(0, 3)), 1.0,
                    delay=float(rng.uniform(0, 2.0)))
        )
    g = SDFG(n_actors=n, exec_time=tau, channels=channels)
    g.validate()
    return g


def _ring_stack(b: int, n: int, seed: int, *, shortcuts: bool) -> EdgeStack:
    """Length-n one-token rings, optionally with exact path-doubling
    shortcut edges (the PR-3 composition: span-s edge = summed w/tokens
    of the underlying span-s ring path, so the MCR is preserved while
    the hop diameter collapses to O(log n))."""
    r = np.random.default_rng(seed)
    src = np.broadcast_to(np.arange(n), (b, n)).copy()
    dst = (src + 1) % n
    tok = np.zeros_like(src)
    tok[:, -1] = 1
    w = r.uniform(0.5, 2.0, (b, n))
    srcs, dsts, toks, ws = [src], [dst], [tok.astype(np.float64)], [w]
    if shortcuts:
        cw, ct, nx = w.copy(), tok.astype(np.float64), dst.copy()
        span = 1
        while 2 * span < n:
            cw = cw + np.take_along_axis(cw, nx, axis=1)
            ct = ct + np.take_along_axis(ct, nx, axis=1)
            nx = np.take_along_axis(nx, nx, axis=1)
            span *= 2
            srcs.append(src)
            dsts.append(nx.copy())
            toks.append(ct.copy())
            ws.append(cw.copy())
    return EdgeStack(
        n_actors=n,
        src=np.concatenate(srcs, axis=1),
        dst=np.concatenate(dsts, axis=1),
        tokens=np.concatenate(toks, axis=1).astype(np.int64),
        weights=np.concatenate(ws, axis=1),
    )


# ======================================================================
# csr-jit == edges == Howard on random live graphs (property test)
# ======================================================================
@settings(max_examples=6, deadline=None)
@given(st.integers(min_value=0, max_value=10_000))
def test_csr_jit_matches_edges_and_howard(seed):
    rng = np.random.default_rng(seed)
    graphs = [
        random_live_sdfg(rng, int(rng.integers(3, 14))) for _ in range(5)
    ]
    stack = stack_graphs(graphs)
    pe = mcr_batch(stack, backend="edges", rel_tol=1e-9)
    pc = mcr_batch(stack, backend="csr-jit", rel_tol=1e-9)
    howard = np.array([mcr_howard(g) for g in graphs])
    np.testing.assert_allclose(pc, pe, rtol=1e-8, atol=1e-8)
    np.testing.assert_allclose(pc, howard, rtol=1e-6, atol=1e-6)


def test_csr_jit_deadlocked_rows_report_inf():
    """A zero-token cycle deadlocks the graph: Howard says inf, and both
    backends must agree under ``detect_deadlock=True``."""
    live = SDFG(
        n_actors=3, exec_time=np.array([1.0, 2.0, 3.0]),
        channels=[Channel(0, 1, 0, 1.0), Channel(1, 2, 0, 1.0),
                  Channel(2, 0, 1, 1.0)],
    )
    dead = SDFG(
        n_actors=3, exec_time=np.array([1.0, 2.0, 3.0]),
        channels=[Channel(0, 1, 0, 1.0), Channel(1, 0, 0, 1.0),
                  Channel(2, 2, 1, 1.0)],
    )
    assert mcr_howard(dead) == np.inf
    stack = stack_graphs([live, dead, live])
    pe = mcr_batch(stack, backend="edges", detect_deadlock=True)
    pc = mcr_batch(stack, backend="csr-jit", detect_deadlock=True)
    assert pe[1] == np.inf and pc[1] == np.inf
    np.testing.assert_allclose(pc, pe, rtol=1e-8)
    np.testing.assert_allclose(pe[[0, 2]], mcr_howard(live), rtol=1e-6)


def test_csr_jit_acyclic_rows_report_neg_inf():
    """Rows with no cycle at all are unbounded: -inf on every backend."""
    chain = SDFG(
        n_actors=4, exec_time=np.ones(4),
        channels=[Channel(0, 1, 1, 1.0), Channel(1, 2, 0, 1.0),
                  Channel(2, 3, 2, 1.0)],
    )
    ring = SDFG(
        n_actors=4, exec_time=np.ones(4),
        channels=[Channel(i, (i + 1) % 4, 1 if i == 3 else 0, 1.0)
                  for i in range(4)],
    )
    assert mcr_howard(chain) == NEG_INF
    stack = stack_graphs([chain, ring, chain])
    for backend in ("edges", "csr-jit"):
        p = mcr_batch(stack, backend=backend)
        assert p[0] == NEG_INF and p[2] == NEG_INF, (backend, p)
        np.testing.assert_allclose(p[1], mcr_howard(ring), rtol=1e-6)


def test_csr_jit_deterministic_and_probe_count_invariant():
    """Bit-identical across calls, and the multi-lambda probe count is a
    speed knob, not a semantics knob."""
    rng = np.random.default_rng(77)
    stack = stack_graphs(
        [random_live_sdfg(rng, int(rng.integers(4, 12))) for _ in range(4)]
    )
    a = mcr_batch(stack, backend="csr-jit", rel_tol=1e-9)
    b = mcr_batch(stack, backend="csr-jit", rel_tol=1e-9)
    np.testing.assert_array_equal(a, b)
    k1 = mp._mcr_batch_csr(stack, rel_tol=1e-9, k_probes=1)
    k3 = mp._mcr_batch_csr(stack, rel_tol=1e-9, k_probes=3)
    np.testing.assert_allclose(k1, k3, rtol=1e-8, atol=1e-8)


def test_csr_jit_ignores_neg_inf_padding_rows():
    """-inf-weight padding slots (index 0-filled) must not create
    phantom edges — the fused-scoring path depends on this."""
    rng = np.random.default_rng(5)
    g = random_live_sdfg(rng, 8)
    base = stack_graphs([g, g])
    pad = 7
    padded = EdgeStack(
        n_actors=base.n_actors,
        src=np.pad(base.src, ((0, 0), (0, pad))),
        dst=np.pad(base.dst, ((0, 0), (0, pad))),
        tokens=np.pad(base.tokens, ((0, 0), (0, pad)), constant_values=1),
        weights=np.pad(base.weights, ((0, 0), (0, pad)),
                       constant_values=NEG_INF),
    )
    for backend in ("edges", "csr-jit"):
        np.testing.assert_allclose(
            mcr_batch(padded, backend=backend),
            mcr_batch(base, backend=backend),
            rtol=1e-9,
        )


# ======================================================================
# degraded ChipState stacks: dead rows -> inf, throttled links agree
# ======================================================================
@pytest.fixture(scope="module")
def compiled_app():
    snn = small_app(200, 2600, seed=21)
    cl = partition_greedy(snn, DYNAP_SE)
    app = sdfg_from_clusters(cl, hw=DYNAP_SE)
    rng = np.random.default_rng(11)
    bindings = np.stack([
        rng.integers(0, DYNAP_SE.n_tiles, size=app.n_actors)
        for _ in range(6)
    ])
    return app, bindings


def test_backends_agree_on_degraded_chip_state(compiled_app):
    app, bindings = compiled_app
    state = ChipState(DYNAP_SE)
    state.fail_tiles([int(bindings[0, 0])])
    state.throttle_link(0, 1, 3.0)
    rep_e = batch_execute(app, bindings, DYNAP_SE, backend="edges",
                          chip_state=state)
    rep_c = batch_execute(app, bindings, DYNAP_SE, backend="csr-jit",
                          chip_state=state)
    dead = state.dead_rows(bindings)
    assert dead.any() and not dead.all()
    assert np.isinf(rep_e.periods[dead]).all()
    assert np.isinf(rep_c.periods[dead]).all()
    np.testing.assert_allclose(
        rep_c.periods[~dead], rep_e.periods[~dead], rtol=1e-7
    )


# ======================================================================
# backend auto-selection (satellite: accelerator-aware, not TPU-only)
# ======================================================================
def test_mcr_batch_auto_selects_csr_jit_on_accelerator(monkeypatch):
    rng = np.random.default_rng(3)
    stack = stack_graphs([random_live_sdfg(rng, 6)])
    calls = []
    real = mp._mcr_batch_csr

    def recording(st_, **kw):
        calls.append("csr-jit")
        return real(st_, **kw)

    monkeypatch.setattr(mp, "_mcr_batch_csr", recording)
    monkeypatch.setattr(mp, "_on_accelerator", lambda: True)
    out = mcr_batch(stack, backend="auto")
    assert calls == ["csr-jit"]
    np.testing.assert_allclose(
        out, mcr_batch(stack, backend="edges"), rtol=1e-8
    )
    # no accelerator -> the numpy oracle, device path untouched
    calls.clear()
    monkeypatch.setattr(mp, "_on_accelerator", lambda: False)
    mcr_batch(stack, backend="auto")
    assert calls == []


def test_engine_resolve_backend_is_accelerator_aware(monkeypatch):
    """GPU hosts must get the device backend too — the selection predicate
    is any-non-CPU-device, not TPU-only."""
    monkeypatch.setattr(engine_mod, "_engine_on_accelerator", lambda: True)
    assert engine_mod._resolve_backend("auto") == "csr-jit"
    monkeypatch.setattr(engine_mod, "_engine_on_accelerator", lambda: False)
    assert engine_mod._resolve_backend("auto") == "edges"
    # explicit choices always pass through
    for explicit in ("edges", "csr-jit", "dense"):
        assert engine_mod._resolve_backend(explicit) == explicit


# ======================================================================
# dense backend: squaring rounds derived from the shortcut-reduced
# hop diameter (satellite a)
# ======================================================================
def test_dense_squaring_rounds_drop_with_shortcut_edges():
    """With PR-3 path-doubling shortcuts in the stack the max-plus value
    closure saturates in fewer squarings than the log2(n) cap; without
    them the ring's hop diameter forces the full cap.  (max_steps is
    tiny: only the per-step round COUNTS are under test here.)"""
    n, cap = 32, max(1, int(math.ceil(math.log2(32))))
    short = _ring_stack(2, n, seed=9, shortcuts=True)
    plain = _ring_stack(2, n, seed=9, shortcuts=False)
    mp._mcr_batch_dense(short, max_steps=4)
    rounds_short = list(mp._DENSE_LAST_ROUNDS)
    mp._mcr_batch_dense(plain, max_steps=4)
    rounds_plain = list(mp._DENSE_LAST_ROUNDS)
    assert rounds_short and rounds_plain
    assert max(rounds_short + rounds_plain) <= cap
    assert all(r == cap for r in rounds_plain), rounds_plain
    assert min(rounds_short) < cap, rounds_short


def test_dense_shortcut_stack_same_answer_fewer_rounds():
    """The early exit must not change the verdict: dense on the shortcut
    ring matches the edges oracle on the plain ring (the shortcuts are
    exact compositions, so the MCR is identical)."""
    short = _ring_stack(2, 16, seed=4, shortcuts=True)
    plain = _ring_stack(2, 16, seed=4, shortcuts=False)
    pe = mcr_batch(plain, backend="edges", rel_tol=1e-9)
    pd = mcr_batch(short, backend="dense", rel_tol=1e-4)
    np.testing.assert_allclose(pd, pe, rtol=5e-4)


def test_maxplus_fixpoint_predicate():
    a = np.array([[0.0, NEG_INF], [1.5, 2.0]])
    assert mp._maxplus_fixpoint(a, a.copy())
    # float32 re-association slack is tolerated
    assert mp._maxplus_fixpoint(a + 1e-8, a)
    # value growth is not
    b = a.copy()
    b[1, 1] += 1.0
    assert not mp._maxplus_fixpoint(b, a)
    # support change is never a fixpoint
    c = a.copy()
    c[0, 1] = 3.0
    assert not mp._maxplus_fixpoint(c, a)

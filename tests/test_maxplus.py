"""Max-Plus analysis: three evaluators must agree; brute force on tiny graphs."""

import numpy as np
import pytest

from tests._hypothesis_compat import given, settings, st

from repro.core.maxplus import (
    maxplus_matrix,
    mcm_power_iteration,
    mcr_binary_search,
    mcr_howard,
)
from repro.core.sdfg import SDFG, Channel


def brute_force_mcr(g: SDFG) -> float:
    """Enumerate all simple cycles (tiny graphs only)."""
    src, dst, w, m = g.edges_arrays()
    n = g.n_actors
    best = -np.inf
    edges = list(zip(src.tolist(), dst.tolist(), w.tolist(), m.tolist()))
    # enumerate cycles by DFS from each start node
    def dfs(path_nodes, path_edges, node):
        nonlocal best
        for e, (s, d, ww, mm) in enumerate(edges):
            if s != node:
                continue
            if d == path_nodes[0]:
                wsum = sum(ww2 for (_, _, ww2, _) in path_edges) + ww
                msum = sum(mm2 for (_, _, _, mm2) in path_edges) + mm
                if msum > 0:
                    best = max(best, wsum / msum)
            elif d not in path_nodes:
                dfs(path_nodes + [d], path_edges + [(s, d, ww, mm)], d)

    for start in range(n):
        dfs([start], [], start)
    return best


def random_live_sdfg(rng: np.random.Generator, n: int) -> SDFG:
    """Random strongly-cyclic live event graph."""
    tau = rng.uniform(0.5, 5.0, size=n)
    channels = [Channel(i, i, 1, 1.0, kind="self") for i in range(n)]
    # a ring with one token guarantees a cycle through all actors
    for i in range(n):
        channels.append(Channel(i, (i + 1) % n, 1 if i == n - 1 else 0, 1.0))
    # extra random edges; backward edges carry a token to preserve liveness
    n_extra = int(rng.integers(0, 2 * n))
    for _ in range(n_extra):
        i, j = int(rng.integers(n)), int(rng.integers(n))
        if i == j:
            continue
        channels.append(Channel(i, j, 1 if j <= i else int(rng.integers(0, 3)), 1.0,
                                delay=float(rng.uniform(0, 2.0))))
    g = SDFG(n_actors=n, exec_time=tau, channels=channels)
    g.validate()
    return g


@pytest.mark.parametrize("seed", range(12))
def test_howard_matches_brute_force(seed):
    rng = np.random.default_rng(seed)
    g = random_live_sdfg(rng, int(rng.integers(2, 6)))
    assert g.is_live()
    exact = brute_force_mcr(g)
    howard = mcr_howard(g)
    assert np.isclose(howard, exact, rtol=1e-9), (howard, exact)


@pytest.mark.parametrize("seed", range(8))
def test_howard_matches_binary_search(seed):
    rng = np.random.default_rng(100 + seed)
    g = random_live_sdfg(rng, int(rng.integers(3, 20)))
    howard = mcr_howard(g)
    binary = mcr_binary_search(g, tol=1e-7)
    assert np.isclose(howard, binary, atol=1e-5), (howard, binary)


@pytest.mark.parametrize("seed", range(6))
def test_power_iteration_matches_howard_single_token(seed):
    """T-matrix power iteration is exact when all markings are <= 1."""
    rng = np.random.default_rng(200 + seed)
    n = int(rng.integers(3, 12))
    tau = rng.uniform(0.5, 5.0, size=n)
    channels = [Channel(i, i, 1, 1.0, kind="self") for i in range(n)]
    for i in range(n):
        channels.append(Channel(i, (i + 1) % n, 1 if i == n - 1 else 0, 1.0))
    for _ in range(n):
        i, j = int(rng.integers(n)), int(rng.integers(n))
        if i != j:
            channels.append(Channel(i, j, 1, 1.0))
    g = SDFG(n_actors=n, exec_time=tau, channels=channels)
    howard = mcr_howard(g)
    power = mcm_power_iteration(maxplus_matrix(g), iters=400, use_kernel=False)
    assert np.isclose(power, howard, rtol=1e-3), (power, howard)


@pytest.mark.parametrize("seed", range(4))
def test_power_iteration_converges_on_strongly_connected(seed):
    """Convergence check after the renormalization cleanup: power iteration
    (kernel matvec path included) agrees with Howard on random strongly-
    connected event graphs whose markings are all <= 1 (where T is exact)."""
    rng = np.random.default_rng(300 + seed)
    n = int(rng.integers(4, 16))
    tau = rng.uniform(0.5, 5.0, size=n)
    channels = [Channel(i, i, 1, 1.0, kind="self") for i in range(n)]
    # a random Hamiltonian cycle makes the graph strongly connected
    perm = rng.permutation(n)
    for a, b in zip(perm, np.roll(perm, -1)):
        channels.append(Channel(int(a), int(b), 1, 1.0,
                                delay=float(rng.uniform(0, 1.0))))
    for _ in range(2 * n):
        i, j = int(rng.integers(n)), int(rng.integers(n))
        if i != j:
            channels.append(Channel(i, j, 1, 1.0))
    g = SDFG(n_actors=n, exec_time=tau, channels=channels)
    assert g.is_live()
    howard = mcr_howard(g)
    for use_kernel in (False, True):
        power = mcm_power_iteration(
            maxplus_matrix(g), iters=400, use_kernel=use_kernel
        )
        assert np.isclose(power, howard, rtol=1e-3), (use_kernel, power, howard)


def test_deadlocked_graph_reports_inf():
    # 0 -> 1 -> 0 with no tokens anywhere on the cycle
    g = SDFG(
        n_actors=2,
        exec_time=np.array([1.0, 1.0]),
        channels=[Channel(0, 1, 0, 1.0), Channel(1, 0, 0, 1.0)],
    )
    assert not g.is_live()
    assert mcr_howard(g) == np.inf


def test_two_cycle_example():
    """Hand-checked: cycle A (tau 2+3, 1 token) vs B (tau 2+4+1, 2 tokens)."""
    g = SDFG(
        n_actors=3,
        exec_time=np.array([2.0, 3.0, 4.0]),
        channels=[
            Channel(0, 1, 0, 1.0),
            Channel(1, 0, 1, 1.0),          # cycle 0-1: (3+2)/1 = 5
            Channel(1, 2, 0, 1.0),
            Channel(2, 0, 2, 1.0, delay=1.0),  # cycle 0-1-2: (3+4+2+1)/2 = 5
        ],
    )
    assert np.isclose(mcr_howard(g), 5.0)


@settings(max_examples=25, deadline=None)
@given(st.integers(min_value=0, max_value=10_000))
def test_property_mcr_scale_invariance(seed):
    """MCR scales linearly with execution times (max-plus homogeneity)."""
    rng = np.random.default_rng(seed)
    g = random_live_sdfg(rng, int(rng.integers(2, 10)))
    base = mcr_howard(g)
    g2 = SDFG(g.n_actors, g.exec_time * 3.0,
              [Channel(c.src, c.dst, c.tokens, c.rate, c.delay * 3.0, c.kind)
               for c in g.channels], g.name)
    assert np.isclose(mcr_howard(g2), 3.0 * base, rtol=1e-9)


@settings(max_examples=20, deadline=None)
@given(st.integers(min_value=0, max_value=10_000))
def test_property_adding_tokens_never_slows(seed):
    """More initial tokens (bigger buffers) can only improve throughput."""
    rng = np.random.default_rng(seed)
    g = random_live_sdfg(rng, int(rng.integers(2, 8)))
    base = mcr_howard(g)
    bumped = SDFG(
        g.n_actors,
        g.exec_time,
        [Channel(c.src, c.dst, c.tokens + 1, c.rate, c.delay, c.kind)
         for c in g.channels],
        g.name,
    )
    assert mcr_howard(bumped) <= base + 1e-9

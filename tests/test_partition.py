"""Algorithm 1 (crossbar-aware partitioning): invariants + property tests."""

import numpy as np
import pytest

from tests._hypothesis_compat import given, settings, st

from repro.core import (
    DYNAP_SE,
    APP_SPECS,
    HardwareConfig,
    build_app,
    partition_greedy,
    small_app,
)
from repro.core.snn import feedforward, calibrate_spikes


def test_small_partition_respects_constraints():
    snn = small_app(200, 3000, seed=1)
    cl = partition_greedy(snn, DYNAP_SE)
    xbar = DYNAP_SE.tile.crossbar
    assert cl.inputs_used.max() <= xbar.inputs
    assert cl.neurons_used.max() <= xbar.outputs
    assert cl.synapses_used.max() <= xbar.crosspoints
    assert cl.neurons_used.sum() == cl.snn.n_neurons


def test_every_synapse_preserved_after_split():
    snn = small_app(150, 2500, seed=2)
    work = snn.split_high_fanin(DYNAP_SE.tile.crossbar.inputs)
    # relay synapses add to the count; original endpoints all still reachable
    assert work.n_synapses >= snn.n_synapses
    assert work.fanin().max() <= DYNAP_SE.tile.crossbar.inputs


def test_channel_spikes_conserve_traffic():
    snn = small_app(180, 2000, seed=3)
    cl = partition_greedy(snn, DYNAP_SE)
    # AER multicast: one packet per spike per distinct (pre, dst-cluster)
    total = sum(cl.channel_spikes.values())
    src_c = cl.cluster_of[cl.snn.pre]
    dst_c = cl.cluster_of[cl.snn.post]
    cut = src_c != dst_c
    pairs = np.unique(
        cl.snn.pre[cut].astype(np.int64) * cl.n_clusters + dst_c[cut]
    )
    expected = cl.snn.spikes[(pairs // cl.n_clusters)].sum()
    assert np.isclose(total, expected)


@pytest.mark.parametrize("name", ["ImgSmooth", "MLP-MNIST"])
def test_table1_totals_exact(name):
    snn = build_app(name)
    assert snn.n_synapses == APP_SPECS[name].synapses
    per_iter = APP_SPECS[name].spikes / APP_SPECS[name].recorded_iters
    assert np.isclose(snn.spikes.sum(), per_iter)


def test_heterogeneous_rates_match_reference_bit_for_bit():
    """Vectorized walk (with bulk run commits) vs. the scalar oracle under
    wildly heterogeneous spike rates: heavy-tailed hot neurons and silent
    neurons stress both the rate-ordered buffer cutoff inside a run and
    the fallback to the scalar probe when a run is cut short."""
    from repro.core import partition_greedy_reference

    for seed in (0, 1, 2):
        snn = small_app(260, 3600, seed=seed)
        rng = np.random.default_rng(seed + 11)
        spikes = snn.spikes.copy()
        spikes[rng.random(snn.n_neurons) < 0.3] *= 40.0   # hot tail
        spikes[rng.random(snn.n_neurons) < 0.1] = 0.0     # silent
        # keep each neuron legal for the tile output buffer
        spikes *= min(1.0, 3000.0 / spikes.max())
        snn.spikes = spikes
        ref = partition_greedy_reference(snn, DYNAP_SE)
        vec = partition_greedy(snn, DYNAP_SE)
        assert np.array_equal(ref.cluster_of, vec.cluster_of)
        assert np.array_equal(ref.inputs_used, vec.inputs_used)
        assert np.array_equal(ref.synapses_used, vec.synapses_used)


def test_conv_windows_heterogeneous_rates_match_reference():
    """Conv-style shared windows create long identical-window runs — the
    exact shape the bulk commit accelerates; heterogeneous rates force
    mid-run breaks.  Must stay bit-identical to the scalar oracle."""
    from repro.core import partition_greedy_reference

    for seed in (5, 6):
        # wide shallow layers -> long identical shared-window runs
        snn = feedforward([256, 256, 64], 7000, seed=seed, name="conv")
        snn = calibrate_spikes(snn, 40_000.0, seed=seed + 1)
        rng = np.random.default_rng(seed)
        spikes = snn.spikes.copy()
        spikes[rng.random(snn.n_neurons) < 0.25] *= 25.0
        spikes *= min(1.0, 3000.0 / spikes.max())
        snn.spikes = spikes
        ref = partition_greedy_reference(snn, DYNAP_SE)
        vec = partition_greedy(snn, DYNAP_SE)
        assert np.array_equal(ref.cluster_of, vec.cluster_of)


def test_partition_deterministic():
    a = partition_greedy(build_app("MLP-MNIST"), DYNAP_SE)
    b = partition_greedy(build_app("MLP-MNIST"), DYNAP_SE)
    assert a.n_clusters == b.n_clusters
    assert np.array_equal(a.cluster_of, b.cluster_of)


@settings(max_examples=15, deadline=None)
@given(
    st.integers(min_value=30, max_value=300),
    st.integers(min_value=100, max_value=4000),
    st.integers(min_value=0, max_value=1000),
)
def test_property_partition_always_fits(n_neurons, n_synapses, seed):
    snn = small_app(n_neurons, n_synapses, seed=seed)
    cl = partition_greedy(snn, DYNAP_SE)
    xbar = DYNAP_SE.tile.crossbar
    assert cl.inputs_used.max() <= xbar.inputs
    assert cl.neurons_used.max() <= xbar.outputs
    assert cl.synapses_used.max() <= xbar.crosspoints
    # spike conservation: per-cluster out spikes == traffic on its channels
    out = np.zeros(cl.n_clusters)
    for (i, j), r in cl.channel_spikes.items():
        out[i] += r
    # out spikes on channels never exceed total cluster spike production
    prod = np.zeros(cl.n_clusters)
    np.add.at(prod, cl.cluster_of, cl.snn.spikes)
    # each spike can fan out to several clusters, so no upper bound; but
    # channels only exist where synapses cross clusters
    for (i, j) in cl.channel_spikes:
        assert i != j


@settings(max_examples=10, deadline=None)
@given(st.integers(min_value=0, max_value=100))
def test_property_smaller_crossbar_more_clusters(seed):
    snn = small_app(250, 3000, seed=seed)
    big = partition_greedy(snn, DYNAP_SE)
    import dataclasses

    from repro.core.hardware import CrossbarConfig, TileConfig

    small_hw = dataclasses.replace(
        DYNAP_SE,
        tile=TileConfig(crossbar=CrossbarConfig(64, 64, 64 * 64)),
    )
    small = partition_greedy(snn, small_hw)
    assert small.n_clusters >= big.n_clusters

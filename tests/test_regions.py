"""Region-scoped joint placement, component-cached chip metrics, and the
chip-scale workload generator (PR 6)."""

import dataclasses

import numpy as np
import pytest

from repro.core import (
    DYNAP_SE,
    DYNAP_SE_1024,
    AdmissionController,
    small_app,
)
from repro.core.workloads import TABLE1_FIT, sample_workload, workload_suite

HW64 = dataclasses.replace(DYNAP_SE, n_tiles=64)


def _apps(n, seed0=70, prefix="r"):
    apps = []
    for i in range(n):
        snn = small_app(150, 1800, seed=seed0 + i)
        snn.name = f"{prefix}{i}"
        apps.append(snn)
    return apps


def _drive(ctl, prefix="r"):
    """A fixed admit/evict/finish churn (deterministic)."""
    apps = _apps(6, seed0=90, prefix=prefix)
    for a in apps:
        ctl.register(a)
    for a in apps[:5]:
        ctl.admit(a.name, n_tiles_request=3)
    ctl.evict(apps[1].name)
    ctl.admit(apps[5].name, n_tiles_request=3)
    ctl.finish(apps[2].name)
    ctl.admit(apps[1].name, n_tiles_request=2)
    return ctl


# ======================================================================
# tentpole: region-scoped incremental rebalancing
# ======================================================================
def test_region_rebalances_never_regress_chip_throughput():
    """On a 32x32 mesh the regions stay strictly smaller than the chip;
    every rebalance (region or full) must hold the seeding invariant:
    chip throughput never worse than the pre-event binding."""
    ctl = AdmissionController(
        DYNAP_SE_1024, placement="joint", joint_budget=(2, 8),
        track_chip_metrics=True,
    )
    apps = _apps(10, seed0=120)
    for a in apps:
        ctl.register(a)
    for a in apps:
        ctl.admit(a.name, n_tiles_request=2)
    ctl.evict(apps[0].name)
    ctl.evict(apps[5].name)

    prev = None
    for e in ctl.events:
        if e.kind == "rebalance" and prev is not None and prev > 0:
            assert e.chip_throughput >= prev * (1 - 1e-6), (
                e.scope, e.chip_throughput, prev
            )
        if e.chip_throughput > 0:
            prev = e.chip_throughput
    scopes = {e.scope for e in ctl.events if e.kind == "rebalance"}
    assert "region" in scopes          # incremental path actually exercised
    region_evs = [
        e for e in ctl.events
        if e.kind == "rebalance" and e.scope == "region"
    ]
    assert all(
        0 < e.region_apps < len(apps) for e in region_evs
    )


def test_forced_full_fallback_bit_identical_to_unscoped():
    """``full_rebalance_every=1`` must reduce EXACTLY to the always-full
    (PR-5) behaviour: same events, same allocations, same bindings."""
    a = _drive(AdmissionController(
        HW64, placement="joint", joint_budget=(2, 8),
        track_chip_metrics=True, region_scope=False,
    ), prefix="fa")
    b = _drive(AdmissionController(
        HW64, placement="joint", joint_budget=(2, 8),
        track_chip_metrics=True, region_scope=True,
        full_rebalance_every=1,
    ), prefix="fb")
    assert [e.kind for e in a.events] == [e.kind for e in b.events]
    assert all(
        e.scope == "full" for e in b.events if e.kind == "rebalance"
    )
    ra = {n[2:]: sorted(t) for n, t in a.running().items()}
    rb = {n[2:]: sorted(t) for n, t in b.running().items()}
    assert ra == rb
    for n in a.reports:
        assert np.array_equal(
            a.reports[n].binding, b.reports["fb" + n[2:]].binding
        )
        assert a.reports[n].orders == b.reports["fb" + n[2:]].orders


def test_cached_component_combine_matches_exact_union():
    """The component-cached chip metrics must agree with the single
    full-union engine call (they are the same quantity by tile/graph
    disjointness of the components)."""
    ctl = _drive(AdmissionController(
        HW64, placement="joint", joint_budget=(2, 8),
        track_chip_metrics=True,
    ), prefix="cx")
    m = ctl.chip_metrics()
    x = ctl.chip_metrics(exact=True)
    assert m["n_resident"] == x["n_resident"]
    assert m["chip_period"] == pytest.approx(x["chip_period"], rel=1e-6)
    assert m["chip_energy"] == pytest.approx(x["chip_energy"], rel=1e-6)
    assert m["chip_noc_traffic"] == pytest.approx(
        x["chip_noc_traffic"], rel=1e-9, abs=1e-9
    )
    assert set(m["app_throughputs"]) == set(x["app_throughputs"])
    for n, thr in m["app_throughputs"].items():
        assert thr == pytest.approx(x["app_throughputs"][n], rel=1e-6)


def test_per_app_rates_dominate_chip_rate():
    """An app's true steady-state rate is 1/max over the components it
    touches — never below the conservative whole-chip rate; trajectory
    events carry the same per-app dict."""
    ctl = _drive(AdmissionController(
        HW64, placement="joint", joint_budget=(2, 8),
        track_chip_metrics=True,
    ), prefix="pa")
    m = ctl.chip_metrics()
    assert m["chip_throughput"] > 0
    assert set(m["app_throughputs"]) == set(ctl.running())
    for thr in m["app_throughputs"].values():
        assert thr >= m["chip_throughput"] * (1 - 1e-9)
    stamped = [
        e for e in ctl.events
        if e.kind in ("admit", "rebalance") and e.app_throughputs
    ]
    assert stamped
    last = ctl.events[-1]
    assert set(last.app_throughputs) == set(ctl.running())


def test_component_cache_reuses_untouched_components():
    """Metrics calls after an unrelated event must rebuild only the
    touched component's record."""
    ctl = AdmissionController(
        HW64, placement="joint", joint_budget=(2, 8),
        track_chip_metrics=True, region_scope=True,
    )
    apps = _apps(4, seed0=150, prefix="cc")
    for a in apps:
        ctl.register(a)
    for a in apps:
        ctl.admit(a.name, n_tiles_request=2)
    ctl.chip_metrics()
    cached_before = set(ctl._comp_cache)
    assert cached_before
    ctl.chip_metrics()              # no event in between: no new records
    assert set(ctl._comp_cache) == cached_before


# ======================================================================
# satellite: synthetic workload generator
# ======================================================================
def test_workload_suite_deterministic_and_scaled():
    a = workload_suite(5, seed=9, scale=0.05)
    b = workload_suite(5, seed=9, scale=0.05)
    assert [s.name for s in a] == [f"tenant{i}" for i in range(5)]
    for x, y in zip(a, b):
        assert np.array_equal(x.pre, y.pre)
        assert np.array_equal(x.post, y.post)
        assert np.array_equal(x.spikes, y.spikes)
    lo, hi = TABLE1_FIT.neurons_range
    for s in a:
        assert 8 <= s.n_neurons <= int(hi * 0.05) + 1
        assert s.n_synapses >= s.n_neurons
        assert float(s.spikes.sum()) > 0


def test_workload_fit_matches_table1_statistics():
    """The population fit must recover the Table-1 per-neuron log-moments
    (large-sample check on the sampler itself)."""
    rng = np.random.default_rng(0)
    spn = []
    for _ in range(40):
        s = sample_workload(rng, scale=0.1)
        spn.append(s.n_synapses / s.n_neurons)
    mu = float(np.mean(np.log(spn)))
    # clamping and the connectivity cap bias the tail slightly; the
    # log-mean must still sit near the Table-1 fit
    assert abs(mu - TABLE1_FIT.syn_per_neuron[0]) < 1.0

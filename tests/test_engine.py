"""Batched self-timed engine: cross-validation against the heapq
:class:`SelfTimedExecutor` oracle and per-graph ``mcr_howard`` (§4.4-§5)."""

import dataclasses

import numpy as np
import pytest

from repro.core import (
    DYNAP_SE,
    SelfTimedExecutor,
    batch_execute,
    bind_ours,
    build_static_orders,
    mcr_batch,
    mcr_howard,
    partition_greedy,
    sdfg_from_clusters,
    small_app,
    stack_graphs,
    stack_hardware_aware,
)
from repro.core.hardware import HardwareConfig, TileConfig
from repro.core.maxplus import evolve_batch, maxplus_matrix_batch
from repro.core.sdfg import SDFG, ChannelTable, KIND_SELF, hardware_aware_sdfg
from tests._hypothesis_compat import given, settings, st

# small buffers keep the periodic regime's firing-count transient short, so
# the oracle's steady_period() resolves within a few hundred iterations
SMALL_BUF = dataclasses.replace(
    DYNAP_SE, tile=TileConfig(input_buffer=8, output_buffer=8)
)


def random_strongly_connected_sdfg(seed: int, n: int = 8) -> SDFG:
    """Random live strongly-connected timed event graph.

    A forward ring 0->1->...->n-1 with 0-token edges and a 1+-token
    wrap-around makes the graph strongly connected and live (the 0-token
    subgraph follows actor order, hence acyclic); random chords carry a
    token whenever they point backward.
    """
    rng = np.random.default_rng(seed)
    tau = rng.uniform(0.5, 5.0, size=n)
    src = list(range(n))
    dst = list(range(n))
    tokens = [1] * n
    kind = [KIND_SELF] * n
    for i in range(n):
        j = (i + 1) % n
        src.append(i)
        dst.append(j)
        tokens.append(int(rng.integers(1, 3)) if j <= i else 0)
        kind.append(0)
    for _ in range(2 * n):
        i, j = rng.integers(0, n, size=2)
        if i == j:
            continue
        src.append(int(i))
        dst.append(int(j))
        tokens.append(int(rng.integers(1, 4)) if j <= i else int(rng.integers(0, 2)))
        kind.append(0)
    g = SDFG(
        n_actors=n,
        exec_time=tau,
        channels=ChannelTable.from_arrays(
            src=src, dst=dst, tokens=tokens, rate=np.ones(len(src)), kind=kind
        ),
        name=f"rand{seed}",
    )
    g.validate()
    assert g.is_live()
    return g


# ======================================================================
# engine vs heapq oracle vs Howard, random strongly-connected graphs
# ======================================================================
@settings(max_examples=12, deadline=None)
@given(st.integers(min_value=0, max_value=10_000))
def test_engine_matches_oracle_on_random_graphs(seed):
    n = 6 + seed % 5
    g = random_strongly_connected_sdfg(seed, n=n)
    hw = dataclasses.replace(SMALL_BUF, n_tiles=n)
    binding = np.arange(n)
    orders = [[a] for a in range(n)]

    rep = batch_execute(g, binding, hw, [orders], backend="edges")
    assert rep.periods.shape == (1,)
    period_engine = float(rep.periods[0])

    period_howard = mcr_howard(hardware_aware_sdfg(g, binding, hw, orders))
    trace = SelfTimedExecutor(g, binding, hw, orders=orders).run(iterations=400)
    period_oracle = trace.steady_period()

    assert period_engine == pytest.approx(period_howard, rel=1e-6)
    assert period_engine == pytest.approx(period_oracle, rel=1e-6)


def test_engine_matches_oracle_with_shared_tiles():
    """Multi-actor tiles under static-order replay: the order-augmented
    graph's MCR must equal the operational steady-state period."""
    snn = small_app(200, 2400, seed=17)
    # cluster under the real buffer constraint, then execute on a
    # moderate-buffer variant so the periodic regime is reached within the
    # recorded window (buffer depth bounds how far actors run ahead)
    cl = partition_greedy(snn, DYNAP_SE)
    hw = dataclasses.replace(
        DYNAP_SE, tile=dataclasses.replace(
            DYNAP_SE.tile, input_buffer=64, output_buffer=64
        )
    )
    app = sdfg_from_clusters(cl, hw=hw)
    rng = np.random.default_rng(2)

    bindings, orders_list = [], []
    for i in range(4):
        b = (bind_ours(cl, hw).binding if i == 0
             else rng.integers(0, hw.n_tiles, size=app.n_actors))
        orders, _ = build_static_orders(app, b, hw, iterations=8)
        bindings.append(b)
        orders_list.append(orders)

    rep = batch_execute(app, np.array(bindings), hw, orders_list,
                        backend="edges")
    for row, (b, orders) in enumerate(zip(bindings, orders_list)):
        trace = SelfTimedExecutor(app, b, hw, orders=orders).run(
            iterations=400
        )
        assert rep.periods[row] == pytest.approx(
            trace.steady_period(), rel=1e-6
        ), row
        assert rep.periods[row] == pytest.approx(
            mcr_howard(hardware_aware_sdfg(app, b, hw, orders)),
            rel=1e-6,
        ), row


# ======================================================================
# stack construction: array-native batch == per-graph construction
# ======================================================================
def test_stack_hardware_aware_matches_per_graph_stack():
    snn = small_app(180, 2000, seed=23)
    cl = partition_greedy(snn, DYNAP_SE)
    app = sdfg_from_clusters(cl, hw=DYNAP_SE)
    rng = np.random.default_rng(5)
    bindings = [rng.integers(0, DYNAP_SE.n_tiles, size=app.n_actors)
                for _ in range(6)]
    orders_list = []
    for b in bindings:
        o, _ = build_static_orders(app, b, DYNAP_SE, iterations=6)
        orders_list.append(o)

    direct = stack_hardware_aware(app, np.array(bindings), DYNAP_SE, orders_list)
    via_graphs = stack_graphs([
        hardware_aware_sdfg(app, b, DYNAP_SE, o)
        for b, o in zip(bindings, orders_list)
    ])
    np.testing.assert_allclose(
        mcr_batch(direct, backend="edges"),
        mcr_batch(via_graphs, backend="edges"),
        rtol=1e-9,
    )


def test_stack_accepts_single_binding_and_no_orders():
    g = random_strongly_connected_sdfg(1, n=5)
    hw = dataclasses.replace(SMALL_BUF, n_tiles=5)
    rep = batch_execute(g, np.arange(5), hw)
    assert rep.periods.shape == (1,)
    assert rep.periods[0] == pytest.approx(
        mcr_howard(hardware_aware_sdfg(g, np.arange(5), hw)), rel=1e-6
    )


def test_stack_preserves_app_level_self_edge_delays():
    """hardware_aware_sdfg keeps self-edge delays; the batched construction
    must too (regression: base weights once dropped them)."""
    from repro.core.sdfg import Channel

    channels = [Channel(i, i, 1, 1.0, delay=0.7, kind="self") for i in range(3)]
    channels += [Channel(0, 1, 0, 1.0), Channel(1, 2, 0, 1.0),
                 Channel(2, 0, 1, 1.0)]
    g = SDFG(n_actors=3, exec_time=np.array([1.0, 2.0, 3.0]),
             channels=channels)
    hw = dataclasses.replace(SMALL_BUF, n_tiles=3)
    rep = batch_execute(g, np.arange(3), hw, backend="edges")
    assert rep.periods[0] == pytest.approx(
        mcr_howard(hardware_aware_sdfg(g, np.arange(3), hw)), rel=1e-6
    )


def test_stack_rejects_out_of_range_binding():
    g = random_strongly_connected_sdfg(0, n=4)
    hw = dataclasses.replace(SMALL_BUF, n_tiles=2)
    with pytest.raises(AssertionError):
        batch_execute(g, np.array([0, 1, 2, 0]), hw)


def test_steady_period_short_traces_do_not_crash():
    g = random_strongly_connected_sdfg(1, n=4)
    hw = dataclasses.replace(SMALL_BUF, n_tiles=4)
    for iters in (1, 2, 3):
        trace = SelfTimedExecutor(g, np.arange(4), hw).run(iterations=iters)
        p = trace.steady_period()
        assert np.isfinite(p) and p > 0


def test_stack_mixed_order_and_orderless_rows():
    g = random_strongly_connected_sdfg(9, n=6)
    hw = dataclasses.replace(SMALL_BUF, n_tiles=3)
    rng = np.random.default_rng(0)
    bindings = np.stack([rng.integers(0, 3, size=6) for _ in range(3)])
    orders_list = [None]
    for b in bindings[1:]:
        o, _ = build_static_orders(g, b, hw, iterations=6)
        orders_list.append(o)
    rep = batch_execute(g, bindings, hw, orders_list, backend="edges")
    expected = [
        mcr_howard(hardware_aware_sdfg(g, b, hw, o))
        for b, o in zip(bindings, orders_list)
    ]
    np.testing.assert_allclose(rep.periods, expected, rtol=1e-6)


# ======================================================================
# Eq.-4 recursion: batched matrix + evolution through the kernels
# ======================================================================
def test_batched_maxplus_matrix_power_agrees_with_mcr():
    """On graphs whose tokens are all <= 1 the batched Eq.-4 matrix is
    exact, so the batched power estimate must converge to the MCR (tail
    averaging leaves an O(1/window) remainder -> loose tolerance); with
    multi-token edges it stays a sound upper bound on the period."""
    graphs = []
    for s in (3, 4, 5):
        g = random_strongly_connected_sdfg(s, n=7)
        t = g.channels
        graphs.append(SDFG(
            n_actors=g.n_actors,
            exec_time=g.exec_time,
            channels=t.replace(tokens=np.minimum(t.tokens, 1)),
            name=g.name,
        ))
    stack = stack_graphs(graphs)
    t_mat = maxplus_matrix_batch(stack)
    _, period_est = evolve_batch(t_mat, iters=400)
    exact = np.array([mcr_howard(g) for g in graphs])
    np.testing.assert_allclose(period_est, exact, rtol=0.05)

    # multi-token graphs: conservative (1-token) matrix -> upper bound
    multi = stack_graphs([random_strongly_connected_sdfg(s, n=7)
                          for s in (3, 4, 5)])
    _, est_multi = evolve_batch(maxplus_matrix_batch(multi), iters=200)
    rho = mcr_batch(multi, backend="edges")
    assert np.all(est_multi >= rho * (1 - 1e-3))


def test_engine_starts_are_admissible_offsets():
    """Steady-state start offsets: finite, zero-based, and consistent with
    the max-plus recursion (x stays a fixed point up to the period)."""
    g = random_strongly_connected_sdfg(11, n=6)
    hw = dataclasses.replace(SMALL_BUF, n_tiles=6)
    rep = batch_execute(g, np.arange(6), hw, with_starts=True)
    assert rep.starts is not None and rep.starts.shape == (1, 6)
    assert np.isfinite(rep.starts).all()
    assert rep.starts.min() == 0.0

"""Beyond-paper SDFG pipeline analysis for the LM architectures."""

import numpy as np
import pytest

from repro.configs import get_arch
from repro.core.maxplus import mcr_howard
from repro.core.pipeline import analyze_pipeline, pipeline_sdfg, plan_stages


def test_stage_plan_balances_flops():
    cfg = get_arch("qwen1.5-110b")
    plan = plan_stages(cfg, 8, micro_tokens=4096)
    f = np.array(plan.stage_flops)
    assert f.min() > 0
    assert f.max() / f.min() < 1.6  # roughly balanced


def test_pipeline_period_equals_bottleneck_stage():
    """For a balanced pipeline with cheap comm, MCM == slowest stage's
    fwd+bwd time — the classic 1F1B steady state."""
    cfg = get_arch("qwen2-1.5b")
    plan = plan_stages(cfg, 4, micro_tokens=2048)
    g = pipeline_sdfg(plan, n_microbatches=16)
    period = mcr_howard(g)
    s = len(plan.stage_flops)
    per_stage = [g.exec_time[i] + g.exec_time[2 * s - 1 - i] for i in range(s)]
    assert period >= max(per_stage) - 1e-12
    assert period <= 1.5 * max(per_stage)


def test_more_microbatches_reduce_bubble():
    cfg = get_arch("codeqwen1.5-7b")
    b8 = analyze_pipeline(cfg, n_stages=4, n_microbatches=8,
                          micro_tokens=2048).bubble_frac
    b64 = analyze_pipeline(cfg, n_stages=4, n_microbatches=64,
                           micro_tokens=2048).bubble_frac
    assert b64 < b8


def test_matches_classic_bubble_formula():
    """With zero comm and perfectly balanced stages, bubble ~ (S-1)/(M+S-1)."""
    cfg = get_arch("qwen2-1.5b")
    S, M = 4, 16
    rep = analyze_pipeline(cfg, n_stages=S, n_microbatches=M,
                           micro_tokens=2048)
    classic = (S - 1) / (M + S - 1)
    assert rep.bubble_frac == pytest.approx(classic, rel=0.6)


@pytest.mark.parametrize("arch", ["deepseek-v3-671b", "jamba-v0.1-52b"])
def test_hbm_gate_detects_oversized_stages(arch):
    cfg = get_arch(arch)
    small = analyze_pipeline(cfg, n_stages=2, n_microbatches=8,
                             micro_tokens=4096)
    big = analyze_pipeline(cfg, n_stages=32, n_microbatches=8,
                           micro_tokens=4096)
    # 671B over 2 stages cannot fit a 16GB chip; over 32 it parks less/stage
    assert not small.hbm_fit
    assert big.tokens_per_s > 0

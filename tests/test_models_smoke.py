"""Per-architecture smoke tests: reduced config, one forward/train/decode
step on CPU; output shapes + finiteness.  (Full configs are exercised only
via the dry-run with ShapeDtypeStructs.)"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, ARCHS, get_arch, reduced
from repro.models import transformer as tf

KEY = jax.random.PRNGKey(0)


def _batch(cfg, b=2, s=64):
    batch = {
        "tokens": jnp.asarray(
            np.random.default_rng(0).integers(0, cfg.vocab, (b, s)), jnp.int32
        ),
        "labels": jnp.asarray(
            np.random.default_rng(1).integers(0, cfg.vocab, (b, s)), jnp.int32
        ),
    }
    if cfg.frontend:
        batch["frontend_embeds"] = jnp.ones(
            (b, cfg.frontend_tokens, cfg.d_model), jnp.float32
        )
    return batch


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_forward_shapes_and_finite(name):
    cfg = reduced(get_arch(name))
    params = tf.init_params(cfg, KEY)
    batch = _batch(cfg)
    logits, aux = tf.forward(params, batch, cfg)
    assert logits.shape == (2, 64, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_one_train_step_improves_nothing_breaks(name):
    from repro.launch.steps import make_train_step
    from repro.optim import AdamWConfig, adamw_init

    cfg = reduced(get_arch(name))
    params = tf.init_params(cfg, KEY)
    opt = AdamWConfig(lr=1e-3)
    state = adamw_init(params, opt)
    step = make_train_step(cfg, opt)
    batch = _batch(cfg)
    params2, state2, metrics = step(params, state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    assert int(state2["step"]) == 1
    # params actually moved
    delta = sum(
        float(jnp.abs(a - b).sum())
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params2))
    )
    assert delta > 0


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_decode_matches_forward_prefix(name):
    """Teacher-forced decode must reproduce forward() logits step by step.

    Recurrent families (ssm/hybrid) accumulate fp-ordering differences
    between the chunkwise-parallel forward and the sequential decode cell —
    state feedback compounds ~1e-6/block into ~1e-2 over 12 steps x 8-16
    blocks, so their tolerance is looser (both paths are validated exactly
    at block level elsewhere).

    Frontend configs run TEXT-ONLY here: without ``frontend_embeds`` the
    forward prepends nothing, so token positions line up with the decode
    cache's step counter and the same parity check applies (the
    frontend-prefixed forward itself is covered by the forward/train
    smoke tests above)."""
    cfg = reduced(get_arch(name))
    params = tf.init_params(cfg, KEY)
    b, s = 2, 12
    batch = _batch(cfg, b, s)
    batch.pop("frontend_embeds", None)
    full_logits, _ = tf.forward(params, batch, cfg)

    cache = tf.init_cache(cfg, b, 32, dtype=jnp.float32)
    outs = []
    for i in range(s):
        logits, cache = tf.decode_step(
            params, batch["tokens"][:, i : i + 1], cache, jnp.int32(i), cfg
        )
        outs.append(logits[:, 0])
    dec = np.asarray(jnp.stack(outs, axis=1))
    ref = np.asarray(full_logits)
    if cfg.family in ("ssm", "hybrid"):
        # the mLSTM normalizer max(|q.n|, e^-m) flips sides under fp noise
        # and the recurrence amplifies it: assert distributional agreement
        bad = np.abs(dec - ref) > (5e-2 + 5e-2 * np.abs(ref))
        assert bad.mean() < 0.08, f"{bad.mean():.3f} of logits diverged"
        np.testing.assert_array_equal(
            np.argmax(dec[:, :4], -1), np.argmax(ref[:, :4], -1)
        )
    else:
        np.testing.assert_allclose(dec, ref, atol=2e-2, rtol=2e-2)


def test_train_step_grad_accum_equivalence():
    """accum=4 must equal accum=1 up to accumulation-dtype rounding."""
    from repro.launch.steps import make_train_step
    from repro.optim import AdamWConfig, adamw_init

    cfg = reduced(get_arch("qwen2-1.5b"))
    params = tf.init_params(cfg, KEY)
    opt = AdamWConfig(lr=1e-3)
    batch = _batch(cfg, b=4, s=32)
    s1 = make_train_step(cfg, opt)(params, adamw_init(params, opt), batch)
    s4 = make_train_step(cfg, opt, accum=4, accum_dtype=jnp.float32)(
        params, adamw_init(params, opt), batch
    )
    assert np.isclose(float(s1[2]["loss"]), float(s4[2]["loss"]), rtol=1e-4)
    for a, b in zip(jax.tree.leaves(s1[0]), jax.tree.leaves(s4[0])):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            atol=5e-3, rtol=5e-2,
        )


def test_moe_dispatch_modes_agree():
    """gather and onehot dispatch produce identical outputs (same drops)."""
    import dataclasses

    from repro.models import moe as moe_mod

    cfg = reduced(get_arch("deepseek-moe-16b"))
    p = moe_mod.init_moe(jax.random.PRNGKey(1), cfg)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 32, cfg.d_model))
    y_g, aux_g = moe_mod.moe_forward(
        p, x, dataclasses.replace(cfg, moe_dispatch="gather")
    )
    y_o, aux_o = moe_mod.moe_forward(
        p, x, dataclasses.replace(cfg, moe_dispatch="onehot")
    )
    np.testing.assert_allclose(np.asarray(y_g), np.asarray(y_o), atol=2e-5)
    np.testing.assert_allclose(float(aux_g), float(aux_o), rtol=1e-6)


def test_mlstm_chunkwise_matches_sequential():
    from repro.models import xlstm as xl

    b, h, l, dh = 1, 2, 96, 16
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(b, h, l, dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, h, l, dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, h, l, dh)), jnp.float32)
    i_g = jnp.asarray(rng.normal(size=(b, h, l)), jnp.float32)
    f_g = jnp.asarray(2.0 + rng.normal(size=(b, h, l)), jnp.float32)
    seq = xl._mlstm_scan(q, k, v, i_g, f_g)
    par = xl._mlstm_chunkwise(q, k, v, i_g, f_g, chunk=32)
    np.testing.assert_allclose(np.asarray(seq), np.asarray(par),
                               atol=2e-4, rtol=1e-3)


def test_mamba_forward_matches_kernel_oracle():
    """models/mamba chunked scan == kernels/ref sequential oracle."""
    from repro.kernels import ref
    from repro.models.mamba import _chunked_scan

    B, L, D, N = 1, 96, 32, 8
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(B, L, D)), jnp.float32)
    dt = jnp.asarray(0.05 + 0.1 * rng.random((B, L, D)), jnp.float32)
    a = jnp.asarray(-np.exp(rng.normal(size=(D, N))), jnp.float32)
    bm = jnp.asarray(rng.normal(size=(B, L, N)), jnp.float32)
    cm = jnp.asarray(rng.normal(size=(B, L, N)), jnp.float32)
    y, h = _chunked_scan(x, dt, a, bm, cm, chunk=32)
    ye, he = ref.mamba_scan_ref(x, dt, a, bm, cm)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ye), atol=3e-3)
    np.testing.assert_allclose(np.asarray(h), np.asarray(he), atol=3e-3)

"""Self-timed executor, static orders, run-time admission (paper §4.4-§5)."""

import dataclasses

import numpy as np
import pytest

from repro.core import (
    DYNAP_SE,
    AdmissionController,
    AdmissionError,
    HardwareState,
    SelfTimedExecutor,
    analyze_throughput,
    bind_ours,
    bind_pycarl,
    bind_spinemap,
    build_static_orders,
    design_time_compile,
    measured_throughput,
    mcr_howard,
    partition_greedy,
    project_order,
    random_orders,
    runtime_admit,
    sdfg_from_clusters,
    single_tile_order,
    small_app,
    verify_deadlock_free,
)
from repro.core.sdfg import SDFG, Channel


@pytest.fixture(scope="module")
def compiled():
    snn = small_app(220, 2600, seed=11)
    cl = partition_greedy(snn, DYNAP_SE)
    app = sdfg_from_clusters(cl, hw=DYNAP_SE)
    return snn, cl, app


def test_executor_matches_mcr_on_dedicated_tiles():
    """1 actor per tile, strongly connected -> period == MCR exactly."""
    n = 4
    tau = np.array([2.0, 3.0, 1.0, 4.0])
    channels = [Channel(i, i, 1, 1.0, kind="self") for i in range(n)]
    for i in range(n):
        channels.append(Channel(i, (i + 1) % n, 1 if i == n - 1 else 0, 1.0))
    g = SDFG(n_actors=n, exec_time=tau, channels=channels)
    hw = dataclasses.replace(DYNAP_SE, n_tiles=4)
    binding = np.arange(n)
    ex = SelfTimedExecutor(g, binding, hw)
    trace = ex.run(iterations=400)
    # compare against the MCR of the same hardware-aware graph the executor
    # runs (incl. NoC delays + buffer back-edges); period includes the
    # pipeline-fill transient, amortized over many iterations
    rho = mcr_howard(ex.graph)
    assert np.isclose(trace.period, rho, rtol=0.02), (trace.period, rho)
    # and the raw-graph MCR is a lower bound (no resource penalties)
    assert mcr_howard(g) <= trace.period + 1e-9


def test_static_order_analysis_matches_simulation(compiled):
    _, cl, app = compiled
    b = bind_ours(cl, DYNAP_SE)
    orders, _ = build_static_orders(app, b.binding, DYNAP_SE)
    analytic = analyze_throughput(app, b.binding, DYNAP_SE, orders)
    simulated = measured_throughput(app, b.binding, DYNAP_SE, orders,
                                    iterations=40)
    assert analytic > 0 and simulated > 0
    assert np.isclose(analytic, simulated, rtol=0.05), (analytic, simulated)


def test_static_order_beats_random_order(compiled):
    _, cl, app = compiled
    b = bind_ours(cl, DYNAP_SE)
    static, _ = build_static_orders(app, b.binding, DYNAP_SE)
    thr_static = measured_throughput(app, b.binding, DYNAP_SE, static)
    worst_random = min(
        measured_throughput(
            app, b.binding, DYNAP_SE, random_orders(app, b.binding, DYNAP_SE,
                                                    seed=s)
        )
        for s in range(3)
    )
    assert thr_static >= worst_random * 0.999


def test_binding_strategies_disagree(compiled):
    _, cl, _ = compiled
    ours = bind_ours(cl, DYNAP_SE).binding
    spine = bind_spinemap(cl, DYNAP_SE).binding
    pycarl = bind_pycarl(cl, DYNAP_SE).binding
    assert len(ours) == len(spine) == len(pycarl) == cl.n_clusters
    for b in (ours, spine, pycarl):
        assert b.min() >= 0 and b.max() < DYNAP_SE.n_tiles


def test_runtime_projection_deadlock_free(compiled):
    snn, cl, app = compiled
    order, _ = single_tile_order(cl, DYNAP_SE)
    assert sorted(order) == list(range(cl.n_clusters))
    state = HardwareState(DYNAP_SE)
    report = runtime_admit(cl, state, order)
    assert report.throughput > 0
    assert verify_deadlock_free(cl, DYNAP_SE, report)


def test_runtime_admission_faster_than_design_time(compiled):
    snn, cl, app = compiled
    design = design_time_compile(cl, DYNAP_SE)
    order, _ = single_tile_order(cl, DYNAP_SE)
    state = HardwareState(DYNAP_SE)
    run = runtime_admit(cl, state, order)
    # admission skips schedule construction: scheduling time must shrink
    assert run.schedule_time_s < design.schedule_time_s
    # and throughput stays within a bounded gap of design time (paper: ~15%)
    assert run.throughput >= 0.5 * design.throughput


def test_runtime_adapts_to_partial_availability(compiled):
    snn, cl, app = compiled
    order, _ = single_tile_order(cl, DYNAP_SE)
    state = HardwareState(DYNAP_SE)
    state.allocated["other-app"] = [0, 1]  # two tiles already taken
    report = runtime_admit(cl, state, order)
    used = set(report.binding.tolist())
    assert used <= {2, 3}
    assert report.throughput > 0


def test_project_order_preserves_relative_order():
    order = [4, 2, 0, 3, 1, 5]
    binding = np.array([0, 1, 0, 1, 0, 1])
    per_tile = project_order(order, binding, 2)
    assert per_tile[0] == [4, 2, 0]
    assert per_tile[1] == [3, 1, 5]


def test_admission_controller_lifecycle(compiled):
    """admit -> evict -> re-admit: tiles cycle back, the design-time
    artifact cache makes re-admission skip clustering and ordering."""
    snn, cl, _ = compiled
    ctl = AdmissionController(DYNAP_SE)
    art = ctl.register(cl)
    assert art.design_time_s > 0 and sorted(art.single_order) == list(
        range(cl.n_clusters)
    )
    # registering again is a pure cache hit (same object, no recompute)
    assert ctl.register(cl) is art

    rep1 = ctl.admit(snn.name, n_tiles_request=2)
    tiles1 = ctl.running()[snn.name]
    assert len(tiles1) == 2 and rep1.throughput > 0
    # double admission of a running app is refused
    with pytest.raises(AdmissionError, match="already running"):
        ctl.admit(snn.name)

    freed = ctl.evict(snn.name)
    assert freed == tiles1
    assert ctl.running() == {}
    assert len(ctl.free_tiles()) == DYNAP_SE.n_tiles

    # re-admission: cache hit, no clustering/ordering redone
    hits_before = art.hits
    rep2 = ctl.admit(snn.name)
    assert art.hits > hits_before
    assert rep2.throughput > 0
    assert len(ctl.running()[snn.name]) == DYNAP_SE.n_tiles

    kinds = [e.kind for e in ctl.events]
    assert kinds == ["admit", "reject", "evict", "admit"]
    assert all(e.cache_hit for e in ctl.events if e.kind == "admit")


def test_admission_controller_multi_tenant_and_rejection(compiled):
    snn, cl, _ = compiled
    ctl = AdmissionController(DYNAP_SE)
    other = dataclasses.replace(cl, snn=dataclasses.replace(cl.snn, name="app-b"))
    ctl.register(cl)
    ctl.register(other)

    ctl.admit(snn.name, n_tiles_request=2)
    ctl.admit("app-b", n_tiles_request=2)
    # chip is full: a third tenant (fresh name) must be rejected and logged
    third = dataclasses.replace(cl, snn=dataclasses.replace(cl.snn, name="app-c"))
    with pytest.raises(AdmissionError):
        ctl.admit(third)
    assert ctl.events[-1].kind == "reject" and ctl.events[-1].app == "app-c"

    # tenants own disjoint tiles; finishing one frees exactly its tiles
    run = ctl.running()
    assert set(run[snn.name]).isdisjoint(run["app-b"])
    ctl.finish("app-b")
    assert sorted(ctl.free_tiles()) == sorted(run["app-b"])
    with pytest.raises(KeyError):
        ctl.finish("app-b")

    assert ctl.admit("app-c", n_tiles_request=2).throughput > 0


def test_more_tiles_scale_throughput():
    """Paper Fig. 16: more tiles generally improve throughput.  Not strictly
    monotone per-app (inter-tile AER traffic has a price; ImgSmooth is flat
    in the paper too), so use a deep, moderately-active app where pipelining
    across tiles genuinely helps, and assert with a comm-cost tolerance."""
    from repro.core import calibrate_spikes
    from repro.core.snn import feedforward

    snn = feedforward([128] * 10, 12_000, seed=5, name="deep")
    snn = calibrate_spikes(snn, 4.0 * snn.n_neurons, seed=6)
    cl = partition_greedy(snn, DYNAP_SE)
    assert cl.n_clusters >= 8
    thrs = []
    for n_tiles in (1, 4, 16):
        hw = dataclasses.replace(DYNAP_SE, n_tiles=n_tiles)
        rep = design_time_compile(cl, hw)
        thrs.append(rep.throughput)
    assert thrs[1] >= thrs[0] * 1.02, thrs   # 4 tiles beat 1 tile
    assert thrs[2] >= thrs[1] * 0.95, thrs   # 16 no worse than 4

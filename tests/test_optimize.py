"""Throughput-in-the-loop binding optimizer: invariants, batching contract,
registry/admission integration, and the SpiNeMap load-cap regression."""

import dataclasses

import numpy as np
import pytest

import repro.core.engine as engine_mod
from repro.core import (
    APP_NAMES,
    DYNAP_SE,
    AdmissionController,
    HardwareState,
    bind_optimized,
    bind_ours,
    bind_spinemap,
    build_app,
    optimize_binding,
    partition_greedy,
    runtime_admit,
    single_tile_order,
    small_app,
    sweep,
)
from repro.core.binding import BindingResult, LoadWeights, _cluster_loads, cut_spikes
from repro.core.explore import BINDERS
from repro.core.partition import ClusteredSNN


@pytest.fixture(scope="module")
def tiny():
    snn = small_app(260, 3200, seed=31)
    return partition_greedy(snn, DYNAP_SE)


# ======================================================================
# optimizer invariants
# ======================================================================
def test_optimized_never_worse_than_seeds_on_standard_apps():
    """Acceptance invariant: on every Table-1 app, the optimized binding's
    exact period is <= every heuristic seed's (the seeds are in the final
    exact scoring pool, so this holds by construction)."""
    rng = np.random.default_rng(2024)
    for name in APP_NAMES:
        cl = partition_greedy(build_app(name), DYNAP_SE)
        rep = optimize_binding(
            cl, DYNAP_SE, population=16, generations=2, elite=4,
            rng_seed=int(rng.integers(0, 2**31)),
        )
        assert rep.period <= rep.best_seed_period * (1 + 1e-9), name
        assert rep.period <= min(rep.seed_periods.values()) * (1 + 1e-9), name
        assert rep.throughput > 0, name
        assert rep.binding.shape == (cl.n_clusters,)
        assert rep.binding.min() >= 0 and rep.binding.max() < DYNAP_SE.n_tiles


def test_optimizer_deterministic_under_fixed_seed(tiny):
    a = optimize_binding(tiny, DYNAP_SE, population=24, generations=3, rng_seed=7)
    b = optimize_binding(tiny, DYNAP_SE, population=24, generations=3, rng_seed=7)
    np.testing.assert_array_equal(a.binding, b.binding)
    assert a.period == b.period
    assert [h.best_period for h in a.history] == [h.best_period for h in b.history]


def test_optimizer_improves_on_mlp():
    """MLP-MNIST has real headroom over the Eq.-7 heuristics; the default
    budget must find a strictly better binding (regression guard on the
    guided mutations)."""
    cl = partition_greedy(build_app("MLP-MNIST"), DYNAP_SE)
    rep = optimize_binding(cl, DYNAP_SE, population=64, generations=6, rng_seed=0)
    assert rep.improvement > 1e-4
    assert rep.period < rep.best_seed_period


# ======================================================================
# batching contract: one EdgeStack build per generation (+ final rescore)
# ======================================================================
def test_one_stack_build_per_generation(tiny, monkeypatch):
    calls = []
    real = engine_mod.stack_hardware_aware

    def counting(app, bindings, hw, orders_list=None, **kw):
        b = np.asarray(bindings)
        calls.append(1 if b.ndim == 1 else b.shape[0])
        return real(app, bindings, hw, orders_list, **kw)

    monkeypatch.setattr(engine_mod, "stack_hardware_aware", counting)
    gens, pop = 4, 24
    rep = optimize_binding(tiny, DYNAP_SE, population=pop, generations=gens,
                           rng_seed=1)
    # one build per generation plus exactly one final exact re-score
    assert len(calls) == gens + 1
    assert rep.n_stack_builds == gens + 1
    # every generation scores the whole population in its single build
    assert all(c == pop for c in calls[:gens])


# ======================================================================
# integration: BINDERS registry, sweep, admission knob
# ======================================================================
def test_bind_optimized_registered_and_sweepable(tiny):
    assert BINDERS["optimized"] is bind_optimized
    res = bind_optimized(tiny, DYNAP_SE, population=12, generations=2)
    assert isinstance(res, BindingResult)
    assert res.strategy == "optimized"

    report = sweep(
        [tiny.snn], tile_counts=(4,), binders=("ours", "optimized"),
    )
    pts = {p.binder: p for p in report.points}
    assert set(pts) == {"ours", "optimized"}
    assert pts["optimized"].throughput > 0
    # NOTE: the sweep re-scores the binding under freshly built FCFS
    # static orders, not the Lemma-1 projection the optimizer optimized
    # against, so "never worse" is only structural inside optimize_binding
    # (tested above); here we only guard against gross regressions.
    assert pts["optimized"].throughput >= pts["ours"].throughput * 0.9


def test_runtime_admit_optimize_budget(tiny):
    order, _ = single_tile_order(tiny, DYNAP_SE)
    plain = runtime_admit(tiny, HardwareState(DYNAP_SE), order,
                          n_tiles_request=2)
    tuned = runtime_admit(tiny, HardwareState(DYNAP_SE), order,
                          n_tiles_request=2, optimize_budget=(2, 12))
    # heuristic binding is a seed of the refinement: never worse
    assert tuned.throughput >= plain.throughput * (1 - 1e-6)
    assert len(set(tuned.binding.tolist())) <= 2


def test_admission_controller_optimize_budget(tiny):
    # population below the default elite count must clamp, not crash
    ctl = AdmissionController(DYNAP_SE, optimize_budget=(2, 4))
    ctl.register(tiny)
    rep = ctl.admit(tiny.snn.name, n_tiles_request=2)
    assert rep.throughput > 0
    assert ctl.running() == {tiny.snn.name: sorted(set(rep.binding.tolist()))}


def test_optimize_budget_validation(tiny):
    with pytest.raises(ValueError, match="optimize budget"):
        optimize_binding(tiny, DYNAP_SE, population=1, generations=2)
    with pytest.raises(ValueError, match="optimize budget"):
        optimize_binding(tiny, DYNAP_SE, population=8, generations=0)


# ======================================================================
# SpiNeMap balance-cap regression: cap accumulated load, not counts
# ======================================================================
def _skewed_clusters(n=16, n_tiles=4):
    """4 heavy clusters (indices 0,4,8,12) with strong mutual traffic that
    pulls them onto one tile; 12 light clusters with negligible load."""
    heavy = np.array([0, 4, 8, 12])
    out_spikes = np.full(n, 1.0)
    out_spikes[heavy] = 1000.0
    src, dst, rate = [], [], []
    for i in heavy:
        for j in heavy:
            if i < j:
                src.append(i)
                dst.append(j)
                rate.append(5000.0)
    for i in range(n - 1):  # weak chain keeps the rest connected
        src.append(i)
        dst.append(i + 1)
        rate.append(1.0)
    order = np.lexsort((np.array(dst), np.array(src)))
    return ClusteredSNN(
        snn=None,
        cluster_of=np.zeros(n, dtype=np.int32),
        n_clusters=n,
        channel_src=np.array(src, dtype=np.int64)[order],
        channel_dst=np.array(dst, dtype=np.int64)[order],
        channel_rate=np.array(rate)[order],
        inputs_used=np.full(n, 10.0),
        neurons_used=np.full(n, 10.0),
        synapses_used=np.full(n, 50.0),
        out_spikes=out_spikes,
        in_spikes=out_spikes.copy(),
    )


def test_spinemap_caps_accumulated_load_not_counts():
    cl = _skewed_clusters()
    hw = DYNAP_SE  # 4 tiles
    res = bind_spinemap(cl, hw)
    loads = _cluster_loads(cl, LoadWeights(), hw)
    tile_load = np.bincount(res.binding, weights=loads, minlength=hw.n_tiles)
    cap = 1.5 * loads.sum() / hw.n_tiles
    # the old count cap admitted all four heavy clusters onto one tile
    # (4 < ceil(1.5 * 16/4) = 6) -> one tile carried ~all the load
    assert tile_load.max() <= cap + 1e-9
    # and the binder still pursues its own objective: the cut does not
    # regress vs the contiguous seed it starts from
    seed = (np.arange(cl.n_clusters) * hw.n_tiles // cl.n_clusters).astype(int)
    assert cut_spikes(cl, res.binding) <= cut_spikes(cl, seed) + 1e-9


def test_spinemap_load_cap_allows_normal_kl_moves(tiny):
    """The cap must not freeze the optimizer on benign inputs: on a real
    clustering, spinemap still reduces cut spikes vs the load balancer."""
    spine = bind_spinemap(tiny, DYNAP_SE)
    ours = bind_ours(tiny, DYNAP_SE)
    assert cut_spikes(tiny, spine.binding) <= cut_spikes(tiny, ours.binding)

"""End-to-end behaviour tests for the paper's system (Fig. 2 pipeline) and
the headline claims of §7, at test scale."""

import numpy as np
import pytest

from repro.core import (
    DYNAP_SE,
    APP_NAMES,
    analyze_throughput,
    bind_ours,
    bind_pycarl,
    bind_spinemap,
    build_app,
    build_static_orders,
    cut_spikes,
    measured_throughput,
    partition_greedy,
    random_orders,
    sdfg_from_clusters,
    small_app,
)


@pytest.fixture(scope="module")
def pipeline():
    snn = small_app(400, 5000, seed=21)
    cl = partition_greedy(snn, DYNAP_SE)
    app = sdfg_from_clusters(cl, hw=DYNAP_SE)
    return snn, cl, app


def test_full_pipeline_produces_throughput(pipeline):
    _, cl, app = pipeline
    rep = bind_ours(cl, DYNAP_SE)
    orders, _ = build_static_orders(app, rep.binding, DYNAP_SE)
    thr = analyze_throughput(app, rep.binding, DYNAP_SE, orders)
    assert thr > 0


def test_claim_static_order_beats_random(pipeline):
    """§7.1: static-order scheduling improves throughput vs random order."""
    _, cl, app = pipeline
    rep = bind_ours(cl, DYNAP_SE)
    static, _ = build_static_orders(app, rep.binding, DYNAP_SE)
    thr_static = analyze_throughput(app, rep.binding, DYNAP_SE, static)
    thr_rand = np.mean([
        analyze_throughput(app, rep.binding, DYNAP_SE,
                           random_orders(app, rep.binding, DYNAP_SE, seed=s))
        for s in range(3)
    ])
    assert thr_static >= 0.99 * thr_rand


def test_claim_spinemap_minimizes_cut(pipeline):
    """SpiNeMap's objective really is lower inter-tile traffic than ours."""
    _, cl, _ = pipeline
    spine = bind_spinemap(cl, DYNAP_SE)
    ours = bind_ours(cl, DYNAP_SE)
    assert cut_spikes(cl, spine.binding) <= cut_spikes(cl, ours.binding) * 1.001


def test_claim_ours_balances_load(pipeline):
    """Eq. 7: our binding spreads clusters more evenly than SpiNeMap."""
    _, cl, _ = pipeline
    spine = bind_spinemap(cl, DYNAP_SE).clusters_per_tile(4)
    ours = bind_ours(cl, DYNAP_SE).clusters_per_tile(4)
    assert np.std(ours) <= np.std(spine) + 1e-9


def test_analytic_equals_operational(pipeline):
    """MCR of the order-augmented graph == self-timed steady-state period."""
    _, cl, app = pipeline
    rep = bind_ours(cl, DYNAP_SE)
    orders, _ = build_static_orders(app, rep.binding, DYNAP_SE)
    analytic = analyze_throughput(app, rep.binding, DYNAP_SE, orders)
    sim = measured_throughput(app, rep.binding, DYNAP_SE, orders, iterations=30)
    assert np.isclose(analytic, sim, rtol=0.05)


@pytest.mark.parametrize("name", ["ImgSmooth", "MLP-MNIST", "CNN-MNIST"])
def test_real_apps_compile(name):
    snn = build_app(name)
    cl = partition_greedy(snn, DYNAP_SE)
    app = sdfg_from_clusters(cl, hw=DYNAP_SE)
    rep = bind_ours(cl, DYNAP_SE)
    orders, _ = build_static_orders(app, rep.binding, DYNAP_SE)
    thr = analyze_throughput(app, rep.binding, DYNAP_SE, orders)
    assert thr > 0
    assert app.is_live()

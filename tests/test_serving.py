"""Cross-region fused scoring and the serving layer (ISSUE 9 tentpole):
fused EdgeStack analysis == sequential analysis, lockstep fused binding
search == standalone search, and coalesced rebalancing via
:class:`ServingQueue` / ``defer_rebalances``."""

import dataclasses

import numpy as np
import pytest

from repro.core import (
    DYNAP_SE,
    AdmissionController,
    ServingQueue,
    batch_execute,
    batch_execute_fused,
    fuse_stacks,
    mcr_batch,
    optimize_binding_graph,
    optimize_binding_graphs_fused,
    partition_greedy,
    prepare_execution,
    project_order_batch,
    sdfg_from_clusters,
    single_tile_order,
    small_app,
)

HW64 = dataclasses.replace(DYNAP_SE, n_tiles=64)


def _compiled(seed, neurons=170, synapses=2100):
    snn = small_app(neurons, synapses, seed=seed)
    cl = partition_greedy(snn, DYNAP_SE)
    app = sdfg_from_clusters(cl, hw=DYNAP_SE)
    order, _ = single_tile_order(cl, DYNAP_SE)
    return app, order


def _bindings(app, n_rows, seed):
    rng = np.random.default_rng(seed)
    return np.stack([
        rng.integers(0, DYNAP_SE.n_tiles, size=app.n_actors)
        for _ in range(n_rows)
    ])


# ======================================================================
# engine layer: fused stacks solve row-identically
# ======================================================================
def test_fuse_stacks_rows_solve_identically():
    preps = []
    for seed, rows in ((1, 3), (2, 5), (3, 2)):
        app, order = _compiled(seed)
        b = _bindings(app, rows, seed)
        ob = project_order_batch(order, b)
        preps.append(prepare_execution(app, b, DYNAP_SE, ob))
    fused, slices = fuse_stacks([p.stack for p in preps])
    assert fused.n_graphs == sum(p.n_rows for p in preps)
    got = mcr_batch(fused, backend="edges")
    for p, s in zip(preps, slices):
        alone = mcr_batch(p.stack, backend="edges")
        np.testing.assert_array_equal(got[s], alone)


def test_batch_execute_fused_matches_sequential():
    preps, reports = [], []
    for seed, rows in ((4, 4), (5, 3)):
        app, order = _compiled(seed)
        b = _bindings(app, rows, seed)
        ob = project_order_batch(order, b)
        preps.append(
            prepare_execution(app, b, DYNAP_SE, ob, with_energy=True)
        )
        reports.append(
            batch_execute(app, b, DYNAP_SE, ob, backend="edges",
                          with_energy=True)
        )
    fused_reports = batch_execute_fused(preps, backend="edges")
    for fr, sr in zip(fused_reports, reports):
        np.testing.assert_allclose(fr.periods, sr.periods, rtol=1e-12)
        np.testing.assert_allclose(fr.energies, sr.energies, rtol=1e-12)


# ======================================================================
# optimizer layer: lockstep fused search == standalone search
# ======================================================================
def _task(seed, *, generations, population=10):
    app, order = _compiled(seed)
    seed_b = (np.arange(app.n_actors) + seed) % DYNAP_SE.n_tiles
    return dict(
        app=app, hw=DYNAP_SE, single_order=order,
        seed_bindings={"seed": seed_b},
        population=population, generations=generations, elite=4,
        rng_seed=seed,
    )


def test_fused_binding_search_bit_matches_sequential():
    """Equal generation counts: every tick fuses into exactly one solve,
    and each search's result is bit-for-bit its standalone run."""
    tasks = [_task(7, generations=2), _task(8, generations=2)]
    seq = [
        optimize_binding_graph(
            t["app"], t["hw"], t["single_order"],
            **{k: v for k, v in t.items()
               if k not in ("app", "hw", "single_order")},
        )
        for t in tasks
    ]
    fused = optimize_binding_graphs_fused(tasks)
    for f, s in zip(fused, seq):
        np.testing.assert_array_equal(f.binding, s.binding)
        assert f.period == s.period
        assert f.n_stack_builds == s.n_stack_builds
        assert [g.best_period for g in f.history] == \
               [g.best_period for g in s.history]


def test_fused_binding_search_mixed_generations():
    """Unequal horizons exercise the per-(tick, tolerance) grouping: a
    finished search's tight final re-score must never be fused with
    another search's loose generation scoring."""
    tasks = [_task(9, generations=1), _task(10, generations=3)]
    seq = [
        optimize_binding_graph(
            t["app"], t["hw"], t["single_order"],
            **{k: v for k, v in t.items()
               if k not in ("app", "hw", "single_order")},
        )
        for t in tasks
    ]
    fused = optimize_binding_graphs_fused(tasks)
    for f, s in zip(fused, seq):
        np.testing.assert_array_equal(f.binding, s.binding)
        assert f.period == s.period
        assert f.n_stack_builds == s.n_stack_builds


# ======================================================================
# runtime/serving layer: deferral + coalesced flush
# ======================================================================
def _registered_controller(n_apps=6, seed0=300, **kw):
    ctl = AdmissionController(
        HW64, placement="joint", joint_budget=(1, 4), **kw
    )
    names = []
    for i in range(n_apps):
        snn = small_app(150, 1800, seed=seed0 + i)
        snn.name = f"sv{i}"
        ctl.register(snn)
        names.append(snn.name)
    return ctl, names


def _rebalance_count(ctl):
    return sum(1 for e in ctl.events if e.kind == "rebalance")


def test_defer_rebalances_records_then_flushes_once():
    ctl, names = _registered_controller()
    for n in names[:2]:
        ctl.admit(n, n_tiles_request=3)
    before = _rebalance_count(ctl)
    with ctl.defer_rebalances():
        for n in names[2:5]:
            ctl.admit(n, n_tiles_request=3)
        assert _rebalance_count(ctl) == before   # recorded, not run
    after = _rebalance_count(ctl)
    assert after == before + 1                   # ONE merged flush
    assert set(ctl.state.allocated) == set(names[:5])


def test_flush_rebalances_noop_when_nothing_pending():
    ctl, names = _registered_controller(n_apps=2)
    ctl.admit(names[0], n_tiles_request=3)
    assert ctl.flush_rebalances() == 0


def test_serving_queue_window_validation():
    ctl, _ = _registered_controller(n_apps=2)
    with pytest.raises(ValueError):
        ServingQueue(ctl, coalesce_window=0)


def test_serving_queue_drain_matches_per_event_residency():
    """The coalesced drain must land on the same resident set as the
    per-event loop, with fewer rebalances and a clean never-regress
    trace."""
    stream = ["sv0", "sv1", "sv2", "sv0", "sv3", "sv4", "sv1", "sv5"]

    ctl_a, _ = _registered_controller()
    for n in stream:
        if n in ctl_a.state.allocated:
            ctl_a.evict(n)
        else:
            ctl_a.admit(n, n_tiles_request=3)

    ctl_b, _ = _registered_controller()
    q = ServingQueue(ctl_b, coalesce_window=4)
    resident = set()
    for n in stream:
        if n in resident:
            q.submit_evict(n)
            resident.discard(n)
        else:
            q.submit_admit(n, n_tiles_request=3)
            resident.add(n)
    stats = q.drain()

    assert q.pending == 0
    assert stats["processed"] == len(stream)
    assert stats["rejected"] == 0 and stats["skipped"] == 0
    assert set(ctl_b.state.allocated) == set(ctl_a.state.allocated)
    assert stats["flushes"] == 2                     # ceil(8 / 4)
    assert stats["coalesced_events"] > 0
    assert _rebalance_count(ctl_b) <= _rebalance_count(ctl_a)
    # admit latency percentiles are well-formed
    assert stats["admit_latency_p99_s"] >= stats["admit_latency_p50_s"] >= 0

    prev = None
    for e in ctl_b.events:
        if e.kind == "rebalance" and prev is not None and prev > 0:
            assert e.chip_throughput >= prev * (1 - 1e-6)
        if e.chip_throughput and e.chip_throughput > 0:
            prev = e.chip_throughput


def test_serving_queue_skips_evicting_non_resident():
    ctl, names = _registered_controller(n_apps=2)
    q = ServingQueue(ctl, coalesce_window=2)
    q.submit_evict(names[1])                 # never admitted
    q.submit_admit(names[0], n_tiles_request=3)
    stats = q.drain()
    assert stats["skipped"] == 1 and stats["admitted"] == 1
    kinds = {t.app: t.status for t in q.tickets}
    assert kinds[names[1]] == "skipped" and kinds[names[0]] == "ok"

"""Cross-region fused scoring and the serving layer (ISSUE 9 tentpole):
fused EdgeStack analysis == sequential analysis, lockstep fused binding
search == standalone search, and coalesced rebalancing via
:class:`ServingQueue` / ``defer_rebalances``."""

import dataclasses

import numpy as np
import pytest

from repro.core import (
    DYNAP_SE,
    AdmissionController,
    ServingQueue,
    batch_execute,
    batch_execute_fused,
    fuse_stacks,
    mcr_batch,
    optimize_binding_graph,
    optimize_binding_graphs_fused,
    partition_greedy,
    prepare_execution,
    project_order_batch,
    sdfg_from_clusters,
    single_tile_order,
    small_app,
)

HW64 = dataclasses.replace(DYNAP_SE, n_tiles=64)


def _compiled(seed, neurons=170, synapses=2100):
    snn = small_app(neurons, synapses, seed=seed)
    cl = partition_greedy(snn, DYNAP_SE)
    app = sdfg_from_clusters(cl, hw=DYNAP_SE)
    order, _ = single_tile_order(cl, DYNAP_SE)
    return app, order


def _bindings(app, n_rows, seed):
    rng = np.random.default_rng(seed)
    return np.stack([
        rng.integers(0, DYNAP_SE.n_tiles, size=app.n_actors)
        for _ in range(n_rows)
    ])


# ======================================================================
# engine layer: fused stacks solve row-identically
# ======================================================================
def test_fuse_stacks_rows_solve_identically():
    preps = []
    for seed, rows in ((1, 3), (2, 5), (3, 2)):
        app, order = _compiled(seed)
        b = _bindings(app, rows, seed)
        ob = project_order_batch(order, b)
        preps.append(prepare_execution(app, b, DYNAP_SE, ob))
    fused, slices = fuse_stacks([p.stack for p in preps])
    assert fused.n_graphs == sum(p.n_rows for p in preps)
    got = mcr_batch(fused, backend="edges")
    for p, s in zip(preps, slices):
        alone = mcr_batch(p.stack, backend="edges")
        np.testing.assert_array_equal(got[s], alone)


def test_batch_execute_fused_matches_sequential():
    preps, reports = [], []
    for seed, rows in ((4, 4), (5, 3)):
        app, order = _compiled(seed)
        b = _bindings(app, rows, seed)
        ob = project_order_batch(order, b)
        preps.append(
            prepare_execution(app, b, DYNAP_SE, ob, with_energy=True)
        )
        reports.append(
            batch_execute(app, b, DYNAP_SE, ob, backend="edges",
                          with_energy=True)
        )
    fused_reports = batch_execute_fused(preps, backend="edges")
    for fr, sr in zip(fused_reports, reports):
        np.testing.assert_allclose(fr.periods, sr.periods, rtol=1e-12)
        np.testing.assert_allclose(fr.energies, sr.energies, rtol=1e-12)


# ======================================================================
# optimizer layer: lockstep fused search == standalone search
# ======================================================================
def _task(seed, *, generations, population=10):
    app, order = _compiled(seed)
    seed_b = (np.arange(app.n_actors) + seed) % DYNAP_SE.n_tiles
    return dict(
        app=app, hw=DYNAP_SE, single_order=order,
        seed_bindings={"seed": seed_b},
        population=population, generations=generations, elite=4,
        rng_seed=seed,
    )


def test_fused_binding_search_bit_matches_sequential():
    """Equal generation counts: every tick fuses into exactly one solve,
    and each search's result is bit-for-bit its standalone run."""
    tasks = [_task(7, generations=2), _task(8, generations=2)]
    seq = [
        optimize_binding_graph(
            t["app"], t["hw"], t["single_order"],
            **{k: v for k, v in t.items()
               if k not in ("app", "hw", "single_order")},
        )
        for t in tasks
    ]
    fused = optimize_binding_graphs_fused(tasks)
    for f, s in zip(fused, seq):
        np.testing.assert_array_equal(f.binding, s.binding)
        assert f.period == s.period
        assert f.n_stack_builds == s.n_stack_builds
        assert [g.best_period for g in f.history] == \
               [g.best_period for g in s.history]


def test_fused_binding_search_mixed_generations():
    """Unequal horizons exercise the per-(tick, tolerance) grouping: a
    finished search's tight final re-score must never be fused with
    another search's loose generation scoring."""
    tasks = [_task(9, generations=1), _task(10, generations=3)]
    seq = [
        optimize_binding_graph(
            t["app"], t["hw"], t["single_order"],
            **{k: v for k, v in t.items()
               if k not in ("app", "hw", "single_order")},
        )
        for t in tasks
    ]
    fused = optimize_binding_graphs_fused(tasks)
    for f, s in zip(fused, seq):
        np.testing.assert_array_equal(f.binding, s.binding)
        assert f.period == s.period
        assert f.n_stack_builds == s.n_stack_builds


# ======================================================================
# runtime/serving layer: deferral + coalesced flush
# ======================================================================
def _registered_controller(n_apps=6, seed0=300, **kw):
    ctl = AdmissionController(
        HW64, placement="joint", joint_budget=(1, 4), **kw
    )
    names = []
    for i in range(n_apps):
        snn = small_app(150, 1800, seed=seed0 + i)
        snn.name = f"sv{i}"
        ctl.register(snn)
        names.append(snn.name)
    return ctl, names


def _rebalance_count(ctl):
    return sum(1 for e in ctl.events if e.kind == "rebalance")


def test_defer_rebalances_records_then_flushes_once():
    ctl, names = _registered_controller()
    for n in names[:2]:
        ctl.admit(n, n_tiles_request=3)
    before = _rebalance_count(ctl)
    with ctl.defer_rebalances():
        for n in names[2:5]:
            ctl.admit(n, n_tiles_request=3)
        assert _rebalance_count(ctl) == before   # recorded, not run
    after = _rebalance_count(ctl)
    assert after == before + 1                   # ONE merged flush
    assert set(ctl.state.allocated) == set(names[:5])


def test_flush_rebalances_noop_when_nothing_pending():
    ctl, names = _registered_controller(n_apps=2)
    ctl.admit(names[0], n_tiles_request=3)
    assert ctl.flush_rebalances() == 0


def test_serving_queue_window_validation():
    ctl, _ = _registered_controller(n_apps=2)
    with pytest.raises(ValueError):
        ServingQueue(ctl, coalesce_window=0)


def test_serving_queue_drain_matches_per_event_residency():
    """The coalesced drain must land on the same resident set as the
    per-event loop, with fewer rebalances and a clean never-regress
    trace."""
    stream = ["sv0", "sv1", "sv2", "sv0", "sv3", "sv4", "sv1", "sv5"]

    ctl_a, _ = _registered_controller()
    for n in stream:
        if n in ctl_a.state.allocated:
            ctl_a.evict(n)
        else:
            ctl_a.admit(n, n_tiles_request=3)

    ctl_b, _ = _registered_controller()
    q = ServingQueue(ctl_b, coalesce_window=4)
    resident = set()
    for n in stream:
        if n in resident:
            q.submit_evict(n)
            resident.discard(n)
        else:
            q.submit_admit(n, n_tiles_request=3)
            resident.add(n)
    stats = q.drain()

    assert q.pending == 0
    assert stats["processed"] == len(stream)
    assert stats["rejected"] == 0 and stats["skipped"] == 0
    assert set(ctl_b.state.allocated) == set(ctl_a.state.allocated)
    assert stats["flushes"] == 2                     # ceil(8 / 4)
    assert stats["coalesced_events"] > 0
    assert _rebalance_count(ctl_b) <= _rebalance_count(ctl_a)
    # admit latency percentiles are well-formed
    assert stats["admit_latency_p99_s"] >= stats["admit_latency_p50_s"] >= 0

    prev = None
    for e in ctl_b.events:
        if e.kind == "rebalance" and prev is not None and prev > 0:
            assert e.chip_throughput >= prev * (1 - 1e-6)
        if e.chip_throughput and e.chip_throughput > 0:
            prev = e.chip_throughput


def test_serving_queue_skips_evicting_non_resident():
    ctl, names = _registered_controller(n_apps=2)
    q = ServingQueue(ctl, coalesce_window=2)
    q.submit_evict(names[1])                 # never admitted
    q.submit_admit(names[0], n_tiles_request=3)
    stats = q.drain()
    assert stats["skipped"] == 1 and stats["admitted"] == 1
    kinds = {t.app: t.status for t in q.tickets}
    assert kinds[names[1]] == "skipped" and kinds[names[0]] == "ok"


# ======================================================================
# sharded scoring (ISSUE 10 tentpole): device-chunked solves and the
# mesh= search path are bit-identical to single-device runs
# ======================================================================
def _live_stack(b, seed, n=6, e=18):
    from repro.core.maxplus import EdgeStack

    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, size=(b, e))
    dst = rng.integers(0, n, size=(b, e))
    tok = rng.integers(0, 3, size=(b, e))
    w = rng.uniform(0.1, 5.0, size=(b, e))
    src[:, 0] = dst[:, 0] = 0
    tok[:, 0] = 1                       # token-carrying self loop: live
    return EdgeStack(n_actors=n, src=src, dst=dst, tokens=tok, weights=w)


def test_mcr_batch_sharded_chunks_bit_identical():
    """Row-chunked multi-device solves (same CPU device repeated — the
    chunking logic is device-count-driven) equal the unsharded solve
    bit-for-bit, including chunk counts that do not divide the batch."""
    import jax

    dev = jax.devices()[0]
    for b in (3, 13, 64):
        stack = _live_stack(b, seed=b)
        ref = mcr_batch(stack, backend="csr-jit")
        for n_dev in (2, 3, 4, 7):
            got = mcr_batch(
                stack, backend="csr-jit", devices=[dev] * n_dev
            )
            np.testing.assert_array_equal(got, ref)


def test_mcr_batch_devices_requires_csr_jit():
    import jax

    stack = _live_stack(4, seed=1)
    with pytest.raises(ValueError):
        mcr_batch(stack, backend="edges", devices=jax.devices() * 2)


def test_batch_execute_mesh_matches_unsharded():
    import jax
    from jax.sharding import Mesh

    app, order = _compiled(11)
    b = _bindings(app, 7, 11)
    ob = project_order_batch(order, b)
    ref = batch_execute(app, b, DYNAP_SE, ob, backend="csr-jit",
                        with_energy=True)
    mesh = Mesh(np.asarray([jax.devices()[0]] * 3), ("data",))
    got = batch_execute(app, b, DYNAP_SE, ob, mesh=mesh, with_energy=True)
    np.testing.assert_array_equal(got.periods, ref.periods)
    np.testing.assert_array_equal(got.energies, ref.energies)


def test_optimize_mesh_trajectory_bit_identical():
    """mesh= sharded search == single-device csr-jit search: same
    per-generation history, same elite, same final binding/period."""
    import jax
    from jax.sharding import Mesh

    t = _task(21, generations=3)
    kw = {k: v for k, v in t.items()
          if k not in ("app", "hw", "single_order")}
    ref = optimize_binding_graph(
        t["app"], t["hw"], t["single_order"], backend="csr-jit", **kw
    )
    mesh = Mesh(np.asarray([jax.devices()[0]] * 4), ("data",))
    got = optimize_binding_graph(
        t["app"], t["hw"], t["single_order"], mesh=mesh, **kw
    )
    np.testing.assert_array_equal(got.binding, ref.binding)
    assert got.period == ref.period
    assert [g.best_period for g in got.history] == \
           [g.best_period for g in ref.history]

    fused_ref = optimize_binding_graphs_fused(
        [_task(22, generations=2)], backend="csr-jit"
    )
    fused_got = optimize_binding_graphs_fused(
        [_task(22, generations=2)], mesh=mesh
    )
    np.testing.assert_array_equal(
        fused_got[0].binding, fused_ref[0].binding
    )
    assert fused_got[0].period == fused_ref[0].period


def test_optimize_mesh_forced_host_devices_subprocess():
    """The acceptance check: under a REAL forced 4-device host platform
    (XLA_FLAGS must precede the jax import, hence the subprocess), the
    host_mesh(4) search trajectory is bit-identical to the unsharded
    one at the same rng_seed."""
    import os
    import subprocess
    import sys

    script = r"""
import numpy as np
from repro.core import (
    DYNAP_SE, optimize_binding_graph, partition_greedy,
    sdfg_from_clusters, single_tile_order, small_app,
)
from repro.launch.sharding import host_mesh
import jax
assert len(jax.devices()) == 4, jax.devices()

snn = small_app(150, 1800, seed=33)
cl = partition_greedy(snn, DYNAP_SE)
app = sdfg_from_clusters(cl, hw=DYNAP_SE)
order, _ = single_tile_order(cl, DYNAP_SE)
kw = dict(
    seed_bindings={"s": np.arange(app.n_actors) % DYNAP_SE.n_tiles},
    population=8, generations=2, elite=4, rng_seed=0,
)
ref = optimize_binding_graph(app, DYNAP_SE, order, backend="csr-jit", **kw)
got = optimize_binding_graph(
    app, DYNAP_SE, order, mesh=host_mesh(4), **kw
)
assert got.period == ref.period, (got.period, ref.period)
assert np.array_equal(got.binding, ref.binding)
assert [g.best_period for g in got.history] == \
    [g.best_period for g in ref.history]
print("IDENTICAL")
"""
    env = os.environ.copy()
    env["XLA_FLAGS"] = (
        env.get("XLA_FLAGS", "")
        + " --xla_force_host_platform_device_count=4"
    ).strip()
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in ("src", env.get("PYTHONPATH", "")) if p
    )
    proc = subprocess.run(
        [sys.executable, "-c", script], env=env, text=True,
        capture_output=True, timeout=600,
    )
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "IDENTICAL" in proc.stdout


# ======================================================================
# speculative pre-compilation (PrecompilePool)
# ======================================================================
def test_precompile_pool_predicts_by_decayed_frequency():
    from repro.core import PrecompilePool

    ctl, names = _registered_controller(n_apps=3)
    pool = PrecompilePool(ctl, decay=0.9, top_k=2)
    for _ in range(3):
        pool.observe(names[0])
    pool.observe(names[1])
    assert pool.predict() == [names[0], names[1]]
    # recency beats stale volume under decay
    for _ in range(4):
        pool.observe(names[2])
    assert pool.predict(1) == [names[2]]


def test_precompile_pool_warm_and_hit_accounting():
    from repro.core import PrecompilePool

    ctl = AdmissionController(HW64, placement="joint", joint_budget=(1, 4))
    apps = {}
    for i in range(3):
        snn = small_app(150, 1800, seed=400 + i)
        snn.name = f"pc{i}"
        apps[snn.name] = snn
    pool = PrecompilePool(ctl, source=apps, top_k=2)

    pool.observe("pc0")
    pool.observe("pc1")
    warmed = pool.warm()
    assert sorted(warmed) == ["pc0", "pc1"]
    assert pool.warmed_artifacts == 2
    assert ("pc0", ctl.hw) in ctl.artifacts

    assert pool.ensure("pc0") is True          # speculation paid design
    assert pool.ensure("pc2") is False         # cold: registered inline
    assert ("pc2", ctl.hw) in ctl.artifacts
    assert pool.hits == 1 and pool.misses == 1
    assert pool.stats()["hit_rate"] == 0.5

    # unresolvable prediction is skipped, never fabricated
    pool2 = PrecompilePool(ctl, top_k=1)
    pool2.observe("ghost")
    assert pool2.warm() == []


def test_serving_queue_precompile_integration():
    from repro.core import PrecompilePool

    ctl = AdmissionController(HW64, placement="joint", joint_budget=(1, 4))
    apps = {}
    for i in range(2):
        snn = small_app(150, 1800, seed=500 + i)
        snn.name = f"pi{i}"
        apps[snn.name] = snn
    pool = PrecompilePool(ctl, source=apps, top_k=2)
    q = ServingQueue(ctl, coalesce_window=2, precompile=pool)
    q.submit_admit("pi0", n_tiles_request=3)
    q.submit_admit("pi1", n_tiles_request=3)
    stats = q.drain()
    # warm() ran before the first apply: both admissions hit
    assert stats["precompile"]["hits"] == 2
    assert stats["precompile"]["misses"] == 0
    assert stats["admitted"] == 2


# ======================================================================
# async front end: cancellation + per-tenant quotas
# ======================================================================
def test_ticket_cancellation_lifecycle():
    ctl, names = _registered_controller(n_apps=3)
    q = ServingQueue(ctl, coalesce_window=2)
    t0 = q.submit_admit(names[0], n_tiles_request=3)
    t1 = q.submit_admit(names[1], n_tiles_request=3)
    assert q.cancel(t0) is True
    assert t0.status == "cancelled"
    assert q.cancel(t0) is False                 # idempotent
    stats = q.drain()
    assert stats["cancelled"] == 1 and stats["admitted"] == 1
    assert t1.status == "ok"
    assert names[0] not in ctl.state.allocated   # never applied
    assert q.cancel(t1) is False                 # drained: too late
    rejects = [e for e in ctl.events if e.kind == "reject"]
    assert [e.reason for e in rejects] == ["cancelled"]
    assert rejects[0].app == names[0]


def test_tenant_quota_rejects_without_placement():
    ctl, names = _registered_controller(n_apps=2)
    q = ServingQueue(ctl, coalesce_window=2, quotas={names[0]: 2})
    q.submit_admit(names[0], n_tiles_request=3)   # over quota
    q.submit_admit(names[1], n_tiles_request=3)
    stats = q.drain()
    assert stats["quota_rejections"] == 1
    assert stats["rejected"] == 1 and stats["admitted"] == 1
    assert names[0] not in ctl.state.allocated
    rejects = [e for e in ctl.events if e.kind == "reject"]
    assert [e.reason for e in rejects] == ["quota"]
    # under-quota re-submission passes
    q.set_quota(names[0], 8)
    q.submit_admit(names[0], n_tiles_request=3)
    assert q.drain()["admitted"] == 1


def test_quota_uses_cluster_count_when_no_explicit_request():
    ctl, names = _registered_controller(n_apps=1)
    art = ctl.artifacts[(names[0], ctl.hw)]
    q = ServingQueue(
        ctl, coalesce_window=1,
        quotas={names[0]: art.clustered.n_clusters - 1},
    )
    q.submit_admit(names[0])                      # implicit full footprint
    stats = q.drain()
    assert stats["quota_rejections"] == 1


def test_drain_reports_wait_service_breakdown():
    ctl, names = _registered_controller(n_apps=3)
    q = ServingQueue(ctl, coalesce_window=2)
    for n in names[:3]:
        q.submit_admit(n, n_tiles_request=3)
    stats = q.drain()
    for key in ("queue_wait_p50_s", "queue_wait_p99_s",
                "service_p50_s", "service_p99_s"):
        assert stats[key] >= 0.0
    assert stats["queue_wait_p99_s"] >= stats["queue_wait_p50_s"]
    assert stats["service_p99_s"] >= stats["service_p50_s"]
    # per-ticket: end-to-end latency decomposes exactly
    for t in q.tickets:
        if t.status == "ok":
            assert t.latency_s == pytest.approx(t.wait_s + t.service_s)

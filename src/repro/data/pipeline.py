"""Deterministic, shardable token data pipeline.

Two sources:
  * synthetic (default): order-k Markov token stream — deterministic per
    (seed, shard), learnable (a real LM loss signal for the e2e example),
    and infinitely long without shipping a dataset.
  * memmap: a flat uint16/uint32 token file (produced by any tokenizer),
    read with zero-copy windows.

Sharding contract: ``shard_id / num_shards`` splits the GLOBAL batch by
row — every data-parallel host constructs only its rows, deterministically,
so restarts resume bit-identically from (seed, step) without coordination.
Prefetch is a simple double-buffer thread: CPU generation overlaps device
compute (compute/IO overlap at the host level).
"""

from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    markov_order: int = 2
    path: Optional[str] = None        # memmap token file (overrides synthetic)
    token_dtype: str = "uint16"


class TokenStream:
    """Deterministic per-shard batch iterator."""

    def __init__(self, cfg: DataConfig, *, shard_id: int = 0, num_shards: int = 1):
        assert cfg.global_batch % num_shards == 0
        self.cfg = cfg
        self.shard_id = shard_id
        self.num_shards = num_shards
        self.rows = cfg.global_batch // num_shards
        self._mm = None
        if cfg.path:
            self._mm = np.memmap(cfg.path, dtype=cfg.token_dtype, mode="r")
        else:
            # fixed random transition structure shared by all shards
            rng = np.random.default_rng(cfg.seed)
            k = 64  # states
            self._proj = rng.integers(0, k, size=(cfg.markov_order, cfg.vocab))
            logits = rng.normal(size=(k, cfg.vocab))
            top = np.argsort(logits, axis=1)[:, -32:]
            probs = np.zeros((k, cfg.vocab))
            for s in range(k):
                probs[s, top[s]] = np.exp(logits[s, top[s]])
            self._probs = probs / probs.sum(axis=1, keepdims=True)

    # ------------------------------------------------------------------
    def batch(self, step: int) -> dict:
        """Batch for a global step — pure function of (seed, step, shard)."""
        cfg = self.cfg
        if self._mm is not None:
            return self._memmap_batch(step)
        out = np.empty((self.rows, cfg.seq_len + 1), dtype=np.int32)
        for r in range(self.rows):
            global_row = self.shard_id * self.rows + r
            rng = np.random.default_rng(
                (cfg.seed, step, global_row)
            )
            toks = list(rng.integers(0, cfg.vocab, size=cfg.markov_order))
            state_rows = self._probs
            for t in range(cfg.seq_len + 1 - cfg.markov_order):
                state = 0
                for o in range(cfg.markov_order):
                    state ^= int(self._proj[o, toks[-1 - o]])
                state %= state_rows.shape[0]
                nxt = rng.choice(cfg.vocab, p=state_rows[state])
                toks.append(int(nxt))
            out[r] = toks[: cfg.seq_len + 1]
        return {"tokens": out[:, :-1], "labels": out[:, 1:]}

    def _memmap_batch(self, step: int) -> dict:
        cfg = self.cfg
        n = self._mm.shape[0] - cfg.seq_len - 1
        rng = np.random.default_rng((cfg.seed, step))
        starts = rng.integers(0, n, size=cfg.global_batch)
        mine = starts[self.shard_id :: self.num_shards][: self.rows]
        toks = np.stack(
            [self._mm[s : s + cfg.seq_len + 1].astype(np.int32) for s in mine]
        )
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    # ------------------------------------------------------------------
    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


def synthetic_stream(vocab, seq_len, global_batch, **kw) -> TokenStream:
    return TokenStream(DataConfig(vocab, seq_len, global_batch, **kw))


def make_batches(stream: TokenStream, *, prefetch: int = 2) -> Iterator[dict]:
    """Double-buffered prefetch: batch r+1 is generated while r trains."""
    q: queue.Queue = queue.Queue(maxsize=prefetch)
    stop = threading.Event()

    def worker():
        for i, b in enumerate(stream):
            if stop.is_set():
                return
            q.put(b)

    t = threading.Thread(target=worker, daemon=True)
    t.start()
    try:
        while True:
            yield q.get()
    finally:
        stop.set()

from .pipeline import DataConfig, TokenStream, make_batches, synthetic_stream

__all__ = ["DataConfig", "TokenStream", "make_batches", "synthetic_stream"]

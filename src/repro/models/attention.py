"""Attention variants for the assigned architectures.

* GQA (grouped-query) with RoPE, optional QKV bias (Qwen), optional sliding
  window (StarCoder2).  Uses the Pallas flash kernel on TPU; a fused-mask
  jnp path otherwise (identical math, used for smoke tests and the CPU-host
  dry-run lowering).
* MLA (multi-head latent attention, DeepSeek-V2/V3): low-rank compressed KV
  with decoupled RoPE keys; decode uses the absorbed form against the
  compressed cache (this is exactly the paper-architecture's KV saving).

KV caches are fixed-capacity ring-free buffers: (B, Hkv, S_max, D) plus an
explicit length; ``decode`` writes at position ``len`` and masks by index.
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from .blocks import apply_rope, init_linear


# ======================================================================
# dense reference attention (masked), shared by GQA paths
# ======================================================================
_SDPA_CHUNK = 2048


def _sdpa_block(q, k, v, *, causal, window, q_offset, kv_len, scale):
    b, h, sq, d = q.shape
    skv = k.shape[2]
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s * scale
    q_idx = q_offset + jnp.arange(sq)[:, None]
    kv_idx = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= q_idx >= kv_idx
    if window and window > 0:
        mask &= (q_idx - kv_idx) < window
    if kv_len is not None:
        mask &= kv_idx < kv_len
    s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)  # fully-masked rows
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)).astype(q.dtype)


def _sdpa(q, k, v, *, causal, window, q_offset=0, kv_len=None):
    """q: (B,H,Sq,D) k,v: (B,H,Skv,D). fp32 softmax.

    Long queries are processed in python-unrolled q-chunks: the (Sq, Skv)
    score tensor at 32k prefill is tens of GB per device otherwise.  Chunks
    are unrolled (not lax.map) so cost_analysis stays trip-count-exact; on
    TPU the Pallas flash kernel replaces this path entirely.
    """
    b, h, sq, d = q.shape
    scale = 1.0 / math.sqrt(d)
    if sq <= _SDPA_CHUNK:
        return _sdpa_block(q, k, v, causal=causal, window=window,
                           q_offset=q_offset, kv_len=kv_len, scale=scale)
    outs = []
    for start in range(0, sq, _SDPA_CHUNK):
        stop = min(start + _SDPA_CHUNK, sq)
        outs.append(
            _sdpa_block(
                q[:, :, start:stop], k, v, causal=causal, window=window,
                q_offset=q_offset + start, kv_len=kv_len, scale=scale,
            )
        )
    return jnp.concatenate(outs, axis=2)


def _grouped(q, k, v, **kw):
    """Expand grouped KV heads and run SDPA (or flash kernel on TPU)."""
    hq, hkv = q.shape[1], k.shape[1]
    if jax.default_backend() == "tpu" and kw.get("kv_len") is None:
        from repro.kernels import ops as kops

        return kops.flash_attention(
            q, k, v, causal=kw.get("causal", True), window=kw.get("window", 0) or 0
        )
    if hq != hkv:
        group = hq // hkv
        k = jnp.repeat(k, group, axis=1)
        v = jnp.repeat(v, group, axis=1)
    return _sdpa(q, k, v, causal=kw.get("causal", True),
                 window=kw.get("window", 0), q_offset=kw.get("q_offset", 0),
                 kv_len=kw.get("kv_len"))


# ======================================================================
# GQA
# ======================================================================
def init_gqa(key, cfg, *, stack=(), dtype=jnp.float32):
    d, hq, hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": init_linear(ks[0], d, hq * dh, stack=stack, dtype=dtype),
        "wk": init_linear(ks[1], d, hkv * dh, stack=stack, dtype=dtype),
        "wv": init_linear(ks[2], d, hkv * dh, stack=stack, dtype=dtype),
        "wo": init_linear(ks[3], hq * dh, d, stack=stack, dtype=dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((*stack, hq * dh), dtype)
        p["bk"] = jnp.zeros((*stack, hkv * dh), dtype)
        p["bv"] = jnp.zeros((*stack, hkv * dh), dtype)
    return p


def gqa_forward(p, x, cfg, *, positions=None, window=None):
    """Training / prefill self-attention. x: (B, S, D)."""
    b, s, d = x.shape
    hq, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    if positions is None:
        positions = jnp.arange(s)[None, :]
    q = (x @ p["wq"] + p.get("bq", 0.0)).reshape(b, s, hq, dh).transpose(0, 2, 1, 3)
    k = (x @ p["wk"] + p.get("bk", 0.0)).reshape(b, s, hkv, dh).transpose(0, 2, 1, 3)
    v = (x @ p["wv"] + p.get("bv", 0.0)).reshape(b, s, hkv, dh).transpose(0, 2, 1, 3)
    q = apply_rope(q, positions[:, None, :], theta=cfg.rope_theta)
    k = apply_rope(k, positions[:, None, :], theta=cfg.rope_theta)
    w = window if window is not None else cfg.window
    o = _grouped(q, k, v, causal=True, window=w)
    o = o.transpose(0, 2, 1, 3).reshape(b, s, hq * dh)
    return o @ p["wo"]


def gqa_init_cache(cfg, batch, max_len, dtype=jnp.bfloat16):
    hkv, dh = cfg.n_kv_heads, cfg.head_dim
    cap = min(max_len, cfg.window) if cfg.window else max_len
    return {
        "k": jnp.zeros((batch, hkv, cap, dh), dtype),
        "v": jnp.zeros((batch, hkv, cap, dh), dtype),
    }


def gqa_decode(p, x, cache, length, cfg):
    """One-token decode. x: (B, 1, D); length: current cache fill (scalar).

    With a sliding window the cache is a rotating buffer of size ``window``
    (StarCoder2's long_500k path: O(window) memory at 500k context).
    """
    b, _, d = x.shape
    hq, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    pos = jnp.full((b, 1), length, dtype=jnp.int32)
    q = (x @ p["wq"] + p.get("bq", 0.0)).reshape(b, 1, hq, dh).transpose(0, 2, 1, 3)
    k = (x @ p["wk"] + p.get("bk", 0.0)).reshape(b, 1, hkv, dh).transpose(0, 2, 1, 3)
    v = (x @ p["wv"] + p.get("bv", 0.0)).reshape(b, 1, hkv, dh).transpose(0, 2, 1, 3)
    q = apply_rope(q, pos[:, None, :], theta=cfg.rope_theta)
    k = apply_rope(k, pos[:, None, :], theta=cfg.rope_theta)

    cap = cache["k"].shape[2]
    slot = jnp.mod(length, cap) if cfg.window else jnp.minimum(length, cap - 1)
    ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                      (0, 0, slot, 0))
    cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                      (0, 0, slot, 0))
    kv_len = jnp.minimum(length + 1, cap)
    # grouped-head decode WITHOUT repeating the KV cache (a x(group) copy of
    # a 32k cache is GBs of pure waste): q reshaped to (B, Hkv, G, D) and
    # contracted directly against the shared KV heads.
    g = hq // hkv
    qg = q[:, :, 0].reshape(b, hkv, g, dh).astype(jnp.float32)
    s = jnp.einsum("bhgd,bhsd->bhgs", qg, ck.astype(jnp.float32))
    s = s / math.sqrt(dh)
    kv_idx = jnp.arange(cap)[None, None, None, :]
    s = jnp.where(kv_idx < kv_len, s, -jnp.inf)
    prob = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgs,bhsd->bhgd", prob, cv.astype(jnp.float32))
    o = o.astype(x.dtype).reshape(b, 1, hq * dh)
    return o @ p["wo"], {"k": ck, "v": cv}


# ======================================================================
# MLA (DeepSeek-V3)
# ======================================================================
def init_mla(key, cfg, *, stack=(), dtype=jnp.float32):
    d, h = cfg.d_model, cfg.n_heads
    rq, rkv = cfg.mla_q_rank, cfg.mla_kv_rank
    dn, dr, dv = cfg.mla_nope_dim, cfg.mla_rope_dim, cfg.mla_v_dim
    ks = jax.random.split(key, 7)
    return {
        "wq_a": init_linear(ks[0], d, rq, stack=stack, dtype=dtype),
        "wq_b": init_linear(ks[1], rq, h * (dn + dr), stack=stack, dtype=dtype),
        "wkv_a": init_linear(ks[2], d, rkv + dr, stack=stack, dtype=dtype),
        "wk_b": init_linear(ks[3], rkv, h * dn, stack=stack, dtype=dtype),
        "wv_b": init_linear(ks[4], rkv, h * dv, stack=stack, dtype=dtype),
        "wo": init_linear(ks[5], h * dv, d, stack=stack, dtype=dtype),
    }


def mla_forward(p, x, cfg, *, positions=None):
    """Training/prefill MLA (decompressed form)."""
    b, s, d = x.shape
    h = cfg.n_heads
    dn, dr, dv = cfg.mla_nope_dim, cfg.mla_rope_dim, cfg.mla_v_dim
    rkv = cfg.mla_kv_rank
    if positions is None:
        positions = jnp.arange(s)[None, :]

    q = (x @ p["wq_a"]) @ p["wq_b"]
    q = q.reshape(b, s, h, dn + dr).transpose(0, 2, 1, 3)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions[:, None, :], theta=cfg.rope_theta)

    kv = x @ p["wkv_a"]                       # (B, S, rkv + dr)
    c_kv, k_rope = kv[..., :rkv], kv[..., rkv:]
    k_rope = apply_rope(
        k_rope[:, None], positions[:, None, :], theta=cfg.rope_theta
    )                                          # (B, 1, S, dr) shared head
    k_nope = (c_kv @ p["wk_b"]).reshape(b, s, h, dn).transpose(0, 2, 1, 3)
    v = (c_kv @ p["wv_b"]).reshape(b, s, h, dv).transpose(0, 2, 1, 3)

    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    k_full = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (b, h, s, dr))], axis=-1
    )
    o = _sdpa(q_full, k_full, v, causal=True, window=0)
    o = o.transpose(0, 2, 1, 3).reshape(b, s, h * dv)
    return o @ p["wo"]


def mla_init_cache(cfg, batch, max_len, dtype=jnp.bfloat16):
    """Compressed cache: latent c_kv + shared rope key — 576 dims/token."""
    return {
        "c_kv": jnp.zeros((batch, max_len, cfg.mla_kv_rank), dtype),
        "k_rope": jnp.zeros((batch, max_len, cfg.mla_rope_dim), dtype),
    }


def mla_decode(p, x, cache, length, cfg):
    """Absorbed-form decode against the compressed cache."""
    b, _, d = x.shape
    h = cfg.n_heads
    dn, dr, dv = cfg.mla_nope_dim, cfg.mla_rope_dim, cfg.mla_v_dim
    rkv = cfg.mla_kv_rank
    pos = jnp.full((b, 1), length, dtype=jnp.int32)

    q = (x @ p["wq_a"]) @ p["wq_b"]
    q = q.reshape(b, 1, h, dn + dr).transpose(0, 2, 1, 3)      # (B,h,1,dn+dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, pos[:, None, :], theta=cfg.rope_theta)

    kv = x @ p["wkv_a"]
    c_new, kr_new = kv[..., :rkv], kv[..., rkv:]
    kr_new = apply_rope(kr_new[:, None], pos[:, None, :], theta=cfg.rope_theta)[
        :, 0
    ]
    c_kv = jax.lax.dynamic_update_slice(
        cache["c_kv"], c_new.astype(cache["c_kv"].dtype), (0, length, 0)
    )
    k_rope = jax.lax.dynamic_update_slice(
        cache["k_rope"], kr_new.astype(cache["k_rope"].dtype), (0, length, 0)
    )

    # absorbed scores: q_nope^T (W_kb c) = (q_nope W_kb^T) c
    wk = p["wk_b"].reshape(rkv, h, dn)
    q_lat = jnp.einsum("bhod,rhd->bhor", q_nope.astype(jnp.float32),
                       wk.astype(jnp.float32))               # (B,h,1,rkv)
    s_lat = jnp.einsum("bhor,bsr->bhos", q_lat,
                       c_kv.astype(jnp.float32))             # (B,h,1,S)
    s_rope = jnp.einsum("bhod,bsd->bhos", q_rope.astype(jnp.float32),
                        k_rope.astype(jnp.float32))
    scale = 1.0 / math.sqrt(dn + dr)
    s_all = (s_lat + s_rope) * scale
    kv_idx = jnp.arange(c_kv.shape[1])[None, None, None, :]
    s_all = jnp.where(kv_idx <= length, s_all, -jnp.inf)
    prob = jax.nn.softmax(s_all, axis=-1)                    # (B,h,1,S)
    ctx_lat = jnp.einsum("bhos,bsr->bhor", prob, c_kv.astype(jnp.float32))
    wv = p["wv_b"].reshape(rkv, h, dv)
    o = jnp.einsum("bhor,rhd->bhod", ctx_lat, wv.astype(jnp.float32))
    o = o.astype(x.dtype).transpose(0, 2, 1, 3).reshape(b, 1, h * dv)
    return o @ p["wo"], {"c_kv": c_kv, "k_rope": k_rope}

"""Composable decoder-only model over heterogeneous scanned layer stacks.

Every architecture in configs/ lowers through this module:

  forward       — training / prefill over full sequences (logits)
  loss_fn       — mean token CE + MoE aux loss
  init_params   — concrete init;  init_abstract — eval_shape (dry-run)
  init_cache    — decode caches/states per layer
  decode_step   — one-token decode updating the cache

Layers are stacked per (repeat, group) "stack": parameters carry a leading
``repeat`` axis and the group is executed under ``jax.lax.scan`` (optionally
rematerialized), so HLO size and SPMD-partitioner time stay O(distinct layer
kinds) even for 61-layer 671B-parameter configs.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, LayerSpec
from repro.launch.sharding import logical_shard

from . import attention as attn
from . import mamba as mam
from . import moe as moe_mod
from . import xlstm as xl
from .blocks import (
    cross_entropy,
    gelu_ffn,
    init_gelu_ffn,
    init_linear,
    init_swiglu,
    layer_norm,
    rms_norm,
    swiglu_ffn,
    truncated_normal,
)


# ======================================================================
# parameter init
# ======================================================================
def _init_layer(key, spec: LayerSpec, cfg: ArchConfig, stack, dtype):
    ks = jax.random.split(key, 4)
    p: dict = {}
    if spec.mixer == "gqa":
        p["mixer"] = attn.init_gqa(ks[0], cfg, stack=stack, dtype=dtype)
    elif spec.mixer == "mla":
        p["mixer"] = attn.init_mla(ks[0], cfg, stack=stack, dtype=dtype)
    elif spec.mixer == "mamba":
        p["mixer"] = mam.init_mamba(ks[0], cfg, stack=stack, dtype=dtype)
    elif spec.mixer == "mlstm":
        p["mixer"] = xl.init_mlstm(ks[0], cfg, stack=stack, dtype=dtype)
    elif spec.mixer == "slstm":
        p["mixer"] = xl.init_slstm(ks[0], cfg, stack=stack, dtype=dtype)
    else:
        raise ValueError(spec.mixer)

    if spec.ffn == "swiglu":
        p["ffn"] = init_swiglu(ks[1], cfg.d_model, cfg.d_ff, stack=stack, dtype=dtype)
    elif spec.ffn == "gelu":
        p["ffn"] = init_gelu_ffn(ks[1], cfg.d_model, cfg.d_ff, stack=stack,
                                 bias=True, dtype=dtype)
    elif spec.ffn == "moe":
        p["ffn"] = moe_mod.init_moe(ks[1], cfg, stack=stack, dtype=dtype)
    elif spec.ffn != "none":
        raise ValueError(spec.ffn)

    if cfg.norm == "rms":
        p["norm1"] = jnp.ones((*stack, cfg.d_model), dtype)
        if spec.ffn != "none":
            p["norm2"] = jnp.ones((*stack, cfg.d_model), dtype)
    else:
        p["norm1"] = jnp.ones((*stack, cfg.d_model), dtype)
        p["norm1_b"] = jnp.zeros((*stack, cfg.d_model), dtype)
        if spec.ffn != "none":
            p["norm2"] = jnp.ones((*stack, cfg.d_model), dtype)
            p["norm2_b"] = jnp.zeros((*stack, cfg.d_model), dtype)
    return p


def init_params(cfg: ArchConfig, key: jax.Array, dtype=jnp.float32):
    ks = jax.random.split(key, 4 + len(cfg.stacks))
    params: dict = {
        "embed": truncated_normal(ks[0], (cfg.vocab, cfg.d_model), 0.02, dtype),
        "final_norm": jnp.ones((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = init_linear(ks[1], cfg.d_model, cfg.vocab, dtype=dtype)
    if cfg.frontend:
        params["frontend_proj"] = init_linear(ks[2], cfg.d_model, cfg.d_model,
                                              dtype=dtype)
    for si, (repeat, specs) in enumerate(cfg.stacks):
        group = {}
        gks = jax.random.split(ks[3 + si], len(specs))
        for li, spec in enumerate(specs):
            group[f"l{li}"] = _init_layer(gks[li], spec, cfg, (repeat,), dtype)
        params[f"stack{si}"] = group
    return params


def init_abstract(cfg: ArchConfig):
    """Shape-only params (no allocation) for the dry-run."""
    return jax.eval_shape(
        lambda k: init_params(cfg, k, dtype=cfg.activation_dtype),
        jax.ShapeDtypeStruct((2,), jnp.uint32),
    )


# ======================================================================
# forward (training / prefill)
# ======================================================================
def _norm(p, name, x, cfg):
    if cfg.norm == "rms":
        return rms_norm(p[name], x)
    return layer_norm(p[name], p[name + "_b"], x)


def _apply_layer(p, spec: LayerSpec, x, cfg, positions):
    h = _norm(p, "norm1", x, cfg)
    if spec.mixer == "gqa":
        h = attn.gqa_forward(p["mixer"], h, cfg, positions=positions)
    elif spec.mixer == "mla":
        h = attn.mla_forward(p["mixer"], h, cfg, positions=positions)
    elif spec.mixer == "mamba":
        h = mam.mamba_forward(p["mixer"], h, cfg)
    elif spec.mixer == "mlstm":
        h = xl.mlstm_forward(p["mixer"], h, cfg)
    elif spec.mixer == "slstm":
        h = xl.slstm_forward(p["mixer"], h, cfg)
    x = x + h
    aux = jnp.zeros((), jnp.float32)
    if spec.ffn != "none":
        h = _norm(p, "norm2", x, cfg)
        if spec.ffn == "swiglu":
            h = swiglu_ffn(p["ffn"], h)
        elif spec.ffn == "gelu":
            h = gelu_ffn(p["ffn"], h)
        else:
            h, aux = moe_mod.moe_forward(p["ffn"], h, cfg)
        x = x + h
    x = logical_shard(x, "act")
    return x, aux


def _run_stacks(params, x, cfg, positions):
    """Scan every (repeat, group) stack over the sequence of layers."""
    aux_total = jnp.zeros((), jnp.float32)
    for si, (repeat, specs) in enumerate(cfg.stacks):
        gp = params[f"stack{si}"]

        def group_fn(x, layer_params, specs=specs):
            aux = jnp.zeros((), jnp.float32)
            for li, spec in enumerate(specs):
                x, a = _apply_layer(layer_params[f"l{li}"], spec, x, cfg, positions)
                aux = aux + a
            return x, aux

        fn = jax.checkpoint(group_fn) if cfg.remat == "full" else group_fn
        if repeat == 1:
            one = jax.tree.map(lambda t: t[0], gp)
            x, aux = fn(x, one)
            aux_total = aux_total + aux
        elif cfg.layer_unroll:
            for r in range(repeat):
                one = jax.tree.map(lambda t, r=r: t[r], gp)
                x, aux = fn(x, one)
                aux_total = aux_total + aux
        else:
            def scan_body(carry, layer_params):
                y, aux = fn(carry, layer_params)
                return y, aux

            x, auxs = jax.lax.scan(scan_body, x, gp)
            aux_total = aux_total + auxs.sum()
    return x, aux_total


def forward(params, batch: dict, cfg: ArchConfig):
    """batch: tokens (B,S) [+ frontend_embeds (B,N,D)] -> logits (B,S,V)."""
    tokens = batch["tokens"]
    x = params["embed"][tokens].astype(cfg.activation_dtype)
    n_front = 0
    if cfg.frontend and "frontend_embeds" in batch:
        fe = batch["frontend_embeds"].astype(cfg.activation_dtype)
        fe = fe @ params["frontend_proj"]
        x = jnp.concatenate([fe, x], axis=1)
        n_front = fe.shape[1]
    x = logical_shard(x, "act")
    positions = jnp.arange(x.shape[1])[None, :]
    x, aux = _run_stacks(params, x, cfg, positions)
    x = _final_norm(params, x, cfg)
    if n_front:
        x = x[:, n_front:]
    logits = x @ (
        params["embed"].T.astype(cfg.activation_dtype)
        if cfg.tie_embeddings
        else params["lm_head"].astype(cfg.activation_dtype)
    )
    return logical_shard(logits, "logits"), aux


def _final_norm(params, x, cfg):
    if cfg.norm == "rms":
        return rms_norm(params["final_norm"], x)
    return rms_norm(params["final_norm"], x)  # final norm is RMS everywhere


def loss_fn(params, batch: dict, cfg: ArchConfig):
    logits, aux = forward(params, batch, cfg)
    loss = cross_entropy(logits, batch["labels"])
    return loss + cfg.aux_loss_weight * aux


# ======================================================================
# decode caches / states
# ======================================================================
def _init_layer_cache(spec: LayerSpec, cfg, batch, max_len, dtype):
    if spec.mixer == "gqa":
        return attn.gqa_init_cache(cfg, batch, max_len, dtype)
    if spec.mixer == "mla":
        return attn.mla_init_cache(cfg, batch, max_len, dtype)
    if spec.mixer == "mamba":
        return mam.mamba_init_state(cfg, batch)
    if spec.mixer == "mlstm":
        return xl.mlstm_init_state(cfg, batch)
    if spec.mixer == "slstm":
        return xl.slstm_init_state(cfg, batch)
    raise ValueError(spec.mixer)


def init_cache(cfg: ArchConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    cache = {}
    for si, (repeat, specs) in enumerate(cfg.stacks):
        group = {}
        for li, spec in enumerate(specs):
            one = _init_layer_cache(spec, cfg, batch, max_len, dtype)
            group[f"l{li}"] = jax.tree.map(
                lambda t: jnp.broadcast_to(t[None], (repeat, *t.shape)).copy(), one
            )
        cache[f"stack{si}"] = group
    return cache


def init_cache_abstract(cfg: ArchConfig, batch: int, max_len: int):
    return jax.eval_shape(lambda: init_cache(cfg, batch, max_len))


def _decode_layer(p, spec: LayerSpec, x, cache, length, cfg):
    h = _norm(p, "norm1", x, cfg)
    if spec.mixer == "gqa":
        h, cache = attn.gqa_decode(p["mixer"], h, cache, length, cfg)
    elif spec.mixer == "mla":
        h, cache = attn.mla_decode(p["mixer"], h, cache, length, cfg)
    elif spec.mixer == "mamba":
        h, cache = mam.mamba_decode(p["mixer"], h, cache, cfg)
    elif spec.mixer == "mlstm":
        h, cache = xl.mlstm_decode(p["mixer"], h, cache, cfg)
    elif spec.mixer == "slstm":
        h, cache = xl.slstm_decode(p["mixer"], h, cache, cfg)
    x = x + h
    if spec.ffn != "none":
        h = _norm(p, "norm2", x, cfg)
        if spec.ffn == "swiglu":
            h = swiglu_ffn(p["ffn"], h)
        elif spec.ffn == "gelu":
            h = gelu_ffn(p["ffn"], h)
        else:
            h, _ = moe_mod.moe_forward(p["ffn"], h, cfg)
        x = x + h
    return x, cache


def decode_step(params, tokens, cache, length, cfg: ArchConfig):
    """One-token decode.  tokens: (B, 1) int32; length: scalar int32.

    Returns (logits (B, 1, V), new_cache).
    """
    x = params["embed"][tokens].astype(cfg.activation_dtype)
    x = logical_shard(x, "act")
    for si, (repeat, specs) in enumerate(cfg.stacks):
        gp = params[f"stack{si}"]
        gc = cache[f"stack{si}"]

        def group_fn(x, pc, specs=specs):
            layer_params, layer_cache = pc
            new_cache = {}
            for li, spec in enumerate(specs):
                x, c = _decode_layer(
                    layer_params[f"l{li}"], spec, x, layer_cache[f"l{li}"],
                    length, cfg,
                )
                new_cache[f"l{li}"] = c
            return x, new_cache

        if repeat == 1:
            one_p = jax.tree.map(lambda t: t[0], gp)
            one_c = jax.tree.map(lambda t: t[0], gc)
            x, nc = group_fn(x, (one_p, one_c))
            cache[f"stack{si}"] = jax.tree.map(lambda t: t[None], nc)
        elif cfg.layer_unroll:
            ncs = []
            for r in range(repeat):
                one_p = jax.tree.map(lambda t, r=r: t[r], gp)
                one_c = jax.tree.map(lambda t, r=r: t[r], gc)
                x, nc = group_fn(x, (one_p, one_c))
                ncs.append(nc)
            cache[f"stack{si}"] = jax.tree.map(
                lambda *ts: jnp.stack(ts), *ncs
            )
        else:
            x, ncs = jax.lax.scan(group_fn, x, (gp, gc))
            cache[f"stack{si}"] = ncs
    x = _final_norm(params, x, cfg)
    logits = x @ (
        params["embed"].T.astype(cfg.activation_dtype)
        if cfg.tie_embeddings
        else params["lm_head"].astype(cfg.activation_dtype)
    )
    return logits, cache

"""Mixture-of-Experts layer (DeepSeekMoE / DeepSeek-V3 / Jamba style).

Fine-grained experts with optional shared experts and top-k routing.  Three
dispatch modes, selectable per config (the progression is a §Perf hillclimb
— see EXPERIMENTS.md):

* ``onehot``    — GShard-classic dense dispatch/combine einsums with a
  (tokens, E, C) one-hot tensor.  Fully SPMD-friendly, but the dispatch
  einsums burn tokens*E*C*D MACs of non-useful compute.
* ``gather``    — capacity dispatch via gather/scatter.  Near-zero FLOP
  overhead single-device, but the computed-index scatter defeats GSPMD
  sharding propagation: under jit the expert compute REPLICATES per chip
  (measured 310x FLOP blowup on deepseek-v3 — see EXPERIMENTS.md §Perf).
* ``shard_map`` — explicit expert parallelism (default on a mesh): tokens
  stay data-sharded and activations are replicated over the model axis, so
  each (data, model) shard locally dispatches its tokens to ITS E/model
  expert slice, runs them, and a psum over "model" combines the partial
  outputs.  No (T,E,C) dense einsum, no replicated compute; the only
  collective is the same-size psum TP already pays for an FFN.

All modes drop overflow tokens beyond per-expert capacity (standard
capacity-factor semantics) and add the switch-style load-balancing aux loss.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .blocks import init_linear, init_swiglu, swiglu_ffn


def init_moe(key, cfg, *, stack=(), dtype=jnp.float32):
    d, e, fe = cfg.d_model, cfg.moe_experts, cfg.moe_d_ff
    ks = jax.random.split(key, 3)
    p = {
        "router": init_linear(ks[0], d, e, stack=stack, dtype=dtype),
        "experts": init_swiglu(ks[1], d, fe, stack=(*stack, e), dtype=dtype),
    }
    if cfg.moe_shared > 0:
        p["shared"] = init_swiglu(ks[2], d, fe * cfg.moe_shared, stack=stack,
                                  dtype=dtype)
    return p


def _routing(p, x, cfg):
    """Common router: top-k gates + aux loss. x: (T, D)."""
    t, d = x.shape
    e, k = cfg.moe_experts, cfg.moe_top_k
    logits = (x @ p["router"]).astype(jnp.float32)            # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, k)                      # (T, k)
    gates = gates / jnp.clip(gates.sum(-1, keepdims=True), 1e-9)
    # switch aux loss: E * sum_e (frac_tokens_e * mean_prob_e)
    onehot_top1 = jax.nn.one_hot(idx[:, 0], e, dtype=jnp.float32)
    aux = e * jnp.mean(jnp.mean(onehot_top1, axis=0) * jnp.mean(probs, axis=0))
    return gates.astype(x.dtype), idx, aux


def _capacity(cfg, tokens: int) -> int:
    c = int(tokens * cfg.moe_top_k * cfg.moe_capacity / cfg.moe_experts)
    return max(c, 4)


# ----------------------------------------------------------------------
def _dispatch_onehot(p, x, gates, idx, cfg):
    """GShard dense dispatch: mask (T, E, C) einsums."""
    t, d = x.shape
    e, k = cfg.moe_experts, cfg.moe_top_k
    c = _capacity(cfg, t)
    # position of each (token, choice) within its expert's capacity
    oh_e = jax.nn.one_hot(idx, e, dtype=jnp.int32)            # (T, k, E)
    flat = oh_e.reshape(t * k, e)
    pos = jnp.cumsum(flat, axis=0) - flat                     # (T*k, E)
    pos = (pos * flat).sum(-1).reshape(t, k)                  # (T, k)
    # out-of-capacity positions one_hot to all-zeros => dropped
    oh_c = jax.nn.one_hot(pos, c, dtype=x.dtype)              # (T, k, C)
    oh_e = oh_e.astype(x.dtype)
    dispatch = jnp.einsum("tke,tkc->tec", oh_e, oh_c)         # (T, E, C)
    expert_in = jnp.einsum("tec,td->ecd", dispatch, x)        # (E, C, D)
    expert_out = _run_experts(p, expert_in, cfg)              # (E, C, D)
    combine = jnp.einsum("tke,tkc,tk->tec", oh_e, oh_c, gates)
    return jnp.einsum("tec,ecd->td", combine, expert_out)


def _dispatch_gather(p, x, gates, idx, cfg):
    """Gather/scatter capacity dispatch (no dense (T,E,C) einsum FLOPs)."""
    t, d = x.shape
    e, k = cfg.moe_experts, cfg.moe_top_k
    c = _capacity(cfg, t)
    flat_idx = idx.reshape(-1)                                 # (T*k,)
    oh = jax.nn.one_hot(flat_idx, e, dtype=jnp.int32)          # (T*k, E)
    pos = (jnp.cumsum(oh, axis=0) - oh)                        # pos within expert
    pos = jnp.take_along_axis(pos, flat_idx[:, None], axis=1)[:, 0]
    keep = pos < c
    slot = jnp.where(keep, flat_idx * c + pos, e * c)          # overflow slot
    # scatter tokens into (E*C+1, D) buffer (last row = dropped)
    src = jnp.repeat(x, k, axis=0)                             # (T*k, D)
    buf = jnp.zeros((e * c + 1, d), x.dtype).at[slot].set(src)
    expert_in = buf[: e * c].reshape(e, c, d)
    expert_out = _run_experts(p, expert_in, cfg).reshape(e * c, d)
    expert_out = jnp.concatenate([expert_out, jnp.zeros((1, d), x.dtype)], 0)
    picked = expert_out[slot] * (gates.reshape(-1)[:, None] * keep[:, None])
    return picked.reshape(t, k, d).sum(axis=1)


def _run_experts(p, expert_in, cfg):
    """Per-expert SwiGLU over (E, C, D) with stacked weights (E, D, F)."""
    h = jax.nn.silu(
        jnp.einsum("ecd,edf->ecf", expert_in, p["experts"]["w_gate"])
    ) * jnp.einsum("ecd,edf->ecf", expert_in, p["experts"]["w_up"])
    return jnp.einsum("ecf,efd->ecd", h, p["experts"]["w_down"])


# ----------------------------------------------------------------------
# explicit expert parallelism (shard_map)
# ----------------------------------------------------------------------
def _local_moe(x_loc, router, w_gate, w_up, w_down, *, cfg, batch_axes,
               expert_axes=("model",), fsdp_gather=True):
    """Per-shard body: x_loc (T_loc, D) token shard (replicated over the
    model axis); w_* this rank's expert slice.

    Training: expert weights enter D-sharded over "data" (FSDP/ZeRO-3) and
    are all-gathered HERE, inside the shard_map: autodiff then transposes
    the gather into a reduce-scatter, so the weight GRADIENT leaves
    data-sharded too.  (Passing full-D weights through the boundary makes
    the cotangent data-replicated, which forced GSPMD into 25-GB full-D
    fp32 optimizer temps — EXPERIMENTS.md §Perf iteration 2.)

    Inference EP (``expert_axes=("model","data")``): whole experts per chip,
    tokens replicated, no per-step weight gathers; combine psums over both
    axes.
    """
    t, d = x_loc.shape
    e, k = cfg.moe_experts, cfg.moe_top_k
    if fsdp_gather:
        w_gate = jax.lax.all_gather(w_gate, "data", axis=1, tiled=True)
        w_up = jax.lax.all_gather(w_up, "data", axis=1, tiled=True)
        w_down = jax.lax.all_gather(w_down, "data", axis=2, tiled=True)
    e_loc = w_gate.shape[0]
    c = _capacity(cfg, t)

    gates, idx, aux = _routing({"router": router}, x_loc, cfg)
    rank = 0
    for ax in expert_axes:  # linearized rank over the expert axes
        rank = rank * jax.lax.psum(1, ax) + jax.lax.axis_index(ax)
    lo = rank * e_loc
    rel = idx - lo                                            # (T, k)
    valid = (rel >= 0) & (rel < e_loc)

    # position within each LOCAL expert (one_hot of clamped rel; invalid
    # choices hash to a trash row e_loc)
    safe_rel = jnp.where(valid, rel, e_loc)
    oh = jax.nn.one_hot(safe_rel.reshape(-1), e_loc + 1, dtype=jnp.int32)
    pos = jnp.cumsum(oh, axis=0) - oh                         # (T*k, E_loc+1)
    pos = jnp.take_along_axis(pos, safe_rel.reshape(-1)[:, None], axis=1)[:, 0]
    keep = (valid.reshape(-1) & (pos < c)).reshape(t, k)
    slot = jnp.where(
        keep, safe_rel * c + pos.reshape(t, k), e_loc * c
    )                                                          # (T, k)

    # dispatch per choice (k scatters of (T, D)): NEVER materialize the
    # (T*k, D) repeat — at k=8, D=7168 that transient alone is ~8 GB/device
    # and triples under autodiff (EXPERIMENTS.md §Perf iteration 2).
    buf = jnp.zeros((e_loc * c + 1, d), x_loc.dtype)
    for j in range(k):
        buf = buf.at[slot[:, j]].set(x_loc)
    expert_in = buf[: e_loc * c].reshape(e_loc, c, d)
    h = jax.nn.silu(
        jnp.einsum("ecd,edf->ecf", expert_in, w_gate)
    ) * jnp.einsum("ecd,edf->ecf", expert_in, w_up)
    expert_out = jnp.einsum("ecf,efd->ecd", h, w_down).reshape(e_loc * c, d)
    expert_out = jnp.concatenate(
        [expert_out, jnp.zeros((1, d), x_loc.dtype)], axis=0
    )
    y_partial = jnp.zeros_like(x_loc)
    for j in range(k):
        w = (gates[:, j] * keep[:, j]).astype(x_loc.dtype)[:, None]
        y_partial = y_partial + expert_out[slot[:, j]] * w
    y = jax.lax.psum(y_partial, expert_axes)
    if batch_axes:
        aux = jax.lax.pmean(aux, batch_axes)
    return y, aux


def _dispatch_shard_map(p, x, cfg, mesh):
    """Expert-parallel MoE over the ambient mesh. x: (T, D) global."""
    from jax.experimental.shard_map import shard_map

    from repro.launch.sharding import batch_axes as _batch_axes

    b_ax = _batch_axes(mesh)
    body = functools.partial(_local_moe, cfg=cfg, batch_axes=b_ax)
    fn = shard_map(
        body,
        mesh=mesh,
        in_specs=(
            P(b_ax, None),                 # tokens: data-sharded
            P(None, None),                 # router: replicated
            P("model", "data", None),      # expert slices, D FSDP-sharded
            P("model", "data", None),
            P("model", None, "data"),      # w_down: (E, F, D)
        ),
        out_specs=(P(b_ax, None), P()),
        check_rep=False,
    )
    return fn(
        x, p["router"], p["experts"]["w_gate"], p["experts"]["w_up"],
        p["experts"]["w_down"],
    )


def _dispatch_inference_ep(p, x, cfg, mesh):
    """Serving-time expert placement (weight-stationary, no per-step weight
    movement — §Perf iteration 6).

    * E divisible by model*data: whole experts per chip over BOTH axes;
      the (small) decode token batch is replicated and one psum over both
      axes combines.
    * otherwise: experts over the model axis only (whole-D slices, no FSDP
      gathers); tokens stay data-sharded when divisible, else replicated.
    """
    from jax.experimental.shard_map import shard_map

    from repro.launch.sharding import batch_axes as _batch_axes

    n_model = mesh.shape["model"]
    n_data = 1
    for ax in _batch_axes(mesh):
        n_data *= mesh.shape[ax]

    if cfg.moe_experts % (n_model * n_data) == 0:
        ep_axes: tuple = ("model", "data")
        tok_spec = P(None, None)
        b_ax: tuple = ()
    else:
        ep_axes = ("model",)
        if x.shape[0] % n_data == 0:
            tok_spec = P(_batch_axes(mesh), None)
            b_ax = _batch_axes(mesh)
        else:
            tok_spec = P(None, None)
            b_ax = ()

    body = functools.partial(
        _local_moe, cfg=cfg, batch_axes=b_ax, expert_axes=ep_axes,
        fsdp_gather=False,
    )
    fn = shard_map(
        body,
        mesh=mesh,
        in_specs=(
            tok_spec,
            P(None, None),
            P(ep_axes, None, None),        # whole experts per rank
            P(ep_axes, None, None),
            P(ep_axes, None, None),
        ),
        out_specs=(tok_spec, P()),
        check_rep=False,
    )
    return fn(
        x, p["router"], p["experts"]["w_gate"], p["experts"]["w_up"],
        p["experts"]["w_down"],
    )


# ----------------------------------------------------------------------
def moe_forward(p, x, cfg):
    """x: (B, S, D) -> (y, aux_loss)."""
    from repro.launch.sharding import current_mesh

    b, s, d = x.shape
    flat = x.reshape(b * s, d)
    mesh = current_mesh()
    dispatch = cfg.moe_dispatch
    if (
        cfg.inference_ep
        and mesh is not None
        and cfg.moe_experts % mesh.shape["model"] == 0
    ):
        dispatch = "inference_ep"
    elif dispatch == "shard_map":
        if mesh is None or cfg.moe_experts % mesh.shape["model"] != 0:
            dispatch = "gather"  # no mesh (smoke) or indivisible experts
        else:
            from repro.launch.sharding import batch_axes as _ba

            n_data = 1
            for ax in _ba(mesh):
                n_data *= mesh.shape[ax]
            if flat.shape[0] % n_data != 0:
                dispatch = "gather"  # e.g. batch-1 long-context decode
    if dispatch == "inference_ep":
        y, aux = _dispatch_inference_ep(p, flat, cfg, mesh)
    elif dispatch == "shard_map":
        y, aux = _dispatch_shard_map(p, flat, cfg, mesh)
    elif dispatch == "onehot":
        gates, idx, aux = _routing(p, flat, cfg)
        y = _dispatch_onehot(p, flat, gates, idx, cfg)
    else:
        gates, idx, aux = _routing(p, flat, cfg)
        y = _dispatch_gather(p, flat, gates, idx, cfg)
    if "shared" in p:
        y = y + swiglu_ffn(p["shared"], flat)
    return y.reshape(b, s, d), aux

"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory, parallelizable)
and sLSTM (scalar memory, sequential scan).

mLSTM uses the chunkwise-parallel formulation: exponential input gates with
a running log-normalizer for numerical stability; the (d_head x d_head)
matrix memory C and normalizer n are the recurrent state, giving O(1) decode
state — xlstm-350m therefore runs the long_500k shape.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .blocks import init_linear


# ======================================================================
# mLSTM
# ======================================================================
def init_mlstm(key, cfg, *, stack=(), dtype=jnp.float32):
    d, h = cfg.d_model, cfg.n_heads
    dh = cfg.xlstm_d_inner // h
    ks = jax.random.split(key, 6)
    return {
        "w_qkv": init_linear(ks[0], d, 3 * cfg.xlstm_d_inner, stack=stack,
                             dtype=dtype),
        "w_if": init_linear(ks[1], d, 2 * h, stack=stack, dtype=dtype),
        "b_if": jnp.tile(jnp.asarray([0.0, 3.0], dtype), (*stack, h)),
        "w_gate": init_linear(ks[2], d, cfg.xlstm_d_inner, stack=stack, dtype=dtype),
        "norm": jnp.ones((*stack, cfg.xlstm_d_inner), dtype),
        "w_out": init_linear(ks[3], cfg.xlstm_d_inner, d, stack=stack, dtype=dtype),
    }


def _mlstm_scan(q, k, v, i_gate, f_gate):
    """Sequential (scan) mLSTM recurrence in log-stabilized form.

    q,k,v: (B, H, L, dh); i_gate,f_gate: (B, H, L) pre-activation.
    Returns y: (B, H, L, dh).
    """
    b, h, l, dh = q.shape
    logf = jax.nn.log_sigmoid(f_gate)                        # (B,H,L)

    def step(carry, t_in):
        c, n, m = carry                                      # (B,H,dh,dh) (B,H,dh) (B,H)
        q_t, k_t, v_t, i_t, lf_t = t_in
        m_new = jnp.maximum(lf_t + m, i_t)
        f_eff = jnp.exp(lf_t + m - m_new)                    # (B,H)
        i_eff = jnp.exp(i_t - m_new)
        c = f_eff[..., None, None] * c + i_eff[..., None, None] * (
            k_t[..., :, None] * v_t[..., None, :]
        )
        n = f_eff[..., None] * n + i_eff[..., None] * k_t
        num = jnp.einsum("bhd,bhde->bhe", q_t, c)
        den = jnp.abs(jnp.einsum("bhd,bhd->bh", q_t, n))
        y = num / jnp.maximum(den, jnp.exp(-m_new))[..., None]
        return (c, n, m_new), y

    init = (
        jnp.zeros((b, h, dh, dh), jnp.float32),
        jnp.zeros((b, h, dh), jnp.float32),
        jnp.full((b, h), -jnp.inf, jnp.float32),
    )
    xs = (
        jnp.moveaxis(q, 2, 0).astype(jnp.float32),
        jnp.moveaxis(k, 2, 0).astype(jnp.float32),
        jnp.moveaxis(v, 2, 0).astype(jnp.float32),
        jnp.moveaxis(i_gate, 2, 0).astype(jnp.float32),
        jnp.moveaxis(logf, 2, 0).astype(jnp.float32),
    )
    _, ys = jax.lax.scan(step, init, xs)
    return jnp.moveaxis(ys, 0, 2).astype(q.dtype)


def _mlstm_chunkwise(q, k, v, i_gate, f_gate, chunk: int):
    """Chunkwise-parallel mLSTM: intra-chunk attention-like term + carried
    inter-chunk matrix state (the standard parallel training form)."""
    b, h, l, dh = q.shape
    pad = (-l) % chunk
    if pad:
        q, k, v = (jnp.pad(t, ((0, 0), (0, 0), (0, pad), (0, 0))) for t in (q, k, v))
        i_gate = jnp.pad(i_gate, ((0, 0), (0, 0), (0, pad)))
        f_gate = jnp.pad(f_gate, ((0, 0), (0, 0), (0, pad)), constant_values=20.0)
    lp = q.shape[2]
    nc = lp // chunk
    # reshape to chunks and scan over them with the sequential cell applied
    # per chunk in parallel form: within-chunk via masked attention matrix.
    qc = q.reshape(b, h, nc, chunk, dh)
    kc = k.reshape(b, h, nc, chunk, dh)
    vc = v.reshape(b, h, nc, chunk, dh)
    ic = i_gate.reshape(b, h, nc, chunk).astype(jnp.float32)
    lfc = jax.nn.log_sigmoid(f_gate.reshape(b, h, nc, chunk).astype(jnp.float32))

    lf_cum = jnp.cumsum(lfc, axis=-1)                         # (B,H,nc,ch)
    lf_tot = lf_cum[..., -1]

    def chunk_step(carry, t_in):
        c, n, m = carry                                       # inter-chunk state
        q_t, k_t, v_t, i_t, lfcum_t, lftot_t = t_in
        # log weights of each in-chunk key for queries at each position
        # a_ij = i_j + lfcum_i - lfcum_j   (j <= i)
        a = i_t[..., None, :] + lfcum_t[..., :, None] - lfcum_t[..., None, :]
        mask = jnp.tril(jnp.ones((a.shape[-2], a.shape[-1]), bool))
        a = jnp.where(mask, a, -jnp.inf)                      # (B,H,ch,ch)
        # state contribution log-weight: m + lfcum_i
        b_state = m[..., None] + lfcum_t                      # (B,H,ch)
        m_loc = jnp.maximum(jnp.max(a, axis=-1), b_state)     # (B,H,ch)
        a_w = jnp.exp(a - m_loc[..., None])
        s_w = jnp.exp(b_state - m_loc)
        scores = jnp.einsum("bhid,bhjd->bhij", q_t, k_t)      # (B,H,ch,ch)
        num = jnp.einsum("bhij,bhjd->bhid", a_w * scores, v_t) + s_w[
            ..., None
        ] * jnp.einsum("bhid,bhde->bhie", q_t, c)
        den = jnp.einsum("bhij,bhij->bhi", a_w, scores) + s_w * jnp.einsum(
            "bhid,bhd->bhi", q_t, n
        )
        y = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_loc))[..., None]
        # update inter-chunk state to end of chunk
        key_logw = i_t + lftot_t[..., None] - lfcum_t          # (B,H,ch)
        m_new = jnp.maximum(lftot_t + m, jnp.max(key_logw, axis=-1))
        c = jnp.exp(lftot_t + m - m_new)[..., None, None] * c + jnp.einsum(
            "bhj,bhjd,bhje->bhde",
            jnp.exp(key_logw - m_new[..., None]),
            k_t,
            v_t,
        )
        n = jnp.exp(lftot_t + m - m_new)[..., None] * n + jnp.einsum(
            "bhj,bhjd->bhd",
            jnp.exp(key_logw - m_new[..., None]),
            k_t,
        )
        return (c, n, m_new), y

    init = (
        jnp.zeros((b, h, dh, dh), jnp.float32),
        jnp.zeros((b, h, dh), jnp.float32),
        jnp.full((b, h), -1e30, jnp.float32),
    )
    xs = (
        jnp.moveaxis(qc, 2, 0).astype(jnp.float32),
        jnp.moveaxis(kc, 2, 0).astype(jnp.float32),
        jnp.moveaxis(vc, 2, 0).astype(jnp.float32),
        jnp.moveaxis(ic, 2, 0),
        jnp.moveaxis(lf_cum, 2, 0),
        jnp.moveaxis(lf_tot, 2, 0),
    )
    _, ys = jax.lax.scan(chunk_step, init, xs)
    y = jnp.moveaxis(ys, 0, 2).reshape(b, h, lp, dh)[:, :, :l]
    return y.astype(q.dtype)


def mlstm_forward(p, x, cfg):
    b, l, d = x.shape
    h = cfg.n_heads
    di = cfg.xlstm_d_inner
    dh = di // h
    qkv = x @ p["w_qkv"]
    q, k, v = jnp.split(qkv, 3, axis=-1)
    q = q.reshape(b, l, h, dh).transpose(0, 2, 1, 3) / jnp.sqrt(float(dh))
    k = k.reshape(b, l, h, dh).transpose(0, 2, 1, 3)
    v = v.reshape(b, l, h, dh).transpose(0, 2, 1, 3)
    if_g = x @ p["w_if"] + p["b_if"]
    if_g = if_g.reshape(b, l, 2, h)
    i_gate = if_g[:, :, 0].transpose(0, 2, 1)
    f_gate = if_g[:, :, 1].transpose(0, 2, 1)
    y = _mlstm_chunkwise(q, k, v, i_gate, f_gate, cfg.xlstm_chunk)
    y = y.transpose(0, 2, 1, 3).reshape(b, l, di)
    y = y * p["norm"] * jax.nn.silu(x @ p["w_gate"])
    return y @ p["w_out"]


def mlstm_init_state(cfg, batch, dtype=jnp.float32):
    h = cfg.n_heads
    dh = cfg.xlstm_d_inner // h
    return {
        "c": jnp.zeros((batch, h, dh, dh), dtype),
        "n": jnp.zeros((batch, h, dh), dtype),
        "m": jnp.full((batch, h), -1e30, dtype),
    }


def mlstm_decode(p, x, state, cfg):
    """One-token recurrent mLSTM step."""
    b, _, d = x.shape
    h = cfg.n_heads
    di = cfg.xlstm_d_inner
    dh = di // h
    qkv = x @ p["w_qkv"]
    q, k, v = jnp.split(qkv[:, 0], 3, axis=-1)
    q = q.reshape(b, h, dh).astype(jnp.float32) / jnp.sqrt(float(dh))
    k = k.reshape(b, h, dh).astype(jnp.float32)
    v = v.reshape(b, h, dh).astype(jnp.float32)
    if_g = (x @ p["w_if"] + p["b_if"])[:, 0].reshape(b, 2, h).astype(jnp.float32)
    i_t, lf_t = if_g[:, 0], jax.nn.log_sigmoid(if_g[:, 1])
    c, n, m = state["c"].astype(jnp.float32), state["n"].astype(jnp.float32), state["m"].astype(jnp.float32)
    m_new = jnp.maximum(lf_t + m, i_t)
    f_eff = jnp.exp(lf_t + m - m_new)
    i_eff = jnp.exp(i_t - m_new)
    c = f_eff[..., None, None] * c + i_eff[..., None, None] * (
        k[..., :, None] * v[..., None, :]
    )
    n = f_eff[..., None] * n + i_eff[..., None] * k
    num = jnp.einsum("bhd,bhde->bhe", q, c)
    den = jnp.abs(jnp.einsum("bhd,bhd->bh", q, n))
    y = num / jnp.maximum(den, jnp.exp(-m_new))[..., None]
    y = y.reshape(b, 1, di).astype(x.dtype)
    y = y * p["norm"] * jax.nn.silu(x @ p["w_gate"])
    return y @ p["w_out"], {"c": c, "n": n, "m": m_new}


# ======================================================================
# sLSTM (scalar memory, sequential)
# ======================================================================
def init_slstm(key, cfg, *, stack=(), dtype=jnp.float32):
    d = cfg.d_model
    di = cfg.xlstm_d_inner
    ks = jax.random.split(key, 3)
    return {
        "w_gates": init_linear(ks[0], d, 4 * di, stack=stack, dtype=dtype),
        "r_gates": init_linear(ks[1], di, 4 * di, stack=stack,
                               scale=1.0 / float(di) ** 0.5, dtype=dtype),
        "w_out": init_linear(ks[2], di, d, stack=stack, dtype=dtype),
    }


def slstm_forward(p, x, cfg):
    """Sequential sLSTM over the sequence. x: (B, L, D)."""
    b, l, d = x.shape
    di = cfg.xlstm_d_inner
    wx = x @ p["w_gates"]                                     # (B, L, 4di)

    def step(carry, wx_t):
        c, n, m, h = carry
        g = wx_t + h @ p["r_gates"]
        z, i, f, o = jnp.split(g.astype(jnp.float32), 4, axis=-1)
        m_new = jnp.maximum(jax.nn.log_sigmoid(f) + m, i)
        i_eff = jnp.exp(i - m_new)
        f_eff = jnp.exp(jax.nn.log_sigmoid(f) + m - m_new)
        c = f_eff * c + i_eff * jnp.tanh(z)
        n = f_eff * n + i_eff
        h_new = (jax.nn.sigmoid(o) * c / jnp.maximum(n, 1e-6)).astype(x.dtype)
        return (c, n, m_new, h_new), h_new

    init = (
        jnp.zeros((b, di), jnp.float32),
        jnp.zeros((b, di), jnp.float32),
        jnp.full((b, di), -1e30, jnp.float32),
        jnp.zeros((b, di), x.dtype),
    )
    _, hs = jax.lax.scan(step, init, jnp.moveaxis(wx, 1, 0))
    y = jnp.moveaxis(hs, 0, 1)
    return y @ p["w_out"]


def slstm_init_state(cfg, batch, dtype=jnp.float32):
    di = cfg.xlstm_d_inner
    return {
        "c": jnp.zeros((batch, di), dtype),
        "n": jnp.zeros((batch, di), dtype),
        "m": jnp.full((batch, di), -1e30, dtype),
        "h": jnp.zeros((batch, di), dtype),
    }


def slstm_decode(p, x, state, cfg):
    wx = (x @ p["w_gates"])[:, 0]
    c, n, m, h = state["c"], state["n"], state["m"], state["h"]
    g = wx + h @ p["r_gates"]
    z, i, f, o = jnp.split(g.astype(jnp.float32), 4, axis=-1)
    m_new = jnp.maximum(jax.nn.log_sigmoid(f) + m, i)
    i_eff = jnp.exp(i - m_new)
    f_eff = jnp.exp(jax.nn.log_sigmoid(f) + m - m_new)
    c = f_eff * c + i_eff * jnp.tanh(z)
    n = f_eff * n + i_eff
    h_new = (jax.nn.sigmoid(o) * c / jnp.maximum(n, 1e-6)).astype(x.dtype)
    y = h_new[:, None] @ p["w_out"]
    return y, {"c": c, "n": n, "m": m_new, "h": h_new}

"""Mamba (S6) block for the Jamba hybrid architecture.

Training/prefill uses a chunked parallel scan (pure jnp two-phase chunk
formulation mirroring :mod:`repro.kernels.mamba_scan`, which is the Pallas
version validated against the same oracle); decode keeps O(1) recurrent
state per layer — this is what makes the long_500k shape tractable.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .blocks import init_linear


def init_mamba(key, cfg, *, stack=(), dtype=jnp.float32):
    d, di, n, dc = cfg.d_model, cfg.mamba_d_inner, cfg.mamba_d_state, cfg.mamba_d_conv
    ks = jax.random.split(key, 7)
    return {
        "w_in": init_linear(ks[0], d, 2 * di, stack=stack, dtype=dtype),
        "w_conv": 0.1 * jax.random.normal(ks[1], (*stack, dc, di), dtype),
        "w_x_dbc": init_linear(ks[2], di, cfg.mamba_dt_rank + 2 * n, stack=stack,
                               dtype=dtype),
        "w_dt": init_linear(ks[3], cfg.mamba_dt_rank, di, stack=stack, dtype=dtype),
        "a_log": jnp.broadcast_to(
            jnp.log(jnp.arange(1, n + 1, dtype=dtype)), (*stack, di, n)
        ).copy(),
        "d_skip": jnp.ones((*stack, di), dtype),
        "w_out": init_linear(ks[5], di, d, stack=stack, dtype=dtype),
    }


def _ssm_params(p, u, cfg):
    """u: (B, L, di) -> dt, A, Bmat, Cmat."""
    n, rk = cfg.mamba_d_state, cfg.mamba_dt_rank
    dbc = u @ p["w_x_dbc"]                                    # (B,L,rk+2n)
    dt = jax.nn.softplus(dbc[..., :rk] @ p["w_dt"])           # (B,L,di)
    bmat = dbc[..., rk : rk + n]                              # (B,L,n)
    cmat = dbc[..., rk + n :]                                 # (B,L,n)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))              # (di,n)
    return dt, a, bmat, cmat


def _causal_conv(p, u, conv_state=None):
    """Depthwise causal conv1d. u: (B, L, di)."""
    dc = p["w_conv"].shape[0]
    state_dtype = conv_state.dtype if conv_state is not None else u.dtype
    if conv_state is None:
        pad = jnp.zeros((u.shape[0], dc - 1, u.shape[2]), u.dtype)
    else:
        pad = conv_state.astype(u.dtype)  # don't let f32 state promote u
    full = jnp.concatenate([pad, u], axis=1)                  # (B, L+dc-1, di)
    out = sum(
        full[:, i : i + u.shape[1]] * p["w_conv"][i][None, None]
        for i in range(dc)
    )
    new_state = (full[:, -(dc - 1) :] if dc > 1 else pad).astype(state_dtype)
    return jax.nn.silu(out), new_state


def _chunked_scan(x, dt, a, bmat, cmat, chunk: int):
    """Two-phase chunked S6 scan in jnp (matches kernels.ref oracle)."""
    b, l, di = x.shape
    n = a.shape[1]
    pad = (-l) % chunk
    if pad:
        x, dt, bmat, cmat = (
            jnp.pad(v, ((0, 0), (0, pad), (0, 0))) for v in (x, dt, bmat, cmat)
        )
    lp = x.shape[1]
    nc = lp // chunk

    def local(chunk_inputs):
        xx, dd, bb, cc = chunk_inputs  # (chunk, di) (chunk, di) (chunk, n) x2

        def step(h, inp):
            x_t, d_t, b_t, c_t = inp
            h = jnp.exp(d_t[:, None] * a) * h + (d_t * x_t)[:, None] * b_t[None]
            return h, jnp.sum(h * c_t[None], axis=1)

        h, y = jax.lax.scan(step, jnp.zeros((di, n), jnp.float32),
                            (xx, dd, bb, cc))
        return y, h

    xc = x.reshape(b, nc, chunk, di).astype(jnp.float32)
    dc_ = dt.reshape(b, nc, chunk, di).astype(jnp.float32)
    bc = bmat.reshape(b, nc, chunk, n).astype(jnp.float32)
    cc = cmat.reshape(b, nc, chunk, n).astype(jnp.float32)

    y_loc, s_loc = jax.vmap(jax.vmap(local))((xc, dc_, bc, cc))
    # propagate chunk-initial states
    decay = jnp.exp(dc_.sum(axis=2)[..., None] * a[None, None])  # (B,nc,di,n)

    def comb(h, inp):
        dec, s = inp
        return dec * h + s, h

    _, h_init = jax.lax.scan(
        comb,
        jnp.zeros((b, di, n), jnp.float32),
        (jnp.moveaxis(decay, 1, 0), jnp.moveaxis(s_loc, 1, 0)),
    )
    h_init = jnp.moveaxis(h_init, 0, 1)                       # (B,nc,di,n)
    # inject initial-state contribution: y_t += C_t . (prefix-decay h_init)
    dt_cum = jnp.cumsum(dc_, axis=2)                          # (B,nc,chunk,di)
    pref = jnp.exp(dt_cum[..., None] * a[None, None, None])   # (B,nc,ch,di,n)
    y_corr = jnp.einsum("bgcn,bgcdn,bgdn->bgcd", cc, pref, h_init)
    y = (y_loc + y_corr).reshape(b, lp, di)[:, :l]
    h_final = decay[:, -1] * h_init[:, -1] + s_loc[:, -1]
    return y.astype(x.dtype), h_final


def mamba_forward(p, x, cfg):
    """Full-sequence block. x: (B, L, D)."""
    u = x @ p["w_in"]
    u, gate = jnp.split(u, 2, axis=-1)
    u, _ = _causal_conv(p, u)
    dt, a, bmat, cmat = _ssm_params(p, u, cfg)
    y, _ = _chunked_scan(u, dt, a, bmat, cmat, cfg.mamba_chunk)
    y = y + u * p["d_skip"]
    y = y * jax.nn.silu(gate)
    return y @ p["w_out"]


def mamba_init_state(cfg, batch, dtype=jnp.float32):
    di, n, dc = cfg.mamba_d_inner, cfg.mamba_d_state, cfg.mamba_d_conv
    return {
        "ssm": jnp.zeros((batch, di, n), dtype),
        "conv": jnp.zeros((batch, dc - 1, di), dtype),
    }


def mamba_decode(p, x, state, cfg):
    """One-token recurrent step. x: (B, 1, D)."""
    u = x @ p["w_in"]
    u, gate = jnp.split(u, 2, axis=-1)
    u, conv_state = _causal_conv(p, u, state["conv"])
    dt, a, bmat, cmat = _ssm_params(p, u, cfg)
    d_t = dt[:, 0].astype(jnp.float32)                        # (B, di)
    h = state["ssm"]
    h = jnp.exp(d_t[..., None] * a[None]) * h + (
        d_t * u[:, 0].astype(jnp.float32)
    )[..., None] * bmat[:, 0, None, :].astype(jnp.float32)
    y = jnp.sum(h * cmat[:, 0, None, :].astype(jnp.float32), axis=-1)
    y = y.astype(x.dtype)[:, None] + u * p["d_skip"]
    y = y * jax.nn.silu(gate)
    return y @ p["w_out"], {"ssm": h, "conv": conv_state}

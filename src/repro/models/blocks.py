"""Shared building blocks for the LM substrate.

Parameters are plain pytrees (nested dicts of jnp arrays) — no framework
dependency.  Initializers take an explicit PRNG key; every block is a pure
function ``f(params, x, ...) -> y`` so pjit/scan/remat compose freely.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


def truncated_normal(key, shape, scale, dtype=jnp.float32):
    # python-float scale: a strong numpy scalar would promote bf16 -> f32
    return float(scale) * jax.random.truncated_normal(key, -2.0, 2.0, shape, dtype)


def init_linear(key, d_in, d_out, *, stack=(), dtype=jnp.float32, scale=None):
    scale = scale if scale is not None else 1.0 / np.sqrt(max(d_in, 1))
    return truncated_normal(key, (*stack, d_in, d_out), scale, dtype)


# ----------------------------------------------------------------------
def rms_norm(w: jax.Array, x: jax.Array, *, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * w.astype(jnp.float32)).astype(dtype)


def layer_norm(w: jax.Array, b: jax.Array, x: jax.Array, *, eps: float = 1e-5):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(dtype)


# ----------------------------------------------------------------------
def swiglu_ffn(p: dict, x: jax.Array) -> jax.Array:
    """LLaMA-style gated FFN: down(silu(gate(x)) * up(x))."""
    h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    return h @ p["w_down"]


def init_swiglu(key, d_model, d_ff, *, stack=(), dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": init_linear(k1, d_model, d_ff, stack=stack, dtype=dtype),
        "w_up": init_linear(k2, d_model, d_ff, stack=stack, dtype=dtype),
        "w_down": init_linear(k3, d_ff, d_model, stack=stack, dtype=dtype),
    }


def gelu_ffn(p: dict, x: jax.Array) -> jax.Array:
    """Plain 2-layer GELU FFN (StarCoder2, Phi-3 style)."""
    return jax.nn.gelu(x @ p["w_up"] + p.get("b_up", 0.0)) @ p["w_down"] + p.get(
        "b_down", 0.0
    )


def init_gelu_ffn(key, d_model, d_ff, *, stack=(), bias=True, dtype=jnp.float32):
    k1, k2 = jax.random.split(key)
    p = {
        "w_up": init_linear(k1, d_model, d_ff, stack=stack, dtype=dtype),
        "w_down": init_linear(k2, d_ff, d_model, stack=stack, dtype=dtype),
    }
    if bias:
        p["b_up"] = jnp.zeros((*stack, d_ff), dtype)
        p["b_down"] = jnp.zeros((*stack, d_model), dtype)
    return p


# ----------------------------------------------------------------------
def rope_frequencies(d_head: int, *, theta: float = 10_000.0) -> jax.Array:
    return 1.0 / (
        theta ** (jnp.arange(0, d_head, 2, dtype=jnp.float32) / d_head)
    )


def apply_rope(x: jax.Array, positions: jax.Array, *, theta: float = 10_000.0):
    """x: (..., S, D_head); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta=theta)                  # (d/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, d/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., 0::2], x[..., 1::2]
    y1 = x1 * cos - x2 * sin
    y2 = x1 * sin + x2 * cos
    out = jnp.stack([y1, y2], axis=-1).reshape(x.shape)
    return out.astype(x.dtype)


# ----------------------------------------------------------------------
def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean token cross-entropy in fp32.

    The gold logit is extracted with a one-hot contraction, NOT
    take_along_axis: a gather over the model-sharded vocab axis would force
    GSPMD to all-gather the full fp32 logits (tens of GB per device at 1M
    tokens x 150k vocab); the contraction reduces over the sharded axis with
    one small all-reduce instead.
    """
    logz = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=logits.dtype)
    gold = jnp.einsum("...v,...v->...", logits, onehot).astype(jnp.float32)
    return jnp.mean(logz - gold)

"""Crossbar-aware SNN partitioning (paper §2.3, Algorithm 1).

Greedy bin-packing: neurons sorted ascending by fan-in are merged into the
first existing cluster (clusters kept sorted by descending utilization) whose
post-merge IO / crosspoint / buffer usage still fits a crossbar; otherwise a
new cluster is opened.  Output is the clustered SNN: a neuron→cluster map
plus the inter-cluster spike-rate matrix used as SDFG channel rates (§2.4).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Optional

import numpy as np

from .hardware import CrossbarConfig, HardwareConfig
from .snn import SNN


@dataclasses.dataclass
class Cluster:
    """Mutable packing state of one cluster (one crossbar's worth of SNN).

    ``input_mask`` is a boolean membership vector over all neurons: the
    union-size probe of Alg. 1 (can neuron n merge?) is then a vectorized
    fancy-index count instead of a Python set union — the difference between
    O(minutes) and O(seconds) on the 24k-neuron applications.
    """

    index: int
    neurons: list[int]
    input_mask: np.ndarray    # (n_neurons,) bool: distinct pre sources
    n_inputs: int
    n_synapses: int
    out_spikes: float         # per-iteration output spike volume (buffer use)

    def utilization(self, xbar: CrossbarConfig) -> float:
        """Paper's sort key: IO and crosspoint utilization, combined."""
        io = (self.n_inputs + len(self.neurons)) / (xbar.inputs + xbar.outputs)
        xpoint = self.n_synapses / xbar.crosspoints
        return 0.5 * (io + xpoint)


@dataclasses.dataclass
class ClusteredSNN:
    """Result of Algorithm 1.

    Inter-cluster channels are stored as parallel arrays sorted by
    ``(src, dst)`` — the array-native IR consumed directly by the SDFG and
    binding layers.  ``channel_spikes`` remains available as a lazily-built
    dict view for incremental call sites and tests.
    """

    snn: SNN
    cluster_of: np.ndarray            # (n_neurons,) int32
    n_clusters: int
    # channel i->j spike rate per application iteration (parallel arrays,
    # sorted by (src, dst); one entry per directed cluster pair with traffic)
    channel_src: np.ndarray           # (n_channels,) int64
    channel_dst: np.ndarray           # (n_channels,) int64
    channel_rate: np.ndarray          # (n_channels,) float64
    # per-cluster stats
    inputs_used: np.ndarray           # (n_clusters,)
    neurons_used: np.ndarray
    synapses_used: np.ndarray
    out_spikes: np.ndarray            # per-iteration spike volume out
    in_spikes: np.ndarray
    partition_time_s: float = 0.0

    @property
    def n_channels(self) -> int:
        return int(self.channel_src.size)

    @property
    def channel_spikes(self) -> dict[tuple[int, int], float]:
        """Compat dict view of the channel arrays (built on demand)."""
        return {
            (int(i), int(j)): float(r)
            for i, j, r in zip(self.channel_src, self.channel_dst, self.channel_rate)
        }

    def channel_degree(self) -> np.ndarray:
        """Per-cluster count of incident channels (in + out)."""
        return np.bincount(
            self.channel_src, minlength=self.n_clusters
        ) + np.bincount(self.channel_dst, minlength=self.n_clusters)

    def utilization(self, xbar: CrossbarConfig) -> dict[str, float]:
        io = (self.inputs_used + self.neurons_used) / (xbar.inputs + xbar.outputs)
        return {
            "io": float(np.mean(io)),
            "crosspoint": float(np.mean(self.synapses_used / xbar.crosspoints)),
        }


def _channel_arrays(
    snn: SNN, cluster_of: np.ndarray
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """AER spike traffic between cluster pairs, as (src, dst, rate) arrays
    sorted by (src, dst).

    The NoC multicasts ONE packet per source-neuron spike per destination
    cluster (the destination crossbar fans it out to all target synapses
    internally), so traffic is summed over distinct (pre-neuron, dst-cluster)
    pairs — not over individual cut synapses.
    """
    src = cluster_of[snn.pre]
    dst = cluster_of[snn.post]
    cut = src != dst
    empty = np.array([], dtype=np.int64)
    if not np.any(cut):
        return empty, empty, np.array([], dtype=np.float64)
    n = int(cluster_of.max() + 1)
    # dedupe (pre neuron, dst cluster): one packet per spike per dst cluster
    pair_key = snn.pre[cut].astype(np.int64) * n + dst[cut]
    uniq_pairs = np.unique(pair_key)
    pre_n = (uniq_pairs // n).astype(np.int64)
    dst_c = (uniq_pairs % n).astype(np.int64)
    src_c = cluster_of[pre_n].astype(np.int64)
    chan_key = src_c * n + dst_c
    uniq, inv = np.unique(chan_key, return_inverse=True)
    sums = np.bincount(inv, weights=snn.spikes[pre_n])
    # np.unique returns sorted keys -> arrays are (src, dst)-sorted already
    return (uniq // n).astype(np.int64), (uniq % n).astype(np.int64), sums


def partition_greedy(
    snn: SNN,
    hw: HardwareConfig,
    *,
    buffer_limit: Optional[int] = None,
    max_probe: int = 96,
    split_fill: float = 0.75,
) -> ClusteredSNN:
    """Algorithm 1 (crossbar-aware greedy bin-packing).

    ``max_probe`` bounds how many clusters (in utilization order) are probed
    per neuron before opening a new cluster — a linear-time guard for the
    10⁴-neuron applications; packing quality is unaffected in practice
    because the probe order is utilization-descending exactly as in line 11.

    ``split_fill``: neurons are pre-split to at most ``split_fill *
    crossbar.inputs`` fan-in so that several (sub-)neurons can share a
    crossbar's input rows; a neuron using 100+ of 128 rows alone would
    force one-cluster-per-neuron fragmentation.
    """
    t0 = time.perf_counter()
    xbar = hw.tile.crossbar
    buffer_limit = buffer_limit or hw.tile.output_buffer

    work = snn.split_high_fanin(max(1, int(xbar.inputs * split_fill)))
    fanin = work.fanin()

    # CSR of fan-in synapse lists (post -> sorted synapse indices).
    order = np.argsort(work.post, kind="stable")
    post_sorted = work.post[order]
    starts = np.searchsorted(post_sorted, np.arange(work.n_neurons), side="left")
    ends = np.searchsorted(post_sorted, np.arange(work.n_neurons), side="right")

    # line 1: ascending fan-in.  Ties (whole conv layers share one fan-in)
    # are broken by receptive-field position so that window-sharing neurons
    # are processed consecutively and land in the probe window of the
    # utilization-sorted cluster list.
    min_pre = np.zeros(work.n_neurons, dtype=np.int64)
    for n in range(work.n_neurons):
        syn = order[starts[n] : ends[n]]
        if syn.size:
            min_pre[n] = int(work.pre[syn].min())
    neuron_order = np.lexsort((min_pre, fanin))

    clusters: list[Cluster] = []
    by_util: list[Cluster] = []  # maintained descending by utilization
    cluster_of = np.full(work.n_neurons, -1, dtype=np.int32)
    merges = 0

    for n in neuron_order:
        syn_idx = order[starts[n] : ends[n]]
        pre_arr = np.unique(work.pre[syn_idx])
        n_pre = int(pre_arr.size)
        n_syn = int(syn_idx.size)
        out_rate = float(work.spikes[n])

        placed = None
        # probe set: highest-utilization clusters (paper line 11) plus the
        # most recently opened ones — neurons arrive sorted by receptive
        # field, so the freshest clusters are the window-compatible ones
        # (they start at the tail of the utilization ordering otherwise).
        probes = by_util[:max_probe]
        if len(clusters) > max_probe:
            probes = clusters[-16:][::-1] + probes
        for c in probes:
            # cheap rejects before the vectorized union-size probe
            if (
                len(c.neurons) + 1 > xbar.outputs
                or c.n_synapses + n_syn > xbar.crosspoints
                or c.out_spikes + out_rate > buffer_limit
                or max(c.n_inputs, n_pre) > xbar.inputs
            ):
                continue
            if c.n_inputs + n_pre <= xbar.inputs:
                placed = c  # fits even with zero overlap
                break
            new_inputs = c.n_inputs + int(
                np.count_nonzero(~c.input_mask[pre_arr])
            )
            if new_inputs <= xbar.inputs:
                placed = c
                break
        if placed is None:
            placed = Cluster(
                len(clusters), [], np.zeros(work.n_neurons, dtype=bool), 0, 0, 0.0
            )
            clusters.append(placed)
            by_util.append(placed)
        placed.neurons.append(int(n))
        placed.n_inputs += int(np.count_nonzero(~placed.input_mask[pre_arr]))
        placed.input_mask[pre_arr] = True
        placed.n_synapses += n_syn
        placed.out_spikes += out_rate
        cluster_of[n] = placed.index
        # line 11: keep clusters utilization-descending (single float key —
        # cheap enough to re-sort lazily every 128 merges; counting merges
        # gives a fixed cadence regardless of which neuron ids are visited).
        merges += 1
        if len(by_util) > 1 and merges % 128 == 0:
            by_util.sort(key=lambda c: -c.utilization(xbar))

    assert np.all(cluster_of >= 0)

    # line 13: consistency / connectivity / deadlock-freedom checks
    ch_src, ch_dst, ch_rate = _channel_arrays(work, cluster_of)
    n_clusters = len(clusters)

    in_spikes = np.bincount(ch_dst, weights=ch_rate, minlength=n_clusters)

    result = ClusteredSNN(
        snn=work,
        cluster_of=cluster_of,
        n_clusters=n_clusters,
        channel_src=ch_src,
        channel_dst=ch_dst,
        channel_rate=ch_rate,
        inputs_used=np.array([c.n_inputs for c in clusters]),
        neurons_used=np.array([len(c.neurons) for c in clusters]),
        synapses_used=np.array([c.n_synapses for c in clusters]),
        out_spikes=np.array([c.out_spikes for c in clusters]),
        in_spikes=in_spikes,
        partition_time_s=time.perf_counter() - t0,
    )
    check_clustering(result, xbar, buffer_limit)
    return result


def check_clustering(
    c: ClusteredSNN, xbar: CrossbarConfig, buffer_limit: float
) -> None:
    """Consistency, connectivity and capacity checks (Alg. 1 line 13)."""
    assert c.inputs_used.max(initial=0) <= xbar.inputs, "input-port overflow"
    assert c.neurons_used.max(initial=0) <= xbar.outputs, "output-port overflow"
    assert c.synapses_used.max(initial=0) <= xbar.crosspoints, "crosspoint overflow"
    assert c.out_spikes.max(initial=0.0) <= buffer_limit + 1e-9, "buffer overflow"
    # every neuron mapped exactly once
    counts = np.bincount(c.cluster_of, minlength=c.n_clusters)
    assert counts.sum() == c.snn.n_neurons
    # deadlock-freedom of the clustered graph is guaranteed by construction:
    # every channel's production is consumed within one iteration (RptV = 1);
    # the SDFG layer re-verifies with an explicit liveness check.

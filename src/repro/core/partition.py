"""Crossbar-aware SNN partitioning (paper §2.3, Algorithm 1).

Greedy bin-packing: neurons sorted ascending by fan-in are merged into the
first existing cluster (clusters kept sorted by descending utilization) whose
post-merge IO / crosspoint / buffer usage still fits a crossbar; otherwise a
new cluster is opened.  Output is the clustered SNN: a neuron→cluster map
plus the inter-cluster spike-rate matrix used as SDFG channel rates (§2.4).

Two implementations of Algorithm 1, cross-validated in tests:

  * :func:`partition_greedy` — the wave-based vectorized packer (default).
    Neurons are processed in fan-in-sorted *waves* of 128 (the lazy
    utilization re-sort cadence); each wave's feasibility and input-overlap
    against the open probe clusters is scored in vectorized blocks (one
    boolean gather + segment-sum over the wave's unique-source CSR), and
    only the O(1) conflict-resolution walk per neuron stays in Python.
    Decisions replicate the scalar path EXACTLY — identical probe order,
    identical overlap counts, identical re-sort points — so ``cluster_of``
    is bit-identical to the reference on every input.
  * :func:`partition_greedy_reference` — the scalar per-neuron loop (the
    original Algorithm-1 transcription), kept as the cross-validation
    oracle and readable specification.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Optional

import numpy as np

from .hardware import CrossbarConfig, HardwareConfig
from .snn import SNN

#: Lazy utilization re-sort cadence of Algorithm 1 line 11 (merges between
#: re-sorts) — also the wave width of the vectorized packer, so both
#: implementations re-sort at identical points.
WAVE = 128

#: Column-block width of the wave packer's lazily computed feasibility
#: matrix (probe clusters scored 16 at a time, on demand).
_F_BLOCK = 16


@dataclasses.dataclass
class Cluster:
    """Mutable packing state of one cluster (one crossbar's worth of SNN).

    ``input_mask`` is a boolean membership vector over all neurons: the
    union-size probe of Alg. 1 (can neuron n merge?) is then a vectorized
    fancy-index count instead of a Python set union — the difference between
    O(minutes) and O(seconds) on the 24k-neuron applications.
    """

    index: int
    neurons: list[int]
    input_mask: np.ndarray    # (n_neurons,) bool: distinct pre sources
    n_inputs: int
    n_synapses: int
    out_spikes: float         # per-iteration output spike volume (buffer use)

    def utilization(self, xbar: CrossbarConfig) -> float:
        """Paper's sort key: IO and crosspoint utilization, combined."""
        io = (self.n_inputs + len(self.neurons)) / (xbar.inputs + xbar.outputs)
        xpoint = self.n_synapses / xbar.crosspoints
        return 0.5 * (io + xpoint)


@dataclasses.dataclass
class ClusteredSNN:
    """Result of Algorithm 1.

    Inter-cluster channels are stored as parallel arrays sorted by
    ``(src, dst)`` — the array-native IR consumed directly by the SDFG and
    binding layers.  ``channel_spikes`` remains available as a lazily-built
    dict view for incremental call sites and tests.
    """

    snn: SNN
    cluster_of: np.ndarray            # (n_neurons,) int32
    n_clusters: int
    # channel i->j spike rate per application iteration (parallel arrays,
    # sorted by (src, dst); one entry per directed cluster pair with traffic)
    channel_src: np.ndarray           # (n_channels,) int64
    channel_dst: np.ndarray           # (n_channels,) int64
    channel_rate: np.ndarray          # (n_channels,) float64
    # per-cluster stats
    inputs_used: np.ndarray           # (n_clusters,)
    neurons_used: np.ndarray
    synapses_used: np.ndarray
    out_spikes: np.ndarray            # per-iteration spike volume out
    in_spikes: np.ndarray
    partition_time_s: float = 0.0

    @property
    def n_channels(self) -> int:
        return int(self.channel_src.size)

    @property
    def channel_spikes(self) -> dict[tuple[int, int], float]:
        """Compat dict view of the channel arrays (built on demand)."""
        return {
            (int(i), int(j)): float(r)
            for i, j, r in zip(self.channel_src, self.channel_dst, self.channel_rate)
        }

    def channel_degree(self) -> np.ndarray:
        """Per-cluster count of incident channels (in + out)."""
        return np.bincount(
            self.channel_src, minlength=self.n_clusters
        ) + np.bincount(self.channel_dst, minlength=self.n_clusters)

    def utilization(self, xbar: CrossbarConfig) -> dict[str, float]:
        io = (self.inputs_used + self.neurons_used) / (xbar.inputs + xbar.outputs)
        return {
            "io": float(np.mean(io)),
            "crosspoint": float(np.mean(self.synapses_used / xbar.crosspoints)),
        }


def _channel_arrays(
    snn: SNN, cluster_of: np.ndarray
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """AER spike traffic between cluster pairs, as (src, dst, rate) arrays
    sorted by (src, dst).

    The NoC multicasts ONE packet per source-neuron spike per destination
    cluster (the destination crossbar fans it out to all target synapses
    internally), so traffic is summed over distinct (pre-neuron, dst-cluster)
    pairs — not over individual cut synapses.
    """
    src = cluster_of[snn.pre]
    dst = cluster_of[snn.post]
    cut = src != dst
    empty = np.array([], dtype=np.int64)
    if not np.any(cut):
        return empty, empty, np.array([], dtype=np.float64)
    n = int(cluster_of.max() + 1)
    # dedupe (pre neuron, dst cluster): one packet per spike per dst cluster
    pair_key = snn.pre[cut].astype(np.int64) * n + dst[cut]
    uniq_pairs = np.unique(pair_key)
    pre_n = (uniq_pairs // n).astype(np.int64)
    dst_c = (uniq_pairs % n).astype(np.int64)
    src_c = cluster_of[pre_n].astype(np.int64)
    chan_key = src_c * n + dst_c
    uniq, inv = np.unique(chan_key, return_inverse=True)
    sums = np.bincount(inv, weights=snn.spikes[pre_n])
    # np.unique returns sorted keys -> arrays are (src, dst)-sorted already
    return (uniq // n).astype(np.int64), (uniq % n).astype(np.int64), sums


def _synapse_csr(work: SNN) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """CSR of fan-in synapse lists: (edge_order, starts, ends) by post."""
    order = np.argsort(work.post, kind="stable")
    post_sorted = work.post[order]
    starts = np.searchsorted(post_sorted, np.arange(work.n_neurons), side="left")
    ends = np.searchsorted(post_sorted, np.arange(work.n_neurons), side="right")
    return order, starts, ends


def _neuron_order(
    work: SNN, order: np.ndarray, starts: np.ndarray, ends: np.ndarray,
    fanin: np.ndarray,
) -> np.ndarray:
    """Alg. 1 line 1: ascending fan-in, ties broken by receptive field.

    Ties (whole conv layers share one fan-in) are broken by the minimum
    pre-synaptic source id so that window-sharing neurons are processed
    consecutively and land in the probe window of the utilization-sorted
    cluster list.  The per-neuron minimum is one ``np.minimum.reduceat``
    over the CSR layout — no Python pass over the neurons.
    """
    min_pre = np.zeros(work.n_neurons, dtype=np.int64)
    nonempty = ends > starts
    if nonempty.any():
        pre_sorted = work.pre[order].astype(np.int64)
        min_pre[nonempty] = np.minimum.reduceat(pre_sorted, starts[nonempty])
    return np.lexsort((min_pre, fanin))


def _finalize(
    work: SNN,
    cluster_of: np.ndarray,
    inputs_used: np.ndarray,
    neurons_used: np.ndarray,
    synapses_used: np.ndarray,
    out_spikes: np.ndarray,
    xbar: CrossbarConfig,
    buffer_limit: float,
    t0: float,
) -> ClusteredSNN:
    """Shared Alg.-1 epilogue: channel arrays, stats, line-13 checks."""
    assert np.all(cluster_of >= 0)
    ch_src, ch_dst, ch_rate = _channel_arrays(work, cluster_of)
    n_clusters = int(inputs_used.size)
    in_spikes = np.bincount(ch_dst, weights=ch_rate, minlength=n_clusters)
    result = ClusteredSNN(
        snn=work,
        cluster_of=cluster_of,
        n_clusters=n_clusters,
        channel_src=ch_src,
        channel_dst=ch_dst,
        channel_rate=ch_rate,
        inputs_used=inputs_used,
        neurons_used=neurons_used,
        synapses_used=synapses_used,
        out_spikes=out_spikes,
        in_spikes=in_spikes,
        partition_time_s=time.perf_counter() - t0,
    )
    check_clustering(result, xbar, buffer_limit)
    return result


# ======================================================================
# scalar reference (the original Algorithm-1 transcription)
# ======================================================================
def partition_greedy_reference(
    snn: SNN,
    hw: HardwareConfig,
    *,
    buffer_limit: Optional[int] = None,
    max_probe: int = 96,
    split_fill: float = 0.75,
) -> ClusteredSNN:
    """Algorithm 1, scalar per-neuron loop (cross-validation oracle).

    ``max_probe`` bounds how many clusters (in utilization order) are probed
    per neuron before opening a new cluster — a linear-time guard for the
    10⁴-neuron applications; packing quality is unaffected in practice
    because the probe order is utilization-descending exactly as in line 11.

    ``split_fill``: neurons are pre-split to at most ``split_fill *
    crossbar.inputs`` fan-in so that several (sub-)neurons can share a
    crossbar's input rows; a neuron using 100+ of 128 rows alone would
    force one-cluster-per-neuron fragmentation.
    """
    t0 = time.perf_counter()
    xbar = hw.tile.crossbar
    buffer_limit = buffer_limit or hw.tile.output_buffer

    work = snn.split_high_fanin(max(1, int(xbar.inputs * split_fill)))
    fanin = work.fanin()
    order, starts, ends = _synapse_csr(work)
    neuron_order = _neuron_order(work, order, starts, ends, fanin)

    clusters: list[Cluster] = []
    by_util: list[Cluster] = []  # maintained descending by utilization
    cluster_of = np.full(work.n_neurons, -1, dtype=np.int32)
    merges = 0

    for n in neuron_order:
        syn_idx = order[starts[n] : ends[n]]
        pre_arr = np.unique(work.pre[syn_idx])
        n_pre = int(pre_arr.size)
        n_syn = int(syn_idx.size)
        out_rate = float(work.spikes[n])

        placed = None
        # probe set: highest-utilization clusters (paper line 11) plus the
        # most recently opened ones — neurons arrive sorted by receptive
        # field, so the freshest clusters are the window-compatible ones
        # (they start at the tail of the utilization ordering otherwise).
        probes = by_util[:max_probe]
        if len(clusters) > max_probe:
            probes = clusters[-16:][::-1] + probes
        for c in probes:
            # cheap rejects before the vectorized union-size probe
            if (
                len(c.neurons) + 1 > xbar.outputs
                or c.n_synapses + n_syn > xbar.crosspoints
                or c.out_spikes + out_rate > buffer_limit
                or max(c.n_inputs, n_pre) > xbar.inputs
            ):
                continue
            if c.n_inputs + n_pre <= xbar.inputs:
                placed = c  # fits even with zero overlap
                break
            new_inputs = c.n_inputs + int(
                np.count_nonzero(~c.input_mask[pre_arr])
            )
            if new_inputs <= xbar.inputs:
                placed = c
                break
        if placed is None:
            placed = Cluster(
                len(clusters), [], np.zeros(work.n_neurons, dtype=bool), 0, 0, 0.0
            )
            clusters.append(placed)
            by_util.append(placed)
        placed.neurons.append(int(n))
        placed.n_inputs += int(np.count_nonzero(~placed.input_mask[pre_arr]))
        placed.input_mask[pre_arr] = True
        placed.n_synapses += n_syn
        placed.out_spikes += out_rate
        cluster_of[n] = placed.index
        # line 11: keep clusters utilization-descending (single float key —
        # cheap enough to re-sort lazily every 128 merges; counting merges
        # gives a fixed cadence regardless of which neuron ids are visited).
        merges += 1
        if len(by_util) > 1 and merges % WAVE == 0:
            by_util.sort(key=lambda c: -c.utilization(xbar))

    return _finalize(
        work,
        cluster_of,
        np.array([c.n_inputs for c in clusters]),
        np.array([len(c.neurons) for c in clusters]),
        np.array([c.n_synapses for c in clusters]),
        np.array([c.out_spikes for c in clusters]),
        xbar,
        buffer_limit,
        t0,
    )


# ======================================================================
# wave-based vectorized packer (default)
# ======================================================================
def partition_greedy(
    snn: SNN,
    hw: HardwareConfig,
    *,
    buffer_limit: Optional[int] = None,
    max_probe: int = 96,
    split_fill: float = 0.75,
) -> ClusteredSNN:
    """Algorithm 1 as a wave-based vectorized packer.

    Neurons are processed in fan-in-sorted waves of :data:`WAVE` (= the
    lazy utilization re-sort cadence, so probe order is frozen within a
    wave exactly as in the scalar path).  Per wave:

      * the wave's distinct pre-synaptic sources come from one global
        unique-(post, pre) CSR built once up front (no per-neuron
        ``np.unique``);
      * feasibility of every (wave neuron, probe cluster) pair is scored in
        vectorized column blocks, computed lazily as the probe walk first
        reaches a block: the capacity checks are one broadcast compare and
        the input-union sizes come from a single boolean gather over the
        input-membership matrix + ``np.add.reduceat`` per neuron segment;
      * placements are applied by a conflict-resolving walk: clusters
        untouched since the wave started use the precomputed block entries
        (O(1) per probe), clusters modified mid-wave are re-probed exactly
        against live state (O(fan-in), the same count the scalar path pays).

    Produces bit-identical ``cluster_of`` to
    :func:`partition_greedy_reference` on every input — the cross-validation
    suite asserts equality — at a fraction of the interpreter cost.
    ``max_probe`` / ``split_fill`` / ``buffer_limit`` as in the reference.
    """
    t0 = time.perf_counter()
    xbar = hw.tile.crossbar
    inputs_cap, outputs_cap, xpoints_cap = (
        xbar.inputs, xbar.outputs, xbar.crosspoints,
    )
    buffer_limit = buffer_limit or hw.tile.output_buffer

    work = snn.split_high_fanin(max(1, int(xbar.inputs * split_fill)))
    n = work.n_neurons
    fanin = work.fanin()
    order, starts, ends = _synapse_csr(work)
    neuron_order = _neuron_order(work, order, starts, ends, fanin)

    # global unique-(post, pre) CSR: per-neuron distinct sources, sorted
    pair_key = work.post.astype(np.int64) * n + work.pre
    upairs = np.unique(pair_key)
    upost = upairs // n
    upre_all = upairs % n
    ustarts = np.searchsorted(upost, np.arange(n), side="left")
    uends = np.searchsorted(upost, np.arange(n), side="right")
    n_pre_all = uends - ustarts

    # -- growable cluster-state arrays (id = creation order) ------------
    cap = 256
    mask_t = np.zeros((cap, n), dtype=bool)       # input membership, by row
    cl_inputs = np.zeros(cap, dtype=np.int64)
    cl_nneur = np.zeros(cap, dtype=np.int64)
    cl_nsyn = np.zeros(cap, dtype=np.int64)
    cl_out = np.zeros(cap, dtype=np.float64)
    cl_lo = np.full(cap, n, dtype=np.int64)       # input-id range (receptive
    cl_hi = np.full(cap, -1, dtype=np.int64)      # field); no overlap outside
    touch_stamp = np.full(cap, -1, dtype=np.int64)   # last wave that modified
    col_stamp = np.full(cap, -1, dtype=np.int64)     # wave of the F column
    col_idx = np.zeros(cap, dtype=np.int64)          # column in this wave's F

    by_util: list[int] = []      # cluster ids, utilization-descending
    cluster_of = np.full(n, -1, dtype=np.int32)
    n_clusters = 0
    spikes = work.spikes

    def _grow() -> None:
        nonlocal cap, mask_t, cl_inputs, cl_nneur, cl_nsyn, cl_out
        nonlocal cl_lo, cl_hi, touch_stamp, col_stamp, col_idx
        extra = cap
        mask_t = np.vstack([mask_t, np.zeros((extra, n), dtype=bool)])
        cl_inputs = np.concatenate([cl_inputs, np.zeros(extra, np.int64)])
        cl_nneur = np.concatenate([cl_nneur, np.zeros(extra, np.int64)])
        cl_nsyn = np.concatenate([cl_nsyn, np.zeros(extra, np.int64)])
        cl_out = np.concatenate([cl_out, np.zeros(extra)])
        cl_lo = np.concatenate([cl_lo, np.full(extra, n, np.int64)])
        cl_hi = np.concatenate([cl_hi, np.full(extra, -1, np.int64)])
        touch_stamp = np.concatenate([touch_stamp, np.full(extra, -1, np.int64)])
        col_stamp = np.concatenate([col_stamp, np.full(extra, -1, np.int64)])
        col_idx = np.concatenate([col_idx, np.zeros(extra, np.int64)])
        cap += extra

    n_waves = (n + WAVE - 1) // WAVE
    for wave_no in range(n_waves):
        wave_ids = neuron_order[wave_no * WAVE : (wave_no + 1) * WAVE]
        w_count = wave_ids.size

        # -- wave snapshot: probe universe (newest 16 first so the common
        # case touches only block 0) + unique-source concatenation -------
        univ: list[int] = []
        if n_clusters > max_probe:
            for cid in range(n_clusters - 1, max(n_clusters - 17, -1), -1):
                if col_stamp[cid] != wave_no:
                    col_stamp[cid] = wave_no
                    col_idx[cid] = len(univ)
                    univ.append(cid)
        for cid in by_util[:max_probe]:
            if col_stamp[cid] != wave_no:
                col_stamp[cid] = wave_no
                col_idx[cid] = len(univ)
                univ.append(cid)
        univ_arr = np.asarray(univ, dtype=np.int64)

        n_pre_w = n_pre_all[wave_ids]
        n_syn_w = fanin[wave_ids].astype(np.int64)
        rate_w = spikes[wave_ids]
        seg_lens = n_pre_w
        seg_starts = np.concatenate([[0], np.cumsum(seg_lens)[:-1]])
        tot = int(seg_lens.sum())
        if tot:
            flat = (
                np.repeat(ustarts[wave_ids] - seg_starts, seg_lens)
                + np.arange(tot)
            )
            wave_pres = upre_all[flat]
            safe_s = np.minimum(ustarts[wave_ids], upre_all.size - 1)
            lo_w = np.where(seg_lens > 0, upre_all[safe_s], 0)
            hi_w = np.where(
                seg_lens > 0, upre_all[np.maximum(uends[wave_ids] - 1, 0)], -1
            )
        else:
            wave_pres = np.array([], dtype=np.int64)
            lo_w = np.zeros(w_count, dtype=np.int64)
            hi_w = np.full(w_count, -1, dtype=np.int64)
        nonempty = seg_lens > 0

        # -- identical-window runs: neuron i chains onto neuron i-1's run
        # when their unique-pre segments and synapse counts are equal (the
        # conv-style generators emit long stretches of these).  A follower
        # whose rate is not below its run head's can then be committed to
        # the head's cluster without walking: it rejects every cluster the
        # head rejected (all checks are shared except the buffer check,
        # which is monotone in the rate), and it adds zero new inputs to
        # the head's cluster — so only the output/crosspoint/buffer
        # capacity cumsums decide how much of the run fits.
        same_prev = np.zeros(w_count, dtype=bool)
        if w_count > 1:
            eq = (
                (seg_lens[1:] == seg_lens[:-1])
                & (n_syn_w[1:] == n_syn_w[:-1])
                & (lo_w[1:] == lo_w[:-1])
                & (hi_w[1:] == hi_w[:-1])
            )
            for p in np.flatnonzero(eq):
                same_prev[p + 1] = bool(np.array_equal(
                    wave_pres[seg_starts[p]:seg_starts[p] + seg_lens[p]],
                    wave_pres[
                        seg_starts[p + 1]:seg_starts[p + 1] + seg_lens[p + 1]
                    ],
                ))
        same_prev_l = same_prev.tolist()

        n_blocks = (len(univ) + _F_BLOCK - 1) // _F_BLOCK
        fit = np.zeros((w_count, n_blocks * _F_BLOCK), dtype=bool)
        blk_done = np.zeros(max(n_blocks, 1), dtype=bool)

        def _compute_block(blk: int) -> None:
            """Feasibility of the whole wave vs one 16-column probe block.

            Valid only for columns untouched since the wave started — the
            probe walk never consults touched columns here.  Input-union
            sizes (the expensive part) are gathered only for columns whose
            input-id range intersects a wave neuron's receptive field —
            disjoint ranges mean zero overlap, which cannot rescue a pair
            that already failed the zero-overlap fit.
            """
            cols = univ_arr[blk * _F_BLOCK : (blk + 1) * _F_BLOCK]
            ci = cl_inputs[cols][None, :]
            cheap = (
                (cl_nneur[cols][None, :] + 1 <= outputs_cap)
                & (cl_nsyn[cols][None, :] + n_syn_w[:, None] <= xpoints_cap)
                & (cl_out[cols][None, :] + rate_w[:, None] <= buffer_limit)
                & (np.maximum(ci, n_pre_w[:, None]) <= inputs_cap)
            )
            zerofit = ci + n_pre_w[:, None] <= inputs_cap
            blk_fit = cheap & zerofit
            need = (
                cheap
                & ~zerofit
                & (cl_lo[cols][None, :] <= hi_w[:, None])
                & (cl_hi[cols][None, :] >= lo_w[:, None])
            )
            col_sel = need.any(axis=0)
            if col_sel.any() and tot:
                cols_g = cols[col_sel]
                vals = mask_t[np.ix_(cols_g, wave_pres)]
                red = np.add.reduceat(vals, seg_starts[nonempty], axis=1)
                ov = np.zeros((cols_g.size, w_count), dtype=np.int64)
                ov[:, nonempty] = red
                fits_ov = (
                    cl_inputs[cols_g][None, :] + n_pre_w[:, None] - ov.T
                    <= inputs_cap
                )
                sub = blk_fit[:, col_sel]
                blk_fit[:, col_sel] = sub | (need[:, col_sel] & fits_ov)
            fit[:, blk * _F_BLOCK : blk * _F_BLOCK + cols.size] = blk_fit
            blk_done[blk] = True

        def _fits_live(
            cid: int, npre: int, nsyn: int, rate: float, upre_seg: np.ndarray
        ) -> bool:
            """Exact live probe of one (possibly mid-wave-modified) cluster
            — the same checks and overlap count the scalar path performs."""
            if (
                cl_nneur[cid] + 1 > outputs_cap
                or cl_nsyn[cid] + nsyn > xpoints_cap
                or cl_out[cid] + rate > buffer_limit
                or max(cl_inputs[cid], npre) > inputs_cap
            ):
                return False
            if cl_inputs[cid] + npre <= inputs_cap:
                return True
            if npre == 0 or cl_hi[cid] < upre_seg[0] or cl_lo[cid] > upre_seg[-1]:
                return False  # disjoint ranges: zero overlap cannot fit
            ov = int(np.count_nonzero(mask_t[cid, upre_seg]))
            return cl_inputs[cid] + npre - ov <= inputs_cap

        # Python-list mirrors of the per-probe lookups: the walk below reads
        # them once per probe, and list indexing is several times cheaper
        # than numpy scalar indexing at this granularity.
        touched_l = (touch_stamp[:n_clusters] == wave_no).tolist()
        col_l = np.where(
            col_stamp[:n_clusters] == wave_no, col_idx[:n_clusters], -1
        ).tolist()
        npre_l = n_pre_w.tolist()
        nsyn_l = n_syn_w.tolist()
        rate_l = rate_w.tolist()
        wave_ids_l = wave_ids.tolist()

        # -- conflict-resolving placement walk (exact scalar semantics,
        # identical-window runs bulk-committed behind each walked head) -
        i = 0
        while i < w_count:
            nid = wave_ids_l[i]
            npre = npre_l[i]
            nsyn = nsyn_l[i]
            rate = rate_l[i]
            upre_seg = upre_all[ustarts[nid] : uends[nid]]

            placed = -1
            if n_clusters > max_probe:
                for cid in range(n_clusters - 1, n_clusters - 17, -1):
                    j = -1 if touched_l[cid] else col_l[cid]
                    if j >= 0:
                        blk = j // _F_BLOCK
                        if not blk_done[blk]:
                            _compute_block(blk)
                        if fit[i, j]:
                            placed = cid
                            break
                    elif _fits_live(cid, npre, nsyn, rate, upre_seg):
                        placed = cid
                        break
            if placed < 0:
                for cid in by_util[:max_probe]:
                    j = -1 if touched_l[cid] else col_l[cid]
                    if j >= 0:
                        blk = j // _F_BLOCK
                        if not blk_done[blk]:
                            _compute_block(blk)
                        if fit[i, j]:
                            placed = cid
                            break
                    elif _fits_live(cid, npre, nsyn, rate, upre_seg):
                        placed = cid
                        break
            if placed < 0:
                if n_clusters == cap:
                    _grow()
                placed = n_clusters
                n_clusters += 1
                by_util.append(placed)
                touched_l.append(True)
                col_l.append(-1)

            row = mask_t[placed]
            cl_inputs[placed] += npre - int(
                np.count_nonzero(row[upre_seg])
            )
            row[upre_seg] = True
            cl_nneur[placed] += 1
            cl_nsyn[placed] += nsyn
            cl_out[placed] += rate
            if npre:
                if upre_seg[0] < cl_lo[placed]:
                    cl_lo[placed] = upre_seg[0]
                if upre_seg[-1] > cl_hi[placed]:
                    cl_hi[placed] = upre_seg[-1]
            cluster_of[nid] = placed
            touched_l[placed] = True
            touch_stamp[placed] = wave_no

            # bulk-commit the identical-window run behind this head: the
            # run extends while each follower chains (same window + nsyn)
            # and its rate is not below the HEAD's (the neuron whose walk
            # rejections the run reuses); capacity decides how many fit.
            i += 1
            run_end = i
            while (
                run_end < w_count
                and same_prev_l[run_end]
                and rate_l[run_end] >= rate
            ):
                run_end += 1
            if run_end > i:
                m = run_end - i
                m = min(m, outputs_cap - int(cl_nneur[placed]))
                if nsyn > 0:
                    m = min(
                        m, (xpoints_cap - int(cl_nsyn[placed])) // nsyn
                    )
                if m > 0:
                    # buffer check accumulates in the scalar loop's exact
                    # float order, so the cutoff is bit-identical
                    out = float(cl_out[placed])
                    take = 0
                    for r in rate_l[i : i + m]:
                        if out + r > buffer_limit:
                            break
                        out += r
                        take += 1
                    m = take
                if m > 0:
                    cluster_of[wave_ids[i : i + m]] = placed
                    cl_nneur[placed] += m
                    cl_nsyn[placed] += m * nsyn
                    cl_out[placed] = out
                    i += m

        # line 11 re-sort at the exact scalar cadence (every WAVE merges);
        # np.argsort(stable) over the negated key == list.sort(key=-util)
        if w_count == WAVE and len(by_util) > 1:
            util = 0.5 * (
                (cl_inputs[:n_clusters] + cl_nneur[:n_clusters])
                / (inputs_cap + outputs_cap)
                + cl_nsyn[:n_clusters] / xpoints_cap
            )
            ids = np.asarray(by_util, dtype=np.int64)
            by_util = ids[np.argsort(-util[ids], kind="stable")].tolist()

    return _finalize(
        work,
        cluster_of,
        cl_inputs[:n_clusters].copy(),
        cl_nneur[:n_clusters].copy(),
        cl_nsyn[:n_clusters].copy(),
        cl_out[:n_clusters].copy(),
        xbar,
        buffer_limit,
        t0,
    )


def check_clustering(
    c: ClusteredSNN, xbar: CrossbarConfig, buffer_limit: float
) -> None:
    """Consistency, connectivity and capacity checks (Alg. 1 line 13)."""
    assert c.inputs_used.max(initial=0) <= xbar.inputs, "input-port overflow"
    assert c.neurons_used.max(initial=0) <= xbar.outputs, "output-port overflow"
    assert c.synapses_used.max(initial=0) <= xbar.crosspoints, "crosspoint overflow"
    assert c.out_spikes.max(initial=0.0) <= buffer_limit + 1e-9, "buffer overflow"
    # every neuron mapped exactly once
    counts = np.bincount(c.cluster_of, minlength=c.n_clusters)
    assert counts.sum() == c.snn.n_neurons
    # deadlock-freedom of the clustered graph is guaranteed by construction:
    # every channel's production is consumed within one iteration (RptV = 1);
    # the SDFG layer re-verifies with an explicit liveness check.

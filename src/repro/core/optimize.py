"""Throughput-in-the-loop binding optimization (closing the §4.2 loop).

The paper's binder balances the Eq.-7 load *proxy* and only afterwards
checks throughput; DFSynthesizer and SpiNeMap likewise optimize proxies
(load spread, cut traffic).  With the batched engine, the *real* objective
is cheap enough to sit inside the search loop: one
:func:`~repro.core.engine.batch_execute` call scores a whole population of
candidate bindings — exact steady-state periods of every candidate's
order-augmented event graph — so cluster-to-tile assignment becomes a
population-based search over (B, n_clusters) binding matrices:

  * generation = ONE EdgeStack build + ONE batched lambda-search (no
    per-candidate SDFG objects, exactly like
    :func:`~repro.core.explore.score_free_tile_subsets`),
  * proposals = the three §4.2/§6.3 heuristic binders as seeds, then
    vectorized pairwise swaps, single-cluster moves, uniform crossover,
    and two guided mutation families — bottleneck-tile moves (serialized
    compute) and comm-critical-path moves (co-locate the heaviest cut
    channel's endpoints, the NoC-bound counterpart),
  * schedules = ONE batched Lemma-1 projection of the design-time
    single-tile order (:func:`~repro.core.engine.project_order_batch`),
    so every scored configuration is deadlock-free and no per-candidate
    Python runs between proposal and scoring,
  * the last build re-scores the elite archive TOGETHER WITH the heuristic
    seeds at exact tolerance and takes the argmin — the result is never
    worse than any seed *by construction*, not by luck.

:func:`bind_optimized` adapts the optimizer to the
:data:`~repro.core.explore.BINDERS` registry signature so sweeps and the
admission controller pick it up as a fourth strategy (``"optimized"``).

The scoring path is the batched chip-objective layer: every generation's
single :func:`~repro.core.engine.batch_execute` call returns per-candidate
(period, chip energy, NoC traffic) from the same stacked arrays
(``with_energy=True`` — the accumulators ride the EdgeStack build's own
hop pass).  ``objective`` selects what the search optimizes:

  * ``"period"`` — the PR-3 behaviour, elites ranked by period;
  * ``"energy"`` — elites ranked by chip energy (pJ/iteration);
  * ``"pareto"`` — the breeding trajectory stays period-ranked (bit-for-bit
    the ``"period"`` trajectory, same rng stream), while an epsilon-Pareto
    archive additionally collects every generation's non-dominated
    (period, energy) rows.  Because the final exact re-score pool is then a
    SUPERSET of the ``"period"`` pool, the reported best period can only be
    equal or better at equal budget — the never-worse-on-period invariant
    holds by construction, and the exact Pareto front comes for free.

The search core, :func:`optimize_binding_graph`, is graph-level (any
:class:`~repro.core.sdfg.SDFG` + explicit seeds); the multi-app joint
placement in :mod:`repro.core.runtime` drives it with a disjoint-union
graph of all resident applications.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Optional, Sequence

import numpy as np

from .binding import (
    BindingResult,
    LoadWeights,
    bind_ours,
    bind_pycarl,
    bind_spinemap,
    lpt_assign,
)
from .engine import (
    batch_execute,
    batch_execute_fused,
    prepare_execution,
    project_order_batch,
)
from .hardware import ChipState, HardwareConfig
from .partition import ClusteredSNN
from .runtime import single_tile_order
from .sdfg import SDFG, sdfg_from_clusters

_SEED_BINDERS = {
    "ours": lambda c, hw, w: bind_ours(c, hw, weights=w),
    "pycarl": lambda c, hw, w: bind_pycarl(c, hw, weights=w),
    "spinemap": lambda c, hw, w: bind_spinemap(c, hw),
}


@dataclasses.dataclass(frozen=True)
class GenerationStat:
    """Progress of one optimizer generation.

    ``best_period``/``mean_period`` are steady-state iteration periods in
    the model's time unit (microseconds), ``best_energy``/``mean_energy``
    chip energies (pJ per iteration), all scored at the *search* tolerance
    (``score_rel_tol``); ``wall_s`` is the generation's wall-clock seconds
    (proposal + one batched scoring call).
    """

    generation: int
    best_period: float
    mean_period: float
    wall_s: float
    best_energy: float = float("nan")
    mean_energy: float = float("nan")


@dataclasses.dataclass(frozen=True)
class ParetoPoint:
    """One exact point of the (period, energy) Pareto front.

    ``binding`` is a (n_clusters,) int64 tile assignment; ``period`` its
    exact steady-state iteration period (microseconds) and ``energy`` its
    chip energy (pJ per iteration), both re-scored at ``final_rel_tol``.
    Fronts are sorted by ascending period (hence descending energy).
    """

    binding: np.ndarray
    period: float
    energy: float


@dataclasses.dataclass
class OptimizeReport:
    """Result of :func:`optimize_binding` / :func:`optimize_binding_graph`.

    ``binding`` is the best (n_clusters,) tile assignment found under
    ``objective`` — argmin period for ``"period"``/``"pareto"``, argmin
    chip energy for ``"energy"`` — with ``period`` (microseconds) and
    ``energy`` (pJ per iteration) its exact scores at ``final_rel_tol``.
    ``seed_periods``/``seed_energies`` hold the seeds' exact scores from
    the SAME final scoring batch, so the result is never worse than any
    seed on the objective metric by construction.  ``front`` is the exact
    (period, energy) Pareto front of the final scoring pool (non-empty
    for every objective; richest under ``"pareto"``, whose archive keeps
    each generation's epsilon-non-dominated rows).  ``history`` records
    per-generation progress; ``n_stack_builds`` counts EdgeStack builds
    (= generations + 1: one per generation plus the final exact
    re-score).
    """

    binding: np.ndarray                 # (n_clusters,) int64 tile ids
    period: float                       # microseconds
    seed_periods: dict[str, float]      # strategy -> exact period (us)
    history: list[GenerationStat]
    n_stack_builds: int
    opt_time_s: float
    population: int
    generations: int
    rng_seed: int
    objective: str = "period"
    energy: float = float("inf")        # pJ per iteration
    seed_energies: dict[str, float] = dataclasses.field(default_factory=dict)
    front: list[ParetoPoint] = dataclasses.field(default_factory=list)

    @property
    def throughput(self) -> float:
        """Iterations per microsecond (1 / period); 0.0 for a dead graph."""
        if self.period <= 0 or not np.isfinite(self.period):
            return 0.0
        return 1.0 / self.period

    @property
    def best_seed_period(self) -> float:
        """Exact period of the best heuristic seed (microseconds)."""
        return min(self.seed_periods.values())

    @property
    def best_seed_energy(self) -> float:
        """Exact chip energy of the most frugal seed (pJ per iteration)."""
        return min(self.seed_energies.values())

    @property
    def improvement(self) -> float:
        """Fractional period reduction vs the best heuristic seed.

        0.05 means the optimized binding's steady-state period is 5%
        shorter than the best of ours/pycarl/spinemap; >= 0 always for
        the ``"period"``/``"pareto"`` objectives.
        """
        best = self.best_seed_period
        if best <= 0 or not np.isfinite(best):
            return 0.0
        return (best - self.period) / best

    def as_binding_result(self) -> BindingResult:
        """Adapt to the :class:`~repro.core.binding.BindingResult` API."""
        return BindingResult(self.binding, self.opt_time_s, "optimized")


def _mutate(pop: np.ndarray, rng, tiles: np.ndarray, *, swaps: int, moves: int) -> None:
    """In-place vectorized mutation of a (B, n) binding population.

    ``swaps`` rounds of pairwise assignment swaps (two random clusters per
    row exchange tiles — preserves per-tile counts) and ``moves`` rounds of
    single-cluster moves (one random cluster per row to a random tile
    drawn from ``tiles``, the allowed physical tile ids).
    """
    b, n = pop.shape
    rows = np.arange(b)
    for _ in range(swaps):
        i = rng.integers(0, n, size=b)
        j = rng.integers(0, n, size=b)
        pi = pop[rows, i].copy()
        pop[rows, i] = pop[rows, j]
        pop[rows, j] = pi
    for _ in range(moves):
        k = rng.integers(0, n, size=b)
        t = tiles[rng.integers(0, tiles.size, size=b)]
        pop[rows, k] = t


def _tile_tau_sums(pop: np.ndarray, tau: np.ndarray, n_tiles: int) -> np.ndarray:
    """(B, n_tiles) per-row serialized compute time per tile.

    Each tile's TDMA order cycle forces its actors to fire once per
    iteration back-to-back, so the row's period is at least the row's max
    tile sum — the bottleneck the guided mutations attack.
    """
    b, n = pop.shape
    sums = np.zeros((b, n_tiles))
    np.add.at(
        sums,
        (np.repeat(np.arange(b), n), pop.ravel()),
        np.broadcast_to(tau, (b, n)).ravel(),
    )
    return sums


def _pick_on_tile(pop: np.ndarray, tiles: np.ndarray, rng) -> np.ndarray:
    """(B,) one uniformly-random cluster index per row among those bound to
    ``tiles[row]``.  An empty tile yields an arbitrary cluster — callers
    must mask those rows out before acting on the pick."""
    keys = rng.random(pop.shape) + (pop != tiles[:, None]) * 10.0
    return keys.argmin(axis=1)


def _guided_mutate(
    pop: np.ndarray, tau: np.ndarray, n_tiles: int, tiles: np.ndarray, rng
) -> None:
    """In-place bottleneck-directed mutation of a (B, n) population.

    Per row: find the heaviest allowed tile (max serialized compute, the
    order cycle that lower-bounds the period) and either MOVE a random
    cluster from it to the lightest allowed tile, or SWAP random clusters
    between the heaviest and lightest tiles — hill-climbing steps on the
    dominant term of the objective that blind swaps rarely sample at
    large n.  The swap branch is skipped for rows whose lightest tile is
    empty (there is nothing to swap back, and the pick would land on the
    bottleneck).  ``tiles`` restricts the heavy/light search to the
    allowed physical tile ids.
    """
    b, n = pop.shape
    rows = np.arange(b)
    sums = _tile_tau_sums(pop, tau, n_tiles)[:, tiles]
    heavy = tiles[sums.argmax(axis=1)]
    light = tiles[sums.argmin(axis=1)]
    a = _pick_on_tile(pop, heavy, rng)
    do_swap = rng.random(b) < 0.5
    do_swap &= (pop == light[:, None]).any(axis=1)
    c = _pick_on_tile(pop, light, rng)
    pop[rows, a] = light
    swap_rows = rows[do_swap]
    pop[swap_rows, c[do_swap]] = heavy[do_swap]




def _comm_guided_mutate(
    pop: np.ndarray,
    ch_src: np.ndarray,
    ch_dst: np.ndarray,
    ch_rate: np.ndarray,
    hw: HardwareConfig,
    rng,
) -> None:
    """In-place comm-critical-path mutation of a (B, n) binding population.

    Per row: find the heaviest *cut* channel — spike rate x current NoC hop
    count, the dominant term of the Eq.-3 comm delay — and co-locate its
    endpoints by moving one endpoint's cluster onto the other endpoint's
    tile (direction chosen at random; the target tile already hosts a
    cluster of the row, so allowed-tile subsets are preserved).  This is
    the NoC-bound counterpart of :func:`_guided_mutate`: where that one
    attacks the serialized-compute order cycle, this one attacks the
    longest communication dependency.  Rows with every channel co-located
    are left untouched.
    """
    if ch_src.size == 0:
        return
    b = pop.shape[0]
    rows = np.arange(b)
    hops = hw.hops_array(pop[:, ch_src], pop[:, ch_dst])
    w = ch_rate[None, :] * hops
    j = w.argmax(axis=1)
    has = w[rows, j] > 0
    to_src = rng.random(b) < 0.5
    movers = np.where(to_src, ch_dst[j], ch_src[j])
    targets = pop[rows, np.where(to_src, ch_src[j], ch_dst[j])]
    pop[rows[has], movers[has]] = targets[has]


def _dedup_rows(rows: np.ndarray) -> np.ndarray:
    """Unique rows of a (B, n) int matrix, first occurrence kept, in order."""
    seen: set[bytes] = set()
    keep = []
    for r, row in enumerate(rows):
        key = row.tobytes()
        if key not in seen:
            seen.add(key)
            keep.append(r)
    return rows[np.asarray(keep)]


def _epsilon_front(
    periods: np.ndarray, energies: np.ndarray, eps: float = 1e-3
) -> np.ndarray:
    """Indices of the epsilon-non-dominated (period, energy) rows.

    Rows sorted by ascending (period, energy) are swept keeping those
    whose energy improves the running best by more than a relative
    ``eps`` (``eps=0`` gives the exact front: strictly lower energy at
    higher-or-equal period; the energy tiebreak ensures a period tie
    keeps only its minimum-energy row).  Dead rows (non-finite period or
    energy) never qualify.  Returns row indices in ascending-period
    order; epsilon thinning bounds the archive the pareto objective
    accumulates across generations.
    """
    periods = np.asarray(periods, dtype=np.float64)
    energies = np.asarray(energies, dtype=np.float64)
    keep: list[int] = []
    best_e = np.inf
    for i in np.lexsort((energies, periods)):
        p, e = periods[i], energies[i]
        if not (np.isfinite(p) and p > 0 and np.isfinite(e)):
            continue
        if not keep or e < best_e * (1.0 - eps):
            keep.append(int(i))
            best_e = e
    return np.asarray(keep, dtype=np.int64)


_OBJECTIVES = ("period", "energy", "pareto")


class _BindingSearch:
    """Stepwise engine of :func:`optimize_binding_graph` (ask/tell form).

    Holds the whole evolutionary state — population, elite archive, rng
    stream, history — and exposes it one *scoring request* at a time:
    :meth:`ask` returns the next (pop, rel_tol) batch to score,
    :meth:`tell` consumes the scores and breeds the next generation (or
    finalizes).  Driven by :func:`optimize_binding_graph` one search at a
    time, or by :func:`optimize_binding_graphs_fused` with MANY searches
    in lockstep so each tick's scoring requests fuse into a single
    analysis call.  The rng draw order and scoring batch contents are
    bit-for-bit those of the original inline loop, so a single-search
    drive reproduces :func:`optimize_binding_graph` exactly.
    """

    def __init__(
        self,
        app: SDFG,
        hw: HardwareConfig,
        single_order: Sequence[int],
        *,
        seed_bindings: dict[str, np.ndarray],
        channel_src: Optional[np.ndarray] = None,
        channel_dst: Optional[np.ndarray] = None,
        channel_rate: Optional[np.ndarray] = None,
        population: int = 64,
        generations: int = 8,
        elite: int = 8,
        rng_seed: int = 0,
        allowed_tiles: Optional[Sequence[int]] = None,
        objective: str = "period",
        period_floor: float = float("-inf"),
        score_rel_tol: float = 1e-4,
        final_rel_tol: float = 1e-8,
        chip_state: Optional[ChipState] = None,
        rate_scale=None,
    ):
        _validate_budget(population, generations, objective)
        self.app, self.hw = app, hw
        self.population, self.generations = population, generations
        self.elite = min(max(1, elite), population)
        self.rng_seed, self.objective = rng_seed, objective
        self.period_floor = period_floor
        self.score_rel_tol, self.final_rel_tol = score_rel_tol, final_rel_tol
        self.chip_state, self.rate_scale = chip_state, rate_scale
        n, n_tiles = app.n_actors, hw.n_tiles
        self.tiles = tiles = (
            np.arange(n_tiles, dtype=np.int64) if allowed_tiles is None
            else np.asarray(sorted(allowed_tiles), dtype=np.int64)
        )
        assert tiles.size >= 1 and tiles.min() >= 0 and tiles.max() < n_tiles, (
            f"allowed_tiles must be distinct ids in [0, {n_tiles}), got {tiles}"
        )
        assert seed_bindings, "need at least one seed binding"
        self.t0 = time.perf_counter()
        self.rng = rng = np.random.default_rng(rng_seed)
        self.single_order = list(single_order)
        self.ch_src = np.asarray(
            channel_src if channel_src is not None else [], dtype=np.int64
        )
        self.ch_dst = np.asarray(
            channel_dst if channel_dst is not None else [], dtype=np.int64
        )
        self.ch_rate = np.asarray(
            channel_rate if channel_rate is not None else [], dtype=np.float64
        )
        self.seed_bindings = seed_bindings
        for name, b in seed_bindings.items():
            assert np.isin(b, tiles).all(), (
                f"seed {name!r} uses tiles outside the allowed set"
            )
        self.seed_mat = seed_mat = np.stack(
            [np.asarray(b, dtype=np.int64) for b in seed_bindings.values()]
        )

        # -- generation 0: seeds + LPT start + mutated seeds + immigrants
        # tau-LPT balances serialized compute directly — a strong start
        # the Eq.-7 binders don't produce (their load mixes buffer/
        # bandwidth terms)
        tau_lpt = tiles[lpt_assign(app.exec_time, int(tiles.size))]
        starts = _dedup_rows(np.concatenate([seed_mat, tau_lpt[None, :]]))
        pop = np.empty((population, n), dtype=np.int64)
        n_start = min(starts.shape[0], population)
        pop[:n_start] = starts[:n_start]
        n_rand = max(0, (population - n_start) // 8)
        fill = population - n_start - n_rand
        if fill > 0:
            children = starts[
                rng.integers(0, starts.shape[0], size=fill)
            ].copy()
            half = fill // 2
            if half:
                blk = children[:half]
                _guided_mutate(blk, app.exec_time, n_tiles, tiles, rng)
                children[:half] = blk
            blk = children[half:]
            _mutate(blk, rng, tiles, swaps=1, moves=1)
            children[half:] = blk
            pop[n_start : n_start + fill] = children
        if n_rand > 0:
            pop[population - n_rand :] = tiles[
                rng.integers(0, tiles.size, size=(n_rand, n))
            ]
        self.pop = pop

        self.history: list[GenerationStat] = []
        # best-ever rows; re-ranked exactly at the end
        self.archive = seed_mat.copy()
        self.n_builds = 0
        self.gen = 0
        self.final_pool: Optional[np.ndarray] = None
        self._report: Optional[OptimizeReport] = None
        self._t_gen = 0.0

    @property
    def done(self) -> bool:
        """True once :meth:`report` is available."""
        return self._report is not None

    def ask(self) -> tuple[np.ndarray, float]:
        """The next binding batch to score and its period tolerance."""
        assert not self.done, "search already finalized"
        if self.final_pool is not None:
            return self.final_pool, self.final_rel_tol
        self._t_gen = time.perf_counter()
        return self.pop, self.score_rel_tol

    def tell(self, periods: np.ndarray, energies: np.ndarray) -> None:
        """Consume the scores of the last :meth:`ask` batch."""
        assert not self.done, "search already finalized"
        self.n_builds += 1
        if self.final_pool is not None:
            self._finalize(periods, energies)
            return
        pop, rng, elite = self.pop, self.rng, self.elite
        population, n = self.population, self.app.n_actors
        # breeding elites: ranked by energy for the energy objective,
        # by period otherwise — the pareto trajectory is bit-for-bit the
        # period trajectory (same elites, same rng stream); what differs
        # is the archive below.  A finite period_floor clamps the ranking
        # key (chip-wide, sub-floor periods are equivalent); the -inf
        # default leaves the ranking bit-for-bit unchanged.
        key = (
            energies if self.objective == "energy"
            else np.maximum(periods, self.period_floor)
        )
        rank = np.argsort(key, kind="stable")
        elites = pop[rank[:elite]]

        # fold this generation's elites into the best-ever archive; the
        # pareto objective additionally keeps the epsilon-non-dominated
        # rows, so minimum-energy and knee candidates survive into the
        # final exact re-score alongside the period-only elites
        self.archive = _dedup_rows(np.concatenate([self.archive, elites]))
        if self.objective == "pareto":
            front_rows = pop[_epsilon_front(periods, energies)]
            self.archive = _dedup_rows(
                np.concatenate([self.archive, front_rows])
            )
        finite_p = np.isfinite(periods)
        finite_e = np.isfinite(energies)
        self.history.append(GenerationStat(
            generation=self.gen,
            best_period=float(periods.min()),
            mean_period=float(np.mean(periods[finite_p])) if finite_p.any()
            else float("inf"),
            wall_s=time.perf_counter() - self._t_gen,
            best_energy=float(energies.min()),
            mean_energy=float(np.mean(energies[finite_e])) if finite_e.any()
            else float("inf"),
        ))

        if self.gen == self.generations - 1:
            # -- final exact re-score pool: archive U seeds ------------
            self.final_pool = _dedup_rows(
                np.concatenate([self.seed_mat, self.archive])
            )
            return
        # -- next generation: elitism + crossover + guided/comm/blind
        nxt = np.empty_like(pop)
        nxt[:elite] = elites
        n_children = population - elite
        pa = elites[rng.integers(0, elite, size=n_children)]
        pb = elites[rng.integers(0, elite, size=n_children)]
        cross = rng.random((n_children, n)) < 0.5
        children = np.where(cross, pa, pb)
        # children split three ways: climb the bottleneck tile (guided
        # compute), co-locate the heaviest cut channel (guided comm — the
        # NoC-bound operating points AND the dominant chip-energy term),
        # or explore blindly; a heavy-mutation slice keeps diversity up
        u = rng.random(n_children)
        guided = u < 0.4
        comm = (u >= 0.4) & (u < 0.6)
        if guided.any():
            block = children[guided]
            _guided_mutate(
                block, self.app.exec_time, self.hw.n_tiles, self.tiles, rng
            )
            children[guided] = block
        if comm.any():
            block = children[comm]
            _comm_guided_mutate(
                block, self.ch_src, self.ch_dst, self.ch_rate, self.hw, rng
            )
            children[comm] = block
        blind = u >= 0.6
        if blind.any():
            block = children[blind]
            _mutate(block, rng, self.tiles, swaps=1, moves=1)
            children[blind] = block
        heavy = rng.random(n_children) < 0.2
        if heavy.any():
            block = children[heavy]
            _mutate(block, rng, self.tiles, swaps=2, moves=2)
            children[heavy] = block
        nxt[elite:] = children
        self.pop = nxt
        self.gen += 1

    def _finalize(
        self, final_periods: np.ndarray, final_energies: np.ndarray
    ) -> None:
        final_pool = self.final_pool
        if self.objective == "energy":
            best_row = int(np.argmin(final_energies))
        elif np.isfinite(self.period_floor):
            # chip-wide ranking: clamp at the rest-of-chip floor, break
            # the (common) floor ties toward lower chip energy, then
            # pool order
            clamped = np.maximum(final_periods, self.period_floor)
            best_row = int(np.lexsort((final_energies, clamped))[0])
        else:
            best_row = int(np.argmin(final_periods))
        front = [
            ParetoPoint(
                binding=final_pool[i].copy(),
                period=float(final_periods[i]),
                energy=float(final_energies[i]),
            )
            for i in _epsilon_front(final_periods, final_energies, eps=0.0)
        ]

        # seed scores from the same exact batch (rows 0..n_seeds-1 of
        # the deduped pool ARE the seeds, first occurrence kept)
        seed_periods: dict[str, float] = {}
        seed_energies: dict[str, float] = {}
        pool_index = {row.tobytes(): r for r, row in enumerate(final_pool)}
        for name, b in self.seed_bindings.items():
            r = pool_index[np.asarray(b, dtype=np.int64).tobytes()]
            seed_periods[name] = float(final_periods[r])
            seed_energies[name] = float(final_energies[r])

        self._report = OptimizeReport(
            binding=final_pool[best_row].copy(),
            period=float(final_periods[best_row]),
            seed_periods=seed_periods,
            history=self.history,
            n_stack_builds=self.n_builds,
            opt_time_s=time.perf_counter() - self.t0,
            population=self.population,
            generations=self.generations,
            rng_seed=self.rng_seed,
            objective=self.objective,
            energy=float(final_energies[best_row]),
            seed_energies=seed_energies,
            front=front,
        )

    def report(self) -> OptimizeReport:
        """The finished search's report (only valid once :attr:`done`)."""
        assert self._report is not None, "search not finished"
        return self._report


def _validate_budget(population: int, generations: int, objective: str) -> None:
    """Raise ValueError on an unusable search budget or unknown objective."""
    if population < 2 or generations < 1:
        raise ValueError(
            f"optimize budget must be >= 1 generation of >= 2 candidates, "
            f"got generations={generations}, population={population}"
        )
    if objective not in _OBJECTIVES:
        raise ValueError(
            f"unknown objective {objective!r}; have {_OBJECTIVES}"
        )


def optimize_binding_graph(
    app: SDFG,
    hw: HardwareConfig,
    single_order: Sequence[int],
    *,
    seed_bindings: dict[str, np.ndarray],
    channel_src: Optional[np.ndarray] = None,
    channel_dst: Optional[np.ndarray] = None,
    channel_rate: Optional[np.ndarray] = None,
    population: int = 64,
    generations: int = 8,
    elite: int = 8,
    rng_seed: int = 0,
    allowed_tiles: Optional[Sequence[int]] = None,
    objective: str = "period",
    period_floor: float = float("-inf"),
    score_rel_tol: float = 1e-4,
    final_rel_tol: float = 1e-8,
    backend: str = "auto",
    chip_state: Optional[ChipState] = None,
    rate_scale=None,
    mesh=None,
) -> OptimizeReport:
    """Graph-level search core: optimize actor-to-tile bindings of ``app``.

    The engine room of :func:`optimize_binding`, factored out so the
    multi-app joint placement (:mod:`repro.core.runtime`) can drive the
    same search over a disjoint-union graph: any live
    :class:`~repro.core.sdfg.SDFG` plus an explicit ``seed_bindings`` dict
    (name -> (n_actors,) physical tile ids, all inside ``allowed_tiles``)
    and a design-time ``single_order`` (total actor firing order, Lemma-1
    projected per candidate).  ``channel_src``/``channel_dst``/
    ``channel_rate`` are the spike-traffic arrays the comm-guided mutation
    attacks (omit for graphs without them — the mutation then no-ops).

    Each generation proposes a (``population``, n_actors) binding matrix
    and ranks it with ONE :func:`~repro.core.engine.batch_execute` call
    (``with_energy=True`` — periods and chip energies from the same
    stacked arrays); after ``generations`` rounds, the archive plus all
    seeds are re-scored once at ``final_rel_tol``.  ``objective`` picks
    the ranking metric (see the module docstring): ``"pareto"`` keeps the
    period-ranked trajectory (identical evaluations to ``"period"`` under
    one ``rng_seed``) and additionally archives every generation's
    epsilon-non-dominated (period, energy) rows, so its final pool is a
    superset — never worse on period at equal budget, with the exact
    front reported for free.  The result is never worse than any seed on
    the objective metric by construction.  Deterministic for a fixed
    ``rng_seed``; ``elite`` is clamped to the population size.

    ``period_floor`` is the region-scoped placement's cheap stand-in for
    the rest of the chip: when this graph is a sub-union of the resident
    apps, the chip period is ``max(region period, rest-of-chip period)``,
    so candidates are *ranked* (and the final argmin taken) on
    ``max(period, period_floor)`` — pushing the region below the floor
    buys nothing chip-wide, and floor-ties break toward lower chip
    energy.  The reported ``period``/``seed_periods`` stay the exact
    unclamped sub-union periods.  The default ``-inf`` floor is a no-op
    (bit-for-bit the unclamped ranking).

    ``chip_state``/``rate_scale`` score every candidate under the chip's
    run-time degradation (throttled routes, drifted spike rates — see
    :func:`~repro.core.engine.stack_hardware_aware`); candidates binding a
    dead tile score ``inf`` and lose naturally, but callers searching a
    degraded chip should pass alive-only ``allowed_tiles`` (and repaired
    seeds) so the search budget is not wasted on infeasible rows.

    ``mesh`` shards every generation's population scoring across the mesh
    devices (:func:`~repro.core.engine.batch_execute` ``mesh=`` path):
    each device solves a contiguous population slice with the exact
    ``"csr-jit"`` backend and the elite archive merges host-side.  The
    per-row lambda-search is row-local, so the whole search trajectory —
    every generation's scores, every archive update, the final pick — is
    bit-identical to the single-device run at the same ``rng_seed``.
    """
    search = _BindingSearch(
        app, hw, single_order,
        seed_bindings=seed_bindings,
        channel_src=channel_src, channel_dst=channel_dst,
        channel_rate=channel_rate,
        population=population, generations=generations, elite=elite,
        rng_seed=rng_seed, allowed_tiles=allowed_tiles,
        objective=objective, period_floor=period_floor,
        score_rel_tol=score_rel_tol, final_rel_tol=final_rel_tol,
        chip_state=chip_state, rate_scale=rate_scale,
    )
    while not search.done:
        # one vectorized Lemma-1 projection for the whole population: the
        # engine consumes the OrderBatch directly, so no per-candidate
        # Python runs between proposal and scoring (and the stacked shape
        # is generation-invariant — every scoring call is a compile-cache
        # hit after the first).  Energies ride the same stack build.
        pop, rel_tol = search.ask()
        orders = project_order_batch(single_order, pop)
        rep = batch_execute(
            app, pop, hw, orders, backend=backend, rel_tol=rel_tol,
            with_energy=True, chip_state=chip_state, rate_scale=rate_scale,
            mesh=mesh,
        )
        search.tell(*_alive_scores(rep))
    return search.report()


def _alive_scores(rep) -> tuple[np.ndarray, np.ndarray]:
    """Mask dead/acyclic rows (cannot happen for live apps, but stay safe)."""
    alive = np.isfinite(rep.periods) & (rep.periods > 0)
    return (
        np.where(alive, rep.periods, np.inf),
        np.where(alive, rep.energies, np.inf),
    )


def optimize_binding_graphs_fused(
    tasks: Sequence[dict],
    *,
    backend: str = "auto",
    mesh=None,
) -> list[OptimizeReport]:
    """Run MANY independent binding searches with FUSED scoring.

    ``tasks`` is a sequence of keyword dicts, each exactly the signature
    of :func:`optimize_binding_graph` minus ``backend`` (positional
    ``app``/``hw``/``single_order`` under those keys).  The searches run
    their generations in lockstep: every tick gathers one scoring batch
    per unfinished search, builds each batch's EdgeStack independently
    (:func:`~repro.core.engine.prepare_execution`), and solves them all
    in ONE fused :func:`~repro.core.engine.batch_execute_fused` call —
    device dispatch and compile-cache entry are paid once per tick
    instead of once per region component per generation.  Each search's
    rng stream, scoring batches, and ranking are bit-for-bit those of
    its standalone :func:`optimize_binding_graph` run; only the analysis
    tolerance can be TIGHTER (the fused solve takes the min over its
    members).  Requests are fused per (tick, tolerance) group — mixing
    tolerances would solve some members TIGHTER than their standalone
    run and could reorder near-tie elites, breaking reproducibility —
    so a tick where every search is in the same phase (the common case:
    equal generation counts) is exactly one call.  Reports come back in
    task order.  ``mesh`` shards each fused solve's batch axis over the
    mesh devices (bit-identical — see :func:`optimize_binding_graph`).
    """
    searches = [
        _BindingSearch(
            t["app"], t["hw"], t["single_order"],
            **{
                k: v for k, v in t.items()
                if k not in ("app", "hw", "single_order")
            },
        )
        for t in tasks
    ]
    while True:
        live = [s for s in searches if not s.done]
        if not live:
            break
        groups: dict[float, tuple[list[_BindingSearch], list]] = {}
        for s in live:
            pop, rel_tol = s.ask()
            orders = project_order_batch(s.single_order, pop)
            prep = prepare_execution(
                s.app, pop, s.hw, orders, rel_tol=rel_tol,
                with_energy=True, chip_state=s.chip_state,
                rate_scale=s.rate_scale,
            )
            groups.setdefault(rel_tol, ([], []))
            groups[rel_tol][0].append(s)
            groups[rel_tol][1].append(prep)
        for rel_tol, (members, preps) in groups.items():
            reports = batch_execute_fused(preps, backend=backend, mesh=mesh)
            for s, rep in zip(members, reports):
                s.tell(*_alive_scores(rep))
    return [s.report() for s in searches]


def optimize_binding(
    clustered: ClusteredSNN,
    hw: HardwareConfig,
    *,
    single_order: Optional[Sequence[int]] = None,
    population: int = 64,
    generations: int = 8,
    elite: int = 8,
    rng_seed: int = 0,
    weights: LoadWeights = LoadWeights(),
    seeds: Sequence[str] = ("ours", "pycarl", "spinemap"),
    extra_seeds: Optional[Sequence[np.ndarray]] = None,
    allowed_tiles: Optional[Sequence[int]] = None,
    objective: str = "period",
    score_rel_tol: float = 1e-4,
    final_rel_tol: float = 1e-8,
    backend: str = "auto",
    chip_state: Optional[ChipState] = None,
    rate_scale=None,
) -> OptimizeReport:
    """Search cluster-to-tile bindings with the exact batched chip
    objective in the loop (the §4.2 decision driven by the §4.4 analysis
    itself).

    Each generation proposes a (``population``, n_clusters) binding matrix
    — heuristic seeds, elites, crossover children, vectorized swap/move
    mutants — projects the design-time ``single_order`` per candidate
    (Lemma 1, deadlock-free) and ranks the WHOLE population with one
    :func:`~repro.core.engine.batch_execute` call returning per-candidate
    (period, chip energy, NoC traffic).  After ``generations`` rounds the
    elite archive plus all heuristic seeds are re-scored once at
    ``final_rel_tol`` and the argmin on the objective metric wins, which
    guarantees the result is never worse than any seed.

    ``objective`` is ``"period"`` (default — minimize the steady-state
    iteration period), ``"energy"`` (minimize chip energy per iteration,
    pJ) or ``"pareto"`` (period-driven search whose archive keeps the
    epsilon-non-dominated (period, energy) rows: never worse on period
    than ``objective="period"`` at equal budget by construction, and
    ``report.front`` carries the exact Pareto front).

    ``generations`` x ``population`` is the quality/latency budget knob
    (also surfaced by :func:`~repro.core.runtime.runtime_admit` as
    ``optimize_budget``).  ``score_rel_tol`` is the looser intra-search
    ranking tolerance; periods in the report are exact to
    ``final_rel_tol``.  Deterministic for a fixed ``rng_seed``.

    ``single_order`` (total actor firing order from the 1-tile design-time
    schedule) is computed on demand when not supplied; pass it when the
    caller (admission, benchmarks) already has it cached.

    ``allowed_tiles`` restricts every candidate to a subset of physical
    tile ids (run-time admission on the free tiles): heuristic seeds are
    bound on a virtual |subset|-tile chip and relabeled onto the subset,
    while *scoring and search* use the real physical tile positions — the
    NoC distances of the actual subset, not the virtual adjacency.
    ``extra_seeds`` must already use allowed tile ids.

    ``elite`` is clamped to the population size, so small admission-time
    budgets like ``(2, 4)`` are valid without tuning it.
    """
    _validate_budget(population, generations, objective)
    n_tiles = hw.n_tiles
    tiles = (
        np.arange(n_tiles, dtype=np.int64) if allowed_tiles is None
        else np.asarray(sorted(allowed_tiles), dtype=np.int64)
    )
    t0 = time.perf_counter()
    app = sdfg_from_clusters(clustered, hw=hw)
    if single_order is None:
        single_order, _ = single_tile_order(clustered, hw)

    # -- heuristic seeds (always part of the final comparison); bound on
    # a virtual |tiles|-tile chip, relabeled onto the physical subset ---
    seed_hw = dataclasses.replace(hw, n_tiles=int(tiles.size))
    seed_bindings: dict[str, np.ndarray] = {}
    for name in seeds:
        virt = _SEED_BINDERS[name](clustered, seed_hw, weights).binding
        seed_bindings[name] = tiles[np.asarray(virt, dtype=np.int64)]
    for k, b in enumerate(extra_seeds or []):
        b = np.asarray(b, dtype=np.int64)
        assert np.isin(b, tiles).all(), (
            f"extra seed {k} uses tiles outside the allowed set"
        )
        seed_bindings[f"extra{k}"] = b

    rep = optimize_binding_graph(
        app, hw, single_order,
        seed_bindings=seed_bindings,
        channel_src=clustered.channel_src,
        channel_dst=clustered.channel_dst,
        channel_rate=clustered.channel_rate,
        population=population,
        generations=generations,
        elite=elite,
        rng_seed=rng_seed,
        allowed_tiles=allowed_tiles,
        objective=objective,
        score_rel_tol=score_rel_tol,
        final_rel_tol=final_rel_tol,
        backend=backend,
        chip_state=chip_state,
        rate_scale=rate_scale,
    )
    rep.opt_time_s = time.perf_counter() - t0   # include seed-binder time
    return rep


def bind_optimized(
    c: ClusteredSNN,
    hw: HardwareConfig,
    *,
    weights: LoadWeights = LoadWeights(),
    population: int = 64,
    generations: int = 8,
    rng_seed: int = 0,
    **kwargs,
) -> BindingResult:
    """Throughput-optimized binding, as a drop-in §4.2 strategy.

    Adapter for the :data:`~repro.core.explore.BINDERS` registry (strategy
    name ``"optimized"``): same ``(clustered, hw) -> BindingResult``
    signature as ``bind_ours``/``bind_pycarl``/``bind_spinemap``, so
    :func:`~repro.core.explore.sweep` and the admission controller treat
    it like any other binder.  Extra ``kwargs`` forward to
    :func:`optimize_binding` (budget, tolerance, seeds).
    """
    rep = optimize_binding(
        c, hw, weights=weights, population=population,
        generations=generations, rng_seed=rng_seed, **kwargs,
    )
    return rep.as_binding_result()

"""Cluster-to-tile binding strategies (paper §4.2, §6.3).

Three strategies are evaluated, mirroring the paper:

  * :func:`bind_ours`     — Eq. 7 weighted load + std-dev-reducing pairwise
    swaps (the paper's proposed load balancer).
  * :func:`bind_pycarl`   — PyCARL [5]: balance tile load greedily (largest
    load first onto least-loaded tile); random execution order downstream.
  * :func:`bind_spinemap` — SpiNeMap [8]: minimize inter-tile spike traffic
    with Kernighan-Lin-style swaps; ignores load balance.
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np

from .hardware import HardwareConfig
from .partition import ClusteredSNN


@dataclasses.dataclass(frozen=True)
class LoadWeights:
    """User constants (a, b, c, d) of Eq. 7."""

    crossbar: float = 1.0
    buffer: float = 1.0
    connection: float = 1.0
    bandwidth: float = 1.0


@dataclasses.dataclass
class BindingResult:
    binding: np.ndarray          # (n_clusters,) tile id
    bind_time_s: float
    strategy: str

    def clusters_per_tile(self, n_tiles: int) -> np.ndarray:
        return np.bincount(self.binding, minlength=n_tiles)


def _cluster_loads(c: ClusteredSNN, w: LoadWeights, hw: HardwareConfig) -> np.ndarray:
    """Scalar Eq.-7 load per cluster (normalized per-resource)."""
    xbar = hw.tile.crossbar
    conn = c.channel_degree().astype(np.float64)
    return (
        w.crossbar * (c.inputs_used + c.neurons_used) / (xbar.inputs + xbar.outputs)
        + w.buffer * c.out_spikes / hw.tile.output_buffer
        + w.connection * conn / max(conn.max(initial=1.0), 1.0)
        + w.bandwidth
        * (c.in_spikes + c.out_spikes)
        / max((c.in_spikes + c.out_spikes).max(initial=1.0), 1.0)
    )


def bind_ours(
    c: ClusteredSNN,
    hw: HardwareConfig,
    *,
    weights: LoadWeights = LoadWeights(),
    max_pass: int = 4,
    rng_seed: int = 0,
) -> BindingResult:
    """Eq. 7 load balancing with std-dev-reducing pairwise swaps.

    Swapping clusters i (tile ti, load li) and j (tile tj, load lj) changes
    the sum of squared tile loads by ``2 (lj - li) (a_i - a_j)`` where
    ``a_x = tile_load[t_x] - l_x`` is the residual load of x's tile — so
    one (n, n) outer-product evaluates every candidate swap at once.  Each
    round applies a greedy batch of improving swaps (deltas re-validated
    against the live tile loads before each application, preserving the
    sequential-sweep semantics); ``max_pass`` bounds the rounds.  For very
    large n the full matrix is replaced by a random pair sample, matching
    the old sampled-sweep bound.
    """
    t0 = time.perf_counter()
    loads = _cluster_loads(c, weights, hw)
    n_tiles = hw.n_tiles

    # even initial distribution (round-robin over load-sorted clusters)
    order = np.argsort(loads)[::-1]
    binding = np.empty(c.n_clusters, dtype=np.int64)
    binding[order] = np.arange(c.n_clusters) % n_tiles

    tile_load = np.bincount(binding, weights=loads, minlength=n_tiles)

    rng = np.random.default_rng(rng_seed)
    n = c.n_clusters
    for _ in range(max_pass):
        std = tile_load.std()
        resid = tile_load[binding] - loads          # (n,) a_x
        if n * n <= 4_000_000:
            delta = 2.0 * (loads[None, :] - loads[:, None]) * (
                resid[:, None] - resid[None, :]
            )
            delta[binding[:, None] == binding[None, :]] = 0.0
            delta = np.triu(delta, k=1)             # (i, j) once, i < j
            flat = delta.ravel()
            cand = np.flatnonzero(flat < -1e-12)
            if cand.size > 4 * n:                   # best 4n swaps per round
                cand = cand[np.argpartition(flat[cand], 4 * n)[: 4 * n]]
            cand = cand[np.argsort(flat[cand], kind="stable")]
            pairs = np.stack([cand // n, cand % n], axis=1)
        else:                                       # sampled-sweep bound
            idx = rng.integers(0, n, size=(250_000, 2))
            delta = 2.0 * (loads[idx[:, 1]] - loads[idx[:, 0]]) * (
                resid[idx[:, 0]] - resid[idx[:, 1]]
            )
            delta[binding[idx[:, 0]] == binding[idx[:, 1]]] = 0.0
            cand = np.flatnonzero(delta < -1e-12)
            pairs = idx[cand[np.argsort(delta[cand], kind="stable")]]
        improved = False
        for i, j in pairs:
            ti, tj = binding[i], binding[j]
            if ti == tj:
                continue
            li, lj = loads[i], loads[j]
            # re-validate against the live tile loads (stale deltas skip)
            if (lj - li) * (tile_load[ti] - li - tile_load[tj] + lj) < -1e-12:
                tile_load[ti] += lj - li
                tile_load[tj] += li - lj
                binding[i], binding[j] = tj, ti
                improved = True
        if not improved or std - tile_load.std() < 1e-12:
            break
    return BindingResult(binding, time.perf_counter() - t0, "ours")


def lpt_assign(loads: np.ndarray, n_tiles: int) -> np.ndarray:
    """Longest-processing-time greedy: heaviest load onto the least-loaded
    tile.  ``loads`` is (n,) per-cluster load (any unit); returns (n,)
    int64 tile ids.  Shared by :func:`bind_pycarl` (Eq.-7 loads) and the
    optimizer's tau-balanced start."""
    binding = np.empty(loads.size, dtype=np.int64)
    tile_load = np.zeros(n_tiles)
    for i in np.argsort(loads, kind="stable")[::-1]:
        t = int(np.argmin(tile_load))
        binding[i] = t
        tile_load[t] += loads[i]
    return binding


def bind_pycarl(
    c: ClusteredSNN,
    hw: HardwareConfig,
    *,
    weights: LoadWeights = LoadWeights(),
) -> BindingResult:
    """PyCARL: greedy load balance (LPT), random order downstream."""
    t0 = time.perf_counter()
    binding = lpt_assign(_cluster_loads(c, weights, hw), hw.n_tiles)
    return BindingResult(binding, time.perf_counter() - t0, "pycarl")


def bind_spinemap(
    c: ClusteredSNN,
    hw: HardwareConfig,
    *,
    max_pass: int = 4,
    rng_seed: int = 0,
    balance_factor: float = 1.5,
) -> BindingResult:
    """SpiNeMap: minimize inter-tile spikes (KL-style single moves).

    The affinity matrix ``W[x, t]`` (spike traffic between cluster x and
    the clusters currently bound to tile t, shape (n_clusters, n_tiles))
    makes every move gain a row lookup: moving x from its own tile to t
    changes the cut by ``W[x, own] - W[x, t]``.  W is built once per
    binding (one scatter-add over the channel arrays) and updated
    incrementally per accepted move (O(degree) scatter on x's neighbors),
    replacing the per-cluster O(E) channel scans — the sequential KL
    semantics are unchanged.

    Balance cap: a move onto tile t is admitted only while t's accumulated
    Eq.-7 *load* stays within ``balance_factor`` x the mean tile load
    (the previous cap bounded cluster *counts*, which let a few heavy
    clusters pile onto one tile).
    """
    t0 = time.perf_counter()
    n, n_tiles = c.n_clusters, hw.n_tiles
    rng = np.random.default_rng(rng_seed)

    # adjacency (symmetric spike traffic between cluster pairs)
    src, dst, spk = c.channel_src, c.channel_dst, c.channel_rate

    # seed: contiguous ranges (clusters are index-ordered along layers, so
    # this already groups communicating clusters together)
    binding = (np.arange(n) * n_tiles // max(n, 1)).astype(np.int64)

    # symmetric neighbor lists (both channel directions), CSR by cluster
    nbr_of = np.concatenate([src, dst])
    nbrs = np.concatenate([dst, src])
    wts = np.concatenate([spk, spk])
    order = np.argsort(nbr_of, kind="stable")
    nbr_of, nbrs, wts = nbr_of[order], nbrs[order], wts[order]
    starts = np.searchsorted(nbr_of, np.arange(n), side="left")
    ends = np.searchsorted(nbr_of, np.arange(n), side="right")

    # W[x, t] = spike traffic between x and tile t under `binding`
    aff = np.zeros((n, n_tiles))
    np.add.at(aff, (nbr_of, binding[nbrs]), wts)

    loads = _cluster_loads(c, LoadWeights(), hw)
    tile_load = np.bincount(binding, weights=loads, minlength=n_tiles)
    cap = balance_factor * loads.sum() / n_tiles   # Eq.-7 load cap
    for _ in range(max_pass):
        improved = False
        for x in rng.permutation(n)[: min(n, 2000)]:
            own = int(binding[x])
            gains = aff[x] - aff[x, own]           # cut reduction per tile
            gains[own] = 0.0
            t = int(np.argmax(gains))
            if gains[t] > 1e-9 and tile_load[t] + loads[x] <= cap:
                e = slice(starts[x], ends[x])
                np.add.at(aff, (nbrs[e], np.full(ends[x] - starts[x], own)),
                          -wts[e])
                np.add.at(aff, (nbrs[e], np.full(ends[x] - starts[x], t)),
                          wts[e])
                tile_load[own] -= loads[x]
                tile_load[t] += loads[x]
                binding[x] = t
                improved = True
        if not improved:
            break
    return BindingResult(binding, time.perf_counter() - t0, "spinemap")


def cut_spikes(c: ClusteredSNN, binding: np.ndarray) -> float:
    """Total inter-tile spike traffic of a binding (SpiNeMap's objective)."""
    binding = np.asarray(binding)
    cut = binding[c.channel_src] != binding[c.channel_dst]
    return float(c.channel_rate[cut].sum())


def cut_spikes_batch(c: ClusteredSNN, bindings) -> np.ndarray:
    """Inter-tile spike traffic of a whole (B, n_clusters) binding batch.

    Vectorized :func:`cut_spikes`: one (B, n_channels) gather over the
    clustered SNN's parallel channel arrays scores every row at once (a
    single (n_clusters,) binding is promoted to B=1).  Returns (B,)
    spikes crossing tile boundaries per application iteration — the
    SpiNeMap objective and the AER-encode term of the chip energy model.
    """
    bindings = np.asarray(bindings, dtype=np.int64)
    if bindings.ndim == 1:
        bindings = bindings[None, :]
    cut = bindings[:, c.channel_src] != bindings[:, c.channel_dst]
    return cut.astype(np.float64) @ c.channel_rate

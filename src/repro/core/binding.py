"""Cluster-to-tile binding strategies (paper §4.2, §6.3).

Three strategies are evaluated, mirroring the paper:

  * :func:`bind_ours`     — Eq. 7 weighted load + std-dev-reducing pairwise
    swaps (the paper's proposed load balancer).
  * :func:`bind_pycarl`   — PyCARL [5]: balance tile load greedily (largest
    load first onto least-loaded tile); random execution order downstream.
  * :func:`bind_spinemap` — SpiNeMap [8]: minimize inter-tile spike traffic
    with Kernighan-Lin-style swaps; ignores load balance.
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np

from .hardware import HardwareConfig
from .partition import ClusteredSNN


@dataclasses.dataclass(frozen=True)
class LoadWeights:
    """User constants (a, b, c, d) of Eq. 7."""

    crossbar: float = 1.0
    buffer: float = 1.0
    connection: float = 1.0
    bandwidth: float = 1.0


@dataclasses.dataclass
class BindingResult:
    binding: np.ndarray          # (n_clusters,) tile id
    bind_time_s: float
    strategy: str

    def clusters_per_tile(self, n_tiles: int) -> np.ndarray:
        return np.bincount(self.binding, minlength=n_tiles)


def _cluster_loads(c: ClusteredSNN, w: LoadWeights, hw: HardwareConfig) -> np.ndarray:
    """Scalar Eq.-7 load per cluster (normalized per-resource)."""
    xbar = hw.tile.crossbar
    conn = c.channel_degree().astype(np.float64)
    return (
        w.crossbar * (c.inputs_used + c.neurons_used) / (xbar.inputs + xbar.outputs)
        + w.buffer * c.out_spikes / hw.tile.output_buffer
        + w.connection * conn / max(conn.max(initial=1.0), 1.0)
        + w.bandwidth
        * (c.in_spikes + c.out_spikes)
        / max((c.in_spikes + c.out_spikes).max(initial=1.0), 1.0)
    )


def bind_ours(
    c: ClusteredSNN,
    hw: HardwareConfig,
    *,
    weights: LoadWeights = LoadWeights(),
    max_pass: int = 4,
    rng_seed: int = 0,
) -> BindingResult:
    """Eq. 7 load balancing with std-dev-reducing pairwise swaps."""
    t0 = time.perf_counter()
    loads = _cluster_loads(c, weights, hw)
    n_tiles = hw.n_tiles

    # even initial distribution (round-robin over load-sorted clusters)
    order = np.argsort(loads)[::-1]
    binding = np.empty(c.n_clusters, dtype=np.int64)
    binding[order] = np.arange(c.n_clusters) % n_tiles

    tile_load = np.bincount(binding, weights=loads, minlength=n_tiles)

    rng = np.random.default_rng(rng_seed)
    n = c.n_clusters
    for _ in range(max_pass):
        improved = False
        # sweep cluster pairs; for large n sample pairs (documented bound)
        if n * n <= 250_000:
            pairs = [(i, j) for i in range(n) for j in range(i + 1, n)]
        else:
            idx = rng.integers(0, n, size=(250_000, 2))
            pairs = [(int(a), int(b)) for a, b in idx if a != b]
        std = tile_load.std()
        for i, j in pairs:
            ti, tj = binding[i], binding[j]
            if ti == tj:
                continue
            li, lj = loads[i], loads[j]
            new_ti = tile_load[ti] - li + lj
            new_tj = tile_load[tj] - lj + li
            delta_sq = (
                new_ti**2 + new_tj**2 - tile_load[ti] ** 2 - tile_load[tj] ** 2
            )
            if delta_sq < -1e-12:  # std reduces iff sum of squares reduces
                tile_load[ti], tile_load[tj] = new_ti, new_tj
                binding[i], binding[j] = tj, ti
                improved = True
        new_std = tile_load.std()
        if not improved or std - new_std < 1e-12:
            break
    return BindingResult(binding, time.perf_counter() - t0, "ours")


def bind_pycarl(
    c: ClusteredSNN,
    hw: HardwareConfig,
    *,
    weights: LoadWeights = LoadWeights(),
) -> BindingResult:
    """PyCARL: greedy load balance (LPT), random order downstream."""
    t0 = time.perf_counter()
    loads = _cluster_loads(c, weights, hw)
    binding = np.empty(c.n_clusters, dtype=np.int64)
    tile_load = np.zeros(hw.n_tiles)
    for i in np.argsort(loads)[::-1]:
        t = int(np.argmin(tile_load))
        binding[i] = t
        tile_load[t] += loads[i]
    return BindingResult(binding, time.perf_counter() - t0, "pycarl")


def bind_spinemap(
    c: ClusteredSNN,
    hw: HardwareConfig,
    *,
    max_pass: int = 4,
    rng_seed: int = 0,
) -> BindingResult:
    """SpiNeMap: minimize inter-tile spikes (KL-style single moves/swaps)."""
    t0 = time.perf_counter()
    n, n_tiles = c.n_clusters, hw.n_tiles
    rng = np.random.default_rng(rng_seed)

    # adjacency (symmetric spike traffic between cluster pairs)
    src, dst, spk = c.channel_src, c.channel_dst, c.channel_rate

    # seed: contiguous ranges (clusters are index-ordered along layers, so
    # this already groups communicating clusters together)
    binding = (np.arange(n) * n_tiles // max(n, 1)).astype(np.int64)

    def move_gain(x: int, to: int) -> float:
        """Reduction in cut spikes when moving cluster x to tile `to`."""
        own = binding[x]
        if own == to:
            return 0.0
        mask_s = src == x
        mask_d = dst == x
        cur = spk[mask_s][binding[dst[mask_s]] != own].sum() + spk[mask_d][
            binding[src[mask_d]] != own
        ].sum()
        new = spk[mask_s][binding[dst[mask_s]] != to].sum() + spk[mask_d][
            binding[src[mask_d]] != to
        ].sum()
        return float(cur - new)

    cap = int(np.ceil(1.5 * n / n_tiles))  # loose balance cap only
    counts = np.bincount(binding, minlength=n_tiles)
    for _ in range(max_pass):
        improved = False
        for x in rng.permutation(n)[: min(n, 2000)]:
            gains = [(move_gain(int(x), t), t) for t in range(n_tiles)]
            g, t = max(gains)
            if g > 1e-9 and counts[t] < cap:
                counts[binding[x]] -= 1
                counts[t] += 1
                binding[x] = t
                improved = True
        if not improved:
            break
    return BindingResult(binding, time.perf_counter() - t0, "spinemap")


def cut_spikes(c: ClusteredSNN, binding: np.ndarray) -> float:
    """Total inter-tile spike traffic of a binding (SpiNeMap's objective)."""
    binding = np.asarray(binding)
    cut = binding[c.channel_src] != binding[c.channel_dst]
    return float(c.channel_rate[cut].sum())

"""Static-order scheduling + self-timed execution (paper §4.4 steps 2-3).

Two engines, cross-validated in tests:

  * :func:`analyze_throughput` — analytical: augment the hardware-aware SDFG
    with the per-tile TDMA order cycles and take 1/MCR (Max-Plus, Eq. 6).
  * :class:`SelfTimedExecutor` — operational: a discrete-event simulator with
    the exact §4.4 semantics (atomic crossbar execution, output-buffer claim
    at firing start, AER link delays, per-tile firing order).  Static-order
    construction (§4.4 step 2) records the firing order of one steady-state
    iteration of this executor in FCFS mode; run-time execution (§5) replays
    orders self-timed.

For strongly-connected live event graphs the executor's steady-state period
equals the MCR — a property test asserts this.

Batched evaluation of many candidate configurations does NOT loop this
executor: once static orders exist, the order-augmented event graph fully
determines self-timed execution, and :mod:`repro.core.engine` analyzes a
whole candidate batch in one array pass (``x(k) = A (x) x(k-1)``).  The
heapq executor remains the FCFS static-order *constructor* (§4.4 step 2)
and the operational cross-validation oracle
(:meth:`ExecutionTrace.steady_period` matches the engine to ~1e-9).
"""

from __future__ import annotations

import dataclasses
import heapq
import time
from typing import Optional, Sequence

import numpy as np

from .hardware import HardwareConfig
from .maxplus import mcr_howard
from .sdfg import SDFG, hardware_aware_sdfg


# ======================================================================
# analytical path
# ======================================================================
def analyze_throughput(
    app: SDFG,
    binding: np.ndarray,
    hw: HardwareConfig,
    static_orders: Optional[Sequence[Sequence[int]]] = None,
) -> float:
    """Throughput (1/MCM) of the hardware-aware SDFG (§4.4)."""
    g = hardware_aware_sdfg(app, binding, hw, static_orders)
    rho = mcr_howard(g)
    if rho <= 0 or not np.isfinite(rho):
        return 0.0
    return 1.0 / rho


# ======================================================================
# operational path: self-timed discrete-event execution
# ======================================================================
@dataclasses.dataclass
class ExecutionTrace:
    finish_times: np.ndarray      # (iters, n_actors) firing end times
    tile_orders: list[list[int]]  # realized firing order per tile (1st period)
    period: float                 # steady-state average iteration period
    makespan: float

    @property
    def throughput(self) -> float:
        return 0.0 if self.period <= 0 else 1.0 / self.period

    def steady_period(self, *, atol: float = 1e-9) -> float:
        """Asymptotic per-iteration period, free of the fill transient.

        A live event graph reaches a periodic regime after finitely many
        iterations: ``finish(k + c) = finish(k) + c * period`` for some
        cyclicity ``c``.  Detect the smallest ``c`` whose last two windows
        agree exactly and return the exact per-iteration growth — this is
        what the batched engine's MCR must match to float precision.  Falls
        back to the tail slope when the recorded window is too short for a
        clean periodic match, and to ``period`` (0.0) on deadlock.
        """
        f = self.finish_times
        if self.period <= 0 or f.size == 0 or np.isnan(f).any():
            return self.period
        n_iters = f.shape[0]
        if n_iters < 3:  # no two disjoint windows to compare
            return self.period
        scale = max(1.0, float(np.abs(f[-1]).max()))
        for c in range(1, (n_iters - 1) // 2 + 1):
            a = f[n_iters - 1] - f[n_iters - 1 - c]
            b = f[n_iters - 1 - c] - f[n_iters - 1 - 2 * c]
            if np.allclose(a, b, rtol=0.0, atol=atol * scale):
                # per-actor rates agree across windows; the slowest actor's
                # rate is the iteration period of the whole graph
                return float(a.max() / c)
        k0 = n_iters // 2
        return float((f[n_iters - 1] - f[k0]).max() / (n_iters - 1 - k0))


class SelfTimedExecutor:
    """Discrete-event self-timed execution of a bound SDFG on tiles.

    Modes:
      * ``orders=None``  — FCFS list scheduling (used at design time to
        *construct* static orders, and as the SpiNeMap/PyCARL random-order
        stand-in when given a seeded permutation).
      * ``orders=[...]`` — strict static-order (TDMA) replay per tile.

    Readiness is tracked incrementally: ``deficit[a]`` counts input channels
    of ``a`` with zero tokens, so every event costs O(degree), not O(graph).
    """

    def __init__(
        self,
        app: SDFG,
        binding: np.ndarray,
        hw: HardwareConfig,
        *,
        orders: Optional[Sequence[Sequence[int]]] = None,
        priorities: Optional[np.ndarray] = None,
    ):
        self.app = app
        self.binding = np.asarray(binding, dtype=np.int64)
        self.hw = hw
        # hardware-aware graph WITHOUT order edges: ordering is enforced
        # operationally by the executor itself.
        self.graph = hardware_aware_sdfg(app, binding, hw, None)
        self.orders = [list(o) for o in orders] if orders is not None else None
        # random-order baselines (SpiNeMap/PyCARL §6.3): an ARBITRARY fixed
        # priority decides which ready cluster fires when a tile frees —
        # never deadlocks (only ready actors fire), unlike a strict random
        # TDMA cycle, but pays the throughput cost the paper measures.
        self.priorities = priorities

    # ------------------------------------------------------------------
    def run(self, iterations: int = 30, warmup: int = 5) -> ExecutionTrace:
        g = self.graph
        n = g.n_actors
        binding = self.binding
        n_tiles = self.hw.n_tiles

        table = g.table
        edge_dst = table.dst
        tokens = table.tokens.copy()
        delay = table.delay
        d_order, d_starts, d_ends = table.csr_by("dst", n)
        s_order, s_starts, s_ends = table.csr_by("src", n)
        in_edges = [
            d_order[d_starts[a] : d_ends[a]].tolist() for a in range(n)
        ]
        out_edges = [
            s_order[s_starts[a] : s_ends[a]].tolist() for a in range(n)
        ]
        tau = g.exec_time

        deficit = np.zeros(n, dtype=np.int64)
        for a in range(n):
            deficit[a] = sum(1 for e in in_edges[a] if tokens[e] == 0)

        tile_actors = [
            [int(a) for a in np.flatnonzero(binding == t)] for t in range(n_tiles)
        ]

        fired = np.zeros(n, dtype=np.int64)
        finish_times = np.full((iterations, n), np.nan)
        tile_busy = np.zeros(n_tiles, dtype=bool)
        order_pos = [0] * n_tiles
        tile_orders: list[list[int]] = [[] for _ in range(n_tiles)]
        ready_since = np.full(n, np.inf)  # FCFS tie-break stamps

        def is_ready(a: int) -> bool:
            return fired[a] < iterations and deficit[a] == 0

        for a in range(n):
            if deficit[a] == 0:
                ready_since[a] = 0.0

        # event heap: (time, seq, kind, payload); kind 0=token-arrival, 1=finish
        events: list[tuple[float, int, int, int]] = []
        seq = 0

        def produce(e: int, t: float) -> None:
            nonlocal seq
            tokens[e] += 1
            if tokens[e] == 1:
                d = int(edge_dst[e])
                deficit[d] -= 1
                if deficit[d] == 0 and not np.isfinite(ready_since[d]):
                    ready_since[d] = t

        def try_start(t: float) -> None:
            nonlocal seq
            progress = True
            while progress:
                progress = False
                for tile in range(n_tiles):
                    if tile_busy[tile]:
                        continue
                    a = self._pick(
                        tile, is_ready, ready_since, order_pos, tile_actors
                    )
                    if a is None:
                        continue
                    for e in in_edges[a]:
                        tokens[e] -= 1
                        if tokens[e] == 0:
                            d = int(edge_dst[e])
                            deficit[d] += 1
                            ready_since[d] = np.inf
                    # consuming may have unreadied a itself (self-edge)
                    if deficit[a] > 0:
                        ready_since[a] = np.inf
                    tile_busy[tile] = True
                    heapq.heappush(events, (t + tau[a], seq, 1, a))
                    seq += 1
                    if self.orders is not None and self.orders[tile]:
                        order_pos[tile] = (order_pos[tile] + 1) % len(
                            self.orders[tile]
                        )
                    progress = True

        try_start(0.0)
        makespan = 0.0
        while events:
            now, _, kind, payload = heapq.heappop(events)
            if kind == 1:  # actor finished
                a = payload
                tile = int(binding[a])
                k = int(fired[a])
                if k < iterations:
                    finish_times[k, a] = now
                fired[a] += 1
                if fired[a] == 1:
                    tile_orders[tile].append(a)
                tile_busy[tile] = False
                makespan = max(makespan, now)
                for e in out_edges[a]:
                    if delay[e] <= 0:
                        produce(e, now)
                    else:
                        heapq.heappush(events, (now + delay[e], seq, 0, e))
                        seq += 1
            else:  # token arrival after NoC delay
                produce(payload, now)
            try_start(now)

        done = int(fired.min())
        if done < iterations:
            # deadlock or starvation: report zero throughput
            return ExecutionTrace(finish_times, tile_orders, 0.0, makespan)

        # Steady-state period = total time / iterations.  (A tail-window
        # estimator over per-iteration completion times is poisoned when
        # deep buffers let fast actors run thousands of iterations ahead:
        # the "last iterations" then complete back-to-back as the straggler
        # drains, reporting its single-firing time as the period.)  Fill/
        # drain bias vanishes as iterations grow; callers use >= 30.
        period = float(makespan / iterations)
        return ExecutionTrace(finish_times, tile_orders, period, makespan)

    # ------------------------------------------------------------------
    def _pick(self, tile, is_ready, ready_since, order_pos, tile_actors):
        if self.orders is not None:
            order = self.orders[tile]
            if not order:
                return None
            a = order[order_pos[tile]]
            return a if is_ready(a) else None
        best, best_key = None, None
        for a in tile_actors[tile]:
            if is_ready(a) and np.isfinite(ready_since[a]):
                if self.priorities is not None:
                    key = (self.priorities[a], a)
                else:
                    key = (ready_since[a], a)
                if best_key is None or key < best_key:
                    best, best_key = a, key
        return best


# ======================================================================
# schedule construction (§4.4 step 2) and random-order baselines
# ======================================================================
def build_static_orders(
    app: SDFG,
    binding: np.ndarray,
    hw: HardwareConfig,
    *,
    iterations: int = 12,
) -> tuple[list[list[int]], float]:
    """Construct per-tile static orders by FCFS self-timed execution.

    Returns (orders, construction_time_s).  The recorded order of the first
    steady period is the static-order schedule the paper builds with its
    Max-Plus formulation at design time (§4.4 step 2).
    """
    t0 = time.perf_counter()
    trace = SelfTimedExecutor(app, binding, hw).run(iterations=iterations)
    return trace.tile_orders, time.perf_counter() - t0


def random_orders(
    app: SDFG, binding: np.ndarray, hw: HardwareConfig, *, seed: int = 0
) -> list[list[int]]:
    """Arbitrary per-tile orders (SpiNeMap/PyCARL execute clusters randomly)."""
    rng = np.random.default_rng(seed)
    orders: list[list[int]] = []
    for tile in range(hw.n_tiles):
        actors = np.flatnonzero(np.asarray(binding) == tile)
        orders.append([int(a) for a in rng.permutation(actors)])
    return orders


def measured_throughput(
    app: SDFG,
    binding: np.ndarray,
    hw: HardwareConfig,
    orders: Optional[Sequence[Sequence[int]]],
    *,
    iterations: int = 30,
) -> float:
    """Operational throughput from self-timed execution."""
    return SelfTimedExecutor(app, binding, hw, orders=orders).run(
        iterations=iterations
    ).throughput


def random_order_throughput(
    app: SDFG,
    binding: np.ndarray,
    hw: HardwareConfig,
    *,
    seeds: Sequence[int] = (0, 1, 2),
    iterations: int = 12,
) -> float:
    """SpiNeMap/PyCARL-style random cluster ordering: mean over random
    priority assignments (operational; a strict random TDMA order would
    deadlock whenever it inverts an intra-tile dependency)."""
    vals = []
    for s in seeds:
        pr = np.random.default_rng(s).permutation(app.n_actors).astype(float)
        vals.append(
            SelfTimedExecutor(app, binding, hw, priorities=pr)
            .run(iterations=iterations)
            .throughput
        )
    return float(np.mean(vals))

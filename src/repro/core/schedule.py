"""Static-order scheduling + self-timed execution (paper §4.4 steps 2-3).

Two engines, cross-validated in tests:

  * :func:`analyze_throughput` — analytical: augment the hardware-aware SDFG
    with the per-tile TDMA order cycles and take 1/MCR (Max-Plus, Eq. 6).
  * :class:`SelfTimedExecutor` — operational: a discrete-event simulator with
    the exact §4.4 semantics (atomic crossbar execution, output-buffer claim
    at firing start, AER link delays, per-tile firing order).  Static-order
    construction (§4.4 step 2) records the firing order of one steady-state
    iteration of this executor in FCFS mode; run-time execution (§5) replays
    orders self-timed.

For strongly-connected live event graphs the executor's steady-state period
equals the MCR — a property test asserts this.

Batched evaluation of many candidate configurations does NOT loop this
executor: once static orders exist, the order-augmented event graph fully
determines self-timed execution, and :mod:`repro.core.engine` analyzes a
whole candidate batch in one array pass (``x(k) = A (x) x(k-1)``).
Static-order *construction* is batched too:
:func:`build_static_orders_batch` builds the FCFS orders of B candidate
bindings in one dense tile-synchronous pass and matches the heapq
executor's first-firing record exactly.  The heapq executor remains the
§4.4 step-2 oracle and the operational cross-validation oracle
(:meth:`ExecutionTrace.steady_period` matches the engine to ~1e-9).
"""

from __future__ import annotations

import dataclasses
import heapq
import time
from typing import Optional, Sequence

import numpy as np

from .hardware import HardwareConfig
from .maxplus import mcr_howard
from .sdfg import SDFG, flow_delays, hardware_aware_sdfg, hardware_static_parts


# ======================================================================
# analytical path
# ======================================================================
def analyze_throughput(
    app: SDFG,
    binding: np.ndarray,
    hw: HardwareConfig,
    static_orders: Optional[Sequence[Sequence[int]]] = None,
) -> float:
    """Throughput (1/MCM) of the hardware-aware SDFG (§4.4)."""
    g = hardware_aware_sdfg(app, binding, hw, static_orders)
    rho = mcr_howard(g)
    if rho <= 0 or not np.isfinite(rho):
        return 0.0
    return 1.0 / rho


# ======================================================================
# operational path: self-timed discrete-event execution
# ======================================================================
@dataclasses.dataclass
class ExecutionTrace:
    finish_times: np.ndarray      # (iters, n_actors) firing end times
    tile_orders: list[list[int]]  # realized firing order per tile (1st period)
    period: float                 # steady-state average iteration period
    makespan: float

    @property
    def throughput(self) -> float:
        return 0.0 if self.period <= 0 else 1.0 / self.period

    def steady_period(self, *, atol: float = 1e-9) -> float:
        """Asymptotic per-iteration period, free of the fill transient.

        A live event graph reaches a periodic regime after finitely many
        iterations: ``finish(k + c) = finish(k) + c * period`` for some
        cyclicity ``c``.  Detect the smallest ``c`` whose last two windows
        agree exactly and return the exact per-iteration growth — this is
        what the batched engine's MCR must match to float precision.  Falls
        back to the tail slope when the recorded window is too short for a
        clean periodic match, and to ``period`` (0.0) on deadlock.
        """
        f = self.finish_times
        if self.period <= 0 or f.size == 0 or np.isnan(f).any():
            return self.period
        n_iters = f.shape[0]
        if n_iters < 3:  # no two disjoint windows to compare
            return self.period
        scale = max(1.0, float(np.abs(f[-1]).max()))
        # all candidate cyclicities at once: window deltas a(c) and b(c) are
        # (C, n_actors) slices of the recorded finish times; the smallest c
        # whose two windows agree wins (one vectorized comparison, no
        # per-cycle-length Python loop)
        cs = np.arange(1, (n_iters - 1) // 2 + 1)
        a = f[n_iters - 1][None, :] - f[n_iters - 1 - cs]
        b = f[n_iters - 1 - cs] - f[n_iters - 1 - 2 * cs]
        ok = np.flatnonzero(np.all(np.abs(a - b) <= atol * scale, axis=1))
        if ok.size:
            # per-actor rates agree across windows; the slowest actor's
            # rate is the iteration period of the whole graph
            return float(a[ok[0]].max() / cs[ok[0]])
        k0 = n_iters // 2
        return float((f[n_iters - 1] - f[k0]).max() / (n_iters - 1 - k0))


class SelfTimedExecutor:
    """Discrete-event self-timed execution of a bound SDFG on tiles.

    Modes:
      * ``orders=None``  — FCFS list scheduling (used at design time to
        *construct* static orders, and as the SpiNeMap/PyCARL random-order
        stand-in when given a seeded permutation).
      * ``orders=[...]`` — strict static-order (TDMA) replay per tile.

    Readiness is tracked incrementally: ``deficit[a]`` counts input channels
    of ``a`` with zero tokens, so every event costs O(degree), not O(graph).
    """

    def __init__(
        self,
        app: SDFG,
        binding: np.ndarray,
        hw: HardwareConfig,
        *,
        orders: Optional[Sequence[Sequence[int]]] = None,
        priorities: Optional[np.ndarray] = None,
    ):
        self.app = app
        self.binding = np.asarray(binding, dtype=np.int64)
        self.hw = hw
        # hardware-aware graph WITHOUT order edges: ordering is enforced
        # operationally by the executor itself.
        self.graph = hardware_aware_sdfg(app, binding, hw, None)
        self.orders = [list(o) for o in orders] if orders is not None else None
        # random-order baselines (SpiNeMap/PyCARL §6.3): an ARBITRARY fixed
        # priority decides which ready cluster fires when a tile frees —
        # never deadlocks (only ready actors fire), unlike a strict random
        # TDMA cycle, but pays the throughput cost the paper measures.
        self.priorities = priorities

    # ------------------------------------------------------------------
    def run(self, iterations: int = 30, warmup: int = 5) -> ExecutionTrace:
        g = self.graph
        n = g.n_actors
        binding = self.binding
        n_tiles = self.hw.n_tiles

        table = g.table
        edge_dst = table.dst
        tokens = table.tokens.copy()
        delay = table.delay
        d_order, d_starts, d_ends = table.csr_by("dst", n)
        s_order, s_starts, s_ends = table.csr_by("src", n)
        in_edges = [
            d_order[d_starts[a] : d_ends[a]].tolist() for a in range(n)
        ]
        out_edges = [
            s_order[s_starts[a] : s_ends[a]].tolist() for a in range(n)
        ]
        tau = g.exec_time

        deficit = np.zeros(n, dtype=np.int64)
        for a in range(n):
            deficit[a] = sum(1 for e in in_edges[a] if tokens[e] == 0)

        tile_actors = [
            [int(a) for a in np.flatnonzero(binding == t)] for t in range(n_tiles)
        ]

        fired = np.zeros(n, dtype=np.int64)
        finish_times = np.full((iterations, n), np.nan)
        tile_busy = np.zeros(n_tiles, dtype=bool)
        order_pos = [0] * n_tiles
        tile_orders: list[list[int]] = [[] for _ in range(n_tiles)]
        ready_since = np.full(n, np.inf)  # FCFS tie-break stamps

        def is_ready(a: int) -> bool:
            return fired[a] < iterations and deficit[a] == 0

        for a in range(n):
            if deficit[a] == 0:
                ready_since[a] = 0.0

        # event heap: (time, seq, kind, payload); kind 0=token-arrival, 1=finish
        events: list[tuple[float, int, int, int]] = []
        seq = 0

        def produce(e: int, t: float) -> None:
            nonlocal seq
            tokens[e] += 1
            if tokens[e] == 1:
                d = int(edge_dst[e])
                deficit[d] -= 1
                if deficit[d] == 0 and not np.isfinite(ready_since[d]):
                    ready_since[d] = t

        def try_start(t: float) -> None:
            nonlocal seq
            progress = True
            while progress:
                progress = False
                for tile in range(n_tiles):
                    if tile_busy[tile]:
                        continue
                    a = self._pick(
                        tile, is_ready, ready_since, order_pos, tile_actors
                    )
                    if a is None:
                        continue
                    for e in in_edges[a]:
                        tokens[e] -= 1
                        if tokens[e] == 0:
                            d = int(edge_dst[e])
                            deficit[d] += 1
                            ready_since[d] = np.inf
                    # consuming may have unreadied a itself (self-edge)
                    if deficit[a] > 0:
                        ready_since[a] = np.inf
                    tile_busy[tile] = True
                    heapq.heappush(events, (t + tau[a], seq, 1, a))
                    seq += 1
                    if self.orders is not None and self.orders[tile]:
                        order_pos[tile] = (order_pos[tile] + 1) % len(
                            self.orders[tile]
                        )
                    progress = True

        try_start(0.0)
        makespan = 0.0
        while events:
            now, _, kind, payload = heapq.heappop(events)
            if kind == 1:  # actor finished
                a = payload
                tile = int(binding[a])
                k = int(fired[a])
                if k < iterations:
                    finish_times[k, a] = now
                fired[a] += 1
                if fired[a] == 1:
                    tile_orders[tile].append(a)
                tile_busy[tile] = False
                makespan = max(makespan, now)
                for e in out_edges[a]:
                    if delay[e] <= 0:
                        produce(e, now)
                    else:
                        heapq.heappush(events, (now + delay[e], seq, 0, e))
                        seq += 1
            else:  # token arrival after NoC delay
                produce(payload, now)
            try_start(now)

        done = int(fired.min())
        if done < iterations:
            # deadlock or starvation: report zero throughput
            return ExecutionTrace(finish_times, tile_orders, 0.0, makespan)

        # Steady-state period = total time / iterations.  (A tail-window
        # estimator over per-iteration completion times is poisoned when
        # deep buffers let fast actors run thousands of iterations ahead:
        # the "last iterations" then complete back-to-back as the straggler
        # drains, reporting its single-firing time as the period.)  Fill/
        # drain bias vanishes as iterations grow; callers use >= 30.
        period = float(makespan / iterations)
        return ExecutionTrace(finish_times, tile_orders, period, makespan)

    # ------------------------------------------------------------------
    def _pick(self, tile, is_ready, ready_since, order_pos, tile_actors):
        if self.orders is not None:
            order = self.orders[tile]
            if not order:
                return None
            a = order[order_pos[tile]]
            return a if is_ready(a) else None
        best, best_key = None, None
        for a in tile_actors[tile]:
            if is_ready(a) and np.isfinite(ready_since[a]):
                if self.priorities is not None:
                    key = (self.priorities[a], a)
                else:
                    key = (ready_since[a], a)
                if best_key is None or key < best_key:
                    best, best_key = a, key
        return best


# ======================================================================
# schedule construction (§4.4 step 2) and random-order baselines
# ======================================================================
def build_static_orders(
    app: SDFG,
    binding: np.ndarray,
    hw: HardwareConfig,
    *,
    iterations: int = 12,
) -> tuple[list[list[int]], float]:
    """Construct per-tile static orders by FCFS self-timed execution.

    Returns (orders, construction_time_s).  The recorded order of the first
    steady period is the static-order schedule the paper builds with its
    Max-Plus formulation at design time (§4.4 step 2).
    """
    t0 = time.perf_counter()
    trace = SelfTimedExecutor(app, binding, hw).run(iterations=iterations)
    return trace.tile_orders, time.perf_counter() - t0


def build_static_orders_batch(
    app: SDFG,
    bindings,
    hw: HardwareConfig,
) -> list[list[list[int]]]:
    """FCFS static orders of B candidate bindings in ONE dense array pass.

    ``bindings`` is (B, n_actors) int tile ids (a single (n,) binding is
    promoted to B=1); returns ``orders[b][tile]`` = tile's firing order
    (actor ids) for candidate ``b`` — the same §4.4 step-2 product as
    :func:`build_static_orders`, constructed without a per-candidate Python
    event loop.

    The §4.4 step-2 schedule records each actor's FIRST firing, so the
    construction simulates exactly one firing per actor.  In that regime an
    actor, once ready, stays ready until it fires (every channel has a
    single consumer), so each tile's FCFS order is its actors sorted by
    first-ready time — and readiness is a pure array recursion over the
    zero-token ("gating") edges: ``ready[a] = max over gating in-edges of
    (finish[src] + delay)``.  The simulator advances all B candidates in
    tile-synchronous rounds; a tile's FCFS head with ready time ``r`` is
    committed in the current round only when ``r < s_min + min unfired
    tau`` (``s_min`` = the row's earliest possible next firing), which
    guarantees no later token arrival could produce an earlier-ready
    competitor — the committed prefix always equals the discrete-event
    order.  Matches ``SelfTimedExecutor.run(iterations=1).tile_orders``
    exactly (cross-validated in ``tests/test_frontend.py``); times are in
    the unit of ``app.exec_time`` (microseconds here).
    """
    bindings = np.asarray(bindings, dtype=np.int64)
    if bindings.ndim == 1:
        bindings = bindings[None, :]
    n_b, n = bindings.shape
    assert n == app.n_actors, (bindings.shape, app.n_actors)
    n_tiles = hw.n_tiles
    tau = app.exec_time
    rows = np.arange(n_b)

    # §4.4 edge set WITHOUT order edges (ordering is what we construct),
    # with per-row NoC delays — the same graph the FCFS executor runs on.
    keep_self, flow, back = hardware_static_parts(app, hw)
    base_src = np.concatenate([keep_self.src, flow.src, back.src])
    base_dst = np.concatenate([keep_self.dst, flow.dst, back.dst])
    base_tok = np.concatenate([keep_self.tokens, flow.tokens, back.tokens])
    gating = base_tok == 0          # only empty channels gate a first firing
    g_src = base_src[gating]
    g_dst = base_dst[gating]
    n_gate = g_src.size
    base_delay = np.concatenate([keep_self.delay, np.zeros(len(flow)), back.delay])
    g_delay = np.broadcast_to(base_delay[gating], (n_b, n_gate)).copy()
    if len(flow):
        # flow edges keep NO app delay; gating flow columns get the per-row
        # NoC delays (exactly as in hardware_aware_sdfg / the executor)
        flow_lo = keep_self.src.size
        is_flow_gate = np.zeros(base_src.size, dtype=bool)
        is_flow_gate[flow_lo : flow_lo + len(flow)] = True
        is_flow_gate &= gating
        gate_pos = np.cumsum(gating) - 1          # column among gating edges
        cols = gate_pos[is_flow_gate]
        flow_sel = is_flow_gate[flow_lo : flow_lo + len(flow)]
        g_delay[:, cols] = flow_delays(flow, bindings, hw)[:, flow_sel]

    # gating out-edge CSR by src (token-arrival fan-out of one firing)
    out_order = np.argsort(g_src, kind="stable")
    src_sorted = g_src[out_order]
    out_starts = np.searchsorted(src_sorted, np.arange(n), side="left")
    out_counts = np.searchsorted(src_sorted, np.arange(n), side="right") - out_starts

    # per-(row, tile) segments over actors sorted by (tile, actor id)
    order2d = np.argsort(bindings, axis=1, kind="stable")
    sorted_binding = np.take_along_axis(bindings, order2d, axis=1)
    flat_group = (rows[:, None] * n_tiles + sorted_binding).ravel()
    seg_keys, seg_pos = np.unique(flat_group, return_index=True)

    gin = np.bincount(g_dst, minlength=n)
    defc = np.broadcast_to(gin, (n_b, n)).copy().ravel()
    rmax = np.zeros(n_b * n)
    ready = np.where(defc == 0, 0.0, np.inf).reshape(n_b, n)
    unfired = np.ones((n_b, n), dtype=bool)
    tile_clock = np.zeros((n_b, n_tiles))
    start = np.full((n_b, n), np.inf)
    actor_ids = np.broadcast_to(np.arange(n), (n_b, n))

    for _ in range(n + 1):
        if not unfired.any():
            break
        eligible = unfired & np.isfinite(ready)
        keyr = np.where(eligible, ready, np.inf)
        vals = np.take_along_axis(keyr, order2d, axis=1).ravel()
        m1 = np.full(n_b * n_tiles, np.inf)
        m1[seg_keys] = np.minimum.reduceat(vals, seg_pos)
        m1 = m1.reshape(n_b, n_tiles)
        valid_t = np.isfinite(m1)
        if not valid_t.any():
            break  # deadlock (never for live graphs); report partial orders
        # FCFS head per tile: the smallest actor id at the minimal ready time
        head_ok = eligible & (ready == m1[rows[:, None], bindings])
        cand_vals = np.where(
            np.take_along_axis(head_ok, order2d, axis=1).ravel(),
            np.take_along_axis(actor_ids, order2d, axis=1).ravel(),
            n,
        )
        cand = np.full(n_b * n_tiles, n, dtype=np.int64)
        cand[seg_keys] = np.minimum.reduceat(cand_vals, seg_pos)
        cand = cand.reshape(n_b, n_tiles)

        s = np.maximum(tile_clock, m1)
        s_min = np.where(valid_t, s, np.inf).min(axis=1)
        tau_min = np.where(unfired, tau[None, :], np.inf).min(axis=1)
        commit = valid_t & (m1 < (s_min + tau_min)[:, None])
        # progress guarantee (tau == 0 corner): always commit the row's
        # globally-earliest firing, which is safe by the wavefront argument
        t_star = np.where(valid_t, s, np.inf).argmin(axis=1)
        any_valid = valid_t.any(axis=1)
        commit[rows[any_valid], t_star[any_valid]] = True

        bidx, tidx = np.nonzero(commit)
        actors = cand[bidx, tidx]
        s_c = s[bidx, tidx]
        fin = s_c + tau[actors]
        start[bidx, actors] = s_c
        unfired[bidx, actors] = False
        tile_clock[bidx, tidx] = fin

        # token arrivals: one vectorized scatter over the commits' gating
        # out-edges updates deficits and running ready maxima
        lens = out_counts[actors]
        tot = int(lens.sum())
        if tot:
            seg_off = np.concatenate([[0], np.cumsum(lens)[:-1]])
            e_flat = (
                np.repeat(out_starts[actors] - seg_off, lens) + np.arange(tot)
            )
            e_idx = out_order[e_flat]
            rep_b = np.repeat(bidx, lens)
            avail = np.repeat(fin, lens) + g_delay[rep_b, e_idx]
            keys = rep_b * n + g_dst[e_idx]
            np.maximum.at(rmax, keys, avail)
            np.add.at(defc, keys, -1)
            touched = np.unique(keys)
            ready.ravel()[touched] = np.where(
                defc[touched] == 0, rmax[touched], np.inf
            )

    # per-tile orders = actors sorted by start time (strictly increasing
    # within a tile: each firing advances the tile clock by tau > 0)
    orders: list[list[list[int]]] = []
    for b in range(n_b):
        fire_seq = np.argsort(start[b], kind="stable")
        per_tile: list[list[int]] = [[] for _ in range(n_tiles)]
        row_binding = bindings[b]
        for a in fire_seq:
            if np.isfinite(start[b, a]):
                per_tile[row_binding[a]].append(int(a))
        orders.append(per_tile)
    return orders


def random_orders(
    app: SDFG, binding: np.ndarray, hw: HardwareConfig, *, seed: int = 0
) -> list[list[int]]:
    """Arbitrary per-tile orders (SpiNeMap/PyCARL execute clusters randomly)."""
    rng = np.random.default_rng(seed)
    orders: list[list[int]] = []
    for tile in range(hw.n_tiles):
        actors = np.flatnonzero(np.asarray(binding) == tile)
        orders.append([int(a) for a in rng.permutation(actors)])
    return orders


def measured_throughput(
    app: SDFG,
    binding: np.ndarray,
    hw: HardwareConfig,
    orders: Optional[Sequence[Sequence[int]]],
    *,
    iterations: int = 30,
) -> float:
    """Operational throughput from self-timed execution."""
    return SelfTimedExecutor(app, binding, hw, orders=orders).run(
        iterations=iterations
    ).throughput


def random_order_throughput(
    app: SDFG,
    binding: np.ndarray,
    hw: HardwareConfig,
    *,
    seeds: Sequence[int] = (0, 1, 2),
    iterations: int = 12,
) -> float:
    """SpiNeMap/PyCARL-style random cluster ordering: mean over random
    priority assignments (operational; a strict random TDMA order would
    deadlock whenever it inverts an intra-tile dependency)."""
    vals = []
    for s in seeds:
        pr = np.random.default_rng(s).permutation(app.n_actors).astype(float)
        vals.append(
            SelfTimedExecutor(app, binding, hw, priorities=pr)
            .run(iterations=iterations)
            .throughput
        )
    return float(np.mean(vals))

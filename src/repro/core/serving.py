"""Serving layer: queued admission churn with coalesced rebalances (§5).

The :class:`~repro.core.runtime.AdmissionController` rebalances after
EVERY admit/evict under ``placement="joint"`` — correct and never-worse
per event, but at chip scale (32x32, hundreds of tenants) the per-event
joint re-optimization dominates the event loop, and a burst of K queued
events pays K rebalances where the LAST one already sees the final
placement state.  :class:`ServingQueue` batches that work:

  * events (admit / evict / finish) are **submitted** to a queue;
  * :meth:`ServingQueue.drain` applies them under the controller's
    :meth:`~repro.core.runtime.AdmissionController.defer_rebalances`
    window, so each event's placement lands immediately (admission
    latency stays the cheap free-tile binding) but the joint rebalance
    is *recorded*, not run;
  * every ``coalesce_window`` applied events the pending records merge
    into ONE rebalance (:meth:`~repro.core.runtime.AdmissionController.
    flush_rebalances`) whose affected region seeds from all recorded
    apps and freed tiles at once — and whose multi-component region
    search runs with FUSED scoring (one EdgeStack analysis per
    optimizer generation for the whole region, see
    :func:`~repro.core.optimize.optimize_binding_graphs_fused`).

The chip objective still never regresses: every flush's rebalance seeds
from the then-current bindings and floors at pre-flush component
periods, exactly like a per-event rebalance would.  What coalescing
trades away is intermediate placement quality *between* flushes —
admissions within a window run on their greedy free-tile placement
until the next flush (the ``degraded_admissions`` the serving benchmark
counts) — in exchange for an O(window) cut in rebalance work.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Optional, Union

import numpy as np

from .runtime import AdmissionController, AdmissionError

_KINDS = ("admit", "evict", "finish")


@dataclasses.dataclass
class ServiceTicket:
    """One queued serving request and its outcome.

    ``t_submit``/``t_apply``/``t_done`` are ``time.perf_counter()``
    stamps: submission, the moment the drain loop picked the ticket up
    (its event applied, or its quota rejection decided), and the flush
    covering it — the placement a ticket runs under is final only once
    its window's rebalance flushed, so ``t_done - t_submit`` is the full
    service latency including the coalescing delay.  The breakdown
    ``wait_s`` (queue wait before the drain reached it) vs ``service_s``
    (apply + covering flush) is what makes speculative pre-compilation
    visible: a warm artifact shrinks ``service_s`` only.  ``status`` is
    ``"pending"`` until drained, then ``"ok"``, ``"rejected"``
    (admission refused — placement or quota), ``"cancelled"``
    (withdrawn before its drain), or ``"skipped"`` (e.g. evicting an
    app that is not resident).
    """

    kind: str
    app: str
    n_tiles_request: Optional[int] = None
    t_submit: float = 0.0
    t_apply: float = float("nan")
    t_done: float = float("nan")
    status: str = "pending"
    error: str = ""

    @property
    def latency_s(self) -> float:
        """Submit-to-covered-by-flush seconds (NaN while pending)."""
        return self.t_done - self.t_submit

    @property
    def wait_s(self) -> float:
        """Queue wait: submit-to-apply seconds (NaN while pending)."""
        return self.t_apply - self.t_submit

    @property
    def service_s(self) -> float:
        """Apply-to-covered-by-flush seconds (NaN while pending)."""
        return self.t_done - self.t_apply


class PrecompilePool:
    """Speculative pre-compilation between drains (the actor/learner split).

    Tracks which apps keep arriving (exponentially frequency-decayed
    ticket history — recent tenants outrank historical ones) and, between
    drains, *warms* the controller for the likeliest next admissions:

      * the :class:`~repro.core.runtime.DesignArtifact` cache — a
        predicted app that was never registered runs its design-time flow
        (clustering, single-tile order, SDFG build) NOW, off the
        admission critical path;
      * the EdgeStack shape buckets — one B=1 bucket-padded scoring call
        per predicted artifact, so the admission-time analysis of that
        app's (n_actors, n_edges) bucket lands on a warm trace/compile
        cache entry instead of paying the first-sighting miss inside the
        drain.

    ``observe`` feeds the predictor (every admit submission), ``warm``
    runs the speculation, and ``ensure`` does the admission-time
    accounting: a *hit* means the artifact was already cached when its
    ticket drained (speculation or an earlier admission paid the design
    cost), a *miss* means the admission pays it inline — ``hit_rate`` is
    the cache-warm-hit-rate the serving benchmark reports.  Apps are
    resolved by name through ``source`` (name -> raw/clustered SNN,
    extended via :meth:`offer`); a predicted name with no source and no
    cached artifact is skipped — speculation never invents inputs.
    """

    def __init__(
        self,
        ctl: AdmissionController,
        *,
        source: Optional[dict] = None,
        decay: float = 0.9,
        top_k: int = 4,
    ):
        if not 0.0 < decay <= 1.0:
            raise ValueError(f"decay must be in (0, 1], got {decay}")
        if top_k < 1:
            raise ValueError(f"top_k must be >= 1, got {top_k}")
        self.ctl = ctl
        self.source: dict = dict(source) if source else {}
        self.decay = float(decay)
        self.top_k = int(top_k)
        self.scores: dict[str, float] = {}
        self.hits = 0
        self.misses = 0
        self.warmed_artifacts = 0
        self.warmed_buckets = 0
        self.warm_calls = 0

    def offer(self, name: str, app) -> None:
        """Make ``app`` resolvable by ``name`` for future warming."""
        self.source[name] = app

    def observe(self, name: str) -> None:
        """Feed one (submitted) admission into the frequency predictor."""
        for k in self.scores:
            self.scores[k] *= self.decay
        self.scores[name] = self.scores.get(name, 0.0) + 1.0

    def predict(self, k: Optional[int] = None) -> list[str]:
        """Top-``k`` likeliest next admissions (score desc, name-stable)."""
        k = self.top_k if k is None else int(k)
        ranked = sorted(self.scores.items(), key=lambda kv: (-kv[1], kv[0]))
        return [name for name, _ in ranked[:k]]

    def _warm_bucket(self, art) -> None:
        """One B=1 bucket-padded solve of ``art``'s graph: the scoring
        shape bucket an admission of this app will hit is now a repeat
        key for the compile cache (all actors pinned to tile 0 — the
        binding does not matter, only the bucketed stacked shape does)."""
        from .engine import batch_execute, record_cache_stats

        binding = np.zeros(art.graph.n_actors, dtype=np.int64)
        with record_cache_stats(self.ctl.cache_stats):
            batch_execute(
                art.graph, binding, self.ctl.hw, rel_tol=1e-4,
                pad_shapes=True,
            )
        self.warmed_buckets += 1

    def warm(self) -> list[str]:
        """Speculatively pre-compile for the predicted next admissions.

        Returns the names actually warmed this call.  Idempotent per
        state: a predicted app whose artifact is already cached only
        re-warms its shape bucket (cheap — a compile-cache hit by
        construction after the first warm).
        """
        warmed = []
        for name in self.predict():
            key = (name, self.ctl.hw)
            if key not in self.ctl.artifacts:
                src = self.source.get(name)
                if src is None:
                    continue
                self.ctl.register(src)
                self.warmed_artifacts += 1
            self._warm_bucket(self.ctl.artifacts[key])
            warmed.append(name)
        self.warm_calls += 1
        return warmed

    def ensure(self, app: Union[str, object]) -> bool:
        """Admission-time warmth check (+ registration fallback).

        Called when an admit ticket drains: a cached artifact is a *hit*
        (design time already paid — by speculation or an earlier
        admission), anything else is a *miss* and registers the app from
        ``source`` if resolvable so the admission can proceed.  Returns
        the hit verdict.
        """
        name = app if isinstance(app, str) else getattr(
            getattr(app, "snn", app), "name"
        )
        if (name, self.ctl.hw) in self.ctl.artifacts:
            self.hits += 1
            return True
        self.misses += 1
        src = self.source.get(name)
        if src is not None:
            self.ctl.register(src)
        return False

    @property
    def hit_rate(self) -> float:
        """hits / (hits + misses); 0.0 before any ensure() call."""
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def stats(self) -> dict:
        """JSON-ready counters (stamped into the drain report)."""
        return {
            "hits": self.hits,
            "misses": self.misses,
            "hit_rate": self.hit_rate,
            "warm_calls": self.warm_calls,
            "warmed_artifacts": self.warmed_artifacts,
            "warmed_buckets": self.warmed_buckets,
        }


class ServingQueue:
    """Burst-mode front-end of one :class:`AdmissionController`.

    ``coalesce_window`` is the flush cadence in applied events: 1
    degenerates to per-event rebalancing (the controller's normal
    behaviour, one flush per event), larger windows amortize one region
    rebalance over the whole window.  ``drain`` is synchronous and
    deterministic — events apply in submission order, flushes happen at
    fixed positions — so a replayed trajectory is reproducible.

    ``precompile`` attaches a :class:`PrecompilePool`: every admit
    submission feeds its predictor, every drain starts by warming its
    predictions (the between-drains window is where speculation runs),
    and every draining admit goes through its hit/miss accounting.

    ``quotas`` maps tenant (app name) -> maximum tiles one admission may
    request (``set_quota`` edits it later).  A ticket over quota is
    refused at its drain WITHOUT touching the placement — status
    ``"rejected"``, error ``"quota"`` — and stamped on the controller
    trajectory (:meth:`~repro.core.runtime.AdmissionController.
    record_rejection`), same as a cancellation; the Fig.-11 flow audits
    every outcome.  An admit with no explicit ``n_tiles_request`` counts
    as requesting the app's cluster count (its maximum footprint) when
    the artifact is cached, and is never quota-refused before the design
    flow has revealed its size.
    """

    def __init__(
        self,
        ctl: AdmissionController,
        *,
        coalesce_window: int = 8,
        precompile: Optional[PrecompilePool] = None,
        quotas: Optional[dict[str, int]] = None,
    ):
        if coalesce_window < 1:
            raise ValueError(
                f"coalesce_window must be >= 1, got {coalesce_window}"
            )
        self.ctl = ctl
        self.coalesce_window = int(coalesce_window)
        self.precompile = precompile
        self.quotas: dict[str, int] = dict(quotas) if quotas else {}
        self.tickets: list[ServiceTicket] = []
        self._queue: list[ServiceTicket] = []
        self.flushes = 0
        self.coalesced_events = 0
        self.degraded_admissions = 0
        self.cancelled = 0
        self.quota_rejections = 0

    # -- submission ------------------------------------------------------
    def submit(
        self, kind: str, app: str, *,
        n_tiles_request: Optional[int] = None,
    ) -> ServiceTicket:
        """Queue one event; returns its (pending) ticket."""
        if kind not in _KINDS:
            raise ValueError(f"unknown kind {kind!r}; have {_KINDS}")
        t = ServiceTicket(
            kind=kind, app=app, n_tiles_request=n_tiles_request,
            t_submit=time.perf_counter(),
        )
        self._queue.append(t)
        self.tickets.append(t)
        return t

    def submit_admit(
        self, app: str, *, n_tiles_request: Optional[int] = None
    ) -> ServiceTicket:
        t = self.submit("admit", app, n_tiles_request=n_tiles_request)
        if self.precompile is not None:
            self.precompile.observe(app)
        return t

    def submit_evict(self, app: str) -> ServiceTicket:
        return self.submit("evict", app)

    def cancel(self, ticket: ServiceTicket) -> bool:
        """Withdraw a still-queued ticket before its drain.

        Returns True when the ticket was pending and is now
        ``"cancelled"`` (stamped on the controller trajectory as a
        rejection with reason ``"cancelled"``); False when it already
        drained — a drained ticket's effect is applied and a cancel
        cannot undo it (submit the inverse event instead).
        """
        if ticket.status != "pending" or ticket not in self._queue:
            return False
        self._queue.remove(ticket)
        ticket.status = "cancelled"
        ticket.t_apply = ticket.t_done = time.perf_counter()
        self.cancelled += 1
        self.ctl.record_rejection(ticket.app, "cancelled")
        return True

    def set_quota(self, app: str, max_tiles: Optional[int]) -> None:
        """Set (or clear, with None) one tenant's tile quota."""
        if max_tiles is None:
            self.quotas.pop(app, None)
        else:
            if max_tiles < 1:
                raise ValueError(f"quota must be >= 1, got {max_tiles}")
            self.quotas[app] = int(max_tiles)

    @property
    def pending(self) -> int:
        """Queued events not yet drained."""
        return len(self._queue)

    def _over_quota(self, t: ServiceTicket) -> bool:
        quota = self.quotas.get(t.app)
        if quota is None:
            return False
        requested = t.n_tiles_request
        if requested is None:
            art = self.ctl.artifacts.get((t.app, self.ctl.hw))
            if art is None:
                return False    # size unknown until the design flow runs
            requested = art.clustered.n_clusters
        return int(requested) > quota

    # -- drain -----------------------------------------------------------
    def _apply(self, t: ServiceTicket) -> None:
        ctl = self.ctl
        t.t_apply = time.perf_counter()
        try:
            if t.kind == "admit":
                if self._over_quota(t):
                    t.status = "rejected"
                    t.error = "quota"
                    self.quota_rejections += 1
                    ctl.record_rejection(t.app, "quota")
                    return
                if self.precompile is not None:
                    self.precompile.ensure(t.app)
                ctl.admit(t.app, n_tiles_request=t.n_tiles_request)
                # placement lands greedy (free-tile) now; the joint
                # rebalance that would refine it is deferred to the
                # window's flush
                self.degraded_admissions += 1
            elif t.kind == "evict":
                if t.app not in ctl.state.allocated:
                    t.status = "skipped"
                    return
                ctl.evict(t.app)
            else:
                if t.app not in ctl.state.allocated:
                    t.status = "skipped"
                    return
                ctl.finish(t.app)
            t.status = "ok"
        except AdmissionError as e:
            t.status = "rejected"
            t.error = str(e)

    def drain(self) -> dict:
        """Apply every queued event, flushing each coalescing window.

        Returns a JSON-ready stats dict for this drain call.  Tickets
        stamp ``t_done`` at their covering flush, so latency includes
        the coalescing delay.
        """
        ctl = self.ctl
        if self.precompile is not None:
            # the between-drains speculation window closes here: warm the
            # predicted artifacts/buckets before the first ticket applies
            self.precompile.warm()
        done: list[ServiceTicket] = []
        window: list[ServiceTicket] = []

        def _flush() -> None:
            n = ctl.flush_rebalances()
            self.flushes += 1
            self.coalesced_events += max(n - 1, 0)
            now = time.perf_counter()
            for t in window:
                t.t_done = now
            done.extend(window)
            window.clear()

        with ctl.defer_rebalances():
            while self._queue:
                t = self._queue.pop(0)
                self._apply(t)
                window.append(t)
                if len(window) >= self.coalesce_window:
                    _flush()
            if window:
                _flush()
        ok_admits = [
            t for t in done if t.kind == "admit" and t.status == "ok"
        ]
        lat = [t.latency_s for t in ok_admits]
        waits = [t.wait_s for t in ok_admits]
        services = [t.service_s for t in ok_admits]

        def _pcts(xs: list[float]) -> tuple[float, float]:
            if not xs:
                return 0.0, 0.0
            return (
                float(np.percentile(xs, 50)), float(np.percentile(xs, 99))
            )

        wait_p50, wait_p99 = _pcts(waits)
        service_p50, service_p99 = _pcts(services)
        stats = {
            "processed": len(done),
            "admitted": len(ok_admits),
            "evicted": sum(
                1 for t in done if t.kind == "evict" and t.status == "ok"
            ),
            "rejected": sum(1 for t in done if t.status == "rejected"),
            "quota_rejections": self.quota_rejections,
            "cancelled": self.cancelled,
            "skipped": sum(1 for t in done if t.status == "skipped"),
            "flushes": self.flushes,
            "coalesced_events": self.coalesced_events,
            "degraded_admissions": self.degraded_admissions,
            "admit_latency_p50_s": (
                float(np.percentile(lat, 50)) if lat else 0.0
            ),
            "admit_latency_p99_s": (
                float(np.percentile(lat, 99)) if lat else 0.0
            ),
            # end-to-end latency split: queue wait (submit -> drain picks
            # the ticket up) vs service (apply + covering flush) — a warm
            # precompile cache shows up as a smaller service tail only
            "queue_wait_p50_s": wait_p50,
            "queue_wait_p99_s": wait_p99,
            "service_p50_s": service_p50,
            "service_p99_s": service_p99,
        }
        if self.precompile is not None:
            stats["precompile"] = self.precompile.stats()
        return stats

"""Serving layer: queued admission churn with coalesced rebalances (§5).

The :class:`~repro.core.runtime.AdmissionController` rebalances after
EVERY admit/evict under ``placement="joint"`` — correct and never-worse
per event, but at chip scale (32x32, hundreds of tenants) the per-event
joint re-optimization dominates the event loop, and a burst of K queued
events pays K rebalances where the LAST one already sees the final
placement state.  :class:`ServingQueue` batches that work:

  * events (admit / evict / finish) are **submitted** to a queue;
  * :meth:`ServingQueue.drain` applies them under the controller's
    :meth:`~repro.core.runtime.AdmissionController.defer_rebalances`
    window, so each event's placement lands immediately (admission
    latency stays the cheap free-tile binding) but the joint rebalance
    is *recorded*, not run;
  * every ``coalesce_window`` applied events the pending records merge
    into ONE rebalance (:meth:`~repro.core.runtime.AdmissionController.
    flush_rebalances`) whose affected region seeds from all recorded
    apps and freed tiles at once — and whose multi-component region
    search runs with FUSED scoring (one EdgeStack analysis per
    optimizer generation for the whole region, see
    :func:`~repro.core.optimize.optimize_binding_graphs_fused`).

The chip objective still never regresses: every flush's rebalance seeds
from the then-current bindings and floors at pre-flush component
periods, exactly like a per-event rebalance would.  What coalescing
trades away is intermediate placement quality *between* flushes —
admissions within a window run on their greedy free-tile placement
until the next flush (the ``degraded_admissions`` the serving benchmark
counts) — in exchange for an O(window) cut in rebalance work.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Optional

import numpy as np

from .runtime import AdmissionController, AdmissionError

_KINDS = ("admit", "evict", "finish")


@dataclasses.dataclass
class ServiceTicket:
    """One queued serving request and its outcome.

    ``t_submit``/``t_done`` are ``time.perf_counter()`` stamps; a
    ticket is *done* once its event has been applied AND the flush
    covering it has run (the placement it runs under is final), so
    ``t_done - t_submit`` is the full service latency including the
    coalescing delay.  ``status`` is ``"pending"`` until drained, then
    ``"ok"``, ``"rejected"`` (admission refused), or ``"skipped"``
    (e.g. evicting an app that is not resident).
    """

    kind: str
    app: str
    n_tiles_request: Optional[int] = None
    t_submit: float = 0.0
    t_done: float = float("nan")
    status: str = "pending"
    error: str = ""

    @property
    def latency_s(self) -> float:
        """Submit-to-covered-by-flush seconds (NaN while pending)."""
        return self.t_done - self.t_submit


class ServingQueue:
    """Burst-mode front-end of one :class:`AdmissionController`.

    ``coalesce_window`` is the flush cadence in applied events: 1
    degenerates to per-event rebalancing (the controller's normal
    behaviour, one flush per event), larger windows amortize one region
    rebalance over the whole window.  ``drain`` is synchronous and
    deterministic — events apply in submission order, flushes happen at
    fixed positions — so a replayed trajectory is reproducible.
    """

    def __init__(
        self,
        ctl: AdmissionController,
        *,
        coalesce_window: int = 8,
    ):
        if coalesce_window < 1:
            raise ValueError(
                f"coalesce_window must be >= 1, got {coalesce_window}"
            )
        self.ctl = ctl
        self.coalesce_window = int(coalesce_window)
        self.tickets: list[ServiceTicket] = []
        self._queue: list[ServiceTicket] = []
        self.flushes = 0
        self.coalesced_events = 0
        self.degraded_admissions = 0

    # -- submission ------------------------------------------------------
    def submit(
        self, kind: str, app: str, *,
        n_tiles_request: Optional[int] = None,
    ) -> ServiceTicket:
        """Queue one event; returns its (pending) ticket."""
        if kind not in _KINDS:
            raise ValueError(f"unknown kind {kind!r}; have {_KINDS}")
        t = ServiceTicket(
            kind=kind, app=app, n_tiles_request=n_tiles_request,
            t_submit=time.perf_counter(),
        )
        self._queue.append(t)
        self.tickets.append(t)
        return t

    def submit_admit(
        self, app: str, *, n_tiles_request: Optional[int] = None
    ) -> ServiceTicket:
        return self.submit("admit", app, n_tiles_request=n_tiles_request)

    def submit_evict(self, app: str) -> ServiceTicket:
        return self.submit("evict", app)

    @property
    def pending(self) -> int:
        """Queued events not yet drained."""
        return len(self._queue)

    # -- drain -----------------------------------------------------------
    def _apply(self, t: ServiceTicket) -> None:
        ctl = self.ctl
        try:
            if t.kind == "admit":
                ctl.admit(t.app, n_tiles_request=t.n_tiles_request)
                # placement lands greedy (free-tile) now; the joint
                # rebalance that would refine it is deferred to the
                # window's flush
                self.degraded_admissions += 1
            elif t.kind == "evict":
                if t.app not in ctl.state.allocated:
                    t.status = "skipped"
                    return
                ctl.evict(t.app)
            else:
                if t.app not in ctl.state.allocated:
                    t.status = "skipped"
                    return
                ctl.finish(t.app)
            t.status = "ok"
        except AdmissionError as e:
            t.status = "rejected"
            t.error = str(e)

    def drain(self) -> dict:
        """Apply every queued event, flushing each coalescing window.

        Returns a JSON-ready stats dict for this drain call.  Tickets
        stamp ``t_done`` at their covering flush, so latency includes
        the coalescing delay.
        """
        ctl = self.ctl
        done: list[ServiceTicket] = []
        window: list[ServiceTicket] = []

        def _flush() -> None:
            n = ctl.flush_rebalances()
            self.flushes += 1
            self.coalesced_events += max(n - 1, 0)
            now = time.perf_counter()
            for t in window:
                t.t_done = now
            done.extend(window)
            window.clear()

        with ctl.defer_rebalances():
            while self._queue:
                t = self._queue.pop(0)
                self._apply(t)
                window.append(t)
                if len(window) >= self.coalesce_window:
                    _flush()
            if window:
                _flush()
        lat = [
            t.latency_s for t in done
            if t.kind == "admit" and t.status == "ok"
        ]
        return {
            "processed": len(done),
            "admitted": sum(
                1 for t in done if t.kind == "admit" and t.status == "ok"
            ),
            "evicted": sum(
                1 for t in done if t.kind == "evict" and t.status == "ok"
            ),
            "rejected": sum(1 for t in done if t.status == "rejected"),
            "skipped": sum(1 for t in done if t.status == "skipped"),
            "flushes": self.flushes,
            "coalesced_events": self.coalesced_events,
            "degraded_admissions": self.degraded_admissions,
            "admit_latency_p50_s": (
                float(np.percentile(lat, 50)) if lat else 0.0
            ),
            "admit_latency_p99_s": (
                float(np.percentile(lat, 99)) if lat else 0.0
            ),
        }

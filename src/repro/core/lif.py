"""JAX LIF simulator — the repo's CARLsim analogue (workflow Fig. 2).

Simulates the SNN for ``n_steps`` discrete timesteps with Poisson-encoded
input on layer 0 and leaky-integrate-and-fire dynamics everywhere else, and
records per-neuron spike counts.  Those counts feed the partitioner exactly
like the CARLsim recordings in the paper (§2.4).

The synaptic accumulate (``I[post] += w * s[pre]``) is a sparse gather/
scatter here; the *clustered* execution path (dense 128x128 crossbar blocks)
is the Pallas kernel in :mod:`repro.kernels.lif_crossbar`.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from .snn import SNN


@dataclasses.dataclass(frozen=True)
class LIFParams:
    v_threshold: float = 1.0
    v_reset: float = 0.0
    leak: float = 0.9           # membrane decay per step
    refractory: int = 2         # steps
    input_rate: float = 0.08    # Poisson rate per input neuron per step


@functools.partial(jax.jit, static_argnames=("n_neurons", "n_steps", "params"))
def _simulate(
    pre: jnp.ndarray,
    post: jnp.ndarray,
    weight: jnp.ndarray,
    is_input: jnp.ndarray,
    key: jnp.ndarray,
    *,
    n_neurons: int,
    n_steps: int,
    params: LIFParams,
) -> jnp.ndarray:
    """Run LIF dynamics; returns per-neuron spike counts (float32)."""

    def step(carry, key_t):
        v, refr = carry
        # Poisson input spikes on the input layer.
        rand = jax.random.uniform(key_t, (n_neurons,))
        in_spike = (rand < params.input_rate) & is_input
        # Fire from membrane state; synaptic accumulate is sparse:
        # I[post] += w * spike[pre].
        can_fire = refr <= 0
        fired = ((v >= params.v_threshold) & can_fire & (~is_input)) | in_spike
        s = fired.astype(weight.dtype)
        i_syn = jax.ops.segment_sum(weight * s[pre], post, num_segments=n_neurons)
        v_next = jnp.where(
            fired, params.v_reset, v * params.leak
        ) + jnp.where(is_input, 0.0, i_syn)
        refr_next = jnp.where(fired, params.refractory, jnp.maximum(refr - 1, 0))
        return (v_next, refr_next), s

    keys = jax.random.split(key, n_steps)
    v0 = jnp.zeros((n_neurons,), dtype=weight.dtype)
    refr0 = jnp.zeros((n_neurons,), dtype=jnp.int32)
    (_, _), spikes = jax.lax.scan(step, (v0, refr0), keys)
    return spikes.sum(axis=0)


def simulate_spikes(
    snn: SNN,
    *,
    n_steps: int = 256,
    params: LIFParams = LIFParams(),
    seed: int = 0,
) -> np.ndarray:
    """Record per-neuron spike counts for one application iteration."""
    is_input = jnp.asarray(snn.layer_of == 0)
    # Excitatory-biased weights so activity propagates (rate-coded nets).
    w = jnp.asarray(np.abs(snn.weight) * 0.5)
    counts = _simulate(
        jnp.asarray(snn.pre),
        jnp.asarray(snn.post),
        w,
        is_input,
        jax.random.PRNGKey(seed),
        n_neurons=snn.n_neurons,
        n_steps=n_steps,
        params=params,
    )
    return np.asarray(counts, dtype=np.float64)


def with_simulated_spikes(snn: SNN, **kw) -> SNN:
    """Return a copy of ``snn`` whose spike counts come from LIF simulation."""
    counts = simulate_spikes(snn, **kw)
    # Guard: the partitioner needs strictly nonnegative rates; keep tiny floor
    # so channels exist wherever synapses exist.
    counts = np.maximum(counts, 1e-3)
    return dataclasses.replace(snn, spikes=counts)

"""Synchronous Dataflow Graph IR for clustered SNNs (paper §3, §4).

Because every spike produced on a channel is consumed by the destination
actor within one application iteration, the repetition vector of a clustered
SNN is all-ones (§3.1, Def. 3) — i.e. the SDFG is a *timed event graph*
(homogeneous SDFG).  We therefore represent channels directly with an
integer *marking* (initial tokens, in units of actor firings) and a real
*delay* (AER communication latency), which is exactly the structure Max-Plus
Algebra analyzes (§3.2).

The graph is stored array-native: a :class:`ChannelTable` holds one
struct-of-arrays record per channel (``src/dst/tokens/rate/delay/kind``),
so every analysis pass (liveness, Max-Plus, batched sweeps) consumes flat
numpy arrays with no per-edge Python objects on the hot path.  A thin
:class:`Channel` view plus ``__iter__`` keeps the old object-graph API
working for tests and incremental call sites.

The hardware-aware transformation (§4.4) adds:
  * back-edges with ``floor(buffer / rate)`` initial tokens  (Step 1),
  * TDMA static-order edges per tile                         (Step 2),
  * inter-tile channel delays from the NoC model             (Step 1/3).
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Iterator, Optional, Sequence, Union

import numpy as np

from .hardware import HardwareConfig
from .partition import ClusteredSNN

# channel kinds, encoded as int8 in ChannelTable.kind
KIND_DATA, KIND_BUFFER, KIND_ORDER, KIND_SELF = 0, 1, 2, 3
KIND_NAMES = ("data", "buffer", "order", "self")
KIND_CODES = {name: code for code, name in enumerate(KIND_NAMES)}


@dataclasses.dataclass(frozen=True)
class Channel:
    """One channel record — a *view* row of a :class:`ChannelTable`.

    Kept for construction convenience and backward compatibility; the graph
    itself never stores Channel objects.
    """

    src: int
    dst: int
    tokens: int          # initial marking (units: firings)
    rate: float          # spikes per firing on this channel (port rate)
    delay: float = 0.0   # communication latency added to the dependency
    kind: str = "data"   # data | buffer | order | self


@dataclasses.dataclass(frozen=True)
class ChannelTable:
    """Struct-of-arrays channel storage (the array-native edge IR).

    All arrays share length ``len(self)``.  ``kind`` uses the integer codes
    ``KIND_DATA/KIND_BUFFER/KIND_ORDER/KIND_SELF``; :meth:`kind_names`
    decodes.  The table is immutable — transformations build new tables via
    :meth:`from_arrays` / :meth:`concat` / :meth:`replace`.
    """

    src: np.ndarray      # (E,) int64
    dst: np.ndarray      # (E,) int64
    tokens: np.ndarray   # (E,) int64
    rate: np.ndarray     # (E,) float64
    delay: np.ndarray    # (E,) float64
    kind: np.ndarray     # (E,) int8

    # -- construction ---------------------------------------------------
    @classmethod
    def from_arrays(
        cls,
        src,
        dst,
        tokens,
        rate,
        delay=None,
        kind=None,
    ) -> "ChannelTable":
        src = np.asarray(src, dtype=np.int64)
        e = src.size
        if delay is None:
            delay = np.zeros(e)
        if kind is None:
            kind = np.full(e, KIND_DATA, dtype=np.int8)
        elif np.isscalar(kind):
            kind = np.full(e, int(kind), dtype=np.int8)
        return cls(
            src=src,
            dst=np.asarray(dst, dtype=np.int64),
            tokens=np.asarray(tokens, dtype=np.int64),
            rate=np.asarray(rate, dtype=np.float64),
            delay=np.asarray(delay, dtype=np.float64),
            kind=np.asarray(kind, dtype=np.int8),
        )

    @classmethod
    def from_channels(cls, channels: Iterable[Channel]) -> "ChannelTable":
        chans = list(channels)
        return cls.from_arrays(
            src=[c.src for c in chans],
            dst=[c.dst for c in chans],
            tokens=[c.tokens for c in chans],
            rate=[c.rate for c in chans],
            delay=[c.delay for c in chans],
            kind=[KIND_CODES[c.kind] for c in chans],
        )

    @classmethod
    def empty(cls) -> "ChannelTable":
        return cls.from_arrays([], [], [], [])

    @classmethod
    def concat(cls, tables: Sequence["ChannelTable"]) -> "ChannelTable":
        tables = [t for t in tables if len(t)]
        if not tables:
            return cls.empty()
        return cls(
            src=np.concatenate([t.src for t in tables]),
            dst=np.concatenate([t.dst for t in tables]),
            tokens=np.concatenate([t.tokens for t in tables]),
            rate=np.concatenate([t.rate for t in tables]),
            delay=np.concatenate([t.delay for t in tables]),
            kind=np.concatenate([t.kind for t in tables]),
        )

    # -- transforms -----------------------------------------------------
    def replace(self, **arrays) -> "ChannelTable":
        return dataclasses.replace(
            self, **{k: np.asarray(v) for k, v in arrays.items()}
        )

    def select(self, mask: np.ndarray) -> "ChannelTable":
        return ChannelTable(
            src=self.src[mask],
            dst=self.dst[mask],
            tokens=self.tokens[mask],
            rate=self.rate[mask],
            delay=self.delay[mask],
            kind=self.kind[mask],
        )

    # -- CSR helpers (per-node edge lists without Python adjacency) -----
    def csr_by(self, field: str, n: int) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """CSR index over ``src`` or ``dst``: (edge_order, starts, ends).

        ``edge_order[starts[v]:ends[v]]`` are the edge ids with
        ``getattr(self, field)[e] == v``, for v in [0, n).
        """
        key = getattr(self, field)
        order = np.argsort(key, kind="stable")
        starts = np.searchsorted(key[order], np.arange(n), side="left")
        ends = np.searchsorted(key[order], np.arange(n), side="right")
        return order, starts, ends

    # -- compat / container protocol ------------------------------------
    def __len__(self) -> int:
        return int(self.src.size)

    def __getitem__(self, e: int) -> Channel:
        return Channel(
            src=int(self.src[e]),
            dst=int(self.dst[e]),
            tokens=int(self.tokens[e]),
            rate=float(self.rate[e]),
            delay=float(self.delay[e]),
            kind=KIND_NAMES[int(self.kind[e])],
        )

    def __iter__(self) -> Iterator[Channel]:
        for e in range(len(self)):
            yield self[e]

    def kind_names(self) -> list[str]:
        return [KIND_NAMES[int(k)] for k in self.kind]


ChannelsLike = Union[ChannelTable, Sequence[Channel], Iterable[Channel]]


def as_channel_table(channels: ChannelsLike) -> ChannelTable:
    if isinstance(channels, ChannelTable):
        return channels
    return ChannelTable.from_channels(channels)


@dataclasses.dataclass
class SDFG:
    """Timed event graph: actors with execution times + marked channels.

    ``channels`` is stored as a :class:`ChannelTable`; passing a
    ``list[Channel]`` to the constructor converts it once (compat path for
    tests and hand-built graphs).
    """

    n_actors: int
    exec_time: np.ndarray               # (n_actors,) tau_i
    channels: ChannelTable
    name: str = "sdfg"
    # (n_actors,) mean crossbar row length of each actor: OxRAM crosspoints
    # read per delivered spike (synapses / distinct input rows).  None means
    # "row length 1" — the flat per-spike read model of hand-built graphs.
    read_cost: Optional[np.ndarray] = None

    def __post_init__(self) -> None:
        if not isinstance(self.channels, ChannelTable):
            self.channels = as_channel_table(self.channels)
        if self.read_cost is not None:
            self.read_cost = np.asarray(self.read_cost, dtype=np.float64)
            assert self.read_cost.shape == (self.n_actors,)

    @property
    def table(self) -> ChannelTable:
        return self.channels

    @property
    def n_channels(self) -> int:
        return len(self.channels)

    def validate(self) -> None:
        assert self.exec_time.shape == (self.n_actors,)
        t = self.channels
        if len(t):
            assert t.src.min() >= 0 and t.src.max() < self.n_actors
            assert t.dst.min() >= 0 and t.dst.max() < self.n_actors
            assert t.tokens.min() >= 0

    # -- liveness: every cycle must carry >= 1 token --------------------
    def is_live(self) -> bool:
        t = self.channels
        zero = t.tokens == 0
        return _zero_token_subgraph_is_acyclic(
            self.n_actors, t.src[zero], t.dst[zero]
        )

    def edges_arrays(self):
        """(src, dst, weight, tokens) arrays; weight = tau[dst] + delay."""
        t = self.channels
        w = self.exec_time[t.dst] + t.delay
        return t.src, t.dst, w, t.tokens


def _zero_token_subgraph_is_acyclic(
    n: int, src: np.ndarray, dst: np.ndarray
) -> bool:
    """Kahn's algorithm on the zero-token edge arrays."""
    order = np.argsort(src, kind="stable")
    s_sorted = src[order]
    starts = np.searchsorted(s_sorted, np.arange(n), side="left")
    ends = np.searchsorted(s_sorted, np.arange(n), side="right")
    dst_sorted = dst[order]
    indeg = np.bincount(dst, minlength=n)
    stack = [i for i in range(n) if indeg[i] == 0]
    seen = 0
    while stack:
        u = stack.pop()
        seen += 1
        for v in dst_sorted[starts[u] : ends[u]]:
            indeg[v] -= 1
            if indeg[v] == 0:
                stack.append(int(v))
    return seen == n


# ----------------------------------------------------------------------
def sdfg_from_clusters(
    clustered: ClusteredSNN,
    exec_time: Optional[np.ndarray] = None,
    *,
    hw: Optional[HardwareConfig] = None,
) -> SDFG:
    """Build the application SDFG of a clustered SNN (§3, infinite resources).

    Channel directions follow spike flow; channels that point "backward" in
    layer order (created by partitioning, Fig. 6, or by recurrence) carry one
    initial token — the dependency they encode is on the *previous* iteration,
    which keeps RptV = [1..1] consistent and the graph live.  Every actor gets
    a one-token self-edge (Eq. 2: t_i(k) >= t_i(k-1) + tau_i).

    Fully vectorized: consumes the clustered SNN's parallel channel arrays
    and emits a :class:`ChannelTable` without materializing Channel objects.
    """
    n = clustered.n_clusters
    if exec_time is None:
        base = hw.t_fire if hw is not None else 4.0
        enc = hw.t_spike_encode if hw is not None else 0.01
        # firing cost = crossbar propagation + AER encode of produced spikes
        exec_time = base + enc * clustered.out_spikes
    exec_time = np.asarray(exec_time, dtype=np.float64)

    # topological rank of clusters: earliest layer of any member neuron
    rank = np.full(n, np.iinfo(np.int32).max, dtype=np.int64)
    np.minimum.at(
        rank, clustered.cluster_of, clustered.snn.layer_of.astype(np.int64)
    )
    # tie-break by cluster index so the 0-token subgraph is provably acyclic
    order_key = rank * (n + 1) + np.arange(n)

    actors = np.arange(n)
    self_edges = ChannelTable.from_arrays(
        src=actors,
        dst=actors,
        tokens=np.ones(n, dtype=np.int64),
        rate=np.ones(n),
        kind=KIND_SELF,
    )
    c_src, c_dst, c_rate = (
        clustered.channel_src,
        clustered.channel_dst,
        clustered.channel_rate,
    )
    data_edges = ChannelTable.from_arrays(
        src=c_src,
        dst=c_dst,
        tokens=(order_key[c_dst] <= order_key[c_src]).astype(np.int64),
        rate=np.maximum(c_rate, 1e-6),
        kind=KIND_DATA,
    )

    g = SDFG(
        n_actors=n,
        exec_time=exec_time,
        channels=ChannelTable.concat([self_edges, data_edges]),
        name=clustered.snn.name,
        # mean OxRAM row length per cluster: a spike delivered to cluster c
        # drives one row wire and reads every crosspoint on it, so its read
        # charge scales with synapses-per-input-row, not a flat unit
        read_cost=clustered.synapses_used
        / np.maximum(clustered.inputs_used, 1),
    )
    g.validate()
    assert g.is_live(), "clustered SDFG must be deadlock-free (Alg.1 line 13)"
    return g


# ----------------------------------------------------------------------
def hardware_static_parts(
    app: SDFG, hw: HardwareConfig
) -> tuple[ChannelTable, ChannelTable, ChannelTable]:
    """Binding-independent pieces of the §4.4 transformation.

    Returns ``(self_edges, flow, buffer_back_edges)``: the self-edges, the
    data/flow channels (delays still zero — they depend on the binding),
    and the Step-1 buffer back-edges with ``floor(buffer / rate)`` initial
    tokens (producing claims space, consuming releases it).  Everything a
    candidate binding does to this structure is (a) per-edge NoC delays on
    ``flow`` (:func:`flow_delays`) and (b) extra order edges — which is why
    a *batch* of candidates over one app shares these arrays row-for-row.
    """
    t = app.channels
    keep_self = t.select(t.kind == KIND_SELF)
    flow = t.select(t.kind != KIND_SELF)
    buf_tokens = np.maximum(
        1,
        (hw.tile.output_buffer // np.maximum(flow.rate, 1.0)).astype(np.int64),
    )
    back_edges = ChannelTable.from_arrays(
        src=flow.dst,
        dst=flow.src,
        tokens=buf_tokens,
        rate=flow.rate,
        kind=KIND_BUFFER,
    )
    return keep_self, flow, back_edges


def flow_delays(
    flow: ChannelTable, binding: np.ndarray, hw: HardwareConfig
) -> np.ndarray:
    """NoC delay per flow edge; ``binding`` may be (n,) or batched (B, n).

    Vectorized over the trailing edge axis, so a (B, n) binding matrix
    yields a (B, E_flow) delay matrix in one call — the per-candidate part
    of the §4.4 transformation used by the batched engine.
    """
    binding = np.asarray(binding, dtype=np.int64)
    src_t = np.take(binding, flow.src, axis=-1)
    dst_t = np.take(binding, flow.dst, axis=-1)
    return hw.comm_delay_array(flow.rate, src_t, dst_t)


def hardware_aware_sdfg(
    app: SDFG,
    binding: np.ndarray,
    hw: HardwareConfig,
    static_orders: Optional[Sequence[Sequence[int]]] = None,
) -> SDFG:
    """§4.4: fold resource constraints of the platform into the graph.

    Step 1 (buffers): each data channel (i→j) gets a back-edge (j→i) with
      ``floor(buffer / rate)`` initial tokens: producing claims space,
      consuming releases it.  Inter-tile channels also get their AER/NoC
      latency as edge delay.
    Step 2 (ordering): if per-tile static orders are given, add the TDMA
      order cycle a1→a2→…→ak→a1 (one token on the wrap-around edge), which
      serializes the tile exactly like the crossbar's atomic execution.

    The whole transformation is array-level on the :class:`ChannelTable` —
    no per-edge Python loop on the analysis hot path.
    """
    binding = np.asarray(binding, dtype=np.int64)
    assert binding.shape == (app.n_actors,)
    assert binding.max(initial=0) < hw.n_tiles

    keep_self, flow, back_edges = hardware_static_parts(app, hw)
    flow_delayed = flow.replace(delay=flow_delays(flow, binding, hw))

    parts = [keep_self, flow_delayed, back_edges]
    if static_orders is not None:
        parts.append(order_edges(static_orders, binding))

    g = SDFG(
        n_actors=app.n_actors,
        exec_time=app.exec_time,
        channels=ChannelTable.concat(parts),
        name=f"{app.name}@{hw.n_tiles}t",
    )
    g.validate()
    return g


def disjoint_union(graphs: Sequence[SDFG], name: str = "union") -> SDFG:
    """Disjoint union of SDFGs: one graph with actors offset per part.

    Part ``k``'s actors are relabeled by ``sum(n_actors of parts < k)``
    (offsets are ``np.cumsum`` of the actor counts, exclusive).  No edges
    are added between parts, so the union of live graphs is live and its
    maximum cycle ratio is the max over the parts — until a *binding*
    couples parts through shared-tile TDMA order cycles, which is exactly
    the multi-app joint-placement graph the runtime layer analyzes
    (:class:`repro.core.runtime.AdmissionController` with
    ``placement="joint"``).
    """
    assert graphs, "need at least one graph"
    offsets = np.cumsum([0] + [g.n_actors for g in graphs])
    tables = [
        g.channels.replace(src=g.channels.src + off, dst=g.channels.dst + off)
        for g, off in zip(graphs, offsets[:-1])
    ]
    if any(g.read_cost is not None for g in graphs):
        read_cost = np.concatenate([
            g.read_cost if g.read_cost is not None else np.ones(g.n_actors)
            for g in graphs
        ])
    else:
        read_cost = None
    union = SDFG(
        n_actors=int(offsets[-1]),
        exec_time=np.concatenate([g.exec_time for g in graphs]),
        channels=ChannelTable.concat(tables),
        name=name,
        read_cost=read_cost,
    )
    union.validate()
    return union


def order_edges(
    static_orders: Sequence[Sequence[int]], binding: np.ndarray
) -> ChannelTable:
    """§4.4 step 2: the per-tile TDMA order cycles as a ChannelTable."""
    srcs: list[np.ndarray] = []
    dsts: list[np.ndarray] = []
    toks: list[np.ndarray] = []
    for tile, order in enumerate(static_orders):
        o = np.asarray([a for a in order if binding[a] == tile], dtype=np.int64)
        if o.size <= 1:
            continue
        srcs.append(o)
        dsts.append(np.roll(o, -1))
        tk = np.zeros(o.size, dtype=np.int64)
        tk[-1] = 1  # one token on the wrap-around edge keeps the cycle live
        toks.append(tk)
    if not srcs:
        return ChannelTable.empty()
    src = np.concatenate(srcs)
    return ChannelTable.from_arrays(
        src=src,
        dst=np.concatenate(dsts),
        tokens=np.concatenate(toks),
        rate=np.ones(src.size),
        kind=KIND_ORDER,
    )

"""Synchronous Dataflow Graph IR for clustered SNNs (paper §3, §4).

Because every spike produced on a channel is consumed by the destination
actor within one application iteration, the repetition vector of a clustered
SNN is all-ones (§3.1, Def. 3) — i.e. the SDFG is a *timed event graph*
(homogeneous SDFG).  We therefore represent channels directly with an
integer *marking* (initial tokens, in units of actor firings) and a real
*delay* (AER communication latency), which is exactly the structure Max-Plus
Algebra analyzes (§3.2).

The hardware-aware transformation (§4.4) adds:
  * back-edges with ``floor(buffer / rate)`` initial tokens  (Step 1),
  * TDMA static-order edges per tile                         (Step 2),
  * inter-tile channel delays from the NoC model             (Step 1/3).
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Optional, Sequence

import numpy as np

from .hardware import HardwareConfig
from .partition import ClusteredSNN


@dataclasses.dataclass(frozen=True)
class Channel:
    src: int
    dst: int
    tokens: int          # initial marking (units: firings)
    rate: float          # spikes per firing on this channel (port rate)
    delay: float = 0.0   # communication latency added to the dependency
    kind: str = "data"   # data | buffer | order | self


@dataclasses.dataclass
class SDFG:
    """Timed event graph: actors with execution times + marked channels."""

    n_actors: int
    exec_time: np.ndarray               # (n_actors,) tau_i
    channels: list[Channel]
    name: str = "sdfg"

    def validate(self) -> None:
        assert self.exec_time.shape == (self.n_actors,)
        for ch in self.channels:
            assert 0 <= ch.src < self.n_actors and 0 <= ch.dst < self.n_actors
            assert ch.tokens >= 0

    # -- liveness: every cycle must carry >= 1 token --------------------
    def is_live(self) -> bool:
        return _zero_token_subgraph_is_acyclic(self.n_actors, self.channels)

    def edges_arrays(self):
        """(src, dst, weight, tokens) arrays; weight = tau[dst] + delay."""
        src = np.array([c.src for c in self.channels], dtype=np.int64)
        dst = np.array([c.dst for c in self.channels], dtype=np.int64)
        w = self.exec_time[dst] + np.array([c.delay for c in self.channels])
        m = np.array([c.tokens for c in self.channels], dtype=np.int64)
        return src, dst, w, m


def _zero_token_subgraph_is_acyclic(n: int, channels: Iterable[Channel]) -> bool:
    adj: list[list[int]] = [[] for _ in range(n)]
    indeg = np.zeros(n, dtype=np.int64)
    for c in channels:
        if c.tokens == 0:
            adj[c.src].append(c.dst)
            indeg[c.dst] += 1
    stack = [i for i in range(n) if indeg[i] == 0]
    seen = 0
    while stack:
        u = stack.pop()
        seen += 1
        for v in adj[u]:
            indeg[v] -= 1
            if indeg[v] == 0:
                stack.append(v)
    return seen == n


# ----------------------------------------------------------------------
def sdfg_from_clusters(
    clustered: ClusteredSNN,
    exec_time: Optional[np.ndarray] = None,
    *,
    hw: Optional[HardwareConfig] = None,
) -> SDFG:
    """Build the application SDFG of a clustered SNN (§3, infinite resources).

    Channel directions follow spike flow; channels that point "backward" in
    layer order (created by partitioning, Fig. 6, or by recurrence) carry one
    initial token — the dependency they encode is on the *previous* iteration,
    which keeps RptV = [1..1] consistent and the graph live.  Every actor gets
    a one-token self-edge (Eq. 2: t_i(k) >= t_i(k-1) + tau_i).
    """
    n = clustered.n_clusters
    if exec_time is None:
        base = hw.t_fire if hw is not None else 4.0
        enc = hw.t_spike_encode if hw is not None else 0.01
        # firing cost = crossbar propagation + AER encode of produced spikes
        exec_time = base + enc * clustered.out_spikes
    exec_time = np.asarray(exec_time, dtype=np.float64)

    # topological rank of clusters: earliest layer of any member neuron
    rank = np.full(n, np.iinfo(np.int32).max, dtype=np.int64)
    for neuron, c in enumerate(clustered.cluster_of):
        layer = int(clustered.snn.layer_of[neuron])
        if layer < rank[c]:
            rank[c] = layer
    # tie-break by cluster index so the 0-token subgraph is provably acyclic
    order_key = rank * (n + 1) + np.arange(n)

    channels = [Channel(i, i, 1, 1.0, kind="self") for i in range(n)]
    for (i, j), spikes in sorted(clustered.channel_spikes.items()):
        tokens = 1 if order_key[j] <= order_key[i] else 0
        channels.append(Channel(i, j, tokens, max(spikes, 1e-6), kind="data"))

    g = SDFG(n_actors=n, exec_time=exec_time, channels=channels,
             name=clustered.snn.name)
    g.validate()
    assert g.is_live(), "clustered SDFG must be deadlock-free (Alg.1 line 13)"
    return g


# ----------------------------------------------------------------------
def hardware_aware_sdfg(
    app: SDFG,
    binding: np.ndarray,
    hw: HardwareConfig,
    static_orders: Optional[Sequence[Sequence[int]]] = None,
) -> SDFG:
    """§4.4: fold resource constraints of the platform into the graph.

    Step 1 (buffers): each data channel (i→j) gets a back-edge (j→i) with
      ``floor(buffer / rate)`` initial tokens: producing claims space,
      consuming releases it.  Inter-tile channels also get their AER/NoC
      latency as edge delay.
    Step 2 (ordering): if per-tile static orders are given, add the TDMA
      order cycle a1→a2→…→ak→a1 (one token on the wrap-around edge), which
      serializes the tile exactly like the crossbar's atomic execution.
    """
    binding = np.asarray(binding, dtype=np.int64)
    assert binding.shape == (app.n_actors,)
    assert binding.max(initial=0) < hw.n_tiles

    channels: list[Channel] = []
    for ch in app.channels:
        if ch.kind == "self":
            channels.append(ch)
            continue
        src_t, dst_t = int(binding[ch.src]), int(binding[ch.dst])
        delay = hw.comm_delay(ch.rate, src_t, dst_t)
        channels.append(dataclasses.replace(ch, delay=delay))
        # Step 1: buffer back-edge. Output buffer is claimed at firing start
        # and released when the consumer drains it (§4.4 atomic execution).
        buf_tokens = max(1, int(hw.tile.output_buffer // max(ch.rate, 1.0)))
        channels.append(
            Channel(ch.dst, ch.src, buf_tokens, ch.rate, delay=0.0, kind="buffer")
        )

    if static_orders is not None:
        for tile, order in enumerate(static_orders):
            order = [a for a in order if binding[a] == tile]
            if len(order) <= 1:
                continue
            for a, b in zip(order, order[1:]):
                channels.append(Channel(a, b, 0, 1.0, kind="order"))
            channels.append(Channel(order[-1], order[0], 1, 1.0, kind="order"))

    g = SDFG(
        n_actors=app.n_actors,
        exec_time=app.exec_time,
        channels=channels,
        name=f"{app.name}@{hw.n_tiles}t",
    )
    g.validate()
    return g

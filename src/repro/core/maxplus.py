"""Max-Plus Algebra performance analysis (paper §3.2, §4.4).

Throughput of a (hardware-aware) SDFG = 1 / maximum cycle mean of its
max-plus matrix (Eq. 6).  For a timed event graph with markings ``m`` and
edge weights ``w = tau[dst] + delay`` this is the *maximum cycle ratio*

    rho_max = max over cycles C of  sum_{e in C} w(e) / sum_{e in C} m(e).

Per-graph evaluators (cross-validated in tests):

  * :func:`mcr_howard`      — Howard's policy iteration (exact, fast; default)
  * :func:`mcr_binary_search` — lambda-search + vectorized Bellman-Ford
  * :func:`mcm_power_iteration` — t_k = T (x) t_{k-1} on the explicit max-plus
    matrix ``T = A0* (x) A1`` (Eq. 4), executed with the Pallas
    ``maxplus_matmul`` kernel (VPU semiring matmul; jnp oracle on CPU).

Batched evaluator (the design-space-exploration hot path):

  * :func:`mcr_batch` — lambda-search + Bellman-Ford over an
    :class:`EdgeStack`, a *stack* of edge-weight arrays (one row per
    candidate binding / hardware config / static order).  The whole stack
    bisects together: every Bellman-Ford relaxation touches all candidates
    in one segment-max over flat arrays, so interpreter overhead is paid
    once per sweep instead of once per candidate per sweep.  Two backends:
    ``"edges"`` (float64 numpy segment-max — exact, the CPU default) and
    ``"dense"`` (max-plus matrix squaring through the Pallas
    ``maxplus_bmm`` semiring kernel on TPU / jnp oracle elsewhere —
    float32, looser tolerance, wins at large batch x actor counts).

Batched Eq.-4 evolution (the self-timed engine's start-time path):

  * :func:`maxplus_matrix_batch` — (B, n, n) matrices ``T = A0* (x) A1``
    with the Kleene star computed by repeated ``maxplus_bmm`` squaring.
  * :func:`evolve_batch` — iterate ``x(k) = T (x) x(k-1)`` for the whole
    batch through ``maxplus_bmv``; returns steady-state start vectors and
    a growth-rate period estimate (exact periods come from `mcr_batch`).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence

import numpy as np

from .sdfg import SDFG

NEG_INF = -math.inf


# ======================================================================
# Howard's policy iteration for Maximum Cycle Ratio
# ======================================================================
def mcr_howard(g: SDFG, *, eps: float = 1e-9, max_iter: int = 10_000) -> float:
    """Exact maximum cycle ratio via Howard's algorithm.

    Returns ``inf`` for a deadlocked graph (zero-token cycle) and ``-inf``
    for a graph with no cycles at all (throughput unbounded by the graph).
    """
    src, dst, w, m = g.edges_arrays()
    n = g.n_actors
    ne = src.size
    if ne == 0:
        return NEG_INF

    # adjacency: outgoing edge ids per node
    out: list[list[int]] = [[] for _ in range(n)]
    for e in range(ne):
        out[int(src[e])].append(e)

    has_out = np.array([len(o) > 0 for o in out])
    # nodes with no outgoing edge can't be on a cycle; give them a virtual
    # self-loop of ratio -inf by excluding them from policies.
    policy = np.full(n, -1, dtype=np.int64)
    for v in range(n):
        if out[v]:
            policy[v] = out[v][0]

    lam = np.full(n, NEG_INF)
    u = np.zeros(n)

    for _ in range(max_iter):
        # ---- policy evaluation -------------------------------------
        lam, u, dead = _evaluate_policy(n, policy, src, dst, w, m, has_out)
        if dead:
            return math.inf
        # ---- policy improvement ------------------------------------
        changed = False
        for e in range(ne):
            x, y = int(src[e]), int(dst[e])
            if policy[x] == -1 or lam[y] == NEG_INF:
                continue
            if lam[y] > lam[x] + eps:
                policy[x] = e
                changed = True
            elif abs(lam[y] - lam[x]) <= eps:
                cand = w[e] - lam[x] * m[e] + u[y]
                if cand > u[x] + eps:
                    policy[x] = e
                    changed = True
        if not changed:
            break
    finite = lam[np.isfinite(lam)]
    return float(finite.max()) if finite.size else NEG_INF


def _evaluate_policy(n, policy, src, dst, w, m, has_out):
    """Evaluate a policy (functional graph): per-node cycle ratio + bias."""
    lam = np.full(n, NEG_INF)
    u = np.zeros(n)
    color = np.zeros(n, dtype=np.int8)  # 0 white 1 on-stack 2 done
    dead = False

    for start in range(n):
        if color[start] != 0 or not has_out[start]:
            color[start] = 2
            continue
        path: list[int] = []
        v = start
        while color[v] == 0:
            color[v] = 1
            path.append(v)
            v = int(dst[policy[v]])
            if not has_out[v]:
                break
        if color[v] == 1:
            # found a new cycle: v .. path[-1]
            ci = path.index(v)
            cyc = path[ci:]
            wsum = sum(w[policy[x]] for x in cyc)
            msum = sum(m[policy[x]] for x in cyc)
            if msum == 0:
                dead = True
                return lam, u, dead
            ratio = wsum / msum
            for x in cyc:
                lam[x] = ratio
            # bias along the cycle: u(x) = w̄(x) + u(pi(x)), anchored u(v)=0;
            # walk the cycle backwards so each successor is resolved first
            u[v] = 0.0
            for x in reversed(cyc[1:]):
                y = int(dst[policy[x]])
                u[x] = w[policy[x]] - ratio * m[policy[x]] + u[y]
        # resolve tree part (suffix of `path` before the cycle / known node)
        for x in reversed(path):
            if lam[x] != NEG_INF:
                continue
            y = int(dst[policy[x]])
            if lam[y] == NEG_INF:
                lam[x] = NEG_INF  # leads nowhere cyclic
                u[x] = 0.0
            else:
                lam[x] = lam[y]
                u[x] = w[policy[x]] - lam[x] * m[policy[x]] + u[y]
        for x in path:
            color[x] = 2
        color[v] = 2
    return lam, u, dead


# ======================================================================
# Binary search + vectorized Bellman-Ford (independent cross-check)
# ======================================================================
def mcr_binary_search(
    g: SDFG, *, tol: float = 1e-6, lo: float = 0.0, hi: Optional[float] = None
) -> float:
    """MCR via lambda-search: a positive cycle in weights ``w - lam*m``
    exists iff lam < rho_max.  Longest-path Bellman-Ford, fully vectorized.
    """
    src, dst, w, m = g.edges_arrays()
    n = g.n_actors
    if hi is None:
        hi = float(w.sum()) + 1.0  # any cycle ratio is below total weight

    def has_positive_cycle(lam: float) -> bool:
        ww = w - lam * m
        dist = np.zeros(n)
        for _ in range(n):
            cand = dist[src] + ww
            new = dist.copy()
            np.maximum.at(new, dst, cand)
            new = np.maximum(new, dist)
            if np.allclose(new, dist, rtol=0, atol=1e-12):
                return False
            dist = new
        return True

    if not has_positive_cycle(lo + tol):
        return lo
    while hi - lo > tol:
        mid = 0.5 * (lo + hi)
        if has_positive_cycle(mid):
            lo = mid
        else:
            hi = mid
    return 0.5 * (lo + hi)


# ======================================================================
# Explicit max-plus matrix T = A0* (x) A1 and power iteration (Eq. 4)
# ======================================================================
def maxplus_matrix(g: SDFG) -> np.ndarray:
    """Build T with t_k = T (x) t_{k-1}.

    Dependencies within an iteration (0-token edges) are closed transitively
    over the acyclic 0-token subgraph (Kleene star A0*); dependencies across
    iterations (>=1-token edges) contribute A1.  Markings > 1 relax the
    dependency further into the past and — for a conservative (upper-bound
    period, lower-bound throughput) T — are kept as if 1 token; the exact
    multi-token analysis is done by :func:`mcr_howard`.
    """
    src, dst, w, m = g.edges_arrays()
    n = g.n_actors
    T = np.full((n, n), NEG_INF)

    # A1 edges: j fires after i's previous firing + w
    one = m >= 1
    for s, d, ww in zip(src[one], dst[one], w[one]):
        T[int(d), int(s)] = max(T[int(d), int(s)], float(ww))

    # longest-path closure over 0-token edges, topological order
    zero = m == 0
    z_src, z_dst, z_w = src[zero], dst[zero], w[zero]
    indeg = np.zeros(n, dtype=np.int64)
    adj: list[list[tuple[int, float]]] = [[] for _ in range(n)]
    for s, d, ww in zip(z_src, z_dst, z_w):
        adj[int(s)].append((int(d), float(ww)))
        indeg[int(d)] += 1
    topo: list[int] = [i for i in range(n) if indeg[i] == 0]
    head = 0
    while head < len(topo):
        x = topo[head]
        head += 1
        for y, _ in adj[x]:
            indeg[y] -= 1
            if indeg[y] == 0:
                topo.append(y)
    assert len(topo) == n, "0-token subgraph must be acyclic (liveness)"

    # propagate rows of T along zero edges: T[y,:] >= T[x,:] + w(x->y)
    for x in topo:
        row = T[x]
        for y, ww in adj[x]:
            np.maximum(T[y], row + ww, out=T[y])
    return T


def mcm_power_iteration(
    T: np.ndarray, *, iters: int = 200, use_kernel: bool = True
) -> float:
    """Estimate the max-plus eigenvalue (MCM) of T by power iteration.

    Uses the Pallas ``maxplus_matmul`` kernel when available; falls back to
    the pure-jnp oracle.  For irreducible T the growth rate of
    ``x_k = T (x) x_{k-1}`` converges to the MCM.
    """
    n = T.shape[0]
    if use_kernel:
        try:
            from repro.kernels import ops as kops

            matvec = kops.maxplus_matvec
        except Exception:  # pragma: no cover - kernel import fallback
            matvec = None
    else:
        matvec = None

    x = np.zeros(n)
    warm = max(4, iters // 2)
    x0_at_warm = None
    for k in range(iters):
        if matvec is not None:
            x = np.asarray(matvec(T, x))
        else:
            x = np.max(T + x[None, :], axis=1)
        # renormalize to avoid drift; track growth of the max component
        mx = x.max()
        if not np.isfinite(mx):
            return float(mx)
        if k == warm:
            x0_at_warm = mx
        if mx > 1e12:
            x -= mx
            if x0_at_warm is not None:
                x0_at_warm -= mx
    if x0_at_warm is None:  # pragma: no cover
        return float("nan")
    return float((x.max() - x0_at_warm) / (iters - 1 - warm))


# ======================================================================
# Batched analysis: lambda-search over a stack of edge-weight arrays
# ======================================================================
@dataclasses.dataclass(frozen=True)
class EdgeStack:
    """A batch of timed event graphs as parallel edge arrays.

    Row ``b`` is one candidate graph (a binding / hardware config / static
    order under evaluation).  All rows share the padded edge count ``E`` and
    actor count ``n_actors``; padding slots carry ``weights = -inf``, which
    is the (max,+) neutral element, so they never influence any longest
    path.  Markings may differ per row (buffer sizes are a design axis).
    """

    n_actors: int
    src: np.ndarray       # (B, E) int64
    dst: np.ndarray       # (B, E) int64
    tokens: np.ndarray    # (B, E) int64
    weights: np.ndarray   # (B, E) float64; -inf marks an inactive slot

    @property
    def n_graphs(self) -> int:
        return int(self.weights.shape[0])

    @property
    def n_edges(self) -> int:
        return int(self.weights.shape[1])


def stack_graphs(graphs: Sequence[SDFG]) -> EdgeStack:
    """Pack per-graph edge arrays into one padded :class:`EdgeStack`.

    Graphs may have different topologies and actor counts; rows are padded
    to the maximum edge count with -inf-weight slots and to the maximum
    actor count (extra actors are isolated, so they cannot join a cycle).
    """
    assert graphs, "need at least one graph"
    b = len(graphs)
    n = max(g.n_actors for g in graphs)
    e = max(g.n_channels for g in graphs)
    src = np.zeros((b, e), dtype=np.int64)
    dst = np.zeros((b, e), dtype=np.int64)
    tokens = np.ones((b, e), dtype=np.int64)
    weights = np.full((b, e), NEG_INF)
    for i, g in enumerate(graphs):
        s, d, w, m = g.edges_arrays()
        k = s.size
        src[i, :k] = s
        dst[i, :k] = d
        weights[i, :k] = w
        tokens[i, :k] = m
    return EdgeStack(n_actors=n, src=src, dst=dst, tokens=tokens, weights=weights)


def _bisection_bounds(
    stack: EdgeStack, upper: np.ndarray, lo0: Optional[np.ndarray]
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Shared lambda-search bootstrap for both mcr backends.

    Returns ``(lo, hi, has_cycle)``: the per-row lower bound from one-token
    self-loop cycles folded with the caller's sound ``lo0`` bounds, the
    bisection interval top ``max(upper, lo) + 1``, and which rows are
    already known to contain a cycle.
    """
    finite = np.isfinite(stack.weights)
    self_loop = finite & (stack.src == stack.dst) & (stack.tokens > 0)
    ratio = np.where(self_loop, stack.weights / np.maximum(stack.tokens, 1), NEG_INF)
    lo = np.maximum(ratio.max(axis=1, initial=NEG_INF), 0.0)
    has_cycle = ratio.max(axis=1, initial=NEG_INF) > NEG_INF
    if lo0 is not None:
        lo0 = np.asarray(lo0, dtype=np.float64)
        lo = np.maximum(lo, np.where(np.isfinite(lo0), lo0, NEG_INF))
        has_cycle |= np.isfinite(lo0)
    hi = np.maximum(upper, lo) + 1.0
    return lo, hi, has_cycle


def _upper_path_bound(
    stack: EdgeStack,
    order: np.ndarray,
    uniq_keys: np.ndarray,
    seg_starts: np.ndarray,
) -> np.ndarray:
    """(B,) sound upper bound on any simple-path (hence cycle) weight.

    A simple path or cycle enters each node at most once, so its weight is
    bounded by the per-row sum over nodes of the (positive part of the)
    heaviest incoming edge.  Much tighter than summing every positive edge
    weight when the average in-degree is high, which shrinks both the
    bisection interval and the distance threshold that detects a pumping
    positive cycle.
    """
    b, n = stack.n_graphs, stack.n_actors
    max_in = np.full(b * n, NEG_INF)
    max_in[uniq_keys] = np.maximum.reduceat(stack.weights.ravel()[order], seg_starts)
    return np.clip(max_in.reshape(b, n), 0.0, None).sum(axis=1)


def _positive_cycle_masks(
    stack: EdgeStack,
    lam: np.ndarray,
    src_ord: np.ndarray,
    w_ord: np.ndarray,
    t_ord: np.ndarray,
    row_ord: np.ndarray,
    key_row: np.ndarray,
    uniq_keys: np.ndarray,
    seg_starts: np.ndarray,
    upper: np.ndarray,
    active: Optional[np.ndarray] = None,
    *,
    atol: float = 1e-12,
) -> np.ndarray:
    """Per-row: does weights - lam*tokens contain a positive cycle?

    One vectorized longest-path Bellman-Ford over the whole batch.  A row
    resolves early when a relaxation round changes nothing (no positive
    cycle) or when any distance exceeds the row's maximum simple-path
    weight (positive cycle — only a cycle can pump past it).  Rows outside
    ``active`` start resolved: their probe point sits at (or below) the
    true cycle ratio, where relaxation may never settle, and their answer
    is discarded by the caller anyway — without this, one slow row would
    drag every later bisection step to the full n+1 rounds.

    The relaxation runs in destination-key space: only actors with an
    incoming edge (``uniq_keys``) can ever move off the zero start
    distance, and a zero distance can never exceed ``upper + 1``
    (``upper >= 0``), so tracking the ``(n_keys,)`` vector is exact while
    skipping every full ``(b*n,)`` copy/compare of the dense form.  Edge
    arrays arrive pre-permuted into segment order (``*_ord``), removing
    the per-round gather through ``order``.
    """
    b, n = stack.n_graphs, stack.n_actors
    ww = w_ord - lam[row_ord] * t_ord
    dist = np.zeros(b * n)
    dist_k = np.zeros(len(uniq_keys))
    over_key = upper[key_row] + 1.0
    positive = np.zeros(b, dtype=bool)
    resolved = np.zeros(b, dtype=bool) if active is None else ~active
    for _ in range(n + 1):
        seg_max = np.maximum.reduceat(dist[src_ord] + ww, seg_starts)
        improved = (seg_max - dist_k) > atol
        row_changed = np.bincount(key_row, weights=improved, minlength=b) > 0
        resolved |= ~row_changed
        np.maximum(dist_k, seg_max, out=dist_k)
        over = (
            np.bincount(key_row, weights=dist_k > over_key, minlength=b) > 0
        ) & ~resolved
        positive |= over
        resolved |= over
        dist[uniq_keys] = dist_k
        if resolved.all():
            break
    # rows still improving after n+1 rounds must contain a positive cycle
    positive |= ~resolved
    return positive


def mcr_batch(
    stack: EdgeStack,
    *,
    rel_tol: float = 1e-8,
    max_steps: int = 80,
    backend: str = "auto",
    lo0: Optional[np.ndarray] = None,
    detect_deadlock: bool = False,
    devices: Optional[Sequence] = None,
) -> np.ndarray:
    """Maximum cycle ratio for every row of an :class:`EdgeStack`.

    Lambda-search: a positive cycle in ``weights - lam*tokens`` exists iff
    ``lam < rho_max`` — all rows bisect together.  Inputs must be live
    graphs (a zero-token cycle drives the result to the upper bound instead
    of ``inf``); every graph built by this pipeline is live by construction.
    ``detect_deadlock=True`` adds one probe at the interval top, where any
    remaining positive cycle must be a zero-token one (every token-carrying
    cycle's ratio is bounded by ``upper < hi``), and reports those rows as
    ``inf`` — for callers feeding graphs of unknown liveness.

    Returns a ``(B,)`` float64 array of cycle ratios in the same time unit
    as ``stack.weights`` (microseconds throughout this pipeline);
    ``-inf`` marks an acyclic row.  ``lo0``, when given, is a ``(B,)``
    per-row *sound lower bound* on the cycle ratio (the ratio of any cycle
    the caller knows exists — e.g. a TDMA order cycle's compute sum); it
    shrinks the bisection interval and never changes the result.

    ``backend``: ``"edges"`` (numpy float64, exact — the bit-exactness
    oracle and the default on hosts without an accelerator), ``"csr-jit"``
    (the same exact float64 search as one jitted device program with
    multi-lambda probing — default when any non-CPU device is present),
    ``"dense"`` (Pallas/jnp max-plus matrix squaring, float32, opt-in), or
    ``"auto"``.

    ``devices`` (``"csr-jit"`` only): two or more jax devices shard the
    batch axis — contiguous row chunks solved concurrently, one per
    device, bit-identical to the unsharded solve; a single device pins
    the solve to it.  Forces ``"csr-jit"`` under ``"auto"``.
    """
    if backend == "auto":
        backend = (
            "csr-jit" if (_on_accelerator() or (devices and len(devices) > 1))
            else "edges"
        )
    if devices and backend != "csr-jit":
        raise ValueError(
            f"devices= requires the 'csr-jit' backend, got {backend!r}"
        )
    if backend == "dense":
        if detect_deadlock:
            raise ValueError("detect_deadlock is not supported by 'dense'")
        # float32 squaring can't resolve below ~1e-4 relative; honor a
        # caller-requested looser tolerance but clamp tighter requests
        return _mcr_batch_dense(
            stack, max_steps=max_steps, rel_tol=max(rel_tol, 1e-4), lo0=lo0
        )
    if backend == "csr-jit":
        return _mcr_batch_csr(
            stack, max_steps=max_steps, rel_tol=rel_tol, lo0=lo0,
            detect_deadlock=detect_deadlock, devices=devices,
        )
    assert backend == "edges", backend

    b, n, e = stack.n_graphs, stack.n_actors, stack.n_edges
    if e == 0:
        return np.full(b, NEG_INF)

    # flat batched CSR over (row, dst): segment-max targets, computed once
    rows = np.arange(b, dtype=np.int64)[:, None]
    flat_src = (rows * n + stack.src).ravel()
    flat_dst = (rows * n + stack.dst).ravel()
    order = np.argsort(flat_dst, kind="stable")
    uniq_keys, seg_starts = np.unique(flat_dst[order], return_index=True)
    # segment-ordered edge views + key->row map, hoisted out of the probes
    src_ord = flat_src[order]
    w_ord = stack.weights.ravel()[order]
    t_ord = stack.tokens.ravel()[order]
    row_ord = order // e
    key_row = uniq_keys // n

    upper = _upper_path_bound(stack, order, uniq_keys, seg_starts)
    lo, hi, has_cycle = _bisection_bounds(stack, upper, lo0)

    deadlocked = np.zeros(b, dtype=bool)
    if detect_deadlock:
        deadlocked = _positive_cycle_masks(
            stack, hi, src_ord, w_ord, t_ord, row_ord, key_row,
            uniq_keys, seg_starts, upper, None,
        )

    for _ in range(max_steps):
        tol = rel_tol * np.maximum(1.0, np.abs(hi))
        active = ((hi - lo) > tol) & ~deadlocked
        if not active.any():
            break
        mid = np.where(active, 0.5 * (lo + hi), lo)
        pos = _positive_cycle_masks(
            stack, mid, src_ord, w_ord, t_ord, row_ord, key_row,
            uniq_keys, seg_starts, upper, active,
        )
        has_cycle |= active & pos
        lo = np.where(active & pos, mid, lo)
        hi = np.where(active & ~pos, mid, hi)
    # rows that never showed a positive cycle at any probed lambda (and have
    # no self-loop cycle) are acyclic: no cycle bounds their throughput
    res = np.where(has_cycle, 0.5 * (lo + hi), NEG_INF)
    return np.where(deadlocked, np.inf, res) if detect_deadlock else res


def _pack_csr_chunk(
    stack: EdgeStack, lo0: Optional[np.ndarray]
) -> Optional[tuple]:
    """Host-side packing of one (chunk of an) EdgeStack for the device
    bisection: flat batched CSR -> layout operands + bisection bounds.

    Returns ``(operands, layout, lo, hi, has_cycle)`` or ``None`` when the
    chunk has no finite edge at all (every row is acyclic padding — the
    caller reports those rows as ``-inf`` without a solve).  Packing a
    row subset independently is exact: the ELL width tracks the chunk's
    own in-degree profile and pad slots carry the ``-inf`` neutral
    element, so per-row results never depend on which rows share the
    pack.
    """
    b, n, e = stack.n_graphs, stack.n_actors, stack.n_edges
    rows = np.arange(b, dtype=np.int64)[:, None]
    flat_src = (rows * n + stack.src).ravel()
    flat_dst = (rows * n + stack.dst).ravel()
    # drop -inf padding slots before building the device layout: they all
    # target actor 0 of their row (EdgeStack zero-fills indices), so keeping
    # them would blow the ELL width up to the padding count; the neutral
    # element contributes nothing anyway
    keep = np.isfinite(stack.weights.ravel())
    flat_src = flat_src[keep]
    flat_dst = flat_dst[keep]
    w_flat = stack.weights.ravel()[keep]
    t_flat = stack.tokens.ravel()[keep].astype(np.float64)
    row_flat = np.repeat(np.arange(b, dtype=np.int64), keep.reshape(b, e).sum(axis=1))
    if flat_dst.size == 0:
        return None
    order = np.argsort(flat_dst, kind="stable")
    uniq_keys, seg_starts = np.unique(flat_dst[order], return_index=True)
    src_ord = flat_src[order]
    dst_ord = flat_dst[order]
    w_ord = w_flat[order]
    t_ord = t_flat[order]

    # per-row simple-path bound (same construction as _upper_path_bound,
    # over the filtered edge set — identical values, pads are -inf)
    max_in = np.full(b * n, NEG_INF)
    max_in[uniq_keys] = np.maximum.reduceat(w_ord, seg_starts)
    upper = np.clip(max_in.reshape(b, n), 0.0, None).sum(axis=1)
    lo, hi, has_cycle = _bisection_bounds(stack, upper, lo0)

    from repro.kernels.ops import _on_tpu as _kernels_on_tpu

    if _kernels_on_tpu():
        operands = (src_ord, dst_ord, w_ord, t_ord, row_flat[order])
        layout = "segment-pallas"
    else:
        operands = _ell_pack(
            src_ord, dst_ord, w_ord, t_ord, b * n, uniq_keys, seg_starts
        )
        layout = "ell"
    return operands, layout, lo, hi, has_cycle


def _mcr_batch_csr(
    stack: EdgeStack,
    *,
    max_steps: int = 80,
    rel_tol: float = 1e-8,
    lo0: Optional[np.ndarray] = None,
    detect_deadlock: bool = False,
    k_probes: Optional[int] = None,
    devices: Optional[Sequence] = None,
) -> np.ndarray:
    """Device-resident exact lambda-search (the ``"csr-jit"`` backend).

    Same flat batched CSR packing and path bounds as the ``"edges"`` path,
    but the entire bisection — multi-lambda probes, Bellman-Ford
    relaxation rounds, interval updates — runs inside one jitted float64
    program (:func:`repro.kernels.maxplus_bellman.csr_bisect`): zero
    host/device round-trips per probe, and every relaxation sweep shrinks
    the interval ``(K+1)x``.  Exact to the same ``rel_tol`` contract as
    ``"edges"``; the two agree to bisection-interval width on every row.

    ``devices`` (two or more jax devices) shards the batch axis: the rows
    split into ``len(devices)`` contiguous chunks, each packed and solved
    on its own device with all chunks in flight at once
    (:func:`repro.kernels.maxplus_bellman.mcr_bisect_device_sharded`).
    Per-row results are bit-identical to the unsharded solve — the
    lambda-search is row-local — so device count never changes a result.
    A single device in ``devices`` pins the unsharded solve to it.
    """
    from repro.kernels import maxplus_bellman as kbell

    b, n, e = stack.n_graphs, stack.n_actors, stack.n_edges
    if e == 0:
        return np.full(b, NEG_INF)
    if k_probes is None:
        k_probes = kbell.DEFAULT_K_PROBES
    # multi-probe steps shrink the interval (k+1)x per sweep, so the
    # classic bisection budget over-covers by the same log factor
    steps = max(4, int(math.ceil(max_steps / math.log2(k_probes + 1))) + 1)

    devices = list(devices) if devices else []
    n_chunks = min(len(devices), b) if len(devices) > 1 else 1

    if n_chunks <= 1:
        packed = _pack_csr_chunk(stack, lo0)
        if packed is None:
            return np.full(b, NEG_INF)
        operands, layout, lo, hi, has_cycle = packed
        lo, hi, has_cycle, deadlocked = kbell.mcr_bisect_device(
            operands, lo, hi, has_cycle,
            n_actors=n, rel_tol=rel_tol, k_probes=k_probes, max_steps=steps,
            detect_deadlock=detect_deadlock, layout=layout,
            device=devices[0] if devices else None,
        )
        res = np.where(has_cycle, 0.5 * (lo + hi), NEG_INF)
        return np.where(deadlocked, np.inf, res) if detect_deadlock else res

    # sharded: contiguous near-equal row chunks, chunk k on devices[k]
    # (the launch-layer sharding rule, so boundaries match everywhere).
    # Every chunk is padded with all--inf rows to the LARGEST chunk's row
    # count: with a bucket-padded caller batch the per-device solve shape
    # is then identical across chunks AND across calls, so each device
    # compiles once and stays on its cached executable.  Pad rows carry
    # no finite edge — they start converged and never touch real rows.
    from repro.launch.sharding import row_chunks

    res = np.full(b, NEG_INF)
    dead = np.zeros(b, dtype=bool)
    chunk_slices = row_chunks(b, n_chunks)
    rows_max = max(sl.stop - sl.start for sl in chunk_slices)
    chunks, slices, devs, layout = [], [], [], None
    for k, sl in enumerate(chunk_slices):
        m = sl.stop - sl.start
        pad = rows_max - m
        src, dst = stack.src[sl], stack.dst[sl]
        tok, wts = stack.tokens[sl], stack.weights[sl]
        lo0_c = lo0[sl] if lo0 is not None else None
        if pad:
            src = np.concatenate([src, np.zeros((pad, e), dtype=src.dtype)])
            dst = np.concatenate([dst, np.zeros((pad, e), dtype=dst.dtype)])
            tok = np.concatenate([tok, np.ones((pad, e), dtype=tok.dtype)])
            wts = np.concatenate([wts, np.full((pad, e), NEG_INF)])
            if lo0_c is not None:
                lo0_c = np.concatenate([lo0_c, np.full(pad, NEG_INF)])
        sub = EdgeStack(n_actors=n, src=src, dst=dst, tokens=tok, weights=wts)
        packed = _pack_csr_chunk(sub, lo0_c)
        if packed is None:
            continue                       # all-padding rows stay -inf
        operands, layout, lo_c, hi_c, hc_c = packed
        chunks.append((operands, lo_c, hi_c, hc_c))
        slices.append(sl)
        devs.append(devices[k % len(devices)])
    if not chunks:
        return res
    lo, hi, has_cycle, deadlocked = kbell.mcr_bisect_device_sharded(
        chunks, devs,
        n_actors=n, rel_tol=rel_tol, k_probes=k_probes, max_steps=steps,
        detect_deadlock=detect_deadlock, layout=layout,
    )
    for k, sl in enumerate(slices):
        m = sl.stop - sl.start
        part = slice(k * rows_max, k * rows_max + m)
        res[sl] = np.where(
            has_cycle[part], 0.5 * (lo[part] + hi[part]), NEG_INF
        )
        dead[sl] = deadlocked[part]
    return np.where(dead, np.inf, res) if detect_deadlock else res


def _ell_pack(
    src_ord: np.ndarray,
    dst_ord: np.ndarray,
    w_ord: np.ndarray,
    t_ord: np.ndarray,
    n_keys: int,
    uniq_keys: np.ndarray,
    seg_starts: np.ndarray,
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """dst-sorted flat edges -> ELLPACK ``(B*n, d_max)`` incoming-edge rows.

    Pad slots point at node 0 with -inf weight (the (max,+) neutral), so
    the degree-axis max ignores them.  ``d_max`` is rounded up to the next
    power of two: the device program's shapes then only change when the
    in-degree profile crosses a bucket, not on every edge-count wiggle.
    """
    counts = np.diff(np.append(seg_starts, src_ord.size))
    d_max = int(counts.max(initial=1))
    d_max = 1 << (d_max - 1).bit_length()
    pos = np.arange(src_ord.size) - np.repeat(seg_starts, counts)
    row_idx = dst_ord
    ell_src = np.zeros((n_keys, d_max), dtype=np.int32)
    ell_w = np.full((n_keys, d_max), NEG_INF)
    ell_t = np.zeros((n_keys, d_max))
    ell_src[row_idx, pos] = src_ord
    ell_w[row_idx, pos] = w_ord
    ell_t[row_idx, pos] = t_ord
    return ell_src, ell_w, ell_t


def _on_tpu() -> bool:
    # lazy: keep repro.core importable without pulling jax in at load time
    try:
        from repro.kernels.ops import _on_tpu as kernels_on_tpu

        return kernels_on_tpu()
    except Exception:  # pragma: no cover - jax is a hard dep in practice
        return False


def _on_accelerator() -> bool:
    # lazy for the same reason; any non-CPU jax device (TPU *or* GPU)
    try:
        from repro.kernels.ops import _on_accelerator as kernels_on_accel

        return kernels_on_accel()
    except Exception:  # pragma: no cover - jax is a hard dep in practice
        return False


#: squaring rounds the last :func:`_mcr_batch_dense` call actually ran,
#: one entry per bisection step (instrumentation for tests/benchmarks).
#: With PR-3 path-doubling shortcut edges in the stack
#: (:func:`~repro.core.engine.stack_hardware_aware` with
#: ``relax_shortcuts=True``) the value fixpoint arrives after about
#: log2(shortcut-reduced hop diameter) rounds — the log2(n) bound is
#: only the sound worst-case cap.
_DENSE_LAST_ROUNDS: list[int] = []


def _maxplus_fixpoint(a: np.ndarray, b: np.ndarray) -> bool:
    """True when one more max-plus squaring left the closure unchanged.

    Supports must match exactly; finite entries may drift by float32
    re-association slack (the max of the SAME path weights summed in a
    different association order), so they compare under a relative
    tolerance two decades tighter than the dense backend's 1e-4 growth
    threshold.  A positive cycle above that threshold keeps pumping the
    on-cycle entries geometrically (budget doubles each squaring), so it
    can never masquerade as a fixpoint.
    """
    fa, fb = np.isfinite(a), np.isfinite(b)
    if not np.array_equal(fa, fb):
        return False
    av, bv = a[fa], b[fa]
    if av.size == 0:
        return True
    return bool(
        (np.abs(av - bv) <= 1e-6 * np.maximum(1.0, np.abs(bv))).all()
    )


def _mcr_batch_dense(
    stack: EdgeStack,
    *,
    max_steps: int = 60,
    rel_tol: float = 1e-4,
    lo0: Optional[np.ndarray] = None,
) -> np.ndarray:
    """Dense-kernel lambda-search: positive-cycle detection by max-plus
    matrix squaring through :func:`repro.kernels.ops.maxplus_bmm`.

    ``W[b, i, j] = max over edges j->i of (w - lam*m)`` with a 0 diagonal
    (the (max,+) identity is folded in), so ``W^(2^k)`` holds longest paths
    of length <= 2^k.  With ``2^k >= n_actors`` the paths saturate unless a
    positive cycle keeps pumping — one extra relaxation detects growth.
    float32 on the kernel path, so tolerances are looser than ``"edges"``.

    The squaring count is NOT fixed at log2(n): that is only the cap.
    Each bisection step squares until the closure stops changing
    (:func:`_maxplus_fixpoint`), which it does once ``2^k`` covers the
    graph's hop diameter.  Stacks built by
    :func:`~repro.core.engine.stack_hardware_aware` with
    ``relax_shortcuts=True`` carry the PR-3 order-cycle path-doubling
    shortcut edges, which collapse the length-k TDMA order cycles — the
    hop diameter of the hardware-aware graph — to O(log k) hops, so the
    fixpoint lands after ~log2(shortcut-reduced diameter) rounds instead
    of log2(n).  Saturation implies no positive cycle above the growth
    threshold (a positive cycle doubles its pumping budget every
    squaring, growing geometrically), so the early exit never flips the
    per-step verdict.  Realized round counts land in
    :data:`_DENSE_LAST_ROUNDS` for tests and benchmarks.
    """
    from repro.kernels import ops as kops

    b, n = stack.n_graphs, stack.n_actors
    finite = np.isfinite(stack.weights)
    # loose positive-weight-sum upper bound: the float32 squaring path
    # saturates long before a per-node bound would pay off
    wpos = np.where(finite & (stack.weights > 0), stack.weights, 0.0)
    upper = wpos.sum(axis=1)
    lo, hi, has_cycle = _bisection_bounds(stack, upper, lo0)

    rows = np.arange(b, dtype=np.int64)[:, None]
    flat = (rows * n * n + stack.dst * n + stack.src).ravel()
    order = np.argsort(flat, kind="stable")
    uniq_keys, seg_starts = np.unique(flat[order], return_index=True)
    diag = np.arange(n)
    n_sq_cap = max(1, int(math.ceil(math.log2(max(n, 2)))))
    _DENSE_LAST_ROUNDS.clear()

    for _ in range(max_steps):
        tol = rel_tol * np.maximum(1.0, np.abs(hi))
        active = (hi - lo) > tol
        if not active.any():
            break
        mid = np.where(active, 0.5 * (lo + hi), lo)
        ww = (stack.weights - mid[:, None] * stack.tokens).ravel()
        w_dense = np.full(b * n * n, NEG_INF, dtype=np.float32)
        w_dense[uniq_keys] = np.maximum.reduceat(
            ww[order].astype(np.float32), seg_starts
        )
        w_dense = w_dense.reshape(b, n, n)
        w_dense[:, diag, diag] = np.maximum(w_dense[:, diag, diag], 0.0)

        m_pow = w_dense
        rounds = 0
        for _ in range(n_sq_cap):
            m_new = np.asarray(kops.maxplus_bmm(m_pow, m_pow))
            rounds += 1
            saturated = _maxplus_fixpoint(m_new, m_pow)
            m_pow = m_new
            if saturated:
                break
        _DENSE_LAST_ROUNDS.append(rounds)
        dist = m_pow.max(axis=2)                       # paths from 0-vector
        dist1 = (w_dense + dist[:, None, :]).max(axis=2)
        growth = np.maximum(1.0, np.abs(dist)) * 1e-4
        pos = np.logical_or.reduce(dist1 > dist + growth, axis=1)
        has_cycle |= active & pos
        lo = np.where(active & pos, mid, lo)
        hi = np.where(active & ~pos, mid, hi)
    # rows that never showed a positive cycle at any probed lambda (and have
    # no self-loop cycle) are acyclic — same convention as the edges backend
    return np.where(has_cycle, 0.5 * (lo + hi), NEG_INF).astype(np.float64)


def _dense_weight_matrix(
    stack: EdgeStack, mask: np.ndarray, *, dtype=np.float32
) -> np.ndarray:
    """(B, n, n) dense ``W[b, d, s] = max weight over masked edges s->d``."""
    b, n = stack.n_graphs, stack.n_actors
    w = np.full(b * n * n, NEG_INF, dtype=dtype)
    rows = np.arange(b, dtype=np.int64)[:, None]
    flat = (rows * n * n + stack.dst * n + stack.src).ravel()
    sel = mask.ravel()
    fl = flat[sel]
    if fl.size:
        ww = stack.weights.ravel()[sel].astype(dtype)
        order = np.argsort(fl, kind="stable")
        uniq, seg = np.unique(fl[order], return_index=True)
        w[uniq] = np.maximum.reduceat(ww[order], seg)
    return w.reshape(b, n, n)


def maxplus_matrix_batch(stack: EdgeStack) -> np.ndarray:
    """Batched Eq.-4 matrices: ``T[b] = A0*[b] (x) A1[b]`` as (B, n, n).

    The per-graph construction (:func:`maxplus_matrix`) walks the 0-token
    subgraph in topological order; the batched one instead computes the
    Kleene star ``A0* = (I (+) A0)^(2^ceil(log2 n))`` by repeated max-plus
    squaring through the Pallas ``maxplus_bmm`` kernel — every candidate's
    closure advances together.  Multi-token edges are conservatively kept
    as one-token dependencies (same convention as :func:`maxplus_matrix`);
    exact multi-token periods come from :func:`mcr_batch`.  Rows must be
    live (an acyclic 0-token subgraph), which this pipeline guarantees.
    """
    from repro.kernels import ops as kops

    n = stack.n_actors
    finite = np.isfinite(stack.weights)
    w0 = _dense_weight_matrix(stack, finite & (stack.tokens == 0))
    w1 = _dense_weight_matrix(stack, finite & (stack.tokens >= 1))
    diag = np.arange(n)
    star = w0
    star[:, diag, diag] = np.maximum(star[:, diag, diag], 0.0)
    for _ in range(max(1, int(math.ceil(math.log2(max(n, 2)))))):
        star = np.asarray(kops.maxplus_bmm(star, star))
    return np.asarray(kops.maxplus_bmm(star, w1))


def evolve_batch(
    t_batch: np.ndarray, *, iters: int = 64, x0: Optional[np.ndarray] = None
) -> tuple[np.ndarray, np.ndarray]:
    """Iterate ``x(k) = T (x) x(k-1)`` for a whole batch of candidates.

    Returns ``(x, period_estimate)``: the final (renormalized) start-time
    vectors, whose *relative* offsets converge to the steady-state static
    schedule, and the mean per-iteration growth over the tail half of the
    run — a float32 MCM estimate (use :func:`mcr_batch` when the exact
    period is needed).  Each step renormalizes by the row maximum (max-plus
    scaling invariance) so float32 never accumulates drift.
    """
    from repro.kernels import ops as kops

    t_batch = np.asarray(t_batch, dtype=np.float32)
    b, n, _ = t_batch.shape
    if x0 is None:
        x = np.zeros((b, n), dtype=np.float32)
    else:
        x = np.array(x0, dtype=np.float32, copy=True)
    warm = max(1, iters // 2)
    growth = np.zeros(b)
    counted = 0
    for k in range(iters):
        x = np.asarray(kops.maxplus_bmv(t_batch, x))
        mx = np.where(np.isfinite(x), x, NEG_INF).max(axis=1)
        step = np.where(np.isfinite(mx), mx, 0.0)
        x = x - step[:, None].astype(np.float32)
        if k >= warm:
            growth += step
            counted += 1
    return x.astype(np.float64), growth / max(counted, 1)


def throughput_batch(
    graphs: Sequence[SDFG],
    *,
    backend: str = "auto",
    rel_tol: float = 1e-8,
    group_factor: float = 1.5,
) -> np.ndarray:
    """Per-graph throughput (1/MCR) for a batch of graphs.

    Rows of an :class:`EdgeStack` all pay the padded maximum edge and actor
    count, so stacking a 20-actor graph with a 700-actor one wastes most of
    the sweep.  Graphs are therefore grouped into similar-size sub-stacks
    (within ``group_factor`` in both actors and edges) and each group is
    analyzed in one :func:`mcr_batch` call; a homogeneous batch (the common
    sweep/admission shape) stays a single call.
    """
    order = sorted(
        range(len(graphs)), key=lambda i: (graphs[i].n_actors, graphs[i].n_channels)
    )
    groups: list[list[int]] = []
    for i in order:
        if groups:
            anchor = graphs[groups[-1][0]]
            g = graphs[i]
            if (
                g.n_actors <= group_factor * max(anchor.n_actors, 1)
                and g.n_channels <= group_factor * max(anchor.n_channels, 1)
            ):
                groups[-1].append(i)
                continue
        groups.append([i])

    out = np.zeros(len(graphs))
    for grp in groups:
        rho = mcr_batch(
            stack_graphs([graphs[i] for i in grp]), backend=backend, rel_tol=rel_tol
        )
        ok = np.isfinite(rho) & (rho > 0)
        out[np.asarray(grp)[ok]] = 1.0 / rho[ok]
    return out


# ======================================================================
def throughput(g: SDFG, *, method: str = "howard") -> float:
    """Application throughput = 1 / MCM (paper's headline metric)."""
    if method == "howard":
        rho = mcr_howard(g)
    elif method == "binary":
        rho = mcr_binary_search(g)
    elif method == "power":
        rho = mcm_power_iteration(maxplus_matrix(g))
    else:
        raise ValueError(f"unknown method {method!r}")
    if rho <= 0 or not np.isfinite(rho):
        return 0.0
    return 1.0 / rho

"""Max-Plus Algebra performance analysis (paper §3.2, §4.4).

Throughput of a (hardware-aware) SDFG = 1 / maximum cycle mean of its
max-plus matrix (Eq. 6).  For a timed event graph with markings ``m`` and
edge weights ``w = tau[dst] + delay`` this is the *maximum cycle ratio*

    rho_max = max over cycles C of  sum_{e in C} w(e) / sum_{e in C} m(e).

Three independent evaluators are provided (cross-validated in tests):

  * :func:`mcr_howard`      — Howard's policy iteration (exact, fast; default)
  * :func:`mcr_binary_search` — lambda-search + vectorized Bellman-Ford
  * :func:`mcm_power_iteration` — t_k = T (x) t_{k-1} on the explicit max-plus
    matrix ``T = A0* (x) A1`` (Eq. 4), executed with the Pallas
    ``maxplus_matmul`` kernel (VPU semiring matmul; jnp oracle on CPU).
"""

from __future__ import annotations

import math
from typing import Optional

import numpy as np

from .sdfg import SDFG

NEG_INF = -math.inf


# ======================================================================
# Howard's policy iteration for Maximum Cycle Ratio
# ======================================================================
def mcr_howard(g: SDFG, *, eps: float = 1e-9, max_iter: int = 10_000) -> float:
    """Exact maximum cycle ratio via Howard's algorithm.

    Returns ``inf`` for a deadlocked graph (zero-token cycle) and ``-inf``
    for a graph with no cycles at all (throughput unbounded by the graph).
    """
    src, dst, w, m = g.edges_arrays()
    n = g.n_actors
    ne = src.size
    if ne == 0:
        return NEG_INF

    # adjacency: outgoing edge ids per node
    out: list[list[int]] = [[] for _ in range(n)]
    for e in range(ne):
        out[int(src[e])].append(e)

    has_out = np.array([len(o) > 0 for o in out])
    # nodes with no outgoing edge can't be on a cycle; give them a virtual
    # self-loop of ratio -inf by excluding them from policies.
    policy = np.full(n, -1, dtype=np.int64)
    for v in range(n):
        if out[v]:
            policy[v] = out[v][0]

    lam = np.full(n, NEG_INF)
    u = np.zeros(n)

    for _ in range(max_iter):
        # ---- policy evaluation -------------------------------------
        lam, u, dead = _evaluate_policy(n, policy, src, dst, w, m, has_out)
        if dead:
            return math.inf
        # ---- policy improvement ------------------------------------
        changed = False
        for e in range(ne):
            x, y = int(src[e]), int(dst[e])
            if policy[x] == -1 or lam[y] == NEG_INF:
                continue
            if lam[y] > lam[x] + eps:
                policy[x] = e
                changed = True
            elif abs(lam[y] - lam[x]) <= eps:
                cand = w[e] - lam[x] * m[e] + u[y]
                if cand > u[x] + eps:
                    policy[x] = e
                    changed = True
        if not changed:
            break
    finite = lam[np.isfinite(lam)]
    return float(finite.max()) if finite.size else NEG_INF


def _evaluate_policy(n, policy, src, dst, w, m, has_out):
    """Evaluate a policy (functional graph): per-node cycle ratio + bias."""
    lam = np.full(n, NEG_INF)
    u = np.zeros(n)
    color = np.zeros(n, dtype=np.int8)  # 0 white 1 on-stack 2 done
    dead = False

    for start in range(n):
        if color[start] != 0 or not has_out[start]:
            color[start] = 2
            continue
        path: list[int] = []
        v = start
        while color[v] == 0:
            color[v] = 1
            path.append(v)
            v = int(dst[policy[v]])
            if not has_out[v]:
                break
        if color[v] == 1:
            # found a new cycle: v .. path[-1]
            ci = path.index(v)
            cyc = path[ci:]
            wsum = sum(w[policy[x]] for x in cyc)
            msum = sum(m[policy[x]] for x in cyc)
            if msum == 0:
                dead = True
                return lam, u, dead
            ratio = wsum / msum
            for x in cyc:
                lam[x] = ratio
            # bias along the cycle: u(x) = w̄(x) + u(pi(x)), anchored u(v)=0;
            # walk the cycle backwards so each successor is resolved first
            u[v] = 0.0
            for x in reversed(cyc[1:]):
                y = int(dst[policy[x]])
                u[x] = w[policy[x]] - ratio * m[policy[x]] + u[y]
        # resolve tree part (suffix of `path` before the cycle / known node)
        for x in reversed(path):
            if lam[x] != NEG_INF:
                continue
            y = int(dst[policy[x]])
            if lam[y] == NEG_INF:
                lam[x] = NEG_INF  # leads nowhere cyclic
                u[x] = 0.0
            else:
                lam[x] = lam[y]
                u[x] = w[policy[x]] - lam[x] * m[policy[x]] + u[y]
        for x in path:
            color[x] = 2
        color[v] = 2
    return lam, u, dead


# ======================================================================
# Binary search + vectorized Bellman-Ford (independent cross-check)
# ======================================================================
def mcr_binary_search(
    g: SDFG, *, tol: float = 1e-6, lo: float = 0.0, hi: Optional[float] = None
) -> float:
    """MCR via lambda-search: a positive cycle in weights ``w - lam*m``
    exists iff lam < rho_max.  Longest-path Bellman-Ford, fully vectorized.
    """
    src, dst, w, m = g.edges_arrays()
    n = g.n_actors
    if hi is None:
        hi = float(w.sum()) + 1.0  # any cycle ratio is below total weight

    def has_positive_cycle(lam: float) -> bool:
        ww = w - lam * m
        dist = np.zeros(n)
        for _ in range(n):
            cand = dist[src] + ww
            new = dist.copy()
            np.maximum.at(new, dst, cand)
            new = np.maximum(new, dist)
            if np.allclose(new, dist, rtol=0, atol=1e-12):
                return False
            dist = new
        return True

    if not has_positive_cycle(lo + tol):
        return lo
    while hi - lo > tol:
        mid = 0.5 * (lo + hi)
        if has_positive_cycle(mid):
            lo = mid
        else:
            hi = mid
    return 0.5 * (lo + hi)


# ======================================================================
# Explicit max-plus matrix T = A0* (x) A1 and power iteration (Eq. 4)
# ======================================================================
def maxplus_matrix(g: SDFG) -> np.ndarray:
    """Build T with t_k = T (x) t_{k-1}.

    Dependencies within an iteration (0-token edges) are closed transitively
    over the acyclic 0-token subgraph (Kleene star A0*); dependencies across
    iterations (>=1-token edges) contribute A1.  Markings > 1 relax the
    dependency further into the past and — for a conservative (upper-bound
    period, lower-bound throughput) T — are kept as if 1 token; the exact
    multi-token analysis is done by :func:`mcr_howard`.
    """
    src, dst, w, m = g.edges_arrays()
    n = g.n_actors
    T = np.full((n, n), NEG_INF)

    # A1 edges: j fires after i's previous firing + w
    one = m >= 1
    for s, d, ww in zip(src[one], dst[one], w[one]):
        T[int(d), int(s)] = max(T[int(d), int(s)], float(ww))

    # longest-path closure over 0-token edges, topological order
    zero = m == 0
    z_src, z_dst, z_w = src[zero], dst[zero], w[zero]
    indeg = np.zeros(n, dtype=np.int64)
    adj: list[list[tuple[int, float]]] = [[] for _ in range(n)]
    for s, d, ww in zip(z_src, z_dst, z_w):
        adj[int(s)].append((int(d), float(ww)))
        indeg[int(d)] += 1
    topo: list[int] = [i for i in range(n) if indeg[i] == 0]
    head = 0
    while head < len(topo):
        x = topo[head]
        head += 1
        for y, _ in adj[x]:
            indeg[y] -= 1
            if indeg[y] == 0:
                topo.append(y)
    assert len(topo) == n, "0-token subgraph must be acyclic (liveness)"

    # propagate rows of T along zero edges: T[y,:] >= T[x,:] + w(x->y)
    for x in topo:
        row = T[x]
        for y, ww in adj[x]:
            np.maximum(T[y], row + ww, out=T[y])
    return T


def mcm_power_iteration(
    T: np.ndarray, *, iters: int = 200, use_kernel: bool = True
) -> float:
    """Estimate the max-plus eigenvalue (MCM) of T by power iteration.

    Uses the Pallas ``maxplus_matmul`` kernel when available; falls back to
    the pure-jnp oracle.  For irreducible T the growth rate of
    ``x_k = T (x) x_{k-1}`` converges to the MCM.
    """
    n = T.shape[0]
    if use_kernel:
        try:
            from repro.kernels import ops as kops

            matvec = kops.maxplus_matvec
        except Exception:  # pragma: no cover - kernel import fallback
            matvec = None
    else:
        matvec = None

    x = np.zeros(n)
    warm = max(4, iters // 2)
    x0_at_warm = None
    for k in range(iters):
        if matvec is not None:
            x = np.asarray(matvec(T, x))
        else:
            x = np.max(T + x[None, :], axis=1)
        # renormalize to avoid drift; track growth of the max component
        mx = x.max()
        if not np.isfinite(mx):
            return float(mx)
        if k == warm:
            x0_at_warm = mx
        x = x - 0.0  # keep absolute times; bounded by renorm below
        if mx > 1e12:
            x -= mx
            if x0_at_warm is not None:
                x0_at_warm -= mx
    if x0_at_warm is None:  # pragma: no cover
        return float("nan")
    return float((x.max() - x0_at_warm) / (iters - 1 - warm))


# ======================================================================
def throughput(g: SDFG, *, method: str = "howard") -> float:
    """Application throughput = 1 / MCM (paper's headline metric)."""
    if method == "howard":
        rho = mcr_howard(g)
    elif method == "binary":
        rho = mcr_binary_search(g)
    elif method == "power":
        rho = mcm_power_iteration(maxplus_matrix(g))
    else:
        raise ValueError(f"unknown method {method!r}")
    if rho <= 0 or not np.isfinite(rho):
        return 0.0
    return 1.0 / rho

"""SNN graph IR.

A Spiking Neural Network is a directed graph of neurons connected by weighted
synapses.  For the compiler (partitioning + SDFG analysis) the only
information needed per neuron is its fan-in synapse list and its long-run
spike count per application iteration (recorded from simulation, §2.4); the
LIF dynamics themselves live in :mod:`repro.core.lif` and
:mod:`repro.kernels.lif_crossbar`.

Representation is flat numpy arrays (CSR-like) so multi-million-synapse
networks (Table 1) stay cheap to manipulate.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np


@dataclasses.dataclass
class SNN:
    """A spiking neural network.

    Attributes:
      n_neurons: total neuron count (inputs + hidden + outputs).
      pre, post: int32 arrays of synapse endpoints, shape ``(n_synapses,)``.
      weight: float32 synapse weights, shape ``(n_synapses,)``.
      spikes: float64 per-neuron spike count per application iteration
        (populated by simulation or calibration; see :func:`calibrate_spikes`).
      layer_of: int32 layer index per neuron (−1 when unknown); used only for
        reporting and for the LIF reference simulator.
      name: application name.
    """

    n_neurons: int
    pre: np.ndarray
    post: np.ndarray
    weight: np.ndarray
    spikes: np.ndarray
    layer_of: np.ndarray
    name: str = "snn"

    # ------------------------------------------------------------------
    @property
    def n_synapses(self) -> int:
        return int(self.pre.shape[0])

    def fanin(self) -> np.ndarray:
        """Fan-in synapse count per neuron."""
        return np.bincount(self.post, minlength=self.n_neurons)

    def fanout(self) -> np.ndarray:
        return np.bincount(self.pre, minlength=self.n_neurons)

    def validate(self) -> None:
        assert self.pre.shape == self.post.shape == self.weight.shape
        assert self.pre.min(initial=0) >= 0 and self.pre.max(initial=0) < self.n_neurons
        assert self.post.min(initial=0) >= 0 and self.post.max(initial=0) < self.n_neurons
        assert self.spikes.shape == (self.n_neurons,)
        assert np.all(self.spikes >= 0)

    # ------------------------------------------------------------------
    def split_high_fanin(self, max_fanin: int) -> "SNN":
        """Decompose neurons whose fan-in exceeds the crossbar row count.

        A neuron with fan-in F > max_fanin cannot be realized on a crossbar
        with ``max_fanin`` rows.  Standard practice (e.g. NEUTRAMS [41],
        SpiNeMap [8]) splits it into ``ceil(F/max_fanin)`` accumulator
        sub-neurons feeding one aggregator.  The aggregator keeps the original
        neuron id (and its spike count); sub-neurons are appended at the end
        with spike counts equal to the aggregate they forward.
        """
        fanin = self.fanin()
        heavy = np.flatnonzero(fanin > max_fanin)
        if heavy.size == 0:
            return self

        post = self.post.astype(np.int64)

        # every heavy neuron's synapses, sorted by (post, pre, synapse id):
        # slicing contiguous SOURCE ranges keeps each sub-neuron's receptive
        # field compact (packs into shared crossbar rows)
        key = post * np.int64(self.n_neurons) + self.pre
        order = np.argsort(key, kind="stable")
        post_sorted = post[order]
        starts = np.searchsorted(post_sorted, heavy, side="left")
        ends = np.searchsorted(post_sorted, heavy, side="right")
        counts = ends - starts                      # (H,) fan-in per heavy
        # balanced parts: 133 -> 67+66, not 128+5 — a near-cap part would
        # monopolize an entire crossbar's input rows by itself; the first
        # (count % n_parts) parts carry one extra synapse (np.array_split)
        n_parts = -(-counts // max_fanin)
        base = counts // n_parts
        rem = counts % n_parts

        total = int(counts.sum())
        seg_off = np.concatenate([[0], np.cumsum(counts)[:-1]])
        flat = np.repeat(starts - seg_off, counts) + np.arange(total)
        syn_idx = order[flat]                       # heavy synapses, in order
        pos = np.arange(total) - np.repeat(seg_off, counts)
        base_r = np.repeat(base, counts)
        big = np.repeat(rem, counts) * (base_r + 1)
        part = np.where(
            pos < big,
            pos // (base_r + 1),
            np.repeat(rem, counts) + (pos - big) // base_r,
        )
        part_off = np.concatenate([[0], np.cumsum(n_parts)[:-1]])
        # re-target each heavy synapse to its sub-neuron (ids in
        # (heavy neuron asc, part asc) order, appended after the originals)
        post[syn_idx] = self.n_neurons + np.repeat(part_off, counts) + part

        total_parts = int(n_parts.sum())
        # sub-neuron -> aggregator synapses (weight 1: relay); relay spikes
        # are a proportional share of the target's traffic
        new_pre = self.n_neurons + np.arange(total_parts)
        new_post = np.repeat(heavy, n_parts)
        out = SNN(
            n_neurons=self.n_neurons + total_parts,
            pre=np.concatenate([self.pre, new_pre]).astype(np.int32),
            post=np.concatenate([post, new_post]).astype(np.int32),
            weight=np.concatenate(
                [self.weight, np.ones(total_parts, dtype=np.float32)]
            ).astype(np.float32),
            spikes=np.concatenate(
                [self.spikes, np.repeat(self.spikes[heavy], n_parts)]
            ),
            layer_of=np.concatenate(
                [self.layer_of, np.repeat(self.layer_of[heavy], n_parts)]
            ).astype(np.int32),
            name=self.name,
        )
        out.validate()
        return out


# ----------------------------------------------------------------------
def feedforward(
    layer_sizes: Sequence[int],
    n_synapses: int,
    *,
    seed: int,
    name: str = "snn",
    recurrent: bool = False,
) -> SNN:
    """Generate a (sparse) layered SNN with an exact total synapse count.

    The paper's applications (Table 1) have far fewer synapses than dense
    layer connectivity would imply (conv-style local receptive fields), so we
    draw a deterministic sparse connectivity: synapses are distributed over
    consecutive layer pairs proportionally to ``fanin*fanout`` capacity and
    endpoints are drawn with locality (Gaussian around the aligned position),
    which produces the input-sharing structure bin-packing exploits.
    """
    rng = np.random.default_rng(seed)
    layer_sizes = list(layer_sizes)
    n_neurons = int(sum(layer_sizes))
    offsets = np.cumsum([0] + layer_sizes)
    layer_of = np.concatenate(
        [np.full(s, i, dtype=np.int32) for i, s in enumerate(layer_sizes)]
    )

    pairs = [(i, i + 1) for i in range(len(layer_sizes) - 1)]
    if recurrent:
        pairs += [(len(layer_sizes) - 1, 1)]  # output -> first hidden feedback

    caps = np.array(
        [layer_sizes[a] * layer_sizes[b] for a, b in pairs], dtype=np.float64
    )
    counts = np.floor(n_synapses * caps / caps.sum()).astype(np.int64)
    counts[-1] += n_synapses - counts.sum()  # make the total exact

    pres, posts = [], []
    for (a, b), cnt in zip(pairs, counts):
        sa, sb = layer_sizes[a], layer_sizes[b]
        cnt = int(min(cnt, sa * sb))
        # Conv-style connectivity: each target draws DISTINCT sources from a
        # contiguous window; window starts are quantized so that groups of
        # targets (the "feature maps" at one spatial site) share the exact
        # same window.  Shared windows are what let Alg. 1 co-locate neurons
        # on shared crossbar rows — scattered random connectivity degenerates
        # to one neuron per crossbar on real hardware too.  Synapses are
        # distinct (pre, post) pairs: one OxRAM crosspoint per synapse.
        base = cnt // sb
        fan = np.full(sb, base, dtype=np.int64)
        fan[: cnt - int(fan.sum())] += 1
        w = int(min(sa, max(8, np.ceil(1.25 * max(base, 1)))))
        step = max(1, w // 2)
        centers = (np.arange(sb) * (sa / sb)).astype(np.int64)
        starts_w = np.clip((centers // step) * step, 0, max(sa - w, 0))
        src_list = []
        dst_list = []
        for j in range(sb):
            f = int(fan[j])
            if f == 0:
                continue
            f = min(f, w)
            src_j = rng.choice(w, size=f, replace=False) + starts_w[j]
            src_list.append(src_j)
            dst_list.append(np.full(f, j, dtype=np.int64))
        src = np.concatenate(src_list)
        dst_local = np.concatenate(dst_list)
        pres.append(offsets[a] + src)
        posts.append(offsets[b] + dst_local)

    pre = np.concatenate(pres).astype(np.int32)
    post = np.concatenate(posts).astype(np.int32)
    # dedupe is NOT applied: parallel synapses are legal in SNNs (multapses)
    weight = rng.normal(0.0, 0.5, size=pre.size).astype(np.float32)
    snn = SNN(
        n_neurons=n_neurons,
        pre=pre,
        post=post,
        weight=weight,
        spikes=np.zeros(n_neurons),
        layer_of=layer_of,
        name=name,
    )
    snn.validate()
    return snn


def calibrate_spikes(snn: SNN, total_spikes: float, *, seed: int) -> SNN:
    """Assign deterministic per-neuron spike counts summing to ``total_spikes``.

    The paper records spikes with CARLsim driven by training inputs (§2.4) and
    reports per-application totals (Table 1 'Spikes').  We draw a log-normal
    activity profile (heavy-tailed, as observed in rate-coded SNNs) and scale
    it to the published total, keeping the compiler inputs faithful without
    shipping datasets.  :mod:`repro.core.lif` can replace this with simulated
    counts (``examples/snn_compile.py --simulate``).
    """
    rng = np.random.default_rng(seed)
    profile = rng.lognormal(mean=0.0, sigma=1.0, size=snn.n_neurons)
    spikes = profile * (total_spikes / profile.sum())
    return dataclasses.replace(snn, spikes=spikes)

"""BEYOND-PAPER: the paper's SDFG/Max-Plus machinery applied to pipeline-
parallel transformer execution on TPU meshes (DESIGN.md §4).

Mapping (paper concept -> LM concept):
  cluster/actor        -> pipeline stage (contiguous layer group)
  crossbar capacity    -> per-device HBM budget (Alg.-1-style bin packing)
  spikes per channel   -> activation bytes per microbatch
  AER link bandwidth   -> ICI link bandwidth
  buffer back-edges    -> bounded in-flight microbatches (pipeline depth)
  TDMA static order    -> 1F1B / GPipe stage schedules
  1/MCM                -> steady-state microbatch throughput

This gives closed-form throughput/bubble analysis for any of the assigned
architectures at any stage count, cross-checked against the standard
pipeline formula ``(M + S - 1) / M`` in tests, and is used to pick stage
counts in EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.configs.base import ArchConfig

from .maxplus import mcr_howard
from .sdfg import SDFG, Channel

# TPU v5e constants (launch/mesh.py HW)
PEAK_FLOPS = 197e12
ICI_BW = 50e9


@dataclasses.dataclass(frozen=True)
class StagePlan:
    boundaries: tuple            # layer index ranges per stage
    stage_flops: tuple           # per-microbatch forward flops per stage
    stage_bytes: tuple           # parameter bytes per stage
    act_bytes: int               # activation bytes crossing a boundary


def layer_costs(cfg: ArchConfig, *, micro_tokens: int) -> tuple[list, list]:
    """Per-layer (flops, param_bytes) for one microbatch forward pass."""
    flops, pbytes = [], []
    d = cfg.d_model
    for repeat, specs in cfg.stacks:
        for _ in range(repeat):
            for spec in specs:
                p = 0
                if spec.mixer == "gqa":
                    p += d * (cfg.n_heads + 2 * cfg.n_kv_heads) * cfg.head_dim
                    p += cfg.n_heads * cfg.head_dim * d
                elif spec.mixer == "mla":
                    p += d * cfg.mla_q_rank + cfg.mla_q_rank * cfg.n_heads * (
                        cfg.mla_nope_dim + cfg.mla_rope_dim
                    )
                    p += d * (cfg.mla_kv_rank + cfg.mla_rope_dim)
                    p += cfg.mla_kv_rank * cfg.n_heads * (
                        cfg.mla_nope_dim + cfg.mla_v_dim
                    )
                    p += cfg.n_heads * cfg.mla_v_dim * d
                elif spec.mixer == "mamba":
                    di = cfg.mamba_d_inner
                    p += d * 2 * di + di * (cfg.mamba_dt_rank + 2 * cfg.mamba_d_state)
                    p += cfg.mamba_dt_rank * di + di * d
                elif spec.mixer in ("mlstm", "slstm"):
                    di = cfg.xlstm_d_inner
                    p += d * 4 * di + di * d
                if spec.ffn == "swiglu":
                    p += 3 * d * cfg.d_ff
                elif spec.ffn == "gelu":
                    p += 2 * d * cfg.d_ff
                elif spec.ffn == "moe":
                    # active params only for compute; full bytes for memory
                    p += 3 * d * cfg.moe_d_ff * cfg.moe_experts
                active = p
                if spec.ffn == "moe":
                    active = p - 3 * d * cfg.moe_d_ff * (
                        cfg.moe_experts - cfg.moe_top_k - cfg.moe_shared
                    )
                flops.append(2.0 * active * micro_tokens)
                pbytes.append(2 * p)  # bf16
    return flops, pbytes


def plan_stages(cfg: ArchConfig, n_stages: int, *, micro_tokens: int,
                micro_batch: int = 1) -> StagePlan:
    """Greedy balanced partition of layers into stages (Alg.-1 spirit:
    pack layers into bins under a balance objective)."""
    flops, pbytes = layer_costs(cfg, micro_tokens=micro_tokens)
    total = sum(flops)
    target = total / n_stages
    bounds, acc, start = [], 0.0, 0
    for i, f in enumerate(flops):
        acc += f
        if acc >= target and len(bounds) < n_stages - 1:
            bounds.append((start, i + 1))
            start, acc = i + 1, 0.0
    bounds.append((start, len(flops)))
    stage_flops = tuple(sum(flops[a:b]) for a, b in bounds)
    stage_bytes = tuple(sum(pbytes[a:b]) for a, b in bounds)
    act_bytes = micro_tokens * cfg.d_model * 2
    return StagePlan(tuple(bounds), stage_flops, stage_bytes, act_bytes)


def pipeline_sdfg(plan: StagePlan, *, n_microbatches: int,
                  in_flight: int = 1, bwd_ratio: float = 2.0) -> SDFG:
    """SDFG of a 1F1B-style pipeline (fwd+bwd actor per stage).

    Actors 0..S-1 are forwards, S..2S-1 are backwards (reverse order).
    ``in_flight`` bounds stage-to-stage buffered microbatches (back-edges),
    which is exactly the paper's buffer modeling; the TDMA order on a
    "tile" (device) is (fwd_s, bwd_s) alternation — 1F1B.
    """
    s = len(plan.stage_flops)
    tau = [f / PEAK_FLOPS for f in plan.stage_flops]
    tau += [bwd_ratio * f / PEAK_FLOPS for f in reversed(plan.stage_flops)]
    comm = plan.act_bytes / ICI_BW

    channels = [Channel(i, i, 1, 1.0, kind="self") for i in range(2 * s)]
    # forward chain 0 -> 1 -> ... -> s-1
    for i in range(s - 1):
        channels.append(Channel(i, i + 1, 0, 1.0, delay=comm))
        channels.append(Channel(i + 1, i, in_flight, 1.0, kind="buffer"))
    # fwd s-1 feeds bwd of stage s-1 (actor s)
    channels.append(Channel(s - 1, s, 0, 1.0))
    # backward chain s -> s+1 -> ... -> 2s-1
    for i in range(s, 2 * s - 1):
        channels.append(Channel(i, i + 1, 0, 1.0, delay=comm))
    # device sharing: fwd_i and bwd_(2s-1-i) run on the same device.  In
    # 1F1B stage i holds (s - i) in-flight activations, i.e. its forward
    # may lead its backward by s-i microbatches: that is exactly an order
    # cycle with s-i initial tokens on the bwd->fwd edge (the paper's
    # buffer-as-back-edge modeling, §4.4 step 1).
    for i in range(s):
        b = 2 * s - 1 - i
        channels.append(Channel(i, b, 0, 1.0, kind="order"))
        channels.append(Channel(b, i, s - i, 1.0, kind="order"))
    g = SDFG(n_actors=2 * s, exec_time=np.array(tau), channels=channels,
             name=f"pipeline-{s}stages")
    g.validate()
    return g


@dataclasses.dataclass(frozen=True)
class PipelineReport:
    n_stages: int
    period_s: float              # steady-state per-microbatch period (MCM)
    step_time_s: float           # M microbatches + fill/drain
    bubble_frac: float
    tokens_per_s: float
    hbm_fit: bool


def analyze_pipeline(cfg: ArchConfig, *, n_stages: int, n_microbatches: int,
                     micro_tokens: int, hbm_budget: float = 16e9,
                     in_flight: int = 1) -> PipelineReport:
    plan = plan_stages(cfg, n_stages, micro_tokens=micro_tokens)
    g = pipeline_sdfg(plan, n_microbatches=n_microbatches, in_flight=in_flight)
    period = mcr_howard(g)
    # fill/drain: pipeline depth x max stage time
    fill = (n_stages - 1) * max(g.exec_time)
    step = n_microbatches * period + 2 * fill
    ideal = n_microbatches * (sum(g.exec_time[: n_stages]) +
                              sum(g.exec_time[n_stages:])) / n_stages
    bubble = 1.0 - ideal / step
    tokens = n_microbatches * micro_tokens / step
    fit = max(plan.stage_bytes) * 3 <= hbm_budget  # params+grads+opt rough
    return PipelineReport(n_stages, period, step, max(bubble, 0.0), tokens, fit)

"""Run-time resource management (paper §5): multi-app admission control.

Design time:  build ONE single-tile static-order schedule (all actors bound
to tile 0, FCFS self-timed execution records the total order); discard exact
timings, keep the order.

Run time:  when an application is admitted, (1) bind clusters to the tiles
currently available (§4.2 load balancing restricted to free tiles), then
(2) *project* the single-tile order onto each tile — Lemma 1 guarantees the
resulting multi-tile schedule is deadlock-free — and execute self-timed.
No per-tile schedule is constructed from scratch, which is where ~75% of
compilation time goes (§7.3), so admission is fast (Table 3).

The :class:`AdmissionController` makes this multi-tenant: persistent
tile-occupancy state across applications, an ``admit`` / ``finish`` /
``evict`` lifecycle with an event trajectory, a design-time artifact cache
keyed on ``(app, hardware)`` so re-admission skips clustering and order
construction entirely, and batched scoring of candidate free-tile bindings
through the array-native engine (:mod:`repro.core.engine`).  The
module-level :func:`runtime_admit` remains the single-admission primitive
the controller drives.

With ``placement="joint"`` the controller goes beyond per-admission
isolation: every admit/evict re-optimizes the bindings of ALL resident
applications together, as one disjoint-union graph
(:func:`~repro.core.sdfg.disjoint_union`) whose per-app order cycles come
from the Lemma-1 projection of the concatenated single-tile orders — one
union EdgeStack per optimizer generation, scored on the chip-level
objective (period, chip energy, or their Pareto front) by
:func:`~repro.core.optimize.optimize_binding_graph`.  The current
(isolated) placement is always a seed of that search, so joint placement
is never worse on the scored objective by construction; the trajectory
records chip throughput and chip energy alongside every event.

At chip scale (hundreds of tiles, dozens of tenants) re-optimizing the
WHOLE chip per event is wasteful: an admit or evict only perturbs the
placement near its own tiles.  ``region_scope=True`` (the joint-placement
default) therefore partitions the residents into *placement regions* —
tile-sharing components grown over mesh adjacency — and re-optimizes only
the affected region as a sub-union EdgeStack, holding every other app's
binding fixed.  The slowest component OUTSIDE the region enters the search
as a ``period_floor`` (a cheap stand-in for the rest of the chip: no
region improvement below that floor can move the chip period, so the
optimizer breaks floor-ties toward lower energy), and the region's
candidate tiles are its own footprint plus nearby FREE tiles ranked by
hop distance with a boundary penalty — never another app's tiles, so no
new cross-region coupling can appear and the floor stays valid.  The
current binding seeds the region search, so the chip period never
regresses vs. the pre-event binding by construction (the PR-5 seeding
invariant, now per region).  Every ``full_rebalance_every``-th rebalance
— or any event whose region would cover the whole chip or exceed
``region_max_apps`` — falls back to the exact full-union re-optimization,
so long churns cannot drift away from the jointly-optimal placement.

Chip metrics are cached per tile-sharing component (keyed on the
residents' binding epochs): components untouched by an event are combined
from cache instead of rebuilt, so per-event tracking cost scales with the
event's region, not with the number of resident tenants.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Optional, Union

import numpy as np

from .binding import BindingResult, LoadWeights, bind_ours
from .engine import (
    CompileCacheStats,
    batch_execute,
    project_order_batch,
    record_cache_stats,
    union_component_periods,
)
from .hardware import ChipState, HardwareConfig
from .partition import ClusteredSNN, partition_greedy
from .schedule import (
    SelfTimedExecutor,
    analyze_throughput,
    build_static_orders,
    build_static_orders_batch,
)
from .sdfg import SDFG, disjoint_union, sdfg_from_clusters
from .snn import SNN


@dataclasses.dataclass
class CompileReport:
    """One compiled application: binding + schedules + predicted throughput.

    ``binding`` is (n_clusters,) int tile ids; ``orders[t]`` is tile t's
    static firing order (cluster ids); ``throughput`` is iterations per
    microsecond of model time (1 / steady-state period); the ``*_time_s``
    fields are wall-clock seconds of the compilation steps.
    """

    app: str
    binding: np.ndarray          # (n_clusters,) int64 tile ids
    orders: list[list[int]]      # per-tile static orders (cluster ids)
    throughput: float            # iterations / microsecond of model time
    bind_time_s: float
    schedule_time_s: float

    @property
    def compile_time_s(self) -> float:
        """Total wall-clock compile seconds (binding + scheduling)."""
        return self.bind_time_s + self.schedule_time_s


# ======================================================================
# design-time flow (§4): bind -> per-tile static orders -> analysis
# ======================================================================
def design_time_compile(
    clustered: ClusteredSNN,
    hw: HardwareConfig,
    *,
    binder=bind_ours,
    weights: LoadWeights = LoadWeights(),
    sim_iterations: int = 12,
    order_method: str = "batch",
) -> CompileReport:
    """Full §4 design-time flow: bind, build per-tile static orders, and
    analyze throughput.

    ``binder`` is any :data:`~repro.core.explore.BINDERS`-style strategy
    (``(clustered, hw, **kw) -> BindingResult``).  ``order_method``
    selects the §4.4 step-2 constructor: ``"batch"`` (default, the dense
    FCFS simulator :func:`~repro.core.schedule.build_static_orders_batch`)
    or ``"heapq"`` (the discrete-event oracle; ``sim_iterations`` is its
    FCFS self-timed horizon and is IGNORED under ``"batch"``).  Returns a
    :class:`CompileReport` (binding (n_clusters,), per-tile orders,
    throughput in iterations per microsecond).
    """
    app = sdfg_from_clusters(clustered, hw=hw)
    try:
        bres: BindingResult = binder(clustered, hw, weights=weights)
    except TypeError:  # binders with no `weights` kw (spinemap)
        bres = binder(clustered, hw)
    if order_method == "batch":
        t0 = time.perf_counter()
        orders = build_static_orders_batch(app, bres.binding, hw)[0]
        t_sched = time.perf_counter() - t0
    elif order_method == "heapq":
        orders, t_sched = build_static_orders(
            app, bres.binding, hw, iterations=sim_iterations
        )
    else:
        raise ValueError(f"unknown order_method {order_method!r}")
    thr = analyze_throughput(app, bres.binding, hw, orders)
    return CompileReport(
        app=clustered.snn.name,
        binding=bres.binding,
        orders=orders,
        throughput=thr,
        bind_time_s=bres.bind_time_s,
        schedule_time_s=t_sched,
    )


# ======================================================================
# single-tile schedule (design time, once per application)
# ======================================================================
def single_tile_order(
    clustered: ClusteredSNN,
    hw: HardwareConfig,
    *,
    sim_iterations: int = 8,
    method: str = "batch",
) -> tuple[list[int], float]:
    """Total actor order from a 1-tile execution of the application.

    Returns ``(order, wall_s)``: the (n_clusters,) design-time firing
    order and its construction wall-clock seconds.  ``method="batch"``
    (default) uses the dense FCFS simulator
    (:func:`~repro.core.schedule.build_static_orders_batch`, ~100x faster
    on the large Table-1 apps); ``"heapq"`` replays the discrete-event
    oracle with ``sim_iterations`` FCFS iterations.  ``sim_iterations``
    applies to the heapq path only (the dense constructor simulates the
    one firing per actor that defines the order); longer heapq horizons
    can record a different — equally valid — schedule when repeat firings
    contend for tiles.
    """
    t0 = time.perf_counter()
    one_tile = dataclasses.replace(hw, n_tiles=1)
    app = sdfg_from_clusters(clustered, hw=one_tile)
    binding = np.zeros(clustered.n_clusters, dtype=np.int64)
    if method == "batch":
        orders = build_static_orders_batch(app, binding, one_tile)[0]
    elif method == "heapq":
        orders, _ = build_static_orders(app, binding, one_tile,
                                        iterations=sim_iterations)
    else:
        raise ValueError(f"unknown single-tile order method {method!r}")
    return orders[0], time.perf_counter() - t0


def project_order(
    order: list[int], binding: np.ndarray, n_tiles: int
) -> list[list[int]]:
    """Lemma 1: per-tile orders = the single-tile order filtered per tile.

    Keeping the relative firing order unchanged preserves deadlock freedom
    (Blazewicz 1976 via [12]); Fig. 12 illustrates exactly this projection.
    """
    binding = np.asarray(binding)
    per_tile = [[a for a in order if binding[a] == t] for t in range(n_tiles)]
    # any actor missing from the order (defensive) is appended at the end
    seen = {a for o in per_tile for a in o}
    for a in range(len(binding)):
        if a not in seen:
            per_tile[int(binding[a])].append(a)
    return per_tile


# ======================================================================
# run-time admission (§5, Fig. 11)
# ======================================================================
class AdmissionError(RuntimeError):
    """Raised when an application cannot be admitted on the free tiles."""


@dataclasses.dataclass
class HardwareState:
    """Tracks which tiles are currently allocated to running applications.

    ``chip`` optionally points at the chip's mutable degradation state
    (:class:`~repro.core.hardware.ChipState`): when set, dead tiles are
    never reported free, so every admission and re-placement path that
    draws from :meth:`free_tiles` is dead-tile-safe without further
    checks.
    """

    hw: HardwareConfig
    allocated: dict[str, list[int]] = dataclasses.field(default_factory=dict)
    chip: Optional[ChipState] = None

    def free_tiles(self) -> list[int]:
        """Sorted physical tile ids not allocated to any running app
        (excluding dead tiles when a :class:`ChipState` is attached)."""
        mask = np.ones(self.hw.n_tiles, dtype=bool)
        if self.chip is not None:
            mask &= ~self.chip.dead
        for tiles in self.allocated.values():
            if tiles:
                mask[np.asarray(tiles, dtype=np.int64)] = False
        return [int(t) for t in np.flatnonzero(mask)]

    def release(self, app: str) -> None:
        """Free ``app``'s tiles (no-op when the app is not running)."""
        self.allocated.pop(app, None)


def runtime_admit(
    clustered: ClusteredSNN,
    state: HardwareState,
    single_order: list[int],
    *,
    n_tiles_request: Optional[int] = None,
    weights: LoadWeights = LoadWeights(),
    tile_selection: str = "batched",
    optimize_budget: Optional[tuple[int, int]] = None,
    chip_state: Optional[ChipState] = None,
    rate_scale: float = 1.0,
) -> CompileReport:
    """Admit an application onto the currently-free tiles (Fig. 11).

    Binding runs on the free-tile subset; per-tile schedules are *projected*
    from the design-time single-tile order (no construction from scratch).
    Returns a :class:`CompileReport` whose ``binding`` is (n_clusters,)
    physical tile ids and whose ``throughput`` is 1/period (per
    microsecond of model time).

    When ``n_tiles_request`` asks for fewer tiles than are free, the
    candidate k-subsets of the free tiles are scored in one batched
    Max-Plus call (``tile_selection="batched"``, via
    :func:`repro.core.explore.score_free_tile_subsets`) and the
    best-throughput subset wins; ``tile_selection="first"`` keeps the old
    first-k-free behaviour.  Requesting more tiles than are free raises
    :class:`AdmissionError` instead of silently binding to fewer.

    ``optimize_budget`` is the admission-time quality/latency knob: a
    ``(generations, population)`` pair that refines the heuristic binding
    with the throughput-in-the-loop optimizer
    (:func:`repro.core.optimize.optimize_binding`) on the chosen tile
    subset before projection.  The heuristic binding is one of the
    optimizer's seeds, so the refined admission is never worse; cost grows
    roughly linearly with ``generations x population``.  ``None`` (the
    default) keeps the plain heuristic path.

    ``chip_state``/``rate_scale`` admit onto a DEGRADED chip: candidate
    subsets and the final report score under the chip's throttled routes
    and this app's drift multiplier (``state.free_tiles()`` already
    excludes dead tiles when ``state.chip`` is attached).  On a pristine
    chip with unit drift the path — and the report — is bit-identical to
    the undegraded one.
    """
    free = state.free_tiles()
    if not free:
        raise AdmissionError(
            f"admission rejected for {clustered.snn.name!r}: no free tiles "
            f"({state.hw.n_tiles} total, all allocated)"
        )
    if n_tiles_request is not None:
        if n_tiles_request < 1:
            raise ValueError(f"n_tiles_request must be >= 1, got {n_tiles_request}")
        if len(free) < n_tiles_request:
            raise AdmissionError(
                f"admission rejected for {clustered.snn.name!r}: requested "
                f"{n_tiles_request} tiles but only {len(free)} free "
                f"(free tiles: {free})"
            )

    t0 = time.perf_counter()
    scores = None
    if n_tiles_request is not None and n_tiles_request < len(free):
        if tile_selection == "batched":
            from .explore import score_free_tile_subsets

            scores = score_free_tile_subsets(
                clustered, state.hw, free, n_tiles_request, single_order,
                binder_kwargs={"weights": weights},
                chip_state=chip_state, rate_scale=rate_scale,
            )
            free = list(scores.best)
        elif tile_selection == "first":
            free = free[:n_tiles_request]
        else:
            raise ValueError(f"unknown tile_selection {tile_selection!r}")

    # bind on a virtual hardware with |free| tiles, then relabel to real
    # ids; subset scoring already bound and projected — reuse its result
    if scores is not None:
        virt_binding = scores.binding
    else:
        sub_hw = dataclasses.replace(state.hw, n_tiles=len(free))
        virt_binding = bind_ours(clustered, sub_hw, weights=weights).binding
    refined = False
    if optimize_budget is not None:
        from .optimize import optimize_binding

        gens, pop = optimize_budget
        # optimize over the PHYSICAL free-tile ids (allowed_tiles), so the
        # search sees the subset's real NoC distances; the heuristic
        # binding — relabeled physically — seeds the final exact pool,
        # which makes the refined admission never worse than the plain one
        phys_seed = np.array([free[t] for t in virt_binding], dtype=np.int64)
        phys_opt = optimize_binding(
            clustered, state.hw,
            single_order=single_order,
            generations=gens, population=pop,
            weights=weights, allowed_tiles=free,
            extra_seeds=[phys_seed],
            chip_state=chip_state, rate_scale=rate_scale,
        ).binding
        to_virt = {p: v for v, p in enumerate(free)}
        virt_binding = np.array(
            [to_virt[int(t)] for t in phys_opt], dtype=np.int64
        )
        refined = True
    t_bind = time.perf_counter() - t0

    t1 = time.perf_counter()
    if scores is not None and not refined:
        sub_orders = scores.virt_orders
    else:
        sub_orders = project_order(single_order, virt_binding, len(free))

    # relabel virtual tiles -> physical free tiles
    phys_binding = np.array([free[t] for t in virt_binding], dtype=np.int64)
    phys_orders: list[list[int]] = [[] for _ in range(state.hw.n_tiles)]
    for virt, phys in enumerate(free):
        phys_orders[phys] = sub_orders[virt]
    t_sched = time.perf_counter() - t1

    app = sdfg_from_clusters(clustered, hw=state.hw)
    if chip_state is not None and (not chip_state.pristine or rate_scale != 1.0):
        # degraded chip: the howard-solver path is chip-state-unaware, so
        # score the admitted configuration through the batched engine
        rep = batch_execute(
            app, phys_binding, state.hw, [phys_orders],
            chip_state=chip_state, rate_scale=rate_scale,
        )
        thr = float(rep.throughputs[0])
    else:
        thr = analyze_throughput(app, phys_binding, state.hw, phys_orders)
    state.allocated[clustered.snn.name] = list(free)
    return CompileReport(
        app=clustered.snn.name,
        binding=phys_binding,
        orders=phys_orders,
        throughput=thr,
        bind_time_s=t_bind,
        schedule_time_s=t_sched,
    )


# ======================================================================
# multi-app admission controller (§5 made multi-tenant)
# ======================================================================
@dataclasses.dataclass
class DesignArtifact:
    """Cached design-time products of one (application, hardware) pair.

    Everything admission needs that does NOT depend on which tiles happen
    to be free: the clustering (Alg. 1), the single-tile static order
    (§5), and the application SDFG (``graph`` — reused by the chip-metric
    and joint-placement union builds, so per-event tracking never
    re-derives it from the clusters).  ``hits`` counts cache reuses — a
    re-admitted app pays neither clustering nor order construction again.
    """

    app: str
    clustered: ClusteredSNN
    single_order: list[int]
    design_time_s: float
    hits: int = 0
    graph: Optional[SDFG] = None


@dataclasses.dataclass
class AdmissionEvent:
    """One step of the controller's lifecycle trajectory.

    ``chip_throughput``/``chip_energy`` record the chip-level state after
    the event — 1/period of the union graph of all resident apps
    (iterations per microsecond; every resident app sustains at least this
    rate) and its energy per iteration (pJ) — when the controller tracks
    chip metrics (always under ``placement="joint"``); 0.0 otherwise or
    when the chip is empty.

    ``scope`` distinguishes rebalance flavours (``"full"`` re-optimized
    every resident, ``"region"`` only the ``region_apps`` apps of the
    affected placement region); ``app_throughputs`` maps each resident to
    its TRUE steady-state rate — 1 / max period over the graph components
    its actors touch — which is >= the conservative chip rate for any app
    off the chip's critical cycle.

    The fault/drift layer adds four kinds: ``"fault"``/``"drift"``/
    ``"heal"`` record a chip mutation (their ``chip_throughput`` shows the
    chip DEGRADED, before recovery), ``"remap"`` records the incremental
    recovery — its ``seed_throughput`` is the chip throughput of the
    minimally-repaired seed placement (dead-bound clusters migrated to
    the nearest alive candidate tile) that the region re-optimization
    started from, so ``chip_throughput >= seed_throughput`` is the
    per-event never-regress invariant.  A resident whose component has no
    alive candidate tile left is released with an explicit
    ``"displaced"`` event (never silently dropped).
    """

    kind: str   # admit | reject | finish | evict | rebalance | fault | drift | heal | remap | displaced
    app: str
    tiles: list[int]
    wall_s: float             # wall-clock cost of the operation
    throughput: float = 0.0
    cache_hit: bool = False
    chip_throughput: float = 0.0   # iterations / us of the union graph
    chip_energy: float = 0.0       # pJ / iteration of the union graph
    scope: str = ""                # rebalance events: "full" | "region"
    region_apps: int = 0           # apps re-optimized by a region rebalance
    app_throughputs: dict = dataclasses.field(default_factory=dict)
    seed_throughput: float = 0.0   # remap events: repaired-seed chip rate
    reason: str = ""               # reject events: "" (placement) | quota | cancelled
    factor: float = 0.0            # drift/throttle events: applied multiplier


def _same_application(app: Union[SNN, ClusteredSNN], art: DesignArtifact) -> bool:
    """Guard against a stale cache hit: same name, different network."""
    if isinstance(app, ClusteredSNN):
        return app is art.clustered or app.snn is art.clustered.snn
    cached = art.clustered.snn
    if app is cached:
        return True
    return (
        app.n_neurons == cached.n_neurons
        and np.array_equal(app.pre, cached.pre)
        and np.array_equal(app.post, cached.post)
        and np.array_equal(app.weight, cached.weight)
        and np.array_equal(app.spikes, cached.spikes)
    )


class AdmissionController:
    """Multi-tenant run-time resource manager (§5, Fig. 11).

    Owns the persistent tile-occupancy state (:class:`HardwareState`), the
    design-time artifact cache, and the admission trajectory::

        ctl = AdmissionController(DYNAP_SE)
        ctl.register(snn)                      # design time, once per app
        rep = ctl.admit(snn.name, n_tiles_request=2)
        ctl.finish(snn.name)                   # app completed: tiles free
        rep2 = ctl.admit(snn.name)             # re-admission: cache hit

    ``admit`` scores every feasible free-tile binding in one batched
    engine call (see :func:`runtime_admit` with ``tile_selection=
    "batched"``); ``evict`` is the preemption variant of ``finish`` —
    same release mechanics, distinct trajectory event, returns the freed
    tiles so a caller can re-admit a displaced app.

    ``placement="joint"`` re-optimizes the bindings of ALL resident apps
    after every admit and evict (see :meth:`chip_metrics` and the module
    docstring): one union EdgeStack per optimizer generation over the
    apps' combined tile footprint, with the isolated placement as a seed
    — never worse on the chip ``objective`` (``"period"``/``"energy"``/
    ``"pareto"``) by construction.  ``joint_budget`` is its
    (generations, population) search budget.  ``cache_stats`` holds
    shape-bucket compile-cache counters scoped to THIS controller
    (recorded via :func:`~repro.core.engine.record_cache_stats`, so two
    controllers never leak counters into each other).

    ``region_scope`` (default: on exactly under ``placement="joint"``)
    makes every rebalance *incremental*: only the placement region an
    event touches is re-optimized, the rest of the chip is summarized by
    a period floor (see the module docstring).  ``region_max_apps`` caps
    a region's size (a larger affected region degrades the cover and
    falls back to full), ``region_radius`` is the mesh-hop adjacency that
    grows a region across tile-sharing components, and
    ``full_rebalance_every=K`` forces the K-th rebalance to be a full
    exact re-optimization (0 disables the periodic fallback).
    """

    def __init__(
        self,
        hw: HardwareConfig,
        *,
        weights: LoadWeights = LoadWeights(),
        tile_selection: str = "batched",
        sim_iterations: int = 8,
        optimize_budget: Optional[tuple[int, int]] = None,
        placement: str = "isolated",
        joint_budget: tuple[int, int] = (2, 16),
        objective: str = "period",
        track_chip_metrics: Optional[bool] = None,
        region_scope: Optional[bool] = None,
        region_max_apps: int = 6,
        full_rebalance_every: int = 8,
        region_radius: int = 1,
        fused_scoring: bool = True,
        mesh=None,
    ):
        if placement not in ("isolated", "joint"):
            raise ValueError(
                f"unknown placement {placement!r}; have ('isolated', 'joint')"
            )
        if objective not in ("period", "energy", "pareto"):
            raise ValueError(
                f"unknown objective {objective!r}; "
                f"have ('period', 'energy', 'pareto')"
            )
        self.hw = hw
        # mutable chip degradation state (dead tiles, link throttles,
        # per-app drift); every score the controller takes goes through it
        self.chip = ChipState(hw)
        self.state = HardwareState(hw, chip=self.chip)
        self.weights = weights
        self.tile_selection = tile_selection
        self.sim_iterations = sim_iterations
        # (generations, population) for throughput-in-the-loop refinement
        # of every admission's binding; None = heuristic-only (fastest)
        self.optimize_budget = optimize_budget
        # chip-level placement policy: "isolated" admits each app on its
        # own and never revisits it; "joint" re-optimizes all resident
        # bindings together on every admit/evict (union EdgeStack)
        self.placement = placement
        self.joint_budget = joint_budget
        self.objective = objective
        # chip-metric tracking costs one B=1 union analysis per event;
        # default on exactly when joint placement needs the numbers anyway
        self.track_chip_metrics = (
            placement == "joint" if track_chip_metrics is None
            else track_chip_metrics
        )
        # region-scoped incremental rebalancing (joint placement only):
        # defaults on under "joint", irrelevant (but harmless) otherwise
        self.region_scope = (
            placement == "joint" if region_scope is None
            else bool(region_scope)
        )
        self.region_max_apps = int(region_max_apps)
        self.full_rebalance_every = int(full_rebalance_every)
        self.region_radius = int(region_radius)
        # fused cross-component scoring: a multi-component region runs
        # its component searches in lockstep, one fused EdgeStack
        # analysis per generation (see _optimize_region)
        self.fused_scoring = bool(fused_scoring)
        # scoring mesh: shards every rebalance's population scoring across
        # its devices (bit-identical to single-device — see
        # optimize_binding_graph's mesh= contract); None = unsharded
        self.mesh = mesh
        # rebalance deferral (the serving burst path): while a deferral
        # is active, _rebalance only records the event; flush_rebalances
        # merges all pending events into ONE region rebalance
        self._defer_rebalance = False
        self._pending_event_apps: set[str] = set()
        self._pending_freed: set[int] = set()
        self._deferred_events = 0
        # per-app binding epochs key the component-metric cache: any write
        # to an app's binding invalidates exactly the components it touches
        self._binding_epoch: dict[str, int] = {}
        self._epoch_counter = 0
        self._comp_cache: dict[tuple, dict] = {}
        self._rebalance_count = 0
        # last stamped per-app rates: the staleness detector compares a
        # fresh re-score under the CURRENT chip state against this
        self._app_rate_snapshot: dict[str, float] = {}
        # tiles whose neighborhood skipped opportunistic re-optimization
        # during a latency-critical fault remap; consumed (as extra
        # region seeds) by the next growing rebalance or heal remap
        self._pending_consolidation: set[int] = set()
        self.cache_stats = CompileCacheStats()
        self.artifacts: dict[tuple[str, HardwareConfig], DesignArtifact] = {}
        self.reports: dict[str, CompileReport] = {}
        self.events: list[AdmissionEvent] = []

    # -- design time ----------------------------------------------------
    def register(self, app: Union[SNN, ClusteredSNN]) -> DesignArtifact:
        """Run (or fetch) the design-time flow for ``app`` on this hardware.

        Accepts a raw :class:`SNN` (clustered here) or a pre-clustered
        application.  Idempotent: a second registration of the same name is
        a cache hit and does no work.
        """
        name = app.snn.name if isinstance(app, ClusteredSNN) else app.name
        key = (name, self.hw)
        if key in self.artifacts:
            art = self.artifacts[key]
            if not _same_application(app, art):
                raise ValueError(
                    f"app {name!r} is already registered with different "
                    f"contents on this hardware; use a distinct name"
                )
            art.hits += 1
            return art
        t0 = time.perf_counter()
        clustered = (
            app if isinstance(app, ClusteredSNN)
            else partition_greedy(app, self.hw)
        )
        order, _ = single_tile_order(
            clustered, self.hw, sim_iterations=self.sim_iterations
        )
        art = DesignArtifact(
            app=name,
            clustered=clustered,
            single_order=order,
            design_time_s=time.perf_counter() - t0,
            graph=sdfg_from_clusters(clustered, hw=self.hw),
        )
        self.artifacts[key] = art
        return art

    def _artifact(self, app: Union[str, SNN, ClusteredSNN]) -> tuple[DesignArtifact, bool]:
        if isinstance(app, str):
            key = (app, self.hw)
            if key not in self.artifacts:
                raise KeyError(
                    f"app {app!r} was never registered with this controller; "
                    f"known apps: {sorted(k for k, _ in self.artifacts)}"
                )
            art = self.artifacts[key]
            art.hits += 1
            return art, True
        key = ((app.snn.name if isinstance(app, ClusteredSNN) else app.name),
               self.hw)
        cached = key in self.artifacts
        return self.register(app), cached

    # -- run time -------------------------------------------------------
    def admit(
        self,
        app: Union[str, SNN, ClusteredSNN],
        *,
        n_tiles_request: Optional[int] = None,
    ) -> CompileReport:
        """Admit ``app`` onto the currently-free tiles (Fig. 11).

        Raises :class:`AdmissionError` when the app is already running or
        cannot be placed; rejections are recorded in the trajectory too.
        """
        art, cache_hit = self._artifact(app)
        if art.app in self.state.allocated:
            self.events.append(AdmissionEvent(
                kind="reject", app=art.app, tiles=[], wall_s=0.0,
                cache_hit=cache_hit,
            ))
            raise AdmissionError(
                f"app {art.app!r} is already running on tiles "
                f"{self.state.allocated[art.app]}; finish() or evict() first"
            )
        t0 = time.perf_counter()
        try:
            with record_cache_stats(self.cache_stats):
                report = runtime_admit(
                    art.clustered,
                    self.state,
                    art.single_order,
                    n_tiles_request=n_tiles_request,
                    weights=self.weights,
                    tile_selection=self.tile_selection,
                    optimize_budget=self.optimize_budget,
                    chip_state=self.chip,
                    rate_scale=self.chip.drift.get(art.app, 1.0),
                )
        except AdmissionError:
            self.events.append(AdmissionEvent(
                kind="reject", app=art.app, tiles=[],
                wall_s=time.perf_counter() - t0, cache_hit=cache_hit,
            ))
            raise
        self.reports[art.app] = report
        self._bump_epoch(art.app)
        event = AdmissionEvent(
            kind="admit",
            app=art.app,
            tiles=sorted(self.state.allocated[art.app]),
            wall_s=time.perf_counter() - t0,
            throughput=report.throughput,
            cache_hit=cache_hit,
        )
        self.events.append(event)
        self._stamp_chip_metrics(event)
        if self.placement == "joint":
            self._rebalance(event_app=art.app)
        return report

    def record_rejection(self, app: str, reason: str) -> "AdmissionEvent":
        """Stamp a front-end rejection on the trajectory.

        The serving queue refuses some tickets before they ever reach
        :meth:`admit` — per-tenant quota breaches, cancellations of
        queued work.  Those decisions still belong on the admission
        trajectory (the paper's Fig.-11 flow audits EVERY outcome), so
        the front end records them here with an explicit ``reason``;
        placement rejections raised by :meth:`admit` itself stamp their
        events with an empty reason as before.
        """
        event = AdmissionEvent(
            kind="reject", app=app, tiles=[], wall_s=0.0, reason=reason,
        )
        self.events.append(event)
        return event

    def _release(self, app: str, kind: str) -> list[int]:
        if app not in self.state.allocated:
            raise KeyError(
                f"app {app!r} is not running; running: {sorted(self.state.allocated)}"
            )
        tiles = sorted(self.state.allocated[app])
        self.state.release(app)
        self.reports.pop(app, None)
        self._binding_epoch.pop(app, None)
        event = AdmissionEvent(kind=kind, app=app, tiles=tiles, wall_s=0.0)
        self.events.append(event)
        self._stamp_chip_metrics(event)
        return tiles

    def finish(self, app: str) -> list[int]:
        """App completed normally: free its tiles."""
        return self._release(app, "finish")

    def evict(self, app: str) -> list[int]:
        """Forcibly preempt a running app (the Fig.-11 displacement case).

        Under ``placement="joint"`` the remaining residents are re-placed
        jointly right after the release (the freed tiles may be reclaimed
        by the survivors); ``finish`` deliberately does not re-place.
        """
        tiles = self._release(app, "evict")
        if self.placement == "joint":
            self._rebalance(freed_tiles=tiles)
        return tiles

    # -- fault & drift runtime ------------------------------------------
    def stale_apps(self) -> list[str]:
        """Residents whose last-stamped rate no longer holds on this chip.

        Re-scores every resident component under the CURRENT chip state
        (the component cache keys on the chip's degradation epoch, so any
        mutation forces fresh engine calls) and returns the apps whose
        true steady-state rate moved relative to the snapshot stamped at
        the last trajectory event.  Empty when the chip is pristine, when
        the degradation touches no resident, or when the controller does
        not track chip metrics (no snapshot to compare against).
        """
        if not self.state.allocated:
            return []
        m = self.chip_metrics()
        if m is None:
            return []
        return sorted(
            n for n, thr in m["app_throughputs"].items()
            if not np.isclose(
                thr,
                self._app_rate_snapshot.get(n, thr),
                rtol=1e-6, atol=0.0,
            )
        )

    def _refresh_rate_snapshot(self) -> None:
        m = self.chip_metrics()
        self._app_rate_snapshot = (
            dict(m["app_throughputs"]) if m is not None else {}
        )

    def inject_fault(
        self,
        tiles: Optional[list[int]] = None,
        *,
        links: Optional[list[tuple[int, int]]] = None,
        throttle: float = 4.0,
        remap: bool = True,
    ) -> list[str]:
        """Fail tiles and/or throttle links, then recover incrementally.

        Marks ``tiles`` dead (their rows become infeasible for every
        binding) and multiplies the per-hop link time of each adjacent
        ``links`` pair by ``throttle`` (a wormhole route crossing several
        throttled links is gated by the slowest), re-scores the resident
        set under the degraded chip, records a ``"fault"`` trajectory
        event whose chip metrics show the chip DEGRADED (before
        recovery), and — unless ``remap=False`` — runs :meth:`remap`.
        Returns the names of apps displaced during recovery (empty when
        every resident survived, always empty with ``remap=False``).
        """
        if not tiles and not links:
            raise ValueError("inject_fault needs tiles and/or links")
        t0 = time.perf_counter()
        if tiles:
            self.chip.fail_tiles(tiles)
        for a, b in links or []:
            self.chip.throttle_link(a, b, throttle)
        stale = self.stale_apps()
        event = AdmissionEvent(
            kind="fault", app="*",
            tiles=sorted(int(t) for t in tiles or []),
            wall_s=time.perf_counter() - t0,
            factor=float(throttle) if links else 0.0,
        )
        self._stamp_chip_metrics(event)
        self._refresh_rate_snapshot()
        self.events.append(event)
        if not remap:
            return []
        return self.remap(
            failed_tiles=sorted(int(t) for t in tiles or []),
            stale=stale,
        )

    def inject_drift(
        self, app: str, factor: float, *, remap: bool = True
    ) -> list[str]:
        """Scale ``app``'s observed spike rates by ``factor`` (workload
        drift: the network fires more or less than its design-time
        profile said).  NoC delays and dynamic-energy accumulators see
        the drifted rates; buffer back-edges and the intra-tile
        time-constant stay design-time.  Records a ``"drift"`` event and
        — unless ``remap=False`` — re-places the affected region.
        Returns any displaced app names (normally empty: drift never
        makes a placement infeasible).
        """
        t0 = time.perf_counter()
        self.chip.set_drift(app, factor)
        stale = self.stale_apps()
        event = AdmissionEvent(
            kind="drift", app=app, tiles=[],
            wall_s=time.perf_counter() - t0,
            factor=float(factor),
        )
        self._stamp_chip_metrics(event)
        self._refresh_rate_snapshot()
        self.events.append(event)
        if not remap:
            return []
        return self.remap(stale=stale)

    def heal(
        self,
        tiles: Optional[list[int]] = None,
        *,
        links: Optional[list[tuple[int, int]]] = None,
        drift_apps: Optional[list[str]] = None,
        remap: bool = True,
    ) -> list[str]:
        """Undo degradation: revive tiles, restore links, clear drift.

        Records a ``"heal"`` event, then — unless ``remap=False`` —
        re-places the region around the recovered tiles so residents can
        reclaim them.  Returns any displaced app names (always empty:
        healing only ever widens the feasible set).
        """
        if not tiles and not links and not drift_apps:
            raise ValueError("heal needs tiles, links and/or drift_apps")
        t0 = time.perf_counter()
        if tiles:
            self.chip.heal_tiles(tiles)
        for a, b in links or []:
            self.chip.heal_link(a, b)
        for a in drift_apps or []:
            self.chip.clear_drift(a)
        stale = self.stale_apps()
        event = AdmissionEvent(
            kind="heal", app="*",
            tiles=sorted(int(t) for t in tiles or []),
            wall_s=time.perf_counter() - t0,
        )
        self._stamp_chip_metrics(event)
        self._refresh_rate_snapshot()
        self.events.append(event)
        if not remap:
            return []
        return self.remap(
            healed_tiles=sorted(int(t) for t in tiles or []),
            stale=stale,
        )

    def remap(
        self,
        *,
        failed_tiles: Optional[list[int]] = None,
        healed_tiles: Optional[list[int]] = None,
        stale: Optional[list[str]] = None,
    ) -> list[str]:
        """Incrementally recover the placement after a chip mutation.

        Never a from-scratch re-placement: (1) residents bound to dead
        tiles are found; components with NO alive candidate tile left are
        released with explicit ``"displaced"`` events (never silently
        dropped); (2) the surviving dead-bound clusters are migrated to
        the nearest alive candidate tile (seed repair — the cheapest
        feasible post-fault placement) and the repaired seed's chip
        throughput is stamped; (3) the affected region — the tile-sharing
        components of the broken/``stale`` apps plus components within
        ``region_radius`` of the failed/healed tiles — is re-optimized
        per component with the PR-6 floor machinery, seeded from the
        repaired binding.  The final ``"remap"`` event records
        ``seed_throughput``; ``chip_throughput >= seed_throughput`` holds
        by construction (the seed is always in the candidate pool), so
        recovery never lands below the best repaired placement and
        untouched tenants are never disturbed.  Returns displaced names.
        """
        t0 = time.perf_counter()
        displaced: list[str] = []
        if not self.state.allocated:
            return displaced
        broken = [
            n for n in sorted(self.state.allocated)
            if self.chip.dead[self.reports[n].binding].any()
        ]
        if broken:
            broken_set = set(broken)
            doomed: list[list[str]] = [
                sorted(c) for c in self._tile_components()
                if broken_set & set(c) and not self._component_allowed(sorted(c))
            ]
            for comp in doomed:
                for n in comp:
                    self._release(n, "displaced")
                    displaced.append(n)
            broken = [n for n in broken if n in self.state.allocated]
        if broken:
            # seed repair: minimally migrate dead-bound clusters so the
            # state itself is feasible before any optimization runs
            broken_set = set(broken)
            for comp in [sorted(c) for c in self._tile_components()]:
                if not (broken_set & set(comp)):
                    continue
                arts, union, order, binding, offsets = self._sub_union(comp)
                binding = self._repair_binding(
                    binding, self._component_allowed(comp)
                )
                union_orders = project_order(order, binding, self.hw.n_tiles)
                for k, name in enumerate(comp):
                    lo, hi = int(offsets[k]), int(offsets[k + 1])
                    b_app = binding[lo:hi].copy()
                    self.state.allocated[name] = sorted(
                        {int(t) for t in b_app}
                    )
                    old = self.reports[name]
                    self.reports[name] = CompileReport(
                        app=name,
                        binding=b_app,
                        orders=[
                            [a - lo for a in tile_order if lo <= a < hi]
                            for tile_order in union_orders
                        ],
                        throughput=old.throughput,
                        bind_time_s=old.bind_time_s,
                        schedule_time_s=old.schedule_time_s,
                    )
                    self._bump_epoch(name)
        if not self.state.allocated:
            return displaced
        # the repaired seed IS a feasible placement under the current
        # chip state: its rate is the never-regress floor of this remap
        m_seed = self.chip_metrics()
        seed_thr = (
            m_seed["chip_throughput"] if m_seed is not None else 0.0
        )
        event_apps = (
            set(broken) | set(stale or [])
        ) & set(self.state.allocated)
        if healed_tiles and m_seed is not None:
            # a heal is the cheap moment to attack the CHIP bottleneck:
            # the slowest component's own chip state never changes when
            # capacity returns elsewhere, so it is never rate-stale and
            # no incremental event would ever re-seed it — each heal
            # re-optimizes it (with growth) and walks the incremental
            # placement back toward the full re-optimization's quality
            slowest = min(
                m_seed["app_throughputs"].values(), default=float("inf")
            )
            event_apps |= {
                n for n, r in m_seed["app_throughputs"].items()
                if r <= slowest * (1 + 1e-9)
            }
        event_apps = sorted(event_apps)
        # fault remaps stay latency-critical: only HEALED tiles are
        # immediate placement opportunities for neighbors (dead tiles
        # attract nobody, and every app a failure can affect — dead-bound
        # or rate-stale — is already in event_apps).  The failed tiles'
        # neighborhood is queued instead and consolidated by the next
        # growing rebalance (churn or heal), off the recovery path.
        if failed_tiles:
            self._pending_consolidation.update(int(t) for t in failed_tiles)
        freed = set(healed_tiles or [])
        if freed and self._pending_consolidation:
            freed |= self._pending_consolidation
            self._pending_consolidation.clear()
        freed = sorted(freed)
        region = self._affected_region(
            event_apps=event_apps or None,
            freed_tiles=freed or None,
            grow=bool(healed_tiles),
        ) or []
        if not region and not broken and not displaced:
            return displaced   # mutation touched nothing resident
        if region:
            self._optimize_region(region)
        m = self.chip_metrics()
        thr = m["chip_throughput"] if m is not None else 0.0
        for name in region:
            self.reports[name].throughput = thr
        event = AdmissionEvent(
            kind="remap", app="*",
            tiles=sorted(
                {int(t) for n in region for t in self.state.allocated[n]}
            ),
            wall_s=time.perf_counter() - t0,
            throughput=thr,
            scope="region", region_apps=len(region),
            seed_throughput=seed_thr,
        )
        if self.track_chip_metrics and m is not None:
            event.chip_throughput = thr
            event.chip_energy = m["chip_energy"]
            event.app_throughputs = dict(m["app_throughputs"])
            self._app_rate_snapshot = dict(m["app_throughputs"])
        self.events.append(event)
        return displaced

    # -- chip-level placement (the union-graph objective layer) ---------
    def _resident_union(self):
        """Union view of all resident apps: graph, order, binding, offsets.

        Returns ``(names, arts, union, union_order, union_binding,
        offsets)`` — the disjoint-union SDFG of the resident apps (actors
        offset per app, ``offsets[k]`` is app k's first actor), the
        concatenated single-tile orders (a valid total order of the union:
        no cross-app edges exist) and the concatenated current physical
        bindings.
        """
        names = sorted(self.state.allocated)
        arts = [self.artifacts[(n, self.hw)] for n in names]
        graphs = [
            a.graph if a.graph is not None
            else sdfg_from_clusters(a.clustered, hw=self.hw)
            for a in arts
        ]
        offsets = np.cumsum([0] + [g.n_actors for g in graphs])
        union = disjoint_union(graphs, name="chip-union")
        union_order: list[int] = []
        for art, off in zip(arts, offsets[:-1]):
            union_order.extend(int(a) + int(off) for a in art.single_order)
        union_binding = np.concatenate(
            [self.reports[n].binding for n in names]
        )
        return names, arts, union, union_order, union_binding, offsets

    def _sub_union(self, names: list[str]):
        """Union view of a SUBSET of residents (same layout as
        :meth:`_resident_union`, minus the names echo): ``(arts, union,
        order, binding, offsets)``.  Cost scales with the subset — never
        with the number of resident tenants."""
        arts = [self.artifacts[(n, self.hw)] for n in names]
        graphs = [
            a.graph if a.graph is not None
            else sdfg_from_clusters(a.clustered, hw=self.hw)
            for a in arts
        ]
        offsets = np.cumsum([0] + [g.n_actors for g in graphs])
        union = disjoint_union(graphs, name="sub-union")
        order: list[int] = []
        for art, off in zip(arts, offsets[:-1]):
            order.extend(int(a) + int(off) for a in art.single_order)
        binding = np.concatenate([self.reports[n].binding for n in names])
        return arts, union, order, binding, offsets

    def _bump_epoch(self, app: str) -> None:
        """Mark ``app``'s binding as rewritten (invalidates cached comps)."""
        self._epoch_counter += 1
        self._binding_epoch[app] = self._epoch_counter

    def _union_rate_scale(self, arts) -> Optional[np.ndarray]:
        """Per-flow-edge drift multipliers of a union over ``arts``.

        The union's flow (data) edges are the per-app channel tables
        concatenated in app order (:func:`~repro.core.sdfg.disjoint_union`
        preserves table order; :func:`~repro.core.sdfg.hardware_static_parts`
        drops only self-edges), so each app's scalar drift factor repeats
        over its own channel count.  None when no member app drifts.
        """
        if not self.chip.drift:
            return None
        parts = [
            np.full(
                a.clustered.channel_src.size,
                self.chip.drift.get(a.app, 1.0),
                dtype=np.float64,
            )
            for a in arts
        ]
        if not parts:
            return None
        out = np.concatenate(parts)
        return None if np.all(out == 1.0) else out

    def _tile_components(self) -> list[list[str]]:
        """Tile-sharing components of the residents (deterministic order).

        Two apps are joined iff they share a physical tile; components are
        exactly the units whose TDMA serialization couples — re-optimizing
        any strict subset of a component could silently change an outside
        app's tile cycles, so regions are always unions of whole
        components.  Names inside a component and the component list are
        sorted for reproducibility.
        """
        names = sorted(self.state.allocated)
        parent = list(range(len(names)))

        def find(i: int) -> int:
            while parent[i] != i:
                parent[i] = parent[parent[i]]
                i = parent[i]
            return i

        owner: dict[int, int] = {}
        for k, n in enumerate(names):
            for t in self.state.allocated[n]:
                t = int(t)
                if t in owner:
                    ra, rb = find(owner[t]), find(k)
                    if ra != rb:
                        parent[rb] = ra
                else:
                    owner[t] = k
        groups: dict[int, list[str]] = {}
        for k, n in enumerate(names):
            groups.setdefault(find(k), []).append(n)
        return [groups[r] for r in sorted(groups)]

    def _component_record(self, comp: list[str]) -> dict:
        """Steady-state record of ONE tile-sharing component (cached).

        Keyed on each member's binding epoch AND the slice of chip
        degradation the component can SEE (its dead tiles, its
        route-scale submatrix, its members' drift factors —
        :meth:`ChipState.component_signature`): any rebalance or
        admission that rewrites a member's binding invalidates exactly
        this record and no other, and a chip mutation invalidates only
        the components it actually touches — a fault re-scores its blast
        radius, not every resident, and a cached period can never be
        combined across chip states it depends on.  Stores the component
        period (max over its graph sub-components), its dynamic energy,
        occupied tiles, NoC cut, and every member app's TRUE per-app
        period.
        """
        foot = sorted(
            {int(t) for n in comp for t in self.state.allocated[n]}
        )
        key = (self.chip.component_signature(foot, comp),) + tuple(
            (n, self._binding_epoch.get(n, -1)) for n in comp
        )
        rec = self._comp_cache.get(key)
        if rec is not None:
            return rec
        arts, union, order, binding, offsets = self._sub_union(comp)
        labels, sub_periods, metrics = union_component_periods(
            union, binding, self.hw,
            project_order_batch(order, binding[None, :]),
            with_metrics=True,
            chip_state=self.chip,
            rate_scale=self._union_rate_scale(arts),
        )
        period = (
            float(sub_periods.max()) if sub_periods.size else float("inf")
        )
        # same decomposition as HardwareConfig.chip_energy: dynamic terms
        # are per-component sums, only the idle term needs the CHIP period
        dyn = (
            self.hw.e_spike_read * metrics.read_charge
            + self.hw.e_packet_encode * float(metrics.cut_traffic[0])
            + self.hw.e_link_hop * float(metrics.spike_hops[0])
        )
        app_periods: dict[str, float] = {}
        for k, n in enumerate(comp):
            lo, hi = int(offsets[k]), int(offsets[k + 1])
            ls = np.unique(labels[lo:hi])
            app_periods[n] = (
                float(sub_periods[ls].max()) if ls.size else float("inf")
            )
        rec = {
            "key": key,
            "names": tuple(comp),
            "period": period,
            "dyn": dyn,
            "tiles": int(metrics.tiles_used[0]),
            "cut": float(metrics.cut_traffic[0]),
            "app_periods": app_periods,
        }
        self._comp_cache[key] = rec
        return rec

    def chip_metrics(self, *, exact: bool = False) -> Optional[dict]:
        """Chip-level steady state of the current placement, or None.

        Default: combine the cached per-component records — tile-sharing
        components are tile-disjoint AND graph-disjoint, so the chip
        period is the max of component periods and the chip energy is the
        sum of component dynamic energies plus idle leakage of all
        occupied tiles at the chip period; only components whose members'
        bindings changed since the last call are rebuilt.  ``exact=True``
        forces the single full-union engine call instead (one B=1
        ``batch_execute`` over every resident — the PR-5 path, used as an
        independent cross-check of the cached combine).

        Returns ``{"chip_period", "chip_throughput", "chip_energy",
        "chip_noc_traffic", "n_resident", "n_components",
        "app_throughputs"}`` — period in microseconds (every resident app
        sustains at least 1/period iterations per microsecond), energy in
        pJ per iteration, traffic in inter-tile spikes per iteration, and
        each app's TRUE steady-state rate (1 / max period over the graph
        components its actors touch) — or None when no app is resident.
        """
        if not self.state.allocated:
            return None
        comps = self._tile_components()
        if exact:
            names, arts, union, order, binding, offsets = self._resident_union()
            rs = self._union_rate_scale(arts)
            with record_cache_stats(self.cache_stats):
                ob = project_order_batch(order, binding[None, :])
                rep = batch_execute(
                    union, binding, self.hw, ob, with_energy=True,
                    chip_state=self.chip, rate_scale=rs,
                )
                labels, sub_periods = union_component_periods(
                    union, binding, self.hw, ob,
                    chip_state=self.chip, rate_scale=rs,
                )
            period = float(rep.periods[0])
            energy = float(rep.energies[0])
            cut = float(rep.metrics.cut_traffic[0])
            app_thr: dict[str, float] = {}
            for k, n in enumerate(names):
                lo, hi = int(offsets[k]), int(offsets[k + 1])
                ls = np.unique(labels[lo:hi])
                p = float(sub_periods[ls].max()) if ls.size else float("inf")
                app_thr[n] = 1.0 / p if np.isfinite(p) and p > 0 else 0.0
        else:
            with record_cache_stats(self.cache_stats):
                recs = [self._component_record(c) for c in comps]
            # prune records of dead configurations (evicted apps, stale
            # epochs) so the cache tracks the resident set, not history
            live = {r["key"] for r in recs}
            self._comp_cache = {
                k: v for k, v in self._comp_cache.items() if k in live
            }
            period = max(r["period"] for r in recs)
            dyn = sum(r["dyn"] for r in recs)
            tiles = sum(r["tiles"] for r in recs)
            cut = sum(r["cut"] for r in recs)
            energy = (
                dyn + self.hw.p_tile_idle * tiles * period
                if np.isfinite(period) else float("inf")
            )
            app_thr = {}
            for r in recs:
                for n, p in r["app_periods"].items():
                    app_thr[n] = (
                        1.0 / p if np.isfinite(p) and p > 0 else 0.0
                    )
        alive = np.isfinite(period) and period > 0
        return {
            "chip_period": period,
            "chip_throughput": 1.0 / period if alive else 0.0,
            "chip_energy": energy,
            "chip_noc_traffic": cut,
            "n_resident": len(self.state.allocated),
            "n_components": len(comps),
            "app_throughputs": app_thr,
        }

    def _stamp_chip_metrics(self, event: AdmissionEvent) -> None:
        """Record the post-event chip state onto ``event`` (when tracking).

        Also refreshes the per-app rate snapshot the staleness detector
        (:meth:`stale_apps`) compares against.
        """
        if not self.track_chip_metrics:
            return
        m = self.chip_metrics()
        if m is not None:
            event.chip_throughput = m["chip_throughput"]
            event.chip_energy = m["chip_energy"]
            event.app_throughputs = dict(m["app_throughputs"])
            self._app_rate_snapshot = dict(m["app_throughputs"])
        else:
            self._app_rate_snapshot = {}

    def defer_rebalances(self):
        """Context manager: coalesce rebalances for a burst of events.

        While active, admits and evicts apply their placement changes
        but skip the per-event joint rebalance — `_rebalance` only
        records the event's (apps, freed tiles).  On exit (or an
        explicit :meth:`flush_rebalances` inside the window) all pending
        events merge into ONE rebalance whose affected region seeds from
        every recorded app and freed tile at once — the serving loop's
        batching lever: K churn events cost one region re-optimization
        (with fused per-component scoring) instead of K.
        """
        import contextlib

        @contextlib.contextmanager
        def _guard():
            self._defer_rebalance = True
            try:
                yield self
            finally:
                self._defer_rebalance = False
                self.flush_rebalances()

        return _guard()

    def flush_rebalances(self) -> int:
        """Run the single merged rebalance for all deferred events.

        Returns the number of events coalesced into this flush (0 when
        nothing is pending).  Safe to call mid-window: pending state is
        consumed and the deferral stays active for subsequent events.
        """
        n = self._deferred_events
        if n == 0:
            return 0
        event_apps = sorted(
            a for a in self._pending_event_apps
            if a in self.state.allocated
        )
        freed = sorted(self._pending_freed)
        self._pending_event_apps.clear()
        self._pending_freed.clear()
        self._deferred_events = 0
        was_deferred, self._defer_rebalance = self._defer_rebalance, False
        try:
            self._rebalance(
                event_apps=event_apps or None,
                freed_tiles=freed or None,
            )
        finally:
            self._defer_rebalance = was_deferred
        return n

    def _rebalance(
        self,
        *,
        event_app: Optional[str] = None,
        event_apps: Optional[list[str]] = None,
        freed_tiles: Optional[list[int]] = None,
    ) -> None:
        """Re-place residents after an event (``placement="joint"``).

        Dispatch: without ``region_scope`` — or every
        ``full_rebalance_every``-th call, or when the affected region
        covers all residents — run the exact full-union re-optimization
        (:meth:`_rebalance_full`, the PR-5 path).  An eviction whose
        freed tiles border no resident component is a no-op (nothing can
        move, and losing a component only lowers the chip period).
        Otherwise re-optimize only the placement region the event
        touches (:meth:`_rebalance_region`): the tile-sharing
        component(s) of ``event_app`` on admit, the components within
        ``region_radius`` mesh hops of ``freed_tiles`` on evict, grown
        over component adjacency up to the cap.
        """
        if self._defer_rebalance:
            # burst window (defer_rebalances): record, rebalance later
            if event_app is not None:
                self._pending_event_apps.add(event_app)
            self._pending_event_apps.update(event_apps or [])
            self._pending_freed.update(
                int(t) for t in (freed_tiles or [])
            )
            self._deferred_events += 1
            return
        if len(self.state.allocated) < 2:
            return
        self._rebalance_count += 1
        if not self.region_scope:
            self._rebalance_full()
            return
        if (
            self.full_rebalance_every
            and self._rebalance_count % self.full_rebalance_every == 0
        ):
            self._rebalance_full()
            return
        event_apps = list(event_apps or [])
        if event_app is not None and event_app not in event_apps:
            event_apps.append(event_app)
        if self._pending_consolidation:
            # fold the deferred fault neighborhoods into this event's
            # region seed: consolidation rides a non-recovery event
            freed_tiles = sorted(
                set(freed_tiles or []) | self._pending_consolidation
            )
            self._pending_consolidation.clear()
        if not self.chip.pristine:
            # while the chip is degraded, churn events double as
            # consolidation opportunities: also re-seed the CHIP
            # bottleneck component, which is never rate-stale itself and
            # would otherwise keep the post-fault placement pinned below
            # what a full re-optimization reaches.  A pristine chip takes
            # the exact PR-6 region path, bit for bit.
            m = self.chip_metrics()
            if m is not None and m["app_throughputs"]:
                slowest = min(m["app_throughputs"].values())
                event_apps = sorted(
                    set(event_apps) | {
                        n for n, r in m["app_throughputs"].items()
                        if r <= slowest * (1 + 1e-9)
                    }
                )
        region = self._affected_region(
            event_apps=event_apps or None,
            freed_tiles=freed_tiles,
        )
        if not region:
            # an isolated eviction: the freed tiles border no resident
            # component, so no placement can change — and dropping a
            # component can only LOWER the chip period (max over fewer
            # components).  Nothing to re-optimize.
            if region is not None and freed_tiles:
                return
            self._rebalance_full()
        elif len(region) >= len(self.state.allocated):
            self._rebalance_full()
        else:
            self._rebalance_region(region)

    def _affected_region(
        self,
        *,
        event_apps: Optional[list[str]] = None,
        freed_tiles: Optional[list[int]] = None,
        grow: bool = True,
    ) -> Optional[list[str]]:
        """Resident apps whose placement the event may affect.

        Seeds from the tile-sharing component(s) the event touches —
        every component containing any of ``event_apps`` (an admitted
        app, or the broken/stale apps of a remap), plus components within
        ``region_radius`` mesh hops of ``freed_tiles`` (an eviction's
        released tiles, or a fault's failed / a heal's recovered tiles) —
        then grows across components whose tile footprints sit within
        ``region_radius`` mesh hops of each other (deterministically, in
        sorted component order) while the region stays within
        ``region_max_apps``.  A seed above the cap is trimmed to the
        nearest whole components; every distance-0 component (one that
        CONTAINS an event app) is always kept even above the cap — a
        remap must cover all broken residents, and any union of whole
        components is a sound region.  An empty list means no resident
        is affected.  Returns the sorted app names.
        """
        comps = self._tile_components()
        if not comps:
            return []
        foots = [
            np.asarray(
                sorted({int(t) for n in c for t in self.state.allocated[n]}),
                dtype=np.int64,
            )
            for c in comps
        ]
        seed: set[int] = set()
        seed_dist: dict[int, float] = {}
        for event_app in event_apps or []:
            for i, c in enumerate(comps):
                if event_app in c:
                    seed.add(i)
                    seed_dist[i] = 0.0
        if freed_tiles:
            ft = np.asarray(sorted(freed_tiles), dtype=np.int64)
            for i, f in enumerate(foots):
                if f.size:
                    d = int(
                        self.hw.hops_array(ft[:, None], f[None, :]).min()
                    )
                    if d <= self.region_radius:
                        seed.add(i)
                        seed_dist.setdefault(i, float(d))
        if not seed:
            return []
        if sum(len(comps[i]) for i in seed) > self.region_max_apps:
            # over-cap seed (many components bordering the freed tiles,
            # or a component snowballed by a past full rebalance): trim
            # to the nearest whole components.  Distance-0 components —
            # the ones CONTAINING an event app — are all kept even above
            # the cap (a remap must cover every broken resident); nearby
            # (distance > 0) components are added only while they fit.
            # Dropping the rest only narrows the re-optimization, never
            # breaks it.
            picked: list[int] = []
            total = 0
            for i in sorted(seed, key=lambda i: (seed_dist[i], i)):
                if (
                    seed_dist[i] > 0.0
                    and picked
                    and total + len(comps[i]) > self.region_max_apps
                ):
                    break
                picked.append(i)
                total += len(comps[i])
            seed = set(picked)
            if total > self.region_max_apps:
                return sorted({n for i in seed for n in comps[i]})
        region = set(seed)
        # fault remaps pass grow=False: adjacency growth co-optimizes
        # NEIGHBORS as an opportunity heuristic, which is worth the wall
        # time on churn events but pure recovery latency on a fault —
        # a neighbor component's optimum provably did not move unless it
        # is broken or rate-stale, and those are already in the seed
        grew = grow
        while grew:
            grew = False
            for i in sorted(region):
                for j, f in enumerate(foots):
                    if j in region or not f.size or not foots[i].size:
                        continue
                    near = int(
                        self.hw.hops_array(
                            foots[i][:, None], f[None, :]
                        ).min()
                    ) <= self.region_radius
                    fits = (
                        sum(len(comps[k]) for k in region) + len(comps[j])
                        <= self.region_max_apps
                    )
                    if near and fits:
                        region.add(j)
                        grew = True
        return sorted({n for i in region for n in comps[i]})

    def _rebalance_full(self) -> None:
        """Jointly re-place ALL resident apps (the exact PR-5 path).

        Runs :func:`~repro.core.optimize.optimize_binding_graph` on the
        disjoint-union graph over the residents' combined tile footprint
        (free tiles are NOT consumed — joint placement redistributes, and
        may even shrink, the existing allocation).  The current
        placement seeds the search, so the chip objective never
        regresses; shared-tile serialization is modeled exactly by the
        union order cycles the projection produces.  Per-app reports are
        updated with the (conservative) union throughput and each app's
        slice of the union schedule; the trajectory records a
        ``"rebalance"`` event with the new chip throughput and energy.
        """
        from .optimize import optimize_binding_graph

        t0 = time.perf_counter()
        names, arts, union, order, binding, offsets = self._resident_union()
        footprint = sorted(
            {
                int(t)
                for ts in self.state.allocated.values()
                for t in ts
                if not self.chip.dead[int(t)]
            }
        )
        if not footprint:
            # every resident tile is dead — nothing to optimize over;
            # remap() handles displacement, a plain rebalance cannot
            return
        # a degraded chip may leave the current binding on dead tiles;
        # repair the seed (nearest alive footprint tile) before searching
        binding = self._repair_binding(binding, footprint)
        gens, pop = self.joint_budget
        ch_src = np.concatenate([
            a.clustered.channel_src + off
            for a, off in zip(arts, offsets[:-1])
        ])
        ch_dst = np.concatenate([
            a.clustered.channel_dst + off
            for a, off in zip(arts, offsets[:-1])
        ])
        ch_rate = np.concatenate(
            [a.clustered.channel_rate for a in arts]
        )
        with record_cache_stats(self.cache_stats):
            rep = optimize_binding_graph(
                union, self.hw, order,
                seed_bindings={"isolated": binding},
                channel_src=ch_src, channel_dst=ch_dst, channel_rate=ch_rate,
                population=pop, generations=gens, rng_seed=0,
                allowed_tiles=footprint, objective=self.objective,
                chip_state=self.chip,
                rate_scale=self._union_rate_scale(arts),
                mesh=self.mesh,
            )
        union_orders = project_order(order, rep.binding, self.hw.n_tiles)
        thr = (
            1.0 / rep.period
            if np.isfinite(rep.period) and rep.period > 0 else 0.0
        )
        for k, name in enumerate(names):
            lo, hi = int(offsets[k]), int(offsets[k + 1])
            b_app = rep.binding[lo:hi].copy()
            self.state.allocated[name] = sorted(
                {int(t) for t in b_app}
            )
            self.reports[name] = CompileReport(
                app=name,
                binding=b_app,
                orders=[
                    [a - lo for a in tile_order if lo <= a < hi]
                    for tile_order in union_orders
                ],
                throughput=thr,
                bind_time_s=rep.opt_time_s / len(names),
                schedule_time_s=0.0,
            )
            self._bump_epoch(name)
        event = AdmissionEvent(
            kind="rebalance", app="*", tiles=footprint,
            wall_s=time.perf_counter() - t0, throughput=thr,
            scope="full", region_apps=len(names),
        )
        if self.track_chip_metrics:
            event.chip_throughput = thr
            event.chip_energy = rep.energy
            m = self.chip_metrics()
            if m is not None:
                event.app_throughputs = dict(m["app_throughputs"])
                self._app_rate_snapshot = dict(m["app_throughputs"])
        self.events.append(event)

    def _rebalance_region(self, names: list[str]) -> None:
        """Re-place ONLY the apps of one affected placement region.

        The region is processed one tile-sharing COMPONENT at a time:
        each component's sub-union is optimized over its own footprint
        plus nearby FREE tiles — ranked by mesh-hop distance to the
        component with a penalty for tiles bordering an outside app (the
        cheap region-boundary traffic term) and never including another
        app's tiles (sibling components included, since the state is
        written back between components), so no new cross-component
        coupling can appear and components never MERGE during region
        rebalances — region cost stays bounded by component size instead
        of snowballing as the optimizer compacts tenants together.
        Cross-component co-location (a global, occasionally-worthwhile
        move) remains available to the periodic full fallback.

        Everything OUTSIDE the component under optimization enters as
        ``period_floor``: candidates are ranked on ``max(component
        period, floor)`` and floor-ties break toward lower energy,
        because no local improvement below the floor can move the chip
        period.  The current binding seeds each search, so the chip
        period never regresses vs. the pre-event binding by construction
        (the floor handed to each component never exceeds the pre-event
        chip period).
        """
        t0 = time.perf_counter()
        self._optimize_region(names)
        m = self.chip_metrics()
        thr = m["chip_throughput"] if m is not None else 0.0
        for name in names:
            self.reports[name].throughput = thr
        event = AdmissionEvent(
            kind="rebalance", app="*",
            tiles=sorted(
                {int(t) for n in names for t in self.state.allocated[n]}
            ),
            wall_s=time.perf_counter() - t0, throughput=thr,
            scope="region", region_apps=len(names),
        )
        if self.track_chip_metrics and m is not None:
            event.chip_throughput = thr
            event.chip_energy = m["chip_energy"]
            event.app_throughputs = dict(m["app_throughputs"])
            self._app_rate_snapshot = dict(m["app_throughputs"])
        self.events.append(event)

    def _optimize_region(self, names: list[str]) -> None:
        """Optimize every tile-sharing component touching ``names``, each
        against the floor set by everything else on the chip (outside
        components AND the other region components' periods).  Shared by
        region rebalances and fault remaps.

        With ``fused_scoring`` (the default) a multi-component region
        runs all component searches in LOCKSTEP through
        :func:`~repro.core.optimize.optimize_binding_graphs_fused`: one
        fused EdgeStack analysis per optimizer generation for the whole
        region instead of one per component per generation.  Floors are
        taken from the PRE-event component periods — each is then at
        most the pre-event chip period, so the never-regress argument is
        unchanged (every search seeds from the current binding and ranks
        on ``max(period, floor)``; the post-event chip period is at most
        ``max_k max(seed_k, floor_k)`` = the pre-event chip period).
        The free tiles offered to the sibling searches are PARTITIONED
        up front (:meth:`_component_allowed` with a shrinking pool), so
        two components can never claim the same free tile and the
        no-merge invariant of sequential processing is preserved.
        """
        region = set(names)
        comps = [
            sorted(c) for c in self._tile_components() if region & set(c)
        ]
        out_periods = [
            self._component_record(c)["period"]
            for c in self._tile_components()
            if not region & set(c)
        ]
        # current period of every region component (cached records)
        comp_periods = [
            self._component_record(c)["period"] for c in comps
        ]
        if len(comps) > 1 and self.fused_scoring:
            self._optimize_components_fused(comps, out_periods, comp_periods)
            return
        for k, comp in enumerate(comps):
            floor = max(
                out_periods + comp_periods[:k] + comp_periods[k + 1:],
                default=float("-inf"),
            )
            comp_periods[k] = self._optimize_component(comp, floor)

    def _component_task(
        self, comp: list[str], floor: float,
        free_pool: Optional[list[int]] = None,
    ) -> tuple[dict, tuple]:
        """One component's fused-search task (kwargs for
        :func:`~repro.core.optimize.optimize_binding_graphs_fused`) plus
        the write-back context ``(names, order, offsets)``.  Mirrors
        :meth:`_optimize_component`'s setup exactly."""
        arts, union, order, binding, offsets = self._sub_union(comp)
        allowed = self._component_allowed(comp, free_pool=free_pool)
        binding = self._repair_binding(binding, allowed)
        gens, pop = self.joint_budget
        if len(comp) > self.region_max_apps:
            gens = 1
            pop = max(2, (pop * self.region_max_apps) // len(comp))
        ch_src = np.concatenate([
            a.clustered.channel_src + off
            for a, off in zip(arts, offsets[:-1])
        ])
        ch_dst = np.concatenate([
            a.clustered.channel_dst + off
            for a, off in zip(arts, offsets[:-1])
        ])
        ch_rate = np.concatenate(
            [a.clustered.channel_rate for a in arts]
        )
        task = dict(
            app=union, hw=self.hw, single_order=order,
            seed_bindings={"current": binding},
            channel_src=ch_src, channel_dst=ch_dst, channel_rate=ch_rate,
            population=pop, generations=gens, rng_seed=0,
            allowed_tiles=allowed, objective=self.objective,
            period_floor=floor,
            chip_state=self.chip,
            rate_scale=self._union_rate_scale(arts),
        )
        return task, (comp, order, offsets)

    def _apply_component_result(
        self, names: list[str], order, offsets, rep
    ) -> None:
        """Write one component's optimized binding back into the chip
        state (allocations, per-app reports, binding epochs)."""
        union_orders = project_order(order, rep.binding, self.hw.n_tiles)
        for k, name in enumerate(names):
            lo, hi = int(offsets[k]), int(offsets[k + 1])
            b_app = rep.binding[lo:hi].copy()
            self.state.allocated[name] = sorted(
                {int(t) for t in b_app}
            )
            self.reports[name] = CompileReport(
                app=name,
                binding=b_app,
                orders=[
                    [a - lo for a in tile_order if lo <= a < hi]
                    for tile_order in union_orders
                ],
                throughput=0.0,   # patched to the chip rate by the caller
                bind_time_s=rep.opt_time_s / len(names),
                schedule_time_s=0.0,
            )
            self._bump_epoch(name)

    def _optimize_components_fused(
        self,
        comps: list[list[str]],
        out_periods: list[float],
        comp_periods: list[float],
    ) -> None:
        """Fused lockstep re-optimization of a region's components."""
        from .optimize import optimize_binding_graphs_fused

        tasks, contexts = [], []
        free = self.state.free_tiles()
        for k, comp in enumerate(comps):
            floor = max(
                out_periods + comp_periods[:k] + comp_periods[k + 1:],
                default=float("-inf"),
            )
            task, ctx = self._component_task(comp, floor, free_pool=free)
            # tiles offered to this component leave the sibling pool:
            # siblings can never bind them, so components cannot merge
            offered = set(task["allowed_tiles"])
            free = [t for t in free if t not in offered]
            tasks.append(task)
            contexts.append(ctx)
        with record_cache_stats(self.cache_stats):
            reps = optimize_binding_graphs_fused(tasks, mesh=self.mesh)
        for (comp, order, offsets), rep in zip(contexts, reps):
            self._apply_component_result(comp, order, offsets, rep)

    def _component_allowed(
        self, names: list[str],
        free_pool: Optional[list[int]] = None,
    ) -> list[int]:
        """Candidate tiles of one component's region search (alive only).

        The component's own (alive) footprint plus the closest free tiles
        — ranked by mesh-hop distance to the footprint with a penalty for
        tiles bordering an outside app (the cheap region-boundary traffic
        term) and never including another app's tiles.  Dead tiles are
        excluded on both sides (``free_tiles`` masks them, the footprint
        is filtered here); a fully-dead footprint still anchors the
        distance ranking so replacement tiles stay near the component's
        original location.  ``free_pool`` overrides the live free-tile
        set — the fused region path partitions one pool among sibling
        components so their offered tiles never overlap.  On a DEGRADED
        chip the free-tile pool is
        widened (2x the footprint instead of matching it): a drifted or
        throttled component recovers chip throughput by spreading over
        free tiles, and the region search can only use tiles it is
        offered — cross-component tile stealing stays reserved for the
        full fallback either way.  An EMPTY result means the component
        has no alive candidate tile at all — the displacement case.
        """
        footprint = sorted(
            {int(t) for n in names for t in self.state.allocated[n]}
        )
        alive_fp = [t for t in footprint if not self.chip.dead[t]]
        allowed = list(alive_fp)
        free = np.asarray(
            self.state.free_tiles() if free_pool is None
            else sorted(free_pool),
            dtype=np.int64,
        )
        if free.size and footprint:
            anchor = np.asarray(
                alive_fp if alive_fp else footprint, dtype=np.int64
            )
            dist = self.hw.hops_array(
                free[:, None], anchor[None, :]
            ).min(axis=1)
            outside = sorted({
                int(t)
                for n, ts in self.state.allocated.items()
                if n not in names
                for t in ts
            })
            penalty = np.zeros(free.size)
            if outside:
                ot = np.asarray(outside, dtype=np.int64)
                d_out = self.hw.hops_array(
                    free[:, None], ot[None, :]
                ).min(axis=1)
                penalty = np.where(d_out <= 1, 2.0, 0.0)
            rank = np.argsort(dist + penalty, kind="stable")
            n_extra = (
                max(4, len(footprint)) if self.chip.pristine
                else max(8, 2 * len(footprint))
            )
            allowed = sorted(
                set(alive_fp) | {int(t) for t in free[rank[:n_extra]]}
            )
        return allowed

    def _repair_binding(self, binding: np.ndarray, allowed: list[int]) -> np.ndarray:
        """Minimal migration of dead-bound actors onto ``allowed`` tiles.

        Every actor on a dead tile moves to the allowed tile nearest its
        original position (deterministic: mesh-hop distance, ties to the
        lowest tile id); actors on alive tiles stay put.  This is the
        remap seed — the cheapest feasible post-fault placement — which
        the region optimizer then only improves on.
        """
        binding = np.asarray(binding, dtype=np.int64).copy()
        bad = self.chip.dead[binding]
        if not bad.any():
            return binding
        assert allowed, "cannot repair a binding with no alive candidate tile"
        al = np.asarray(sorted(allowed), dtype=np.int64)
        d = self.hw.hops_array(binding[bad][:, None], al[None, :])
        binding[bad] = al[np.argmin(d, axis=1)]
        return binding

    def _optimize_component(self, names: list[str], floor: float) -> float:
        """Re-optimize ONE tile-sharing component against ``floor``.

        Seeds from the current binding (repaired off dead tiles first),
        searches the component footprint plus a few ranked free tiles
        (:meth:`_component_allowed`), writes the result back (bindings,
        allocations, projected orders, epochs) and returns the
        component's new (floor-clamped) period.  Oversized components —
        possible only after a full rebalance co-located many tenants —
        get a reduced search budget so per-event latency stays bounded.
        """
        from .optimize import optimize_binding_graph

        task, (_, order, offsets) = self._component_task(names, floor)
        app = task.pop("app")
        hw = task.pop("hw")
        single_order = task.pop("single_order")
        with record_cache_stats(self.cache_stats):
            rep = optimize_binding_graph(
                app, hw, single_order, mesh=self.mesh, **task
            )
        self._apply_component_result(names, order, offsets, rep)
        return max(float(rep.period), floor)

    # -- introspection --------------------------------------------------
    def running(self) -> dict[str, list[int]]:
        """Currently-admitted apps -> sorted physical tile ids they hold."""
        return {a: sorted(t) for a, t in self.state.allocated.items()}

    def free_tiles(self) -> list[int]:
        """Sorted physical tile ids currently available for admission."""
        return self.state.free_tiles()

    def trajectory(self) -> list[dict]:
        """JSON-ready event log (consumed by ``benchmarks/admission.py``)."""
        return [dataclasses.asdict(e) for e in self.events]


def verify_deadlock_free(
    clustered: ClusteredSNN,
    hw: HardwareConfig,
    report: CompileReport,
    *,
    iterations: int = 6,
) -> bool:
    """Operational Lemma-1 check: the projected schedule must complete."""
    app = sdfg_from_clusters(clustered, hw=hw)
    trace = SelfTimedExecutor(
        app, report.binding, hw, orders=report.orders
    ).run(iterations=iterations)
    return trace.period > 0

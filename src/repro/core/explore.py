"""Design-space exploration over the array-native IR.

The paper evaluates one binding per (application, hardware) pair; real
deployments ask the opposite question — *which* crossbar size / tile count /
binder / tile subset should this SNN get?  Answering it multiplies the
number of hardware-aware SDFGs to analyze (SpiNeMap-style baselines double
it again), which is exactly what the batched Max-Plus layer is for: build
all candidate graphs, stack their edge arrays (:func:`~.maxplus.stack_graphs`),
and bisect every candidate's maximum cycle ratio together in one
:func:`~.maxplus.mcr_batch` call.

Two entry points:

  * :func:`sweep` — full factorial sweep ``apps x crossbar_sizes x
    tile_counts x binders`` -> :class:`SweepReport` (used by
    ``benchmarks/sweep.py`` for the paper-style comparisons).
  * :func:`score_free_tile_subsets` — run-time admission helper: score all
    candidate k-subsets of the currently-free tiles in one batched call
    (used by :func:`repro.core.runtime.runtime_admit`).
"""

from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Callable, Optional, Sequence, Union

import numpy as np

from .binding import bind_ours, bind_pycarl, bind_spinemap, cut_spikes_batch
from .engine import batch_execute, project_order_batch
from .hardware import DYNAP_SE, CrossbarConfig, HardwareConfig, TileConfig
from .maxplus import mcr_batch, mcr_howard, stack_graphs, throughput_batch
from .optimize import bind_optimized
from .partition import ClusteredSNN, partition_greedy
from .runtime import project_order
from .schedule import build_static_orders, build_static_orders_batch
from .sdfg import SDFG, hardware_aware_sdfg, sdfg_from_clusters
from .snn import SNN

#: Binding strategies by name: the paper's three §4.2/§6.3 heuristics plus
#: the throughput-in-the-loop optimizer (:mod:`repro.core.optimize`).  All
#: share the ``(clustered, hw, **kwargs) -> BindingResult`` signature, so
#: :func:`sweep` / :func:`build_candidates` / admission treat them alike.
BINDERS: dict[str, Callable] = {
    "ours": bind_ours,
    "pycarl": bind_pycarl,
    "spinemap": bind_spinemap,
    "optimized": bind_optimized,
}


@dataclasses.dataclass(frozen=True)
class SweepPoint:
    """One evaluated candidate configuration.

    ``throughput`` is iterations per microsecond (1/period);
    ``cut_spikes`` the inter-tile spikes per iteration (SpiNeMap's
    objective) and ``spike_hops`` the rate-weighted NoC hop count — both
    from one batched :func:`~repro.core.binding.cut_spikes_batch`-style
    pass per binder group.  ``energy`` is the chip energy (pJ per
    iteration, :meth:`~repro.core.hardware.HardwareConfig.chip_energy`,
    filled in after analysis — it needs the period for the idle term), so
    (throughput, energy) Pareto fronts over a sweep come for free.
    """

    app: str
    crossbar: int        # crossbar inputs (= outputs; crosspoints = n^2)
    n_tiles: int
    binder: str
    n_clusters: int
    throughput: float
    cut_spikes: float
    spike_hops: float = 0.0     # rate-weighted NoC hops / iteration
    energy: float = 0.0         # pJ / iteration (0.0 until analyzed)


@dataclasses.dataclass
class SweepReport:
    """Result of one design-space sweep.

    ``build_time_s`` covers candidate construction (partition / bind /
    schedule / graph build); ``analysis_time_s`` is the Max-Plus evaluation
    of all candidates — the part the batched layer accelerates.
    """

    points: list[SweepPoint]
    build_time_s: float
    analysis_time_s: float
    method: str

    @property
    def n_candidates(self) -> int:
        """Number of evaluated (app, crossbar, tiles, binder) points."""
        return len(self.points)

    def best(self, app: str) -> SweepPoint:
        """Highest-throughput sweep point of ``app`` (throughput in
        iterations per microsecond of model time)."""
        mine = [p for p in self.points if p.app == app]
        if not mine:
            raise KeyError(f"no sweep points for app {app!r}")
        return max(mine, key=lambda p: p.throughput)

    def rows(self) -> list[tuple]:
        """CSV-ready rows (header + one tuple per sweep point)."""
        out: list[tuple] = [
            ("app", "crossbar", "tiles", "binder", "clusters",
             "throughput", "cut_spikes", "spike_hops", "energy_pj")
        ]
        for p in self.points:
            out.append((
                p.app, p.crossbar, p.n_tiles, p.binder, p.n_clusters,
                f"{p.throughput:.6e}", f"{p.cut_spikes:.1f}",
                f"{p.spike_hops:.1f}", f"{p.energy:.1f}",
            ))
        return out

    def pareto_front(self, app: str) -> list[SweepPoint]:
        """Non-dominated (period, energy) sweep points of ``app``.

        Points sorted by descending throughput; a point survives iff no
        other point of the same app has both higher-or-equal throughput
        and strictly lower energy (the ascending-energy tiebreak makes a
        throughput tie keep only its cheapest point).  Dead points (zero
        throughput) never qualify.
        """
        mine = sorted(
            (p for p in self.points if p.app == app and p.throughput > 0),
            key=lambda p: (-p.throughput, p.energy),
        )
        front: list[SweepPoint] = []
        best_e = np.inf
        for p in mine:
            if p.energy < best_e:
                front.append(p)
                best_e = p.energy
        return front


def _hw_for(base: HardwareConfig, crossbar: int, n_tiles: int) -> HardwareConfig:
    tile = dataclasses.replace(
        base.tile,
        crossbar=CrossbarConfig(crossbar, crossbar, crossbar * crossbar),
    )
    return dataclasses.replace(base, n_tiles=n_tiles, tile=tile)


def build_candidates(
    apps: Sequence[Union[str, SNN]],
    *,
    crossbar_sizes: Sequence[int] = (128,),
    tile_counts: Sequence[int] = (4,),
    binders: Sequence[str] = ("ours",),
    hw_base: HardwareConfig = DYNAP_SE,
    with_orders: bool = True,
    sim_iterations: int = 12,
    order_method: str = "batch",
) -> tuple[list[SweepPoint], list[SDFG], float, dict]:
    """Construct every candidate's hardware-aware SDFG for a factorial sweep.

    ``apps`` mixes Table-1 app names and prebuilt :class:`SNN` objects.
    Partitioning (Alg. 1) runs once per (app, crossbar); binding per
    candidate; static orders per (app, crossbar, tiles) GROUP — all
    binders' bindings go through one
    :func:`~repro.core.schedule.build_static_orders_batch` call
    (``order_method="heapq"`` restores the per-candidate discrete-event
    loop with ``sim_iterations`` FCFS iterations; ``sim_iterations`` is
    IGNORED under the default ``"batch"`` constructor).  Returns
    ``(points, graphs, build_time_s, energy_aux)`` with throughputs still
    zero — analysis is a separate (batchable) step.  Traffic metrics
    (``cut_spikes``, ``spike_hops``) are scored per binder GROUP in one
    :func:`~repro.core.binding.cut_spikes_batch`-style vectorized pass;
    ``energy_aux`` carries the period-independent energy pieces
    (``dyn_energy`` pJ and ``idle_per_us`` pJ/us arrays, one entry per
    point) that :func:`sweep` combines with the analyzed periods.
    """
    from .apps import build_app

    t_build0 = time.perf_counter()
    snns: list[SNN] = [
        build_app(a) if isinstance(a, str) else a for a in apps
    ]

    clustered: dict[tuple[str, int], ClusteredSNN] = {}
    metas: list[SweepPoint] = []
    graphs: list[SDFG] = []
    for snn, xb in itertools.product(snns, crossbar_sizes):
        key = (snn.name, xb)
        if key not in clustered:
            clustered[key] = partition_greedy(snn, _hw_for(hw_base, xb, 1))
    dyn_energy: list[float] = []
    idle_per_us: list[float] = []
    for snn, xb, n_tiles in itertools.product(
        snns, crossbar_sizes, tile_counts
    ):
        cl = clustered[(snn.name, xb)]
        hw = _hw_for(hw_base, xb, n_tiles)
        app_g = sdfg_from_clusters(cl, hw=hw)
        bres_list = [BINDERS[binder](cl, hw) for binder in binders]
        bind_mat = np.stack([b.binding for b in bres_list])
        # one vectorized traffic/energy pass for the whole binder group
        cuts = cut_spikes_batch(cl, bind_mat)
        hops = hw.hops_array(
            bind_mat[:, cl.channel_src], bind_mat[:, cl.channel_dst]
        )
        s_hops = (cl.channel_rate[None, :] * hops).sum(axis=1)
        # crossbar read charge: delivered spikes weighted by the target
        # cluster's mean OxRAM row length (matches ChipMetrics.read_charge)
        row_len = cl.synapses_used / np.maximum(cl.inputs_used, 1)
        read_charge = float(
            (cl.channel_rate * row_len[cl.channel_dst]).sum()
        )
        dyn = (
            hw.e_spike_read * read_charge
            + hw.e_packet_encode * cuts
            + hw.e_link_hop * s_hops
        )
        orders_group: Optional[list] = None
        if with_orders and order_method == "batch":
            orders_group = build_static_orders_batch(app_g, bind_mat, hw)
        for k, (binder, bres) in enumerate(zip(binders, bres_list)):
            orders = None
            if with_orders:
                if orders_group is not None:
                    orders = orders_group[k]
                else:
                    orders, _ = build_static_orders(
                        app_g, bres.binding, hw, iterations=sim_iterations
                    )
            graphs.append(hardware_aware_sdfg(app_g, bres.binding, hw, orders))
            dyn_energy.append(float(dyn[k]))
            idle_per_us.append(
                hw.p_tile_idle * len(set(bres.binding.tolist()))
            )
            metas.append(SweepPoint(
                app=snn.name,
                crossbar=xb,
                n_tiles=n_tiles,
                binder=binder,
                n_clusters=cl.n_clusters,
                throughput=0.0,
                cut_spikes=float(cuts[k]),
                spike_hops=float(s_hops[k]),
            ))
    aux = {
        "dyn_energy": np.asarray(dyn_energy),
        "idle_per_us": np.asarray(idle_per_us),
    }
    return metas, graphs, time.perf_counter() - t_build0, aux


def analyze_candidates(
    graphs: Sequence[SDFG],
    *,
    method: str = "batched",
    backend: str = "auto",
    rel_tol: float = 1e-8,
) -> np.ndarray:
    """Throughput of every candidate graph.

    ``method``: ``"batched"`` (default, one :func:`mcr_batch` call over the
    stacked edge arrays) or ``"howard-loop"`` / ``"binary-loop"`` — the
    per-graph Python loops, kept as the benchmark baselines the batched
    layer is measured against.
    """
    from .maxplus import mcr_binary_search

    if method == "batched":
        return throughput_batch(graphs, backend=backend, rel_tol=rel_tol)
    if method in ("howard-loop", "binary-loop"):
        fn = mcr_howard if method == "howard-loop" else mcr_binary_search
        rhos = np.array([fn(g) for g in graphs])
        return np.where(
            np.isfinite(rhos) & (rhos > 0), 1.0 / np.maximum(rhos, 1e-300), 0.0
        )
    raise ValueError(f"unknown sweep method {method!r}")


def sweep(
    apps: Sequence[Union[str, SNN]],
    *,
    crossbar_sizes: Sequence[int] = (128,),
    tile_counts: Sequence[int] = (4,),
    binders: Sequence[str] = ("ours",),
    hw_base: HardwareConfig = DYNAP_SE,
    with_orders: bool = True,
    sim_iterations: int = 12,
    order_method: str = "batch",
    method: str = "batched",
    backend: str = "auto",
    rel_tol: float = 1e-8,
) -> SweepReport:
    """Factorial design-space sweep, analyzed in one batched Max-Plus call.

    Composition of :func:`build_candidates` and :func:`analyze_candidates`;
    see those for the knobs.  Every point reports the chip metrics —
    throughput, cut spikes, spike-hops and total energy (pJ/iteration,
    idle term from the analyzed period) — so
    :meth:`SweepReport.pareto_front` yields DSE Pareto fronts without a
    second pass.
    """
    metas, graphs, build_time, aux = build_candidates(
        apps,
        crossbar_sizes=crossbar_sizes,
        tile_counts=tile_counts,
        binders=binders,
        hw_base=hw_base,
        with_orders=with_orders,
        sim_iterations=sim_iterations,
        order_method=order_method,
    )
    t_an0 = time.perf_counter()
    thrs = analyze_candidates(
        graphs, method=method, backend=backend, rel_tol=rel_tol
    )
    analysis_time = time.perf_counter() - t_an0

    periods = np.where(
        np.asarray(thrs) > 0, 1.0 / np.maximum(thrs, 1e-300), np.inf
    )
    energies = np.where(
        np.isfinite(periods),
        aux["dyn_energy"] + aux["idle_per_us"] * periods,
        np.inf,
    )
    points = [
        dataclasses.replace(p, throughput=float(t), energy=float(e))
        for p, t, e in zip(metas, thrs, energies)
    ]
    return SweepReport(
        points=points,
        build_time_s=build_time,
        analysis_time_s=analysis_time,
        method=method,
    )


# ======================================================================
# run-time admission: batched scoring of candidate free-tile subsets
# ======================================================================
def candidate_subsets(
    free: Sequence[int], k: int, *, max_candidates: int = 64, seed: int = 0
) -> list[tuple[int, ...]]:
    """k-subsets of the free tiles to score (exhaustive when small).

    Falls back to contiguous windows plus random samples when the binomial
    count explodes — admission must stay fast (§5, Table 3).  The windows
    themselves are strided down to 3/4 of the budget when the chip is
    large (a 32x32 mesh with ~900 free tiles would otherwise emit ~900
    window candidates and swamp the batched scorer); small chips keep
    every window, bit-identical to the unstrided behaviour.
    """
    free = list(free)
    from math import comb

    if comb(len(free), k) <= max_candidates:
        return list(itertools.combinations(free, k))
    subsets: dict[tuple[int, ...], None] = {}
    n_windows = len(free) - k + 1                # contiguous = few NoC hops
    if n_windows > max_candidates:
        keep = max(1, (3 * max_candidates) // 4)
        starts = np.unique(np.linspace(0, n_windows - 1, keep).astype(int))
        for i in starts:
            subsets[tuple(free[int(i) : int(i) + k])] = None
    else:
        for i in range(n_windows):
            subsets[tuple(free[i : i + k])] = None
    rng = np.random.default_rng(seed)
    while len(subsets) < max_candidates:
        pick = tuple(sorted(rng.choice(len(free), size=k, replace=False)))
        subsets[tuple(free[i] for i in pick)] = None
    return list(subsets)


@dataclasses.dataclass
class SubsetScores:
    """Batched scoring of candidate tile subsets (admission helper).

    ``subsets[i]`` is a k-tuple of physical tile ids scored by
    ``throughputs[i]`` (iterations per microsecond; shape (len(subsets),))
    and ``energies[i]`` (chip energy, pJ per iteration — same batched
    engine call, ``inf`` for dead candidates).  ``binding``/
    ``virt_orders`` are the *virtual* (k-tile) binding ((n_clusters,) ids
    in [0, k)) and the Lemma-1 projected per-tile orders — computed once,
    reusable by the caller so admission doesn't bind or project twice.
    """

    subsets: list[tuple[int, ...]]
    throughputs: np.ndarray
    binding: np.ndarray              # (n_clusters,) virtual tile ids in [0, k)
    virt_orders: list[list[int]]
    energies: Optional[np.ndarray] = None   # (len(subsets),) pJ / iteration

    @property
    def best(self) -> tuple[int, ...]:
        """The physical tile ids of the highest-throughput subset."""
        return self.subsets[int(np.argmax(self.throughputs))]

    @property
    def best_energy(self) -> tuple[int, ...]:
        """The physical tile ids of the lowest-chip-energy subset."""
        assert self.energies is not None, "scored without energies"
        return self.subsets[int(np.argmin(self.energies))]


def score_free_tile_subsets(
    clustered: ClusteredSNN,
    hw: HardwareConfig,
    free: Sequence[int],
    k: int,
    single_order: Sequence[int],
    *,
    binder: Callable = bind_ours,
    binder_kwargs: Optional[dict] = None,
    max_candidates: int = 64,
    backend: str = "auto",
    chip_state=None,
    rate_scale=None,
) -> SubsetScores:
    """Score every candidate k-subset of the free tiles in ONE batched call.

    The virtual binding and the Lemma-1 projected per-tile orders depend
    only on ``k``, so they are computed once; candidates differ in which
    physical tiles the virtual tiles land on — i.e. purely in NoC delays —
    which is exactly a stack of edge-weight arrays over a shared topology.

    ``chip_state``/``rate_scale`` score the candidates under run-time
    degradation (throttled routes, drifted spike rates; see
    :func:`~repro.core.engine.batch_execute`) — callers must already have
    excluded dead tiles from ``free``.
    """
    subsets = candidate_subsets(free, k, max_candidates=max_candidates)
    sub_hw = dataclasses.replace(hw, n_tiles=k)
    kwargs = binder_kwargs or {}
    try:
        bres = binder(clustered, sub_hw, **kwargs)
    except TypeError:  # binders without the kwargs (spinemap)
        bres = binder(clustered, sub_hw)
    virt_orders = project_order(list(single_order), bres.binding, k)

    # one (B, n_clusters) binding matrix + ONE vectorized Lemma-1
    # projection (OrderBatch): the engine builds the candidate EdgeStack
    # directly — no per-candidate SDFG objects, no per-candidate order
    # lists, no per-candidate §4.4 transformation in Python.  Projecting
    # the single order under each candidate's physical binding yields
    # exactly the virtual per-tile sequences relabeled onto the subset.
    app_g = sdfg_from_clusters(clustered, hw=hw)
    phys_bindings = np.asarray(subsets, dtype=np.int64)[:, bres.binding]
    orders = project_order_batch(list(single_order), phys_bindings)
    rep = batch_execute(
        app_g, phys_bindings, hw, orders, backend=backend, with_energy=True,
        chip_state=chip_state, rate_scale=rate_scale,
    )
    return SubsetScores(
        subsets=subsets,
        throughputs=rep.throughputs,
        binding=bres.binding,
        virt_orders=virt_orders,
        energies=rep.energies,
    )

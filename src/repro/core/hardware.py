"""Neuromorphic hardware model (DYNAP-SE-like tiled crossbar chip).

The paper (§4.1, §6.1) models DYNAP-SE [51]: a tiled array of crossbars
connected by a mesh NoC using the AER protocol.  Each tile has

  * one crossbar with ``crossbar_inputs`` row wires and ``crossbar_outputs``
    column wires (128x128 on DYNAP-SE, 65,536 OxRAM crosspoints),
  * an input buffer and an output buffer (spike packets),
  * a network interface serializing AER packets on the interconnect.

Timing constants are modeled from the paper's cited sources: the execution
time of a cluster firing is the current-propagation delay through an OxRAM
synapse array (Mallik et al. [49]; Garbin et al. [36] for HfO2 devices) and
the AER link serializes one spike packet per ``t_spike_link`` on the mesh.
Absolute scales are configurable; every benchmark reports *normalized*
throughput exactly like the paper, which is invariant to the absolute unit.

Energy constants follow the same sources plus the SpiNeMap energy argument
(Balaji et al.): inter-tile AER events dominate chip dynamic energy, so the
model charges a per-spike crossbar read (OxRAM read, [49]/[36]), a
per-packet AER encode at the source NI, a per-packet-per-hop mesh link
cost, and a per-tile idle/leakage power integrated over the iteration
period.  Units are picojoules (pJ) and microwatts (pJ/us); as with timing,
absolute scales are configurable and benchmarks compare *relative* energy.
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np


@dataclasses.dataclass(frozen=True)
class CrossbarConfig:
    """Resource constraints of a single crossbar (the bin in Alg. 1)."""

    inputs: int = 128          # row wires = max distinct pre-synaptic sources
    outputs: int = 128         # column wires = max neurons per cluster
    crosspoints: int = 128 * 128  # OxRAM cells = max synapses per cluster

    def fits(self, n_inputs: int, n_neurons: int, n_synapses: int) -> bool:
        return (
            n_inputs <= self.inputs
            and n_neurons <= self.outputs
            and n_synapses <= self.crosspoints
        )


@dataclasses.dataclass(frozen=True)
class TileConfig:
    """A tile: crossbar + IO buffers + network interface."""

    crossbar: CrossbarConfig = CrossbarConfig()
    input_buffer: int = 4096    # spike packets
    output_buffer: int = 4096   # spike packets
    # NoC connections available per tile (mesh: N/E/S/W + local).
    connections: int = 4


@dataclasses.dataclass(frozen=True)
class HardwareConfig:
    """A tiled neuromorphic chip (Fig. 7)."""

    n_tiles: int = 4
    tile: TileConfig = TileConfig()

    # --- timing model (microseconds) ------------------------------------
    # Crossbar current-propagation delay per firing (OxRAM read, [49]).
    t_fire: float = 4.0
    # AER encode/serialize per spike packet at the source NI.  Calibrated so
    # the model reproduces the paper's measured regime (Table 2: 5-23%
    # bandwidth utilization — compute/TDMA-bound, not comm-bound).
    t_spike_encode: float = 0.001
    # Mesh link time per spike packet per hop (~500 Mevents/s/link).
    t_spike_link: float = 0.002
    # Fixed per-message NoC latency (route setup), per channel per firing.
    t_route: float = 0.05

    # --- energy model (picojoules; idle power in pJ/us = uW) -------------
    # OxRAM crossbar read + integrate per delivered spike ([49], [36]).
    e_spike_read: float = 2.0
    # AER encode/serialize per inter-tile spike packet at the source NI.
    e_packet_encode: float = 1.0
    # Mesh link traversal per spike packet per hop (SpiNeMap's dominant
    # term: inter-tile AER events on the interconnect).
    e_link_hop: float = 0.5
    # Idle/leakage power per occupied tile (pJ per microsecond of period).
    p_tile_idle: float = 0.25

    @property
    def mesh_shape(self) -> tuple[int, int]:
        """(n_cols, n_rows) of the 2D mesh NoC, ``n_cols * n_rows == n_tiles``.

        The most-square exact factorization with ``n_cols <= n_rows``:
        4 -> (2, 2), 8 -> (2, 4), 9 -> (3, 3), 12 -> (3, 4); a prime tile
        count degenerates to a (1, n) chain.  Tile ``t`` sits at column
        ``t % n_cols``, row ``t // n_cols`` — always inside the mesh, which
        the old square-only ``isqrt`` dimension did not guarantee for
        non-square ``n_tiles``.
        """
        n = self.n_tiles
        c = max(1, math.isqrt(n))
        while c > 1 and n % c:
            c -= 1
        return c, n // c

    @property
    def mesh_dim(self) -> int:
        """Mesh column count (compat alias; equals both dims on squares)."""
        return self.mesh_shape[0]

    def hops(self, src_tile: int, dst_tile: int) -> int:
        """Manhattan hop count on the 2D mesh NoC."""
        if src_tile == dst_tile:
            return 0
        d, _ = self.mesh_shape
        sx, sy = src_tile % d, src_tile // d
        dx, dy = dst_tile % d, dst_tile // d
        return abs(sx - dx) + abs(sy - dy)

    def comm_delay(self, n_spikes: float, src_tile: int, dst_tile: int) -> float:
        """Time to move ``n_spikes`` AER packets from src to dst tile."""
        if src_tile == dst_tile:
            return 0.0
        hops = self.hops(src_tile, dst_tile)
        # Pipelined wormhole: serialization dominates, one extra link time/hop.
        return (
            self.t_route
            + n_spikes * (self.t_spike_encode + self.t_spike_link)
            + (hops - 1) * self.t_spike_link
        )

    def hops_array(self, src_tiles: np.ndarray, dst_tiles: np.ndarray) -> np.ndarray:
        """Vectorized Manhattan hop counts (same-tile pairs report 0)."""
        d, _ = self.mesh_shape
        src_tiles = np.asarray(src_tiles, dtype=np.int64)
        dst_tiles = np.asarray(dst_tiles, dtype=np.int64)
        return np.abs(src_tiles % d - dst_tiles % d) + np.abs(
            src_tiles // d - dst_tiles // d
        )

    def comm_delay_from_hops(
        self, n_spikes: np.ndarray, hops: np.ndarray, link_scale=None
    ) -> np.ndarray:
        """Vectorized :meth:`comm_delay` from precomputed hop counts.

        ``hops == 0`` marks a same-tile pair (distinct tiles are always
        >= 1 hop apart on the mesh) and yields zero delay.  Shared by
        :meth:`comm_delay_array` and the batched engine, which derives
        delay AND energy from one hop computation.

        ``link_scale`` (broadcastable against ``hops``) multiplies the mesh
        link time — the per-route throttle factor from
        :meth:`ChipState.route_scale`.  Wormhole serialization is gated by
        the slowest link on the route, so one factor scales both the
        per-packet serialization term and the pipeline-fill term.
        """
        t_link = self.t_spike_link
        if link_scale is not None:
            t_link = t_link * np.asarray(link_scale, dtype=np.float64)
        delay = (
            self.t_route
            + np.asarray(n_spikes) * (self.t_spike_encode + t_link)
            + (hops - 1) * t_link
        )
        return np.where(hops == 0, 0.0, delay)

    def comm_delay_array(
        self, n_spikes: np.ndarray, src_tiles: np.ndarray, dst_tiles: np.ndarray
    ) -> np.ndarray:
        """Vectorized :meth:`comm_delay` over parallel channel arrays."""
        src_tiles = np.asarray(src_tiles, dtype=np.int64)
        dst_tiles = np.asarray(dst_tiles, dtype=np.int64)
        return self.comm_delay_from_hops(
            n_spikes, self.hops_array(src_tiles, dst_tiles)
        )

    def energy_from_hops(
        self, n_spikes: np.ndarray, hops: np.ndarray
    ) -> np.ndarray:
        """Dynamic NoC energy (pJ) per channel per iteration from hop counts.

        ``n_spikes`` AER packets each pay one encode at the source NI plus
        one link traversal per hop; same-tile channels (``hops == 0``) are
        free — their spikes never leave the crossbar.  Mirrors
        :meth:`comm_delay_from_hops` and broadcasts identically, so a
        (B, E) hop matrix yields (B, E) energies in one call.
        """
        n_spikes = np.asarray(n_spikes)
        return np.where(
            hops == 0,
            0.0,
            n_spikes * (self.e_packet_encode + self.e_link_hop * hops),
        )

    def energy_array(
        self, n_spikes: np.ndarray, src_tiles: np.ndarray, dst_tiles: np.ndarray
    ) -> np.ndarray:
        """Vectorized per-channel dynamic NoC energy (pJ per iteration).

        Mirrors :meth:`comm_delay_array`: parallel channel arrays of spike
        rates and endpoint tiles (leading batch dims broadcast) yield the
        AER encode + link energy of moving each channel's spikes, zero for
        co-located endpoints.
        """
        src_tiles = np.asarray(src_tiles, dtype=np.int64)
        dst_tiles = np.asarray(dst_tiles, dtype=np.int64)
        return self.energy_from_hops(
            n_spikes, self.hops_array(src_tiles, dst_tiles)
        )

    def chip_energy(
        self,
        periods: np.ndarray,
        cut_traffic: np.ndarray,
        spike_hops: np.ndarray,
        tiles_used: np.ndarray,
        read_charge: float,
    ) -> np.ndarray:
        """Total chip energy (pJ) per iteration for a batch of candidates.

        ``periods`` is (B,) steady-state iteration periods (us);
        ``cut_traffic`` is (B,) inter-tile spikes per iteration,
        ``spike_hops`` (B,) rate-weighted hop counts, ``tiles_used`` (B,)
        occupied-tile counts, and ``read_charge`` the binding-independent
        crossbar read charge per iteration in row-crosspoint units: each
        delivered spike drives one crossbar row wire and reads every OxRAM
        crosspoint on it, so a spike's charge scales with the destination
        cluster's fan-out row length (mean crosspoints per input row).
        Passing a plain delivered-spike count keeps the older flat
        per-spike model (row length 1).  Energy = crossbar reads + AER
        encode of the cut + link hops + idle leakage of the occupied tiles
        over one period; rows with a dead/acyclic period (non-finite or
        <= 0) report ``inf``.
        """
        periods = np.asarray(periods, dtype=np.float64)
        dyn = (
            self.e_spike_read * read_charge
            + self.e_packet_encode * np.asarray(cut_traffic)
            + self.e_link_hop * np.asarray(spike_hops)
        )
        ok = np.isfinite(periods) & (periods > 0)
        return np.where(
            ok,
            dyn + self.p_tile_idle * np.asarray(tiles_used) * np.where(ok, periods, 0.0),
            np.inf,
        )


class ChipState:
    """Mutable degradation state of one physical chip.

    :class:`HardwareConfig` is frozen and hashable — it is the *design-time*
    model and doubles as a compile-cache key, so run-time degradation lives
    here instead: dead tiles, per-link NoC throttle factors, and per-app
    spike-rate drift multipliers.  The engine consumes this state inside its
    one-pass hop traversal (``stack_hardware_aware``), so degraded candidate
    bindings score exactly in the same batched ``EdgeStack`` path as healthy
    ones — no second modeling path.

    Every mutation bumps :attr:`epoch`; callers that cache period analyses
    (the runtime's component-record cache) key on the epoch so stale results
    can never be combined with fresh ones.

    Link throttles use the mesh's XY (dimension-order) routing: a route
    first travels along the row to the destination column, then along the
    column.  Wormhole serialization is gated by the slowest link on the
    route, so a route's scale factor is the *max* throttle over the links it
    crosses, precomputed as an (n_tiles, n_tiles) matrix and gathered per
    (candidate, edge) pair in the batched path.
    """

    def __init__(self, hw: HardwareConfig):
        self.hw = hw
        self.dead = np.zeros(hw.n_tiles, dtype=bool)
        self.link_throttle: dict[tuple[int, int], float] = {}
        self.drift: dict[str, float] = {}
        self.epoch = 0
        self._scale_cache: np.ndarray | None = None
        self._sig_cache: dict[tuple, tuple[int, tuple]] = {}

    # --- introspection ---------------------------------------------------
    @property
    def pristine(self) -> bool:
        """True when no degradation is active (fast-path: skip all scaling)."""
        return not (self.dead.any() or self.link_throttle or self.drift)

    def alive_tiles(self) -> np.ndarray:
        return np.flatnonzero(~self.dead)

    @property
    def n_alive(self) -> int:
        return int((~self.dead).sum())

    def dead_rows(self, bindings: np.ndarray) -> np.ndarray:
        """(B,) mask of candidate bindings that touch any dead tile."""
        bindings = np.asarray(bindings, dtype=np.int64)
        return self.dead[bindings].any(axis=-1)

    # --- mutations (each bumps the epoch) --------------------------------
    def _bump(self, *, links: bool = False) -> None:
        self.epoch += 1
        if links:
            self._scale_cache = None

    def fail_tiles(self, tiles) -> None:
        tiles = np.asarray(tiles, dtype=np.int64).reshape(-1)
        if tiles.size and (tiles.min() < 0 or tiles.max() >= self.hw.n_tiles):
            raise ValueError(f"tile ids out of range for n_tiles={self.hw.n_tiles}")
        self.dead[tiles] = True
        self._bump()

    def heal_tiles(self, tiles) -> None:
        tiles = np.asarray(tiles, dtype=np.int64).reshape(-1)
        self.dead[tiles] = False
        self._bump()

    def throttle_link(self, a: int, b: int, factor: float) -> None:
        """Slow the mesh link between adjacent tiles ``a`` and ``b``.

        ``factor`` multiplies the link's serialization time (>= 1; 1 heals).
        """
        if self.hw.hops(int(a), int(b)) != 1:
            raise ValueError(f"tiles {a} and {b} are not mesh-adjacent")
        if not factor >= 1.0:
            raise ValueError("throttle factor must be >= 1.0")
        key = (min(int(a), int(b)), max(int(a), int(b)))
        if factor == 1.0:
            self.link_throttle.pop(key, None)
        else:
            self.link_throttle[key] = float(factor)
        self._bump(links=True)

    def heal_link(self, a: int, b: int) -> None:
        key = (min(int(a), int(b)), max(int(a), int(b)))
        self.link_throttle.pop(key, None)
        self._bump(links=True)

    def set_drift(self, app: str, factor: float) -> None:
        """Observed spike rates of ``app`` run at ``factor`` x the design profile."""
        if not factor > 0.0:
            raise ValueError("drift factor must be positive")
        if factor == 1.0:
            self.drift.pop(app, None)
        else:
            self.drift[app] = float(factor)
        self._bump()

    def clear_drift(self, app: str) -> None:
        self.drift.pop(app, None)
        self._bump()

    def component_signature(self, tiles, apps) -> tuple:
        """Hashable view of the degradation VISIBLE to one placement
        component: its dead tiles, the route-scale submatrix over its
        tile pairs (None when clean), and its member apps' drift factors.
        Everything chip-dependent in a component's steady-state score is
        a function of this tuple plus the bindings, so record caches
        keyed on it survive mutations that do not touch the component —
        a fault invalidates the components it hits, not the whole chip.

        Memoized per chip epoch: the route-scale submatrix extraction is
        the expensive part, and between mutations every caller asks for
        the same footprints again (cache combines re-derive the signature
        on every lookup).
        """
        key = (tuple(int(t) for t in tiles), tuple(apps))
        hit = self._sig_cache.get(key)
        if hit is not None and hit[0] == self.epoch:
            return hit[1]
        tiles = np.asarray(key[0], dtype=np.int64)
        dead_part = tuple(int(t) for t in tiles[self.dead[tiles]])
        link_part = None
        if self.link_throttle:
            sub = self.route_scale()[np.ix_(tiles, tiles)]
            if (sub != 1.0).any():
                link_part = sub.tobytes()
        drift_part = tuple(self.drift.get(a, 1.0) for a in apps)
        sig = (dead_part, link_part, drift_part)
        if len(self._sig_cache) > 4096:
            self._sig_cache.clear()
        self._sig_cache[key] = (self.epoch, sig)
        return sig

    # --- route throttle matrix -------------------------------------------
    def route_scale(self) -> np.ndarray | None:
        """(n_tiles, n_tiles) per-route link-time multiplier, or None if clean.

        Entry [s, d] is the max throttle factor over the links the XY route
        s -> d crosses (1.0 where the route is clean).  A horizontal link
        (x, y)-(x+1, y) is crossed iff the route's source row is ``y`` and
        ``min(sx, dx) <= x < max(sx, dx)``; a vertical link (x, y)-(x, y+1)
        iff the destination column is ``x`` and ``min(sy, dy) <= y <
        max(sy, dy)``.  Rebuilt lazily after link mutations.
        """
        if not self.link_throttle:
            return None
        if self._scale_cache is None:
            d, _ = self.hw.mesh_shape
            t = np.arange(self.hw.n_tiles, dtype=np.int64)
            x, y = t % d, t // d
            sx, sy = x[:, None], y[:, None]   # source coords (rows)
            dx, dy = x[None, :], y[None, :]   # destination coords (cols)
            scale = np.ones((self.hw.n_tiles, self.hw.n_tiles), dtype=np.float64)
            for (a, b), f in sorted(self.link_throttle.items()):
                ax, ay = a % d, a // d
                bx, by = b % d, b // d
                if ay == by:  # horizontal link (lx, ly)-(lx+1, ly)
                    lx, ly = min(ax, bx), ay
                    crossed = (
                        (sy == ly)
                        & (np.minimum(sx, dx) <= lx)
                        & (lx < np.maximum(sx, dx))
                    )
                else:  # vertical link (lx, ly)-(lx, ly+1)
                    lx, ly = ax, min(ay, by)
                    crossed = (
                        (dx == lx)
                        & (np.minimum(sy, dy) <= ly)
                        & (ly < np.maximum(sy, dy))
                    )
                scale = np.where(crossed, np.maximum(scale, f), scale)
            self._scale_cache = scale
        return self._scale_cache

    def route_scale_array(self, src_tiles, dst_tiles) -> np.ndarray | None:
        """Gather per-pair route scales; None when no link is throttled."""
        scale = self.route_scale()
        if scale is None:
            return None
        return scale[np.asarray(src_tiles, np.int64), np.asarray(dst_tiles, np.int64)]


# The three hardware models evaluated in the paper (§6.1, Fig. 16).
DYNAP_SE = HardwareConfig(n_tiles=4)
DYNAP_SE_9 = HardwareConfig(n_tiles=9)
DYNAP_SE_16 = HardwareConfig(n_tiles=16)
# Production-shape chip for the multi-tenant stress harness: a 32x32 mesh
# (1024 tiles), the scale at which region-scoped joint placement pays off.
DYNAP_SE_1024 = HardwareConfig(n_tiles=1024)


def hardware_by_name(name: str) -> HardwareConfig:
    table = {
        "dynap-se": DYNAP_SE,
        "dynap-se-9": DYNAP_SE_9,
        "dynap-se-16": DYNAP_SE_16,
        "dynap-se-1024": DYNAP_SE_1024,
    }
    try:
        return table[name.lower()]
    except KeyError:  # pragma: no cover - defensive
        raise ValueError(f"unknown hardware model {name!r}; have {sorted(table)}")

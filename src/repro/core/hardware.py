"""Neuromorphic hardware model (DYNAP-SE-like tiled crossbar chip).

The paper (§4.1, §6.1) models DYNAP-SE [51]: a tiled array of crossbars
connected by a mesh NoC using the AER protocol.  Each tile has

  * one crossbar with ``crossbar_inputs`` row wires and ``crossbar_outputs``
    column wires (128x128 on DYNAP-SE, 65,536 OxRAM crosspoints),
  * an input buffer and an output buffer (spike packets),
  * a network interface serializing AER packets on the interconnect.

Timing constants are modeled from the paper's cited sources: the execution
time of a cluster firing is the current-propagation delay through an OxRAM
synapse array (Mallik et al. [49]; Garbin et al. [36] for HfO2 devices) and
the AER link serializes one spike packet per ``t_spike_link`` on the mesh.
Absolute scales are configurable; every benchmark reports *normalized*
throughput exactly like the paper, which is invariant to the absolute unit.
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np


@dataclasses.dataclass(frozen=True)
class CrossbarConfig:
    """Resource constraints of a single crossbar (the bin in Alg. 1)."""

    inputs: int = 128          # row wires = max distinct pre-synaptic sources
    outputs: int = 128         # column wires = max neurons per cluster
    crosspoints: int = 128 * 128  # OxRAM cells = max synapses per cluster

    def fits(self, n_inputs: int, n_neurons: int, n_synapses: int) -> bool:
        return (
            n_inputs <= self.inputs
            and n_neurons <= self.outputs
            and n_synapses <= self.crosspoints
        )


@dataclasses.dataclass(frozen=True)
class TileConfig:
    """A tile: crossbar + IO buffers + network interface."""

    crossbar: CrossbarConfig = CrossbarConfig()
    input_buffer: int = 4096    # spike packets
    output_buffer: int = 4096   # spike packets
    # NoC connections available per tile (mesh: N/E/S/W + local).
    connections: int = 4


@dataclasses.dataclass(frozen=True)
class HardwareConfig:
    """A tiled neuromorphic chip (Fig. 7)."""

    n_tiles: int = 4
    tile: TileConfig = TileConfig()

    # --- timing model (microseconds) ------------------------------------
    # Crossbar current-propagation delay per firing (OxRAM read, [49]).
    t_fire: float = 4.0
    # AER encode/serialize per spike packet at the source NI.  Calibrated so
    # the model reproduces the paper's measured regime (Table 2: 5-23%
    # bandwidth utilization — compute/TDMA-bound, not comm-bound).
    t_spike_encode: float = 0.001
    # Mesh link time per spike packet per hop (~500 Mevents/s/link).
    t_spike_link: float = 0.002
    # Fixed per-message NoC latency (route setup), per channel per firing.
    t_route: float = 0.05

    @property
    def mesh_dim(self) -> int:
        return max(1, math.isqrt(self.n_tiles))

    def hops(self, src_tile: int, dst_tile: int) -> int:
        """Manhattan hop count on the 2D mesh NoC."""
        if src_tile == dst_tile:
            return 0
        d = self.mesh_dim
        sx, sy = src_tile % d, src_tile // d
        dx, dy = dst_tile % d, dst_tile // d
        return abs(sx - dx) + abs(sy - dy)

    def comm_delay(self, n_spikes: float, src_tile: int, dst_tile: int) -> float:
        """Time to move ``n_spikes`` AER packets from src to dst tile."""
        if src_tile == dst_tile:
            return 0.0
        hops = self.hops(src_tile, dst_tile)
        # Pipelined wormhole: serialization dominates, one extra link time/hop.
        return (
            self.t_route
            + n_spikes * (self.t_spike_encode + self.t_spike_link)
            + (hops - 1) * self.t_spike_link
        )

    def hops_array(self, src_tiles: np.ndarray, dst_tiles: np.ndarray) -> np.ndarray:
        """Vectorized Manhattan hop counts (same-tile pairs report 0)."""
        d = self.mesh_dim
        src_tiles = np.asarray(src_tiles, dtype=np.int64)
        dst_tiles = np.asarray(dst_tiles, dtype=np.int64)
        return np.abs(src_tiles % d - dst_tiles % d) + np.abs(
            src_tiles // d - dst_tiles // d
        )

    def comm_delay_array(
        self, n_spikes: np.ndarray, src_tiles: np.ndarray, dst_tiles: np.ndarray
    ) -> np.ndarray:
        """Vectorized :meth:`comm_delay` over parallel channel arrays."""
        src_tiles = np.asarray(src_tiles, dtype=np.int64)
        dst_tiles = np.asarray(dst_tiles, dtype=np.int64)
        hops = self.hops_array(src_tiles, dst_tiles)
        delay = (
            self.t_route
            + np.asarray(n_spikes) * (self.t_spike_encode + self.t_spike_link)
            + (hops - 1) * self.t_spike_link
        )
        return np.where(src_tiles == dst_tiles, 0.0, delay)


# The three hardware models evaluated in the paper (§6.1, Fig. 16).
DYNAP_SE = HardwareConfig(n_tiles=4)
DYNAP_SE_9 = HardwareConfig(n_tiles=9)
DYNAP_SE_16 = HardwareConfig(n_tiles=16)


def hardware_by_name(name: str) -> HardwareConfig:
    table = {"dynap-se": DYNAP_SE, "dynap-se-9": DYNAP_SE_9, "dynap-se-16": DYNAP_SE_16}
    try:
        return table[name.lower()]
    except KeyError:  # pragma: no cover - defensive
        raise ValueError(f"unknown hardware model {name!r}; have {sorted(table)}")

"""Batched self-timed execution engine (paper §4.4–§5, array-native).

Once static orders exist, the order-edge-augmented event graph fully
determines self-timed execution: its evolution is the max-plus recursion
``x(k) = A (x) x(k-1)`` (Eq. 4), so the steady-state period and per-actor
start times follow from *analysis* rather than discrete-event replay.  This
module evaluates MANY candidate configurations of one application at once —
bindings, free-tile subsets, static orders — exploiting that all candidates
share the application's topology (self-edges, data flow, buffer back-edges)
and differ only in NoC delays and TDMA order edges:

  * :func:`stack_hardware_aware` builds the whole candidate batch directly
    as an :class:`~.maxplus.EdgeStack` (B, E) — per-row §4.4 transformation
    without materializing B ``SDFG`` objects.
  * :func:`batch_execute` analyzes the stack in one shot: exact periods via
    the batched lambda-search (:func:`~.maxplus.mcr_batch`), and optionally
    steady-state start-time vectors by iterating the batched max-plus
    recursion through the Pallas ``maxplus_bmm``/``maxplus_bmv`` kernels
    (:func:`~.maxplus.maxplus_matrix_batch` / :func:`~.maxplus.evolve_batch`).

Static orders travel through this module array-natively as well: an
:class:`OrderBatch` carries B candidates' TDMA order cycles as (B, n)
edge arrays (built in one shot by :func:`project_order_batch` or
:func:`~.schedule.build_static_orders_batch`), keeping stacked shapes
candidate-count-invariant; :func:`batch_execute` additionally rounds the
stacked (B, n, E) shape up to pow2-ish buckets on the traced ("dense")
backend so repeated admissions and optimizer generations hit the XLA
compile cache (:func:`compile_cache_stats` exposes the counters).

The heapq :class:`~.schedule.SelfTimedExecutor` remains the operational
cross-validation oracle — see ``tests/test_engine.py`` and
``tests/test_frontend.py``.
"""

from __future__ import annotations

import contextlib
import dataclasses
import time
from typing import Optional, Sequence, Union

import numpy as np

from .hardware import ChipState, HardwareConfig
from .maxplus import (
    NEG_INF,
    EdgeStack,
    _on_accelerator as _engine_on_accelerator,
    evolve_batch,
    maxplus_matrix_batch,
    mcr_batch,
)
from .sdfg import SDFG, hardware_static_parts, order_edges


# ======================================================================
# batched §4.4 graph construction: one EdgeStack for B candidates
# ======================================================================
def _as_binding_matrix(bindings, n_actors: int) -> np.ndarray:
    b = np.asarray(bindings, dtype=np.int64)
    if b.ndim == 1:
        b = b[None, :]
    assert b.ndim == 2 and b.shape[1] == n_actors, b.shape
    return b


# ======================================================================
# array-native static orders: (B, n) TDMA order-edge batch
# ======================================================================
@dataclasses.dataclass(frozen=True)
class OrderBatch:
    """Batched §4.4 step-2 TDMA order cycles as (B, n_actors) edge arrays.

    Row ``b`` holds candidate b's order edges: every actor appears exactly
    once as a source (``src[b]`` is a permutation of the actors) and its
    edge points to the next actor in its tile's firing cycle, with one
    initial token on each cycle's wrap-around edge.  A single-actor tile
    degenerates to a one-token self-edge, whose cycle ratio (``tau``) is
    already implied by the actor's own self-edge — so the slot count is
    exactly ``n_actors`` for EVERY candidate, making stacked shapes
    invariant across bindings (the shape-bucket compile cache's best
    case).  Replaces ``list[list[int]]`` orders on every batched hot path;
    the list form remains supported for hand-built schedules.
    """

    src: np.ndarray        # (B, n_actors) int64; row = permutation of actors
    dst: np.ndarray        # (B, n_actors) int64 successor on the tile cycle
    tokens: np.ndarray     # (B, n_actors) int64; 1 on each wrap-around edge

    @property
    def n_graphs(self) -> int:
        """Number of candidate rows B."""
        return int(self.src.shape[0])

    @property
    def n_actors(self) -> int:
        """Actor count n shared by all rows."""
        return int(self.src.shape[1])

    def row(
        self, b: int, binding: np.ndarray, n_tiles: Optional[int] = None
    ) -> list[list[int]]:
        """Row ``b`` as per-tile order lists (compat with the list form).

        ``binding`` is the row's (n_actors,) tile assignment; tiles are
        returned in id order (``n_tiles`` of them — defaults to the highest
        bound tile + 1) with their actors in firing order.
        """
        binding = np.asarray(binding)
        if n_tiles is None:
            n_tiles = int(binding.max(initial=0)) + 1
        per_tile: list[list[int]] = [[] for _ in range(n_tiles)]
        for a in self.src[b]:
            per_tile[int(binding[a])].append(int(a))
        return per_tile


#: Orders accepted by the batched engine: per-candidate Python lists
#: (entries may be None) or one array-native :class:`OrderBatch`.
OrdersLike = Union[Sequence[Optional[Sequence[Sequence[int]]]], OrderBatch]


def project_order_batch(single_order: Sequence[int], bindings) -> OrderBatch:
    """Lemma-1 projection of ONE total order onto B bindings, batched.

    ``single_order`` is the design-time single-tile actor order (a
    permutation of ``range(n_actors)``; missing actors are appended in id
    order, exactly like :func:`repro.core.runtime.project_order`);
    ``bindings`` is (B, n_actors) int tile ids (a single (n,) binding is
    promoted).  Returns the :class:`OrderBatch` whose row ``b`` chains each
    tile's actors in ``single_order``'s relative order — the same per-tile
    sequences ``project_order`` + ``order_edges`` produce, built with three
    vectorized array ops instead of a per-candidate Python loop.
    """
    bindings = np.asarray(bindings, dtype=np.int64)
    if bindings.ndim == 1:
        bindings = bindings[None, :]
    n_b, n = bindings.shape
    order_arr = np.asarray(list(single_order), dtype=np.int64)
    pos = np.full(n, -1, dtype=np.int64)
    pos[order_arr] = np.arange(order_arr.size)
    missing = np.flatnonzero(pos < 0)
    pos[missing] = order_arr.size + np.arange(missing.size)

    idx = np.arange(n)
    key = bindings * n + pos[None, :]
    sortidx = np.argsort(key, axis=1)                 # actors by (tile, rank)
    sb = np.take_along_axis(bindings, sortidx, axis=1)
    is_start = np.ones((n_b, n), dtype=bool)
    is_start[:, 1:] = sb[:, 1:] != sb[:, :-1]
    is_last = np.ones((n_b, n), dtype=bool)
    is_last[:, :-1] = sb[:, 1:] != sb[:, :-1]
    run_start = np.maximum.accumulate(
        np.where(is_start, idx[None, :], 0), axis=1
    )
    nxt_pos = np.where(is_last, run_start, np.minimum(idx[None, :] + 1, n - 1))
    dst = np.take_along_axis(sortidx, nxt_pos, axis=1)
    return OrderBatch(
        src=sortidx, dst=dst, tokens=is_last.astype(np.int64)
    )


def _order_shortcuts_batch(
    ob: OrderBatch, tau: np.ndarray, bindings: np.ndarray
) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Batched max-plus path-doubling shortcuts over an :class:`OrderBatch`.

    Same contract as :func:`_order_shortcuts`, vectorized across rows: for
    span s = 2, 4, 8, … one composed edge per actor whose weight / tokens
    are the sums along the underlying span-s path of its tile's order
    cycle, so every cycle ratio — hence :func:`~.maxplus.mcr_batch` — is
    exactly preserved while relaxation crosses a length-k cycle in O(log k)
    rounds.  Returns ``(src, dst, tokens, weights)`` as (B, n * n_spans)
    arrays (possibly zero-width).  NOT valid as Eq.-4 dependencies.
    """
    n_b, n = ob.src.shape
    rows = np.arange(n_b)[:, None]
    empty = np.zeros((n_b, 0), dtype=np.int64)
    n_tiles = int(bindings.max(initial=0)) + 1
    occ = np.bincount(
        (rows * n_tiles + bindings).ravel(), minlength=n_b * n_tiles
    )
    max_len = int(occ.max(initial=0))
    if n < 4 or max_len < 4:
        return empty, empty, empty, np.zeros((n_b, 0))

    nxt = np.empty((n_b, n), dtype=np.int64)
    nxt[rows, ob.src] = ob.dst
    m = np.zeros((n_b, n), dtype=np.int64)
    m[rows, ob.src] = ob.tokens
    w = np.take_along_axis(
        np.broadcast_to(tau, (n_b, n)), nxt, axis=1
    ).astype(np.float64)
    base = np.broadcast_to(np.arange(n), (n_b, n))
    srcs, dsts, toks, ws = [], [], [], []
    span = 1
    while 2 * span < max_len:
        w = w + np.take_along_axis(w, nxt, axis=1)
        m = m + np.take_along_axis(m, nxt, axis=1)
        nxt = np.take_along_axis(nxt, nxt, axis=1)
        span *= 2
        srcs.append(base)
        dsts.append(nxt.copy())
        toks.append(m.copy())
        ws.append(w.copy())
    if not srcs:
        return empty, empty, empty, np.zeros((n_b, 0))
    return (
        np.concatenate(srcs, axis=1),
        np.concatenate(dsts, axis=1),
        np.concatenate(toks, axis=1),
        np.concatenate(ws, axis=1),
    )


def _order_shortcuts(
    n_actors: int, t, tau: np.ndarray, max_len: int
) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Max-plus path-doubling shortcuts along one row's TDMA order cycles.

    The order edges of a row form disjoint per-tile cycles (a functional
    graph on the ordered actors), which makes them the *diameter* of the
    hardware-aware graph: plain Bellman-Ford needs O(cycle length) rounds
    to move information around a tile.  This emits, for span ``s = 2, 4,
    8, … < max_len``, one composed edge per ordered actor with ``weight`` /
    ``tokens`` equal to the SUM along the underlying span-``s`` path.  Each
    shortcut is the max-plus composition of a real path, so every cycle
    through shortcuts corresponds to a closed walk of the original graph
    with identical weight and token sums — the maximum cycle ratio is
    *exactly* preserved while relaxation reaches across a length-k cycle
    in O(log k) rounds.

    Returns ``(src, dst, tokens, weights)`` arrays of the shortcut edges
    (possibly empty).  NOT valid as Eq.-4 dependencies: a multi-token
    shortcut is a *relaxed* multi-iteration dependency, so these edges
    must never feed :func:`~.maxplus.maxplus_matrix_batch`.
    """
    nodes = t.src
    k = nodes.size
    if k < 4 or max_len < 4:
        empty = np.array([], dtype=np.int64)
        return empty, empty, empty, np.array([], dtype=np.float64)
    inv = np.full(n_actors, -1, dtype=np.int64)
    inv[nodes] = np.arange(k)
    nx = inv[t.dst]                      # successor, as an index into nodes
    w = tau[t.dst].astype(np.float64)    # span-1 path weight
    m = t.tokens.astype(np.int64)        # span-1 token sum
    srcs, dsts, toks, ws = [], [], [], []
    span = 1
    while 2 * span < max_len:
        w = w + w[nx]
        m = m + m[nx]
        nx = nx[nx]
        span *= 2
        srcs.append(nodes)
        dsts.append(nodes[nx])
        toks.append(m.copy())
        ws.append(w.copy())
    if not srcs:
        empty = np.array([], dtype=np.int64)
        return empty, empty, empty, np.array([], dtype=np.float64)
    return (
        np.concatenate(srcs),
        np.concatenate(dsts),
        np.concatenate(toks),
        np.concatenate(ws),
    )


def order_cycle_lower_bounds(
    tau: np.ndarray,
    bindings: np.ndarray,
    orders_list: Optional[OrdersLike],
) -> Optional[np.ndarray]:
    """(B,) sound per-row lower bounds on the steady-state period.

    Every tile whose static order serializes >= 2 actors contributes a real
    cycle (the TDMA order cycle, one token on the wrap-around edge) whose
    ratio is the sum of its actors' execution times ``tau`` (time units of
    ``tau``, microseconds here).  The row bound is the max over tiles;
    rows without orders get ``-inf``.  Feeding this into
    :func:`~.maxplus.mcr_batch` (``lo0``) shrinks the bisection interval —
    in the paper's compute-bound regime (Table 2) it is usually within a
    few percent of the true period.  Returns None when no row has orders.
    An :class:`OrderBatch` (every actor ordered on its tile) is scored with
    two vectorized bincounts instead of the per-row Python walk.
    """
    if orders_list is None:
        return None
    if isinstance(orders_list, OrderBatch):
        n_b, n = bindings.shape
        n_tiles = int(bindings.max(initial=0)) + 1
        flat = (np.arange(n_b)[:, None] * n_tiles + bindings).ravel()
        sums = np.bincount(
            flat,
            weights=np.broadcast_to(tau, (n_b, n)).ravel(),
            minlength=n_b * n_tiles,
        ).reshape(n_b, n_tiles)
        counts = np.bincount(flat, minlength=n_b * n_tiles).reshape(
            n_b, n_tiles
        )
        return np.where(counts >= 2, sums, -np.inf).max(
            axis=1, initial=-np.inf
        )
    n_b = bindings.shape[0]
    lo0 = np.full(n_b, -np.inf)
    any_orders = False
    for row, orders in enumerate(orders_list):
        if orders is None:
            continue
        any_orders = True
        best = -np.inf
        binding = bindings[row]
        for tile, order in enumerate(orders):
            members = [a for a in order if binding[a] == tile]
            if len(members) > 1:
                best = max(best, float(tau[np.asarray(members)].sum()))
        lo0[row] = best
    return lo0 if any_orders else None


@dataclasses.dataclass(frozen=True)
class ChipMetrics:
    """Per-candidate chip-objective accumulators of one EdgeStack build.

    Computed from the SAME vectorized hop pass that produces the stack's
    NoC delays (no second traversal of the flow edges, no per-candidate
    Python): ``cut_traffic[b]`` is candidate b's inter-tile spikes per
    iteration (SpiNeMap's objective), ``spike_hops[b]`` the rate-weighted
    NoC hop count (the link-energy term), ``tiles_used[b]`` the number of
    occupied tiles (the idle-leakage term), ``total_spikes`` the
    binding-independent spikes delivered per iteration, and
    ``read_charge`` those spikes weighted by the destination actor's mean
    OxRAM row length (``SDFG.read_cost``): one delivered spike drives one
    crossbar row and reads every crosspoint on it, so the crossbar read
    energy scales with fan-out row length.  When the graph carries no
    ``read_cost`` the charge equals ``total_spikes`` (flat model).
    Feed into :meth:`~repro.core.hardware.HardwareConfig.chip_energy`
    together with the periods to get (B,) chip energies.
    """

    cut_traffic: np.ndarray   # (B,) inter-tile spikes per iteration
    spike_hops: np.ndarray    # (B,) rate-weighted NoC hops per iteration
    tiles_used: np.ndarray    # (B,) occupied tiles per candidate
    total_spikes: float       # spikes delivered per iteration (all rows)
    read_charge: float        # row-length-weighted crossbar reads (all rows)


def stack_hardware_aware(
    app: SDFG,
    bindings,
    hw: HardwareConfig,
    orders_list: Optional[OrdersLike] = None,
    *,
    relax_shortcuts: bool = False,
    with_metrics: bool = False,
    chip_state: Optional[ChipState] = None,
    rate_scale=None,
) -> Union[EdgeStack, tuple[EdgeStack, ChipMetrics]]:
    """Hardware-aware graphs of B candidate bindings as ONE EdgeStack.

    ``bindings`` is (B, n_actors) int (a single (n,) binding is promoted);
    ``orders_list`` optionally gives per-candidate static orders — either
    per-candidate Python lists (entries may be None for order-free
    candidates) or one array-native :class:`OrderBatch`, whose uniform
    ``n_actors`` order-edge slots skip the per-row Python path entirely
    AND keep the stacked shape invariant across candidate batches (the
    shape-bucket compile cache's best case).  Self-edges, flow edges and
    buffer back-edges share src/dst/tokens across rows — only flow delays
    (NoC hops of each candidate's binding) and the order-edge slots differ.
    Order-edge slots are padded to the batch maximum with ``-inf`` weight,
    the (max,+) neutral element, so padding never joins a longest path.

    ``relax_shortcuts=True`` additionally emits path-doubling shortcut
    edges along each row's order cycles (:func:`_order_shortcuts` /
    :func:`_order_shortcuts_batch`): the maximum cycle ratio — and
    therefore every period computed by :func:`~.maxplus.mcr_batch` — is
    exactly preserved, while Bellman-Ford relaxation converges in
    O(log cycle-length) instead of O(cycle-length) rounds.  Stacks built
    this way are for cycle-ratio analysis ONLY; do not pass them to
    :func:`~.maxplus.maxplus_matrix_batch`.

    Returns an :class:`~.maxplus.EdgeStack` with (B, E) arrays; weights
    carry ``tau[dst] + delay`` in the time unit of ``app.exec_time``
    (microseconds throughout this pipeline).  ``with_metrics=True``
    returns ``(stack, ChipMetrics)`` instead: the per-candidate chip
    accumulators (cut traffic, spike-hops, occupied tiles) fall out of
    the same vectorized hop pass that produced the NoC delays, so the
    energy objective costs no extra traversal.

    ``chip_state`` (a :class:`~repro.core.hardware.ChipState`) applies the
    chip's current degradation inside the SAME hop pass: throttled-route
    scale factors are gathered per (candidate, flow-edge) pair and
    multiply the NoC link time.  Dead tiles do NOT change the stack — they
    make whole candidate rows infeasible, which :func:`batch_execute`
    masks to ``inf`` periods.  ``rate_scale`` (scalar, or (n_flow_edges,)
    per-flow-edge factors — the per-app drift multipliers of a union
    graph) scales the observed spike rates used for both NoC delays and
    the chip-metric accumulators; the design-time buffer provisioning
    (back-edge tokens) and crossbar firing times ``tau`` stay at their
    design values.
    """
    bindings = _as_binding_matrix(bindings, app.n_actors)
    n_b = bindings.shape[0]
    assert bindings.min(initial=0) >= 0 and bindings.max(initial=0) < hw.n_tiles, (
        f"binding tile ids must lie in [0, {hw.n_tiles})"
    )
    order_batch: Optional[OrderBatch] = None
    if isinstance(orders_list, OrderBatch):
        order_batch = orders_list
        assert order_batch.src.shape == (n_b, app.n_actors), (
            order_batch.src.shape, (n_b, app.n_actors)
        )
        orders_list = None
    elif orders_list is not None:
        assert len(orders_list) == n_b, (len(orders_list), n_b)

    keep_self, flow, back = hardware_static_parts(app, hw)
    tau = app.exec_time

    # shared part: (E0,) arrays broadcast over rows.  Self/buffer edges keep
    # their app-level delay (flow delays are *replaced* by the NoC model,
    # exactly as in hardware_aware_sdfg).
    base_src = np.concatenate([keep_self.src, flow.src, back.src])
    base_dst = np.concatenate([keep_self.dst, flow.dst, back.dst])
    base_tok = np.concatenate([keep_self.tokens, flow.tokens, back.tokens])
    e0 = base_src.size
    ef = len(flow)

    # per-row NoC hops in one vectorized gather: delays — and, when asked,
    # the chip-objective accumulators — derive from this single pass
    flow_rate = flow.rate
    if rate_scale is not None:
        scale = np.asarray(rate_scale, dtype=np.float64)
        assert scale.ndim == 0 or scale.shape == (ef,), (
            f"rate_scale must be scalar or ({ef},), got {scale.shape}"
        )
        flow_rate = flow_rate * scale
    if ef:
        src_t = np.take(bindings, flow.src, axis=-1)
        dst_t = np.take(bindings, flow.dst, axis=-1)
        hops = hw.hops_array(src_t, dst_t)
        link_scale = (
            chip_state.route_scale_array(src_t, dst_t)
            if chip_state is not None
            else None
        )
        delays = hw.comm_delay_from_hops(flow_rate, hops, link_scale)
    else:
        hops = np.zeros((n_b, 0), dtype=np.int64)
        delays = np.zeros((n_b, 0))
    metrics: Optional[ChipMetrics] = None
    if with_metrics:
        occ = np.bincount(
            (np.arange(n_b)[:, None] * hw.n_tiles + bindings).ravel(),
            minlength=n_b * hw.n_tiles,
        ).reshape(n_b, hw.n_tiles)
        read_w = (
            app.read_cost[flow.dst] if app.read_cost is not None else 1.0
        )
        metrics = ChipMetrics(
            cut_traffic=(flow_rate * (hops > 0)).sum(axis=1),
            spike_hops=(flow_rate * hops).sum(axis=1),
            tiles_used=(occ > 0).sum(axis=1),
            total_spikes=float(np.asarray(flow_rate).sum()),
            read_charge=float((flow_rate * read_w).sum()),
        )
    base_w = (tau[base_dst] + np.concatenate(
        [keep_self.delay, np.zeros(ef), back.delay]
    ))[None, :].repeat(n_b, axis=0)
    base_w[:, keep_self.src.size : keep_self.src.size + ef] += delays

    if order_batch is not None:
        # array-native order part: (B, n [+ shortcut spans]) — no per-row
        # Python, and a candidate-count-invariant slot width.  Unlike the
        # list path (order_edges filters each order by binding), the batch
        # arrays are used as-is — so a stale OrderBatch reused after the
        # bindings changed would chain actors across tiles; reject it.
        rows_ix = np.arange(n_b)[:, None]
        assert np.array_equal(
            bindings[rows_ix, order_batch.src],
            bindings[rows_ix, order_batch.dst],
        ), "OrderBatch is inconsistent with bindings (edge crosses tiles); " \
           "rebuild it with project_order_batch for these bindings"
        o_src, o_dst = order_batch.src, order_batch.dst
        o_tok = order_batch.tokens
        o_w = tau[o_dst]
        if relax_shortcuts:
            s_src, s_dst, s_tok, s_w = _order_shortcuts_batch(
                order_batch, tau, bindings
            )
            if s_src.shape[1]:
                o_src = np.concatenate([o_src, s_src], axis=1)
                o_dst = np.concatenate([o_dst, s_dst], axis=1)
                o_tok = np.concatenate([o_tok, s_tok], axis=1)
                o_w = np.concatenate([o_w, s_w], axis=1)
        src = np.concatenate(
            [np.broadcast_to(base_src, (n_b, e0)), o_src], axis=1
        )
        dst = np.concatenate(
            [np.broadcast_to(base_dst, (n_b, e0)), o_dst], axis=1
        )
        tokens = np.concatenate(
            [np.broadcast_to(base_tok, (n_b, e0)), o_tok], axis=1
        )
        weights = np.concatenate([base_w, o_w], axis=1)
        stack = EdgeStack(
            n_actors=app.n_actors, src=src, dst=dst, tokens=tokens,
            weights=weights,
        )
        return (stack, metrics) if with_metrics else stack

    # per-row order edges (+ optional shortcuts), padded to the batch max
    order_rows: list[Optional[tuple]] = []
    if orders_list is not None:
        for row, orders in enumerate(orders_list):
            if orders is None:
                order_rows.append(None)
                continue
            t = order_edges(orders, bindings[row])
            o_src, o_dst = t.src, t.dst
            o_tok, o_w = t.tokens, tau[t.dst]
            if relax_shortcuts and len(t):
                max_len = int(np.bincount(bindings[row]).max(initial=0))
                s_src, s_dst, s_tok, s_w = _order_shortcuts(
                    app.n_actors, t, tau, max_len
                )
                if s_src.size:
                    o_src = np.concatenate([o_src, s_src])
                    o_dst = np.concatenate([o_dst, s_dst])
                    o_tok = np.concatenate([o_tok, s_tok])
                    o_w = np.concatenate([o_w, s_w])
            order_rows.append((o_src, o_dst, o_tok, o_w))
    eo = max((r[0].size for r in order_rows if r is not None), default=0)

    src = np.zeros((n_b, e0 + eo), dtype=np.int64)
    dst = np.zeros((n_b, e0 + eo), dtype=np.int64)
    tokens = np.ones((n_b, e0 + eo), dtype=np.int64)
    weights = np.full((n_b, e0 + eo), NEG_INF)
    src[:, :e0] = base_src
    dst[:, :e0] = base_dst
    tokens[:, :e0] = base_tok
    weights[:, :e0] = base_w
    for row, r in enumerate(order_rows):
        if r is None or not r[0].size:
            continue
        o_src, o_dst, o_tok, o_w = r
        k = o_src.size
        src[row, e0 : e0 + k] = o_src
        dst[row, e0 : e0 + k] = o_dst
        tokens[row, e0 : e0 + k] = o_tok
        weights[row, e0 : e0 + k] = o_w
    stack = EdgeStack(
        n_actors=app.n_actors, src=src, dst=dst, tokens=tokens, weights=weights
    )
    return (stack, metrics) if with_metrics else stack


# ======================================================================
# shape-bucket compile cache: stable stacked shapes across admissions
# ======================================================================
def _bucket_size(x: int) -> int:
    """Round up to the next pow2-ish bucket (1, 2, 3, 4, 6, 8, 12, 16, …).

    Half-steps between powers of two keep the bucket within 2x of the
    request (< 50% padding waste, vs the plain next-power-of-two's ~100%)
    while collapsing the long tail of one-off shapes onto a few buckets.
    """
    if x <= 1:
        return 1
    p = 1 << (x - 1).bit_length()          # next power of two >= x
    if x <= (3 * p) // 4:
        return (3 * p) // 4
    return p


@dataclasses.dataclass
class CompileCacheStats:
    """Shape-bucket reuse counters of the batched analysis layer.

    Every :func:`batch_execute` call records its (backend, B, n_actors,
    n_edges) stacked shape after bucket rounding; a shape seen before is a
    ``hit`` (the XLA/toolchain compile cache can reuse the traced program),
    a first sighting is a ``miss`` (a fresh trace/compile).  ``shapes``
    maps each shape key to its occurrence count.
    """

    hits: int = 0
    misses: int = 0
    shapes: dict = dataclasses.field(default_factory=dict)

    @property
    def hit_rate(self) -> float:
        """hits / (hits + misses); 0.0 before any recorded call."""
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def record(self, key: tuple) -> None:
        """Count one analysis call with stacked-shape signature ``key``."""
        if key in self.shapes:
            self.hits += 1
            self.shapes[key] += 1
        else:
            self.misses += 1
            self.shapes[key] = 1

    def as_dict(self) -> dict:
        """JSON-ready snapshot (consumed by ``benchmarks/compile_latency``)."""
        return {
            "hits": self.hits,
            "misses": self.misses,
            "hit_rate": self.hit_rate,
            "n_distinct_shapes": len(self.shapes),
        }


_CACHE_STATS = CompileCacheStats()
_CACHE_SINKS: list[CompileCacheStats] = []


def compile_cache_stats() -> CompileCacheStats:
    """The engine's live shape-bucket counters (see :class:`CompileCacheStats`)."""
    return _CACHE_STATS


@contextlib.contextmanager
def record_cache_stats(stats: CompileCacheStats):
    """Additionally record every batched-analysis shape into ``stats``.

    Context manager: while active, each :func:`batch_execute` call records
    its bucketed shape key into ``stats`` AS WELL AS the module-global
    counters — hit/miss is judged against ``stats``' own history, so the
    caller gets counters scoped to its lifetime (the
    :class:`~repro.core.runtime.AdmissionController` wraps every admission
    in one of these, keeping per-controller counters from leaking into
    each other).  Re-entrant; sinks nest.
    """
    _CACHE_SINKS.append(stats)
    try:
        yield stats
    finally:
        # remove by identity: CompileCacheStats is a value-equal dataclass,
        # so list.remove() could unregister a DIFFERENT sink with equal
        # counters (e.g. two fresh controllers nesting)
        for i in range(len(_CACHE_SINKS) - 1, -1, -1):
            if _CACHE_SINKS[i] is stats:
                del _CACHE_SINKS[i]
                break


def reset_compile_cache_stats() -> None:
    """Zero the engine's shape-bucket counters (benchmark harness hook)."""
    _CACHE_STATS.hits = 0
    _CACHE_STATS.misses = 0
    _CACHE_STATS.shapes.clear()


def pad_stack_to_buckets(
    stack: EdgeStack, lo0: Optional[np.ndarray] = None
) -> tuple[EdgeStack, Optional[np.ndarray]]:
    """Pad an EdgeStack's (B, E) arrays and actor count up to pow2-ish
    bucket sizes (:func:`_bucket_size`).

    Padded edge slots carry ``-inf`` weight (the (max,+) neutral element),
    padded rows are entirely ``-inf`` (analyzed as acyclic and sliced off
    by the caller), and padded actors are isolated — results over the
    original rows/actors are bit-for-bit unchanged.  ``lo0`` (per-row
    lower bounds) is padded with ``-inf`` rows alongside.  Bucketing the
    shapes means repeated admissions and optimizer generations re-enter
    the XLA compile cache instead of retracing ``maxplus_bmm`` /
    ``mcr_batch`` for every one-off (B, n, E) combination.
    """
    b, e, n = stack.n_graphs, stack.n_edges, stack.n_actors
    b2, e2, n2 = _bucket_size(b), _bucket_size(e), _bucket_size(n)
    if (b2, e2, n2) == (b, e, n):
        return stack, lo0
    src = np.zeros((b2, e2), dtype=np.int64)
    dst = np.zeros((b2, e2), dtype=np.int64)
    tokens = np.ones((b2, e2), dtype=np.int64)
    weights = np.full((b2, e2), NEG_INF)
    src[:b, :e] = stack.src
    dst[:b, :e] = stack.dst
    tokens[:b, :e] = stack.tokens
    weights[:b, :e] = stack.weights
    padded = EdgeStack(
        n_actors=n2, src=src, dst=dst, tokens=tokens, weights=weights
    )
    if lo0 is not None:
        lo0 = np.concatenate([lo0, np.full(b2 - b, -np.inf)])
    return padded, lo0


# ======================================================================
# batched execution: periods (+ optional steady-state start times)
# ======================================================================
@dataclasses.dataclass
class EngineReport:
    """Batched self-timed analysis of B candidate configurations.

    ``periods[b]`` is candidate b's steady-state iteration period (the MCR
    of its order-augmented event graph) in the model's time unit
    (microseconds, see :mod:`repro.core.hardware`); ``starts``, when
    requested, holds per-actor steady-state start-time offsets from the
    max-plus recursion (normalized so each row's earliest actor starts at
    0) — the static schedule the paper's Eq. 4 evolution converges to.
    ``energies``/``metrics``, when requested (``with_energy=True``), hold
    per-candidate chip energy (pJ per iteration,
    :meth:`~repro.core.hardware.HardwareConfig.chip_energy`; ``inf`` for
    dead rows) and the raw :class:`ChipMetrics` accumulators.
    ``build_time_s`` / ``analysis_time_s`` are wall-clock seconds of the
    EdgeStack build and the batched analysis.
    """

    periods: np.ndarray                 # (B,) microseconds of model time
    starts: Optional[np.ndarray]        # (B, n_actors) microseconds, or None
    build_time_s: float
    analysis_time_s: float
    energies: Optional[np.ndarray] = None   # (B,) pJ per iteration, or None
    metrics: Optional[ChipMetrics] = None

    @property
    def throughputs(self) -> np.ndarray:
        """(B,) iterations per microsecond (1/period); 0.0 for dead or
        acyclic rows (non-finite or non-positive period)."""
        ok = np.isfinite(self.periods) & (self.periods > 0)
        out = np.zeros_like(self.periods)
        out[ok] = 1.0 / self.periods[ok]
        return out

    @property
    def n_candidates(self) -> int:
        """Number of candidate configurations B in this batch."""
        return int(self.periods.size)


@dataclasses.dataclass
class PreparedExec:
    """One application's stacked analysis inputs, built but not yet solved.

    Produced by :func:`prepare_execution`; consumed either by
    :func:`batch_execute` (one solve per prepared stack) or by
    :func:`batch_execute_fused`, which concatenates the rows of MANY
    independent prepared stacks into one fused :class:`EdgeStack` so a
    whole tick's worth of scoring — several optimizer populations,
    several region components — pays device dispatch and compile-cache
    entry once.  ``rel_tol`` rides along so a fused solve can take the
    tightest tolerance over its members (tighter is sound for all rows,
    it only costs bisection rounds).
    """

    app: SDFG
    bindings: np.ndarray                 # (B, n_actors) int tile ids
    hw: HardwareConfig
    stack: EdgeStack
    metrics: Optional[ChipMetrics]
    lo0: Optional[np.ndarray]            # (B,) per-row lower bounds
    n_rows: int
    n_act: int
    rel_tol: float
    with_energy: bool
    chip_state: Optional[ChipState]
    build_time_s: float


def prepare_execution(
    app: SDFG,
    bindings,
    hw: HardwareConfig,
    orders_list: Optional[OrdersLike] = None,
    *,
    rel_tol: float = 1e-8,
    with_energy: bool = False,
    chip_state: Optional[ChipState] = None,
    rate_scale=None,
    relax_shortcuts: bool = True,
) -> PreparedExec:
    """Build one candidate batch's :class:`EdgeStack` and row bounds.

    The build half of :func:`batch_execute`, factored out so independent
    batches (different apps, different region components) can be fused
    into a single analysis call (:func:`batch_execute_fused`).
    """
    bindings = _as_binding_matrix(bindings, app.n_actors)
    t0 = time.perf_counter()
    built = stack_hardware_aware(
        app, bindings, hw, orders_list, relax_shortcuts=relax_shortcuts,
        with_metrics=with_energy, chip_state=chip_state,
        rate_scale=rate_scale,
    )
    stack, metrics = built if with_energy else (built, None)
    lo0 = order_cycle_lower_bounds(app.exec_time, bindings, orders_list)
    return PreparedExec(
        app=app,
        bindings=bindings,
        hw=hw,
        stack=stack,
        metrics=metrics,
        lo0=lo0,
        n_rows=stack.n_graphs,
        n_act=stack.n_actors,
        rel_tol=rel_tol,
        with_energy=with_energy,
        chip_state=chip_state,
        build_time_s=time.perf_counter() - t0,
    )


def finish_execution(
    prep: PreparedExec,
    periods: np.ndarray,
    *,
    analysis_time_s: float,
    starts: Optional[np.ndarray] = None,
) -> EngineReport:
    """Turn one prepared batch's solved periods into an :class:`EngineReport`.

    Slices padded rows off, masks dead-tile rows to ``inf`` under the
    prepared :class:`~repro.core.hardware.ChipState`, and computes chip
    energies from the metrics that rode the stack build.
    """
    periods = periods[:prep.n_rows]
    chip_state = prep.chip_state
    if chip_state is not None and chip_state.dead.any():
        periods = np.where(
            chip_state.dead_rows(prep.bindings), np.inf, periods
        )
    energies = None
    if prep.with_energy:
        m = prep.metrics
        energies = prep.hw.chip_energy(
            periods,
            m.cut_traffic,
            m.spike_hops,
            m.tiles_used,
            m.read_charge,
        )
    return EngineReport(
        periods=periods,
        starts=starts,
        build_time_s=prep.build_time_s,
        analysis_time_s=analysis_time_s,
        energies=energies,
        metrics=prep.metrics,
    )


def _resolve_backend(backend: str, n_devices: int = 0) -> str:
    """Resolve ``"auto"``: exact device backend on any accelerator
    (TPU *or* GPU — see :func:`~repro.core.maxplus._on_accelerator`),
    host numpy otherwise.  A multi-device scoring mesh also forces the
    device backend — sharding is a ``"csr-jit"`` capability."""
    if backend == "auto":
        on_dev = _engine_on_accelerator() or n_devices > 1
        return "csr-jit" if on_dev else "edges"
    return backend


def _solve_devices(mesh) -> list:
    """Flat device list for the scoring mesh (explicit arg wins, else the
    ambient :func:`repro.launch.sharding.current_mesh`); ``[]`` when no
    mesh is active.  Lazy import keeps ``repro.core`` importable without
    touching jax device state through the launch layer."""
    from repro.launch.sharding import current_mesh, mesh_devices

    return mesh_devices(mesh if mesh is not None else current_mesh())


def fuse_stacks(
    stacks: Sequence[EdgeStack],
) -> tuple[EdgeStack, list[slice]]:
    """Concatenate independent EdgeStacks into ONE row-stacked batch.

    Pads every stack to the common (n_actors, n_edges) envelope — padded
    edge slots carry ``-inf`` weight (the (max,+) neutral element) so
    they are invisible to every backend, and extra actors are isolated —
    then stacks rows.  The per-row lambda-search is row-local, so the
    fused result restricted to each member's row slice is bit-for-bit
    the result of analyzing that member alone (at equal tolerance).
    Returns the fused stack and each member's row slice.
    """
    assert stacks, "need at least one stack to fuse"
    if len(stacks) == 1:
        return stacks[0], [slice(0, stacks[0].n_graphs)]
    n_max = max(s.n_actors for s in stacks)
    e_max = max(s.n_edges for s in stacks)
    srcs, dsts, toks, ws = [], [], [], []
    slices: list[slice] = []
    row = 0
    for s in stacks:
        b, e = s.n_graphs, s.n_edges
        pad = e_max - e
        if pad:
            srcs.append(np.pad(s.src, ((0, 0), (0, pad))))
            dsts.append(np.pad(s.dst, ((0, 0), (0, pad))))
            toks.append(np.pad(s.tokens, ((0, 0), (0, pad)),
                               constant_values=1))
            ws.append(np.pad(s.weights, ((0, 0), (0, pad)),
                             constant_values=NEG_INF))
        else:
            srcs.append(s.src)
            dsts.append(s.dst)
            toks.append(s.tokens)
            ws.append(s.weights)
        slices.append(slice(row, row + b))
        row += b
    fused = EdgeStack(
        n_actors=n_max,
        src=np.concatenate(srcs),
        dst=np.concatenate(dsts),
        tokens=np.concatenate(toks),
        weights=np.concatenate(ws),
    )
    return fused, slices


def batch_execute_fused(
    preps: Sequence[PreparedExec],
    *,
    backend: str = "auto",
    pad_shapes: Optional[bool] = None,
    mesh=None,
) -> list[EngineReport]:
    """Solve MANY independent prepared batches in ONE analysis call.

    The cross-region fused scoring path: rows from every prepared stack
    (one optimizer generation per region component, elite re-scores,
    pending admissions) are concatenated (:func:`fuse_stacks`) and run
    through a single :func:`~repro.core.maxplus.mcr_batch`, so per-call
    dispatch, trace/compile-cache entry, and (on device) kernel-launch
    overheads are paid once per tick instead of once per region.  The
    fused solve uses the TIGHTEST member tolerance (sound for all rows).
    Per-member results are bit-for-bit the standalone results at that
    tolerance (the lambda-search is row-local).  ``with_starts`` is
    deliberately unsupported — scoring paths never need start vectors.

    ``mesh`` (or an ambient :func:`repro.launch.sharding.use_mesh`)
    shards the fused batch axis across the mesh devices — contiguous row
    chunks, one concurrent ``"csr-jit"`` solve per device, merged
    host-side.  Results are bit-identical to the single-device solve at
    the same (tightest-member) tolerance, so device count never changes
    which candidate wins.
    """
    assert preps, "need at least one prepared execution to fuse"
    t1 = time.perf_counter()
    devices = _solve_devices(mesh)
    backend = _resolve_backend(backend, len(devices))
    if backend != "csr-jit":
        devices = []
    if pad_shapes is None:
        pad_shapes = backend in ("dense", "csr-jit")
    fused, slices = fuse_stacks([p.stack for p in preps])
    if any(p.lo0 is not None for p in preps):
        lo0 = np.concatenate([
            p.lo0 if p.lo0 is not None
            else np.full(p.n_rows, -np.inf)
            for p in preps
        ])
    else:
        lo0 = None
    rel_tol = min(p.rel_tol for p in preps)
    if pad_shapes:
        fused, lo0 = pad_stack_to_buckets(fused, lo0)
    key = (backend, fused.n_graphs, fused.n_actors, fused.n_edges)
    _CACHE_STATS.record(key)
    for sink in _CACHE_SINKS:
        sink.record(key)
    periods = mcr_batch(
        fused, backend=backend, rel_tol=rel_tol, lo0=lo0,
        devices=devices or None,
    )
    analysis_s = (time.perf_counter() - t1) / len(preps)
    return [
        finish_execution(p, periods[s], analysis_time_s=analysis_s)
        for p, s in zip(preps, slices)
    ]


def batch_execute(
    app: SDFG,
    bindings,
    hw: HardwareConfig,
    orders_list: Optional[OrdersLike] = None,
    *,
    backend: str = "auto",
    rel_tol: float = 1e-8,
    with_starts: bool = False,
    with_energy: bool = False,
    power_iters: int = 64,
    pad_shapes: Optional[bool] = None,
    chip_state: Optional[ChipState] = None,
    rate_scale=None,
    mesh=None,
) -> EngineReport:
    """Self-timed steady state of every candidate, in one batched pass.

    ``bindings`` is (B, n_actors) int tile ids (a single (n,) binding is
    promoted to B=1); the result's ``periods`` is (B,) in the time unit of
    ``app.exec_time`` (microseconds here) and ``starts`` — when requested —
    is (B, n_actors) steady-state start offsets in the same unit.
    ``orders_list`` is per-candidate order lists or one
    :class:`OrderBatch` (the array-native fast path).

    Replaces the per-candidate heapq simulation loop: periods come from the
    batched lambda-search over the stacked edge arrays (order-cycle
    shortcuts + per-row order-cycle lower bounds keep the search fast on
    large graphs; both are exact), and start-time vectors (optional — they
    cost a dense (B, n, n) matrix build) from iterating ``x(k) = A (x)
    x(k-1)`` through the batched semiring kernels.  ``rel_tol`` is the
    period's relative tolerance: 1e-8 for exact comparisons, looser (1e-4)
    when only ranking candidates matters.

    ``pad_shapes`` rounds the stacked (B, n_actors, n_edges) shape up to
    pow2-ish buckets (:func:`pad_stack_to_buckets`) so repeated calls hit
    the XLA compile cache instead of retracing per shape; ``None`` (the
    default) enables it exactly when the resolved backend is ``"dense"``
    (the traced/compiled path — the float64 ``"edges"`` backend gains
    nothing from padding and would only pay for the extra slots).  Every
    call is recorded in :func:`compile_cache_stats` either way.

    ``with_energy=True`` additionally returns per-candidate chip energy
    (``energies``, pJ per iteration) and the raw :class:`ChipMetrics`:
    the accumulators ride the stack build's own hop pass, so the energy
    objective adds no second traversal and no per-candidate Python.

    ``chip_state``/``rate_scale`` apply run-time degradation (see
    :func:`stack_hardware_aware`): throttled routes and drifted spike
    rates rescale the stacked delays, and any candidate row binding a
    dead tile reports an ``inf`` period (hence zero throughput and ``inf``
    energy) — degraded candidates rank in the same batched pass as
    healthy ones.

    ``mesh`` (or an ambient :func:`repro.launch.sharding.use_mesh`)
    shards the candidate batch axis across the mesh devices exactly as
    in :func:`batch_execute_fused` — bit-identical, merged host-side.
    """
    # shortcut edges preserve every cycle ratio but are NOT Eq.-4
    # dependencies, so the starts path must build the plain stack
    prep = prepare_execution(
        app, bindings, hw, orders_list, rel_tol=rel_tol,
        with_energy=with_energy, chip_state=chip_state,
        rate_scale=rate_scale, relax_shortcuts=not with_starts,
    )

    t1 = time.perf_counter()
    devices = _solve_devices(mesh)
    backend = _resolve_backend(backend, len(devices))
    if backend != "csr-jit":
        devices = []
    if pad_shapes is None:
        pad_shapes = backend in ("dense", "csr-jit")
    stack, lo0 = prep.stack, prep.lo0
    if pad_shapes:
        stack, lo0 = pad_stack_to_buckets(stack, lo0)
    key = (backend, stack.n_graphs, stack.n_actors, stack.n_edges)
    _CACHE_STATS.record(key)
    for sink in _CACHE_SINKS:
        sink.record(key)
    periods = mcr_batch(
        stack, backend=backend, rel_tol=rel_tol, lo0=lo0,
        devices=devices or None,
    )
    starts = None
    if with_starts:
        t_mat = maxplus_matrix_batch(stack)
        x, _ = evolve_batch(t_mat, iters=power_iters)
        finite = np.isfinite(x)
        lo = np.where(finite, x, np.inf).min(axis=1, keepdims=True)
        starts = np.where(finite, x - lo, np.inf)[:prep.n_rows, :prep.n_act]
    return finish_execution(
        prep, periods,
        analysis_time_s=time.perf_counter() - t1,
        starts=starts,
    )


def batch_throughputs(
    app: SDFG,
    bindings,
    hw: HardwareConfig,
    orders_list: Optional[OrdersLike] = None,
    *,
    backend: str = "auto",
    rel_tol: float = 1e-8,
) -> np.ndarray:
    """Throughput (1/period) per candidate; zero for dead/acyclic rows."""
    return batch_execute(
        app, bindings, hw, orders_list, backend=backend, rel_tol=rel_tol
    ).throughputs


# ======================================================================
# per-component cycle ratios: each app's TRUE steady-state rate
# ======================================================================
def weak_components(n_actors: int, src, dst) -> np.ndarray:
    """Weakly connected component labels of an edge list.

    In a hardware-aware event graph every edge lies on a cycle (data
    channels pair with buffer back-edges, order edges form tile cycles,
    self-edges are 1-cycles), so weak components ARE the strongly
    connected components — and the graph's maximum cycle ratio is the max
    over its components.  Union-find with path halving; returns (n_actors,)
    int64 labels compacted to ``0..n_components-1`` (isolated actors get
    their own label).
    """
    parent = np.arange(n_actors, dtype=np.int64)

    def find(x: int) -> int:
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = int(parent[x])
        return x

    for a, b in zip(
        np.asarray(src, dtype=np.int64).tolist(),
        np.asarray(dst, dtype=np.int64).tolist(),
    ):
        ra, rb = find(a), find(b)
        if ra != rb:
            parent[rb] = ra
    roots = np.fromiter(
        (find(i) for i in range(n_actors)), dtype=np.int64, count=n_actors
    )
    return np.unique(roots, return_inverse=True)[1]


def union_component_periods(
    app: SDFG,
    binding,
    hw: HardwareConfig,
    orders_list: Optional[OrdersLike] = None,
    *,
    backend: str = "auto",
    rel_tol: float = 1e-8,
    with_metrics: bool = False,
    chip_state: Optional[ChipState] = None,
    rate_scale=None,
):
    """Per-component steady-state periods of ONE bound configuration.

    The union period reported by :func:`batch_execute` is the max cycle
    ratio over the whole chip — conservative for any resident app that
    does not sit on the chip's critical cycle.  This splits the bound
    graph into its (weak = strong, see :func:`weak_components`) components
    and computes every component's exact cycle ratio with ONE masked
    :func:`~.maxplus.mcr_batch` call of batch size ``n_components``: row k
    keeps only component k's edge weights, every other edge is ``-inf``
    (the (max,+) neutral element), so row k's MCR is exactly component k's.

    Returns ``(labels, periods)``: ``labels`` is (n_actors,) component ids,
    ``periods`` (n_components,) each component's period.  An app's true
    steady-state rate is ``1 / max(periods of components it touches)``.
    With ``with_metrics=True`` returns ``(labels, periods, metrics)`` where
    ``metrics`` is the :class:`ChipMetrics` of the same (single-row) build,
    so callers caching per-component records pay for one stack build only.

    ``chip_state``/``rate_scale`` score the configuration under run-time
    degradation (see :func:`stack_hardware_aware`); a component whose
    actors bind any dead tile reports an ``inf`` period.
    """
    binding = _as_binding_matrix(binding, app.n_actors)
    assert binding.shape[0] == 1, "one configuration at a time"
    metrics = None
    if with_metrics:
        stack, metrics = stack_hardware_aware(
            app, binding, hw, orders_list, relax_shortcuts=True,
            with_metrics=True, chip_state=chip_state, rate_scale=rate_scale,
        )
    else:
        stack = stack_hardware_aware(
            app, binding, hw, orders_list, relax_shortcuts=True,
            chip_state=chip_state, rate_scale=rate_scale,
        )
    src, dst = stack.src[0], stack.dst[0]
    tokens, w = stack.tokens[0], stack.weights[0]
    live = np.isfinite(w)
    labels = weak_components(app.n_actors, src[live], dst[live])
    n_comp = int(labels.max(initial=-1)) + 1
    backend = _resolve_backend(backend)
    # row k masks every edge outside component k; shortcut edges never
    # cross components (they compose real order-cycle paths)
    mask = labels[src][None, :] == np.arange(max(n_comp, 1))[:, None]
    comp_stack = EdgeStack(
        n_actors=app.n_actors,
        src=np.repeat(src[None, :], max(n_comp, 1), axis=0),
        dst=np.repeat(dst[None, :], max(n_comp, 1), axis=0),
        tokens=np.repeat(tokens[None, :], max(n_comp, 1), axis=0),
        weights=np.where(mask, w[None, :], NEG_INF),
    )
    periods = mcr_batch(comp_stack, backend=backend, rel_tol=rel_tol)
    if chip_state is not None and chip_state.dead.any():
        dead_actors = chip_state.dead[binding[0]]
        if dead_actors.any():
            periods = periods.copy()
            periods[np.unique(labels[dead_actors])] = np.inf
    if with_metrics:
        return labels, periods, metrics
    return labels, periods

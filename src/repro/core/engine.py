"""Batched self-timed execution engine (paper §4.4–§5, array-native).

Once static orders exist, the order-edge-augmented event graph fully
determines self-timed execution: its evolution is the max-plus recursion
``x(k) = A (x) x(k-1)`` (Eq. 4), so the steady-state period and per-actor
start times follow from *analysis* rather than discrete-event replay.  This
module evaluates MANY candidate configurations of one application at once —
bindings, free-tile subsets, static orders — exploiting that all candidates
share the application's topology (self-edges, data flow, buffer back-edges)
and differ only in NoC delays and TDMA order edges:

  * :func:`stack_hardware_aware` builds the whole candidate batch directly
    as an :class:`~.maxplus.EdgeStack` (B, E) — per-row §4.4 transformation
    without materializing B ``SDFG`` objects.
  * :func:`batch_execute` analyzes the stack in one shot: exact periods via
    the batched lambda-search (:func:`~.maxplus.mcr_batch`), and optionally
    steady-state start-time vectors by iterating the batched max-plus
    recursion through the Pallas ``maxplus_bmm``/``maxplus_bmv`` kernels
    (:func:`~.maxplus.maxplus_matrix_batch` / :func:`~.maxplus.evolve_batch`).

The heapq :class:`~.schedule.SelfTimedExecutor` remains the FCFS
static-order *constructor* (§4.4 step 2) and the operational
cross-validation oracle — see ``tests/test_engine.py``.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Optional, Sequence

import numpy as np

from .hardware import HardwareConfig
from .maxplus import (
    NEG_INF,
    EdgeStack,
    evolve_batch,
    maxplus_matrix_batch,
    mcr_batch,
)
from .sdfg import SDFG, flow_delays, hardware_static_parts, order_edges


# ======================================================================
# batched §4.4 graph construction: one EdgeStack for B candidates
# ======================================================================
def _as_binding_matrix(bindings, n_actors: int) -> np.ndarray:
    b = np.asarray(bindings, dtype=np.int64)
    if b.ndim == 1:
        b = b[None, :]
    assert b.ndim == 2 and b.shape[1] == n_actors, b.shape
    return b


def stack_hardware_aware(
    app: SDFG,
    bindings,
    hw: HardwareConfig,
    orders_list: Optional[Sequence[Optional[Sequence[Sequence[int]]]]] = None,
) -> EdgeStack:
    """Hardware-aware graphs of B candidate bindings as ONE EdgeStack.

    ``bindings`` is (B, n_actors) (a single (n,) binding is promoted);
    ``orders_list`` optionally gives per-candidate static orders (entries
    may be None for order-free candidates).  Self-edges, flow edges and
    buffer back-edges share src/dst/tokens across rows — only flow delays
    (NoC hops of each candidate's binding) and the order-edge slots differ.
    Order-edge slots are padded to the batch maximum with ``-inf`` weight,
    the (max,+) neutral element, so padding never joins a longest path.
    """
    bindings = _as_binding_matrix(bindings, app.n_actors)
    n_b = bindings.shape[0]
    assert bindings.min(initial=0) >= 0 and bindings.max(initial=0) < hw.n_tiles, (
        f"binding tile ids must lie in [0, {hw.n_tiles})"
    )
    if orders_list is not None:
        assert len(orders_list) == n_b, (len(orders_list), n_b)

    keep_self, flow, back = hardware_static_parts(app, hw)
    tau = app.exec_time

    # shared part: (E0,) arrays broadcast over rows.  Self/buffer edges keep
    # their app-level delay (flow delays are *replaced* by the NoC model,
    # exactly as in hardware_aware_sdfg).
    base_src = np.concatenate([keep_self.src, flow.src, back.src])
    base_dst = np.concatenate([keep_self.dst, flow.dst, back.dst])
    base_tok = np.concatenate([keep_self.tokens, flow.tokens, back.tokens])
    e0 = base_src.size
    ef = len(flow)

    # per-row flow delays in one vectorized call: (B, Ef)
    delays = flow_delays(flow, bindings, hw) if ef else np.zeros((n_b, 0))
    base_w = (tau[base_dst] + np.concatenate(
        [keep_self.delay, np.zeros(ef), back.delay]
    ))[None, :].repeat(n_b, axis=0)
    base_w[:, keep_self.src.size : keep_self.src.size + ef] += delays

    # per-row order edges (variable count), padded to the batch maximum
    order_tables = []
    if orders_list is not None:
        for row, orders in enumerate(orders_list):
            order_tables.append(
                order_edges(orders, bindings[row]) if orders is not None
                else None
            )
    eo = max((len(t) for t in order_tables if t is not None), default=0)

    src = np.zeros((n_b, e0 + eo), dtype=np.int64)
    dst = np.zeros((n_b, e0 + eo), dtype=np.int64)
    tokens = np.ones((n_b, e0 + eo), dtype=np.int64)
    weights = np.full((n_b, e0 + eo), NEG_INF)
    src[:, :e0] = base_src
    dst[:, :e0] = base_dst
    tokens[:, :e0] = base_tok
    weights[:, :e0] = base_w
    for row, t in enumerate(order_tables):
        if t is None or not len(t):
            continue
        k = len(t)
        src[row, e0 : e0 + k] = t.src
        dst[row, e0 : e0 + k] = t.dst
        tokens[row, e0 : e0 + k] = t.tokens
        weights[row, e0 : e0 + k] = tau[t.dst]
    return EdgeStack(
        n_actors=app.n_actors, src=src, dst=dst, tokens=tokens, weights=weights
    )


# ======================================================================
# batched execution: periods (+ optional steady-state start times)
# ======================================================================
@dataclasses.dataclass
class EngineReport:
    """Batched self-timed analysis of B candidate configurations.

    ``periods[b]`` is candidate b's steady-state iteration period (the MCR
    of its order-augmented event graph); ``starts``, when requested, holds
    per-actor steady-state start-time offsets from the max-plus recursion
    (normalized so each row's earliest actor starts at 0) — the static
    schedule the paper's Eq. 4 evolution converges to.
    """

    periods: np.ndarray                 # (B,)
    starts: Optional[np.ndarray]        # (B, n_actors) or None
    build_time_s: float
    analysis_time_s: float

    @property
    def throughputs(self) -> np.ndarray:
        ok = np.isfinite(self.periods) & (self.periods > 0)
        out = np.zeros_like(self.periods)
        out[ok] = 1.0 / self.periods[ok]
        return out

    @property
    def n_candidates(self) -> int:
        return int(self.periods.size)


def batch_execute(
    app: SDFG,
    bindings,
    hw: HardwareConfig,
    orders_list: Optional[Sequence[Optional[Sequence[Sequence[int]]]]] = None,
    *,
    backend: str = "auto",
    rel_tol: float = 1e-8,
    with_starts: bool = False,
    power_iters: int = 64,
) -> EngineReport:
    """Self-timed steady state of every candidate, in one batched pass.

    Replaces the per-candidate heapq simulation loop: periods come from the
    batched lambda-search over the stacked edge arrays; start-time vectors
    (optional — they cost a dense (B, n, n) matrix build) from iterating
    ``x(k) = A (x) x(k-1)`` through the batched semiring kernels.
    """
    t0 = time.perf_counter()
    stack = stack_hardware_aware(app, bindings, hw, orders_list)
    t_build = time.perf_counter() - t0

    t1 = time.perf_counter()
    periods = mcr_batch(stack, backend=backend, rel_tol=rel_tol)
    starts = None
    if with_starts:
        t_mat = maxplus_matrix_batch(stack)
        x, _ = evolve_batch(t_mat, iters=power_iters)
        finite = np.isfinite(x)
        lo = np.where(finite, x, np.inf).min(axis=1, keepdims=True)
        starts = np.where(finite, x - lo, np.inf)
    return EngineReport(
        periods=periods,
        starts=starts,
        build_time_s=t_build,
        analysis_time_s=time.perf_counter() - t1,
    )


def batch_throughputs(
    app: SDFG,
    bindings,
    hw: HardwareConfig,
    orders_list=None,
    *,
    backend: str = "auto",
    rel_tol: float = 1e-8,
) -> np.ndarray:
    """Throughput (1/period) per candidate; zero for dead/acyclic rows."""
    return batch_execute(
        app, bindings, hw, orders_list, backend=backend, rel_tol=rel_tol
    ).throughputs

"""Batched self-timed execution engine (paper §4.4–§5, array-native).

Once static orders exist, the order-edge-augmented event graph fully
determines self-timed execution: its evolution is the max-plus recursion
``x(k) = A (x) x(k-1)`` (Eq. 4), so the steady-state period and per-actor
start times follow from *analysis* rather than discrete-event replay.  This
module evaluates MANY candidate configurations of one application at once —
bindings, free-tile subsets, static orders — exploiting that all candidates
share the application's topology (self-edges, data flow, buffer back-edges)
and differ only in NoC delays and TDMA order edges:

  * :func:`stack_hardware_aware` builds the whole candidate batch directly
    as an :class:`~.maxplus.EdgeStack` (B, E) — per-row §4.4 transformation
    without materializing B ``SDFG`` objects.
  * :func:`batch_execute` analyzes the stack in one shot: exact periods via
    the batched lambda-search (:func:`~.maxplus.mcr_batch`), and optionally
    steady-state start-time vectors by iterating the batched max-plus
    recursion through the Pallas ``maxplus_bmm``/``maxplus_bmv`` kernels
    (:func:`~.maxplus.maxplus_matrix_batch` / :func:`~.maxplus.evolve_batch`).

The heapq :class:`~.schedule.SelfTimedExecutor` remains the FCFS
static-order *constructor* (§4.4 step 2) and the operational
cross-validation oracle — see ``tests/test_engine.py``.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Optional, Sequence

import numpy as np

from .hardware import HardwareConfig
from .maxplus import (
    NEG_INF,
    EdgeStack,
    evolve_batch,
    maxplus_matrix_batch,
    mcr_batch,
)
from .sdfg import SDFG, flow_delays, hardware_static_parts, order_edges


# ======================================================================
# batched §4.4 graph construction: one EdgeStack for B candidates
# ======================================================================
def _as_binding_matrix(bindings, n_actors: int) -> np.ndarray:
    b = np.asarray(bindings, dtype=np.int64)
    if b.ndim == 1:
        b = b[None, :]
    assert b.ndim == 2 and b.shape[1] == n_actors, b.shape
    return b


def _order_shortcuts(
    n_actors: int, t, tau: np.ndarray, max_len: int
) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Max-plus path-doubling shortcuts along one row's TDMA order cycles.

    The order edges of a row form disjoint per-tile cycles (a functional
    graph on the ordered actors), which makes them the *diameter* of the
    hardware-aware graph: plain Bellman-Ford needs O(cycle length) rounds
    to move information around a tile.  This emits, for span ``s = 2, 4,
    8, … < max_len``, one composed edge per ordered actor with ``weight`` /
    ``tokens`` equal to the SUM along the underlying span-``s`` path.  Each
    shortcut is the max-plus composition of a real path, so every cycle
    through shortcuts corresponds to a closed walk of the original graph
    with identical weight and token sums — the maximum cycle ratio is
    *exactly* preserved while relaxation reaches across a length-k cycle
    in O(log k) rounds.

    Returns ``(src, dst, tokens, weights)`` arrays of the shortcut edges
    (possibly empty).  NOT valid as Eq.-4 dependencies: a multi-token
    shortcut is a *relaxed* multi-iteration dependency, so these edges
    must never feed :func:`~.maxplus.maxplus_matrix_batch`.
    """
    nodes = t.src
    k = nodes.size
    if k < 4 or max_len < 4:
        empty = np.array([], dtype=np.int64)
        return empty, empty, empty, np.array([], dtype=np.float64)
    inv = np.full(n_actors, -1, dtype=np.int64)
    inv[nodes] = np.arange(k)
    nx = inv[t.dst]                      # successor, as an index into nodes
    w = tau[t.dst].astype(np.float64)    # span-1 path weight
    m = t.tokens.astype(np.int64)        # span-1 token sum
    srcs, dsts, toks, ws = [], [], [], []
    span = 1
    while 2 * span < max_len:
        w = w + w[nx]
        m = m + m[nx]
        nx = nx[nx]
        span *= 2
        srcs.append(nodes)
        dsts.append(nodes[nx])
        toks.append(m.copy())
        ws.append(w.copy())
    if not srcs:
        empty = np.array([], dtype=np.int64)
        return empty, empty, empty, np.array([], dtype=np.float64)
    return (
        np.concatenate(srcs),
        np.concatenate(dsts),
        np.concatenate(toks),
        np.concatenate(ws),
    )


def order_cycle_lower_bounds(
    tau: np.ndarray,
    bindings: np.ndarray,
    orders_list: Optional[Sequence[Optional[Sequence[Sequence[int]]]]],
) -> Optional[np.ndarray]:
    """(B,) sound per-row lower bounds on the steady-state period.

    Every tile whose static order serializes >= 2 actors contributes a real
    cycle (the TDMA order cycle, one token on the wrap-around edge) whose
    ratio is the sum of its actors' execution times ``tau`` (time units of
    ``tau``, microseconds here).  The row bound is the max over tiles;
    rows without orders get ``-inf``.  Feeding this into
    :func:`~.maxplus.mcr_batch` (``lo0``) shrinks the bisection interval —
    in the paper's compute-bound regime (Table 2) it is usually within a
    few percent of the true period.  Returns None when no row has orders.
    """
    if orders_list is None:
        return None
    n_b = bindings.shape[0]
    lo0 = np.full(n_b, -np.inf)
    any_orders = False
    for row, orders in enumerate(orders_list):
        if orders is None:
            continue
        any_orders = True
        best = -np.inf
        binding = bindings[row]
        for tile, order in enumerate(orders):
            members = [a for a in order if binding[a] == tile]
            if len(members) > 1:
                best = max(best, float(tau[np.asarray(members)].sum()))
        lo0[row] = best
    return lo0 if any_orders else None


def stack_hardware_aware(
    app: SDFG,
    bindings,
    hw: HardwareConfig,
    orders_list: Optional[Sequence[Optional[Sequence[Sequence[int]]]]] = None,
    *,
    relax_shortcuts: bool = False,
) -> EdgeStack:
    """Hardware-aware graphs of B candidate bindings as ONE EdgeStack.

    ``bindings`` is (B, n_actors) int (a single (n,) binding is promoted);
    ``orders_list`` optionally gives per-candidate static orders (entries
    may be None for order-free candidates).  Self-edges, flow edges and
    buffer back-edges share src/dst/tokens across rows — only flow delays
    (NoC hops of each candidate's binding) and the order-edge slots differ.
    Order-edge slots are padded to the batch maximum with ``-inf`` weight,
    the (max,+) neutral element, so padding never joins a longest path.

    ``relax_shortcuts=True`` additionally emits path-doubling shortcut
    edges along each row's order cycles (:func:`_order_shortcuts`): the
    maximum cycle ratio — and therefore every period computed by
    :func:`~.maxplus.mcr_batch` — is exactly preserved, while Bellman-Ford
    relaxation converges in O(log cycle-length) instead of O(cycle-length)
    rounds.  Stacks built this way are for cycle-ratio analysis ONLY; do
    not pass them to :func:`~.maxplus.maxplus_matrix_batch`.

    Returns an :class:`~.maxplus.EdgeStack` with (B, E) arrays; weights
    carry ``tau[dst] + delay`` in the time unit of ``app.exec_time``
    (microseconds throughout this pipeline).
    """
    bindings = _as_binding_matrix(bindings, app.n_actors)
    n_b = bindings.shape[0]
    assert bindings.min(initial=0) >= 0 and bindings.max(initial=0) < hw.n_tiles, (
        f"binding tile ids must lie in [0, {hw.n_tiles})"
    )
    if orders_list is not None:
        assert len(orders_list) == n_b, (len(orders_list), n_b)

    keep_self, flow, back = hardware_static_parts(app, hw)
    tau = app.exec_time

    # shared part: (E0,) arrays broadcast over rows.  Self/buffer edges keep
    # their app-level delay (flow delays are *replaced* by the NoC model,
    # exactly as in hardware_aware_sdfg).
    base_src = np.concatenate([keep_self.src, flow.src, back.src])
    base_dst = np.concatenate([keep_self.dst, flow.dst, back.dst])
    base_tok = np.concatenate([keep_self.tokens, flow.tokens, back.tokens])
    e0 = base_src.size
    ef = len(flow)

    # per-row flow delays in one vectorized call: (B, Ef)
    delays = flow_delays(flow, bindings, hw) if ef else np.zeros((n_b, 0))
    base_w = (tau[base_dst] + np.concatenate(
        [keep_self.delay, np.zeros(ef), back.delay]
    ))[None, :].repeat(n_b, axis=0)
    base_w[:, keep_self.src.size : keep_self.src.size + ef] += delays

    # per-row order edges (+ optional shortcuts), padded to the batch max
    order_rows: list[Optional[tuple]] = []
    if orders_list is not None:
        for row, orders in enumerate(orders_list):
            if orders is None:
                order_rows.append(None)
                continue
            t = order_edges(orders, bindings[row])
            o_src, o_dst = t.src, t.dst
            o_tok, o_w = t.tokens, tau[t.dst]
            if relax_shortcuts and len(t):
                max_len = int(np.bincount(bindings[row]).max(initial=0))
                s_src, s_dst, s_tok, s_w = _order_shortcuts(
                    app.n_actors, t, tau, max_len
                )
                if s_src.size:
                    o_src = np.concatenate([o_src, s_src])
                    o_dst = np.concatenate([o_dst, s_dst])
                    o_tok = np.concatenate([o_tok, s_tok])
                    o_w = np.concatenate([o_w, s_w])
            order_rows.append((o_src, o_dst, o_tok, o_w))
    eo = max((r[0].size for r in order_rows if r is not None), default=0)

    src = np.zeros((n_b, e0 + eo), dtype=np.int64)
    dst = np.zeros((n_b, e0 + eo), dtype=np.int64)
    tokens = np.ones((n_b, e0 + eo), dtype=np.int64)
    weights = np.full((n_b, e0 + eo), NEG_INF)
    src[:, :e0] = base_src
    dst[:, :e0] = base_dst
    tokens[:, :e0] = base_tok
    weights[:, :e0] = base_w
    for row, r in enumerate(order_rows):
        if r is None or not r[0].size:
            continue
        o_src, o_dst, o_tok, o_w = r
        k = o_src.size
        src[row, e0 : e0 + k] = o_src
        dst[row, e0 : e0 + k] = o_dst
        tokens[row, e0 : e0 + k] = o_tok
        weights[row, e0 : e0 + k] = o_w
    return EdgeStack(
        n_actors=app.n_actors, src=src, dst=dst, tokens=tokens, weights=weights
    )


# ======================================================================
# batched execution: periods (+ optional steady-state start times)
# ======================================================================
@dataclasses.dataclass
class EngineReport:
    """Batched self-timed analysis of B candidate configurations.

    ``periods[b]`` is candidate b's steady-state iteration period (the MCR
    of its order-augmented event graph) in the model's time unit
    (microseconds, see :mod:`repro.core.hardware`); ``starts``, when
    requested, holds per-actor steady-state start-time offsets from the
    max-plus recursion (normalized so each row's earliest actor starts at
    0) — the static schedule the paper's Eq. 4 evolution converges to.
    ``build_time_s`` / ``analysis_time_s`` are wall-clock seconds of the
    EdgeStack build and the batched analysis.
    """

    periods: np.ndarray                 # (B,) microseconds of model time
    starts: Optional[np.ndarray]        # (B, n_actors) microseconds, or None
    build_time_s: float
    analysis_time_s: float

    @property
    def throughputs(self) -> np.ndarray:
        """(B,) iterations per microsecond (1/period); 0.0 for dead or
        acyclic rows (non-finite or non-positive period)."""
        ok = np.isfinite(self.periods) & (self.periods > 0)
        out = np.zeros_like(self.periods)
        out[ok] = 1.0 / self.periods[ok]
        return out

    @property
    def n_candidates(self) -> int:
        """Number of candidate configurations B in this batch."""
        return int(self.periods.size)


def batch_execute(
    app: SDFG,
    bindings,
    hw: HardwareConfig,
    orders_list: Optional[Sequence[Optional[Sequence[Sequence[int]]]]] = None,
    *,
    backend: str = "auto",
    rel_tol: float = 1e-8,
    with_starts: bool = False,
    power_iters: int = 64,
) -> EngineReport:
    """Self-timed steady state of every candidate, in one batched pass.

    ``bindings`` is (B, n_actors) int tile ids (a single (n,) binding is
    promoted to B=1); the result's ``periods`` is (B,) in the time unit of
    ``app.exec_time`` (microseconds here) and ``starts`` — when requested —
    is (B, n_actors) steady-state start offsets in the same unit.

    Replaces the per-candidate heapq simulation loop: periods come from the
    batched lambda-search over the stacked edge arrays (order-cycle
    shortcuts + per-row order-cycle lower bounds keep the search fast on
    large graphs; both are exact), and start-time vectors (optional — they
    cost a dense (B, n, n) matrix build) from iterating ``x(k) = A (x)
    x(k-1)`` through the batched semiring kernels.  ``rel_tol`` is the
    period's relative tolerance: 1e-8 for exact comparisons, looser (1e-4)
    when only ranking candidates matters.
    """
    bindings = _as_binding_matrix(bindings, app.n_actors)
    t0 = time.perf_counter()
    # shortcut edges preserve every cycle ratio but are NOT Eq.-4
    # dependencies, so the starts path must build the plain stack
    stack = stack_hardware_aware(
        app, bindings, hw, orders_list, relax_shortcuts=not with_starts
    )
    t_build = time.perf_counter() - t0

    t1 = time.perf_counter()
    lo0 = order_cycle_lower_bounds(app.exec_time, bindings, orders_list)
    periods = mcr_batch(stack, backend=backend, rel_tol=rel_tol, lo0=lo0)
    starts = None
    if with_starts:
        t_mat = maxplus_matrix_batch(stack)
        x, _ = evolve_batch(t_mat, iters=power_iters)
        finite = np.isfinite(x)
        lo = np.where(finite, x, np.inf).min(axis=1, keepdims=True)
        starts = np.where(finite, x - lo, np.inf)
    return EngineReport(
        periods=periods,
        starts=starts,
        build_time_s=t_build,
        analysis_time_s=time.perf_counter() - t1,
    )


def batch_throughputs(
    app: SDFG,
    bindings,
    hw: HardwareConfig,
    orders_list=None,
    *,
    backend: str = "auto",
    rel_tol: float = 1e-8,
) -> np.ndarray:
    """Throughput (1/period) per candidate; zero for dead/acyclic rows."""
    return batch_execute(
        app, bindings, hw, orders_list, backend=backend, rel_tol=rel_tol
    ).throughputs

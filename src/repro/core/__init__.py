"""Core paper contribution: SNN compilation to neuromorphic hardware.

Pipeline (paper Fig. 2):
  SNN (apps.py / snn.py)
    -> spike recording (lif.py; or calibrated counts)
    -> crossbar-aware clustering (partition.py, Alg. 1)
    -> SDFG (sdfg.py) + Max-Plus analysis (maxplus.py, Eq. 6)
    -> binding (binding.py, Eq. 7) + static-order scheduling (schedule.py)
    -> run-time admission via self-timed execution (runtime.py, Lemma 1)
"""

from .apps import APP_NAMES, APP_SPECS, all_apps, build_app, small_app
from .engine import (
    ChipMetrics,
    CompileCacheStats,
    EngineReport,
    OrderBatch,
    PreparedExec,
    batch_execute,
    batch_execute_fused,
    batch_throughputs,
    compile_cache_stats,
    finish_execution,
    fuse_stacks,
    order_cycle_lower_bounds,
    pad_stack_to_buckets,
    prepare_execution,
    project_order_batch,
    record_cache_stats,
    reset_compile_cache_stats,
    stack_hardware_aware,
    union_component_periods,
    weak_components,
)
from .explore import (
    BINDERS,
    SubsetScores,
    SweepPoint,
    SweepReport,
    analyze_candidates,
    build_candidates,
    candidate_subsets,
    score_free_tile_subsets,
    sweep,
)
from .binding import (
    BindingResult,
    LoadWeights,
    bind_ours,
    bind_pycarl,
    bind_spinemap,
    cut_spikes,
    cut_spikes_batch,
)
from .hardware import (
    DYNAP_SE,
    DYNAP_SE_9,
    DYNAP_SE_16,
    DYNAP_SE_1024,
    ChipState,
    CrossbarConfig,
    HardwareConfig,
    TileConfig,
    hardware_by_name,
)
from .lif import LIFParams, simulate_spikes, with_simulated_spikes
from .maxplus import (
    EdgeStack,
    evolve_batch,
    maxplus_matrix,
    maxplus_matrix_batch,
    mcm_power_iteration,
    mcr_batch,
    mcr_binary_search,
    mcr_howard,
    stack_graphs,
    throughput,
    throughput_batch,
)
from .optimize import (
    GenerationStat,
    OptimizeReport,
    ParetoPoint,
    bind_optimized,
    optimize_binding,
    optimize_binding_graph,
    optimize_binding_graphs_fused,
)
from .partition import (
    Cluster,
    ClusteredSNN,
    partition_greedy,
    partition_greedy_reference,
)
from .runtime import (
    AdmissionController,
    AdmissionError,
    AdmissionEvent,
    CompileReport,
    DesignArtifact,
    HardwareState,
    design_time_compile,
    project_order,
    runtime_admit,
    single_tile_order,
    verify_deadlock_free,
)
from .serving import PrecompilePool, ServiceTicket, ServingQueue
from .schedule import (
    ExecutionTrace,
    SelfTimedExecutor,
    analyze_throughput,
    build_static_orders,
    build_static_orders_batch,
    measured_throughput,
    random_orders,
)
from .sdfg import (
    SDFG,
    Channel,
    ChannelTable,
    as_channel_table,
    disjoint_union,
    hardware_aware_sdfg,
    order_edges,
    sdfg_from_clusters,
)
from .snn import SNN, calibrate_spikes, feedforward
from .workloads import (
    TABLE1_FIT,
    FaultEvent,
    WorkloadSpec,
    failure_storm,
    sample_workload,
    workload_suite,
)

__all__ = [k for k in dir() if not k.startswith("_")]

"""Generators for the eight Table-1 evaluation applications.

Each generator reproduces the paper's published per-application totals
EXACTLY — synapse count, neuron count and recorded spike count (Table 1) —
because those are the quantities the compiler consumes (bin capacities,
channel rates).  The 'Topology' column of Table 1 is internally inconsistent
with the neuron totals it sits next to (e.g. MLP-MNIST lists FF(784,100,10)
= 894 neurons beside a count of 984), so we treat the topology column as the
*shape* (number of layers + relative widths) and scale layer widths to the
exact published neuron total; see DESIGN.md §8.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import numpy as np

from .snn import SNN, calibrate_spikes, feedforward


@dataclasses.dataclass(frozen=True)
class AppSpec:
    name: str
    synapses: int
    neurons: int
    spikes: int                 # Table-1 total over the recorded run
    layer_shape: Sequence[int]  # nominal relative widths (Table-1 topology)
    recurrent: bool = False
    seed: int = 0
    # Table-1 'Spikes' counts a whole recorded test run; image apps are
    # "iteratively executed on test images" (§6.2), so per-iteration channel
    # rates = total / recorded iterations.  100 test inputs per recording.
    recorded_iters: int = 100


# Nominal layer widths follow the Table-1 topology strings; LeNet widths are
# the classic LeCun-5 feature-map sizes, HeartClass follows footnote 1.
APP_SPECS: dict[str, AppSpec] = {
    "ImgSmooth": AppSpec("ImgSmooth", 136_314, 980, 17_600, (4096, 1024), seed=101),
    "EdgeDet": AppSpec(
        "EdgeDet", 272_628, 1_372, 22_780, (4096, 1024, 1024, 1024), seed=102
    ),
    "MLP-MNIST": AppSpec("MLP-MNIST", 79_400, 984, 2_395_300, (784, 100, 10), seed=103),
    "HeartEstm": AppSpec(
        "HeartEstm", 636_578, 6_952, 3_002_223, (1000, 5000, 952), recurrent=True, seed=104
    ),
    # CNN widths: the Table-1 topology strings fix the structure but not the
    # feature-map widths; widths below are chosen so the totals equal the
    # published neuron counts exactly.
    "HeartClass": AppSpec(
        "HeartClass",
        2_396_521,
        24_732,
        1_036_485,
        (6724, 13456, 4290, 256, 6),  # Input(82x82), [C,P]*16, [C,P]*16, FC, FC
        seed=105,
    ),
    "CNN-MNIST": AppSpec(
        "CNN-MNIST", 159_553, 5_576, 97_585, (576, 4840, 150, 10), seed=106
    ),
    "LeNet-MNIST": AppSpec(
        "LeNet-MNIST",
        1_029_286,
        4_634,
        165_997,
        (1024, 2688, 708, 120, 84, 10),
        seed=107,
    ),
    "LeNet-CIFAR": AppSpec(
        "LeNet-CIFAR",
        2_136_560,
        18_472,
        589_953,
        (3072, 12288, 3018, 84, 10),
        seed=108,
    ),
}

APP_NAMES: tuple[str, ...] = tuple(APP_SPECS)


def _scale_layers(shape: Sequence[int], total: int) -> list[int]:
    """Scale nominal widths to an exact neuron total (largest remainder)."""
    shape = np.asarray(shape, dtype=np.float64)
    raw = shape * (total / shape.sum())
    floor = np.floor(raw).astype(np.int64)
    floor = np.maximum(floor, 1)
    rem = total - int(floor.sum())
    if rem > 0:
        order = np.argsort(raw - floor)[::-1]
        for i in order[:rem]:
            floor[i] += 1
    elif rem < 0:
        order = np.argsort(raw - floor)
        k = 0
        while rem < 0:
            i = order[k % len(order)]
            if floor[i] > 1:
                floor[i] -= 1
                rem += 1
            k += 1
    assert int(floor.sum()) == total
    return [int(x) for x in floor]


def build_app(name: str, *, exact_neurons: bool = False) -> SNN:
    """Build one of the eight evaluation applications by name.

    Synapse and spike totals match Table 1 exactly.  Layer widths follow the
    published topology; because Table 1's neuron column is inconsistent with
    its own topology column (see module docstring), the generated neuron
    count equals the topology sum by default.  ``exact_neurons=True`` scales
    widths to hit the published neuron total instead (used by the fidelity
    report, which shows both).
    """
    try:
        spec = APP_SPECS[name]
    except KeyError:
        raise ValueError(f"unknown application {name!r}; have {list(APP_SPECS)}")
    layers = (
        _scale_layers(spec.layer_shape, spec.neurons)
        if exact_neurons
        else list(spec.layer_shape)
    )
    snn = feedforward(
        layers,
        spec.synapses,
        seed=spec.seed,
        name=spec.name,
        recurrent=spec.recurrent,
    )
    snn = calibrate_spikes(
        snn, float(spec.spikes) / spec.recorded_iters, seed=spec.seed + 7
    )
    assert snn.n_synapses == spec.synapses, (snn.n_synapses, spec.synapses)
    return snn


def all_apps() -> dict[str, SNN]:
    return {name: build_app(name) for name in APP_SPECS}


def small_app(
    n_neurons: int = 60,
    n_synapses: int = 400,
    *,
    seed: int = 0,
    recurrent: bool = False,
    builder: Callable[..., SNN] = feedforward,
) -> SNN:
    """A tiny SNN for unit tests (3 layers, deterministic)."""
    per = max(2, n_neurons // 3)
    layers = [per, per, n_neurons - 2 * per]
    snn = builder(layers, n_synapses, seed=seed, name="tiny", recurrent=recurrent)
    return calibrate_spikes(snn, 50.0 * n_neurons, seed=seed + 1)

"""Parameterized synthetic SNN workloads (chip-scale stress tenants).

The eight Table-1 applications (:mod:`repro.core.apps`) are single data
points; stressing a 1024-tile chip needs *hundreds* of tenants with the
same statistical character.  This module fits the Table-1 population —
layer topologies, synapses-per-neuron (fan-in) and per-iteration
spikes-per-neuron (firing rate) — and samples arbitrarily many tenants
from those distributions:

  * the layer SHAPE is drawn from the Table-1 topology templates (relative
    widths jittered multiplicatively, then scaled to the drawn neuron
    total), so the conv-style window sharing that Alg.-1 bin-packing
    exploits is preserved;
  * synapses-per-neuron and spikes-per-neuron are drawn log-normally with
    the log-mean/log-std of the Table-1 apps (both quantities span more
    than an order of magnitude across the eight apps, so a normal fit
    would be badly wrong);
  * the recurrence probability equals the Table-1 frequency (1/8).

``scale`` shrinks the neuron-count range without touching the per-neuron
distributions: a ``scale=0.1`` tenant is a statistically faithful
miniature, sized so hundreds fit a mesh at a few tiles each.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Union

import numpy as np

from .apps import APP_SPECS
from .snn import SNN, calibrate_spikes, feedforward

__all__ = [
    "WorkloadSpec",
    "TABLE1_FIT",
    "sample_workload",
    "workload_suite",
]


@dataclasses.dataclass(frozen=True)
class WorkloadSpec:
    """Distribution parameters of a synthetic tenant population.

    ``neurons_range`` is sampled log-uniformly (the Table-1 neuron counts
    span 980..24732); ``syn_per_neuron`` / ``spikes_per_neuron`` are
    (log-mean, log-std) of log-normal draws; ``templates`` holds the
    relative layer-width shapes the topology is drawn from;
    ``width_jitter`` is the multiplicative layer-width noise (log-uniform
    in [1/j, j]); ``recurrent_p`` the probability of a feedback edge.
    """

    neurons_range: tuple[int, int]
    syn_per_neuron: tuple[float, float]      # (mu, sigma) of log
    spikes_per_neuron: tuple[float, float]   # (mu, sigma) of log
    templates: tuple[tuple[int, ...], ...]
    width_jitter: float = 1.3
    recurrent_p: float = 0.125
    min_syn_per_neuron: float = 4.0
    max_syn_per_neuron: float = 512.0


def _fit_table1() -> WorkloadSpec:
    """Log-space moment fit of the Table-1 application population."""
    specs = list(APP_SPECS.values())
    spn = np.array([s.synapses / s.neurons for s in specs])
    rate = np.array(
        [s.spikes / s.recorded_iters / s.neurons for s in specs]
    )
    return WorkloadSpec(
        neurons_range=(
            min(s.neurons for s in specs), max(s.neurons for s in specs)
        ),
        syn_per_neuron=(
            float(np.mean(np.log(spn))), float(np.std(np.log(spn)))
        ),
        spikes_per_neuron=(
            float(np.mean(np.log(rate))), float(np.std(np.log(rate)))
        ),
        templates=tuple(tuple(s.layer_shape) for s in specs),
        recurrent_p=sum(s.recurrent for s in specs) / len(specs),
    )


#: The Table-1 population fit (computed once at import; APP_SPECS is
#: frozen, so this is deterministic).
TABLE1_FIT: WorkloadSpec = _fit_table1()


def _sample_layers(
    rng: np.random.Generator, spec: WorkloadSpec, n_neurons: int
) -> list[int]:
    """Draw a layer topology: jittered template scaled to ``n_neurons``."""
    shape = np.asarray(
        spec.templates[int(rng.integers(len(spec.templates)))],
        dtype=np.float64,
    )
    jitter = np.exp(
        rng.uniform(
            -np.log(spec.width_jitter), np.log(spec.width_jitter),
            size=shape.size,
        )
    )
    shape = shape * jitter
    raw = shape * (n_neurons / shape.sum())
    widths = np.maximum(np.floor(raw).astype(np.int64), 2)
    # largest-remainder top-up to the exact neuron total
    rem = n_neurons - int(widths.sum())
    if rem > 0:
        order = np.argsort(raw - widths)[::-1]
        widths[order[np.arange(rem) % widths.size]] += 1
    elif rem < 0:
        order = np.argsort(raw - widths)
        k = 0
        while rem < 0:
            i = order[k % widths.size]
            if widths[i] > 2:
                widths[i] -= 1
                rem += 1
            k += 1
    return [int(w) for w in widths]


def sample_workload(
    seed_or_rng: Union[int, np.random.Generator],
    *,
    spec: WorkloadSpec = TABLE1_FIT,
    scale: float = 1.0,
    name: Optional[str] = None,
) -> SNN:
    """Sample ONE synthetic tenant from the fitted population.

    ``scale`` multiplies the neuron-count range (per-neuron fan-in and
    firing-rate distributions are scale-free); ``name`` defaults to a
    draw-derived identifier.  Deterministic given the seed / generator
    state.
    """
    rng = (
        seed_or_rng
        if isinstance(seed_or_rng, np.random.Generator)
        else np.random.default_rng(seed_or_rng)
    )
    lo, hi = spec.neurons_range
    lo = max(8, int(round(lo * scale)))
    hi = max(lo + 1, int(round(hi * scale)))
    n_neurons = int(np.exp(rng.uniform(np.log(lo), np.log(hi))))
    n_neurons = int(np.clip(n_neurons, lo, hi))
    layers = _sample_layers(rng, spec, n_neurons)
    mu, sg = spec.syn_per_neuron
    spn = float(
        np.clip(
            np.exp(rng.normal(mu, sg)),
            spec.min_syn_per_neuron, spec.max_syn_per_neuron,
        )
    )
    # cap at the topology's connectivity capacity (the generator clamps
    # internally too, but an explicit cap keeps the EXACT-total invariant)
    cap = sum(a * b for a, b in zip(layers[:-1], layers[1:]))
    n_syn = int(np.clip(round(spn * n_neurons), n_neurons, max(cap, n_neurons)))
    recurrent = bool(rng.random() < spec.recurrent_p)
    gen_seed = int(rng.integers(2**31 - 1))
    snn = feedforward(
        layers, n_syn,
        seed=gen_seed,
        name=name or f"wl-{gen_seed:08x}",
        recurrent=recurrent,
    )
    mu_r, sg_r = spec.spikes_per_neuron
    rate = float(np.exp(rng.normal(mu_r, sg_r)))
    return calibrate_spikes(
        snn, max(1.0, rate * n_neurons), seed=gen_seed + 7
    )


def workload_suite(
    n: int,
    *,
    seed: int = 0,
    spec: WorkloadSpec = TABLE1_FIT,
    scale: float = 1.0,
    name_prefix: str = "tenant",
) -> list[SNN]:
    """Sample ``n`` distinct tenants from one generator stream.

    Names are ``{name_prefix}{i}`` — stable identifiers for admission
    controllers and trajectory logs.
    """
    rng = np.random.default_rng(seed)
    return [
        sample_workload(rng, spec=spec, scale=scale, name=f"{name_prefix}{i}")
        for i in range(n)
    ]


# -- failure storms ------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One scheduled chip mutation of a failure storm.

    ``t`` is the (dimensionless) arrival time used only for ordering and
    inter-arrival statistics; ``kind`` selects the controller call:
    ``"fail"`` carries ``tiles``, ``"throttle"`` carries a ``link``
    (adjacent tile pair) and a slow-down ``factor``, ``"drift"`` carries
    an ``app`` name and a rate ``factor``; ``"heal"`` carries either the
    ``tiles`` or the ``link`` being restored.
    """

    t: float
    kind: str                                   # fail | heal | throttle | drift
    tiles: tuple[int, ...] = ()
    link: Optional[tuple[int, int]] = None
    app: Optional[str] = None
    factor: float = 1.0


def failure_storm(
    n_faults: int,
    n_tiles: int,
    *,
    seed: int = 0,
    rate: float = 1.0,
    tiles_per_fault: int = 1,
    heal_after: Optional[float] = None,
    p_throttle: float = 0.0,
    p_drift: float = 0.0,
    drift_apps: Sequence[str] = (),
    drift_range: tuple[float, float] = (0.5, 3.0),
    throttle_range: tuple[float, float] = (2.0, 8.0),
    max_dead_frac: float = 0.25,
    mesh_side: Optional[int] = None,
) -> list[FaultEvent]:
    """Poisson failure storm: a deterministic, time-sorted event list.

    Arrivals are exponential with ``rate`` events per unit time.  Each
    arrival is a tile failure (``tiles_per_fault`` distinct uniform picks
    over the tiles still alive in the generator's own bookkeeping), a
    link throttle with probability ``p_throttle`` (a uniformly-picked
    mesh-adjacent pair, factor log-uniform over ``throttle_range``), or a
    spike-rate drift with probability ``p_drift`` (an app uniform over
    ``drift_apps``, factor log-uniform over ``drift_range``).  With
    ``heal_after`` every failed tile set is revived — and every throttled
    link restored — that much later, so degradation stays transient and
    the dead fraction stays bounded; independent of healing, no failure
    is emitted that would push the dead fraction above ``max_dead_frac``
    (the arrival is skipped, keeping the storm well-posed on small
    meshes; a storm whose remaining arrivals are ALL skippable ends
    early rather than spinning).  Same ``seed`` -> identical storm,
    always.
    """
    assert 0.0 <= p_throttle + p_drift <= 1.0
    side = mesh_side if mesh_side is not None else int(round(n_tiles ** 0.5))
    assert side * side == n_tiles, "failure_storm assumes a square mesh"
    rng = np.random.default_rng(seed)
    events: list[FaultEvent] = []
    dead: set[int] = set()
    t = 0.0
    made = 0
    stalled = 0
    while made < n_faults:
        if stalled > 100 + 10 * n_faults:
            break   # every remaining arrival is skippable (cap saturated)
        t += float(rng.exponential(1.0 / rate))
        u = float(rng.random())
        if u < p_throttle:
            a = int(rng.integers(n_tiles))
            x, y = a % side, a // side
            opts = []
            if x + 1 < side:
                opts.append(a + 1)
            if y + 1 < side:
                opts.append(a + side)
            if not opts:
                stalled += 1
                continue
            b = int(opts[int(rng.integers(len(opts)))])
            lo, hi = np.log(throttle_range[0]), np.log(throttle_range[1])
            f = float(np.exp(rng.uniform(lo, hi)))
            events.append(FaultEvent(t=t, kind="throttle", link=(a, b), factor=f))
            if heal_after is not None:
                events.append(
                    FaultEvent(t=t + float(heal_after), kind="heal", link=(a, b))
                )
        elif u < p_throttle + p_drift and drift_apps:
            app = str(drift_apps[int(rng.integers(len(drift_apps)))])
            lo, hi = np.log(drift_range[0]), np.log(drift_range[1])
            f = float(np.exp(rng.uniform(lo, hi)))
            events.append(FaultEvent(t=t, kind="drift", app=app, factor=f))
        else:
            alive = sorted(set(range(n_tiles)) - dead)
            k = min(tiles_per_fault, len(alive))
            if k == 0 or (len(dead) + k) / n_tiles > max_dead_frac:
                stalled += 1
                continue
            picks = tuple(
                int(alive[i])
                for i in sorted(rng.choice(len(alive), size=k, replace=False))
            )
            dead.update(picks)
            events.append(FaultEvent(t=t, kind="fail", tiles=picks))
            if heal_after is not None:
                events.append(
                    FaultEvent(t=t + float(heal_after), kind="heal", tiles=picks)
                )
        made += 1
        stalled = 0
    events.sort(key=lambda e: (e.t, e.kind))
    return events

"""Parameterized synthetic SNN workloads (chip-scale stress tenants).

The eight Table-1 applications (:mod:`repro.core.apps`) are single data
points; stressing a 1024-tile chip needs *hundreds* of tenants with the
same statistical character.  This module fits the Table-1 population —
layer topologies, synapses-per-neuron (fan-in) and per-iteration
spikes-per-neuron (firing rate) — and samples arbitrarily many tenants
from those distributions:

  * the layer SHAPE is drawn from the Table-1 topology templates (relative
    widths jittered multiplicatively, then scaled to the drawn neuron
    total), so the conv-style window sharing that Alg.-1 bin-packing
    exploits is preserved;
  * synapses-per-neuron and spikes-per-neuron are drawn log-normally with
    the log-mean/log-std of the Table-1 apps (both quantities span more
    than an order of magnitude across the eight apps, so a normal fit
    would be badly wrong);
  * the recurrence probability equals the Table-1 frequency (1/8).

``scale`` shrinks the neuron-count range without touching the per-neuron
distributions: a ``scale=0.1`` tenant is a statistically faithful
miniature, sized so hundreds fit a mesh at a few tiles each.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Union

import numpy as np

from .apps import APP_SPECS
from .snn import SNN, calibrate_spikes, feedforward

__all__ = [
    "WorkloadSpec",
    "TABLE1_FIT",
    "sample_workload",
    "workload_suite",
]


@dataclasses.dataclass(frozen=True)
class WorkloadSpec:
    """Distribution parameters of a synthetic tenant population.

    ``neurons_range`` is sampled log-uniformly (the Table-1 neuron counts
    span 980..24732); ``syn_per_neuron`` / ``spikes_per_neuron`` are
    (log-mean, log-std) of log-normal draws; ``templates`` holds the
    relative layer-width shapes the topology is drawn from;
    ``width_jitter`` is the multiplicative layer-width noise (log-uniform
    in [1/j, j]); ``recurrent_p`` the probability of a feedback edge.
    """

    neurons_range: tuple[int, int]
    syn_per_neuron: tuple[float, float]      # (mu, sigma) of log
    spikes_per_neuron: tuple[float, float]   # (mu, sigma) of log
    templates: tuple[tuple[int, ...], ...]
    width_jitter: float = 1.3
    recurrent_p: float = 0.125
    min_syn_per_neuron: float = 4.0
    max_syn_per_neuron: float = 512.0


def _fit_table1() -> WorkloadSpec:
    """Log-space moment fit of the Table-1 application population."""
    specs = list(APP_SPECS.values())
    spn = np.array([s.synapses / s.neurons for s in specs])
    rate = np.array(
        [s.spikes / s.recorded_iters / s.neurons for s in specs]
    )
    return WorkloadSpec(
        neurons_range=(
            min(s.neurons for s in specs), max(s.neurons for s in specs)
        ),
        syn_per_neuron=(
            float(np.mean(np.log(spn))), float(np.std(np.log(spn)))
        ),
        spikes_per_neuron=(
            float(np.mean(np.log(rate))), float(np.std(np.log(rate)))
        ),
        templates=tuple(tuple(s.layer_shape) for s in specs),
        recurrent_p=sum(s.recurrent for s in specs) / len(specs),
    )


#: The Table-1 population fit (computed once at import; APP_SPECS is
#: frozen, so this is deterministic).
TABLE1_FIT: WorkloadSpec = _fit_table1()


def _sample_layers(
    rng: np.random.Generator, spec: WorkloadSpec, n_neurons: int
) -> list[int]:
    """Draw a layer topology: jittered template scaled to ``n_neurons``."""
    shape = np.asarray(
        spec.templates[int(rng.integers(len(spec.templates)))],
        dtype=np.float64,
    )
    jitter = np.exp(
        rng.uniform(
            -np.log(spec.width_jitter), np.log(spec.width_jitter),
            size=shape.size,
        )
    )
    shape = shape * jitter
    raw = shape * (n_neurons / shape.sum())
    widths = np.maximum(np.floor(raw).astype(np.int64), 2)
    # largest-remainder top-up to the exact neuron total
    rem = n_neurons - int(widths.sum())
    if rem > 0:
        order = np.argsort(raw - widths)[::-1]
        widths[order[np.arange(rem) % widths.size]] += 1
    elif rem < 0:
        order = np.argsort(raw - widths)
        k = 0
        while rem < 0:
            i = order[k % widths.size]
            if widths[i] > 2:
                widths[i] -= 1
                rem += 1
            k += 1
    return [int(w) for w in widths]


def sample_workload(
    seed_or_rng: Union[int, np.random.Generator],
    *,
    spec: WorkloadSpec = TABLE1_FIT,
    scale: float = 1.0,
    name: Optional[str] = None,
) -> SNN:
    """Sample ONE synthetic tenant from the fitted population.

    ``scale`` multiplies the neuron-count range (per-neuron fan-in and
    firing-rate distributions are scale-free); ``name`` defaults to a
    draw-derived identifier.  Deterministic given the seed / generator
    state.
    """
    rng = (
        seed_or_rng
        if isinstance(seed_or_rng, np.random.Generator)
        else np.random.default_rng(seed_or_rng)
    )
    lo, hi = spec.neurons_range
    lo = max(8, int(round(lo * scale)))
    hi = max(lo + 1, int(round(hi * scale)))
    n_neurons = int(np.exp(rng.uniform(np.log(lo), np.log(hi))))
    n_neurons = int(np.clip(n_neurons, lo, hi))
    layers = _sample_layers(rng, spec, n_neurons)
    mu, sg = spec.syn_per_neuron
    spn = float(
        np.clip(
            np.exp(rng.normal(mu, sg)),
            spec.min_syn_per_neuron, spec.max_syn_per_neuron,
        )
    )
    # cap at the topology's connectivity capacity (the generator clamps
    # internally too, but an explicit cap keeps the EXACT-total invariant)
    cap = sum(a * b for a, b in zip(layers[:-1], layers[1:]))
    n_syn = int(np.clip(round(spn * n_neurons), n_neurons, max(cap, n_neurons)))
    recurrent = bool(rng.random() < spec.recurrent_p)
    gen_seed = int(rng.integers(2**31 - 1))
    snn = feedforward(
        layers, n_syn,
        seed=gen_seed,
        name=name or f"wl-{gen_seed:08x}",
        recurrent=recurrent,
    )
    mu_r, sg_r = spec.spikes_per_neuron
    rate = float(np.exp(rng.normal(mu_r, sg_r)))
    return calibrate_spikes(
        snn, max(1.0, rate * n_neurons), seed=gen_seed + 7
    )


def workload_suite(
    n: int,
    *,
    seed: int = 0,
    spec: WorkloadSpec = TABLE1_FIT,
    scale: float = 1.0,
    name_prefix: str = "tenant",
) -> list[SNN]:
    """Sample ``n`` distinct tenants from one generator stream.

    Names are ``{name_prefix}{i}`` — stable identifiers for admission
    controllers and trajectory logs.
    """
    rng = np.random.default_rng(seed)
    return [
        sample_workload(rng, spec=spec, scale=scale, name=f"{name_prefix}{i}")
        for i in range(n)
    ]

"""deepseek-v3-671b [arXiv:2412.19437]: 61L d7168, MLA (128 heads), 1 shared
+ 256 routed top-8 fine-grained experts (d_ff 2048); first 3 layers dense.

MTP (multi-token prediction) is a training-objective add-on in the paper;
the backbone compiled here is the standard next-token path (see DESIGN.md
§Arch-applicability)."""

from .base import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="deepseek-v3-671b",
    family="moe",
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    d_ff=18432,            # dense prefix FFN width
    vocab=129_280,
    stacks=(
        (3, (LayerSpec("mla", "swiglu"),)),
        (58, (LayerSpec("mla", "moe"),)),
    ),
    moe_experts=256,
    moe_top_k=8,
    moe_shared=1,
    moe_d_ff=2048,
    mla_q_rank=1536,
    mla_kv_rank=512,
    mla_nope_dim=128,
    mla_rope_dim=64,
    mla_v_dim=128,
    rope_theta=10_000.0,
)

"""phi-3-vision-4.2b [hf:microsoft/Phi-3-vision-128k-instruct]: phi3-mini
backbone (32L d3072 MHA, SwiGLU d_ff 8192) + CLIP vision frontend.

Per assignment the modality frontend is a STUB: input_specs() provides 576
precomputed patch embeddings (CLIP ViT-L/14 @ 336px -> 24x24 patches) that
are linearly projected and prepended to the text tokens."""

from .base import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="phi-3-vision-4.2b",
    family="vlm",
    d_model=3072,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab=32_064,
    stacks=((32, (LayerSpec("gqa", "swiglu"),)),),
    frontend="vision",
    frontend_tokens=576,
    rope_theta=10_000.0,
)

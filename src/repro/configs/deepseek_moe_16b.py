"""deepseek-moe-16b [arXiv:2401.06066]: 28L d2048 16H MHA, fine-grained MoE
64 routed top-6 + 2 shared experts (d_ff 1408); layer 0 is a dense FFN."""

from .base import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="deepseek-moe-16b",
    family="moe",
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=10944,            # dense layer-0 FFN (official DeepSeekMoE width)
    vocab=102_400,
    stacks=(
        (1, (LayerSpec("gqa", "swiglu"),)),
        (27, (LayerSpec("gqa", "moe"),)),
    ),
    moe_experts=64,
    moe_top_k=6,
    moe_shared=2,
    moe_d_ff=1408,
    rope_theta=10_000.0,
)

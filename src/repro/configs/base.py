"""Architecture config schema + input specs for the assigned shape cells.

Every architecture is described by one :class:`ArchConfig`; heterogeneous
stacks (Jamba groups, DeepSeek dense-prefix) are expressed as ``stacks`` —
a list of (repeat, [LayerSpec...]) scanned groups, which keeps the lowered
HLO size O(distinct layer kinds), not O(depth).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """One layer inside a scanned group."""

    mixer: str          # gqa | mla | mamba | mlstm | slstm
    ffn: str            # swiglu | gelu | moe | none


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    stacks: tuple                    # ((repeat, (LayerSpec, ...)), ...)
    d_head: int = 0                  # 0 -> d_model // n_heads

    # attention
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    window: int = 0                  # sliding-window size (0 = full)

    # MLA (DeepSeek-V3)
    mla_q_rank: int = 1536
    mla_kv_rank: int = 512
    mla_nope_dim: int = 128
    mla_rope_dim: int = 64
    mla_v_dim: int = 128

    # MoE
    moe_experts: int = 0
    moe_top_k: int = 0
    moe_shared: int = 0
    moe_d_ff: int = 0
    moe_capacity: float = 1.25
    moe_dispatch: str = "shard_map"  # shard_map (EP) | gather | onehot
    # inference expert placement: experts sharded over (model x data) with
    # whole experts per chip and the small decode token batch replicated —
    # removes the per-step FSDP weight all-gathers (EXPERIMENTS.md §Perf
    # iteration 6).  Set by the serve path; training keeps EP over model.
    inference_ep: bool = False
    aux_loss_weight: float = 0.01

    # Mamba
    mamba_d_inner: int = 0
    mamba_d_state: int = 16
    mamba_d_conv: int = 4
    mamba_dt_rank: int = 0
    mamba_chunk: int = 128

    # xLSTM
    xlstm_d_inner: int = 0
    xlstm_chunk: int = 64

    # frontends (STUBS per assignment: precomputed embeddings)
    frontend: Optional[str] = None   # vision | audio | None
    frontend_tokens: int = 0         # e.g. image patches prepended

    # norms / misc
    norm: str = "rms"                # rms | ln
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    remat: str = "full"              # none | full
    # unroll scanned stacks into straight-line HLO.  scan keeps compiles
    # fast; unroll makes cost_analysis trip-count-exact (XLA counts a
    # while-loop body once) — the dry-run lowers both variants.
    layer_unroll: bool = False
    # sub-quadratic? (decides long_500k applicability)
    subquadratic: bool = False

    # ------------------------------------------------------------------
    @property
    def head_dim(self) -> int:
        return self.d_head or (self.d_model // self.n_heads)

    @property
    def n_layers(self) -> int:
        return sum(r * len(specs) for r, specs in self.stacks)

    @property
    def activation_dtype(self):
        return jnp.bfloat16 if self.dtype == "bfloat16" else jnp.float32

    def param_count(self) -> int:
        """Analytic parameter count (used for 6ND roofline maths)."""
        from repro.models.transformer import init_abstract

        leaves = jax.tree.leaves(init_abstract(self))
        return int(sum(int(np.prod(l.shape)) for l in leaves))

    def active_param_count(self) -> int:
        """Active params per token (MoE: shared + top_k experts only)."""
        total = self.param_count()
        if self.moe_experts == 0:
            return total
        expert_p = 3 * self.d_model * self.moe_d_ff
        n_moe_layers = sum(
            r * sum(1 for s in specs if s.ffn == "moe") for r, specs in self.stacks
        )
        inactive = n_moe_layers * (self.moe_experts - self.moe_top_k) * expert_p
        return total - inactive


# ======================================================================
# shape cells (assignment: 4 shapes per LM arch)
# ======================================================================
SHAPES = {
    "train_4k": dict(kind="train", seq_len=4_096, global_batch=256),
    "prefill_32k": dict(kind="prefill", seq_len=32_768, global_batch=32),
    "decode_32k": dict(kind="decode", seq_len=32_768, global_batch=128),
    "long_500k": dict(kind="decode", seq_len=524_288, global_batch=1),
}


def input_specs(cfg: ArchConfig, shape: str, *, reduced: bool = False):
    """ShapeDtypeStruct stand-ins for every model input of a shape cell.

    ``train``/``prefill``: token batch (+ stub frontend embeddings).
    ``decode``: one new token against a KV/state cache of seq_len.
    """
    info = SHAPES[shape]
    s, b = info["seq_len"], info["global_batch"]
    if reduced:
        s, b = min(s, 256), min(b, 4)
    i32 = jnp.int32
    specs: dict = {}
    if info["kind"] in ("train", "prefill"):
        n_front = cfg.frontend_tokens if cfg.frontend else 0
        s_tok = s - n_front
        specs["tokens"] = jax.ShapeDtypeStruct((b, s_tok), i32)
        if info["kind"] == "train":
            specs["labels"] = jax.ShapeDtypeStruct((b, s_tok), i32)
        if cfg.frontend:
            specs["frontend_embeds"] = jax.ShapeDtypeStruct(
                (b, n_front, cfg.d_model), cfg.activation_dtype
            )
    else:  # decode
        specs["tokens"] = jax.ShapeDtypeStruct((b, 1), i32)
        specs["cache_len"] = jax.ShapeDtypeStruct((), i32)
    return specs, info


def decode_cache_specs(cfg: ArchConfig, shape: str, *, reduced: bool = False):
    """ShapeDtypeStructs of the decode cache for a shape cell."""
    from repro.models.transformer import init_cache_abstract

    info = SHAPES[shape]
    s, b = info["seq_len"], info["global_batch"]
    if reduced:
        s, b = min(s, 256), min(b, 4)
    return init_cache_abstract(cfg, b, s)

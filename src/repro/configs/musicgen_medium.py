"""musicgen-medium [arXiv:2306.05284]: 48L d1536 decoder-only over EnCodec
tokens (vocab 2048), LayerNorm + GELU.

Per assignment the EnCodec/conditioning frontend is a STUB: input_specs()
provides 256 precomputed conditioning-frame embeddings prepended to the
codec-token sequence; the codec tokens themselves are ordinary vocabulary
ids (the delay-pattern interleave is a data-layout choice upstream)."""

from .base import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="musicgen-medium",
    family="audio",
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,
    d_ff=6144,
    vocab=2048,
    stacks=((48, (LayerSpec("gqa", "gelu"),)),),
    norm="ln",
    frontend="audio",
    frontend_tokens=256,
    rope_theta=10_000.0,
)

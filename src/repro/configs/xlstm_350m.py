"""xlstm-350m [arXiv:2405.04517]: 24 blocks d1024, xLSTM[7:1] — one sLSTM per
seven mLSTM blocks; no separate FFN (blocks carry internal 2x expansion).
Recurrent state is O(1) per token => runs the long_500k cell."""

from .base import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="xlstm-350m",
    family="ssm",
    d_model=1024,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab=50_304,
    stacks=(
        (3, (LayerSpec("slstm", "none"),) + tuple(
            LayerSpec("mlstm", "none") for _ in range(7)
        )),
    ),
    xlstm_d_inner=2048,
    xlstm_chunk=64,
    subquadratic=True,
    tie_embeddings=True,
)

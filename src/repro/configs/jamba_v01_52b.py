"""jamba-v0.1-52b [arXiv:2403.19887]: 32L d4096 hybrid — Jamba blocks of 8
layers with attention:mamba 1:7 (attention at in-block index 3) and MoE (16
experts top-2) on every other layer; GQA 32H/kv8.  SSM state + 1/8 attention
layers => runs the long_500k cell."""

from .base import ArchConfig, LayerSpec


def _jamba_block():
    specs = []
    for i in range(8):
        mixer = "gqa" if i == 3 else "mamba"
        ffn = "moe" if i % 2 == 1 else "swiglu"
        specs.append(LayerSpec(mixer, ffn))
    return tuple(specs)


CONFIG = ArchConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=65_536,
    stacks=((4, _jamba_block()),),
    moe_experts=16,
    moe_top_k=2,
    moe_shared=0,
    moe_d_ff=14336,
    mamba_d_inner=8192,
    mamba_d_state=16,
    mamba_d_conv=4,
    mamba_dt_rank=256,
    rope_theta=10_000.0,
    subquadratic=True,
)

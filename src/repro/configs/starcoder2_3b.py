"""starcoder2-3b [arXiv:2402.19173]: 30L d3072, GQA 24H/kv2, RoPE, sliding-
window attention (4096) => O(window) KV and a valid long_500k cell;
LayerNorm + GELU FFN per the StarCoder2 architecture."""

from .base import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="starcoder2-3b",
    family="dense",
    d_model=3072,
    n_heads=24,
    n_kv_heads=2,
    d_ff=12288,
    vocab=49_152,
    stacks=((30, (LayerSpec("gqa", "gelu"),)),),
    window=4096,
    norm="ln",
    rope_theta=100_000.0,
    subquadratic=True,
)

"""codeqwen1.5-7b [hf:Qwen/CodeQwen1.5-7B]: Qwen1.5 architecture — 32L d4096
MHA with QKV bias, SwiGLU d_ff 13440, 92k vocab, long-context rope theta."""

from .base import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="codeqwen1.5-7b",
    family="dense",
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    d_ff=13440,
    vocab=92_416,
    stacks=((32, (LayerSpec("gqa", "swiglu"),)),),
    qkv_bias=True,
    rope_theta=1_000_000.0,
)

"""qwen2-1.5b [arXiv:2407.10671]: 28L d1536, GQA 12H/kv2, QKV bias, SwiGLU
d_ff 8960, tied embeddings over the 152k vocab."""

from .base import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="qwen2-1.5b",
    family="dense",
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    d_ff=8960,
    vocab=151_936,
    stacks=((28, (LayerSpec("gqa", "swiglu"),)),),
    qkv_bias=True,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
)

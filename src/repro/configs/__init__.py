"""Architecture registry: ``--arch <id>`` resolution + reduced smoke configs."""

from __future__ import annotations

import dataclasses

from .base import SHAPES, ArchConfig, LayerSpec, decode_cache_specs, input_specs
from .codeqwen15_7b import CONFIG as CODEQWEN15_7B
from .deepseek_moe_16b import CONFIG as DEEPSEEK_MOE_16B
from .deepseek_v3_671b import CONFIG as DEEPSEEK_V3_671B
from .jamba_v01_52b import CONFIG as JAMBA_V01_52B
from .musicgen_medium import CONFIG as MUSICGEN_MEDIUM
from .phi3_vision_4_2b import CONFIG as PHI3_VISION_4_2B
from .qwen15_110b import CONFIG as QWEN15_110B
from .qwen2_1_5b import CONFIG as QWEN2_1_5B
from .starcoder2_3b import CONFIG as STARCODER2_3B
from .xlstm_350m import CONFIG as XLSTM_350M

ARCHS: dict[str, ArchConfig] = {
    c.name: c
    for c in (
        DEEPSEEK_MOE_16B,
        DEEPSEEK_V3_671B,
        XLSTM_350M,
        CODEQWEN15_7B,
        QWEN2_1_5B,
        QWEN15_110B,
        STARCODER2_3B,
        PHI3_VISION_4_2B,
        MUSICGEN_MEDIUM,
        JAMBA_V01_52B,
    )
}

ARCH_NAMES = tuple(ARCHS)


def get_arch(name: str) -> ArchConfig:
    try:
        return ARCHS[name]
    except KeyError:
        raise ValueError(f"unknown arch {name!r}; have {sorted(ARCHS)}")


def reduced(cfg: ArchConfig) -> ArchConfig:
    """Shrink an architecture to CPU smoke-test size, same family/topology."""
    n_heads = 4
    n_kv = max(1, min(cfg.n_kv_heads * n_heads // cfg.n_heads, n_heads))
    stacks = tuple((min(r, 2), specs) for r, specs in cfg.stacks)
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-reduced",
        d_model=256,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        d_head=64,
        d_ff=512 if cfg.d_ff else 0,
        vocab=512,
        stacks=stacks,
        window=min(cfg.window, 64) if cfg.window else 0,
        moe_experts=min(cfg.moe_experts, 8),
        moe_top_k=min(cfg.moe_top_k, 2),
        moe_d_ff=128 if cfg.moe_experts else 0,
        # undropped at smoke scale: capacity drops depend on batch
        # composition, which would make decode != forward by construction
        moe_capacity=8.0,
        mla_q_rank=96,
        mla_kv_rank=64,
        mla_nope_dim=32,
        mla_rope_dim=16,
        mla_v_dim=32,
        mamba_d_inner=512 if cfg.mamba_d_inner else 0,
        mamba_dt_rank=16 if cfg.mamba_d_inner else 0,
        mamba_chunk=32,
        xlstm_d_inner=512 if cfg.xlstm_d_inner else 0,
        xlstm_chunk=16,
        frontend_tokens=min(cfg.frontend_tokens, 16),
        dtype="float32",
        remat="none",
    )


__all__ = [
    "ARCHS",
    "ARCH_NAMES",
    "ArchConfig",
    "LayerSpec",
    "SHAPES",
    "get_arch",
    "reduced",
    "input_specs",
    "decode_cache_specs",
]

"""qwen1.5-110b [hf:Qwen/Qwen1.5-110B]: 80L d8192, GQA 64H/kv8, QKV bias,
SwiGLU d_ff 49152, 152k vocab."""

from .base import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="qwen1.5-110b",
    family="dense",
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=49152,
    vocab=152_064,
    stacks=((80, (LayerSpec("gqa", "swiglu"),)),),
    qkv_bias=True,
    rope_theta=1_000_000.0,
)

"""Pallas TPU kernels for the perf-critical compute layers.

  maxplus_matmul  — (max,+) semiring matmul for Max-Plus MCM analysis (VPU)
  maxplus_bellman — device-resident CSR/segment max-plus Bellman-Ford
                    lambda-search (the exact "csr-jit" mcr_batch backend:
                    multi-lambda probing, ELLPACK or segment-Pallas layout,
                    donated distance buffers)
  lif_crossbar    — fused crossbar matvec (MXU) + LIF neuron update (VPU)
  flash_attention — block-wise online-softmax attention (MXU+VPU)
  mamba_scan      — chunked selective-state-space scan (VPU)

Each kernel has a pure-jnp oracle in ``ref.py``; ``ops.py`` holds the jit'd
public wrappers (padding, interpret-mode dispatch on CPU).
``maxplus_bellman.py`` carries its own jnp fallbacks and is imported
lazily by :mod:`repro.core.maxplus` (keeps core importable without jax).
"""

from . import ops, ref

__all__ = ["ops", "ref"]

"""Pure-jnp oracles for every Pallas kernel (the correctness references).

Each ``<name>_ref`` matches the corresponding kernel's public wrapper in
:mod:`repro.kernels.ops` bit-for-bit semantics (up to fp associativity);
tests sweep shapes/dtypes and assert allclose kernel-vs-ref.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


# ----------------------------------------------------------------------
def maxplus_matmul_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    """C[i,j] = max_k A[i,k] + B[k,j]."""
    return jnp.max(a[:, :, None] + b[None, :, :], axis=1)


def maxplus_matvec_ref(a: jax.Array, x: jax.Array) -> jax.Array:
    return jnp.max(a + x[None, :], axis=1)


def maxplus_bmv_ref(a: jax.Array, x: jax.Array) -> jax.Array:
    """y[g,i] = max_k A[g,i,k] + x[g,k]."""
    return jnp.max(a + x[:, None, :], axis=2)


def maxplus_bmm_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    """C[g,i,j] = max_k A[g,i,k] + B[g,k,j].

    ``lax.map`` over the batch keeps the peak intermediate at one
    (M, K, N) broadcast instead of materializing the whole stack's.
    """
    return jax.lax.map(lambda ab: maxplus_matmul_ref(ab[0], ab[1]), (a, b))


# ----------------------------------------------------------------------
def lif_crossbar_step_ref(
    spikes: jax.Array,
    weights: jax.Array,
    v: jax.Array,
    *,
    leak: float = 0.9,
    v_th: float = 1.0,
    v_reset: float = 0.0,
) -> tuple[jax.Array, jax.Array]:
    """Crossbar accumulate + LIF update, unfused."""
    i_syn = jnp.dot(
        spikes.astype(jnp.float32),
        weights.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
    v_new = leak * v.astype(jnp.float32) + i_syn
    fired = v_new >= v_th
    out_v = jnp.where(fired, v_reset, v_new)
    return fired.astype(spikes.dtype), out_v.astype(v.dtype)


# ----------------------------------------------------------------------
def attention_ref(
    q: jax.Array,  # (B, Hq, Sq, D)
    k: jax.Array,  # (B, Hkv, Skv, D)
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
) -> jax.Array:
    """Dense softmax attention with GQA head grouping + optional SWA."""
    b, hq, sq, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    group = hq // hkv
    kx = jnp.repeat(k, group, axis=1)
    vx = jnp.repeat(v, group, axis=1)
    s = jnp.einsum(
        "bhqd,bhkd->bhqk", q.astype(jnp.float32), kx.astype(jnp.float32)
    ) / math.sqrt(d)
    q_idx = jnp.arange(sq)[:, None]
    kv_idx = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), dtype=bool)
    if causal:
        mask &= q_idx >= kv_idx
    if window > 0:
        mask &= (q_idx - kv_idx) < window
    s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vx.astype(jnp.float32)).astype(q.dtype)


# ----------------------------------------------------------------------
def mamba_scan_ref(
    x: jax.Array,   # (B, L, D)
    dt: jax.Array,  # (B, L, D)
    a: jax.Array,   # (D, N)
    b: jax.Array,   # (B, L, N)
    c: jax.Array,   # (B, L, N)
    h0: jax.Array | None = None,  # (B, D, N)
) -> tuple[jax.Array, jax.Array]:
    """Sequential S6 scan. Returns (y, h_final)."""
    B, L, D = x.shape
    N = a.shape[1]
    if h0 is None:
        h0 = jnp.zeros((B, D, N), jnp.float32)

    def step(h, inp):
        x_t, dt_t, b_t, c_t = inp  # (B,D) (B,D) (B,N) (B,N)
        decay = jnp.exp(dt_t[..., None] * a[None])            # (B, D, N)
        h = decay * h + (dt_t * x_t)[..., None] * b_t[:, None, :]
        y_t = jnp.sum(h * c_t[:, None, :], axis=-1)           # (B, D)
        return h, y_t

    xs = (
        jnp.moveaxis(x, 1, 0).astype(jnp.float32),
        jnp.moveaxis(dt, 1, 0).astype(jnp.float32),
        jnp.moveaxis(b, 1, 0).astype(jnp.float32),
        jnp.moveaxis(c, 1, 0).astype(jnp.float32),
    )
    h, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), h

"""Block-wise online-softmax (flash) attention Pallas kernel.

Used by the LM substrate for training and prefill attention (GQA and MQA via
head grouping; optional sliding window for StarCoder2; optional causal mask).

TPU mapping: the score matmul q·kᵀ and the p·v matmul hit the MXU with
(bq, d) x (d, bkv) tiles; the online-softmax rescale runs on the VPU between
them.  Running stats (m, l) and the output accumulator live in VMEM scratch
across the kv grid axis, so each q block streams the whole kv sequence
without HBM round-trips.  Block sizes default to the MXU-native 128 and all
blocks are (8,128)-aligned.

Softmax stats are stored lane-replicated (bq, 128) — the standard TPU trick
to keep reductions register-aligned.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = float("-inf")


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
    *, n_kv: int, bq: int, bkv: int, scale: float,
    causal: bool, window: int,
):
    ik = pl.program_id(3)
    iq = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref[...])
        m_ref[...] = jnp.full_like(m_ref[...], NEG)
        l_ref[...] = jnp.zeros_like(l_ref[...])

    q_start = iq * bq
    kv_start = ik * bkv

    # block-level skip: strictly-future kv blocks (causal) and blocks fully
    # left of the sliding window contribute nothing.
    run = jnp.full((), True)
    if causal:
        run = jnp.logical_and(run, kv_start <= q_start + bq - 1)
    if window > 0:
        run = jnp.logical_and(run, kv_start + bkv - 1 >= q_start - window + 1)

    @pl.when(run)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32) * scale          # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)                   # (bkv, d)
        v = v_ref[0, 0].astype(jnp.float32)                   # (bkv, d)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )                                                     # (bq, bkv)

        q_idx = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
        kv_idx = kv_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
        mask = jnp.ones_like(s, dtype=jnp.bool_)
        if causal:
            mask &= q_idx >= kv_idx
        if window > 0:
            mask &= (q_idx - kv_idx) < window
        s = jnp.where(mask, s, NEG)

        m_prev = m_ref[...][:, :1]                            # (bq, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)             # (bq, 1)
        m_new = jnp.maximum(m_prev, m_cur)
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)

        p = jnp.exp(s - m_safe)
        p = jnp.where(mask, p, 0.0)                           # kill -inf rows
        alpha = jnp.where(
            jnp.isfinite(m_prev), jnp.exp(m_prev - m_safe), 0.0
        )                                                     # (bq, 1)

        l_ref[...] = l_ref[...] * alpha + jnp.sum(
            p, axis=1, keepdims=True
        ) * jnp.ones_like(l_ref[...])
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
            p, v, preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new * jnp.ones_like(m_ref[...])

    @pl.when(ik == n_kv - 1)
    def _flush():
        l = l_ref[...][:, :1]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "bq", "bkv", "interpret"),
)
def flash_attention(
    q: jax.Array,  # (B, Hq, Sq, D)
    k: jax.Array,  # (B, Hkv, Skv, D)
    v: jax.Array,  # (B, Hkv, Skv, D)
    *,
    causal: bool = True,
    window: int = 0,          # 0 = no sliding window
    bq: int = 128,
    bkv: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Flash attention with grouped KV heads. Sq, Skv must be block multiples."""
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    assert hq % hkv == 0, (hq, hkv)
    group = hq // hkv
    assert sq % bq == 0 and skv % bkv == 0, (sq, skv, bq, bkv)
    n_q, n_kv = sq // bq, skv // bkv
    scale = 1.0 / math.sqrt(d)

    kernel = functools.partial(
        _flash_kernel,
        n_kv=n_kv, bq=bq, bkv=bkv, scale=scale,
        causal=causal, window=window,
    )
    grid = (b, hq, n_q, n_kv)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda ib, ih, iq, ik: (ib, ih, iq, 0)),
            pl.BlockSpec(
                (1, 1, bkv, d),
                lambda ib, ih, iq, ik, g=group: (ib, ih // g, ik, 0),
            ),
            pl.BlockSpec(
                (1, 1, bkv, d),
                lambda ib, ih, iq, ik, g=group: (ib, ih // g, ik, 0),
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, bq, d), lambda ib, ih, iq, ik: (ib, ih, iq, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((b, hq, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, d), jnp.float32),
            pltpu.VMEM((bq, 128), jnp.float32),
            pltpu.VMEM((bq, 128), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)

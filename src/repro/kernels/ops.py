"""Public jit'd wrappers around the Pallas kernels.

Handle arbitrary shapes (pad to block multiples with the correct neutral
element), select interpret mode automatically on non-TPU backends (the
kernel body then executes in Python on CPU — our validation mode), and fall
back to the pure-jnp reference for shapes where a kernel launch would not
pay off.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from . import ref
from .flash_attention import flash_attention as _flash
from .lif_crossbar import lif_crossbar_step as _lif
from .mamba_scan import mamba_chunk_scan as _mamba_chunk
from .maxplus_matmul import maxplus_bmm as _maxplus_bmm
from .maxplus_matmul import maxplus_bmv as _maxplus_bmv
from .maxplus_matmul import maxplus_matmul as _maxplus


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _on_accelerator() -> bool:
    """Any non-CPU jax device visible (TPU *or* GPU)?

    Backend auto-selection must not key on ``default_backend() == "tpu"``
    alone: on a CUDA host that test is false and the exact analysis would
    silently fall back to host numpy.
    """
    try:
        return any(d.platform != "cpu" for d in jax.devices())
    except Exception:  # pragma: no cover - no backend initialised at all
        return False


def _pad_to(x: jax.Array, mults: tuple[int, ...], fill: float) -> jax.Array:
    pads = []
    for dim, m in zip(x.shape, mults):
        target = -(-dim // m) * m
        pads.append((0, target - dim))
    if all(p == (0, 0) for p in pads):
        return x
    return jnp.pad(x, pads, constant_values=fill)


# ======================================================================
# (max,+) matmul / matvec
# ======================================================================
def maxplus_matmul(a, b, *, interpret: bool | None = None):
    """C = A (x) B for arbitrary shapes (pads with -inf)."""
    a = jnp.asarray(a, dtype=jnp.float32)
    b = jnp.asarray(b, dtype=jnp.float32)
    m, k = a.shape
    _, n = b.shape
    if m * n * k < 64**3:  # launch not worth it; oracle is exact
        return ref.maxplus_matmul_ref(a, b)
    if interpret is None:
        interpret = not _on_tpu()
    bm = bn = bk = 128
    ap = _pad_to(a, (bm, bk), float("-inf"))
    bp = _pad_to(b, (bk, bn), float("-inf"))
    out = _maxplus(ap, bp, bm=bm, bn=bn, bk=bk, interpret=interpret)
    return out[:m, :n]


def maxplus_matvec(a, x, *, interpret: bool | None = None):
    """t' = A (x) t.  Matvec has no MXU/VPU win at SDFG sizes; the power
    iteration batches vectors through :func:`maxplus_matmul` when wide."""
    a = jnp.asarray(a, dtype=jnp.float32)
    x = jnp.asarray(x, dtype=jnp.float32)
    return ref.maxplus_matvec_ref(a, x)


def maxplus_bmv(a, x, *, interpret: bool | None = None):
    """y[g] = A[g] (x) x[g] for arbitrary shapes (pads with -inf).

    One launch advances every candidate's Eq.-4 recursion by one step.  On
    CPU / small stacks the jnp oracle is exact and cheaper than an
    interpret-mode launch.
    """
    a = jnp.asarray(a, dtype=jnp.float32)
    x = jnp.asarray(x, dtype=jnp.float32)
    g, m, k = a.shape
    if interpret is None:
        interpret = not _on_tpu()
    if interpret or g * m * k < 64**3:
        return ref.maxplus_bmv_ref(a, x)
    bm = bk = 128
    ap = _pad_to(a, (1, bm, bk), float("-inf"))
    xp = _pad_to(x, (1, bk), float("-inf"))
    out = _maxplus_bmv(ap, xp, bm=bm, bk=bk, interpret=False)
    return out[:, :m]


def maxplus_bmm(a, b, *, interpret: bool | None = None):
    """C[g] = A[g] (x) B[g] for arbitrary shapes (pads with -inf).

    The batched-analysis workhorse: one candidate graph per batch row.  On
    TPU the stack streams through the batched Pallas kernel; elsewhere the
    jnp oracle is exact and avoids interpret-mode launch overhead.
    """
    a = jnp.asarray(a, dtype=jnp.float32)
    b = jnp.asarray(b, dtype=jnp.float32)
    g, m, k = a.shape
    _, _, n = b.shape
    if interpret is None:
        interpret = not _on_tpu()
    if interpret or m * n * k < 64**3:
        return ref.maxplus_bmm_ref(a, b)
    bm = bn = bk = 128
    ap = _pad_to(a, (1, bm, bk), float("-inf"))
    bp = _pad_to(b, (1, bk, bn), float("-inf"))
    out = _maxplus_bmm(ap, bp, bm=bm, bn=bn, bk=bk, interpret=False)
    return out[:, :m, :n]


# ======================================================================
# fused LIF crossbar step
# ======================================================================
def lif_crossbar_step(
    spikes, weights, v, *, leak=0.9, v_th=1.0, v_reset=0.0,
    interpret: bool | None = None,
):
    spikes = jnp.asarray(spikes)
    weights = jnp.asarray(weights)
    v = jnp.asarray(v)
    b, n_in = spikes.shape
    _, n_out = weights.shape
    if interpret is None:
        interpret = not _on_tpu()
    bb = 8
    sp = _pad_to(spikes, (bb, 128), 0.0)
    wp = _pad_to(weights, (128, 128), 0.0)
    vp = _pad_to(v, (bb, 128), 0.0)
    out_s, out_v = _lif(
        sp, wp, vp, leak=leak, v_th=v_th, v_reset=v_reset,
        bb=bb, bn=128, bk=128, interpret=interpret,
    )
    return out_s[:b, :n_out], out_v[:b, :n_out]


# ======================================================================
# flash attention
# ======================================================================
def flash_attention(
    q, k, v, *, causal=True, window=0, interpret: bool | None = None,
    bq: int = 128, bkv: int = 128,
):
    """(B, Hq, Sq, D) x (B, Hkv, Skv, D) -> (B, Hq, Sq, D).

    Pads Sq/Skv to block multiples; padded kv columns are masked out by the
    causal/window mask plus an explicit validity mask via -inf scores being
    impossible for padded keys (k rows are zero but q_idx >= kv_idx keeps
    padded FUTURE keys out; padding is appended at the end so causal masking
    already excludes it for every real query).
    """
    sq, skv = q.shape[2], k.shape[2]
    if interpret is None:
        interpret = not _on_tpu()
    if not causal and skv % bkv != 0:
        # non-causal padding would attend to padded keys; use the oracle
        return ref.attention_ref(q, k, v, causal=False, window=window)
    qp = _pad_to(q, (1, 1, bq, 1), 0.0)
    kp = _pad_to(k, (1, 1, bkv, 1), 0.0)
    vp = _pad_to(v, (1, 1, bkv, 1), 0.0)
    if kp.shape[2] > qp.shape[2] and causal and skv == sq:
        qp = _pad_to(q, (1, 1, kp.shape[2], 1), 0.0)
    out = _flash(
        qp, kp, vp, causal=causal, window=window,
        bq=min(bq, qp.shape[2]), bkv=min(bkv, kp.shape[2]),
        interpret=interpret,
    )
    return out[:, :, :sq, :]


# ======================================================================
# mamba selective scan (two-phase chunked)
# ======================================================================
def mamba_scan(
    x, dt, a, b, c, *, chunk: int = 128, interpret: bool | None = None,
):
    """Full-sequence S6 scan via the chunked kernel. Returns (y, h_final)."""
    B, L, D = x.shape
    N = a.shape[1]
    if interpret is None:
        interpret = not _on_tpu()
    if L % chunk != 0:
        pad = -(-L // chunk) * chunk - L
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
    Lp = x.shape[1]
    n_chunks = Lp // chunk
    bd = min(128, D)

    zeros = jnp.zeros((B, n_chunks, D, N), jnp.float32)
    # phase 1: local scans from zero state -> per-chunk final local states
    _, s_local = _mamba_chunk(
        x, dt, a, b, c, zeros, chunk=chunk, bd=bd, interpret=interpret
    )
    # host combine: H_init(c) = Decay(c-1) * H_init(c-1) + S_local(c-1)
    dt_sum = dt.reshape(B, n_chunks, chunk, D).sum(axis=2)        # (B,C,D)
    decay_chunk = jnp.exp(dt_sum[..., None] * a[None, None])       # (B,C,D,N)

    def comb(h, inp):
        dec, s = inp
        h_next = dec * h + s
        return h_next, h

    (_, h_inits) = jax.lax.scan(
        comb,
        jnp.zeros((B, D, N), jnp.float32),
        (jnp.moveaxis(decay_chunk, 1, 0), jnp.moveaxis(s_local, 1, 0)),
    )
    h_inits = jnp.moveaxis(h_inits, 0, 1)                          # (B,C,D,N)
    # phase 2: true scan from the propagated initial states
    y, h_fin = _mamba_chunk(
        x, dt, a, b, c, h_inits, chunk=chunk, bd=bd, interpret=interpret
    )
    return y[:, :L], h_fin[:, -1]

"""(max,+) semiring matmul Pallas kernel.

The Max-Plus power iteration ``t_k = T (x) t_{k-1}`` (paper Eq. 4) and the
closure computations over large clustered SDFGs reduce to matmuls in the
(max,+) semiring:   C[i,j] = max_k (A[i,k] + B[k,j]).

TPU adaptation (DESIGN.md §3): the MXU implements only the (+,*) semiring,
so this kernel targets the VPU — blocks of A and B are staged in VMEM and
the reduction is an 8x128-vreg ``max`` over broadcast sums.  Block shapes
are multiples of (8, 128) so loads/stores stay register-aligned; K is the
minor grid dimension with a VMEM accumulator initialized to -inf and flushed
on the last K step.

Neutral element is -inf: padding rows/cols with -inf keeps results exact for
non-multiple shapes (handled in ops.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = float("-inf")


def _maxplus_kernel(a_ref, b_ref, out_ref, acc_ref, *, n_k: int, unroll_k: int):
    """One (bm, bn) output block; K iterated via grid dim 2."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.full_like(acc_ref[...], NEG)

    a = a_ref[...]  # (bm, bk)
    b = b_ref[...]  # (bk, bn)
    bk = a.shape[1]

    # Reduce over k in sub-chunks to bound the (bm, chunk, bn) VREG footprint.
    def body(c, acc):
        a_c = jax.lax.dynamic_slice_in_dim(a, c * unroll_k, unroll_k, axis=1)
        b_c = jax.lax.dynamic_slice_in_dim(b, c * unroll_k, unroll_k, axis=0)
        part = jnp.max(a_c[:, :, None] + b_c[None, :, :], axis=1)
        return jnp.maximum(acc, part)

    acc = jax.lax.fori_loop(0, bk // unroll_k, body, acc_ref[...])
    acc_ref[...] = acc

    @pl.when(k == n_k - 1)
    def _flush():
        out_ref[...] = acc_ref[...]


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "unroll_k", "interpret"))
def maxplus_matmul(
    a: jax.Array,
    b: jax.Array,
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    unroll_k: int = 8,
    interpret: bool = False,
) -> jax.Array:
    """C = A (x) B in (max,+); shapes must be multiples of the block shape.

    Use :func:`repro.kernels.ops.maxplus_matmul` for arbitrary shapes
    (it pads with -inf) and for the CPU/interpret dispatch.
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (
        f"shape {(m, k, n)} not a multiple of blocks {(bm, bk, bn)}"
    )
    assert bk % unroll_k == 0
    n_k = k // bk
    grid = (m // bm, n // bn, n_k)

    return pl.pallas_call(
        functools.partial(_maxplus_kernel, n_k=n_k, unroll_k=unroll_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), a.dtype)],
        interpret=interpret,
    )(a, b)


# ----------------------------------------------------------------------
# batched variant: one grid dimension per candidate graph in the stack
# ----------------------------------------------------------------------
def _maxplus_bmm_kernel(a_ref, b_ref, out_ref, acc_ref, *, n_k: int, unroll_k: int):
    """One (bm, bn) output block of one batch element; K is grid dim 3."""
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.full_like(acc_ref[...], NEG)

    a = a_ref[0]  # (bm, bk)
    b = b_ref[0]  # (bk, bn)
    bk = a.shape[1]

    def body(c, acc):
        a_c = jax.lax.dynamic_slice_in_dim(a, c * unroll_k, unroll_k, axis=1)
        b_c = jax.lax.dynamic_slice_in_dim(b, c * unroll_k, unroll_k, axis=0)
        part = jnp.max(a_c[:, :, None] + b_c[None, :, :], axis=1)
        return jnp.maximum(acc, part)

    acc = jax.lax.fori_loop(0, bk // unroll_k, body, acc_ref[...])
    acc_ref[...] = acc

    @pl.when(k == n_k - 1)
    def _flush():
        out_ref[0] = acc_ref[...]


# ----------------------------------------------------------------------
# batched matvec: the Eq.-4 recursion x(k) = T (x) x(k-1) over a stack
# ----------------------------------------------------------------------
def _maxplus_bmv_kernel(a_ref, x_ref, out_ref, acc_ref, *, n_k: int):
    """One (bm,) output slice of one batch element; K is grid dim 2."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.full_like(acc_ref[...], NEG)

    a = a_ref[0]          # (bm, bk)
    x = x_ref[...]        # (1, bk)
    part = jnp.max(a + x, axis=1)[None, :]          # (1, bm)
    acc_ref[...] = jnp.maximum(acc_ref[...], part)

    @pl.when(k == n_k - 1)
    def _flush():
        out_ref[...] = acc_ref[...]


@functools.partial(jax.jit, static_argnames=("bm", "bk", "interpret"))
def maxplus_bmv(
    a: jax.Array,
    x: jax.Array,
    *,
    bm: int = 128,
    bk: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """y[g] = A[g] (x) x[g] in (max,+) for a stack of g matrix/vector pairs.

    The self-timed evolution workhorse: each power-iteration step of the
    whole candidate batch is one launch.  The reduction runs as a VPU max
    over the broadcast (bm, bk) sum — a vector has no MXU path anyway, and
    batching amortizes the launch.  Shapes must be block multiples; use
    :func:`repro.kernels.ops.maxplus_bmv` for arbitrary shapes.
    """
    g, m, k = a.shape
    g2, k2 = x.shape
    assert g == g2 and k == k2, (a.shape, x.shape)
    assert m % bm == 0 and k % bk == 0, (
        f"shape {(g, m, k)} not a multiple of blocks {(bm, bk)}"
    )
    n_k = k // bk
    grid = (g, m // bm, n_k)

    return pl.pallas_call(
        functools.partial(_maxplus_bmv_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bm, bk), lambda gg, i, kk: (gg, i, kk)),
            pl.BlockSpec((1, bk), lambda gg, i, kk: (gg, kk)),
        ],
        out_specs=pl.BlockSpec((1, bm), lambda gg, i, kk: (gg, i)),
        out_shape=jax.ShapeDtypeStruct((g, m), a.dtype),
        scratch_shapes=[pltpu.VMEM((1, bm), a.dtype)],
        interpret=interpret,
    )(a, x)


@functools.partial(
    jax.jit, static_argnames=("bm", "bn", "bk", "unroll_k", "interpret")
)
def maxplus_bmm(
    a: jax.Array,
    b: jax.Array,
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    unroll_k: int = 8,
    interpret: bool = False,
) -> jax.Array:
    """C[g] = A[g] (x) B[g] in (max,+) for a stack of g matrices.

    The batch dimension becomes the major grid dimension — each candidate's
    blocks stream through VMEM independently with the same accumulator
    scheme as :func:`maxplus_matmul`.  Shapes must be block multiples; use
    :func:`repro.kernels.ops.maxplus_bmm` for arbitrary shapes.
    """
    g, m, k = a.shape
    g2, k2, n = b.shape
    assert g == g2 and k == k2, (a.shape, b.shape)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (
        f"shape {(g, m, k, n)} not a multiple of blocks {(bm, bk, bn)}"
    )
    assert bk % unroll_k == 0
    n_k = k // bk
    grid = (g, m // bm, n // bn, n_k)

    return pl.pallas_call(
        functools.partial(_maxplus_bmm_kernel, n_k=n_k, unroll_k=unroll_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bm, bk), lambda gg, i, j, kk: (gg, i, kk)),
            pl.BlockSpec((1, bk, bn), lambda gg, i, j, kk: (gg, kk, j)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda gg, i, j, kk: (gg, i, j)),
        out_shape=jax.ShapeDtypeStruct((g, m, n), a.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), a.dtype)],
        interpret=interpret,
    )(a, b)

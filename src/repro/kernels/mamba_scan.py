"""Chunked selective-state-space (Mamba/S6) scan Pallas kernel.

Jamba's Mamba blocks need ``h_t = exp(dt_t * A) ⊙ h_{t-1} + dt_t * B_t x_t``
with ``y_t = C_t · h_t + D ⊙ x_t`` over very long sequences.  The TPU
adaptation is a two-phase chunked scan (Mamba-2-style reformulation, adapted
to VMEM):

  phase 1 (this kernel, h0 = 0):   per-chunk local scan, in parallel over
      (batch, chunk, channel-block); emits local outputs and the chunk-final
      local state.
  combine (host, jnp):             an ``n_chunks``-step associative scan
      propagates initial states across chunks:
      ``H_init(c) = Decay(c-1) ⊙ H_init(c-1) + S_local(c-1)``.
  phase 2 (this kernel, h0 = H_init): re-scan each chunk from its true
      initial state (recompute beats materializing (L, D, N) decay tensors —
      HBM traffic is the binding constraint, see DESIGN.md §3).

The channel dimension is blocked at 128 (VREG lane width); the state dim N
(=16 for Jamba) rides along in VMEM; the per-step recurrence is a
``fori_loop`` over the chunk inside the kernel.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _scan_kernel(
    x_ref,      # (1, lc, bd)
    dt_ref,     # (1, lc, bd)
    a_ref,      # (bd, n_state)
    b_ref,      # (1, lc, n_state)
    c_ref,      # (1, lc, n_state)
    h0_ref,     # (1, 1, bd, n_state)
    y_ref,      # (1, lc, bd)
    hout_ref,   # (1, 1, bd, n_state)
    *,
    lc: int,
):
    x = x_ref[0].astype(jnp.float32)        # (lc, bd)
    dt = dt_ref[0].astype(jnp.float32)      # (lc, bd)
    a = a_ref[...].astype(jnp.float32)      # (bd, n)
    bmat = b_ref[0].astype(jnp.float32)     # (lc, n)
    cmat = c_ref[0].astype(jnp.float32)     # (lc, n)
    h = h0_ref[0, 0].astype(jnp.float32)    # (bd, n)

    def step(t, carry):
        h, y = carry
        dt_t = jax.lax.dynamic_slice_in_dim(dt, t, 1, axis=0)[0]   # (bd,)
        x_t = jax.lax.dynamic_slice_in_dim(x, t, 1, axis=0)[0]     # (bd,)
        b_t = jax.lax.dynamic_slice_in_dim(bmat, t, 1, axis=0)[0]  # (n,)
        c_t = jax.lax.dynamic_slice_in_dim(cmat, t, 1, axis=0)[0]  # (n,)
        decay = jnp.exp(dt_t[:, None] * a)                         # (bd, n)
        h = decay * h + (dt_t * x_t)[:, None] * b_t[None, :]
        y_t = jnp.sum(h * c_t[None, :], axis=1)                    # (bd,)
        y = jax.lax.dynamic_update_slice_in_dim(y, y_t[None, :], t, axis=0)
        return h, y

    y0 = jnp.zeros((lc, x.shape[1]), jnp.float32)
    h, y = jax.lax.fori_loop(0, lc, step, (h, y0))
    y_ref[0] = y.astype(y_ref.dtype)
    hout_ref[0, 0] = h.astype(hout_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "bd", "interpret"))
def mamba_chunk_scan(
    x: jax.Array,    # (B, L, D)
    dt: jax.Array,   # (B, L, D)   post-softplus step sizes
    a: jax.Array,    # (D, N)      negative log decays
    b: jax.Array,    # (B, L, N)
    c: jax.Array,    # (B, L, N)
    h0: jax.Array,   # (B, n_chunks, D, N) initial state per chunk
    *,
    chunk: int = 128,
    bd: int = 128,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Scan every chunk from its given initial state.

    Returns (y, h_final) with y: (B, L, D) and h_final: (B, n_chunks, D, N)
    — the final state of each chunk's scan.  ``ops.mamba_scan`` wires the
    two phases + the host combine into the full sequence scan.
    """
    B, L, D = x.shape
    N = a.shape[1]
    assert L % chunk == 0 and D % bd == 0
    n_chunks = L // chunk
    assert h0.shape == (B, n_chunks, D, N), h0.shape

    grid = (B, n_chunks, D // bd)
    y, hout = pl.pallas_call(
        functools.partial(_scan_kernel, lc=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, bd), lambda ib, ic, idd: (ib, ic, idd)),
            pl.BlockSpec((1, chunk, bd), lambda ib, ic, idd: (ib, ic, idd)),
            pl.BlockSpec((bd, N), lambda ib, ic, idd: (idd, 0)),
            pl.BlockSpec((1, chunk, N), lambda ib, ic, idd: (ib, ic, 0)),
            pl.BlockSpec((1, chunk, N), lambda ib, ic, idd: (ib, ic, 0)),
            pl.BlockSpec((1, 1, bd, N), lambda ib, ic, idd: (ib, ic, idd, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, bd), lambda ib, ic, idd: (ib, ic, idd)),
            pl.BlockSpec((1, 1, bd, N), lambda ib, ic, idd: (ib, ic, idd, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, L, D), x.dtype),
            jax.ShapeDtypeStruct((B, n_chunks, D, N), jnp.float32),
        ],
        interpret=interpret,
    )(x, dt, a, b, c, h0)
    return y, hout

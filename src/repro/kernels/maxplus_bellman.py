"""Device-resident exact max-plus lambda-search: CSR Bellman-Ford on JAX.

The batched analysis hot path (:func:`repro.core.maxplus.mcr_batch`)
bisects a per-row lambda and asks, per probe, whether ``weights -
lam*tokens`` contains a positive cycle — a longest-path Bellman-Ford
relaxation over the whole EdgeStack.  The numpy ``"edges"`` backend runs
that host-side, one python-level relaxation round at a time; this module
executes the WHOLE search as one jitted program (the ``"csr-jit"``
backend):

  * the bisection state (lo, hi, has_cycle) and the ``(B*n, K)`` distance
    buffer live on device across all probe rounds — the scratch buffer is
    donated, so XLA reuses the allocation in place instead of copying it
    through every loop step;
  * every relaxation sweep evaluates ``K`` probe lambdas per row at once
    (a broadcast axis on the edge weights).  The relaxation round count
    per sweep is pinned at the Bellman-Ford bound (~``n+1``) regardless
    of how many lambdas ride along, so one K-wide sweep replaces
    ``log2(K+1)`` binary-bisection sweeps nearly for free — sequential
    probe rounds drop from ``~log2(range/tol)`` to ``~log_{K+1}``;
  * rows whose interval already closed start their probes resolved and
    are masked out of the convergence test, so one slow row never drags
    the batch through extra relaxation rounds.

Two relaxation layouts, selected per backend:

``"ell"``
    ELLPACK: incoming edges of every destination node padded to the max
    in-degree ``d`` — the per-round segment fold becomes a dense
    ``dist[ell_src] + ww`` gather and a ``max`` over the degree axis.
    No scatter anywhere; this is what CPU/GPU XLA vectorizes well (the
    scatter-based ``segment_max`` lowering costs several times a numpy
    ``reduceat`` per round on CPU).

``"segment"`` / ``"segment-pallas"``
    Flat dst-sorted CSR folded by :func:`jax.ops.segment_max` (the
    oracle) or by the Pallas kernel below (TPU: sorted segment ids
    accumulate through the sequential grid, no padding blow-up when the
    in-degree distribution is skewed).

Everything here is float64 (``jax.experimental.enable_x64`` scoped to
these calls): the bisection must resolve 1e-8-class relative tolerances,
which float32 intervals cannot represent.  Host-side packing (the CSR
sort, the ELL build, the path bounds) stays in
:mod:`repro.core.maxplus`; this module is pure array-in/array-out.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

NEG_INF = float("-inf")

#: Probe lambdas evaluated per relaxation sweep (the broadcast axis K).
#: Sweeps shrink the interval (K+1)x, i.e. sweep count falls log2(K+1)x,
#: while per-round gather cost grows ~linearly in K — the efficiency
#: frontier K / log2(K+1) favors small K, but K=1 forfeits the shared
#: per-sweep costs (convergence checks, cycle certificates, loop
#: dispatch).  K=3 is the measured sweet spot on CPU; accelerators with
#: wide vector units amortize larger K.
DEFAULT_K_PROBES = 3

_LAYOUTS = ("ell", "segment", "segment-pallas")


# ======================================================================
# Pallas segment-max: sorted segment ids, sequential-grid accumulation
# ======================================================================
def _segment_max_kernel(cand_ref, seg_ref, out_ref):
    """Fold edge candidates into their destination segments (max).

    The grid walks edge blocks sequentially (TPU grid order), the output
    block is the WHOLE (n_segments, K) accumulator (constant index map),
    so read-modify-write per edge is race-free; block 0 initializes the
    accumulator to -inf, the (max,+) neutral element.  Padded edge rows
    carry -inf candidates and segment 0 — they never change a maximum.
    """
    @pl.when(pl.program_id(0) == 0)
    def _init():
        out_ref[...] = jnp.full_like(out_ref, NEG_INF)

    def body(i, carry):
        sid = seg_ref[i]
        out_ref[sid, :] = jnp.maximum(out_ref[sid, :], cand_ref[i, :])
        return carry

    jax.lax.fori_loop(0, cand_ref.shape[0], body, 0)


@functools.partial(
    jax.jit, static_argnames=("n_segments", "block_e", "interpret")
)
def segment_max_pallas(
    cand, seg_ids, *, n_segments: int, block_e: int = 512,
    interpret: bool = True,
):
    """(E, K) candidates + sorted (E,) segment ids -> (n_segments, K) maxima.

    Segments the edges never touch stay at -inf (exactly like
    ``jax.ops.segment_max``).  ``interpret=True`` runs the kernel body in
    Python — the CPU validation mode; on TPU pass ``interpret=False``.
    The whole accumulator must fit one VMEM block, so this kernel is for
    stacks up to ~10^5 destination keys; the jnp oracle has no such cap.
    """
    e, k = cand.shape
    ep = -(-e // block_e) * block_e
    if ep != e:
        cand = jnp.pad(cand, ((0, ep - e), (0, 0)), constant_values=NEG_INF)
        seg_ids = jnp.pad(seg_ids, (0, ep - e))
    seg_ids = seg_ids.astype(jnp.int32)
    return pl.pallas_call(
        _segment_max_kernel,
        grid=(ep // block_e,),
        in_specs=[
            pl.BlockSpec((block_e, k), lambda b: (b, 0)),
            pl.BlockSpec((block_e,), lambda b: (b,)),
        ],
        out_specs=pl.BlockSpec((n_segments, k), lambda b: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((n_segments, k), cand.dtype),
        interpret=interpret,
    )(cand, seg_ids)


# ======================================================================
# the jitted device-resident bisection
# ======================================================================
def csr_bisect(
    dist0,          # (B*n, K) float64 scratch, donated (contents ignored)
    operands,       # layout-specific edge arrays, see mcr_bisect_device
    lo,             # (B,) float64 sound lower bounds
    hi,             # (B,) float64 interval tops (> any finite cycle ratio)
    has_cycle,      # (B,) bool rows already known cyclic
    rel_tol,        # () float64 relative interval tolerance
    *,
    n_actors: int,
    k_probes: int = DEFAULT_K_PROBES,
    max_steps: int = 40,
    max_rounds: int = 0,       # relaxation rounds per probe; 0 -> n+1
    detect_deadlock: bool = False,
    layout: str = "ell",
):
    """Whole-stack lambda bisection, resident on the default device.

    Returns ``(lo, hi, has_cycle, deadlocked)``; the caller's result is
    ``0.5 * (lo + hi)`` where ``has_cycle`` (and ``inf``/``-inf``
    elsewhere).  ``upper`` — the per-row simple-path weight bound whose
    breach flags a pumping positive cycle — is recovered from ``hi``
    (the host passes ``hi = max(upper, lo) + 1``).  Mirrors
    :func:`repro.core.maxplus._positive_cycle_masks` exactly, with the
    K-probe broadcast axis and converged-row masking on top.
    """
    b = lo.shape[0]
    nk = b * n_actors
    rounds = max_rounds if max_rounds else n_actors + 1
    check_every = 4                        # relaxation rounds per verdict
    n_blocks = -(-rounds // check_every)
    n_doublings = max(1, (n_actors + 1).bit_length())
    upper = hi - 1.0                       # host invariant: hi = upper' + 1
    over_node = jnp.repeat(upper, n_actors)[:, None] + 1.0   # (B*n, 1)
    key_row = jnp.arange(nk, dtype=jnp.int32) // n_actors
    ids = jnp.arange(nk, dtype=jnp.int32)

    if layout == "ell":
        ell_src, ell_w, ell_t = operands

        def make_round(lams):
            # (B*n, 1, K) probe weights fold into the gathered candidates;
            # XLA fuses the subtraction into the degree-axis reduction, so
            # nothing (B*n, d, K)-sized is ever materialized
            lam_key = lams[key_row][:, None, :]

            def best_of(dist):
                cand = (
                    dist[ell_src]
                    + (ell_w[:, :, None] - lam_key * ell_t[:, :, None])
                )
                return cand.max(axis=1)

            def witness(dist):
                cand = (
                    dist[ell_src]
                    + (ell_w[:, :, None] - lam_key * ell_t[:, :, None])
                )
                amax = cand.argmax(axis=1)                      # (B*n, K)
                best = jnp.take_along_axis(
                    cand, amax[:, None, :], axis=1
                )[:, 0, :]
                return best, jnp.take_along_axis(ell_src, amax, axis=1)

            return best_of, witness
    else:
        src_sorted, dst_sorted, w_sorted, t_sorted, row_sorted = operands
        src_f = src_sorted.astype(jnp.float64)

        if layout == "segment-pallas":
            def _segmax(cand):
                return segment_max_pallas(
                    cand, dst_sorted, n_segments=nk, interpret=False
                )
        else:
            def _segmax(cand):
                return jax.ops.segment_max(
                    cand, dst_sorted, num_segments=nk,
                    indices_are_sorted=True,
                )

        def make_round(lams):
            lam_e = lams[row_sorted]                        # (E_tot, K)
            ww = w_sorted[:, None] - lam_e * t_sorted[:, None]

            def best_of(dist):
                return _segmax(dist[src_sorted] + ww)

            def witness(dist):
                cand = dist[src_sorted] + ww
                best = _segmax(cand)
                # second fold recovers a predecessor achieving each max
                at_max = cand >= best[dst_sorted]
                psrc = _segmax(
                    jnp.where(at_max, src_f[:, None], NEG_INF)
                ).astype(jnp.int32)
                return best, psrc

            return best_of, witness

    def probe(dist, lams, active):
        """(B, k) positive-cycle verdicts at per-row probe lambdas.

        Longest-path Bellman-Ford with three resolution rules, applied
        every ``check_every`` rounds: a probe with no improving node has
        settled (no positive cycle — the fixpoint is monotone); a node
        past the simple-path bound can only have been pumped by a
        positive cycle; and — the rule the numpy backend cannot afford —
        a cycle in the *tight-edge graph* certifies a (>= 0)-weight
        cycle right now.  Tight edges point each still-improvable node
        ``v`` (``best(v) >= dist(v)``) at an argmax predecessor ``p``
        over the same distance snapshot, so around any cycle of them
        ``sum(w) = sum(best(v_next) - dist(v)) >= sum(dist(v_next) -
        dist(v)) = 0``.  (The boundary probe this conflates with
        "positive" sits within the bisection tolerance by definition.)
        Pointer doubling finds tight-edge cycles in log2(n) gathers, so
        positive probes resolve in O(path + cycle hops) rounds instead
        of pumping distances toward the bound for O(n) rounds — the
        round count that actually gates every sweep.  The relaxation
        rounds between checks stay pure gather/max (no argmax, no
        bookkeeping), which is what keeps them at memory-bandwidth cost.
        """
        k = lams.shape[1]
        best_of, witness = make_round(lams)
        resolved0 = jnp.broadcast_to(~active[:, None], (b, k))
        positive0 = jnp.zeros((b, k), dtype=bool)
        dist = jnp.zeros((nk, k), dtype=dist.dtype) if k != dist.shape[1] \
            else dist * 0.0

        def cond(carry):
            _, resolved, _, blk = carry
            return (blk < n_blocks) & ~resolved.all()

        def body(carry):
            dist, resolved, positive, blk = carry
            dist = jax.lax.fori_loop(
                0, check_every - 1,
                lambda _, d: jnp.maximum(d, best_of(d)), dist,
            )
            # the block's last round doubles as the verdict pass: its
            # candidate fold is computed once with an argmax witness, so
            # the checks cost one argmax + log2(n) pointer hops on top of
            # the relaxation the round does anyway
            best, psrc = witness(dist)
            # once a round improves nothing, no later round can
            improving = (
                (best > dist + 1e-12).reshape(b, n_actors, k).any(axis=1)
            )
            # tight-edge parents: only nodes that can still match or beat
            # their pre-round distance join the cycle-candidate graph
            par = jnp.where(best >= dist, psrc, ids[:, None])
            dist = jnp.maximum(dist, best)
            over = (dist > over_node).reshape(b, n_actors, k).any(axis=1)
            anc = par
            for _ in range(n_doublings):
                anc = jnp.take_along_axis(anc, anc, axis=0)
            on_cycle = jnp.take_along_axis(par, anc, axis=0) != anc
            cyc = on_cycle.reshape(b, n_actors, k).any(axis=1)
            positive = positive | ((over | cyc) & ~resolved)
            resolved = resolved | over | cyc | ~improving
            return dist, resolved, positive, blk + 1

        dist, resolved, positive, _ = jax.lax.while_loop(
            cond, body, (dist, resolved0, positive0, 0)
        )
        # probes still improving after n+1 rounds contain a positive cycle
        return positive | ~resolved, dist

    deadlocked = jnp.zeros(b, dtype=bool)
    if detect_deadlock:
        # any cycle with >= 1 token has ratio <= upper < hi, so a positive
        # cycle AT lam = hi can only be a zero-token (deadlock) cycle with
        # positive weight sum — always the case for tau > 0 graphs
        pos, _ = probe(dist0, hi[:, None], jnp.ones(b, dtype=bool))
        deadlocked = pos[:, 0]

    frac = jnp.arange(1, k_probes + 1, dtype=lo.dtype) / (k_probes + 1)

    def outer_cond(carry):
        lo, hi, _, _, step = carry
        tol = rel_tol * jnp.maximum(1.0, jnp.abs(hi))
        return (step < max_steps) & ((hi - lo) > tol).any()

    def outer_body(carry):
        lo, hi, has_cycle, dist, step = carry
        tol = rel_tol * jnp.maximum(1.0, jnp.abs(hi))
        active = ((hi - lo) > tol) & ~deadlocked
        lams = lo[:, None] + (hi - lo)[:, None] * frac[None, :]  # ascending
        positive, dist = probe(dist, lams, active)
        # positives form a prefix of the ascending probes (positive iff
        # lam < rho); the count locates rho in (lams[c-1], lams[c]]
        c = jnp.sum(positive & active[:, None], axis=1)
        pick = lambda idx: jnp.take_along_axis(
            lams, jnp.clip(idx, 0, k_probes - 1)[:, None], axis=1
        )[:, 0]
        lo = jnp.where(active & (c > 0), pick(c - 1), lo)
        hi = jnp.where(active & (c < k_probes), pick(c), hi)
        has_cycle = has_cycle | (active & (c > 0))
        return lo, hi, has_cycle, dist, step + 1

    lo, hi, has_cycle, _, _ = jax.lax.while_loop(
        outer_cond, outer_body, (lo, hi, has_cycle, dist0, 0)
    )
    return lo, hi, has_cycle, deadlocked


_CSR_STATIC = (
    "n_actors", "k_probes", "max_steps", "max_rounds",
    "detect_deadlock", "layout",
)
#: Donating the distance scratch lets XLA alias it in place through the
#: bisection loop on accelerators; CPU buffers are never donatable, so a
#: separate non-donating entry avoids a warning per call there.
_csr_bisect_donating = jax.jit(
    csr_bisect, static_argnames=_CSR_STATIC, donate_argnums=(0,)
)
_csr_bisect_plain = jax.jit(csr_bisect, static_argnames=_CSR_STATIC)


def _default_layout(layout: str | None) -> str:
    from .ops import _on_tpu

    if layout is None:
        layout = "segment-pallas" if _on_tpu() else "ell"
    assert layout in _LAYOUTS, layout
    return layout


def _dispatch_bisect(
    operands, lo, hi, has_cycle,
    *,
    n_actors: int,
    rel_tol: float,
    k_probes: int,
    max_steps: int,
    max_rounds: int,
    detect_deadlock: bool,
    layout: str,
    device=None,
):
    """Enqueue one chunk's bisection (inside an ``enable_x64`` scope).

    Returns the four result arrays WITHOUT forcing them to host: jax
    dispatch is async, so a caller placing successive chunks on different
    devices overlaps their execution and synchronizes only at the final
    ``np.asarray`` gather.  ``device=None`` keeps the default placement.
    """
    from .ops import _on_accelerator

    fn = _csr_bisect_donating if _on_accelerator() else _csr_bisect_plain
    b = int(np.asarray(lo).shape[0])

    def put(x, dtype):
        arr = np.asarray(x, dtype=dtype)
        return jax.device_put(arr, device) if device is not None \
            else jnp.asarray(arr)

    if layout == "ell":
        ell_src, ell_w, ell_t = operands
        ops_dev = (
            put(ell_src, np.int32),
            put(ell_w, np.float64),
            put(ell_t, np.float64),
        )
    else:
        src, dst, w, tok, row = operands
        ops_dev = (
            put(src, np.int32),
            put(dst, np.int32),
            put(w, np.float64),
            put(tok, np.float64),
            put(row, np.int32),
        )
    return fn(
        put(np.zeros((b * n_actors, k_probes)), np.float64),
        ops_dev,
        put(lo, np.float64),
        put(hi, np.float64),
        put(has_cycle, bool),
        put(rel_tol, np.float64),
        n_actors=n_actors,
        k_probes=k_probes,
        max_steps=max_steps,
        max_rounds=max_rounds,
        detect_deadlock=detect_deadlock,
        layout=layout,
    )


def mcr_bisect_device(
    operands, lo, hi, has_cycle,
    *,
    n_actors: int,
    rel_tol: float,
    k_probes: int = DEFAULT_K_PROBES,
    max_steps: int = 40,
    max_rounds: int = 0,
    detect_deadlock: bool = False,
    layout: str | None = None,
    device=None,
):
    """Host-facing entry: numpy CSR/ELL arrays in, numpy results out.

    ``operands`` is ``(ell_src, ell_w, ell_t)`` for the ``"ell"`` layout
    (each ``(B*n, d)``) or ``(src, dst, w, tok, row)`` dst-sorted flat
    arrays for the segment layouts.  Scopes ``enable_x64`` around
    conversion, tracing and execution so the bisection runs in float64
    without flipping the process-global jax precision (the Pallas
    semiring kernels stay float32).  ``layout`` defaults to the Pallas
    segment kernel on TPU and ELL everywhere else.  ``device`` pins the
    whole solve to one specific jax device (the sharded path's per-chunk
    placement); ``None`` keeps the default device.
    """
    layout = _default_layout(layout)
    with jax.experimental.enable_x64():
        out = _dispatch_bisect(
            operands, lo, hi, has_cycle,
            n_actors=n_actors, rel_tol=rel_tol, k_probes=k_probes,
            max_steps=max_steps, max_rounds=max_rounds,
            detect_deadlock=detect_deadlock, layout=layout, device=device,
        )
        lo, hi, has_cycle, deadlocked = (np.asarray(x) for x in out)
    return lo, hi, has_cycle, deadlocked


def mcr_bisect_device_sharded(
    chunks,
    devices,
    *,
    n_actors: int,
    rel_tol: float,
    k_probes: int = DEFAULT_K_PROBES,
    max_steps: int = 40,
    max_rounds: int = 0,
    detect_deadlock: bool = False,
    layout: str | None = None,
):
    """Shard-friendly solve entry: one bisection chunk per mesh device.

    ``chunks`` is a sequence of ``(operands, lo, hi, has_cycle)`` tuples —
    row-contiguous slices of one batched lambda-search, each packed
    host-side by :func:`repro.core.maxplus._mcr_batch_csr` — and
    ``devices`` the matching jax devices (chunk k runs on
    ``devices[k % len(devices)]``).  Every chunk is DISPATCHED before any
    is gathered: jax execution is async, so chunks run concurrently
    across the mesh and the host blocks once, at the ``np.asarray``
    gather.

    Per-row results are bit-identical to the unsharded solve: the
    bisection is row-local (each row's probe lambdas depend only on its
    own interval, and converged rows never move), so splitting the batch
    changes which rows ride along in a convergence loop but never any
    row's trajectory.  A chunk whose rows all converge early simply
    stops — sharding also stops slow rows dragging the whole batch
    through extra relaxation sweeps.

    Returns concatenated ``(lo, hi, has_cycle, deadlocked)`` rows in
    chunk order.
    """
    assert chunks, "need at least one chunk"
    layout = _default_layout(layout)
    devices = list(devices) or [None]
    with jax.experimental.enable_x64():
        futs = [
            _dispatch_bisect(
                operands, lo, hi, has_cycle,
                n_actors=n_actors, rel_tol=rel_tol, k_probes=k_probes,
                max_steps=max_steps, max_rounds=max_rounds,
                detect_deadlock=detect_deadlock, layout=layout,
                device=devices[k % len(devices)],
            )
            for k, (operands, lo, hi, has_cycle) in enumerate(chunks)
        ]
        parts = [tuple(np.asarray(x) for x in out) for out in futs]
    return tuple(
        np.concatenate([p[i] for p in parts]) for i in range(4)
    )

"""Fused crossbar accumulate + LIF neuron update Pallas kernel.

TPU-native analogue of one neuromorphic tile executing a cluster (paper
§4.3, Fig. 8): the crossbar's Kirchhoff current summation
``I_j = sum_i s_i * w_ij`` becomes an MXU matmul over the 128x128 weight
block (deliberately the crossbar's own granularity = the MXU's native
systolic tile), and the neuron dynamics

    v' = leak * v + I
    spike = v' >= v_th
    v_out = spike ? v_reset : v'

run on the VPU in the same kernel invocation, so membrane state never
round-trips to HBM between the accumulate and the update.

Batched over clusters: input spikes are (B, n_in), weights (n_in, n_out),
state (B, n_out).  BlockSpecs tile B and n_out; n_in is reduced through a
VMEM accumulator over the minor grid axis.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _lif_kernel(
    s_ref, w_ref, v_ref, out_spike_ref, out_v_ref, acc_ref,
    *, n_k: int, leak: float, v_th: float, v_reset: float,
):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref[...])

    # crossbar accumulate on the MXU (fp32 accumulation)
    acc_ref[...] += jnp.dot(
        s_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == n_k - 1)
    def _update():
        v = v_ref[...].astype(jnp.float32)
        v_new = leak * v + acc_ref[...]
        fired = v_new >= v_th
        out_spike_ref[...] = fired.astype(out_spike_ref.dtype)
        out_v_ref[...] = jnp.where(fired, v_reset, v_new).astype(out_v_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("leak", "v_th", "v_reset", "bb", "bn", "bk", "interpret"),
)
def lif_crossbar_step(
    spikes: jax.Array,   # (B, n_in)  0/1 activity
    weights: jax.Array,  # (n_in, n_out)
    v: jax.Array,        # (B, n_out) membrane state
    *,
    leak: float = 0.9,
    v_th: float = 1.0,
    v_reset: float = 0.0,
    bb: int = 8,
    bn: int = 128,
    bk: int = 128,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """One fused crossbar step. Returns (out_spikes, v_next).

    Shapes must be block multiples; :mod:`repro.kernels.ops` pads and
    dispatches for arbitrary shapes.
    """
    b, n_in = spikes.shape
    n_in2, n_out = weights.shape
    assert n_in == n_in2 and v.shape == (b, n_out)
    assert b % bb == 0 and n_out % bn == 0 and n_in % bk == 0
    n_k = n_in // bk
    grid = (b // bb, n_out // bn, n_k)

    kernel = functools.partial(
        _lif_kernel, n_k=n_k, leak=leak, v_th=v_th, v_reset=v_reset
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bb, bn), lambda i, j, kk: (i, j)),
        ],
        out_specs=[
            pl.BlockSpec((bb, bn), lambda i, j, kk: (i, j)),
            pl.BlockSpec((bb, bn), lambda i, j, kk: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, n_out), spikes.dtype),
            jax.ShapeDtypeStruct((b, n_out), v.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((bb, bn), jnp.float32)],
        interpret=interpret,
    )(spikes, weights, v)

"""Sharded checkpoint/restart.

Layout: ``<dir>/step_<N>/``
  manifest.json   — step, pytree structure, leaf shapes/dtypes, mesh shape,
                    data-pipeline cursor (seed, step) for bit-exact resume
  shard_<i>.npz   — flat leaf arrays (one file per host in multi-host runs;
                    single host writes one)

Fault-tolerance contract (launch/elastic.py):
  * writes are atomic: a tmp dir is renamed only after fsync — a crash
    mid-write never corrupts the latest checkpoint;
  * ``latest_step`` scans for the newest COMPLETE manifest, so restart after
    any failure resumes from the last good step;
  * leaves are saved device-host-gathered; on restore they are re-sharded to
    the CURRENT mesh (which may differ after elastic resize).
"""

from __future__ import annotations

import dataclasses
import json
import os
import pathlib
import shutil
import tempfile
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree) -> tuple[list[np.ndarray], Any]:
    leaves, treedef = jax.tree.flatten(tree)
    return [np.asarray(l) for l in leaves], treedef


def save_checkpoint(path, step: int, tree, *, extra: Optional[dict] = None) -> str:
    path = pathlib.Path(path)
    path.mkdir(parents=True, exist_ok=True)
    final = path / f"step_{step:08d}"
    tmp = pathlib.Path(tempfile.mkdtemp(dir=path, prefix=".tmp_"))
    try:
        leaves, treedef = _flatten(tree)
        np.savez(tmp / "shard_0.npz", **{f"leaf_{i}": l for i, l in enumerate(leaves)})
        manifest = {
            "step": step,
            "n_leaves": len(leaves),
            "treedef": str(treedef),
            "shapes": [list(l.shape) for l in leaves],
            "dtypes": [str(l.dtype) for l in leaves],
            "extra": extra or {},
            "complete": True,
        }
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        with open(tmp / "manifest.json", "rb+") as f:
            os.fsync(f.fileno())
        if final.exists():
            shutil.rmtree(final)
        os.rename(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    return str(final)


def latest_step(path) -> Optional[int]:
    path = pathlib.Path(path)
    if not path.exists():
        return None
    steps = []
    for d in path.iterdir():
        if d.name.startswith("step_") and (d / "manifest.json").exists():
            try:
                m = json.loads((d / "manifest.json").read_text())
                if m.get("complete"):
                    steps.append(m["step"])
            except (ValueError, KeyError):
                continue  # torn write: ignore
    return max(steps) if steps else None


def load_checkpoint(path, step: int, like_tree, *, shardings=None):
    """Restore into the structure of ``like_tree``; re-shard to ``shardings``
    (pass the CURRENT mesh's shardings after an elastic resize)."""
    d = pathlib.Path(path) / f"step_{step:08d}"
    manifest = json.loads((d / "manifest.json").read_text())
    data = np.load(d / "shard_0.npz")
    leaves = [data[f"leaf_{i}"] for i in range(manifest["n_leaves"])]
    _, treedef = jax.tree.flatten(like_tree)
    tree = jax.tree.unflatten(treedef, leaves)
    if shardings is not None:
        tree = jax.tree.map(
            lambda leaf, sh: jax.device_put(leaf, sh), tree, shardings
        )
    return tree, manifest["extra"]


@dataclasses.dataclass
class CheckpointManager:
    """Keeps the last ``keep`` checkpoints; trivial API for the train loop."""

    directory: str
    keep: int = 3
    every: int = 100

    def maybe_save(self, step: int, tree, *, extra=None) -> Optional[str]:
        if step % self.every != 0:
            return None
        out = save_checkpoint(self.directory, step, tree, extra=extra)
        self._gc()
        return out

    def restore_latest(self, like_tree, *, shardings=None):
        step = latest_step(self.directory)
        if step is None:
            return None, None, None
        tree, extra = load_checkpoint(
            self.directory, step, like_tree, shardings=shardings
        )
        return step, tree, extra

    def _gc(self) -> None:
        p = pathlib.Path(self.directory)
        steps = sorted(
            d for d in p.iterdir() if d.name.startswith("step_")
        )
        for d in steps[: -self.keep]:
            shutil.rmtree(d, ignore_errors=True)

from .adamw import AdamWConfig, adamw_init, adamw_update
from .compression import compress_int8, decompress_int8, ef_compress_gradients
from .schedule import cosine_schedule

__all__ = [
    "AdamWConfig",
    "adamw_init",
    "adamw_update",
    "cosine_schedule",
    "compress_int8",
    "decompress_int8",
    "ef_compress_gradients",
]

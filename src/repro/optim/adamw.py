"""AdamW with dtype-configurable, fully-sharded optimizer state.

At 671B parameters the optimizer state dominates HBM: fp32 moments are
8 bytes/param — more than 2x the bf16 weights.  ``state_dtype`` selects
fp32 / bf16 / int8-blockwise moments; int8 uses per-block (128) absmax
scaling with stochastic-free symmetric quantization (8-bit Adam), which is
what lets deepseek-v3-671b fit 256 v5e chips in the dry-run (EXPERIMENTS.md
§Dry-run shows the per-device byte counts).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    state_dtype: str = "float32"   # float32 | bfloat16 | int8
    block: int = 128               # int8 quantization block


# ----------------------------------------------------------------------
# int8 moments are quantized in blocks ALONG THE LAST AXIS, keeping the
# parameter's leading dims: the q/scale tensors then inherit the parameter's
# sharding (a flat (n_blocks, block) layout cannot be resharded back to a
# TP/FSDP-sharded weight without GSPMD replicating the fp32 dequant — 406 GB
# temps per expert stack on deepseek-v3; EXPERIMENTS.md §Perf iteration 2).
def _pad_last(x: jax.Array, block: int):
    last = x.shape[-1]
    pad = (-last) % block
    if pad:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    return x


def _quantize(x: jax.Array, block: int):
    xp = _pad_last(x, block)
    nb = xp.shape[-1] // block
    blocks = xp.reshape(*xp.shape[:-1], nb, block)
    scale = jnp.max(jnp.abs(blocks), axis=-1, keepdims=True) / 127.0
    scale = jnp.where(scale == 0, 1.0, scale)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q.reshape(xp.shape), scale[..., 0].astype(jnp.float32)


def _dequantize(q: jax.Array, scale: jax.Array, shape, block: int):
    nb = q.shape[-1] // block
    blocks = q.reshape(*q.shape[:-1], nb, block).astype(jnp.float32)
    full = (blocks * scale[..., None]).reshape(q.shape)
    return full[..., : shape[-1]]


def _moment_zeros(p: jax.Array, cfg: AdamWConfig):
    if cfg.state_dtype == "int8":
        last = p.shape[-1] if p.ndim else 1
        padded = -(-last // cfg.block) * cfg.block
        nb = padded // cfg.block
        shape = p.shape[:-1] if p.ndim else ()
        return {
            "q": jnp.zeros((*shape, padded), jnp.int8),
            "scale": jnp.ones((*shape, nb), jnp.float32),
        }
    dt = jnp.bfloat16 if cfg.state_dtype == "bfloat16" else jnp.float32
    return jnp.zeros_like(p, dtype=dt)


_V_FLOOR = 1e-16


def _moment_read(m, shape, cfg: AdamWConfig, *, kind: str = "m"):
    if cfg.state_dtype == "int8":
        if kind == "v":
            # v is stored log-quantized: linear int8 absmax would round the
            # small entries of a block to zero and 1/sqrt(v)+eps explodes
            # (8-bit Adam needs non-linear quantization for the 2nd moment).
            logv = _dequantize(m["q"], m["scale"], shape, cfg.block)
            return jnp.where(
                logv <= jnp.log(_V_FLOOR) + 1e-3, 0.0, jnp.exp(logv)
            )
        return _dequantize(m["q"], m["scale"], shape, cfg.block)
    return m.astype(jnp.float32)


def _moment_write(val: jax.Array, cfg: AdamWConfig, *, kind: str = "m"):
    if cfg.state_dtype == "int8":
        if kind == "v":
            val = jnp.log(jnp.maximum(val, _V_FLOOR))
        q, scale = _quantize(val, cfg.block)
        return {"q": q, "scale": scale}
    dt = jnp.bfloat16 if cfg.state_dtype == "bfloat16" else jnp.float32
    return val.astype(dt)


# ----------------------------------------------------------------------
def adamw_init(params, cfg: AdamWConfig):
    if cfg.state_dtype == "int8":
        # go through the codec so a zero moment decodes as zero (v is
        # log-quantized: a zero-filled q with unit scale would decode to 1)
        zero = lambda p, kind: _moment_write(
            jnp.zeros(p.shape, jnp.float32), cfg, kind=kind
        )
        return {
            "step": jnp.zeros((), jnp.int32),
            "m": jax.tree.map(lambda p: zero(p, "m"), params),
            "v": jax.tree.map(lambda p: zero(p, "v"), params),
        }
    return {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(lambda p: _moment_zeros(p, cfg), params),
        "v": jax.tree.map(lambda p: _moment_zeros(p, cfg), params),
    }


def adamw_update(params, grads, state, cfg: AdamWConfig, lr_scale=1.0):
    """One AdamW step; global-norm clip; returns (new_params, new_state)."""
    step = state["step"] + 1
    # global-norm clip in fp32
    gnorm = jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
    )
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))

    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    is_moment_leaf = lambda x: isinstance(x, dict) and "q" in x

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        m_f = _moment_read(m, p.shape, cfg, kind="m")
        v_f = _moment_read(v, p.shape, cfg, kind="v")
        m_f = cfg.b1 * m_f + (1 - cfg.b1) * g
        v_f = cfg.b2 * v_f + (1 - cfg.b2) * g * g
        upd_ = (m_f / b1c) / (jnp.sqrt(v_f / b2c) + cfg.eps)
        decay = cfg.weight_decay * p.astype(jnp.float32) if p.ndim >= 2 else 0.0
        new_p = (p.astype(jnp.float32) - lr * (upd_ + decay)).astype(p.dtype)
        return (
            new_p,
            _moment_write(m_f, cfg, kind="m"),
            _moment_write(v_f, cfg, kind="v"),
        )

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.flatten(state["m"], is_leaf=is_moment_leaf)[0]
    flat_v = jax.tree.flatten(state["v"], is_leaf=is_moment_leaf)[0]
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, {"step": step, "m": new_m, "v": new_v}

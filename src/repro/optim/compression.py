"""Gradient compression with error feedback (distributed-optimization trick).

int8 blockwise quantization of gradients before the data-parallel
all-reduce cuts cross-pod gradient traffic 4x (bf16->int8 at equal block
scale cost).  Error feedback accumulates the quantization residual locally
and re-injects it next step, preserving convergence (1-bit Adam lineage).

The compressed all-reduce path is exercised by launch/train.py when
``--compress-grads`` is set; EXPERIMENTS.md §Perf quantifies the collective-
byte reduction on the multi-pod mesh.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def compress_int8(g: jax.Array, block: int = 256):
    flat = g.astype(jnp.float32).reshape(-1)
    pad = (-flat.size) % block
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.where(scale == 0, 1.0, scale)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q: jax.Array, scale: jax.Array, shape) -> jax.Array:
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for d in shape:
        n *= d
    return flat[:n].reshape(shape)


def ef_compress_gradients(grads, error_state, block: int = 256):
    """Error-feedback compression of a gradient pytree.

    Returns (compressed pytree of (q, scale), new_error_state).  The caller
    all-reduces the dequantized gradients (or the int8 payload with a custom
    reduction) across the data/pod axes.
    """
    if error_state is None:
        error_state = jax.tree.map(
            lambda g: jnp.zeros_like(g, dtype=jnp.float32), grads
        )

    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        q, scale = compress_int8(corrected, block)
        deq = decompress_int8(q, scale, g.shape)
        return (q, scale), corrected - deq

    pairs = jax.tree.map(one, grads, error_state)
    compressed = jax.tree.map(
        lambda pair: pair[0], pairs, is_leaf=lambda x: isinstance(x, tuple)
    )
    new_err = jax.tree.map(
        lambda pair: pair[1], pairs, is_leaf=lambda x: isinstance(x, tuple)
    )
    return compressed, new_err

"""LR schedules."""

from __future__ import annotations

import jax.numpy as jnp


def cosine_schedule(step, *, warmup: int = 200, total: int = 10_000,
                    floor: float = 0.1):
    """Linear warmup then cosine decay to ``floor`` of peak (returns scale)."""
    step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
    # (step+1): the very first step must not have a zero learning rate
    warm = jnp.minimum((step + 1.0) / max(warmup, 1), 1.0)
    progress = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * progress))
    return warm * cos

"""Elastic scaling + fault tolerance for 1000+-node deployments.

Design (mirrors the paper's §5 run-time philosophy: adapt to the resources
actually available, without recompiling the world from scratch):

  * **Failure detection**: every host heartbeats a small file (or KV entry);
    the coordinator declares a host dead after ``timeout`` missed beats.
  * **Elastic re-mesh**: on membership change we pick the largest (data',
    model) mesh buildable from surviving hosts — the MODEL axis is kept
    intact (TP requires all its shards) and the DATA axis shrinks/grows, so
    the jit cache keyed by (mesh shape, shapes) only recompiles when the
    data extent changes.  Parameters are restored from the latest complete
    checkpoint and re-sharded to the new mesh (checkpoint/manager.py).
  * **Straggler mitigation**: the paper's own Lemma-1 machinery — keep actor
    ORDER, drop exact timing: our step loop uses bounded staleness: a host
    that misses ``straggle_patience`` consecutive deadlines is treated as
    failed and triggers the same re-mesh path (fail-slow == fail-stop).
  * **Data continuity**: the pipeline is a pure function of (seed, step,
    shard), so after any resize every host regenerates exactly its rows.

This module is hardware-agnostic and fully exercised in tests with
simulated clocks/failures (tests/test_fault_tolerance.py).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import numpy as np


@dataclasses.dataclass
class HostState:
    host_id: int
    last_beat: float
    alive: bool = True


class HeartbeatTracker:
    """Coordinator-side failure detector (file/KV backend pluggable)."""

    def __init__(self, n_hosts: int, *, timeout: float = 30.0,
                 clock: Callable[[], float] = time.monotonic):
        self.timeout = timeout
        self.clock = clock
        self.hosts = {
            h: HostState(h, last_beat=clock()) for h in range(n_hosts)
        }

    def beat(self, host_id: int) -> None:
        st = self.hosts[host_id]
        st.last_beat = self.clock()
        st.alive = True

    def sweep(self) -> list[int]:
        """Mark dead hosts; returns newly-dead host ids."""
        now = self.clock()
        newly_dead = []
        for st in self.hosts.values():
            if st.alive and now - st.last_beat > self.timeout:
                st.alive = False
                newly_dead.append(st.host_id)
        return newly_dead

    def alive_hosts(self) -> list[int]:
        return [h for h, st in self.hosts.items() if st.alive]


def plan_elastic_mesh(
    n_alive_chips: int, *, model_parallel: int = 16, min_data: int = 1
) -> Optional[tuple[int, int]]:
    """Largest (data, model) mesh from surviving chips.

    The model axis is preserved (TP shards are not optional); data shrinks
    to the largest extent that divides the survivors.  Returns None when
    fewer than one model group survives.
    """
    data = n_alive_chips // model_parallel
    if data < min_data:
        return None
    return (data, model_parallel)


@dataclasses.dataclass
class StragglerPolicy:
    """Bounded-staleness deadline policy (fail-slow == fail-stop)."""

    deadline_s: float = 60.0
    patience: int = 3

    def __post_init__(self):
        self._misses: dict[int, int] = {}

    def report(self, host_id: int, step_time_s: float) -> bool:
        """Record a step time; True -> treat host as failed."""
        if step_time_s > self.deadline_s:
            self._misses[host_id] = self._misses.get(host_id, 0) + 1
        else:
            self._misses[host_id] = 0
        return self._misses.get(host_id, 0) >= self.patience


class ElasticController:
    """Glue: heartbeats + straggler policy -> re-mesh decisions.

    ``on_remesh(new_mesh_shape)`` is the caller's hook: it rebuilds the mesh,
    restores the latest checkpoint with new shardings, and resumes the data
    stream at (seed, step) — see examples/elastic_restart.py.
    """

    def __init__(self, n_hosts: int, chips_per_host: int, *,
                 model_parallel: int = 16,
                 tracker: Optional[HeartbeatTracker] = None,
                 straggler: Optional[StragglerPolicy] = None):
        self.tracker = tracker or HeartbeatTracker(n_hosts)
        self.straggler = straggler or StragglerPolicy()
        self.chips_per_host = chips_per_host
        self.model_parallel = model_parallel

    def step(self, step_times: dict[int, float]) -> Optional[tuple[int, int]]:
        """Call once per training step with per-host step times.

        Returns a new (data, model) mesh shape when a re-mesh is needed,
        else None.
        """
        changed = False
        for host, t in step_times.items():
            self.tracker.beat(host)
            if self.straggler.report(host, t):
                self.tracker.hosts[host].alive = False
                changed = True
        changed |= bool(self.tracker.sweep())
        if not changed:
            return None
        alive = len(self.tracker.alive_hosts()) * self.chips_per_host
        return plan_elastic_mesh(alive, model_parallel=self.model_parallel)

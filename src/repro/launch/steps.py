"""jit-able train / serve steps shared by dryrun.py, train.py and serve.py."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import transformer as tf
from repro.optim import AdamWConfig, adamw_update, cosine_schedule


def make_train_step(cfg: ArchConfig, opt: AdamWConfig, *, accum: int = 1,
                    accum_dtype=jnp.bfloat16, compress_grads: bool = False):
    """(params, opt_state, batch) -> (params, opt_state, metrics).

    ``accum`` > 1 splits the global batch into that many microbatches and
    accumulates gradients under a lax.scan — the per-layer activation
    stash (the dominant residency term for the 100B+ cells, see
    EXPERIMENTS.md §Dry-run) shrinks by the same factor.

    ``compress_grads`` applies int8 error-feedback compression to the
    gradients (the payload a cross-pod DP all-reduce would carry; the EF
    residual rides in the optimizer state pytree as ``ef``).
    """

    def grads_of(params, batch):
        return jax.value_and_grad(tf.loss_fn)(params, batch, cfg)

    def train_step(params, opt_state, batch):
        if accum == 1:
            loss, grads = grads_of(params, batch)
        else:
            micro = jax.tree.map(
                lambda t: t.reshape(accum, t.shape[0] // accum, *t.shape[1:]),
                batch,
            )

            def body(acc, mb):
                loss_sum, gsum = acc
                loss, g = grads_of(params, mb)
                gsum = jax.tree.map(
                    lambda a, b: a + b.astype(accum_dtype), gsum, g
                )
                return (loss_sum + loss, gsum), ()

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, accum_dtype), params
            )
            (loss, grads), _ = jax.lax.scan(body, (0.0, zeros), micro)
            loss = loss / accum
            grads = jax.tree.map(lambda g: g / accum, grads)
        if compress_grads:
            from repro.optim import decompress_int8, ef_compress_gradients

            comp, ef = ef_compress_gradients(
                grads, opt_state.get("ef"), block=256
            )
            grads = jax.tree.map(
                lambda pair, g: decompress_int8(*pair, g.shape),
                comp, grads,
                is_leaf=lambda x: isinstance(x, tuple),
            )
            opt_state = dict(opt_state, ef=ef)
        lr_scale = cosine_schedule(opt_state["step"])
        ef_state = opt_state.get("ef")
        params, opt_state = adamw_update(
            params, grads, {k: v for k, v in opt_state.items() if k != "ef"},
            opt, lr_scale,
        )
        if ef_state is not None:
            opt_state = dict(opt_state, ef=ef_state)
        gnorm = jnp.sqrt(
            sum(
                jnp.sum(jnp.square(g.astype(jnp.float32)))
                for g in jax.tree.leaves(grads)
            )
        )
        return params, opt_state, {"loss": loss, "grad_norm": gnorm}

    return train_step


def make_prefill_step(cfg: ArchConfig):
    """(params, batch) -> logits for the full prompt (no cache write-back:
    the prefill cell measures the prompt-processing compute)."""

    def prefill_step(params, batch):
        logits, _ = tf.forward(params, batch, cfg)
        return logits

    return prefill_step


def make_serve_step(cfg: ArchConfig):
    """One-token decode against a seq_len cache: (params, cache, tokens,
    cache_len) -> (logits, new_cache)."""

    def serve_step(params, cache, tokens, cache_len):
        logits, cache = tf.decode_step(params, tokens, cache, cache_len, cfg)
        return logits, cache

    return serve_step

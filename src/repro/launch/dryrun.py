import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes and extract the roofline terms.

  single-pod mesh: (16, 16)    axes (data, model)         = 256 chips
  multi-pod mesh : (2, 16, 16) axes (pod, data, model)    = 512 chips

For each cell we record to benchmarks/artifacts/dryrun/<cell>.json:
  * compiled.memory_analysis()  — per-device bytes (proves residency)
  * compiled.cost_analysis()    — HLO FLOPs + bytes accessed
  * collective bytes            — parsed from the optimized HLO text
    (all-gather / all-reduce / reduce-scatter / all-to-all /
     collective-permute operand sizes)
  * the three roofline terms (seconds) for TPU v5e constants.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-1.5b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--opt-dtype ...]
"""

import argparse
import json
import pathlib
import re
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, SHAPES, get_arch, input_specs
from repro.configs.base import decode_cache_specs
from repro.launch import sharding as sh
from repro.launch.mesh import HW, make_production_mesh
from repro.launch.steps import make_prefill_step, make_serve_step, make_train_step
from repro.models import transformer as tf
from repro.optim import AdamWConfig, adamw_init

ART = pathlib.Path(__file__).resolve().parents[3] / "benchmarks" / "artifacts" / "dryrun"

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4,
    "u16": 2, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)


def _shape_bytes(shape_str: str) -> int:
    """'bf16[8,128,4096]' -> byte size. Tuple shapes handled by caller."""
    m = re.match(r"([a-z0-9]+)\[([0-9,]*)\]", shape_str)
    if not m:
        return 0
    dt, dims = m.groups()
    nbytes = _DTYPE_BYTES.get(dt, 4)
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * nbytes


def collective_bytes(hlo_text: str) -> dict:
    """Sum output-operand bytes of every collective op in optimized HLO."""
    out: dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    counts: dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        m = re.match(r"(?:ROOT )?%?[\w.\-]+ = (\(?[a-z0-9]+\[[^=]*?) ([a-z0-9\-]+)\(", s)
        if not m:
            continue
        shapes_str, op = m.groups()
        if op not in _COLLECTIVES:
            continue
        total = sum(_shape_bytes(x) for x in re.findall(r"[a-z0-9]+\[[0-9,]*\]", shapes_str))
        out[op] += total
        counts[op] += 1
    out_named = {f"bytes_{k}": v for k, v in out.items()}
    out_named.update({f"count_{k}": counts[k] for k in _COLLECTIVES})
    out_named["bytes_total"] = sum(out.values())
    return out_named


# ----------------------------------------------------------------------
def pick_opt_dtype(cfg) -> str:
    """Optimizer-state dtype policy by model size (DESIGN.md §6)."""
    n = cfg.param_count()
    if n > 50e9:
        return "int8"
    if n > 5e9:
        return "bfloat16"
    return "float32"


def model_flops(cfg, shape_info) -> float:
    """6*N_active*D for train (fwd+bwd), 2*N_active*D for inference."""
    n_active = cfg.active_param_count()
    if shape_info["kind"] == "train":
        tokens = shape_info["seq_len"] * shape_info["global_batch"]
        return 6.0 * n_active * tokens
    if shape_info["kind"] == "prefill":
        tokens = shape_info["seq_len"] * shape_info["global_batch"]
        return 2.0 * n_active * tokens
    tokens = shape_info["global_batch"]  # one token per sequence
    return 2.0 * n_active * tokens


# ----------------------------------------------------------------------
def lower_cell(arch: str, shape: str, *, multi_pod: bool, opt_dtype=None,
               unroll: bool = False, repeats_override=None, skip_probes=False):
    """Lower + compile one (arch, shape, mesh) cell; return the record.

    Cost accounting: XLA counts a while-loop (scan) body ONCE, so the main
    scan-variant artifact under-reports FLOPs/collectives by the trip
    counts.  We therefore compile small UNROLLED probes — all stack repeats
    at 1, then each stack at 2 — and solve the per-stack body costs by
    differencing; the recorded roofline numbers are
    ``probe1 + sum_k (repeat_k - 1) * body_k`` (trip-count exact).
    ``unroll=True`` instead lowers the whole model unrolled (slow; used to
    cross-validate the probe method on the hillclimb cells).
    """
    import dataclasses as _dc

    cfg = get_arch(arch)
    if repeats_override is not None:
        cfg = _dc.replace(
            cfg,
            layer_unroll=True,
            stacks=tuple(
                (int(r), specs)
                for r, (_, specs) in zip(repeats_override, cfg.stacks)
            ),
        )
    elif unroll:
        cfg = _dc.replace(cfg, layer_unroll=True)
    info = SHAPES[shape]
    if shape == "long_500k" and not cfg.subquadratic:
        return {"arch": arch, "shape": shape, "skipped": "quadratic-attention"}

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = int(np.prod(list(mesh.shape.values())))
    record = {
        "arch": arch, "shape": shape,
        "mesh": "x".join(str(mesh.shape[a]) for a in mesh.axis_names),
        "chips": n_chips,
    }
    t0 = time.time()

    params_abs = tf.init_abstract(cfg)
    params_sh = sh.params_shardings(params_abs, mesh)
    specs, _ = input_specs(cfg, shape)

    with sh.use_mesh(mesh):
        if info["kind"] == "train":
            opt = AdamWConfig(state_dtype=opt_dtype or pick_opt_dtype(cfg))
            opt_abs = jax.eval_shape(lambda p: adamw_init(p, opt), params_abs)
            opt_sh = sh.opt_state_shardings(opt_abs, params_abs, mesh)
            batch_sh = sh.batch_shardings(specs, mesh)
            # microbatch big models so the activation stash fits residency;
            # probes lower with accum=1 (the accum scan would single-count
            # the whole fwd/bwd in cost_analysis — same trip-count caveat)
            accum = (
                1
                if repeats_override is not None
                else (16 if cfg.param_count() > 30e9 else 1)
            )
            record["grad_accum"] = accum
            step = make_train_step(cfg, opt, accum=accum)
            jitted = jax.jit(
                step,
                in_shardings=(params_sh, opt_sh, batch_sh),
                out_shardings=(params_sh, opt_sh, None),
                donate_argnums=(0, 1),
            )
            lowered = jitted.lower(params_abs, opt_abs, specs)
        elif info["kind"] == "prefill":
            batch_sh = sh.batch_shardings(specs, mesh)
            step = make_prefill_step(cfg)
            jitted = jax.jit(step, in_shardings=(params_sh, batch_sh))
            lowered = jitted.lower(params_abs, specs)
        else:  # decode
            # serving: weight-stationary params (no FSDP axis) + whole-
            # expert inference EP — §Perf iteration 6
            cfg = _dc.replace(cfg, inference_ep=True)
            params_sh = sh.params_shardings(params_abs, mesh, inference=True)
            cache_abs = decode_cache_specs(cfg, shape)
            cache_sh = sh.cache_shardings(cache_abs, mesh)
            tok_abs = specs["tokens"]
            tok_sh = sh.batch_shardings({"t": tok_abs}, mesh)["t"]
            len_abs = specs["cache_len"]
            step = make_serve_step(cfg)
            jitted = jax.jit(
                step,
                in_shardings=(params_sh, cache_sh, tok_sh, None),
                out_shardings=(None, cache_sh),
                donate_argnums=(1,),
            )
            lowered = jitted.lower(params_abs, cache_abs, tok_abs, len_abs)

        record["lower_s"] = round(time.time() - t0, 1)
        t1 = time.time()
        compiled = lowered.compile()
        record["compile_s"] = round(time.time() - t1, 1)

        mem = compiled.memory_analysis()
        record["memory"] = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
        }
        cost = compiled.cost_analysis()
        record["cost"] = {
            "flops": cost.get("flops", 0.0),
            "bytes_accessed": cost.get("bytes accessed", 0.0),
        }
        hlo = compiled.as_text()
        record["collectives"] = collective_bytes(hlo)

    # roofline terms (seconds) — single-chip constants x chip count
    flops = record["cost"]["flops"]
    # cost_analysis flops on the CPU backend are per-partition post-SPMD;
    # normalize to per-chip if they look global (heuristic recorded below).
    record["roofline"] = roofline_terms(record, cfg, info, n_chips)
    record["model_flops"] = model_flops(cfg, info)
    record["params_total"] = cfg.param_count()
    record["params_active"] = cfg.active_param_count()
    return record


def roofline_terms(record, cfg, info, n_chips) -> dict:
    flops = float(record["cost"]["flops"])
    bytes_acc = float(record["cost"]["bytes_accessed"])
    coll = float(record["collectives"]["bytes_total"])
    # cost_analysis reports the per-device (post-SPMD) program: flops and
    # bytes are per chip; collective bytes from HLO text are per chip too.
    t_compute = flops / HW["peak_flops_bf16"]
    t_memory = bytes_acc / HW["hbm_bw"]
    t_collective = coll / HW["ici_bw"]
    dominant = max(
        ("compute", t_compute), ("memory", t_memory), ("collective", t_collective),
        key=lambda kv: kv[1],
    )[0]
    return {
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_collective,
        "dominant": dominant,
    }


# ----------------------------------------------------------------------
def _probe_costs(arch, shape, *, multi_pod, opt_dtype):
    """Trip-count-exact costs via unrolled probe differencing."""
    cfg = get_arch(arch)
    repeats = [r for r, _ in cfg.stacks]
    base = lower_cell(arch, shape, multi_pod=multi_pod, opt_dtype=opt_dtype,
                      repeats_override=[1] * len(repeats))
    if "skipped" in base:
        return None
    flops = float(base["cost"]["flops"])
    bytes_acc = float(base["cost"]["bytes_accessed"])
    coll = dict(base["collectives"])
    probes = {"probe1": base["cost"] | {"coll": base["collectives"]["bytes_total"]}}
    for k, r_k in enumerate(repeats):
        if r_k == 1:
            continue
        reps = [1] * len(repeats)
        reps[k] = 2
        pk = lower_cell(arch, shape, multi_pod=multi_pod, opt_dtype=opt_dtype,
                        repeats_override=reps)
        # clamp at 0: XLA may fuse the 2-layer probe differently than the
        # 1-layer one; a small negative delta is compile noise, not physics
        body_flops = max(
            0.0, float(pk["cost"]["flops"]) - float(base["cost"]["flops"])
        )
        body_bytes = max(
            0.0,
            float(pk["cost"]["bytes_accessed"])
            - float(base["cost"]["bytes_accessed"]),
        )
        flops += (r_k - 1) * body_flops
        bytes_acc += (r_k - 1) * body_bytes
        for key in coll:
            if key.startswith("bytes_") or key.startswith("count_"):
                delta = max(
                    0.0, pk["collectives"][key] - base["collectives"][key]
                )
                coll[key] += (r_k - 1) * delta
        probes[f"probe_stack{k}"] = pk["cost"] | {
            "coll": pk["collectives"]["bytes_total"]
        }
    coll["bytes_total"] = sum(
        v for k, v in coll.items() if k.startswith("bytes_") and k != "bytes_total"
    )
    return {
        "flops": flops,
        "bytes_accessed": bytes_acc,
        "collectives": coll,
        "probes": probes,
    }


def run_cell(arch, shape, *, multi_pod, opt_dtype=None, tag="", unroll=False,
             probes=True):
    name = f"{arch}__{shape}__{'512' if multi_pod else '256'}"
    if unroll:
        name += "__unroll"
    name += tag
    ART.mkdir(parents=True, exist_ok=True)
    path = ART / f"{name}.json"
    try:
        # decode cells: per-layer costs are small vs the embed/logits base,
        # so probe differencing is noise-dominated — lower fully UNROLLED
        # instead (decode graphs are small; compile stays cheap).
        if SHAPES[shape]["kind"] == "decode" and not unroll:
            unroll = True
        rec = lower_cell(arch, shape, multi_pod=multi_pod, opt_dtype=opt_dtype,
                         unroll=unroll)
        rec["unroll"] = unroll
        if probes and not unroll and "skipped" not in rec:
            corrected = _probe_costs(arch, shape, multi_pod=multi_pod,
                                     opt_dtype=opt_dtype)
            if corrected is not None:
                rec["cost_corrected"] = {
                    "flops": corrected["flops"],
                    "bytes_accessed": corrected["bytes_accessed"],
                }
                rec["collectives_corrected"] = corrected["collectives"]
                rec["probes"] = corrected["probes"]
                cfg = get_arch(arch)
                info = SHAPES[shape]
                rec["roofline"] = roofline_terms(
                    {
                        "cost": rec["cost_corrected"],
                        "collectives": rec["collectives_corrected"],
                    },
                    cfg, info, rec["chips"],
                )
    except Exception as e:  # record failures — they are bugs to fix
        rec = {
            "arch": arch, "shape": shape, "multi_pod": multi_pod,
            "unroll": unroll,
            "error": f"{type(e).__name__}: {e}",
            "traceback": traceback.format_exc()[-4000:],
        }
    path.write_text(json.dumps(rec, indent=2, default=float))
    status = rec.get("error", rec.get("skipped", "ok"))
    print(f"[dryrun] {name}: {status}", flush=True)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--opt-dtype", default=None)
    ap.add_argument("--tag", default="")
    ap.add_argument("--unroll", action="store_true",
                    help="lower stacks unrolled (cost-exact roofline variant)")
    args = ap.parse_args()

    cells = []
    archs = list(ARCHS) if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                cells.append((arch, shape, mp))

    ok = 0
    for arch, shape, mp in cells:
        # probes (roofline correction) only on the single-pod mesh: the
        # multi-pod pass proves the pod axis shards (per assignment, the
        # roofline table is single-pod).
        rec = run_cell(arch, shape, multi_pod=mp, opt_dtype=args.opt_dtype,
                       tag=args.tag, unroll=args.unroll, probes=not mp)
        if "error" not in rec:
            ok += 1
    print(f"[dryrun] {ok}/{len(cells)} cells OK")


if __name__ == "__main__":
    main()

"""Production mesh construction.

Target: TPU v5e pods — 256 chips (16x16 ICI torus) per pod; the multi-pod
configuration is 2 pods = 512 chips with the ``pod`` axis crossing DCN.
Importing this module never touches jax device state; meshes are built
lazily inside the functions (dryrun.py sets XLA_FLAGS before any jax call).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh():
    """1-device mesh with the production axis names (smoke tests)."""
    return jax.make_mesh((1, 1), ("data", "model"))


HW = {
    # TPU v5e per-chip constants for the roofline terms
    "peak_flops_bf16": 197e12,   # FLOP/s
    "hbm_bw": 819e9,             # B/s
    "ici_bw": 50e9,              # B/s per link
    "hbm_bytes": 16 * 1024**3,   # capacity
}

"""End-to-end training driver.

On a real TPU fleet this runs the full config over the production mesh; on
the CPU container it drives a reduced config (``--smoke``) for a few hundred
steps — the e2e example required by the assignment.

Features: deterministic shardable data, AdamW (+schedule, clip), checkpoint/
restart (resume is bit-exact via the (seed, step) data contract), optional
int8 error-feedback gradient compression across the data/pod axes, elastic
re-mesh hooks.

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b --smoke \
      --steps 200 --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_arch, reduced
from repro.data import DataConfig, TokenStream
from repro.launch import sharding as sh
from repro.launch.mesh import make_local_mesh, make_production_mesh
from repro.launch.steps import make_train_step
from repro.models import transformer as tf
from repro.optim import AdamWConfig, adamw_init


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--smoke", action="store_true", help="reduced config (CPU)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--opt-dtype", default="float32")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument(
        "--compress-grads", action="store_true",
        help="int8 error-feedback gradient compression before the update "
             "(the cross-pod DP all-reduce payload; 4x DCN traffic cut)",
    )
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = reduced(cfg)
    n_dev = len(jax.devices())
    mesh = (
        make_local_mesh()
        if n_dev == 1
        else make_production_mesh(multi_pod=args.multi_pod)
    )

    data = TokenStream(
        DataConfig(vocab=cfg.vocab, seq_len=args.seq_len,
                   global_batch=args.batch)
    )
    opt = AdamWConfig(lr=1e-3, state_dtype=args.opt_dtype)
    step_fn = make_train_step(
        cfg, opt, accum=args.accum, compress_grads=args.compress_grads
    )

    key = jax.random.PRNGKey(0)
    with sh.use_mesh(mesh):
        params = tf.init_params(cfg, key, dtype=jnp.float32)
        opt_state = adamw_init(params, opt)
        if args.compress_grads:  # keep the state tree jit-stable from step 0
            opt_state["ef"] = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
        params_sh = sh.params_shardings(params, mesh)
        opt_sh = sh.opt_state_shardings(
            jax.eval_shape(lambda: opt_state), params, mesh
        )
        jitted = jax.jit(
            step_fn,
            in_shardings=(params_sh, opt_sh, None),
            out_shardings=(params_sh, opt_sh, None),
            donate_argnums=(0, 1),
        )

        start_step = 0
        ckpt = None
        if args.ckpt_dir:
            ckpt = CheckpointManager(args.ckpt_dir, every=args.ckpt_every)
            restored = ckpt.restore_latest((params, opt_state))
            if restored[0] is not None:
                start_step, (params, opt_state), _ = restored
                print(f"[train] resumed from step {start_step}")

        losses = []
        t0 = time.time()
        for step in range(start_step, args.steps):
            batch = jax.tree.map(jnp.asarray, data.batch(step))
            params, opt_state, metrics = jitted(params, opt_state, batch)
            losses.append(float(metrics["loss"]))
            if step % args.log_every == 0 or step == args.steps - 1:
                dt = time.time() - t0
                tok_s = (step - start_step + 1) * args.batch * args.seq_len / dt
                print(
                    f"[train] step={step} loss={losses[-1]:.4f} "
                    f"gnorm={float(metrics['grad_norm']):.3f} tok/s={tok_s:.0f}",
                    flush=True,
                )
            if ckpt:
                ckpt.maybe_save(step + 1, (params, opt_state),
                                extra={"data_step": step + 1})

        first = np.mean(losses[: max(3, len(losses) // 10)])
        last = np.mean(losses[-max(3, len(losses) // 10):])
        print(f"[train] loss {first:.4f} -> {last:.4f} "
              f"({'improved' if last < first else 'NOT improved'})")
        return losses


if __name__ == "__main__":
    main()

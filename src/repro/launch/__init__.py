# Launch layer: mesh construction, sharding rules, dry-run, train/serve
# drivers.  Keep this __init__ import-free: importing repro.launch.* must
# never touch jax device state (dryrun.py sets XLA_FLAGS first).

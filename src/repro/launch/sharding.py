"""Sharding rules: one place mapping every parameter / activation / cache
leaf to a PartitionSpec over the production mesh.

Mesh axes: ``("data", "model")`` single-pod, ``("pod", "data", "model")``
multi-pod; the pod axis extends data parallelism.  Rules:

  batch dims            -> ("pod","data")      (DP; ZeRO-style state shard)
  attention heads / FFN hidden / experts / vocab -> "model"  (TP / EP)
  KV-cache: heads over "model" when divisible, else sequence (SP) —
            the long_500k cells shard the 524k-token cache by sequence.

Every rule degrades gracefully: an axis is applied only if the dim is
divisible by the mesh axis size (e.g. 8 KV heads on a 16-wide model axis
fall back to sequence sharding).
"""

from __future__ import annotations

import contextlib
import re
import threading
from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_STATE = threading.local()


def current_mesh() -> Optional[Mesh]:
    return getattr(_STATE, "mesh", None)


def _mesh_context(mesh: Mesh):
    """Version-tolerant global-mesh context.

    ``jax.set_mesh`` (newer jax) and ``jax.sharding.use_mesh`` (a brief
    intermediate spelling) both set the mesh that resolves bare
    ``PartitionSpec`` axis names; on jax versions with neither (e.g.
    0.4.x), ``Mesh`` itself is the context manager with that meaning.
    """
    set_mesh = getattr(jax, "set_mesh", None)
    if set_mesh is not None:
        return set_mesh(mesh)
    use = getattr(jax.sharding, "use_mesh", None)
    if use is not None:
        return use(mesh)
    return mesh


@contextlib.contextmanager
def use_mesh(mesh: Mesh):
    """Make sharding constraints active (dry-run / real runs enter this)."""
    prev = getattr(_STATE, "mesh", None)
    _STATE.mesh = mesh
    try:
        with _mesh_context(mesh):
            yield mesh
    finally:
        _STATE.mesh = prev


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        return int(np.prod([mesh.shape[a] for a in axis]))
    return int(mesh.shape[axis])


def _fit(mesh: Mesh, shape, spec_axes) -> P:
    """Drop spec axes that do not divide the corresponding dim."""
    fitted = []
    for dim, axis in zip(shape, spec_axes):
        if axis is not None and dim % _axis_size(mesh, axis) == 0 and dim > 0:
            fitted.append(axis)
        else:
            fitted.append(None)
    return P(*fitted)


def batch_axes(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def mesh_devices(mesh: Optional[Mesh]) -> list:
    """Flat device list of ``mesh`` (row-major over its axes); ``[]`` if None.

    The sharded analysis path (:func:`repro.core.engine.batch_execute` /
    ``batch_execute_fused``) chunks the EdgeStack batch axis over exactly
    this ordering, so chunk k always lands on the same device across
    calls — per-device executable caches stay warm.
    """
    if mesh is None:
        return []
    return list(np.asarray(mesh.devices).reshape(-1))


def host_mesh(n_devices: Optional[int] = None, *, axis: str = "data") -> Mesh:
    """A 1-D data mesh over the visible devices (CPU host devices included).

    The serving benchmarks force ``N`` host devices with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` and build the
    scoring mesh here; on a real accelerator pod the same call meshes the
    accelerators.  ``n_devices`` clamps to what is actually visible.
    """
    devs = jax.devices()
    if n_devices is not None:
        if n_devices < 1:
            raise ValueError(f"n_devices must be >= 1, got {n_devices}")
        devs = devs[: min(int(n_devices), len(devs))]
    return Mesh(np.asarray(devs), (axis,))


def row_chunks(n_rows: int, n_parts: int) -> list[slice]:
    """Contiguous near-equal row slices: the batch-axis sharding rule.

    Mirrors ``np.array_split`` boundaries (first ``n_rows % n_parts``
    chunks get one extra row); empty chunks are dropped so every returned
    slice maps to real work on its device.
    """
    n_parts = max(1, min(int(n_parts), int(n_rows)))
    base, extra = divmod(int(n_rows), n_parts)
    out, start = [], 0
    for k in range(n_parts):
        size = base + (1 if k < extra else 0)
        if size:
            out.append(slice(start, start + size))
        start += size
    return out


# ======================================================================
# activations
# ======================================================================
def logical_shard(x: jax.Array, kind: str) -> jax.Array:
    """Constraint activations inside model code; no-op without a mesh."""
    mesh = current_mesh()
    if mesh is None:
        return x
    b = batch_axes(mesh)
    if kind == "act":  # (B, S, D)
        spec = _fit(mesh, x.shape, (b, None, None))
    elif kind == "logits":  # (B, S, V)
        spec = _fit(mesh, x.shape, (b, None, "model"))
    elif kind == "rows":  # (B, ...) row-batched analysis arrays
        spec = _fit(mesh, x.shape, (b,) + (None,) * (x.ndim - 1))
    else:
        return x
    return jax.lax.with_sharding_constraint(x, spec)


# ======================================================================
# parameters
# ======================================================================
_PARAM_RULES: list[tuple[str, tuple]] = [
    # (path regex, spec template aligned from the RIGHT; left dims pad None).
    # Two-axis sharding: "model" = tensor/expert parallel, "data" = FSDP /
    # ZeRO-3 — without the data axis a 671B-param arch cannot reside on a
    # 16-GB-HBM chip at 16-way TP (EXPERIMENTS.md §Dry-run).
    (r"experts/w_(gate|up)$", ("model", "data", None)),  # (L,E,D,F)
    (r"experts/w_down$", ("model", None, "data")),       # (L,E,F,D)
    (r"router$", (None, None)),                          # replicated (tiny)
    (r"(wq|wk|wv|w_gate|w_up|w_qkv|w_in|w_dt|wq_b|wk_b|wv_b|w_if|wq_a|wkv_a)$",
     ("data", "model")),                                 # (..., D, F)
    (r"(wo|w_down|w_out)$", ("model", "data")),          # (..., F, D)
    (r"r_gates$", ("data", "model")),
    (r"a_log$", ("model", None)),                        # (L, di, n)
    (r"d_skip$", ("model",)),
    (r"w_conv$", (None, "model")),
    (r"(b_up|bq|bk|bv)$", ("model",)),
    (r"(b_down|b_if|norm.*|d_skip)$", (None,)),
    (r"^embed$", ("model", "data")),                     # (V, D)
    (r"^lm_head$", ("data", "model")),                   # (D, V)
    (r"^frontend_proj$", ("data", "model")),
    (r"^final_norm$", (None,)),
]


def param_pspec(path: str, shape, mesh: Mesh, *, inference: bool = False) -> P:
    for pattern, tail in _PARAM_RULES:
        if re.search(pattern, path):
            if inference:
                # weight-stationary serving: no FSDP axis (no per-step
                # gathers); experts spread over (model x data) whole-expert
                if "experts" in path:
                    tail = (("model", "data"), None, None)
                else:
                    tail = tuple(None if a == "data" else a for a in tail)
            full = (None,) * max(0, len(shape) - len(tail)) + tuple(
                tail[-len(shape):] if len(tail) > len(shape) else tail
            )
            return _fit(mesh, shape, full)
    return _fit(mesh, shape, (None,) * len(shape))


def _path_str(path) -> str:
    parts = []
    for p in path:
        key = getattr(p, "key", None)
        parts.append(str(key) if key is not None else str(p))
    return "/".join(parts)


def params_shardings(params_abstract, mesh: Mesh, *, inference: bool = False):
    """NamedShardings for a (possibly abstract) param pytree."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(
            mesh,
            param_pspec(_path_str(path), leaf.shape, mesh, inference=inference),
        ),
        params_abstract,
    )


# ======================================================================
# decode caches / states / optimizer
# ======================================================================
def cache_pspec(shape, mesh: Mesh) -> P:
    """Shard a decode-cache leaf.

    Cache leaves are stacked per layer: (L, B, ...rest) — e.g. GQA KV
    (L, B, H, S, D), MLA latent (L, B, S, r), Mamba state (L, B, di, n).
    Rule: L replicated; B -> data when divisible; the first remaining dim
    divisible by the model axis -> model (heads for GQA, sequence for MLA —
    that IS sequence parallelism for the long-context cells, di for SSM
    states)."""
    b = batch_axes(mesh)
    spec: list = [None] * len(shape)
    if len(shape) >= 2 and shape[1] % _axis_size(mesh, b) == 0:
        spec[1] = b
    for dim in range(2, len(shape)):
        if shape[dim] % _axis_size(mesh, "model") == 0:
            spec[dim] = "model"
            break
    return P(*spec)


def cache_shardings(cache_abstract, mesh: Mesh):
    return jax.tree.map(
        lambda leaf: NamedSharding(mesh, cache_pspec(leaf.shape, mesh)),
        cache_abstract,
    )


def batch_shardings(batch_abstract, mesh: Mesh):
    b = batch_axes(mesh)
    return jax.tree.map(
        lambda leaf: NamedSharding(
            mesh, _fit(mesh, leaf.shape, (b,) + (None,) * (len(leaf.shape) - 1))
        ),
        batch_abstract,
    )


def _is_int8_moment(x) -> bool:
    return isinstance(x, dict) and "q" in x and "scale" in x


def opt_state_shardings(opt_abstract, params_abstract, mesh: Mesh):
    """Shardings for the AdamW state tree.

    fp32/bf16 moments mirror their parameter's sharding; int8 blockwise
    moments are flat (n_blocks, block) tensors sharded over ALL mesh axes
    on the block dim (fully flat ZeRO sharding).
    """
    param_sh = params_shardings(params_abstract, mesh)

    def mom(m_leaf, p_sh):
        if _is_int8_moment(m_leaf):
            # q keeps the parameter's dims (last padded to the quant block);
            # scale swaps the last dim for n_blocks — both inherit the
            # parameter's PartitionSpec so no resharding happens in-update.
            q_shape = m_leaf["q"].shape
            base = tuple(p_sh.spec) + (None,) * (len(q_shape) - len(p_sh.spec))
            return {
                "q": NamedSharding(mesh, _fit(mesh, q_shape, base)),
                "scale": NamedSharding(
                    mesh,
                    _fit(mesh, m_leaf["scale"].shape, base[:-1] + (None,)),
                ),
            }
        return p_sh

    out = {
        "step": NamedSharding(mesh, P()),
        "m": jax.tree.map(mom, opt_abstract["m"], param_sh,
                          is_leaf=_is_int8_moment),
        "v": jax.tree.map(mom, opt_abstract["v"], param_sh,
                          is_leaf=_is_int8_moment),
    }
    if "ef" in opt_abstract:  # error-feedback residuals follow params
        out["ef"] = param_sh
    return out

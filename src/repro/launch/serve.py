"""Batched serving driver: prefill + self-timed decode loop.

The decode scheduler reuses the paper's self-timed execution idea at the
request level: a request fires (decodes) whenever its inputs are ready —
no global barrier per token; finished requests leave their cache slot and
the admission queue backfills it (continuous batching).

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --smoke \
      --requests 8 --gen-tokens 32
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, reduced
from repro.launch import sharding as sh
from repro.launch.mesh import make_local_mesh
from repro.models import transformer as tf


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-tokens", type=int, default=32)
    ap.add_argument("--max-len", type=int, default=128)
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = reduced(cfg)
    mesh = make_local_mesh()
    key = jax.random.PRNGKey(0)

    with sh.use_mesh(mesh):
        params = tf.init_params(cfg, key, dtype=jnp.float32)
        b = args.requests
        rng = np.random.default_rng(0)
        prompts = rng.integers(0, cfg.vocab, size=(b, args.prompt_len))

        decode = jax.jit(
            lambda p, t, c, l: tf.decode_step(p, t, c, l, cfg)
        )

        cache = tf.init_cache(cfg, b, args.max_len, dtype=jnp.float32)
        # prefill by stepping the prompt (teacher-forced decode steps)
        t0 = time.time()
        logits = None
        for i in range(args.prompt_len):
            logits, cache = decode(
                params, jnp.asarray(prompts[:, i : i + 1]), cache, jnp.int32(i)
            )
        t_prefill = time.time() - t0

        # greedy decode, self-timed continuous batch
        out = []
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        t1 = time.time()
        for j in range(args.gen_tokens):
            out.append(np.asarray(tok))
            logits, cache = decode(
                params, tok, cache, jnp.int32(args.prompt_len + j)
            )
            tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        t_decode = time.time() - t1

        gen = np.concatenate(out, axis=1)
        tok_s = b * args.gen_tokens / t_decode
        print(f"[serve] prefill={t_prefill:.2f}s decode={t_decode:.2f}s "
              f"({tok_s:.1f} tok/s) sample={gen[0][:16].tolist()}")
        return gen


if __name__ == "__main__":
    main()

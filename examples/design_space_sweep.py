"""Design-space exploration: which hardware should this SNN get?

  PYTHONPATH=src python examples/design_space_sweep.py [--app MLP-MNIST]

Sweeps crossbar sizes x tile counts x binding strategies for one Table-1
application and prints the Pareto-interesting rows.  All candidate graphs
are analyzed in ONE batched Max-Plus call (`repro.core.explore.sweep`)
instead of a per-candidate Python loop — the array-native ChannelTable IR
makes the stack of edge-weight arrays cheap to build.
"""

import argparse
import sys

sys.path.insert(0, "src")

from repro.core import build_app, sweep  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--app", default="MLP-MNIST")
    args = ap.parse_args()

    snn = build_app(args.app)
    print(f"== sweeping {args.app}: crossbars x tiles x binders")
    report = sweep(
        [snn],
        crossbar_sizes=(64, 128),
        tile_counts=(4, 9, 16),
        binders=("ours", "spinemap", "pycarl"),
    )
    print(f"   {report.n_candidates} candidates, "
          f"build {report.build_time_s:.2f}s, "
          f"batched analysis {report.analysis_time_s:.3f}s")
    for row in report.rows():
        print("   " + ",".join(str(x) for x in row))

    best = report.best(args.app)
    print(f"== best: {best.crossbar}x{best.crossbar} crossbar, "
          f"{best.n_tiles} tiles, binder={best.binder} "
          f"-> {best.throughput:.4e} iterations/us")


if __name__ == "__main__":
    main()

"""Quickstart: compile one SNN application to DYNAP-SE end-to-end.

  PYTHONPATH=src python examples/quickstart.py [--app MLP-MNIST]

Walks the whole paper pipeline (Fig. 2): build SNN -> record/calibrate
spikes -> crossbar-aware clustering (Alg. 1) -> SDFG -> Max-Plus throughput
-> binding + static-order schedule -> self-timed execution, and prints each
stage's result.
"""

import argparse
import sys

sys.path.insert(0, "src")

from repro.core import (  # noqa: E402
    DYNAP_SE,
    analyze_throughput,
    bind_ours,
    build_app,
    build_static_orders,
    measured_throughput,
    mcr_howard,
    partition_greedy,
    sdfg_from_clusters,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--app", default="MLP-MNIST")
    args = ap.parse_args()

    print(f"== building {args.app} (Table-1 totals)")
    snn = build_app(args.app)
    print(f"   neurons={snn.n_neurons:,} synapses={snn.n_synapses:,} "
          f"spikes/iter={snn.spikes.sum():,.0f}")

    print("== Algorithm 1: crossbar-aware clustering")
    cl = partition_greedy(snn, DYNAP_SE)
    util = cl.utilization(DYNAP_SE.tile.crossbar)
    print(f"   clusters={cl.n_clusters} channels={cl.n_channels} "
          f"io_util={util['io']:.0%} xpoint_util={util['crosspoint']:.0%} "
          f"({cl.partition_time_s * 1e3:.1f} ms)")

    print("== SDFG + Max-Plus analysis (infinite resources)")
    app = sdfg_from_clusters(cl, hw=DYNAP_SE)
    rho = mcr_howard(app)
    print(f"   actors={app.n_actors} MCM={rho:.2f} us "
          f"-> throughput={1e6 / rho:,.0f} iterations/s")

    print("== binding (Eq. 7 load balance) + static-order schedule")
    b = bind_ours(cl, DYNAP_SE)
    orders, t_sched = build_static_orders(app, b.binding, DYNAP_SE)
    thr = analyze_throughput(app, b.binding, DYNAP_SE, orders)
    print(f"   clusters/tile={[len(o) for o in orders]} "
          f"schedule_time={t_sched * 1e3:.1f} ms")
    print(f"   hardware-aware throughput={1e6 * thr:,.1f} iterations/s "
          f"(gap vs infinite: {thr * rho:.1%})")

    print("== self-timed execution (operational cross-check)")
    sim = measured_throughput(app, b.binding, DYNAP_SE, orders, iterations=15)
    print(f"   simulated throughput={1e6 * sim:,.1f} iterations/s "
          f"(analytic match: {sim / thr:.4f})")


if __name__ == "__main__":
    main()

"""Execute a clustered SNN with the TPU crossbar kernel (DESIGN.md §3).

  PYTHONPATH=src python examples/snn_on_tpu.py

Maps each cluster to a 128x128 dense crossbar block and runs LIF dynamics
with the fused Pallas kernel (interpret mode on CPU; Mosaic on real TPU),
cross-checking against the sparse JAX reference simulator.
"""

import sys

sys.path.insert(0, "src")

import numpy as np  # noqa: E402

from repro.core import DYNAP_SE, partition_greedy, small_app  # noqa: E402
from repro.kernels import ops, ref  # noqa: E402


def main():
    snn = small_app(200, 2400, seed=5)
    cl = partition_greedy(snn, DYNAP_SE)
    work = cl.snn
    print(f"SNN: {work.n_neurons} neurons -> {cl.n_clusters} clusters")

    # build one dense crossbar block per cluster (inputs x neurons)
    rng = np.random.default_rng(0)
    clusters = []
    for c in range(cl.n_clusters):
        members = np.flatnonzero(cl.cluster_of == c)
        mask = np.isin(work.post, members)
        pre_ids = np.unique(work.pre[mask])
        w = np.zeros((128, 128), np.float32)
        row = {int(p): i for i, p in enumerate(pre_ids)}
        col = {int(n): i for i, n in enumerate(members)}
        for p_, n_, wt in zip(work.pre[mask], work.post[mask], work.weight[mask]):
            w[row[int(p_)], col[int(n_)]] += wt
        clusters.append((pre_ids, members, w))

    # run 5 crossbar steps on the first few clusters, kernel vs oracle
    for ci, (pre_ids, members, w) in enumerate(clusters[:4]):
        s = (rng.random((8, 128)) < 0.15).astype(np.float32)
        v_k = np.zeros((8, 128), np.float32)
        v_r = v_k.copy()
        s_k = s_r = s
        for _ in range(5):
            s_k, v_k = ops.lif_crossbar_step(np.asarray(s_k), w, np.asarray(v_k))
            s_r, v_r = ref.lif_crossbar_step_ref(s_r, w, v_r)
        ok = np.allclose(np.asarray(v_k), np.asarray(v_r), atol=1e-4)
        print(f"cluster {ci}: {len(members)} neurons, {len(pre_ids)} inputs, "
              f"kernel==oracle: {ok}")
        assert ok


if __name__ == "__main__":
    main()

"""Multi-tenant run-time admission with the batched engine (paper §5).

  PYTHONPATH=src python examples/multi_app_admission.py

A 16-tile chip serves several applications at once through an
:class:`AdmissionController`:

  * design time runs ONCE per (app, hardware) — clustering + the
    single-tile static order — and is cached;
  * every admission scores all candidate free-tile subsets in one batched
    engine call (EdgeStack + mcr_batch) instead of replaying a heapq
    simulation per candidate;
  * finish/evict free tiles, and re-admission is a pure cache hit.
"""

import dataclasses
import sys

sys.path.insert(0, "src")

from repro.core import (  # noqa: E402
    DYNAP_SE,
    AdmissionController,
    AdmissionError,
    small_app,
)

HW16 = dataclasses.replace(DYNAP_SE, n_tiles=16)


def main():
    ctl = AdmissionController(HW16)

    print("== design time (once per app; cached by (app, hardware))")
    for i, (n, syn) in enumerate([(600, 12_000), (1000, 24_000), (800, 16_000)]):
        snn = small_app(n, syn, seed=40 + i)
        snn.name = f"tenant{i}"
        art = ctl.register(snn)
        print(f"   {art.app}: {art.clustered.n_clusters} clusters, "
              f"single-tile order in {art.design_time_s * 1e3:.1f} ms")

    print("== t0: three tenants admitted (batched free-tile scoring each)")
    for name, req in (("tenant0", 6), ("tenant1", 6), ("tenant2", 4)):
        rep = ctl.admit(name, n_tiles_request=req)
        print(f"   {name}: tiles={ctl.running()[name]} "
              f"thr={rep.throughput:.2e} "
              f"admit={ctl.events[-1].wall_s * 1e3:.1f} ms")
    print(f"   free tiles: {ctl.free_tiles()}")

    print("== t1: chip is full — a fourth tenant is rejected")
    late = small_app(700, 14_000, seed=99)
    late.name = "latecomer"
    ctl.register(late)
    try:
        ctl.admit("latecomer", n_tiles_request=4)
    except AdmissionError as e:
        print(f"   AdmissionError: {e}")

    print("== t2: tenant1 finishes; latecomer now fits")
    ctl.finish("tenant1")
    rep = ctl.admit("latecomer", n_tiles_request=4)
    print(f"   latecomer: tiles={ctl.running()['latecomer']} "
          f"thr={rep.throughput:.2e}")

    print("== t3: tenant0 is EVICTED, then re-admitted (cache hit)")
    freed = ctl.evict("tenant0")
    print(f"   evicted tenant0, freed tiles {freed}")
    rep = ctl.admit("tenant0", n_tiles_request=6)
    assert ctl.events[-1].cache_hit
    print(f"   re-admitted on {ctl.running()['tenant0']} in "
          f"{ctl.events[-1].wall_s * 1e3:.1f} ms "
          f"(design artifacts reused, hits={ctl.artifacts[('tenant0', HW16)].hits})")

    print("== trajectory")
    for e in ctl.events:
        print(f"   {e.kind:7s} {e.app:10s} tiles={e.tiles} "
              f"wall={e.wall_s * 1e3:6.1f} ms cache_hit={e.cache_hit}")


if __name__ == "__main__":
    main()

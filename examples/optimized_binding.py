"""Throughput-in-the-loop binding: search placements with the real
steady-state period as the objective.

  PYTHONPATH=src python examples/optimized_binding.py [--app MLP-MNIST]

The paper's §4.2 binder balances the Eq.-7 load *proxy*; here the batched
engine scores a whole population of candidate cluster-to-tile bindings per
generation (ONE EdgeStack build + ONE `mcr_batch` call), seeds the search
with all three heuristic binders, and is therefore never worse than any of
them.  The same optimizer is available:

  * as a fourth sweep strategy: `sweep(..., binders=("ours", "optimized"))`
  * at admission time:        `AdmissionController(hw, optimize_budget=(g, p))`
"""

import argparse
import sys

sys.path.insert(0, "src")

from repro.core import (  # noqa: E402
    DYNAP_SE,
    AdmissionController,
    build_app,
    optimize_binding,
    partition_greedy,
)


def main():
    """Optimize one Table-1 app's binding, then admit it with the knob."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--app", default="MLP-MNIST")
    ap.add_argument("--population", type=int, default=64)
    ap.add_argument("--generations", type=int, default=8)
    args = ap.parse_args()

    snn = build_app(args.app)
    clustered = partition_greedy(snn, DYNAP_SE)
    print(f"== {args.app}: {clustered.n_clusters} clusters on "
          f"{DYNAP_SE.n_tiles} tiles")

    rep = optimize_binding(
        clustered, DYNAP_SE,
        population=args.population, generations=args.generations,
    )
    print(f"== heuristic seeds (steady-state period, us):")
    for name, period in sorted(rep.seed_periods.items(), key=lambda kv: kv[1]):
        print(f"   {name:10s} {period:12.4f}")
    print(f"== optimized    {rep.period:12.4f}  "
          f"({rep.improvement * 100:.3f}% better than the best seed, "
          f"{rep.opt_time_s:.1f}s, {rep.n_stack_builds} stack builds for "
          f"{rep.generations} generations x {rep.population} candidates)")
    print("   per-generation best period:",
          " -> ".join(f"{h.best_period:.4f}" for h in rep.history))

    # the same knob at admission time: refine every admission's binding
    ctl = AdmissionController(DYNAP_SE, optimize_budget=(2, 24))
    ctl.register(snn)
    admitted = ctl.admit(snn.name, n_tiles_request=2)
    print(f"== admitted on tiles {sorted(set(admitted.binding.tolist()))} "
          f"with optimize_budget=(2, 24): "
          f"throughput {admitted.throughput:.6f} iter/us")


if __name__ == "__main__":
    main()

"""Run-time multi-application admission (paper §5, Figs. 11-12).

  PYTHONPATH=src python examples/runtime_admission.py

Scenario: ImgSmooth is running on 2 tiles; MLP-MNIST arrives and must be
admitted onto the remaining tiles in the least possible time, using the
design-time single-tile static order + Lemma-1 projection.  Then ImgSmooth
finishes, its tiles free up, and MLP-MNIST is re-admitted at higher
throughput — the dynamic adaptation loop of Fig. 11.
"""

import sys
import time

sys.path.insert(0, "src")

from repro.core import (  # noqa: E402
    DYNAP_SE,
    AdmissionError,
    HardwareState,
    build_app,
    design_time_compile,
    partition_greedy,
    runtime_admit,
    single_tile_order,
    verify_deadlock_free,
)


def main():
    state = HardwareState(DYNAP_SE)

    print("== design time (offline, once per application)")
    apps = {}
    for name in ("ImgSmooth", "MLP-MNIST"):
        cl = partition_greedy(build_app(name), DYNAP_SE)
        order, t = single_tile_order(cl, DYNAP_SE)
        apps[name] = (cl, order)
        print(f"   {name}: single-tile order built in {t * 1e3:.1f} ms")

    print("== t0: ImgSmooth admitted on 2 tiles (best subset, batched scoring)")
    rep1 = runtime_admit(apps["ImgSmooth"][0], state, apps["ImgSmooth"][1],
                         n_tiles_request=2)
    print(f"   tiles={sorted(set(rep1.binding.tolist()))} "
          f"thr={rep1.throughput:.2e} admit={rep1.compile_time_s * 1e3:.1f} ms")

    print("== t0b: a 3-tile request must be REJECTED (only 2 tiles free)")
    try:
        runtime_admit(apps["MLP-MNIST"][0], state, apps["MLP-MNIST"][1],
                      n_tiles_request=3)
    except AdmissionError as e:
        print(f"   AdmissionError: {e}")

    print("== t1: MLP-MNIST arrives, admitted on the free tiles")
    t0 = time.perf_counter()
    rep2 = runtime_admit(apps["MLP-MNIST"][0], state, apps["MLP-MNIST"][1])
    print(f"   tiles={sorted(set(rep2.binding.tolist()))} "
          f"thr={rep2.throughput:.2e} admit={(time.perf_counter()-t0)*1e3:.1f} ms")
    assert verify_deadlock_free(apps["MLP-MNIST"][0], DYNAP_SE, rep2)
    print("   deadlock-free (Lemma 1) verified operationally")

    print("== t2: ImgSmooth finishes; MLP-MNIST re-admitted on all 4 tiles")
    state.release("ImgSmooth")
    state.release("MLP-MNIST")
    rep3 = runtime_admit(apps["MLP-MNIST"][0], state, apps["MLP-MNIST"][1])
    gain = rep3.throughput / rep2.throughput
    print(f"   tiles={sorted(set(rep3.binding.tolist()))} "
          f"thr={rep3.throughput:.2e} ({gain:.2f}x after rescale)")

    print("== design-time reference (per-tile schedules from scratch)")
    rep4 = design_time_compile(apps["MLP-MNIST"][0], DYNAP_SE)
    print(f"   thr={rep4.throughput:.2e} "
          f"compile={rep4.compile_time_s * 1e3:.1f} ms "
          f"(run-time was {rep3.compile_time_s * 1e3:.1f} ms)")


if __name__ == "__main__":
    main()

"""End-to-end LM training driver example (~reduced 100M-class config, a few
hundred steps on CPU; the same driver runs full configs on a TPU mesh).

  PYTHONPATH=src python examples/lm_train_e2e.py [--steps 200]

Demonstrates: deterministic sharded data pipeline, AdamW + cosine schedule,
checkpoint/restart (kill it mid-run and re-run: it resumes bit-exactly),
loss actually decreasing on the synthetic Markov stream.
"""

import argparse
import sys

sys.path.insert(0, "src")

from repro.launch import train  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_e2e_ckpt")
    args = ap.parse_args()

    losses = train.main([
        "--arch", args.arch, "--smoke",
        "--steps", str(args.steps),
        "--seq-len", "128", "--batch", "8",
        "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "50",
        "--log-every", "20",
    ])
    assert losses[-1] < losses[0], "loss must improve on the Markov stream"


if __name__ == "__main__":
    main()

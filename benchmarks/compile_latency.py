"""Compile front-end latency benchmark (array-native front-end).

  PYTHONPATH=src python -m benchmarks.compile_latency            # full run
  PYTHONPATH=src python -m benchmarks.compile_latency --smoke    # CI smoke
  PYTHONPATH=src python -m benchmarks.run compile                # via runner

Three sections, all recorded into ``BENCH_compile.json``:

  1. *Front-end* — per-stage wall-clock of the OLD serial front-end
     (scalar Alg. 1, heapq FCFS order construction, per-graph Howard) vs
     the NEW array-native one (wave-based partitioner, dense batched FCFS
     constructor, batched engine analysis) on the Table-1 apps.
     Acceptance: >= 5x end-to-end on the largest app, identical clusters,
     identical static orders, periods within 1e-6.
  2. *Admission* — warm multi-tenant admission throughput of the new
     front-end vs the ``BENCH_admission.json`` baseline.  Acceptance:
     >= 2x admissions/sec.
  3. *Compile cache* — shape-bucket hit rates under repeated admissions
     and optimizer generations (the EdgeStack shapes the XLA cache sees).
"""

from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from repro.core import (
    DYNAP_SE,
    AdmissionController,
    analyze_throughput,
    batch_execute,
    bind_ours,
    build_app,
    build_static_orders,
    build_static_orders_batch,
    compile_cache_stats,
    optimize_binding,
    partition_greedy,
    partition_greedy_reference,
    reset_compile_cache_stats,
    sdfg_from_clusters,
    single_tile_order,
    small_app,
)
from repro.core.apps import APP_SPECS

#: trajectory-bench admissions/sec recorded before this PR (the stored
#: BENCH_admission.json baseline; used when the file is absent)
FALLBACK_BASELINE_ADMISSIONS_PER_SEC = 36.85

SPEEDUP_TARGET = 5.0
ADMISSION_TARGET = 2.0


# ======================================================================
# section 1: old vs new front-end, per stage, per app
# ======================================================================
def frontend_app_bench(name: str) -> dict:
    """Time every compile stage of one app through both front-ends."""
    snn = build_app(name)

    # -- old: scalar partitioner, heapq orders, per-graph Howard --------
    t0 = time.perf_counter()
    cl_old = partition_greedy_reference(snn, DYNAP_SE)
    t_part_old = time.perf_counter() - t0
    app = sdfg_from_clusters(cl_old, hw=DYNAP_SE)
    t0 = time.perf_counter()
    bres = bind_ours(cl_old, DYNAP_SE)
    t_bind_old = time.perf_counter() - t0
    t0 = time.perf_counter()
    orders_old, _ = build_static_orders(app, bres.binding, DYNAP_SE,
                                        iterations=12)
    t_ord_old = time.perf_counter() - t0
    _, t_s1t_old = single_tile_order(cl_old, DYNAP_SE, method="heapq")
    t0 = time.perf_counter()
    thr_old = analyze_throughput(app, bres.binding, DYNAP_SE, orders_old)
    t_an_old = time.perf_counter() - t0

    # -- new: wave partitioner, dense batched FCFS, batched engine ------
    t0 = time.perf_counter()
    cl_new = partition_greedy(snn, DYNAP_SE)
    t_part_new = time.perf_counter() - t0
    app_new = sdfg_from_clusters(cl_new, hw=DYNAP_SE)
    t0 = time.perf_counter()
    bres_new = bind_ours(cl_new, DYNAP_SE)
    t_bind_new = time.perf_counter() - t0
    t0 = time.perf_counter()
    orders_new = build_static_orders_batch(app_new, bres_new.binding,
                                           DYNAP_SE)[0]
    t_ord_new = time.perf_counter() - t0
    _, t_s1t_new = single_tile_order(cl_new, DYNAP_SE)
    t0 = time.perf_counter()
    rep = batch_execute(app_new, bres_new.binding, DYNAP_SE, [orders_new],
                        backend="edges")
    thr_new = float(rep.throughputs[0])
    t_an_new = time.perf_counter() - t0

    old = {
        "partition_s": t_part_old, "bind_s": t_bind_old,
        "orders_s": t_ord_old, "single_tile_order_s": t_s1t_old,
        "analyze_s": t_an_old,
        "total_s": t_part_old + t_bind_old + t_ord_old + t_s1t_old + t_an_old,
    }
    new = {
        "partition_s": t_part_new, "bind_s": t_bind_new,
        "orders_s": t_ord_new, "single_tile_order_s": t_s1t_new,
        "analyze_s": t_an_new,
        "total_s": t_part_new + t_bind_new + t_ord_new + t_s1t_new + t_an_new,
    }
    # correctness contracts:
    #  * clusters bit-identical to the scalar Algorithm 1,
    #  * orders == the §4.4 step-2 oracle (heapq FCFS, first firings),
    #  * engine period on the SAME orders == per-graph Howard to 1e-6.
    # The old front-end's 12-iteration heapq horizon may legitimately
    # record a different (equally valid) schedule when repeat firings
    # contend — its throughput is reported as an informational ratio.
    from repro.core import SelfTimedExecutor

    oracle = SelfTimedExecutor(app_new, bres_new.binding, DYNAP_SE).run(
        iterations=1
    ).tile_orders
    thr_howard = analyze_throughput(app_new, bres_new.binding, DYNAP_SE,
                                    orders_new)
    engine_dev = abs(thr_new - thr_howard) / max(thr_howard, 1e-300)
    return {
        "app": name,
        "n_neurons": snn.n_neurons,
        "n_clusters": cl_new.n_clusters,
        "old": old,
        "new": new,
        "speedup": old["total_s"] / max(new["total_s"], 1e-12),
        "clusters_identical": bool(
            np.array_equal(cl_new.cluster_of, cl_old.cluster_of)
        ),
        "orders_match_oracle": orders_new == oracle,
        "orders_identical_to_12iter_heapq": orders_new == orders_old,
        "engine_vs_howard_rel_dev": engine_dev,
        "throughput_vs_old": thr_new / max(thr_old, 1e-300),
        "throughput": thr_new,
    }


def frontend_bench(apps: list[str]) -> dict:
    records = [frontend_app_bench(name) for name in apps]
    largest = max(records, key=lambda r: r["n_neurons"])
    return {
        "apps": records,
        "largest_app": largest["app"],
        "largest_speedup": largest["speedup"],
        "target_speedup": SPEEDUP_TARGET,
        "all_clusters_identical": all(r["clusters_identical"] for r in records),
        "all_orders_match_oracle": all(
            r["orders_match_oracle"] for r in records
        ),
        "all_periods_close": all(
            r["engine_vs_howard_rel_dev"] <= 1e-6 for r in records
        ),
        "pass": largest["speedup"] >= SPEEDUP_TARGET,
    }


# ======================================================================
# section 2: admission throughput vs the stored baseline
# ======================================================================
def admission_bench(baseline_path: str = "BENCH_admission.json",
                    *, rounds: int = 8) -> dict:
    from .admission import trajectory_bench

    baseline = FALLBACK_BASELINE_ADMISSIONS_PER_SEC
    if os.path.exists(baseline_path):
        try:
            with open(baseline_path) as fh:
                baseline = json.load(fh)["trajectory_bench"][
                    "admissions_per_sec"
                ]
        except (KeyError, json.JSONDecodeError):
            pass
    trajectory_bench(n_apps=2, rounds=1, seed=99)   # warm jax + code paths
    _, payload = trajectory_bench(n_apps=6, rounds=rounds)
    aps = payload["admissions_per_sec"]
    return {
        "admissions_per_sec": aps,
        "n_admissions": payload["n_admissions"],
        "baseline_admissions_per_sec": baseline,
        "ratio_vs_baseline": aps / max(baseline, 1e-12),
        "target_ratio": ADMISSION_TARGET,
        "pass": aps / max(baseline, 1e-12) >= ADMISSION_TARGET,
    }


# ======================================================================
# section 3: shape-bucket compile-cache hit rates
# ======================================================================
def cache_bench(*, n_admission_cycles: int = 6) -> dict:
    """Repeated admissions + optimizer generations through one engine.

    The OrderBatch order representation keeps the stacked (B, n, E) shape
    invariant across optimizer generations, and the admission controller
    re-admits with the same candidate-subset count — so after the first
    trace every analysis call lands on a previously-seen bucket.
    """
    snn = small_app(240, 3000, seed=5)
    snn.name = "cache-app"
    ctl = AdmissionController(DYNAP_SE)
    ctl.register(snn)
    cl = ctl.artifacts[(snn.name, DYNAP_SE)].clustered

    reset_compile_cache_stats()
    for _ in range(n_admission_cycles):
        ctl.admit(snn.name, n_tiles_request=2)
        ctl.finish(snn.name)
    admission_stats = compile_cache_stats().as_dict()

    reset_compile_cache_stats()
    optimize_binding(cl, DYNAP_SE, population=16, generations=4, rng_seed=3)
    optimizer_stats = compile_cache_stats().as_dict()
    reset_compile_cache_stats()
    return {
        "repeated_admissions": admission_stats,
        "optimizer_generations": optimizer_stats,
    }


# ======================================================================
def run(out_path: str = "BENCH_compile.json", *, smoke: bool = False):
    """Run all sections and write the artifact.

    Returns ``(rows, summary, ok)`` in the benchmarks/run.py convention.
    ``smoke=True`` runs the smallest app only and skips the largest-app
    acceptance gate (CI keeps the wall clock short but still exercises
    every stage and the equality checks).
    """
    by_size = sorted(APP_SPECS, key=lambda n: sum(APP_SPECS[n].layer_shape))
    apps = [by_size[0]] if smoke else list(APP_SPECS)
    fe = frontend_bench(apps)
    adm = admission_bench(rounds=2 if smoke else 8)
    cache = cache_bench(n_admission_cycles=2 if smoke else 6)

    rows = [("app", "clusters", "old_total_s", "new_total_s", "speedup",
             "identical_clusters", "orders_match_oracle",
             "engine_vs_howard", "thr_vs_old")]
    for r in fe["apps"]:
        rows.append((
            r["app"], r["n_clusters"], f"{r['old']['total_s']:.3f}",
            f"{r['new']['total_s']:.3f}", f"{r['speedup']:.1f}x",
            r["clusters_identical"], r["orders_match_oracle"],
            f"{r['engine_vs_howard_rel_dev']:.1e}",
            f"{r['throughput_vs_old']:.4f}",
        ))
    rows += [
        ("--",) * 9,
        ("admissions_per_sec", f"{adm['admissions_per_sec']:.1f}"),
        ("admission_ratio_vs_baseline", f"{adm['ratio_vs_baseline']:.1f}x"),
        ("cache_hit_rate_admissions",
         f"{cache['repeated_admissions']['hit_rate']:.2f}"),
        ("cache_hit_rate_optimizer",
         f"{cache['optimizer_generations']['hit_rate']:.2f}"),
    ]

    correctness = (
        fe["all_clusters_identical"]
        and fe["all_orders_match_oracle"]
        and fe["all_periods_close"]
    )
    # smoke (CI) gates on correctness only — wall-clock ratios are too
    # machine-dependent for a shared runner; the full run enforces both
    # acceptance speedups on top
    ok = correctness and (smoke or (adm["pass"] and fe["pass"]))
    payload = {
        "smoke": smoke,
        "frontend_bench": fe,
        "admission_bench": adm,
        "cache_bench": cache,
        "ok": ok,
    }
    from .common import write_bench
    write_bench(out_path, payload)

    gate = "" if smoke else (
        f"largest app {fe['largest_app']} {fe['largest_speedup']:.1f}x "
        f"(target >= {SPEEDUP_TARGET:.0f}x: "
        f"{'PASS' if fe['pass'] else 'MISS'}); "
    )
    summary = (
        f"{gate}admission {adm['admissions_per_sec']:.1f}/s = "
        f"{adm['ratio_vs_baseline']:.1f}x baseline (target >= "
        f"{ADMISSION_TARGET:.0f}x: {'PASS' if adm['pass'] else 'MISS'}); "
        f"clusters identical + orders == oracle + engine == Howard on "
        f"{len(fe['apps'])}/{len(fe['apps'])} apps: "
        f"{'yes' if correctness else 'NO'}; "
        f"cache hit rate {cache['repeated_admissions']['hit_rate']:.0%} "
        f"(admissions) / {cache['optimizer_generations']['hit_rate']:.0%} "
        f"(optimizer); wrote {out_path}"
    )
    return rows, summary, ok


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_compile.json")
    ap.add_argument("--smoke", action="store_true",
                    help="smallest app only; skip the largest-app gate (CI)")
    args = ap.parse_args()
    rows, summary, ok = run(args.out, smoke=args.smoke)
    print("# compile_latency")
    for row in rows:
        print(",".join(str(x) for x in row))
    print("##", summary)
    if not ok:
        raise SystemExit(1)


if __name__ == "__main__":
    main()

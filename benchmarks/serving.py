"""Serving-loop benchmark: burst admission throughput vs per-event joint placement.

  PYTHONPATH=src python -m benchmarks.serving             # 32x32 full run
  PYTHONPATH=src python -m benchmarks.serving --smoke     # 12 tenants, 8x8
  PYTHONPATH=src python -m benchmarks.run serving         # via the runner

Replays the SAME Zipf-1.1 tenant churn as :mod:`benchmarks.stress`
(224 Table-1-fit tenants, 640 admit/evict events, 32x32 mesh) in two
modes against a joint-placement region-scoped
:class:`~repro.core.runtime.AdmissionController`:

  * **baseline** — every event runs its own region rebalance (the
    controller's normal per-event path, fused multi-component scoring
    included);
  * **burst** — all events submitted up front to a
    :class:`~repro.core.serving.ServingQueue` and drained with
    coalescing: one merged region rebalance per ``coalesce_window``
    applied events, scored through the fused cross-region path
    (:func:`~repro.core.optimize.optimize_binding_graphs_fused`).

Recorded into ``BENCH_serving.json`` (schema in README.md): sustained
admissions/s per mode, the per-rebalance never-regress check, flush/
coalescing counters, and the burst speedup over baseline.  Acceptance:
burst admissions/s beats the stored pre-refactor burst baseline
(10.716/s on the reference host) with ``never_regressed`` true.

``--devices N`` adds the device-scaling sweep: for each count ``d`` up
to ``N`` a SUBPROCESS re-runs the burst mode with
``XLA_FLAGS=--xla_force_host_platform_device_count=d`` (the flag must
precede the jax import, hence the subprocess) and a ``host_mesh(d)``
scoring mesh on the controller, so every rebalance's population scoring
is sharded d ways.  Per-arm trajectories are bit-identical by the
``mesh=`` contract — the sweep varies wall-clock only.  A separate
speculative pre-compilation bench (cold controller, the same churn
drained in waves through a :class:`~repro.core.serving.PrecompilePool`)
reports the cache-warm-hit-rate.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import math
import os
import subprocess
import sys
import time

import numpy as np

from repro.core import (
    DYNAP_SE,
    DYNAP_SE_1024,
    AdmissionController,
    AdmissionError,
    PrecompilePool,
    ServingQueue,
)
from repro.core.workloads import workload_suite

from .stress import ZIPF_S, _tiles_request, _zipf_probs

#: pre-refactor burst throughput on the reference host (admissions/s);
#: the acceptance bar this benchmark must beat
STORED_BASELINE_ADMISSIONS_PER_S = 10.716


def _never_regressed(events) -> bool:
    """Each rebalance's chip throughput vs. the chip just before it."""
    ok, prev_thr = True, None
    for e in events:
        if e.chip_throughput and e.chip_throughput > 0:
            if (
                e.kind == "rebalance"
                and prev_thr is not None
                and prev_thr > 0
                and e.chip_throughput < prev_thr * (1 - 1e-6)
            ):
                ok = False
            prev_thr = e.chip_throughput
        elif e.kind in ("admit", "evict", "finish"):
            prev_thr = e.chip_throughput or None
    return ok


def _make_controller(hw, joint_budget, mesh=None):
    return AdmissionController(
        hw,
        placement="joint",
        joint_budget=joint_budget,
        full_rebalance_every=0,
        mesh=mesh,
    )


def _event_stream(names, n_events, seed):
    """The deterministic Zipf churn (shared with benchmarks.stress)."""
    rng = np.random.default_rng(seed + 1)
    probs = _zipf_probs(len(names))
    return [names[int(rng.choice(len(names), p=probs))]
            for _ in range(n_events)]


def _run_baseline(ctl, stream, requests):
    """Per-event rebalancing: the stress-harness event loop."""
    admits = evicts = rejects = 0
    residents = []
    t0 = time.perf_counter()
    for name in stream:
        if name in ctl.state.allocated:
            ctl.evict(name)
            evicts += 1
        else:
            try:
                ctl.admit(name, n_tiles_request=requests[name])
                admits += 1
            except AdmissionError:
                rejects += 1
        residents.append(len(ctl.state.allocated))
    loop_s = time.perf_counter() - t0
    return {
        "events": len(stream),
        "admits": admits,
        "evicts": evicts,
        "rejects": rejects,
        "event_loop_s": round(loop_s, 2),
        "admissions_per_s": (
            round(admits / loop_s, 3) if loop_s > 0 else 0.0
        ),
        "never_regressed": _never_regressed(ctl.events),
        "max_residents": max(residents, default=0),
    }


def _run_burst(ctl, stream, requests, *, coalesce_window):
    """Submit everything up front, drain with coalesced rebalances."""
    q = ServingQueue(ctl, coalesce_window=coalesce_window)
    submitted_admits = submitted_evicts = 0
    resident = set()
    for name in stream:
        # mirror the baseline's admit-if-absent / evict-if-resident
        # policy over the QUEUED (not yet applied) trajectory
        if name in resident:
            q.submit_evict(name)
            resident.discard(name)
            submitted_evicts += 1
        else:
            q.submit_admit(name, n_tiles_request=requests[name])
            resident.add(name)
            submitted_admits += 1
    t0 = time.perf_counter()
    service = q.drain()
    loop_s = time.perf_counter() - t0
    admits = service["admitted"]
    return {
        "events": len(stream),
        "submitted_admits": submitted_admits,
        "submitted_evicts": submitted_evicts,
        "coalesce_window": coalesce_window,
        "event_loop_s": round(loop_s, 2),
        "admissions_per_s": (
            round(admits / loop_s, 3) if loop_s > 0 else 0.0
        ),
        "drained": q.pending == 0,
        "never_regressed": _never_regressed(ctl.events),
        "max_residents": max(
            (len(ctl.state.allocated),), default=0
        ),
        "service": service,
    }


def _build_workload(smoke, n_tenants, n_events, scale, joint_budget, seed):
    """Shared deterministic setup: hardware, tenants, churn, design cache."""
    if smoke:
        hw = dataclasses.replace(DYNAP_SE, n_tiles=64)
        n_tenants, n_events = 12, 36
    else:
        hw = DYNAP_SE_1024
    tenants = workload_suite(n_tenants, seed=seed, scale=scale)
    names = [s.name for s in tenants]
    stream = _event_stream(names, n_events, seed)
    requests = {}
    design_ctl = _make_controller(hw, joint_budget)
    for snn in tenants:
        art = design_ctl.register(snn)
        requests[snn.name] = _tiles_request(art.clustered.n_clusters)
    return hw, tenants, stream, requests, design_ctl, n_tenants, n_events


def _precompile_bench(
    hw, tenants, stream, requests, *,
    joint_budget, coalesce_window, waves=4,
):
    """Speculative pre-compilation over a COLD controller.

    The same churn drained in ``waves`` batches: each drain first warms
    the :class:`PrecompilePool`'s frequency-decayed predictions (design
    artifacts + scoring shape buckets), so admissions of recurring
    tenants find their design work already done.  Reports the pool's
    hit/miss accounting — ``hit_rate`` is the cache-warm-hit-rate stat
    of the device-scaling section.
    """
    ctl = _make_controller(hw, joint_budget)
    pool = PrecompilePool(
        ctl, source={s.name: s for s in tenants},
        top_k=max(4, len(tenants) // 8),
    )
    q = ServingQueue(ctl, coalesce_window=coalesce_window, precompile=pool)
    resident: set = set()
    per_wave = max(1, math.ceil(len(stream) / waves))
    t0 = time.perf_counter()
    for w in range(0, len(stream), per_wave):
        for name in stream[w:w + per_wave]:
            if name in resident:
                q.submit_evict(name)
                resident.discard(name)
            else:
                q.submit_admit(name, n_tiles_request=requests[name])
                resident.add(name)
        q.drain()
    loop_s = time.perf_counter() - t0
    return {
        "waves": int(math.ceil(len(stream) / per_wave)),
        "event_loop_s": round(loop_s, 2),
        "drained": q.pending == 0,
        **pool.stats(),
    }


def serving_bench(
    *,
    smoke: bool = False,
    n_tenants: int = 224,
    n_events: int = 640,
    scale: float = 0.06,
    joint_budget: tuple[int, int] = (1, 6),
    coalesce_window: int = 16,
    seed: int = 0,
    devices: int = 0,
):
    """Run both modes over the same churn; return ``(rows, payload, ok)``."""
    t0 = time.perf_counter()
    hw, tenants, stream, requests, design_ctl, n_tenants, n_events = (
        _build_workload(smoke, n_tenants, n_events, scale, joint_budget, seed)
    )
    design_wall_s = time.perf_counter() - t0

    # baseline: fresh controller, per-event rebalancing
    base_ctl = _make_controller(hw, joint_budget)
    base_ctl.artifacts = design_ctl.artifacts   # share the design cache
    baseline = _run_baseline(base_ctl, stream, requests)

    # burst: fresh controller, coalesced rebalancing
    burst_ctl = _make_controller(hw, joint_budget)
    burst_ctl.artifacts = design_ctl.artifacts
    burst = _run_burst(
        burst_ctl, stream, requests, coalesce_window=coalesce_window
    )

    # speculative pre-compilation: cold controller, wave-drained churn
    precompile = _precompile_bench(
        hw, tenants, stream, requests,
        joint_budget=joint_budget, coalesce_window=coalesce_window,
    )

    # device-scaling sweep: one subprocess per forced host-device count
    device_scaling = None
    if devices > 0:
        device_scaling = _device_sweep(
            devices, smoke=smoke, n_tenants=n_tenants, n_events=n_events,
            scale=scale, joint_budget=joint_budget,
            coalesce_window=coalesce_window, seed=seed,
        )
        device_scaling["cache_warm_hit_rate"] = precompile["hit_rate"]

    speedup = (
        burst["admissions_per_s"] / baseline["admissions_per_s"]
        if baseline["admissions_per_s"] > 0 else 0.0
    )
    beats_stored = (
        smoke
        or burst["admissions_per_s"] > STORED_BASELINE_ADMISSIONS_PER_S
    )
    ok = (
        baseline["never_regressed"]
        and burst["never_regressed"]
        and burst["drained"]
        and beats_stored
        and precompile["drained"]
        and (device_scaling is None or device_scaling["sweep_ok"])
    )
    summary = {
        "mesh": list(hw.mesh_shape),
        "n_tiles": hw.n_tiles,
        "n_tenants": n_tenants,
        "n_events": n_events,
        "tenant_scale": scale,
        "zipf_s": ZIPF_S,
        "joint_budget": list(joint_budget),
        "coalesce_window": coalesce_window,
        "design_wall_s": round(design_wall_s, 2),
        "baseline": baseline,
        "burst": burst,
        "precompile": precompile,
        "speedup_burst_vs_baseline": round(speedup, 3),
        "stored_baseline_admissions_per_s": STORED_BASELINE_ADMISSIONS_PER_S,
        "beats_stored_baseline": beats_stored,
        "ok": ok,
    }
    if device_scaling is not None:
        summary["device_scaling"] = device_scaling
    rows = [
        ("mode", "events", "admits", "event_loop_s", "admissions_per_s",
         "never_regressed"),
        ("baseline", n_events, baseline["admits"],
         baseline["event_loop_s"], baseline["admissions_per_s"],
         baseline["never_regressed"]),
        ("burst", n_events, burst["service"]["admitted"],
         burst["event_loop_s"], burst["admissions_per_s"],
         burst["never_regressed"]),
    ]
    if device_scaling is not None:
        for d, aps in zip(device_scaling["device_counts"],
                          device_scaling["admissions_per_s"]):
            rows.append((f"burst@{d}dev", n_events, "-", "-", aps, "-"))
    return rows, summary, ok


def _device_counts(n: int) -> list[int]:
    """1 plus powers of two up to ``n`` (always ending at ``n``)."""
    return sorted({1} | {d for d in (2, 4, 8, 16) if d <= n} | {int(n)})


def _device_arm(
    d: int, *, smoke, n_tenants, n_events, scale,
    joint_budget, coalesce_window, seed,
) -> dict:
    """One sweep arm — runs INSIDE the forced-device-count subprocess.

    Re-derives the identical workload (same seed), shares the design
    cache, and drains the burst with a ``host_mesh(d)`` scoring mesh on
    the controller; ``d == 1`` runs unsharded in the same forced-device
    environment so every arm pays identical interpreter overheads.
    """
    import jax

    from repro.launch.sharding import host_mesh, mesh_devices

    hw, tenants, stream, requests, design_ctl, n_tenants, n_events = (
        _build_workload(smoke, n_tenants, n_events, scale, joint_budget, seed)
    )
    mesh = host_mesh(d) if d > 1 else None
    ctl = _make_controller(hw, joint_budget, mesh=mesh)
    ctl.artifacts = design_ctl.artifacts
    burst = _run_burst(
        ctl, stream, requests, coalesce_window=coalesce_window
    )
    return {
        "devices_requested": d,
        "devices_visible": len(jax.devices()),
        "mesh_devices": len(mesh_devices(mesh)) if mesh is not None else 1,
        "admissions_per_s": burst["admissions_per_s"],
        "event_loop_s": burst["event_loop_s"],
        "admitted": burst["service"]["admitted"],
        "drained": burst["drained"],
        "never_regressed": burst["never_regressed"],
    }


def _device_sweep(
    n_devices: int, *, smoke, n_tenants, n_events, scale,
    joint_budget, coalesce_window, seed,
) -> dict:
    """Admissions/s vs forced host-device count, one subprocess per arm.

    ``XLA_FLAGS=--xla_force_host_platform_device_count=d`` must be set
    before jax imports, so each arm is a fresh ``benchmarks.serving
    --arm d`` subprocess printing its result on a ``##ARM`` stdout line.
    """
    counts = _device_counts(n_devices)
    arms = []
    for d in counts:
        env = os.environ.copy()
        env["XLA_FLAGS"] = (
            env.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={d}"
        ).strip()
        cmd = [
            sys.executable, "-m", "benchmarks.serving", "--arm", str(d),
            "--tenants", str(n_tenants), "--events", str(n_events),
            "--scale", str(scale), "--window", str(coalesce_window),
            "--seed", str(seed),
        ]
        if smoke:
            cmd.append("--smoke")
        proc = subprocess.run(cmd, capture_output=True, text=True, env=env)
        arm = None
        for line in proc.stdout.splitlines():
            if line.startswith("##ARM "):
                arm = json.loads(line[len("##ARM "):])
        if proc.returncode != 0 or arm is None:
            arm = {
                "devices_requested": d,
                "error": (proc.stderr or "no ##ARM output").strip()[-2000:],
                "admissions_per_s": 0.0,
                "drained": False,
                "never_regressed": False,
            }
        arms.append(arm)
    aps = [float(a.get("admissions_per_s", 0.0)) for a in arms]
    base = aps[0] if aps and aps[0] > 0 else 0.0
    # 5% tolerance absorbs wall-clock noise on shared CI hosts
    monotonic = all(b >= a * 0.95 for a, b in zip(aps, aps[1:]))
    speedup = round(aps[-1] / base, 3) if base else 0.0
    return {
        "device_counts": counts,
        "admissions_per_s": aps,
        "monotonic_nondecreasing": monotonic,
        "speedup_at_max_devices": speedup,
        "target_speedup": 1.5,
        "target_met": bool(base and speedup >= 1.5),
        "sweep_ok": all(
            a.get("drained") and a.get("never_regressed") for a in arms
        ),
        "arms": arms,
    }


def run(out_path: str = "BENCH_serving.json", *, smoke: bool = False,
        **kw):
    rows, summary, ok = serving_bench(smoke=smoke, **kw)
    from .common import write_bench
    write_bench(out_path, {"serving_bench": summary})
    return rows, summary, ok


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_serving.json")
    ap.add_argument("--smoke", action="store_true",
                    help="12 tenants on an 8x8 mesh (CI tier-1)")
    ap.add_argument("--tenants", type=int, default=224)
    ap.add_argument("--events", type=int, default=640)
    ap.add_argument("--scale", type=float, default=0.06)
    ap.add_argument("--window", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--devices", type=int, default=0,
                    help="device-scaling sweep up to N forced host devices")
    ap.add_argument("--arm", type=int, default=0, help=argparse.SUPPRESS)
    args = ap.parse_args()
    if args.arm:
        arm = _device_arm(
            args.arm, smoke=args.smoke, n_tenants=args.tenants,
            n_events=args.events, scale=args.scale, joint_budget=(1, 6),
            coalesce_window=args.window, seed=args.seed,
        )
        print("##ARM " + json.dumps(arm))
        raise SystemExit(0)
    rows, summary, ok = run(
        args.out, smoke=args.smoke, n_tenants=args.tenants,
        n_events=args.events, scale=args.scale,
        coalesce_window=args.window, seed=args.seed,
        devices=args.devices,
    )
    for row in rows:
        print(",".join(str(x) for x in row))
    print("##", json.dumps(summary))
    print("OK" if ok else "FAILED")
    raise SystemExit(0 if ok else 1)


if __name__ == "__main__":
    main()

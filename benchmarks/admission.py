"""Run-time admission benchmark (paper §5, Table 3 — made multi-tenant).

  PYTHONPATH=src python -m benchmarks.admission            # standalone
  PYTHONPATH=src python -m benchmarks.run admission        # via the runner

Two sections, both recorded into ``BENCH_admission.json``:

  1. *Trajectory* — an :class:`AdmissionController` serving app churn on a
     16-tile chip: register apps once (design time), then rounds of
     admit / finish / evict / re-admit.  Reports admissions/sec; the full
     event trajectory goes into the JSON file.
  2. *Speedup* — one admission decision scoring ``>= 16`` candidate
     bindings: the batched engine (one EdgeStack + ``mcr_batch``) vs the
     serial per-candidate heapq ``SelfTimedExecutor`` replay loop the
     engine replaces.  Acceptance target: >= 3x.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import time

import numpy as np

from repro.core import (
    AdmissionController,
    AdmissionError,
    DYNAP_SE,
    SelfTimedExecutor,
    batch_execute,
    bind_ours,
    partition_greedy,
    project_order,
    sdfg_from_clusters,
    single_tile_order,
    small_app,
)

HW16 = dataclasses.replace(DYNAP_SE, n_tiles=16)


# ======================================================================
# section 1: multi-app admission trajectory
# ======================================================================
def trajectory_bench(n_apps: int = 6, rounds: int = 4, seed: int = 0):
    """Churn ``n_apps`` tenants through admit/finish/evict for ``rounds``."""
    rng = np.random.default_rng(seed)
    ctl = AdmissionController(HW16)

    t_design0 = time.perf_counter()
    names = []
    for i in range(n_apps):
        snn = small_app(
            int(rng.integers(140, 260)), int(rng.integers(1500, 3000)),
            seed=100 + i,
        )
        snn.name = f"app{i}"
        ctl.register(snn)
        names.append(snn.name)
    t_design = time.perf_counter() - t_design0

    n_admits = 0
    t_admit = 0.0
    for r in range(rounds):
        for name in names:
            req = int(rng.integers(1, 5))
            t0 = time.perf_counter()
            try:
                ctl.admit(name, n_tiles_request=req)
                n_admits += 1
            except AdmissionError:
                pass
            t_admit += time.perf_counter() - t0
        # churn: finish half, evict a quarter, keep the rest running
        running = list(ctl.running())
        rng.shuffle(running)
        for name in running[: len(running) // 2]:
            ctl.finish(name)
        for name in running[len(running) // 2 : (3 * len(running)) // 4]:
            ctl.evict(name)
    for name in list(ctl.running()):
        ctl.finish(name)

    admissions_per_sec = n_admits / max(t_admit, 1e-12)
    rows = [
        ("metric", "value"),
        ("apps", n_apps),
        ("rounds", rounds),
        ("admissions", n_admits),
        ("rejections", sum(1 for e in ctl.events if e.kind == "reject")),
        ("evictions", sum(1 for e in ctl.events if e.kind == "evict")),
        ("design_time_s", f"{t_design:.3f}"),
        ("admit_time_s", f"{t_admit:.3f}"),
        ("admissions_per_sec", f"{admissions_per_sec:.1f}"),
    ]
    payload = {
        "n_apps": n_apps,
        "rounds": rounds,
        "n_admissions": n_admits,
        "design_time_s": t_design,
        "admit_time_s": t_admit,
        "admissions_per_sec": admissions_per_sec,
        "trajectory": ctl.trajectory(),
    }
    return rows, payload


# ======================================================================
# section 2: batched engine vs serial heapq scoring of one admission
# ======================================================================
def speedup_bench(n_candidates: int = 16, seed: int = 0,
                  sim_iterations: int = 30):
    """Score ``n_candidates`` free-tile bindings: engine vs heapq loop."""
    rng = np.random.default_rng(seed)
    snn = small_app(1500, 40_000, seed=7)
    snn.name = "score-me"
    cl = partition_greedy(snn, HW16)
    app = sdfg_from_clusters(cl, hw=HW16)
    order, _ = single_tile_order(cl, HW16)

    bindings = [bind_ours(cl, HW16).binding]
    while len(bindings) < n_candidates:
        bindings.append(rng.integers(0, HW16.n_tiles, size=cl.n_clusters))
    orders_list = [
        project_order(order, b, HW16.n_tiles) for b in bindings
    ]

    t0 = time.perf_counter()
    rep = batch_execute(app, np.array(bindings), HW16, orders_list,
                        backend="edges")
    t_batched = time.perf_counter() - t0

    t0 = time.perf_counter()
    serial = np.array([
        SelfTimedExecutor(app, b, HW16, orders=o)
        .run(iterations=sim_iterations).period
        for b, o in zip(bindings, orders_list)
    ])
    t_serial = time.perf_counter() - t0

    # fidelity: heapq period amortizes the pipeline-fill transient over the
    # run, so compare loosely; the engine value is the exact steady state
    ok_rows = serial > 0
    rel = np.abs(rep.periods[ok_rows] - serial[ok_rows]) / serial[ok_rows]
    speedup = t_serial / max(t_batched, 1e-12)
    rows = [
        ("metric", "value"),
        ("candidates", len(bindings)),
        ("actors", app.n_actors),
        ("t_batched_s", f"{t_batched:.4f}"),
        ("t_heapq_serial_s", f"{t_serial:.4f}"),
        ("speedup", f"{speedup:.1f}x"),
        ("max_rel_dev_vs_heapq", f"{rel.max():.2e}"),
        ("best_candidate", int(np.argmin(np.where(
            rep.periods > 0, rep.periods, np.inf)))),
    ]
    payload = {
        "n_candidates": len(bindings),
        "t_batched_s": t_batched,
        "t_heapq_serial_s": t_serial,
        "speedup_batched_vs_heapq": speedup,
        "max_rel_dev_vs_heapq": float(rel.max()),
        "periods_batched": rep.periods.tolist(),
        "periods_heapq": serial.tolist(),
    }
    ok = speedup >= 3.0
    return rows, payload, ok


# ======================================================================
def run(out_path: str = "BENCH_admission.json", *, n_apps: int = 6,
        rounds: int = 4, n_candidates: int = 16):
    """Run both sections and write the trajectory file.

    Returns ``(rows, summary, ok)`` in the benchmarks/run.py convention.
    """
    t_rows, t_payload = trajectory_bench(n_apps=n_apps, rounds=rounds)
    s_rows, s_payload, ok = speedup_bench(n_candidates=n_candidates)
    from .common import write_bench
    write_bench(out_path,
                {"trajectory_bench": t_payload, "speedup_bench": s_payload})
    rows = t_rows + [("--", "--")] + s_rows
    summary = (
        f"{t_payload['n_admissions']} admissions at "
        f"{t_payload['admissions_per_sec']:.1f}/s; batched scoring of "
        f"{s_payload['n_candidates']} candidates "
        f"{s_payload['speedup_batched_vs_heapq']:.1f}x vs heapq loop "
        f"(target >= 3x: {'PASS' if ok else 'MISS'}); wrote {out_path}"
    )
    return rows, summary, ok


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_admission.json")
    ap.add_argument("--apps", type=int, default=6)
    ap.add_argument("--rounds", type=int, default=4)
    ap.add_argument("--candidates", type=int, default=16)
    args = ap.parse_args()

    if args.candidates < 16:
        ap.error("--candidates must be >= 16 (the acceptance target scores "
                 "at least 16 bindings)")
    rows, summary, ok = run(
        args.out, n_apps=args.apps, rounds=args.rounds,
        n_candidates=args.candidates,
    )
    print("# admission")
    for row in rows:
        print(",".join(str(x) for x in row))
    print("##", summary)
    if not ok:
        raise SystemExit(1)


if __name__ == "__main__":
    main()

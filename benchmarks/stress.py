"""Chip-scale stress harness: Zipf tenant churn on a 32x32 mesh.

  PYTHONPATH=src python -m benchmarks.stress              # 1024-tile run
  PYTHONPATH=src python -m benchmarks.stress --smoke      # 12 tenants, 8x8
  PYTHONPATH=src python -m benchmarks.run stress          # via the runner

Open-loop arrival/departure churn of synthetic Table-1-fit tenants
(:mod:`repro.core.workloads`) against a joint-placement
:class:`~repro.core.runtime.AdmissionController` with region-scoped
incremental rebalancing: each event draws a tenant from a Zipf popularity
distribution and admits it when absent, evicts it when resident — hot
tenants cycle, the tail accumulates residents.  Recorded into
``BENCH_stress.json``:

  * sustained admissions/s over the event loop;
  * p50/p99 per-event joint-placement (rebalance) latency — region-scoped
    rebalances keep this bounded by the REGION size, not the resident
    count;
  * the never-regress check: every rebalance's chip throughput vs. the
    chip state just before it (the seeding invariant, per event);
  * throughput retention vs. FULL re-optimization at checkpoints: the
    event loop runs pure region-scoped, then a full-union re-optimization
    is forced outside the timed loop and the before/after chip throughput
    ratio is recorded (1.0 = region placement had lost nothing).

Acceptance (full run): >= 64 concurrent residents on the 32x32 mesh,
per-event joint-placement p99 < 1 s, no rebalance ever regresses chip
throughput, checkpoint retention >= 0.95.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import time

import numpy as np

from repro.core import (
    DYNAP_SE,
    DYNAP_SE_1024,
    AdmissionController,
    AdmissionError,
)
from repro.core.workloads import workload_suite

#: Zipf popularity exponent of the tenant draw (p ~ rank^-ZIPF_S).
ZIPF_S = 1.1


def _zipf_probs(n: int, s: float = ZIPF_S) -> np.ndarray:
    r = np.arange(1, n + 1, dtype=np.float64) ** -s
    return r / r.sum()


def _tiles_request(n_clusters: int) -> int:
    """Small per-tenant footprint so hundreds of tenants fit the mesh."""
    return max(1, min(4, n_clusters))


def _percentiles(xs: list[float]) -> tuple[float, float]:
    if not xs:
        return 0.0, 0.0
    arr = np.asarray(xs)
    return float(np.percentile(arr, 50)), float(np.percentile(arr, 99))


def stress_bench(
    *,
    smoke: bool = False,
    n_tenants: int = 224,
    n_events: int = 640,
    scale: float = 0.06,
    joint_budget: tuple[int, int] = (1, 6),
    n_checkpoints: int = 2,
    seed: int = 0,
):
    """Run the churn and return ``(rows, payload, ok)``.

    ``--smoke`` shrinks to 12 tenants / 24 events on an 8x8 (64-tile)
    mesh — the CI tier-1 configuration.
    """
    if smoke:
        hw = dataclasses.replace(DYNAP_SE, n_tiles=64)
        n_tenants, n_events, n_checkpoints = 12, 36, 1
    else:
        hw = DYNAP_SE_1024
    mesh = hw.mesh_shape

    t0 = time.perf_counter()
    tenants = workload_suite(n_tenants, seed=seed, scale=scale)
    ctl = AdmissionController(
        hw,
        placement="joint",
        joint_budget=joint_budget,
        # the bench forces full re-optimizations at explicit checkpoints
        # OUTSIDE the timed loop; per-event latency stays region-scoped
        full_rebalance_every=0,
    )
    requests = {}
    for snn in tenants:
        art = ctl.register(snn)
        requests[snn.name] = _tiles_request(art.clustered.n_clusters)
    design_wall_s = time.perf_counter() - t0

    rng = np.random.default_rng(seed + 1)
    probs = _zipf_probs(n_tenants)
    names = [s.name for s in tenants]

    rows = [(
        "event", "kind", "tenant", "residents", "wall_s",
        "rebalance_wall_s", "rebalance_scope", "region_apps",
        "chip_throughput",
    )]
    admits = evicts = rejects = 0
    residents_track: list[int] = []
    event_loop_t0 = time.perf_counter()
    for ev in range(n_events):
        name = names[int(rng.choice(n_tenants, p=probs))]
        n_before = len(ctl.events)
        t_ev = time.perf_counter()
        if name in ctl.state.allocated:
            ctl.evict(name)
            kind = "evict"
            evicts += 1
        else:
            try:
                ctl.admit(name, n_tiles_request=requests[name])
                kind = "admit"
                admits += 1
            except AdmissionError:
                kind = "reject"
                rejects += 1
        wall = time.perf_counter() - t_ev
        new_events = ctl.events[n_before:]
        reb = [e for e in new_events if e.kind == "rebalance"]
        chip_thr = new_events[-1].chip_throughput if new_events else 0.0
        residents_track.append(len(ctl.state.allocated))
        rows.append((
            ev, kind, name, len(ctl.state.allocated), round(wall, 4),
            round(reb[-1].wall_s, 4) if reb else 0.0,
            reb[-1].scope if reb else "",
            reb[-1].region_apps if reb else 0,
            chip_thr,
        ))
    event_loop_s = time.perf_counter() - event_loop_t0
    n_loop_events = len(ctl.events)   # checkpoint rebalances come after

    # -- never-regress: each rebalance vs. the chip state just before it
    never_regressed = True
    prev_thr = None
    for e in ctl.events:
        if e.chip_throughput > 0:
            if (
                e.kind == "rebalance"
                and prev_thr is not None
                and prev_thr > 0
                and e.chip_throughput < prev_thr * (1 - 1e-6)
            ):
                never_regressed = False
            prev_thr = e.chip_throughput
        elif e.kind in ("admit", "evict", "finish"):
            prev_thr = e.chip_throughput or None

    # -- retention checkpoints: force a FULL re-optimization and compare
    retention: list[float] = []
    for _ in range(max(n_checkpoints, 0)):
        if len(ctl.state.allocated) < 2:
            break
        before = ctl.chip_metrics()
        t_full = time.perf_counter()
        ctl._rebalance_full()
        full_wall = time.perf_counter() - t_full
        after = ctl.chip_metrics()
        if before and after and after["chip_throughput"] > 0:
            retention.append(
                before["chip_throughput"] / after["chip_throughput"]
            )
        rows.append((
            "checkpoint", "full_rebalance", "*",
            len(ctl.state.allocated), round(full_wall, 4),
            round(full_wall, 4), "full", len(ctl.state.allocated),
            after["chip_throughput"] if after else 0.0,
        ))

    # latency stats cover every rebalance the EVENT LOOP ran (region and
    # full-fallback alike) — checkpoint fulls happen outside the loop
    reb_events = [
        e for e in ctl.events[:n_loop_events] if e.kind == "rebalance"
    ]
    region_walls = [e.wall_s for e in reb_events if e.scope == "region"]
    event_walls = [e.wall_s for e in reb_events] or [0.0]
    p50, p99 = _percentiles(event_walls)
    r50, r99 = _percentiles(region_walls)
    max_res = max(residents_track, default=0)
    retention_min = min(retention, default=1.0)

    min_residents = 64 if not smoke else 6
    ok = (
        max_res >= min_residents
        and p99 < 1.0
        and never_regressed
        and retention_min >= 0.95
    )
    summary = {
        "mesh": list(mesh),
        "n_tiles": hw.n_tiles,
        "n_tenants": n_tenants,
        "n_events": n_events,
        "tenant_scale": scale,
        "zipf_s": ZIPF_S,
        "joint_budget": list(joint_budget),
        "design_wall_s": round(design_wall_s, 2),
        "event_loop_s": round(event_loop_s, 2),
        "admits": admits,
        "evicts": evicts,
        "rejects": rejects,
        "admissions_per_s": (
            round(admits / event_loop_s, 3) if event_loop_s > 0 else 0.0
        ),
        "max_residents": max_res,
        "mean_residents": round(float(np.mean(residents_track)), 1),
        "rebalances_region": sum(
            1 for e in reb_events if e.scope == "region"
        ),
        "rebalances_full": sum(1 for e in reb_events if e.scope == "full"),
        "event_rebalance_p50_s": round(p50, 4),
        "event_rebalance_p99_s": round(p99, 4),
        "region_rebalance_p50_s": round(r50, 4),
        "region_rebalance_p99_s": round(r99, 4),
        "never_regressed": never_regressed,
        "retention_vs_full": [round(r, 4) for r in retention],
        "retention_min": round(retention_min, 4),
        "ok": ok,
    }
    return rows, summary, ok


def run(out_path: str = "BENCH_stress.json", *, smoke: bool = False,
        **kw):
    rows, summary, ok = stress_bench(smoke=smoke, **kw)
    from .common import write_bench
    write_bench(out_path, {"stress_bench": summary})
    return rows, summary, ok


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_stress.json")
    ap.add_argument("--smoke", action="store_true",
                    help="12 tenants on an 8x8 mesh (CI tier-1)")
    ap.add_argument("--tenants", type=int, default=224)
    ap.add_argument("--events", type=int, default=640)
    ap.add_argument("--scale", type=float, default=0.06)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    rows, summary, ok = run(
        args.out, smoke=args.smoke, n_tenants=args.tenants,
        n_events=args.events, scale=args.scale, seed=args.seed,
    )
    for row in rows:
        print(",".join(str(x) for x in row))
    print("##", json.dumps(summary))
    print("OK" if ok else "FAILED")
    raise SystemExit(0 if ok else 1)


if __name__ == "__main__":
    main()

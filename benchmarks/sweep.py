"""Design-space sweep benchmark: batched Max-Plus analysis vs the
per-graph Python loop, across the eight Table-1 applications.

  PYTHONPATH=src python -m benchmarks.sweep            # full (all 8 apps)
  PYTHONPATH=src python -m benchmarks.sweep --quick    # 3 small apps

Two sections:

  1. *Fidelity* — full factorial sweep (apps x tile counts x binders);
     batched throughputs are checked against per-graph ``mcr_howard`` and
     must agree within 1e-6 relative.
  2. *Speedup* — a >= 32-candidate binding sweep of one app (shared graph
     topology, the admission-scoring shape); wall-clock of one batched
     ``mcr_batch`` call vs looping ``mcr_binary_search`` per graph (the
     same lambda-search algorithm, un-batched).  Target: >= 5x.
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import numpy as np

from repro.core import (
    DYNAP_SE,
    APP_NAMES,
    analyze_candidates,
    build_app,
    build_candidates,
    build_static_orders,
    mcr_howard,
    partition_greedy,
    sdfg_from_clusters,
)
from repro.core.binding import bind_ours, bind_pycarl, bind_spinemap
from repro.core.maxplus import mcr_batch, mcr_binary_search, stack_graphs
from repro.core.sdfg import hardware_aware_sdfg

QUICK_APPS = ("ImgSmooth", "MLP-MNIST", "CNN-MNIST")


# ======================================================================
def fidelity_sweep(apps, tile_counts=(4, 9, 16), binders=("ours", "spinemap", "pycarl")):
    """Factorial sweep; batched analysis must match per-graph Howard."""
    metas, graphs, t_build, _ = build_candidates(
        apps, tile_counts=tile_counts, binders=binders
    )
    t0 = time.perf_counter()
    thr_batched = analyze_candidates(graphs, method="batched")
    t_batched = time.perf_counter() - t0

    t0 = time.perf_counter()
    rhos = np.array([mcr_howard(g) for g in graphs])
    t_howard = time.perf_counter() - t0
    thr_howard = np.where(rhos > 0, 1.0 / np.maximum(rhos, 1e-300), 0.0)

    rel_err = np.abs(thr_batched - thr_howard) / np.maximum(np.abs(thr_howard), 1e-300)
    rows = [("app", "crossbar", "tiles", "binder", "thr_batched", "thr_howard",
             "rel_err")]
    for p, tb, th, re_ in zip(metas, thr_batched, thr_howard, rel_err):
        rows.append((p.app, p.crossbar, p.n_tiles, p.binder,
                     f"{tb:.6e}", f"{th:.6e}", f"{re_:.2e}"))
    ok = bool(np.all(rel_err <= 1e-6))
    summary = (
        f"candidates={len(graphs)} build={t_build:.2f}s "
        f"batched={t_batched:.3f}s howard_loop={t_howard:.3f}s "
        f"max_rel_err={rel_err.max():.2e} within_1e-6={ok}"
    )
    return rows, summary, ok


# ======================================================================
def speedup_sweep(app_name: str = "MLP-MNIST", n_candidates: int = 48,
                  n_tiles: int = 16, seed: int = 0):
    """>= 32 candidate bindings of one app, batched vs per-graph loop.

    The candidate set mimics admission scoring: the three binder outputs
    plus random bindings, all over the same application graph (shared
    topology, differing NoC delays and TDMA order edges).
    """
    hw = dataclasses.replace(DYNAP_SE, n_tiles=n_tiles)
    snn = build_app(app_name)
    cl = partition_greedy(snn, hw)
    app = sdfg_from_clusters(cl, hw=hw)

    bindings = [b(cl, hw).binding for b in (bind_ours, bind_spinemap, bind_pycarl)]
    rng = np.random.default_rng(seed)
    while len(bindings) < n_candidates:
        bindings.append(rng.integers(0, n_tiles, size=cl.n_clusters))
    graphs = []
    for binding in bindings:
        orders, _ = build_static_orders(app, binding, hw, iterations=8)
        graphs.append(hardware_aware_sdfg(app, binding, hw, orders))

    stack = stack_graphs(graphs)
    t0 = time.perf_counter()
    rhos_b = mcr_batch(stack, backend="edges")
    t_batched = time.perf_counter() - t0

    t0 = time.perf_counter()
    rhos_loop = np.array([mcr_binary_search(g, tol=1e-6) for g in graphs])
    t_loop = time.perf_counter() - t0

    t0 = time.perf_counter()
    rhos_h = np.array([mcr_howard(g) for g in graphs])
    t_howard = time.perf_counter() - t0

    rel_err = np.abs(rhos_b - rhos_h) / np.abs(rhos_h)
    speedup = t_loop / max(t_batched, 1e-12)
    rows = [
        ("metric", "value"),
        ("app", app_name),
        ("candidates", len(graphs)),
        ("actors", app.n_actors),
        ("edges_padded", stack.n_edges),
        ("t_batched_s", f"{t_batched:.3f}"),
        ("t_pergraph_loop_s", f"{t_loop:.3f}"),
        ("t_howard_loop_s", f"{t_howard:.3f}"),
        ("speedup_vs_loop", f"{speedup:.1f}x"),
        ("max_rel_err_vs_howard", f"{rel_err.max():.2e}"),
    ]
    ok = speedup >= 5.0
    summary = (
        f"{len(graphs)} candidates: batched {t_batched:.3f}s vs per-graph "
        f"loop {t_loop:.3f}s -> {speedup:.1f}x (target >= 5x: "
        f"{'PASS' if ok else 'MISS'}); howard loop {t_howard:.3f}s; "
        f"max rel err vs howard {rel_err.max():.2e}"
    )
    return rows, summary, ok


# ======================================================================
def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="3 small apps + smaller speedup sweep")
    ap.add_argument("--app", default="MLP-MNIST",
                    help="application for the speedup section")
    ap.add_argument("--candidates", type=int, default=48)
    args = ap.parse_args()

    apps = QUICK_APPS if args.quick else APP_NAMES
    print(f"# fidelity_sweep ({len(apps)} apps)")
    rows, summary, ok_fid = fidelity_sweep(apps)
    for row in rows:
        print(",".join(str(x) for x in row))
    print("##", summary)

    print("\n# speedup_sweep")
    rows, summary, ok_speed = speedup_sweep(
        args.app, n_candidates=max(32, args.candidates)
    )
    for row in rows:
        print(",".join(str(x) for x in row))
    print("##", summary)

    if not (ok_fid and ok_speed):
        raise SystemExit(1)


if __name__ == "__main__":
    main()

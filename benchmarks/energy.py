"""Chip-level objective benchmark: energy model + multi-app joint placement.

  PYTHONPATH=src python -m benchmarks.energy              # all 8 apps
  PYTHONPATH=src python -m benchmarks.energy --smoke      # CI-sized run
  PYTHONPATH=src python -m benchmarks.run energy          # via the runner

Two sections, both recorded into ``BENCH_energy.json``:

  1. *Isolated vs joint churn* — the same deterministic admission churn
     (admit / finish / evict rounds on a 16-tile chip) served twice by an
     :class:`~repro.core.runtime.AdmissionController`: once with
     ``placement="isolated"`` (each admission optimized alone, the PR-2
     behaviour) and once with ``placement="joint"`` (every admit/evict
     re-optimizes ALL resident bindings as one union EdgeStack).  After
     every operation the chip steady state (union period, chip energy) is
     snapshotted; acceptance: joint strictly improves mean chip
     throughput OR mean chip energy — it can never be worse on the scored
     objective, because the isolated placement seeds each rebalance.
  2. *Pareto front per app* — ``optimize_binding(objective="pareto")`` on
     every Table-1 application: the exact (period, energy) front, plus
     the structural check that the front's best period is never worse
     than the heuristic seeds'.
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.core import (
    APP_NAMES,
    DYNAP_SE,
    AdmissionController,
    AdmissionError,
    HardwareConfig,
    build_app,
    optimize_binding,
    partition_greedy,
    small_app,
)
import dataclasses

HW16 = dataclasses.replace(DYNAP_SE, n_tiles=16)
SMOKE_APPS = 3          # synthetic small apps for --smoke


def _churn_apps(smoke: bool, n_apps: int):
    """The tenant set: Table-1 apps, or small synthetic ones for --smoke."""
    if smoke:
        apps = []
        for i in range(SMOKE_APPS):
            snn = small_app(300, 5200, seed=40 + i)
            snn.name = f"smoke{i}"
            apps.append(snn)
        return apps
    return [build_app(name) for name in APP_NAMES[:n_apps]]


def _churn_hw(smoke: bool) -> HardwareConfig:
    """16 tiles for the Table-1 churn; 8 (a 2x4 rectangular mesh) for
    --smoke so the synthetic tenants actually contend — joint placement
    has nothing to fix on an uncontended chip."""
    return dataclasses.replace(DYNAP_SE, n_tiles=8) if smoke else HW16


def _drive_churn(ctl: AdmissionController, apps, rounds: int, seed: int):
    """One deterministic churn schedule; returns per-operation snapshots.

    The schedule (requests, finish/evict picks) depends only on the rng
    seed and the app list — NOT on admission outcomes — so the isolated
    and joint controllers serve identical workloads and their snapshots
    compare one-to-one.  ``apps`` may be SNNs or pre-clustered apps.
    """
    rng = np.random.default_rng(seed)
    names = [getattr(a, "name", None) or a.snn.name for a in apps]
    for a in apps:
        ctl.register(a)
    snapshots = []

    def snap(op: str):
        m = ctl.chip_metrics()
        snapshots.append({
            "op": op,
            "n_resident": 0 if m is None else m["n_resident"],
            "chip_period": np.nan if m is None else m["chip_period"],
            "chip_throughput": 0.0 if m is None else m["chip_throughput"],
            "chip_energy": np.nan if m is None else m["chip_energy"],
            "chip_noc_traffic": (
                np.nan if m is None else m["chip_noc_traffic"]
            ),
        })

    for _ in range(rounds):
        for name in names:
            req = int(rng.integers(2, 5))
            try:
                ctl.admit(name, n_tiles_request=req)
            except AdmissionError:
                pass
            snap(f"admit:{name}")
        drop = [names[i] for i in rng.permutation(len(names))]
        for name in drop[: len(drop) // 2]:          # finish half...
            if name in ctl.running():
                ctl.finish(name)
            snap(f"finish:{name}")
        for name in drop[len(drop) // 2 : (3 * len(drop)) // 4]:
            if name in ctl.running():                # ...evict a quarter
                ctl.evict(name)
            snap(f"evict:{name}")
    return snapshots


def churn_bench(*, smoke: bool = False, n_apps: int = 8, rounds: int = 2,
                joint_budget=(2, 12), seed: int = 0):
    """Serve the same churn isolated and joint; compare chip metrics."""
    # partition once, share the clustered apps across both controllers
    # (register() accepts ClusteredSNN, so neither pays Alg. 1 twice)
    hw = _churn_hw(smoke)
    apps = [
        partition_greedy(snn, hw) for snn in _churn_apps(smoke, n_apps)
    ]
    results = {}
    walls = {}
    for placement in ("isolated", "joint"):
        ctl = AdmissionController(
            hw, placement=placement, joint_budget=joint_budget,
            track_chip_metrics=True,
        )
        t0 = time.perf_counter()
        snaps = _drive_churn(ctl, apps, rounds, seed)
        walls[placement] = time.perf_counter() - t0
        results[placement] = {
            "snapshots": snaps,
            "n_rebalances": sum(
                1 for e in ctl.events if e.kind == "rebalance"
            ),
            "trajectory": ctl.trajectory(),
        }

    # mean over the snapshots where BOTH runs had residents (one-to-one
    # comparable: the schedule is outcome-independent)
    iso, joi = results["isolated"]["snapshots"], results["joint"]["snapshots"]
    assert len(iso) == len(joi), "churn schedules diverged"
    both = [
        (a, b) for a, b in zip(iso, joi)
        if a["n_resident"] > 0 and b["n_resident"] > 0
    ]
    thr_iso = float(np.mean([a["chip_throughput"] for a, _ in both]))
    thr_joi = float(np.mean([b["chip_throughput"] for _, b in both]))
    e_iso = float(np.mean([a["chip_energy"] for a, _ in both]))
    e_joi = float(np.mean([b["chip_energy"] for _, b in both]))
    thr_gain = (thr_joi - thr_iso) / max(thr_iso, 1e-300)
    e_gain = (e_iso - e_joi) / max(e_iso, 1e-300)
    ok = thr_joi > thr_iso * (1 + 1e-9) or e_joi < e_iso * (1 - 1e-9)

    rows = [
        ("metric", "isolated", "joint", "gain"),
        ("mean_chip_throughput", f"{thr_iso:.6e}", f"{thr_joi:.6e}",
         f"{thr_gain:+.2%}"),
        ("mean_chip_energy_pj", f"{e_iso:.1f}", f"{e_joi:.1f}",
         f"{e_gain:+.2%}"),
        ("rebalances", 0, results["joint"]["n_rebalances"], ""),
        ("wall_s", f"{walls['isolated']:.2f}", f"{walls['joint']:.2f}", ""),
    ]
    payload = {
        "n_apps": len(apps),
        "rounds": rounds,
        "joint_budget": list(joint_budget),
        "mean_chip_throughput": {"isolated": thr_iso, "joint": thr_joi},
        "mean_chip_energy_pj": {"isolated": e_iso, "joint": e_joi},
        "throughput_gain": thr_gain,
        "energy_gain": e_gain,
        "joint_improves": bool(ok),
        "wall_s": walls,
        "isolated": results["isolated"],
        "joint": results["joint"],
    }
    return rows, payload, ok


# ======================================================================
# section 2: (period, energy) Pareto front per application
# ======================================================================
def pareto_bench(apps=None, *, population: int = 24, generations: int = 3,
                 rng_seed: int = 0, smoke: bool = False):
    """Per-app exact Pareto fronts from the pareto-objective optimizer."""
    per_app = []
    ok = True
    if apps is None:
        apps = (
            [s.name for s in _churn_apps(True, SMOKE_APPS)] if smoke
            else APP_NAMES
        )
    for name in apps:
        snn = (
            small_app(170, 2100, seed=40 + int(name[-1]))
            if smoke else build_app(name)
        )
        if smoke:
            snn.name = name
        cl = partition_greedy(snn, DYNAP_SE)
        t0 = time.perf_counter()
        rep = optimize_binding(
            cl, DYNAP_SE, population=population, generations=generations,
            rng_seed=rng_seed, objective="pareto",
        )
        never_worse = rep.period <= rep.best_seed_period * (1 + 1e-9)
        ok = ok and never_worse and len(rep.front) >= 1
        per_app.append({
            "app": name,
            "n_clusters": int(cl.n_clusters),
            "front": [
                {"period_us": pt.period, "energy_pj": pt.energy}
                for pt in rep.front
            ],
            "best_period_us": rep.period,
            "best_seed_period_us": rep.best_seed_period,
            "min_energy_pj": min(pt.energy for pt in rep.front),
            "seed_energies_pj": rep.seed_energies,
            "never_worse_than_seeds": bool(never_worse),
            "wall_s": time.perf_counter() - t0,
        })
    rows = [("app", "clusters", "front_size", "best_period_us",
             "min_energy_pj", "never_worse")]
    for r in per_app:
        rows.append((
            r["app"], r["n_clusters"], len(r["front"]),
            f"{r['best_period_us']:.4f}", f"{r['min_energy_pj']:.1f}",
            r["never_worse_than_seeds"],
        ))
    payload = {"population": population, "generations": generations,
               "apps": per_app}
    return rows, payload, ok


# ======================================================================
def run(out_path: str = "BENCH_energy.json", *, smoke: bool = False,
        n_apps: int = 8, rounds: int = 2):
    """Run both sections and write the JSON artifact.

    Returns ``(rows, summary, ok)`` in the benchmarks/run.py convention.
    """
    c_rows, c_payload, c_ok = churn_bench(
        smoke=smoke, n_apps=n_apps, rounds=rounds,
    )
    p_rows, p_payload, p_ok = pareto_bench(smoke=smoke)
    from .common import write_bench
    write_bench(out_path,
                {"churn_bench": c_payload, "pareto_bench": p_payload})
    rows = c_rows + [("--", "--", "--", "--")] + p_rows
    ok = c_ok and p_ok
    summary = (
        f"joint vs isolated churn: throughput "
        f"{c_payload['throughput_gain']:+.2%}, energy "
        f"{c_payload['energy_gain']:+.2%} "
        f"(improves: {'PASS' if c_ok else 'MISS'}); pareto fronts on "
        f"{len(p_payload['apps'])} apps, never worse than seeds: "
        f"{'PASS' if p_ok else 'MISS'}; wrote {out_path}"
    )
    return rows, summary, ok


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_energy.json")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run: 3 synthetic apps, 1 round")
    ap.add_argument("--apps", type=int, default=8)
    ap.add_argument("--rounds", type=int, default=2)
    args = ap.parse_args()

    rows, summary, ok = run(
        args.out, smoke=args.smoke,
        n_apps=args.apps, rounds=1 if args.smoke else args.rounds,
    )
    print("# energy")
    for row in rows:
        print(",".join(str(x) for x in row))
    print("##", summary)
    if not ok:
        raise SystemExit(1)


if __name__ == "__main__":
    main()

"""Throughput-in-the-loop binding optimizer benchmark (closing §4.2's loop).

  PYTHONPATH=src python -m benchmarks.binding_opt             # all 8 apps
  PYTHONPATH=src python -m benchmarks.binding_opt --quick     # 3 small apps
  PYTHONPATH=src python -m benchmarks.run binding_opt         # via the runner

Two sections, both recorded into ``BENCH_binding_opt.json``:

  1. *Optimizer vs heuristics* — for every Table-1 application, run
     :func:`repro.core.optimize.optimize_binding` (>= 64-candidate
     generations, each scored by ONE batched engine call) and compare the
     exact steady-state period against the three §4.2/§6.3 heuristic
     binders.  Acceptance: strictly better than the best heuristic on
     >= 6 of the 8 apps and never worse on any (the seeds are in the
     final scoring pool, so "never worse" is structural).
  2. *Population scaling* — wall-clock per generation as the population
     grows (one EdgeStack build + one ``mcr_batch`` per generation, so
     per-candidate cost should fall with batch size).
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.core import (
    APP_NAMES,
    DYNAP_SE,
    build_app,
    optimize_binding,
    partition_greedy,
    single_tile_order,
)

QUICK_APPS = ("ImgSmooth", "MLP-MNIST", "CNN-MNIST")
BEAT_TOL = 1e-6       # relative period margin that counts as a win


# ======================================================================
# section 1: optimizer vs the three heuristic binders, per application
# ======================================================================
def optimizer_bench(apps, *, population=64, generations=8, rng_seed=0):
    """Optimize every app's binding; compare against the heuristic seeds."""
    per_app = []
    for name in apps:
        cl = partition_greedy(build_app(name), DYNAP_SE)
        order, _ = single_tile_order(cl, DYNAP_SE)
        t0 = time.perf_counter()
        rep = optimize_binding(
            cl, DYNAP_SE, single_order=order,
            population=population, generations=generations, rng_seed=rng_seed,
        )
        wall = time.perf_counter() - t0
        gen_walls = [h.wall_s for h in rep.history]
        per_app.append({
            "app": name,
            "n_clusters": int(cl.n_clusters),
            "period_optimized_us": rep.period,
            "period_seeds_us": rep.seed_periods,
            "period_best_seed_us": rep.best_seed_period,
            "period_ours_us": rep.seed_periods["ours"],
            "improvement_vs_best_seed": rep.improvement,
            "improvement_vs_ours": (
                (rep.seed_periods["ours"] - rep.period)
                / rep.seed_periods["ours"]
            ),
            "beat_best_seed": bool(rep.improvement > BEAT_TOL),
            "never_worse": bool(rep.period <= rep.best_seed_period * (1 + 1e-9)),
            "wall_s": wall,
            "wall_per_generation_s": float(np.mean(gen_walls)),
            "n_stack_builds": rep.n_stack_builds,
            "one_build_per_generation": bool(
                rep.n_stack_builds == generations + 1
            ),
        })
    wins = sum(a["beat_best_seed"] for a in per_app)
    all_never_worse = all(a["never_worse"] for a in per_app)
    rows = [("app", "clusters", "best_heuristic_us", "optimized_us",
             "improv_vs_best", "improv_vs_ours", "wall_s", "s_per_gen")]
    for a in per_app:
        rows.append((
            a["app"], a["n_clusters"],
            f"{a['period_best_seed_us']:.4f}",
            f"{a['period_optimized_us']:.4f}",
            f"{a['improvement_vs_best_seed'] * 100:.3f}%",
            f"{a['improvement_vs_ours'] * 100:.3f}%",
            f"{a['wall_s']:.1f}", f"{a['wall_per_generation_s']:.2f}",
        ))
    payload = {
        "population": population,
        "generations": generations,
        "rng_seed": rng_seed,
        "beat_tolerance_rel": BEAT_TOL,
        "apps": per_app,
        "wins": int(wins),
        "n_apps": len(per_app),
        "all_never_worse": all_never_worse,
    }
    return rows, payload, wins, all_never_worse


# ======================================================================
# section 2: wall-clock per generation vs population size
# ======================================================================
def scaling_bench(app_name="CNN-MNIST", *, populations=(16, 32, 64, 128),
                  generations=2, rng_seed=0):
    """One batched call scores the whole generation: per-candidate cost
    must fall as the population grows."""
    cl = partition_greedy(build_app(app_name), DYNAP_SE)
    order, _ = single_tile_order(cl, DYNAP_SE)
    points = []
    for pop in populations:
        rep = optimize_binding(
            cl, DYNAP_SE, single_order=order,
            population=pop, generations=generations, rng_seed=rng_seed,
        )
        per_gen = float(np.mean([h.wall_s for h in rep.history]))
        points.append({
            "population": pop,
            "wall_per_generation_s": per_gen,
            "wall_per_candidate_ms": 1e3 * per_gen / pop,
            "period_us": rep.period,
        })
    rows = [("population", "s_per_gen", "ms_per_candidate", "period_us")]
    for p in points:
        rows.append((
            p["population"], f"{p['wall_per_generation_s']:.3f}",
            f"{p['wall_per_candidate_ms']:.2f}", f"{p['period_us']:.4f}",
        ))
    payload = {"app": app_name, "generations": generations, "points": points}
    return rows, payload


# ======================================================================
def run(out_path: str = "BENCH_binding_opt.json", *, apps=APP_NAMES,
        population: int = 64, generations: int = 8,
        scaling_app: str = "CNN-MNIST"):
    """Run both sections and write ``BENCH_binding_opt.json``.

    Returns ``(rows, summary, ok)`` in the benchmarks/run.py convention;
    ``ok`` is the acceptance check (wins on >= 6 of the 8 Table-1 apps —
    scaled proportionally for --quick runs — and never worse on any).
    """
    o_rows, o_payload, wins, never_worse = optimizer_bench(
        apps, population=population, generations=generations
    )
    s_rows, s_payload = scaling_bench(scaling_app, generations=2)
    from .common import write_bench
    write_bench(out_path,
                {"optimizer_bench": o_payload, "scaling_bench": s_payload})
    need = max(1, (6 * len(apps)) // 8)      # 6-of-8, scaled for --quick
    ok = wins >= need and never_worse
    rows = o_rows + [("--",) * 8] + s_rows
    summary = (
        f"optimizer beats best heuristic on {wins}/{len(apps)} apps "
        f"(target >= {need}: {'PASS' if wins >= need else 'MISS'}); "
        f"never worse: {never_worse}; "
        f"{population}-candidate generations, one EdgeStack build each; "
        f"wrote {out_path}"
    )
    return rows, summary, ok


def main() -> None:
    """CLI entry point (see module docstring for usage)."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_binding_opt.json")
    ap.add_argument("--quick", action="store_true",
                    help="3 small apps + smaller scaling app")
    ap.add_argument("--population", type=int, default=64)
    ap.add_argument("--generations", type=int, default=8)
    args = ap.parse_args()

    if args.population < 64:
        ap.error("--population must be >= 64 (the acceptance target scores "
                 ">= 64-candidate generations)")
    apps = QUICK_APPS if args.quick else APP_NAMES
    scaling_app = "MLP-MNIST" if args.quick else "CNN-MNIST"
    rows, summary, ok = run(
        args.out, apps=apps, population=args.population,
        generations=args.generations, scaling_app=scaling_app,
    )
    print("# binding_opt")
    for row in rows:
        print(",".join(str(x) for x in row))
    print("##", summary)
    if not ok:
        raise SystemExit(1)


if __name__ == "__main__":
    main()

"""Shared harness: compile every Table-1 application once per configuration
and cache the results for all figure benchmarks."""

from __future__ import annotations

import dataclasses
import functools
import json
import time

import numpy as np

from repro.core import (
    DYNAP_SE,
    APP_NAMES,
    HardwareConfig,
    analyze_throughput,
    bind_ours,
    bind_pycarl,
    bind_spinemap,
    build_app,
    build_static_orders,
    mcr_howard,
    partition_greedy,
    random_orders,
    sdfg_from_clusters,
)
from repro.core.schedule import random_order_throughput

BINDERS = {"spinemap": bind_spinemap, "pycarl": bind_pycarl, "ours": bind_ours}


def device_metadata() -> dict:
    """Execution-environment stamp for every BENCH_*.json artifact.

    CPU interpret-mode numbers and real-accelerator numbers share one
    schema, so without this stamp a stored baseline is ambiguous about
    what produced it.  Records the jax version, backend, device kind and
    count (forced host devices via ``--xla_force_host_platform_device_
    count`` show up here), and whether the Pallas kernels run in
    interpret mode on this host (True everywhere but TPU — see
    ``repro.kernels.ops``).
    """
    import jax

    from repro.kernels.ops import _on_tpu

    devs = jax.devices()
    return {
        "jax_version": jax.__version__,
        "backend": jax.default_backend(),
        "device_kind": devs[0].device_kind if devs else "none",
        "device_count": len(devs),
        "pallas_interpret_mode": not _on_tpu(),
    }


def write_bench(out_path: str, payload: dict) -> None:
    """Write one BENCH_*.json with the device/backend stamp attached.

    All benchmark mains route their artifact through here: ``payload``
    gains an ``"env"`` section (:func:`device_metadata`) alongside the
    benchmark's own sections.
    """
    payload = dict(payload)
    payload["env"] = device_metadata()
    with open(out_path, "w") as fh:
        json.dump(payload, fh, indent=2)


@functools.lru_cache(maxsize=None)
def clustered_app(name: str, n_tiles: int = 4):
    hw = dataclasses.replace(DYNAP_SE, n_tiles=n_tiles)
    snn = build_app(name)
    cl = partition_greedy(snn, hw)
    app = sdfg_from_clusters(cl, hw=hw)
    return hw, snn, cl, app


@functools.lru_cache(maxsize=None)
def binding_for(name: str, strategy: str, n_tiles: int = 4):
    hw, _, cl, _ = clustered_app(name, n_tiles)
    t0 = time.perf_counter()
    res = BINDERS[strategy](cl, hw)
    return res, time.perf_counter() - t0


@functools.lru_cache(maxsize=None)
def throughput_of(name: str, strategy: str, order_kind: str, n_tiles: int = 4):
    """order_kind: 'random' | 'static'. Returns (throughput, sched_time_s).

    'static' is the analytical 1/MCM of the order-augmented graph (equal to
    self-timed steady state — tests assert this); 'random' is the
    operational mean over random firing priorities (§6.3 baselines)."""
    hw, _, cl, app = clustered_app(name, n_tiles)
    res, _ = binding_for(name, strategy, n_tiles)
    if order_kind == "random":
        return random_order_throughput(app, res.binding, hw), 0.0
    orders, t_sched = build_static_orders(app, res.binding, hw)
    return analyze_throughput(app, res.binding, hw, orders), t_sched


@functools.lru_cache(maxsize=None)
def infinite_resource_throughput(name: str) -> float:
    _, _, _, app = clustered_app(name)
    rho = mcr_howard(app)
    return 0.0 if rho <= 0 or not np.isfinite(rho) else 1.0 / rho

"""Roofline table builder: reads dry-run artifacts (benchmarks/artifacts/
dryrun/*.json) and emits the per-(arch x shape) three-term roofline rows
used in EXPERIMENTS.md §Roofline."""

from __future__ import annotations

import json
import pathlib

ART = pathlib.Path(__file__).resolve().parent / "artifacts" / "dryrun"


def load_cells(mesh: str = "256"):
    cells = {}
    for f in sorted(ART.glob(f"*__{mesh}.json")):
        rec = json.loads(f.read_text())
        cells[(rec["arch"], rec["shape"])] = rec
    return cells


def rows(mesh: str = "256"):
    out = [(
        "arch", "shape", "status", "t_compute_s", "t_memory_s",
        "t_collective_s", "dominant", "model_flops", "hlo_flops_global",
        "useful_ratio", "peak_arg_GB", "temp_GB",
    )]
    for (arch, shape), rec in sorted(load_cells(mesh).items()):
        if "error" in rec:
            out.append((arch, shape, "ERROR", *[""] * 9))
            continue
        if "skipped" in rec:
            out.append((arch, shape, f"skip:{rec['skipped']}", *[""] * 9))
            continue
        r = rec["roofline"]
        cc = rec.get("cost_corrected", rec["cost"])
        global_flops = cc["flops"] * rec["chips"]
        out.append((
            arch, shape, "ok",
            f"{r['t_compute_s']:.4g}", f"{r['t_memory_s']:.4g}",
            f"{r['t_collective_s']:.4g}", r["dominant"],
            f"{rec['model_flops']:.3e}", f"{global_flops:.3e}",
            f"{rec['model_flops'] / global_flops:.3f}",
            f"{(rec['memory']['argument_bytes'] or 0) / 2**30:.1f}",
            f"{(rec['memory']['temp_bytes'] or 0) / 2**30:.1f}",
        ))
    return out


def bottleneck_summary(mesh: str = "256"):
    counts: dict[str, int] = {}
    for rec in load_cells(mesh).values():
        if "roofline" in rec:
            d = rec["roofline"]["dominant"]
            counts[d] = counts.get(d, 0) + 1
    return counts

"""One function per paper table/figure (§7).  Each returns CSV-ish rows and
is registered in run.py.  All throughputs are analytical (1/MCM of the
hardware-aware SDFG) exactly as the paper computes them; Fig. 17 also runs
the operational self-timed executor."""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import (
    DYNAP_SE,
    APP_NAMES,
    HardwareState,
    measured_throughput,
    runtime_admit,
    single_tile_order,
    verify_deadlock_free,
)

from . import common


# ======================================================================
def fig1_gap():
    """Fig. 1: throughput on limited hardware vs unlimited resources."""
    rows = [("app", "thr_unlimited", "thr_current_practice", "thr_ours",
             "gap_current_%", "gap_ours_%")]
    for name in APP_NAMES:
        inf = common.infinite_resource_throughput(name)
        cur, _ = common.throughput_of(name, "spinemap", "random")
        ours, _ = common.throughput_of(name, "ours", "static")
        rows.append((
            name, f"{inf:.4e}", f"{cur:.4e}", f"{ours:.4e}",
            f"{100 * (1 - cur / inf):.1f}", f"{100 * (1 - ours / inf):.1f}",
        ))
    return rows


def fig13_throughput():
    """Fig. 13: ours vs SpiNeMap vs PyCARL, normalized to SpiNeMap."""
    rows = [("app", "spinemap", "pycarl_norm", "ours_norm")]
    ratios_p, ratios_o = [], []
    for name in APP_NAMES:
        base, _ = common.throughput_of(name, "spinemap", "random")
        pyc, _ = common.throughput_of(name, "pycarl", "random")
        ours, _ = common.throughput_of(name, "ours", "static")
        rows.append((name, "1.00", f"{pyc / base:.2f}", f"{ours / base:.2f}"))
        ratios_p.append(pyc / base)
        ratios_o.append(ours / base)
    rows.append(("GEOMEAN", "1.00",
                 f"{np.exp(np.mean(np.log(ratios_p))):.2f}",
                 f"{np.exp(np.mean(np.log(ratios_o))):.2f}"))
    return rows


def fig14_binding():
    """Fig. 14: binding ablation — SpiNeMap+random vs SpiNeMap+static vs
    ours(+static): load balance matters beyond scheduling."""
    rows = [("app", "spinemap_random", "spinemap_static", "ours_static")]
    for name in APP_NAMES:
        base, _ = common.throughput_of(name, "spinemap", "random")
        s_static, _ = common.throughput_of(name, "spinemap", "static")
        ours, _ = common.throughput_of(name, "ours", "static")
        rows.append((name, "1.00", f"{s_static / base:.2f}", f"{ours / base:.2f}"))
    return rows


def fig15_compile_time():
    """Fig. 15: compile time split into binding vs schedule construction."""
    rows = [("app", "bind_ms", "schedule_ms", "schedule_frac_%")]
    for name in APP_NAMES:
        _, t_bind = common.binding_for(name, "ours")
        _, t_sched = common.throughput_of(name, "ours", "static")
        total = t_bind + t_sched
        rows.append((
            name, f"{1e3 * t_bind:.1f}", f"{1e3 * t_sched:.1f}",
            f"{100 * t_sched / total:.1f}",
        ))
    return rows


def table2_utilization():
    """Table 2: resource utilization on DYNAP-SE (never exceeds 100%)."""
    rows = [("app", "tile_io_%", "buffer_%", "connections_%",
             "bw_in_%", "bw_out_%")]
    for name in APP_NAMES:
        hw, _, cl, app = common.clustered_app(name)
        res, _ = common.binding_for(name, "ours")
        xbar = hw.tile.crossbar
        util = cl.utilization(xbar)
        # buffer: fraction of output buffer used by the busiest cluster
        buf = float(np.max(cl.out_spikes) / hw.tile.output_buffer)
        # connections: distinct inter-tile links used / links available
        links = set()
        for (i, j) in cl.channel_spikes:
            ti, tj = res.binding[i], res.binding[j]
            if ti != tj:
                links.add((min(ti, tj), max(ti, tj)))
        conn = len(links) / (hw.n_tiles * hw.tile.connections / 2)
        # bandwidth: spikes crossing tiles per period vs link capacity
        period = 1.0 / max(common.throughput_of(name, "ours", "static")[0], 1e-12)
        cross = sum(
            r for (i, j), r in cl.channel_spikes.items()
            if res.binding[i] != res.binding[j]
        )
        cap = period / (hw.t_spike_encode + hw.t_spike_link)  # spikes/period/link
        bw = cross / max(hw.n_tiles * cap, 1e-9)
        for v in (util["io"], buf, conn, bw):
            assert v <= 1.0 + 1e-9, f"{name}: utilization {v} exceeds 100%"
        rows.append((
            name, f"{100 * util['io']:.1f}", f"{100 * buf:.2f}",
            f"{100 * conn:.1f}", f"{100 * bw:.2f}", f"{100 * bw:.2f}",
        ))
    return rows


def fig16_scalability():
    """Fig. 16: ours on 4/9/16 tiles, normalized to SpiNeMap on 4 tiles."""
    rows = [("app", "tiles4", "tiles9", "tiles16")]
    for name in APP_NAMES:
        base, _ = common.throughput_of(name, "spinemap", "random", 4)
        vals = []
        for n_tiles in (4, 9, 16):
            thr, _ = common.throughput_of(name, "ours", "static", n_tiles)
            vals.append(thr / base)
        rows.append((name, *(f"{v:.2f}" for v in vals)))
    return rows


def fig17_runtime_and_table3():
    """Fig. 17 + Table 3: run-time admission (single-tile order projection)
    vs design-time per-tile schedules; compile-time reduction."""
    rows = [("app", "design_thr_norm", "runtime_thr_norm", "runtime_vs_design_%",
             "design_ms", "runtime_ms", "reduction_%", "deadlock_free")]
    for name in APP_NAMES:
        hw, _, cl, app = common.clustered_app(name)
        base, _ = common.throughput_of(name, "spinemap", "random")
        design, t_sched = common.throughput_of(name, "ours", "static")
        _, t_bind = common.binding_for(name, "ours")
        t_design = t_bind + t_sched

        order, _ = single_tile_order(cl, hw)
        state = HardwareState(hw)
        report = runtime_admit(cl, state, order)
        ok = verify_deadlock_free(cl, hw, report, iterations=4)
        t_runtime = report.compile_time_s
        rows.append((
            name,
            f"{design / base:.2f}",
            f"{report.throughput / base:.2f}",
            f"{100 * report.throughput / design:.1f}",
            f"{1e3 * t_design:.1f}",
            f"{1e3 * t_runtime:.1f}",
            f"{100 * (1 - t_runtime / t_design):.1f}",
            str(ok),
        ))
    return rows


ALL = {
    "fig1_gap": fig1_gap,
    "fig13_throughput": fig13_throughput,
    "fig14_binding": fig14_binding,
    "fig15_compile_time": fig15_compile_time,
    "table2_utilization": table2_utilization,
    "fig16_scalability": fig16_scalability,
    "fig17_table3_runtime": fig17_runtime_and_table3,
}

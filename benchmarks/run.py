"""Benchmark entry point: one function per paper table/figure + the LM
roofline table from dry-run artifacts.  Prints CSV blocks.

  PYTHONPATH=src python -m benchmarks.run              # everything
  PYTHONPATH=src python -m benchmarks.run fig13        # one benchmark
  PYTHONPATH=src python -m benchmarks.run admission    # + BENCH_admission.json
  PYTHONPATH=src python -m benchmarks.run binding_opt  # + BENCH_binding_opt.json
  PYTHONPATH=src python -m benchmarks.run compile      # + BENCH_compile.json
  PYTHONPATH=src python -m benchmarks.run energy       # + BENCH_energy.json
  PYTHONPATH=src python -m benchmarks.run stress       # + BENCH_stress.json (full 32x32)
  PYTHONPATH=src python -m benchmarks.run faults       # + BENCH_faults.json (failure storm)
  PYTHONPATH=src python -m benchmarks.run maxplus      # + BENCH_maxplus.json (backend sweep)
  PYTHONPATH=src python -m benchmarks.run serving      # + BENCH_serving.json (burst admissions)

The design-space sweep benchmark (batched Max-Plus vs per-graph loop)
lives in its own module:  PYTHONPATH=src python -m benchmarks.sweep
"""

from __future__ import annotations

import sys
import time


def main() -> None:
    from . import paper_figures, roofline

    want = sys.argv[1] if len(sys.argv) > 1 else None
    for name, fn in paper_figures.ALL.items():
        if want and want not in name:
            continue
        t0 = time.perf_counter()
        rows = fn()
        dt = time.perf_counter() - t0
        print(f"\n# {name}  ({dt:.1f}s)")
        for row in rows:
            print(",".join(str(x) for x in row))

    if want is None or "admission" in want:
        from . import admission

        t0 = time.perf_counter()
        rows, summary, _ = admission.run()
        print(f"\n# admission  ({time.perf_counter() - t0:.1f}s)")
        for row in rows:
            print(",".join(str(x) for x in row))
        print("##", summary)

    if want is None or "binding_opt" in want:
        from . import binding_opt

        t0 = time.perf_counter()
        rows, summary, _ = binding_opt.run()
        print(f"\n# binding_opt  ({time.perf_counter() - t0:.1f}s)")
        for row in rows:
            print(",".join(str(x) for x in row))
        print("##", summary)

    if want is None or "compile" in want:
        from . import compile_latency

        t0 = time.perf_counter()
        rows, summary, _ = compile_latency.run()
        print(f"\n# compile_latency  ({time.perf_counter() - t0:.1f}s)")
        for row in rows:
            print(",".join(str(x) for x in row))
        print("##", summary)

    if want is None or "energy" in want:
        from . import energy

        t0 = time.perf_counter()
        rows, summary, _ = energy.run()
        print(f"\n# energy  ({time.perf_counter() - t0:.1f}s)")
        for row in rows:
            print(",".join(str(x) for x in row))
        print("##", summary)

    if want is None or "stress" in want:
        from . import stress

        t0 = time.perf_counter()
        rows, summary, _ = stress.run(smoke=want is None)
        print(f"\n# stress  ({time.perf_counter() - t0:.1f}s)")
        for row in rows:
            print(",".join(str(x) for x in row))
        print("##", summary)

    if want is None or "faults" in want:
        from . import faults

        t0 = time.perf_counter()
        rows, summary, _ = faults.run(smoke=want is None)
        print(f"\n# faults  ({time.perf_counter() - t0:.1f}s)")
        for row in rows:
            print(",".join(str(x) for x in row))
        print("##", summary)

    if want is None or "maxplus" in want:
        from . import maxplus_backends

        t0 = time.perf_counter()
        rows, summary, _ = maxplus_backends.run(smoke=want is None)
        print(f"\n# maxplus_backends  ({time.perf_counter() - t0:.1f}s)")
        for row in rows:
            print(",".join(str(x) for x in row))
        print("##", summary)

    if want is None or "serving" in want:
        from . import serving

        t0 = time.perf_counter()
        rows, summary, _ = serving.run(smoke=want is None)
        print(f"\n# serving  ({time.perf_counter() - t0:.1f}s)")
        for row in rows:
            print(",".join(str(x) for x in row))
        print("##", summary)

    if want is None or "roofline" in want:
        print("\n# roofline_single_pod (from dry-run artifacts)")
        for row in roofline.rows("256"):
            print(",".join(str(x) for x in row))
        print("\n# dominant bottleneck counts:", roofline.bottleneck_summary())


if __name__ == "__main__":
    main()

"""Failure-storm harness: fault/drift recovery on a loaded 32x32 mesh.

  PYTHONPATH=src python -m benchmarks.faults              # 1024-tile run
  PYTHONPATH=src python -m benchmarks.faults --smoke      # 8x8 CI config
  PYTHONPATH=src python -m benchmarks.run faults          # via the runner

Loads the mesh with the PR-6 Zipf churn workload, then drives a Poisson
failure storm (:func:`repro.core.workloads.failure_storm`) through the
controller's fault runtime — tile failures, link throttles, spike-rate
drift, delayed heals — interleaved with continuing tenant churn.  Each
mutation triggers staleness detection and an incremental region
:meth:`~repro.core.runtime.AdmissionController.remap`.  Recorded into
``BENCH_faults.json``:

  * per-fault recovery latency (the full inject call including detection
    and remap), p50/p99;
  * the remap never-regress check: every remap's chip throughput vs. the
    minimally-repaired seed placement it started from
    (``seed_throughput``), per event;
  * dead-binding violations: after EVERY storm event, no resident may
    hold a dead tile (must stay zero);
  * displaced tenants: released with explicit ``"displaced"`` events
    when their component has no alive tile left (never silently lost);
  * throughput retention vs. FULL re-optimization under the SAME
    degraded chip at checkpoints outside the timed loop (>= 0.9 means
    incremental recovery kept >= 90% of what a from-scratch joint
    re-placement would get).

Acceptance (full run): per-fault recovery p99 < 1 s, zero never-regress
violations, zero dead bindings, nonzero recoveries, retention >= 0.9.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import time

import numpy as np

from repro.core import (
    DYNAP_SE,
    DYNAP_SE_1024,
    AdmissionController,
    AdmissionError,
)
from repro.core.workloads import failure_storm, workload_suite

from .stress import _percentiles, _tiles_request, _zipf_probs


def _dead_binding_violations(ctl) -> int:
    return sum(
        1
        for ts in ctl.running().values()
        if any(bool(ctl.chip.dead[int(t)]) for t in ts)
    )


def _churn_step(ctl, rng, names, probs, requests) -> str:
    name = names[int(rng.choice(len(names), p=probs))]
    if name in ctl.state.allocated:
        ctl.evict(name)
        return "evict"
    try:
        ctl.admit(name, n_tiles_request=requests[name])
        return "admit"
    except AdmissionError:
        return "reject"


def faults_bench(
    *,
    smoke: bool = False,
    n_tenants: int = 96,
    n_warmup: int = 160,
    n_faults: int = 30,
    churn_per_fault: int = 2,
    scale: float = 0.06,
    joint_budget: tuple[int, int] = (1, 6),
    n_checkpoints: int = 2,
    seed: int = 0,
):
    """Run the storm and return ``(rows, summary, ok)``.

    ``--smoke`` shrinks to 10 tenants / 4 faults on an 8x8 (64-tile)
    mesh — the CI tier-1 configuration.
    """
    if smoke:
        hw = dataclasses.replace(DYNAP_SE, n_tiles=64)
        n_tenants, n_warmup, n_faults = 10, 16, 6
        churn_per_fault, n_checkpoints = 1, 1
        storm_kw = dict(
            tiles_per_fault=1, heal_after=2.0,
            p_throttle=0.15, p_drift=0.15, max_dead_frac=0.15,
        )
    else:
        hw = DYNAP_SE_1024
        storm_kw = dict(
            tiles_per_fault=2, heal_after=4.0,
            p_throttle=0.15, p_drift=0.15, max_dead_frac=0.10,
        )
    mesh = hw.mesh_shape

    t0 = time.perf_counter()
    tenants = workload_suite(n_tenants, seed=seed, scale=scale)
    ctl = AdmissionController(
        hw,
        placement="joint",
        joint_budget=joint_budget,
        full_rebalance_every=0,   # checkpoints force fulls OUTSIDE the loop
    )
    requests = {}
    for snn in tenants:
        art = ctl.register(snn)
        requests[snn.name] = _tiles_request(art.clustered.n_clusters)
    design_wall_s = time.perf_counter() - t0

    rng = np.random.default_rng(seed + 1)
    probs = _zipf_probs(n_tenants)
    names = [s.name for s in tenants]

    # -- phase 1: churn warm-up loads the mesh ---------------------------
    warmup_t0 = time.perf_counter()
    for _ in range(n_warmup):
        _churn_step(ctl, rng, names, probs, requests)
    warmup_s = time.perf_counter() - warmup_t0
    baseline = ctl.chip_metrics()
    baseline_thr = baseline["chip_throughput"] if baseline else 0.0

    # -- phase 2: the storm, interleaved with continuing churn -----------
    # The generator's picks are uniform over the mesh; on a sparsely
    # loaded chip most would miss every resident, so each pick is mapped
    # onto the CURRENTLY-BOUND tiles / resident apps at injection time
    # (deterministic — the storm supplies the randomness, occupancy the
    # targets; a production chip at load faults under its tenants too).
    storm = failure_storm(
        n_faults, hw.n_tiles, seed=seed + 2,
        drift_apps=names, **storm_kw,
    )
    side = mesh[1]

    def _bound_tiles() -> list[int]:
        return sorted({
            int(t) for ts in ctl.running().values() for t in ts
        })

    def _target_link(a: int, horiz: bool) -> tuple[int, int]:
        bound = _bound_tiles()
        base = bound[a % len(bound)] if bound else a
        if horiz:
            nb = base + 1 if base % side + 1 < side else base - 1
        else:
            nb = base + side if base + side < hw.n_tiles else base - side
        return (min(base, nb), max(base, nb))
    rows = [(
        "event", "kind", "detail", "residents", "recovery_s",
        "displaced", "stale", "seed_throughput", "chip_throughput",
        "dead_tiles",
    )]
    recoveries: list[float] = []
    displaced_total = 0
    dead_binding_violations = 0
    heal_map: dict[tuple, tuple] = {}
    link_map: dict[tuple, tuple] = {}
    storm_t0 = time.perf_counter()
    for i, ev in enumerate(storm):
        for _ in range(churn_per_fault):
            _churn_step(ctl, rng, names, probs, requests)
        n_before = len(ctl.events)
        t_ev = time.perf_counter()
        if ev.kind == "fail":
            bound = [t for t in _bound_tiles() if not ctl.chip.dead[t]]
            tiles = tuple(sorted(
                {bound[t % len(bound)] for t in ev.tiles} if bound
                else {t for t in ev.tiles if not ctl.chip.dead[t]}
            ))
            heal_map[ev.tiles] = tiles
            if not tiles:
                continue
            disp = ctl.inject_fault(list(tiles))
        elif ev.kind == "heal" and ev.link is not None:
            link = link_map.pop(ev.link, None)
            if link is None or link not in ctl.chip.link_throttle:
                continue
            ev = dataclasses.replace(ev, link=link)
            disp = ctl.heal(links=[link])
        elif ev.kind == "heal":
            tiles = tuple(
                t for t in heal_map.pop(ev.tiles, ev.tiles)
                if ctl.chip.dead[t]
            )
            if not tiles:
                continue
            disp = ctl.heal(list(tiles))
        elif ev.kind == "throttle":
            a, b = ev.link
            link = _target_link(a, horiz=(b - a == 1))
            link_map[ev.link] = link
            ev = dataclasses.replace(ev, link=link)
            disp = ctl.inject_fault(links=[link], throttle=ev.factor)
        else:   # drift
            app = ev.app
            if app not in ctl.state.allocated:
                res = sorted(ctl.state.allocated)
                if not res:
                    continue
                app = res[i % len(res)]
                ev = dataclasses.replace(ev, app=app)
            disp = ctl.inject_drift(app, ev.factor)
        wall = time.perf_counter() - t_ev
        if ev.kind == "fail":
            recoveries.append(wall)
        displaced_total += len(disp)
        dead_binding_violations += _dead_binding_violations(ctl)
        new = ctl.events[n_before:]
        remaps = [e for e in new if e.kind == "remap"]
        detail = (
            f"link={ev.link}x{ev.factor:.2f}" if ev.link is not None
            else f"tiles={list(tiles)}" if ev.kind in ("fail", "heal")
            else f"{ev.app}x{ev.factor:.2f}"
        )
        rows.append((
            i, ev.kind, detail, len(ctl.state.allocated), round(wall, 4),
            len(disp),
            sum(len(e.app_throughputs) for e in remaps),
            round(remaps[-1].seed_throughput, 6) if remaps else 0.0,
            round(remaps[-1].chip_throughput, 6) if remaps else 0.0,
            int(ctl.chip.dead.sum()),
        ))
    storm_s = time.perf_counter() - storm_t0

    # -- never-regress: every remap vs. its repaired seed ----------------
    remap_events = [e for e in ctl.events if e.kind == "remap"]
    regressions = sum(
        1 for e in remap_events
        if e.seed_throughput > 0
        and e.chip_throughput < e.seed_throughput * (1 - 1e-6)
    )
    never_regressed = regressions == 0

    # -- retention checkpoints: full re-opt under the SAME degraded chip -
    retention: list[float] = []
    for _ in range(max(n_checkpoints, 0)):
        if len(ctl.state.allocated) < 2:
            break
        before = ctl.chip_metrics()
        t_full = time.perf_counter()
        ctl._rebalance_full()
        full_wall = time.perf_counter() - t_full
        after = ctl.chip_metrics()
        if before and after and after["chip_throughput"] > 0:
            retention.append(
                before["chip_throughput"] / after["chip_throughput"]
            )
        rows.append((
            "checkpoint", "full_rebalance", "*",
            len(ctl.state.allocated), round(full_wall, 4),
            0, 0, 0.0,
            round(after["chip_throughput"], 6) if after else 0.0,
            int(ctl.chip.dead.sum()),
        ))

    p50, p99 = _percentiles(recoveries)
    retention_min = min(retention, default=1.0)
    n_recovery_events = len(remap_events)

    # smoke runs a deliberately congested 8x8 where retention measures
    # churn packing rather than fault recovery; the perf gates (p99,
    # retention) bind only on the full 32x32 scenario.
    ok = (
        n_recovery_events > 0
        and never_regressed
        and dead_binding_violations == 0
        and (smoke or (p99 < 1.0 and retention_min >= 0.9))
    )
    summary = {
        "mesh": list(mesh),
        "n_tiles": hw.n_tiles,
        "n_tenants": n_tenants,
        "n_warmup": n_warmup,
        "n_faults": len(storm),
        "storm_kinds": {
            k: sum(1 for e in storm if e.kind == k)
            for k in ("fail", "heal", "throttle", "drift")
        },
        "tenant_scale": scale,
        "joint_budget": list(joint_budget),
        "design_wall_s": round(design_wall_s, 2),
        "warmup_s": round(warmup_s, 2),
        "storm_s": round(storm_s, 2),
        "baseline_throughput": round(baseline_thr, 6),
        "residents_at_storm_end": len(ctl.state.allocated),
        "dead_tiles_at_end": int(ctl.chip.dead.sum()),
        "recovery_events": n_recovery_events,
        "displaced": displaced_total,
        "recovery_p50_s": round(p50, 4),
        "recovery_p99_s": round(p99, 4),
        "never_regressed": never_regressed,
        "regressions": regressions,
        "dead_binding_violations": dead_binding_violations,
        "retention_vs_full": [round(r, 4) for r in retention],
        "retention_min": round(retention_min, 4),
        "ok": ok,
    }
    return rows, summary, ok


def run(out_path: str = "BENCH_faults.json", *, smoke: bool = False,
        **kw):
    rows, summary, ok = faults_bench(smoke=smoke, **kw)
    from .common import write_bench
    write_bench(out_path, {"faults_bench": summary})
    return rows, summary, ok


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_faults.json")
    ap.add_argument("--smoke", action="store_true",
                    help="10 tenants / 4 faults on an 8x8 mesh (CI tier-1)")
    ap.add_argument("--tenants", type=int, default=96)
    ap.add_argument("--warmup", type=int, default=160)
    ap.add_argument("--faults", type=int, default=30)
    ap.add_argument("--scale", type=float, default=0.06)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    rows, summary, ok = run(
        args.out, smoke=args.smoke, n_tenants=args.tenants,
        n_warmup=args.warmup, n_faults=args.faults, scale=args.scale,
        seed=args.seed,
    )
    for row in rows:
        print(",".join(str(x) for x in row))
    print("##", json.dumps(summary))
    print("OK" if ok else "FAILED")
    raise SystemExit(0 if ok else 1)


if __name__ == "__main__":
    main()

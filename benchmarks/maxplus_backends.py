"""Max-plus backend sweep: "edges" vs "csr-jit" vs "dense" (ISSUE 9).

  PYTHONPATH=src python -m benchmarks.maxplus_backends           # full sweep
  PYTHONPATH=src python -m benchmarks.maxplus_backends --smoke   # CI tier-1
  PYTHONPATH=src python -m benchmarks.run maxplus                # via runner

Times :func:`repro.core.maxplus.mcr_batch` across (B, n, E) stack shapes
and backends and cross-validates every backend against the numpy
``"edges"`` float64 oracle.  Two graph families:

  * **shortcut** — one-token rings carrying the PR-3 path-doubling
    shortcut edges plus random chords: the shape
    :func:`~repro.core.engine.stack_hardware_aware` actually emits with
    ``relax_shortcuts=True`` (hop diameter O(log n)).  This is the
    headline: the acceptance bar is ``"csr-jit"`` >= 3x faster than
    ``"edges"`` at B >= 64, n >= 256 with <= 1e-6 relative error.
  * **ring** — the same rings WITHOUT shortcuts: hop diameter n-1, the
    documented worst case for the blocked device sweep (each Bellman-
    Ford probe needs ~n rounds and the early-exit check can't save
    them), kept honest in the output rather than hidden.

The dense float32 squaring backend is probed at one small shape only
(Pallas interpret mode makes it minutes-slow at n >= 64 on CPU hosts)
together with its per-bisection squaring-round counts — evidence that
the shortcut-derived fixpoint exit (satellite a) beats the log2(n) cap.

``followups.shape_bucket_padding`` measures satellite (c): total
``"csr-jit"`` wall time over a burst of slightly-varying batch sizes
with and without :func:`~repro.core.engine.pad_stack_to_buckets` —
bucketing stabilizes the jitted program's (B*n, d_max) signature, so
padding wins whenever shapes churn (the engine's default
``pad_shapes=True`` for device backends).

Writes ``BENCH_maxplus.json`` (schema in README.md).
"""

from __future__ import annotations

import argparse
import json
import math
import time

import numpy as np

from repro.core import maxplus as mp
from repro.core.engine import pad_stack_to_buckets
from repro.core.maxplus import EdgeStack, mcr_batch

REL_ERR_BAR = 1e-6
SPEEDUP_BAR = 3.0


def make_stack(
    b: int, n: int, seed: int, *, shortcuts: bool, chords: int = 8
) -> EdgeStack:
    """One-token rings (+ random chords) with optional exact path-doubling
    shortcut edges — the synthetic twin of the engine's
    ``relax_shortcuts=True`` hardware-aware stacks."""
    r = np.random.default_rng(seed)
    src = np.broadcast_to(np.arange(n), (b, n)).copy()
    dst = (src + 1) % n
    tok = np.zeros_like(src)
    tok[:, -1] = 1
    w = r.uniform(0.5, 2.0, (b, n))
    srcs, dsts, toks, ws = [src], [dst], [tok.astype(np.float64)], [w]
    if shortcuts:
        cw, ct, nx = w.copy(), tok.astype(np.float64), dst.copy()
        span = 1
        while 2 * span < n:
            cw = cw + np.take_along_axis(cw, nx, axis=1)
            ct = ct + np.take_along_axis(ct, nx, axis=1)
            nx = np.take_along_axis(nx, nx, axis=1)
            span *= 2
            srcs.append(src)
            dsts.append(nx.copy())
            toks.append(ct.copy())
            ws.append(cw.copy())
    if chords:
        cs = r.integers(0, n, (b, chords))
        cd = r.integers(0, n, (b, chords))
        srcs.append(cs)
        dsts.append(cd)
        toks.append(np.ones((b, chords)))
        ws.append(r.uniform(0.1, 1.0, (b, chords)))
    return EdgeStack(
        n_actors=n,
        src=np.concatenate(srcs, axis=1),
        dst=np.concatenate(dsts, axis=1),
        tokens=np.concatenate(toks, axis=1).astype(np.int64),
        weights=np.concatenate(ws, axis=1),
    )


def _best_of(fn, repeats: int) -> tuple[float, np.ndarray]:
    best, out = float("inf"), None
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn()
        best = min(best, time.perf_counter() - t0)
    return best, out


def _rel_err(got: np.ndarray, ref: np.ndarray) -> float:
    """Max relative period error; non-finite rows must match exactly."""
    if not np.array_equal(np.isfinite(got), np.isfinite(ref)):
        return float("inf")
    fin = np.isfinite(ref)
    if not fin.any():
        return 0.0
    return float(
        (np.abs(got[fin] - ref[fin]) / np.maximum(1.0, np.abs(ref[fin])))
        .max()
    )


def _sweep_point(b: int, n: int, family: str, seed: int,
                 repeats: int) -> dict:
    stack = make_stack(b, n, seed, shortcuts=(family == "shortcut"))
    t_edges, ref = _best_of(
        lambda: mcr_batch(stack, backend="edges", rel_tol=1e-9), repeats
    )
    mcr_batch(stack, backend="csr-jit", rel_tol=1e-9)     # jit warmup
    t_csr, got = _best_of(
        lambda: mcr_batch(stack, backend="csr-jit", rel_tol=1e-9), repeats
    )
    return {
        "family": family,
        "B": b,
        "n": n,
        "E": stack.n_edges,
        "edges_s": round(t_edges, 4),
        "csr_jit_s": round(t_csr, 4),
        "speedup_csr_vs_edges": round(t_edges / t_csr, 3) if t_csr else 0.0,
        "max_rel_err": _rel_err(got, ref),
    }


def _dense_probe(b: int, n: int, seed: int) -> dict:
    """Small-shape dense probe: agreement + realized squaring rounds."""
    short = make_stack(b, n, seed, shortcuts=True, chords=0)
    plain = make_stack(b, n, seed, shortcuts=False, chords=0)
    cap = max(1, int(math.ceil(math.log2(max(n, 2)))))
    t0 = time.perf_counter()
    ref = mcr_batch(plain, backend="edges", rel_tol=1e-9)
    t_edges = time.perf_counter() - t0
    t0 = time.perf_counter()
    got = mcr_batch(short, backend="dense", rel_tol=1e-4)
    t_dense = time.perf_counter() - t0
    rounds_short = list(mp._DENSE_LAST_ROUNDS)
    mcr_batch(plain, backend="dense", rel_tol=1e-4)
    rounds_plain = list(mp._DENSE_LAST_ROUNDS)
    return {
        "B": b,
        "n": n,
        "sq_round_cap": cap,
        "mean_rounds_shortcut": round(float(np.mean(rounds_short)), 2),
        "mean_rounds_plain": round(float(np.mean(rounds_plain)), 2),
        "edges_s": round(t_edges, 4),
        "dense_s": round(t_dense, 4),
        "max_rel_err": _rel_err(got, ref),
        "rounds_reduced": float(np.mean(rounds_short))
        < float(np.mean(rounds_plain)),
    }


def _padding_followup(n: int, batches: list[int], seed: int) -> dict:
    """Satellite (c): does shape-bucket padding pay on the csr path?

    A burst of admissions never repeats the exact batch size; without
    bucketing every distinct B retraces the jitted bisection program.
    """
    stacks = [
        make_stack(b, n, seed + i, shortcuts=True)
        for i, b in enumerate(batches)
    ]

    def _run(pad: bool) -> float:
        t0 = time.perf_counter()
        for s in stacks:
            if pad:
                s, _ = pad_stack_to_buckets(s, None)
            mcr_batch(s, backend="csr-jit", rel_tol=1e-9)
        return time.perf_counter() - t0

    # each variant warms its own traces, then a timed pass re-enters them
    _run(False)
    raw_s = _run(False)
    _run(True)
    padded_s = _run(True)
    return {
        "n": n,
        "batch_sizes": batches,
        "csr_jit_raw_s": round(raw_s, 4),
        "csr_jit_padded_s": round(padded_s, 4),
        "padding_wins": padded_s < raw_s,
        "engine_default": "pad_shapes=True for dense/csr-jit",
    }


def maxplus_bench(*, smoke: bool = False, seed: int = 0,
                  repeats: int = 3):
    """Run the sweep; returns ``(rows, summary, ok)``."""
    if smoke:
        points = [(8, 32, "shortcut"), (8, 32, "ring")]
        repeats = 1
    else:
        points = [
            (16, 64, "shortcut"),
            (64, 256, "shortcut"),
            (128, 256, "shortcut"),
            (64, 256, "ring"),
        ]

    sweep = [
        _sweep_point(b, n, family, seed, repeats)
        for b, n, family in points
    ]
    agreement_ok = all(p["max_rel_err"] <= REL_ERR_BAR for p in sweep)

    headline = [
        p for p in sweep
        if p["family"] == "shortcut" and p["B"] >= 64 and p["n"] >= 256
    ]
    speedup_ok = smoke or (
        bool(headline)
        and all(p["speedup_csr_vs_edges"] >= SPEEDUP_BAR for p in headline)
    )

    followups = {}
    if not smoke:
        followups["dense_shortcut_rounds"] = _dense_probe(8, 32, seed)
        followups["shape_bucket_padding"] = _padding_followup(
            128, [57, 61, 64, 59, 63, 58, 62, 60], seed
        )
        agreement_ok = agreement_ok and (
            followups["dense_shortcut_rounds"]["max_rel_err"] <= 5e-4
        )

    ok = agreement_ok and speedup_ok
    summary = {
        "rel_err_bar": REL_ERR_BAR,
        "speedup_bar": SPEEDUP_BAR,
        "sweep": sweep,
        "followups": followups,
        "agreement_ok": agreement_ok,
        "speedup_ok": speedup_ok,
        "ok": ok,
    }
    rows = [("family", "B", "n", "E", "edges_s", "csr_jit_s",
             "speedup", "max_rel_err")]
    rows += [
        (p["family"], p["B"], p["n"], p["E"], p["edges_s"],
         p["csr_jit_s"], p["speedup_csr_vs_edges"],
         f"{p['max_rel_err']:.2e}")
        for p in sweep
    ]
    return rows, summary, ok


def run(out_path: str = "BENCH_maxplus.json", *, smoke: bool = False,
        **kw):
    rows, summary, ok = maxplus_bench(smoke=smoke, **kw)
    from .common import write_bench
    write_bench(out_path, {"maxplus_backends": summary})
    return rows, summary, ok


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_maxplus.json")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes, agreement-only (CI tier-1)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--repeats", type=int, default=3)
    args = ap.parse_args()
    rows, summary, ok = run(
        args.out, smoke=args.smoke, seed=args.seed, repeats=args.repeats
    )
    for row in rows:
        print(",".join(str(x) for x in row))
    print("##", json.dumps(summary))
    print("OK" if ok else "FAILED")
    raise SystemExit(0 if ok else 1)


if __name__ == "__main__":
    main()
